from .optimizers import (Optimizer, adamw, clip_by_global_norm, sgd,
                         sgd_momentum)
from .schedule import constant, cosine_decay, linear_warmup_cosine

__all__ = ["Optimizer", "adamw", "clip_by_global_norm", "constant",
           "cosine_decay", "linear_warmup_cosine", "sgd", "sgd_momentum"]
