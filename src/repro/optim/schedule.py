"""Learning-rate schedules (pure functions of the step counter)."""
from __future__ import annotations

import jax.numpy as jnp

F32 = jnp.float32


def constant(value: float):
    return lambda step: jnp.asarray(value, F32)


def cosine_decay(peak: float, total_steps: int, final_frac: float = 0.1):
    def sched(step):
        t = jnp.clip(step.astype(F32) / max(total_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return peak * (final_frac + (1 - final_frac) * cos)
    return sched


def linear_warmup_cosine(peak: float, warmup_steps: int, total_steps: int,
                         final_frac: float = 0.1):
    cos = cosine_decay(peak, max(total_steps - warmup_steps, 1), final_frac)

    def sched(step):
        s = step.astype(F32)
        warm = peak * s / max(warmup_steps, 1)
        return jnp.where(s < warmup_steps, warm, cos(s - warmup_steps))
    return sched
