"""Optimizers as pure pytree transforms (no optax dependency).

An :class:`Optimizer` is an (init, update) pair over arbitrary pytrees:

    state = opt.init(params)
    updates, state = opt.update(grads, state, params, step)
    params = tree_map(lambda p, u: p + u, params, updates)

The paper's algorithm is plain SGD (w <- w - eta * g, Eq. 2); AdamW is the
production default for the LM trainer. Optimizer states follow the sharding
of their parameters (same tree structure), so ZeRO-style placement is a
sharding-rule decision, not an optimizer change.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

F32 = jnp.float32

Schedule = Callable[[jax.Array], jax.Array]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jax.Array], Tuple[Any, Any]]


def _as_schedule(lr) -> Schedule:
    if callable(lr):
        return lr
    return lambda step: jnp.asarray(lr, F32)


def sgd(lr) -> Optimizer:
    """Plain SGD — exactly the paper's update (Eq. 2)."""
    sched = _as_schedule(lr)

    def init(params):
        return ()

    def update(grads, state, params, step):
        eta = sched(step)
        updates = jax.tree.map(lambda g: (-eta * g.astype(F32)).astype(
            g.dtype), grads)
        return updates, state

    return Optimizer(init, update)


def sgd_momentum(lr, momentum: float = 0.9, nesterov: bool = False
                 ) -> Optimizer:
    sched = _as_schedule(lr)

    def init(params):
        return jax.tree.map(lambda p: jnp.zeros_like(p, F32), params)

    def update(grads, m, params, step):
        eta = sched(step)
        m = jax.tree.map(lambda b, g: momentum * b + g.astype(F32), m, grads)
        if nesterov:
            upd = jax.tree.map(
                lambda b, g: -eta * (momentum * b + g.astype(F32)), m, grads)
        else:
            upd = jax.tree.map(lambda b: -eta * b, m)
        upd = jax.tree.map(lambda u, p: u.astype(p.dtype), upd, params)
        return upd, m

    return Optimizer(init, update)


class AdamState(NamedTuple):
    mu: Any
    nu: Any


def adamw(lr, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    sched = _as_schedule(lr)

    def init(params):
        zeros = lambda p: jnp.zeros_like(p, F32)
        return AdamState(mu=jax.tree.map(zeros, params),
                         nu=jax.tree.map(zeros, params))

    def update(grads, state, params, step):
        eta = sched(step)
        t = step.astype(F32) + 1.0
        c1 = 1.0 - b1 ** t
        c2 = 1.0 - b2 ** t
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(F32),
                          state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2)
                          * jnp.square(g.astype(F32)), state.nu, grads)

        def upd(m, v, p):
            u = -eta * (m / c1) / (jnp.sqrt(v / c2) + eps)
            if weight_decay:
                u = u - eta * weight_decay * p.astype(F32)
            return u.astype(p.dtype)

        updates = jax.tree.map(upd, mu, nu, params)
        return updates, AdamState(mu, nu)

    return Optimizer(init, update)


def clip_by_global_norm(grads, max_norm: float):
    """Standard global-norm gradient clip (returns clipped tree + norm)."""
    sq = sum(jnp.sum(g.astype(F32) ** 2) for g in jax.tree.leaves(grads))
    norm = jnp.sqrt(sq)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(F32) * scale).astype(g.dtype),
                        grads), norm
