"""Paged KV cache: ref-counted pages, prefix sharing, copy-on-write.

Device side, every attention layer owns a pool of ``num_pages`` pages of
``page_size`` token slots (``models.model.init_paged_cache``); logical
position t of a sequence lives at page ``block_table[t // page_size]``,
slot ``t % page_size`` — the same page index in every layer, so ONE block
table and ONE allocator serve the whole model. Page 0 is reserved as the
scratch page: padded / inactive-lane writes are directed there and its
contents are never attended (lengths mask them out).

Host side, :class:`PrefixPagePool` owns the redundancy-aware accounting
(DESIGN.md §11):

  * **Refcounts.** Every non-scratch page is FREE, CACHED (refcount 0
    but still holding indexed prefix content, reusable without a copy)
    or LIVE (refcount = number of sequences mapping it). Admission
    adopts shared pages with a refcount bump; release decrements and
    only recycles at zero, so a preempted request can never free a page
    another sequence still maps.
  * **Prefix index.** Full pages are content-addressed by a token hash
    chain: ``key_b = (key_{b-1}, tokens[b*ps:(b+1)*ps])`` (exact nested
    tuples — no hash collisions to handle). A new request walks the
    chain and adopts every fully-matching page; only the suffix from
    the first divergent token gets private pages and prefill compute.
  * **Copy-on-write.** When the divergence lands mid-page, the best
    matching indexed page is adopted *partially*: its contents are
    copied into the request's first private page before the first
    divergent write (``copy_pages`` is the device op), so shared pages
    themselves are never written. Refcounted pages with refcount > 1
    are immutable by construction — writes only ever target the
    sequence's private tail.

:class:`BlockAllocator` (the PR 3 free-list allocator, no sharing) is
kept for the contiguous-cache adapters' tests and as the simplest
reference; :class:`PagedKVCache` now runs on :class:`PrefixPagePool`.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.models.nn import split_params

SCRATCH_PAGE = 0

# a prefix key is the nested tuple (parent_key, page_tokens); the root
# parent is None — structural equality makes matching exact, not hashed
PrefixKey = Tuple[Optional[tuple], Tuple[int, ...]]


class BlockAllocator:
    """Free-list page allocator; page 0 (scratch) is never handed out."""

    def __init__(self, num_pages: int):
        if num_pages < 2:
            raise ValueError("need num_pages >= 2 (page 0 is scratch)")
        self.num_pages = num_pages
        self._free: List[int] = list(range(num_pages - 1, 0, -1))
        self._free_set = set(self._free)     # O(1) double-free guard

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def capacity(self) -> int:
        """Allocatable pages (total minus the scratch page)."""
        return self.num_pages - 1

    def alloc(self, n: int) -> Optional[List[int]]:
        """Pop ``n`` pages, or None (and no change) if not enough free."""
        if n > len(self._free):
            return None
        if n <= 0:
            return []
        out = self._free[-n:][::-1]
        del self._free[len(self._free) - n:]
        self._free_set.difference_update(out)
        return out

    def free(self, pages: List[int]) -> None:
        for p in pages:
            if p == SCRATCH_PAGE:
                raise ValueError("cannot free the scratch page")
            if p in self._free_set:
                raise ValueError(f"double free of page {p}")
            self._free.append(p)
            self._free_set.add(p)


@dataclasses.dataclass
class AdmitPlan:
    """What admission gave one sequence (``PrefixPagePool.admit``)."""

    blocks: List[int]            # adopted shared pages + fresh private ones
    keys: List[PrefixKey]        # chain keys of the adopted full blocks
    committed: int               # context tokens already covered by pages
    n_tokens: int                # context length admitted (counter rollback)
    # partial-tail adoption: copy page ``cow_src`` into
    # ``blocks[cow_block]`` BEFORE the first write (the caller runs the
    # device copy, then releases cow_src)
    cow_src: Optional[int] = None
    cow_block: int = -1


class PrefixPagePool:
    """Ref-counted page pool with a content-addressed prefix index.

    ``num_free`` counts *allocatable* pages — the truly-free list plus
    the CACHED pages (refcount 0, content kept for future prefix hits;
    an allocation evicts them in LRU order). Shared pages therefore
    cost nothing until live sequences actually need the space.
    """

    def __init__(self, num_pages: int, page_size: int,
                 prefix_cache: bool = True):
        if num_pages < 2:
            raise ValueError("need num_pages >= 2 (page 0 is scratch)")
        self.num_pages = num_pages
        self.page_size = page_size
        self.prefix_cache = prefix_cache
        self._free: List[int] = list(range(num_pages - 1, 0, -1))
        self._cached: "OrderedDict[int, None]" = OrderedDict()  # LRU order
        self.ref: Dict[int, int] = {}            # live refcounts
        self._index: Dict[PrefixKey, int] = {}   # chain key -> page
        self._entry: Dict[int, PrefixKey] = {}   # page -> its chain key
        self._children: Dict[Optional[tuple], List[int]] = {}
        # counters (the bench's hit-rate / CoW metrics)
        self.admit_tokens = 0                    # context tokens admitted
        self.hit_tokens = 0                      # of which prefix-adopted
        self.cow_copies = 0

    # --- capacity ----------------------------------------------------

    @property
    def capacity(self) -> int:
        return self.num_pages - 1

    @property
    def num_free(self) -> int:
        """Allocatable pages: free list + evictable cached pages."""
        return len(self._free) + len(self._cached)

    @property
    def num_cached(self) -> int:
        return len(self._cached)

    @property
    def num_live(self) -> int:
        return len(self.ref)

    # --- low-level page lifecycle ------------------------------------

    def _evict(self, page: int) -> None:
        """Drop a CACHED page's index entry so the page can be reused."""
        del self._cached[page]
        key = self._entry.pop(page)
        if self._index.get(key) == page:
            del self._index[key]
        kids = self._children.get(key[0])
        if kids is not None:
            kids.remove(page)
            if not kids:
                del self._children[key[0]]

    def alloc(self, n: int) -> Optional[List[int]]:
        """Pop ``n`` private pages (refcount 1), or None (and no change)
        if not enough allocatable pages; cached pages evict LRU-first."""
        if n > self.num_free:
            return None
        if n <= 0:
            return []
        out: List[int] = []
        while len(out) < n and self._free:
            out.append(self._free.pop())
        while len(out) < n:
            page = next(iter(self._cached))      # least recently used
            self._evict(page)
            out.append(page)
        for p in out:
            self.ref[p] = 1
        return out

    def acquire(self, page: int) -> None:
        """Adopt a shared page: refcount++ (revives a CACHED page)."""
        if page == SCRATCH_PAGE:
            raise ValueError("cannot acquire the scratch page")
        if page in self._cached:
            del self._cached[page]
        self.ref[page] = self.ref.get(page, 0) + 1

    def release(self, pages: Sequence[int]) -> None:
        """Drop one reference per page; a page reaching refcount 0 goes
        to the CACHED side if its content is indexed, else to the free
        list. Never double-frees: releasing an unheld page raises."""
        for p in pages:
            if p == SCRATCH_PAGE:
                raise ValueError("cannot release the scratch page")
            n = self.ref.get(p, 0)
            if n <= 0:
                raise ValueError(f"release of unheld page {p}")
            if n > 1:
                self.ref[p] = n - 1
                continue
            del self.ref[p]
            if p in self._entry:
                self._cached[p] = None           # most-recently-used end
            else:
                self._free.append(p)

    # --- the prefix index --------------------------------------------

    def chain_key(self, parent: Optional[PrefixKey],
                  tokens: Sequence[int]) -> PrefixKey:
        return (parent, tuple(int(t) for t in tokens))

    def register(self, page: int, key: PrefixKey) -> None:
        """Index a FULL live page under its chain key. A duplicate key
        keeps the existing mapping (the page stays private/unindexed)."""
        if not self.prefix_cache or key in self._index:
            return
        if self.ref.get(page, 0) <= 0:
            raise ValueError(f"cannot register non-live page {page}")
        if page in self._entry:
            raise ValueError(f"page {page} already registered")
        self._index[key] = page
        self._entry[page] = key
        self._children.setdefault(key[0], []).append(page)

    def indexed_blocks(self, keys: Sequence[PrefixKey]) -> int:
        """How many of a sequence's chain keys still resolve — the
        blocks a re-admission would adopt (recompute-cost credit)."""
        return sum(1 for k in keys if k in self._index)

    def _match(self, tokens: Sequence[int]
               ) -> Tuple[List[int], List[PrefixKey],
                          Optional[Tuple[int, int]]]:
        """Walk the chain over full blocks; returns (pages, keys, tail)
        where tail = (page, overlap) is the best partially-matching
        child at the divergence point (overlap >= 1 tokens). Does NOT
        take references."""
        if not self.prefix_cache:
            return [], [], None
        ps = self.page_size
        pages: List[int] = []
        keys: List[PrefixKey] = []
        key: Optional[PrefixKey] = None
        b = 0
        while (b + 1) * ps <= len(tokens):
            k = self.chain_key(key, tokens[b * ps:(b + 1) * ps])
            page = self._index.get(k)
            if page is None:
                break
            pages.append(page)
            keys.append(k)
            key, b = k, b + 1
        tail: Optional[Tuple[int, int]] = None
        rem = tokens[b * ps:]
        if rem:
            parent = key
            best = 0
            for page in self._children.get(parent, ()):
                blk = self._entry[page][1]
                s = 0
                while s < len(rem) and s < len(blk) \
                        and blk[s] == int(rem[s]):
                    s += 1
                if s > best:
                    best, tail = s, (page, s)
        return pages, keys, tail

    # --- sequence-level API ------------------------------------------

    def blocks_for(self, n_tokens: int) -> int:
        return -(-max(n_tokens, 0) // self.page_size)

    def admit(self, tokens: Sequence[int]) -> Optional[AdmitPlan]:
        """Pages for a new sequence of ``tokens`` context: adopt every
        fully-matching shared page (refcount++), plan a CoW copy for a
        partially-matching tail, and allocate private pages for the
        rest. Returns None (state unchanged) when the pool can't hold
        the private remainder.

        At least the LAST context token is always left to compute —
        its logits seed generation — so ``committed < len(tokens)``.
        """
        L = len(tokens)
        need = self.blocks_for(L)
        # cap adoption at L-1 tokens: match on the prefix that excludes
        # the final token (a full match would leave nothing to prefill)
        pages, keys, tail = self._match(tokens[:L - 1])
        for p in pages:
            self.acquire(p)
        committed = len(pages) * self.page_size
        cow_src, cow_block, overlap = None, -1, 0
        if tail is not None:
            cow_src, overlap = tail
            cow_block = len(pages)
            self.acquire(cow_src)
        priv = self.alloc(need - len(pages))
        if priv is None:
            if cow_src is not None:
                self.release([cow_src])
            self.release(pages)
            return None
        committed += overlap
        self.admit_tokens += L
        self.hit_tokens += committed
        if cow_src is not None:
            self.cow_copies += 1
        return AdmitPlan(blocks=pages + priv, keys=keys,
                         committed=committed, n_tokens=L,
                         cow_src=cow_src, cow_block=cow_block)

    def cancel_admit(self, plan: AdmitPlan) -> None:
        """Roll an unadmitted plan back (budget refusal)."""
        if plan.cow_src is not None:
            self.release([plan.cow_src])
            self.cow_copies -= 1
        self.release(plan.blocks)
        self.admit_tokens -= plan.n_tokens
        self.hit_tokens -= plan.committed

    def extend(self, blocks: List[int], n_tokens: int) -> bool:
        """Grow ``blocks`` in place to cover ``n_tokens``; False on OOM."""
        need = self.blocks_for(n_tokens)
        if need <= len(blocks):
            return True
        got = self.alloc(need - len(blocks))
        if got is None:
            return False
        blocks.extend(got)
        return True

    def register_progress(self, blocks: List[int], keys: List[PrefixKey],
                          tokens: Sequence[int], kv_written: int) -> None:
        """Index every block that ``kv_written`` token positions have
        filled, extending the sequence's chain ``keys`` in place."""
        ps = self.page_size
        while (len(keys) + 1) * ps <= kv_written:
            b = len(keys)
            key = self.chain_key(keys[-1] if keys else None,
                                 tokens[b * ps:(b + 1) * ps])
            self.register(blocks[b], key)
            keys.append(key)

    # --- invariants ---------------------------------------------------

    def check(self) -> None:
        free, cached, live = set(self._free), set(self._cached), \
            set(self.ref)
        assert not (free & cached) and not (free & live) \
            and not (cached & live), "page in two states"
        assert len(free) + len(cached) + len(live) == self.capacity, \
            "page leak"
        assert all(n > 0 for n in self.ref.values()), "dead refcount kept"
        assert set(self._entry) <= (cached | live), \
            "indexed page neither cached nor live"
        for key, page in self._index.items():
            assert self._entry.get(page) == key, "index/entry mismatch"


class PagedKVCache:
    """Device page pools (a plain value tree) + the host pool."""

    def __init__(self, cfg: ModelConfig, num_pages: int, page_size: int,
                 max_blocks_per_seq: int, prefix_cache: bool = True):
        self.cfg = cfg
        self.num_pages = num_pages
        self.page_size = page_size
        self.max_blocks_per_seq = max_blocks_per_seq
        self.allocator = PrefixPagePool(num_pages, page_size,
                                        prefix_cache=prefix_cache)
        self.pages, self.axes = split_params(
            M.init_paged_cache(cfg, num_pages, page_size))

    def blocks_for(self, n_tokens: int) -> int:
        """Pages needed to hold ``n_tokens`` KV slots."""
        return -(-max(n_tokens, 0) // self.page_size)

    def max_seq_tokens(self) -> int:
        return self.max_blocks_per_seq * self.page_size

    def _check_len(self, n_tokens: int) -> int:
        n = self.blocks_for(n_tokens)
        if n > self.max_blocks_per_seq:
            raise ValueError(
                f"sequence of {n_tokens} tokens needs {n} pages > "
                f"max_blocks_per_seq={self.max_blocks_per_seq}")
        return n

    def admit_seq(self, tokens: Sequence[int]) -> Optional[AdmitPlan]:
        """Prefix-aware admission (see :meth:`PrefixPagePool.admit`)."""
        self._check_len(len(tokens))
        return self.allocator.admit(tokens)

    def alloc_seq(self, n_tokens: int) -> Optional[List[int]]:
        """Private pages for ``n_tokens`` (no prefix adoption)."""
        return self.allocator.alloc(self._check_len(n_tokens))

    def extend_seq(self, blocks: List[int], n_tokens: int) -> bool:
        """Grow ``blocks`` in place to cover ``n_tokens``; False on OOM."""
        self._check_len(n_tokens)
        return self.allocator.extend(blocks, n_tokens)

    def free_seq(self, blocks: List[int]) -> None:
        """Release one reference per block (frees only at refcount 0)."""
        self.allocator.release(blocks)
        blocks.clear()

    def table_row(self, blocks: List[int]) -> np.ndarray:
        """(max_blocks_per_seq,) int32 row, scratch-padded."""
        row = np.full((self.max_blocks_per_seq,), SCRATCH_PAGE, np.int32)
        row[:len(blocks)] = blocks
        return row

    @property
    def prefix_hit_rate(self) -> float:
        a = self.allocator
        return a.hit_tokens / a.admit_tokens if a.admit_tokens else 0.0


def copy_pages(pages: Dict[str, Any], src: jax.Array,
               dst: jax.Array) -> Dict[str, Any]:
    """Copy whole KV pages ``src[i] -> dst[i]`` in every layer — the
    CoW device op. Padding entries point both indices at the scratch
    page (an identity write), so one executable serves any copy count
    up to the padded width."""
    out: Dict[str, Any] = {}
    if "layers" in pages:
        stack = dict(pages["layers"])
        stack["kp"] = stack["kp"].at[:, dst].set(stack["kp"][:, src])
        stack["vp"] = stack["vp"].at[:, dst].set(stack["vp"][:, src])
        out["layers"] = stack
    out["head_layers"] = [
        dict(hc, kp=hc["kp"].at[dst].set(hc["kp"][src]),
             vp=hc["vp"].at[dst].set(hc["vp"][src]))
        for hc in pages.get("head_layers", [])]
    for k, v in pages.items():
        if k not in out:
            out[k] = v
    return out


# ---------------------------------------------------------------------------
# Contiguous-cache adapters (tests + migration of running batches)
# ---------------------------------------------------------------------------


def _pack_layer(k: jax.Array, v: jax.Array, kp: jax.Array, vp: jax.Array,
                block_tables: jax.Array, lengths: jax.Array
                ) -> Tuple[jax.Array, jax.Array]:
    """Scatter a contiguous (B, T, K, hd) cache into (P, ps, K, hd) pools.

    Positions >= length are directed to the scratch page (never read)."""
    B, T = k.shape[:2]
    ps = kp.shape[1]
    t = jnp.arange(T)[None, :]                       # (1, T)
    valid = t < lengths[:, None]                     # (B, T)
    blk = t // ps
    page = jnp.take_along_axis(
        block_tables, jnp.broadcast_to(blk, (B, T)), axis=1)
    page = jnp.where(valid, page, SCRATCH_PAGE).reshape(-1)
    slot = jnp.broadcast_to(t % ps, (B, T)).reshape(-1)
    kf = k.reshape((B * T,) + k.shape[2:])
    vf = v.reshape((B * T,) + v.shape[2:])
    return kp.at[page, slot].set(kf), vp.at[page, slot].set(vf)


def paged_from_contiguous(kv: PagedKVCache, cache: Dict[str, Any],
                          lengths) -> List[List[int]]:
    """Pack an ``init_cache``-shaped contiguous value tree into ``kv``.

    Allocates a block run per sequence (returned as per-sequence block
    lists) and scatters every layer's first ``lengths[b]`` KV slots into
    the pools. The contiguous cache must be the non-sliding-window GQA
    form (``k``/``v``/``slot_pos`` leaves) with slots 0..len-1 filled in
    order — exactly what ``M.decode_step`` produces from position 0.
    """
    lengths = np.asarray(lengths)
    all_blocks: List[List[int]] = []
    for n in lengths.tolist():
        blocks = kv.alloc_seq(int(n))
        if blocks is None:
            for b in all_blocks:
                kv.free_seq(b)
            raise ValueError("block pool too small for the batch")
        all_blocks.append(blocks)
    tables = jnp.asarray(np.stack([kv.table_row(b) for b in all_blocks]))
    len_arr = jnp.asarray(lengths, jnp.int32)

    for cont, paged in zip(cache.get("head_layers", []),
                           kv.pages.get("head_layers", [])):
        paged["kp"], paged["vp"] = _pack_layer(
            cont["k"], cont["v"], paged["kp"], paged["vp"], tables, len_arr)
    if "layers" in cache:
        stack = kv.pages["layers"]
        stack["kp"], stack["vp"] = jax.vmap(
            lambda k_, v_, kp_, vp_: _pack_layer(k_, v_, kp_, vp_, tables,
                                                 len_arr)
        )(cache["layers"]["k"], cache["layers"]["v"],
          stack["kp"], stack["vp"])
    return all_blocks


def contiguous_from_paged(kv: PagedKVCache, block_tables, lengths
                          ) -> Dict[str, Any]:
    """Gather the paged pools back into a contiguous value tree with
    T = max_blocks_per_seq * page_size slots (test adapter)."""
    tables = jnp.asarray(block_tables, jnp.int32)
    len_arr = jnp.asarray(lengths, jnp.int32)
    B, NB = tables.shape
    ps = kv.page_size
    T = NB * ps
    pos = jnp.arange(T)[None, :]
    slot_pos = jnp.where(pos < len_arr[:, None], pos, -1).astype(jnp.int32)

    from repro.kernels.ref import gather_pages

    out: Dict[str, Any] = {}
    if "layers" in kv.pages:
        stack = kv.pages["layers"]
        L = stack["kp"].shape[0]
        out["layers"] = {
            "k": jax.vmap(lambda p: gather_pages(p, tables))(stack["kp"]),
            "v": jax.vmap(lambda p: gather_pages(p, tables))(stack["vp"]),
            "slot_pos": jnp.broadcast_to(slot_pos[None], (L, B, T)),
        }
    out["head_layers"] = [
        {"k": gather_pages(hc["kp"], tables),
         "v": gather_pages(hc["vp"], tables), "slot_pos": slot_pos}
        for hc in kv.pages.get("head_layers", [])]
    return out
