"""Paged KV cache: fixed-size pages, per-sequence block tables, free list.

Device side, every attention layer owns a pool of ``num_pages`` pages of
``page_size`` token slots (``models.model.init_paged_cache``); logical
position t of a sequence lives at page ``block_table[t // page_size]``,
slot ``t % page_size`` — the same page index in every layer, so ONE block
table and ONE allocator serve the whole model. Page 0 is reserved as the
scratch page: padded / inactive-lane writes are directed there and its
contents are never attended (lengths mask them out).

Host side, :class:`BlockAllocator` hands out page ids from a free list —
O(1) alloc/free, no compaction, fragmentation-free by construction
(every block is the same size). :class:`PagedKVCache` bundles the device
pools with the allocator and the contiguous-cache adapters.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.models.nn import split_params

SCRATCH_PAGE = 0


class BlockAllocator:
    """Free-list page allocator; page 0 (scratch) is never handed out."""

    def __init__(self, num_pages: int):
        if num_pages < 2:
            raise ValueError("need num_pages >= 2 (page 0 is scratch)")
        self.num_pages = num_pages
        self._free: List[int] = list(range(num_pages - 1, 0, -1))
        self._free_set = set(self._free)     # O(1) double-free guard

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def capacity(self) -> int:
        """Allocatable pages (total minus the scratch page)."""
        return self.num_pages - 1

    def alloc(self, n: int) -> Optional[List[int]]:
        """Pop ``n`` pages, or None (and no change) if not enough free."""
        if n > len(self._free):
            return None
        if n <= 0:
            return []
        out = self._free[-n:][::-1]
        del self._free[len(self._free) - n:]
        self._free_set.difference_update(out)
        return out

    def free(self, pages: List[int]) -> None:
        for p in pages:
            if p == SCRATCH_PAGE:
                raise ValueError("cannot free the scratch page")
            if p in self._free_set:
                raise ValueError(f"double free of page {p}")
            self._free.append(p)
            self._free_set.add(p)


class PagedKVCache:
    """Device page pools (a plain value tree) + the host allocator."""

    def __init__(self, cfg: ModelConfig, num_pages: int, page_size: int,
                 max_blocks_per_seq: int):
        self.cfg = cfg
        self.num_pages = num_pages
        self.page_size = page_size
        self.max_blocks_per_seq = max_blocks_per_seq
        self.allocator = BlockAllocator(num_pages)
        self.pages, self.axes = split_params(
            M.init_paged_cache(cfg, num_pages, page_size))

    def blocks_for(self, n_tokens: int) -> int:
        """Pages needed to hold ``n_tokens`` KV slots."""
        return -(-max(n_tokens, 0) // self.page_size)

    def max_seq_tokens(self) -> int:
        return self.max_blocks_per_seq * self.page_size

    def alloc_seq(self, n_tokens: int) -> Optional[List[int]]:
        n = self.blocks_for(n_tokens)
        if n > self.max_blocks_per_seq:
            raise ValueError(
                f"sequence of {n_tokens} tokens needs {n} pages > "
                f"max_blocks_per_seq={self.max_blocks_per_seq}")
        return self.allocator.alloc(n)

    def extend_seq(self, blocks: List[int], n_tokens: int) -> bool:
        """Grow ``blocks`` in place to cover ``n_tokens``; False on OOM."""
        need = self.blocks_for(n_tokens)
        if need > self.max_blocks_per_seq:
            raise ValueError(
                f"sequence of {n_tokens} tokens exceeds max_blocks_per_seq="
                f"{self.max_blocks_per_seq}")
        if need <= len(blocks):
            return True
        got = self.allocator.alloc(need - len(blocks))
        if got is None:
            return False
        blocks.extend(got)
        return True

    def free_seq(self, blocks: List[int]) -> None:
        self.allocator.free(blocks)
        blocks.clear()

    def table_row(self, blocks: List[int]) -> np.ndarray:
        """(max_blocks_per_seq,) int32 row, scratch-padded."""
        row = np.full((self.max_blocks_per_seq,), SCRATCH_PAGE, np.int32)
        row[:len(blocks)] = blocks
        return row


# ---------------------------------------------------------------------------
# Contiguous-cache adapters (tests + migration of running batches)
# ---------------------------------------------------------------------------


def _pack_layer(k: jax.Array, v: jax.Array, kp: jax.Array, vp: jax.Array,
                block_tables: jax.Array, lengths: jax.Array
                ) -> Tuple[jax.Array, jax.Array]:
    """Scatter a contiguous (B, T, K, hd) cache into (P, ps, K, hd) pools.

    Positions >= length are directed to the scratch page (never read)."""
    B, T = k.shape[:2]
    ps = kp.shape[1]
    t = jnp.arange(T)[None, :]                       # (1, T)
    valid = t < lengths[:, None]                     # (B, T)
    blk = t // ps
    page = jnp.take_along_axis(
        block_tables, jnp.broadcast_to(blk, (B, T)), axis=1)
    page = jnp.where(valid, page, SCRATCH_PAGE).reshape(-1)
    slot = jnp.broadcast_to(t % ps, (B, T)).reshape(-1)
    kf = k.reshape((B * T,) + k.shape[2:])
    vf = v.reshape((B * T,) + v.shape[2:])
    return kp.at[page, slot].set(kf), vp.at[page, slot].set(vf)


def paged_from_contiguous(kv: PagedKVCache, cache: Dict[str, Any],
                          lengths) -> List[List[int]]:
    """Pack an ``init_cache``-shaped contiguous value tree into ``kv``.

    Allocates a block run per sequence (returned as per-sequence block
    lists) and scatters every layer's first ``lengths[b]`` KV slots into
    the pools. The contiguous cache must be the non-sliding-window GQA
    form (``k``/``v``/``slot_pos`` leaves) with slots 0..len-1 filled in
    order — exactly what ``M.decode_step`` produces from position 0.
    """
    lengths = np.asarray(lengths)
    all_blocks: List[List[int]] = []
    for n in lengths.tolist():
        blocks = kv.alloc_seq(int(n))
        if blocks is None:
            for b in all_blocks:
                kv.free_seq(b)
            raise ValueError("block pool too small for the batch")
        all_blocks.append(blocks)
    tables = jnp.asarray(np.stack([kv.table_row(b) for b in all_blocks]))
    len_arr = jnp.asarray(lengths, jnp.int32)

    for cont, paged in zip(cache.get("head_layers", []),
                           kv.pages.get("head_layers", [])):
        paged["kp"], paged["vp"] = _pack_layer(
            cont["k"], cont["v"], paged["kp"], paged["vp"], tables, len_arr)
    if "layers" in cache:
        stack = kv.pages["layers"]
        stack["kp"], stack["vp"] = jax.vmap(
            lambda k_, v_, kp_, vp_: _pack_layer(k_, v_, kp_, vp_, tables,
                                                 len_arr)
        )(cache["layers"]["k"], cache["layers"]["v"],
          stack["kp"], stack["vp"])
    return all_blocks


def contiguous_from_paged(kv: PagedKVCache, block_tables, lengths
                          ) -> Dict[str, Any]:
    """Gather the paged pools back into a contiguous value tree with
    T = max_blocks_per_seq * page_size slots (test adapter)."""
    tables = jnp.asarray(block_tables, jnp.int32)
    len_arr = jnp.asarray(lengths, jnp.int32)
    B, NB = tables.shape
    ps = kv.page_size
    T = NB * ps
    pos = jnp.arange(T)[None, :]
    slot_pos = jnp.where(pos < len_arr[:, None], pos, -1).astype(jnp.int32)

    from repro.kernels.ref import gather_pages

    out: Dict[str, Any] = {}
    if "layers" in kv.pages:
        stack = kv.pages["layers"]
        L = stack["kp"].shape[0]
        out["layers"] = {
            "k": jax.vmap(lambda p: gather_pages(p, tables))(stack["kp"]),
            "v": jax.vmap(lambda p: gather_pages(p, tables))(stack["vp"]),
            "slot_pos": jnp.broadcast_to(slot_pos[None], (L, B, T)),
        }
    out["head_layers"] = [
        {"k": gather_pages(hc["kp"], tables),
         "v": gather_pages(hc["vp"], tables), "slot_pos": slot_pos}
        for hc in kv.pages.get("head_layers", [])]
    return out
