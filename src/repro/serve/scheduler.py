"""SLO-aware continuous-batching scheduler (DESIGN.md §11).

Policy:
  * **Class-ordered admission with token-budget packing.** The waiting
    line is ordered by ``(priority, earliest deadline, tenant tokens
    served, arrival)`` — lower priority number first, then EDF within a
    class, then the tenant that has consumed the fewest tokens, then
    arrival order. With the defaults (one class, no deadlines, one
    tenant) every component is constant and the order IS arrival order:
    the scheduler degenerates to the PR 3 FCFS baseline bit-for-bit
    (pinned by ``tests/test_sched_slo.py``). Admission charges only the
    prefill work actually left after prefix adoption (``ctx -
    committed``) against the step budget; the head request always fits,
    so a long prompt can't deadlock.
  * **Prefix-aware admission.** Pages come from
    ``PagedKVCache.admit_seq`` — fully-matching shared pages are
    adopted by refcount, a partially-matching page becomes a pending
    copy-on-write (``req.cow``), and only the divergent suffix costs
    fresh pages + prefill compute.
  * **Chunked prefill.** ``prefill_chunk > 0`` caps the tokens one lane
    prefills per step; the engine interleaves prefill and decode steps
    while any lane is mid-prompt, so a long prompt can no longer stall
    every in-flight decode for its whole length. ``0`` = unchunked
    (the baseline: whole prompt in one step).
  * **Preemption by class, then recompute cost.** When a decode step
    cannot allocate its next page, the victim is the worst class first
    (highest priority number), then the cheapest to recompute —
    context length minus the tokens its still-indexed prefix pages
    would let a re-admission adopt for free — then the newest request.
    Releasing decrements refcounts; a preempted request can never free
    a page another sequence still maps.

The scheduler owns no device state: it mutates :class:`RequestHandle`s
and the :class:`PagedKVCache` pool, and tells the engine what kind of
step to run.
"""
from __future__ import annotations

import dataclasses
from collections import Counter
from typing import Dict, List, Optional

from repro import obs

from .api import FINISHED, RUNNING, WAITING, RequestHandle
from .kv_cache import PagedKVCache


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    max_batch: int                 # decode lanes
    token_budget: int = 512        # prompt tokens admitted per prefill step
    prefill_chunk: int = 0         # max prefill tokens per lane per step
    #                                (0 = whole prompt in one step)


class Scheduler:
    def __init__(self, kv: PagedKVCache, cfg: SchedulerConfig,
                 hooks: Optional[obs.Hooks] = None):
        self.kv = kv
        self.cfg = cfg
        self.hooks = obs.as_hooks(hooks)
        self.waiting: List[RequestHandle] = []
        self.running: Dict[int, RequestHandle] = {}   # slot -> request
        self._free_slots: List[int] = list(range(cfg.max_batch - 1, -1, -1))
        self._arrivals = 0
        self.tenant_served: Dict[str, int] = {}       # tokens per tenant
        self.admit_order: List[int] = []              # rids, admission order

    # --- queue management -------------------------------------------

    def _sort_key(self, r: RequestHandle):
        deadline = r.t_submit + r.deadline_s \
            if r.deadline_s is not None else float("inf")
        return (r.priority, deadline,
                self.tenant_served.get(r.tenant, 0), r.arrival)

    def submit(self, req: RequestHandle) -> None:
        need = self.kv.blocks_for(len(req.prompt) + req.max_new)
        if need > self.kv.max_blocks_per_seq:
            raise ValueError(
                f"request {req.rid}: prompt+max_new = "
                f"{len(req.prompt) + req.max_new} tokens needs {need} pages "
                f"> max_blocks_per_seq={self.kv.max_blocks_per_seq}")
        if need > self.kv.allocator.capacity:
            raise ValueError(
                f"request {req.rid} can never fit: needs {need} pages, "
                f"pool holds {self.kv.allocator.capacity}")
        req.status = WAITING
        req.arrival = self._arrivals
        self._arrivals += 1
        self.waiting.append(req)

    def admit(self) -> List[RequestHandle]:
        """Pop waiting requests (class order) into free lanes while the
        token budget and the block pool allow. Returns the newly admitted
        requests (their pages + lanes assigned, ready to prefill)."""
        admitted: List[RequestHandle] = []
        budget = self.cfg.token_budget
        self.waiting.sort(key=self._sort_key)
        while self.waiting and self._free_slots:
            req = self.waiting[0]
            plan = self.kv.admit_seq(req.context())
            if plan is None:
                break                         # pool full — decode/finish first
            cost = req.ctx_len() - plan.committed   # prefill work left
            if admitted and cost > budget:
                self.kv.allocator.cancel_admit(plan)
                break                         # packed enough for this step
            self.waiting.pop(0)
            req.blocks = plan.blocks
            req.keys = list(plan.keys)
            req.committed = plan.committed
            req.cow = (plan.cow_src, plan.cow_block) \
                if plan.cow_src is not None else None
            req.slot = self._free_slots.pop()
            req.base_len = req.ctx_len()
            req.status = RUNNING
            self.running[req.slot] = req
            self.admit_order.append(req.rid)
            budget -= cost
            admitted.append(req)
            self.hooks.on_admit(req)
        return admitted

    def prefill_quota(self, req: RequestHandle, budget: int) -> int:
        """Tokens this lane prefills in the coming step: the remaining
        prompt, capped by the chunk size and the step budget."""
        n = req.base_len - req.committed
        if self.cfg.prefill_chunk > 0:
            n = min(n, self.cfg.prefill_chunk)
        return min(n, budget)

    def charge(self, req: RequestHandle, n_tokens: int) -> None:
        """Account ``n_tokens`` of service to the request's tenant (the
        fairness component of the admission order)."""
        if n_tokens > 0:
            self.tenant_served[req.tenant] = \
                self.tenant_served.get(req.tenant, 0) + n_tokens

    # --- decode capacity / preemption -------------------------------

    def _recompute_cost(self, r: RequestHandle) -> int:
        """Prefill tokens a re-admission would pay: context minus what
        the request's still-indexed prefix pages cover for free."""
        hit = self.kv.allocator.indexed_blocks(r.keys) * self.kv.page_size
        return r.ctx_len() - min(hit, r.ctx_len())

    def _evict_victim(self) -> Optional[RequestHandle]:
        cands = list(self.running.values())
        if not cands:
            return None
        victim = min(cands, key=lambda r: (-r.priority,
                                           self._recompute_cost(r),
                                           -r.arrival))
        self._release(victim)
        victim.status = WAITING
        victim.n_preempt += 1
        self.waiting.append(victim)    # arrival key restores its position
        self.hooks.on_preempt(victim)
        return victim

    def ensure_decode_capacity(self, k: int = 1) -> List[RequestHandle]:
        """Grow every decode-phase sequence's block run to cover its next
        ``k`` tokens, preempting by class / recompute cost on pool OOM.
        Returns the preempted requests."""
        preempted: List[RequestHandle] = []
        for req in sorted(self.running.values(), key=lambda r: r.rid):
            if req.slot not in self.running or req.pending_prefill:
                continue                       # evicted / still prefilling
            # writes land at positions ctx_len-1 .. ctx_len+k-2
            need = min(req.ctx_len() + k - 1, self.kv.max_seq_tokens())
            while not self.kv.extend_seq(req.blocks, need):
                victim = self._evict_victim()
                assert victim is not None, "no victim but allocation failed"
                preempted.append(victim)
                if victim is req:
                    break                      # evicted itself; skip decode
        return preempted

    # --- completion --------------------------------------------------

    def _release(self, req: RequestHandle) -> None:
        if req.cow is not None:                # un-executed CoW source
            self.kv.allocator.release([req.cow[0]])
            req.cow = None
        self.kv.free_seq(req.blocks)
        req.keys = []
        req.committed = 0
        self._free_slots.append(req.slot)
        del self.running[req.slot]
        req.slot = None

    def finish(self, req: RequestHandle) -> None:
        self._release(req)
        req.status = FINISHED
        self.hooks.on_finish(req)

    # --- introspection ----------------------------------------------

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    def check_invariants(self) -> None:
        """Page-accounting invariants (exercised by the tests): every
        live refcount equals the number of running sequences mapping the
        page (pending CoW sources count), and free + cached + live pages
        tile the pool exactly."""
        pool = self.kv.allocator
        held = Counter(p for r in self.running.values() for p in r.blocks)
        held.update(r.cow[0] for r in self.running.values()
                    if r.cow is not None)
        assert dict(held) == dict(pool.ref), \
            f"refcount mismatch: held={dict(held)} pool={dict(pool.ref)}"
        pool.check()
        lanes = set(self.running) | set(self._free_slots)
        assert lanes == set(range(self.cfg.max_batch)), "lane leak"
