"""Continuous-batching scheduler (DESIGN.md §7).

Policy:
  * **FCFS admission with token-budget packing** — waiting requests are
    admitted in arrival order while a decode lane is free, the step's
    prefill-token budget is not exceeded (the head request always fits,
    so a long prompt can't deadlock), and the block pool can hold the
    prompt.
  * **Prefill/decode interleaving** — the engine runs one prefill step
    whenever something was admitted, otherwise one decode step over every
    running lane; waiting work therefore never starves behind a long
    generation, and decode lanes refill as soon as a sequence finishes.
  * **Preempt-longest on OOM** — when a decode step cannot allocate the
    next page, the longest running sequence is evicted (its pages freed,
    its progress kept) and re-queued at the head of the waiting line for
    recompute-style re-admission; eviction repeats until the allocation
    succeeds or the requester itself was evicted.

The scheduler owns no device state: it mutates :class:`RequestHandle`s
and the :class:`PagedKVCache` allocator, and tells the engine what kind
of step to run.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional

from .api import FINISHED, RUNNING, WAITING, RequestHandle
from .kv_cache import PagedKVCache


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    max_batch: int                 # decode lanes
    token_budget: int = 512        # prompt tokens admitted per prefill step


class Scheduler:
    def __init__(self, kv: PagedKVCache, cfg: SchedulerConfig):
        self.kv = kv
        self.cfg = cfg
        self.waiting: Deque[RequestHandle] = deque()
        self.running: Dict[int, RequestHandle] = {}   # slot -> request
        self._free_slots: List[int] = list(range(cfg.max_batch - 1, -1, -1))

    # --- queue management -------------------------------------------

    def submit(self, req: RequestHandle) -> None:
        need = self.kv.blocks_for(len(req.prompt) + req.max_new)
        if need > self.kv.max_blocks_per_seq:
            raise ValueError(
                f"request {req.rid}: prompt+max_new = "
                f"{len(req.prompt) + req.max_new} tokens needs {need} pages "
                f"> max_blocks_per_seq={self.kv.max_blocks_per_seq}")
        if need > self.kv.allocator.capacity:
            raise ValueError(
                f"request {req.rid} can never fit: needs {need} pages, "
                f"pool holds {self.kv.allocator.capacity}")
        req.status = WAITING
        self.waiting.append(req)

    def admit(self) -> List[RequestHandle]:
        """FCFS admission: pop waiting requests into free lanes while the
        token budget and the block pool allow. Returns the newly admitted
        requests (their pages + lanes assigned, ready to prefill)."""
        admitted: List[RequestHandle] = []
        budget = self.cfg.token_budget
        while self.waiting and self._free_slots:
            req = self.waiting[0]
            n_tokens = req.ctx_len()
            if admitted and n_tokens > budget:
                break                         # packed enough for this step
            blocks = self.kv.alloc_seq(n_tokens)
            if blocks is None:
                break                         # pool full — decode/finish first
            self.waiting.popleft()
            req.blocks = blocks
            req.slot = self._free_slots.pop()
            req.base_len = n_tokens
            req.status = RUNNING
            self.running[req.slot] = req
            budget -= n_tokens
            admitted.append(req)
        return admitted

    # --- decode capacity / preemption -------------------------------

    def _evict_longest(self, exclude: Optional[RequestHandle] = None
                       ) -> Optional[RequestHandle]:
        cands = [r for r in self.running.values() if r is not exclude]
        if not cands:
            return None
        victim = max(cands, key=lambda r: (r.ctx_len(), r.rid))
        self._release(victim)
        victim.status = WAITING
        victim.n_preempt += 1
        self.waiting.appendleft(victim)       # keeps its FCFS priority
        return victim

    def ensure_decode_capacity(self, k: int = 1) -> List[RequestHandle]:
        """Grow every running sequence's block run to cover its next ``k``
        tokens, preempting the longest sequence on pool OOM. Returns the
        preempted requests."""
        preempted: List[RequestHandle] = []
        for req in sorted(self.running.values(), key=lambda r: r.rid):
            if req.slot not in self.running:   # evicted by an earlier loop
                continue
            # writes land at positions ctx_len-1 .. ctx_len+k-2
            need = min(req.ctx_len() + k - 1, self.kv.max_seq_tokens())
            while not self.kv.extend_seq(req.blocks, need):
                victim = self._evict_longest(exclude=None)
                assert victim is not None, "no victim but allocation failed"
                preempted.append(victim)
                if victim is req:
                    break                      # evicted itself; skip decode
        return preempted

    # --- completion --------------------------------------------------

    def _release(self, req: RequestHandle) -> None:
        self.kv.free_seq(req.blocks)
        self._free_slots.append(req.slot)
        del self.running[req.slot]
        req.slot = None

    def finish(self, req: RequestHandle) -> None:
        self._release(req)
        req.status = FINISHED

    # --- introspection ----------------------------------------------

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    def check_invariants(self) -> None:
        """Block-accounting invariants (exercised by the tests)."""
        held = [p for r in self.running.values() for p in r.blocks]
        assert len(held) == len(set(held)), "page handed out twice"
        assert self.kv.allocator.num_free + len(held) \
            == self.kv.allocator.capacity, "block leak"
        lanes = set(self.running) | set(self._free_slots)
        assert lanes == set(range(self.cfg.max_batch)), "lane leak"
