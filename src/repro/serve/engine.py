"""ServeEngine: the continuous-batching serving driver (DESIGN.md §7, §11).

Owns the jitted paged ``prefill`` / ``decode_step`` / page-copy
executables (built on ``repro.dist.ShardCtx`` — TP via the existing
sharding rules when a mesh is given), the :class:`PagedKVCache` pools,
and the :class:`Scheduler`; ``submit``/``step``/``stream``/``drain`` is
the whole surface.

Fixed shapes keep recompiles bounded: decode always runs the full
``max_batch`` lane set (idle lanes carry pos = -1 and write the scratch
page); prefill pads the active pack to ``max_batch`` lanes and a
power-of-two token length, so at most O(log max_prompt) prefill
executables exist; the CoW page copy pads to ``max_batch``
scratch-identity pairs. Prefill itself is a ``lax.scan`` of the paged
decode step over the prompt *suffix* — chunked prefill and prefix
adoption both just move the scan's start offset, so the same code path
serves full prompts, chunk continuations, and post-adoption tails.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Any, Callable, Dict, Iterator, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs.base import ModelConfig
from repro.dist import make_shard_ctx, tree_shardings
from repro.models import model as M
from repro.models.nn import Param, merge_params, split_params
from repro.run.config import SamplingSpec

from .api import RequestHandle, ServeMetrics
from .kv_cache import PagedKVCache, copy_pages
from .scheduler import Scheduler, SchedulerConfig


def _plain_shardings(param_tree, mesh):
    """Param tree -> plain NamedSharding tree via the default rules."""
    shard = tree_shardings(param_tree, mesh)
    plain, _ = split_params(jax.tree.map(
        lambda p, s: Param(s, p.axes), param_tree, shard,
        is_leaf=lambda x: isinstance(x, Param)))
    return plain


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Serving shapes + policy knobs."""

    max_batch: int = 4             # decode lanes
    page_size: int = 16            # tokens per KV page
    num_pages: int = 128           # pool size incl. the scratch page
    max_blocks_per_seq: int = 16   # block-table width
    token_budget: int = 512        # prefill tokens admitted per step
    decode_quantum: int = 8        # decode steps fused per dispatch
    prefill_chunk: int = 0         # prefill tokens per lane per step
    #                                (0 = whole prompt in one step)
    prefix_cache: bool = True      # cross-request CoW prefix sharing
    metrics_path: Optional[str] = None
    log_every: int = 10
    # token sampling policy: temperature 0 = exact greedy argmax (the
    # pre-sampling engine, bitwise); > 0 softmax-samples, truncated to
    # the top_k largest logits (top_k > 0) and/or the top_p nucleus
    # (0 < top_p < 1), seeded per dispatch.
    sampling: SamplingSpec = SamplingSpec()


def _bucket(n: int, lo: int = 8) -> int:
    """Smallest power of two >= n (>= lo) — bounds prefill recompiles."""
    b = lo
    while b < n:
        b *= 2
    return b


class ServeEngine:
    """Continuous-batching engine over the paged decode path."""

    def __init__(self, cfg: ModelConfig, params, serve: ServeConfig,
                 mesh=None, moe_impl: str = "tp",
                 printer: Optional[Callable[[str], None]] = None,
                 hooks: Optional[obs.Hooks] = None):
        if cfg.family not in ("dense", "vlm", "audio", "moe"):
            raise ValueError(f"paged serving supports transformer families "
                             f"only, got {cfg.family!r}")
        if cfg.attn_type != "gqa":
            raise ValueError("paged serving supports attn_type 'gqa' only")
        if cfg.sliding_window:
            raise ValueError("paged serving does not support sliding-window "
                             "attention (the ring buffer already bounds "
                             "cache memory)")
        self.cfg = cfg
        self.serve = serve
        self.ctx = make_shard_ctx(mesh, serve.max_batch, moe_impl)
        self.mesh = mesh
        self.kv = PagedKVCache(cfg, serve.num_pages, serve.page_size,
                               serve.max_blocks_per_seq,
                               prefix_cache=serve.prefix_cache)
        self.sched = Scheduler(self.kv, SchedulerConfig(
            max_batch=serve.max_batch, token_budget=serve.token_budget,
            prefill_chunk=serve.prefill_chunk), hooks=hooks)
        self.metrics = ServeMetrics(serve.metrics_path, serve.log_every,
                                    printer)
        self.values, _ = split_params(params)
        if mesh is not None:
            # place params + page pools per the logical-axis rules (TP:
            # kv_heads/heads/mlp/vocab over the model axis).
            self.values = jax.device_put(
                self.values, _plain_shardings(params, mesh))
            self.kv.pages = jax.device_put(
                self.kv.pages,
                _plain_shardings(merge_params(self.kv.pages, self.kv.axes),
                                 mesh))
        self._rid = itertools.count()
        self._last_kind = "idle"
        # sampling keys: one per dispatch, folded from the spec's seed —
        # the same submissions replay to the same tokens.
        self._sample_base = jax.random.PRNGKey(serve.sampling.seed)
        self._dispatches = 0
        # the page pools are donated: every dispatch consumes kv.pages and
        # the engine rebinds the returned tree, so the update is in-place
        # instead of copying the whole pool per step.
        self._decode_jit = jax.jit(self._decode_fn, static_argnums=(6,),
                                   donate_argnums=(1,))
        self._prefill_jit = jax.jit(self._prefill_fn, donate_argnums=(1,))
        self._copy_jit = jax.jit(copy_pages, donate_argnums=(0,))

    # --- jitted bodies ----------------------------------------------

    def _model_ctx(self):
        return self.ctx if self.mesh is not None else None

    def _next_key(self):
        self._dispatches += 1
        return jax.random.fold_in(self._sample_base, self._dispatches)

    def _sample(self, logits, key):
        """(B, V) logits -> (B,) int32 token ids per the sampling spec.

        The spec is trace-time static: the greedy default compiles to
        exactly the old argmax (bitwise), temperature > 0 compiles to a
        seeded categorical over the temperature-scaled logits, truncated
        by top-k and/or the top-p nucleus when enabled (top-k first, as
        the conventional composition).
        """
        s = self.serve.sampling
        if s.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        scaled = logits.astype(jnp.float32) / s.temperature
        # the vocab-padding columns (padded_vocab > vocab_size) carry
        # arbitrary logits: mask them so sampling never emits an invalid
        # token id (argmax is exposed too, but padding never beats a
        # trained real token; sampling would hit it every few steps).
        V = self.cfg.vocab_size
        if self.cfg.padded_vocab > V:
            scaled = jnp.where(jnp.arange(scaled.shape[-1]) < V, scaled,
                               -jnp.inf)
        if s.top_k > 0:
            kth = jax.lax.top_k(scaled, min(s.top_k,
                                            scaled.shape[-1]))[0][..., -1:]
            scaled = jnp.where(scaled < kth, -jnp.inf, scaled)
        if 0.0 < s.top_p < 1.0:
            # nucleus: keep the smallest descending-prob prefix whose
            # cumulative mass reaches top_p. A token survives iff the
            # mass *before* it is < top_p, so the top token always does.
            desc = jnp.sort(scaled, axis=-1)[..., ::-1]
            probs = jax.nn.softmax(desc, axis=-1)
            before = jnp.cumsum(probs, axis=-1) - probs
            kept = jnp.where(before < s.top_p, desc, jnp.inf)
            cutoff = jnp.min(kept, axis=-1, keepdims=True)
            scaled = jnp.where(scaled < cutoff, -jnp.inf, scaled)
        return jax.random.categorical(key, scaled, axis=-1).astype(
            jnp.int32)

    def _decode_fn(self, values, pages, tokens, pos, tables, key, k: int):
        """Fused run of ``k`` sampled decode steps (the scheduling
        quantum): tokens (B,1) at pos (B,) -> ((B, k) sampled ids, pages).
        Idle lanes (pos -1) stay idle; the host consumes each lane's run
        up to its EOS / budget and discards the overshoot."""
        def body(carry, i):
            pages, tok, pos = carry
            logits, pages = M.decode_step(values, self.cfg, pages, tok, pos,
                                          shard_ctx=self._model_ctx(),
                                          block_tables=tables)
            nxt = self._sample(logits, jax.random.fold_in(key, i))
            active = pos >= 0
            tok = jnp.where(active, nxt, 0)[:, None]
            pos = jnp.where(active, pos + 1, -1)
            return (pages, tok, pos), nxt

        (pages, _, _), toks = jax.lax.scan(body, (pages, tokens, pos),
                                           jnp.arange(k))
        return jnp.moveaxis(toks, 0, 1), pages           # (B, k)

    def _prefill_fn(self, values, pages, tokens, starts, counts, tables,
                    key):
        """Scan the paged decode step over a ragged prompt-chunk pack.

        tokens (B, S) scratch-padded chunk tokens, starts (B,) the
        logical position of each lane's first chunk token, counts (B,)
        chunk lengths (0 = idle lane). Positions before ``starts`` are
        already in the pages — adopted shared prefix pages or earlier
        chunks — and are attended through the block table. Returns
        (token sampled at each lane's last chunk position (B,), pages).
        """
        B, S = tokens.shape
        V = self.cfg.padded_vocab

        def body(carry, t):
            pages, last = carry
            pos = jnp.where(t < counts, starts + t, -1)
            logits, pages = M.decode_step(
                values, self.cfg, pages, jax.lax.dynamic_slice_in_dim(
                    tokens, t, 1, axis=1), pos,
                shard_ctx=self._model_ctx(), block_tables=tables)
            last = jnp.where((t == counts - 1)[:, None], logits, last)
            return (pages, last), None

        last0 = jnp.zeros((B, V), jnp.float32)
        (pages, last), _ = jax.lax.scan(body, (pages, last0),
                                        jnp.arange(S))
        return self._sample(last, key), pages

    # --- public surface ----------------------------------------------

    def submit(self, prompt_tokens, max_new: int,
               eos: Optional[int] = None, priority: int = 0,
               deadline_s: Optional[float] = None,
               tenant: str = "default") -> RequestHandle:
        prompt = [int(t) for t in np.asarray(prompt_tokens).reshape(-1)]
        if not prompt or max_new < 1:
            raise ValueError("need a non-empty prompt and max_new >= 1")
        if any(t < 0 or t >= self.cfg.vocab_size for t in prompt):
            # out-of-vocab ids would gather garbage embeddings and write
            # NaN KV that outlives this request in recycled pages
            raise ValueError(f"prompt token ids must be in [0, "
                             f"{self.cfg.vocab_size}), got "
                             f"{[t for t in prompt if not 0 <= t < self.cfg.vocab_size][:4]}")
        req = RequestHandle(rid=next(self._rid), prompt=prompt,
                            max_new=max_new, eos=eos, priority=priority,
                            deadline_s=deadline_s, tenant=tenant,
                            t_submit=time.time())
        self.sched.submit(req)
        return req

    def _table_batch(self) -> jnp.ndarray:
        rows = np.full((self.serve.max_batch, self.kv.max_blocks_per_seq),
                       0, np.int32)
        for slot, req in self.sched.running.items():
            rows[slot] = self.kv.table_row(req.blocks)
        return jnp.asarray(rows)

    def _commit_token(self, req: RequestHandle, tok: int,
                      now: float) -> None:
        """Append one generated token; finish on EOS / budget."""
        req.tokens.append(tok)
        if req.t_first_token is None:
            req.t_first_token = now
        if len(req.tokens) >= req.max_new or \
                (req.eos is not None and tok == req.eos):
            req.t_finish = now
            self.sched.finish(req)
            self.metrics.record_finish(req)

    def step(self) -> Dict[str, Any]:
        """One scheduler iteration: admit, then run one prefill or decode
        step. Lanes mid-prompt (chunked prefill) alternate with decode
        so neither phase starves the other; with ``prefill_chunk=0``
        this reduces to the baseline prefill-whole-prompt-on-admission
        policy. Returns the step's metrics record."""
        t0 = time.time()
        with obs.span("serve.step"):
            with obs.span("scheduler"):
                admitted = self.sched.admit()
                cached = sum(r.committed for r in admitted)  # adopted,
                #                                              not computed
                prefillable = any(r.pending_prefill
                                  for r in self.sched.running.values())
                decodable = any(not r.pending_prefill
                                for r in self.sched.running.values())
            if prefillable and (admitted or not decodable
                                or self._last_kind != "prefill"):
                with obs.span("prefill"):
                    record = self._prefill_step(t0, cached)
            elif decodable:
                with obs.span("decode"):
                    record = self._decode_step(t0)
            else:
                self._last_kind = "idle"
                record = self.metrics.record_step(
                    "idle", generated=0, prefilled=0, running=0,
                    waiting=len(self.sched.waiting),
                    free_pages=self.kv.allocator.num_free, preempted=0,
                    dt=time.time() - t0)
        return record

    def _run_cow_copies(self, lanes: List[RequestHandle]) -> None:
        """Execute pending copy-on-write page copies (one padded
        dispatch), then drop the source references."""
        cow = [r for r in lanes if r.cow is not None]
        if not cow:
            return
        with obs.span("cow"):
            self._run_cow_copies_inner(cow)

    def _run_cow_copies_inner(self, cow: List[RequestHandle]) -> None:
        B = self.serve.max_batch
        src = np.zeros((B,), np.int32)     # padding: scratch -> scratch
        dst = np.zeros((B,), np.int32)
        for i, req in enumerate(cow):
            s, blk = req.cow
            src[i], dst[i] = s, req.blocks[blk]
        self.kv.pages = self._copy_jit(self.kv.pages, jnp.asarray(src),
                                       jnp.asarray(dst))
        for req in cow:
            self.kv.allocator.release([req.cow[0]])
            req.cow = None

    def _prefill_step(self, t0: float, cached: int = 0) -> Dict[str, Any]:
        """Prefill one chunk for every mid-prompt lane (class order; the
        first lane's chunk always fits the budget so progress is
        guaranteed)."""
        self._last_kind = "prefill"
        lanes = sorted((r for r in self.sched.running.values()
                        if r.pending_prefill), key=self.sched._sort_key)
        # divergent-tail page copies must land before this step's writes
        self._run_cow_copies(lanes)
        budget = self.sched.cfg.token_budget
        quota: Dict[int, int] = {}
        for i, req in enumerate(lanes):
            n = self.sched.prefill_quota(
                req, budget if i else self.kv.max_seq_tokens())
            quota[req.rid] = n
            budget -= n
        active = [r for r in lanes if quota[r.rid] > 0]
        B = self.serve.max_batch
        S = _bucket(max(quota[r.rid] for r in active)) if active else 8
        tokens = np.zeros((B, S), np.int32)
        starts = np.zeros((B,), np.int32)
        counts = np.zeros((B,), np.int32)
        for req in active:
            n = quota[req.rid]
            ctx = req.context()
            tokens[req.slot, :n] = ctx[req.committed:req.committed + n]
            starts[req.slot] = req.committed
            counts[req.slot] = n
        next_tok, self.kv.pages = self._prefill_jit(
            self.values, self.kv.pages, jnp.asarray(tokens),
            jnp.asarray(starts), jnp.asarray(counts), self._table_batch(),
            self._next_key())
        next_tok = np.asarray(next_tok)
        now = time.time()
        n_new = 0
        for req in active:
            req.committed += quota[req.rid]
            self.sched.charge(req, quota[req.rid])
            self.kv.allocator.register_progress(
                req.blocks, req.keys, req.context(), req.committed)
            if not req.pending_prefill:
                # last chunk: the sample at position base_len-1 seeds
                # generation (mid-chunk samples are discarded) — for a
                # re-admission this continues prompt + prior tokens.
                self._commit_token(req, int(next_tok[req.slot]), now)
                n_new += 1
        return self.metrics.record_step(
            "prefill", generated=n_new,
            prefilled=int(counts.sum()), cached=cached,
            running=len(self.sched.running),
            waiting=len(self.sched.waiting),
            free_pages=self.kv.allocator.num_free, preempted=0,
            dt=now - t0)

    def _decode_step(self, t0: float) -> Dict[str, Any]:
        # the quantum is FIXED so exactly one decode executable exists; a
        # lane finishing mid-quantum (EOS / budget) has its overshoot
        # discarded — the stray writes stay inside its own *private*
        # pages (blocks past the last registered one are never shared,
        # and the block-table gather clamps to its last block) and the
        # pages are released right after the dispatch.
        self._last_kind = "decode"
        k = self.serve.decode_quantum
        preempted = self.sched.ensure_decode_capacity(k)
        lanes = [r for r in self.sched.running.values()
                 if not r.pending_prefill]
        if not lanes:
            return self.metrics.record_step(
                "decode", generated=0, prefilled=0, running=0,
                waiting=len(self.sched.waiting),
                free_pages=self.kv.allocator.num_free,
                preempted=len(preempted), dt=time.time() - t0)
        B = self.serve.max_batch
        tokens = np.zeros((B, 1), np.int32)
        pos = np.full((B,), -1, np.int32)
        for req in lanes:
            tokens[req.slot, 0] = req.last_token()
            pos[req.slot] = req.ctx_len() - 1
        toks, self.kv.pages = self._decode_jit(
            self.values, self.kv.pages, jnp.asarray(tokens),
            jnp.asarray(pos), self._table_batch(), self._next_key(), k)
        toks = np.asarray(toks)
        now = time.time()
        n_new = 0
        for req in lanes:
            got = 0
            for j in range(k):
                self._commit_token(req, int(toks[req.slot, j]), now)
                got += 1
                if req.done:
                    break                 # overshoot past EOS is discarded
            n_new += got
            self.sched.charge(req, got)
            if not req.done:
                self.kv.allocator.register_progress(
                    req.blocks, req.keys, req.context(),
                    req.ctx_len() - 1)
        return self.metrics.record_step(
            "decode", generated=n_new, prefilled=0,
            running=len(self.sched.running),
            waiting=len(self.sched.waiting),
            free_pages=self.kv.allocator.num_free,
            preempted=len(preempted), dt=now - t0)

    def stream(self, handle: RequestHandle,
               max_steps: Optional[int] = None) -> Iterator[int]:
        """Drive the engine until ``handle`` finishes, yielding its
        tokens as decode steps commit them (other in-flight requests
        progress too). TTFT is observable at the first yield."""
        steps = 0
        while True:
            for tok in handle.take_new():
                yield tok
            if handle.done:
                return
            if not self.sched.has_work:
                raise RuntimeError(f"request {handle.rid} cannot finish: "
                                   f"scheduler has no work")
            self.step()
            steps += 1
            if max_steps is not None and steps > max_steps:
                raise RuntimeError(f"stream exceeded {max_steps} steps")

    def drain(self, max_steps: Optional[int] = None
              ) -> List[RequestHandle]:
        """Run steps until every submitted request finished; returns the
        finished handles of this drain in completion order."""
        tracked = list(self.sched.waiting) \
            + list(self.sched.running.values())
        steps = 0
        while self.sched.has_work:
            self.step()
            steps += 1
            if max_steps is not None and steps > max_steps:
                raise RuntimeError(f"drain exceeded {max_steps} steps")
        return [r for r in tracked if r.done]

    def summary(self) -> Dict[str, Any]:
        s = self.metrics.summary()
        pool = self.kv.allocator
        s.update(free_pages=pool.num_free,
                 cached_pages=pool.num_cached,
                 waiting=len(self.sched.waiting),
                 running=len(self.sched.running),
                 prefix_hit_rate=round(self.kv.prefix_hit_rate, 4),
                 prefix_hit_tokens=pool.hit_tokens,
                 cow_copies=pool.cow_copies)
        return s

    def close(self) -> None:
        self.metrics.close()
