"""repro.serve — continuous-batching serving subsystem (DESIGN.md §7).

  kv_cache.py   paged KV cache: fixed-size pages, block tables, free list
  scheduler.py  FCFS token-budget admission, prefill/decode interleave,
                preempt-longest on block-pool OOM
  engine.py     ServeEngine: jitted paged prefill/decode over ShardCtx
  api.py        RequestHandle + jsonl serving metrics

The paged attention hot path dispatches through
``kernels.ops.paged_decode_attention`` (Pallas on TPU,
``REPRO_PAGED_ATTN_BACKEND`` override).
"""
from repro.run.config import SamplingSpec

from .api import FINISHED, RUNNING, WAITING, RequestHandle, ServeMetrics
from .engine import ServeConfig, ServeEngine
from .kv_cache import (SCRATCH_PAGE, BlockAllocator, PagedKVCache,
                       contiguous_from_paged, paged_from_contiguous)
from .scheduler import Scheduler, SchedulerConfig

__all__ = [
    "FINISHED", "RUNNING", "WAITING", "RequestHandle", "SamplingSpec",
    "ServeMetrics",
    "ServeConfig", "ServeEngine", "SCRATCH_PAGE", "BlockAllocator",
    "PagedKVCache", "contiguous_from_paged", "paged_from_contiguous",
    "Scheduler", "SchedulerConfig",
]
