"""repro.serve — continuous-batching serving subsystem (DESIGN.md §7, §11).

  kv_cache.py   paged KV cache: ref-counted pages, content-addressed
                prefix index, copy-on-write sharing
  scheduler.py  SLO-aware admission (priority / deadline / tenant
                fairness), chunked prefill, class-ordered preemption
  engine.py     ServeEngine: jitted paged prefill/decode over ShardCtx,
                streaming token delivery
  api.py        RequestHandle + jsonl serving metrics (TTFT / ITL)

The paged attention hot path dispatches through
``kernels.ops.paged_decode_attention`` (Pallas on TPU,
``REPRO_PAGED_ATTN_BACKEND`` override).
"""
from repro.run.config import SamplingSpec

from .api import FINISHED, RUNNING, WAITING, RequestHandle, ServeMetrics
from .engine import ServeConfig, ServeEngine
from .kv_cache import (SCRATCH_PAGE, AdmitPlan, BlockAllocator,
                       PagedKVCache, PrefixPagePool, contiguous_from_paged,
                       copy_pages, paged_from_contiguous)
from .scheduler import Scheduler, SchedulerConfig

__all__ = [
    "FINISHED", "RUNNING", "WAITING", "RequestHandle", "SamplingSpec",
    "ServeMetrics",
    "ServeConfig", "ServeEngine", "SCRATCH_PAGE", "AdmitPlan",
    "BlockAllocator", "PagedKVCache", "PrefixPagePool", "copy_pages",
    "contiguous_from_paged", "paged_from_contiguous",
    "Scheduler", "SchedulerConfig",
]
