"""Request/response surface + serving metrics (DESIGN.md §7, §11).

A :class:`RequestHandle` is both the scheduler's unit of work and the
caller's view of a request: ``ServeEngine.submit`` returns one, the
engine mutates it as the request moves WAITING -> RUNNING -> FINISHED
(preemption sends it back to WAITING with its progress kept), and
``tokens`` accumulates the generated ids. SLO fields ride on the handle:
``priority`` (lower = more important), an optional soft ``deadline_s``,
and a ``tenant`` label feeding the scheduler's fairness counters.

Streaming: ``take_new()`` drains the tokens generated since the last
call (a cursor, not a copy of history), so callers can emit tokens as
decode steps complete — ``ServeEngine.stream`` wraps it in a generator
and makes TTFT measurable at the API surface.

:class:`ServeMetrics` mirrors the trainer's metrics contract: one jsonl
record per engine step through the same (non-blocking) ``MetricsSink``,
plus throughput / latency counters aggregated into ``summary()`` —
p50/p99 TTFT and ITL (inter-token latency), and the preemption rate.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro import obs
from repro.launch.engine import MetricsSink

WAITING = "waiting"
RUNNING = "running"
FINISHED = "finished"


@dataclasses.dataclass
class RequestHandle:
    """One generation request and its live state."""

    rid: int
    prompt: List[int]                 # prompt token ids
    max_new: int                      # generation budget
    eos: Optional[int] = None         # stop token (None: run to max_new)

    # SLO class (scheduler sort keys; defaults reduce to FCFS)
    priority: int = 0                 # lower = more important
    deadline_s: Optional[float] = None  # soft deadline after submit
    tenant: str = "default"           # fairness accounting bucket
    arrival: int = 0                  # submit sequence number (tiebreak)

    status: str = WAITING
    tokens: List[int] = dataclasses.field(default_factory=list)  # generated
    t_submit: float = 0.0
    t_first_token: Optional[float] = None
    t_finish: Optional[float] = None
    n_preempt: int = 0

    # scheduler state (meaningful while RUNNING)
    slot: Optional[int] = None        # decode lane
    blocks: List[int] = dataclasses.field(default_factory=list)  # page ids
    base_len: int = 0                 # context length at last admission
    # prefill progress: context tokens whose KV is present in the pages
    # (adopted shared pages count; committed < base_len => still
    # prefilling in chunks)
    committed: int = 0
    keys: List[Any] = dataclasses.field(default_factory=list)  # chain keys
    cow: Optional[Tuple[int, int]] = None  # (src page, dst block) pending
    _streamed: int = 0                # take_new() cursor

    @property
    def done(self) -> bool:
        return self.status == FINISHED

    @property
    def pending_prefill(self) -> bool:
        """True while admitted context KV is still being (chunk-)built."""
        return self.committed < self.base_len

    def context(self) -> List[int]:
        """Prompt + everything generated so far — what a (re-)admission
        prefills; the last generated token is the next decode input."""
        return self.prompt + self.tokens

    def ctx_len(self) -> int:
        """len(context()) without building the list (hot-loop accessor)."""
        return len(self.prompt) + len(self.tokens)

    def last_token(self) -> int:
        """The next decode input: the most recent context token."""
        return self.tokens[-1] if self.tokens else self.prompt[-1]

    def take_new(self) -> List[int]:
        """Tokens generated since the last ``take_new`` (streaming)."""
        out = self.tokens[self._streamed:]
        self._streamed = len(self.tokens)
        return out

    @property
    def latency(self) -> Optional[float]:
        if self.t_finish is None:
            return None
        return self.t_finish - self.t_submit

    @property
    def ttft(self) -> Optional[float]:
        if self.t_first_token is None:
            return None
        return self.t_first_token - self.t_submit

    @property
    def itl(self) -> Optional[float]:
        """Mean inter-token latency over the generated run."""
        if self.t_finish is None or self.t_first_token is None \
                or len(self.tokens) < 2:
            return None
        return (self.t_finish - self.t_first_token) / (len(self.tokens) - 1)


def _percentile(xs: List[float], p: float) -> float:
    return float(np.percentile(np.asarray(xs), p)) if xs else 0.0


def _serve_record_line(record: Dict[str, Any]) -> str:
    parts = [f"step {record.get('step', 0):5d}",
             f"{record.get('kind', '?'):7s}",
             f"run={record.get('running', 0)}",
             f"wait={record.get('waiting', 0)}",
             f"tok/s={record.get('tokens_per_s', 0.0):.1f}"]
    if record.get("cached"):
        parts.append(f"cached={record['cached']}")
    if record.get("preempted"):
        parts.append(f"preempted={record['preempted']}")
    return "  ".join(parts)


class ServeMetrics:
    """Per-step serving metrics: jsonl records (trainer sink shape) +
    aggregate throughput / latency counters."""

    def __init__(self, path: Optional[str] = None, log_every: int = 10,
                 printer: Optional[Callable[[str], None]] = None,
                 clock: Callable[[], float] = time.time):
        self.sink = MetricsSink(path, log_every, printer,
                                formatter=_serve_record_line)
        self._clock = clock
        self._t0 = clock()
        self.steps = 0
        self.prefill_steps = 0
        self.decode_steps = 0
        self.tokens_prefilled = 0
        self.tokens_cached = 0        # prefill tokens skipped via sharing
        self.tokens_generated = 0
        self.preemptions = 0
        self.latencies: List[float] = []
        self.ttfts: List[float] = []
        self.itls: List[float] = []

    def record_step(self, kind: str, *, generated: int, prefilled: int,
                    running: int, waiting: int, free_pages: int,
                    preempted: int, dt: float,
                    cached: int = 0) -> Dict[str, Any]:
        self.steps += 1
        self.prefill_steps += kind == "prefill"
        self.decode_steps += kind == "decode"
        self.tokens_generated += generated
        self.tokens_prefilled += prefilled
        self.tokens_cached += cached
        self.preemptions += preempted
        if obs.tracing():
            obs.counter(f"serve.steps.{kind}")
            if generated:
                obs.counter("serve.tokens_generated", generated)
            if prefilled:
                obs.counter("serve.tokens_prefilled", prefilled)
            if cached:
                obs.counter("serve.tokens_cached", cached)
        record = {
            "step": self.steps, "kind": kind, "generated": generated,
            "prefilled": prefilled, "cached": cached, "running": running,
            "waiting": waiting, "free_pages": free_pages,
            "preempted": preempted,
            "step_s": round(dt, 6),
            "tokens_per_s": round(generated / dt, 3) if dt > 0 else 0.0,
            "tokens_generated_cumulative": self.tokens_generated,
        }
        self.sink.emit(record)
        return record

    def record_finish(self, handle: RequestHandle) -> None:
        if handle.latency is not None:
            self.latencies.append(handle.latency)
        if handle.ttft is not None:
            self.ttfts.append(handle.ttft)
        if handle.itl is not None:
            self.itls.append(handle.itl)

    def summary(self) -> Dict[str, Any]:
        wall = max(self._clock() - self._t0, 1e-9)
        done = max(len(self.latencies), 1)
        return {
            "steps": self.steps,
            "prefill_steps": self.prefill_steps,
            "decode_steps": self.decode_steps,
            "tokens_prefilled": self.tokens_prefilled,
            "tokens_cached": self.tokens_cached,
            "tokens_generated": self.tokens_generated,
            "preemptions": self.preemptions,
            "preemption_rate": round(self.preemptions / done, 4),
            "completed": len(self.latencies),
            "wall_s": round(wall, 3),
            "tokens_per_s": round(self.tokens_generated / wall, 3),
            "latency_p50_s": round(_percentile(self.latencies, 50), 6),
            "latency_p99_s": round(_percentile(self.latencies, 99), 6),
            "ttft_p50_s": round(_percentile(self.ttfts, 50), 6),
            "ttft_p99_s": round(_percentile(self.ttfts, 99), 6),
            "itl_p50_s": round(_percentile(self.itls, 50), 6),
            "itl_p99_s": round(_percentile(self.itls, 99), 6),
        }

    def close(self) -> None:
        self.sink.close()
