"""Serving throughput benchmark: continuous batching vs fixed batches.

Drives a synthetic Poisson arrival trace of mixed-length requests (short
generations with a heavy tail — the shape real traffic has) through two
backends over the SAME reduced model:

  fixed      the old path: requests grouped into fixed batches in arrival
             order; each batch prefills (step-wise) then runs
             ``greedy_decode`` until the LONGEST member finishes, so
             short sequences burn decode steps on padding.
  continuous ``repro.serve.ServeEngine``: paged KV cache + FCFS
             continuous batching; finished sequences free their lane and
             pages immediately.

Reports tokens/s (useful generated tokens / wall time) and per-request
p50/p99 latency from arrival, plus the continuous/fixed speedup — the
acceptance gate is >= 2x on the staggered trace.

A second leg (``make_shared_trace`` / ``run_shared``) measures the
redundancy stack (DESIGN.md §11): every request opens with the same
"system prompt", requests carry mixed priorities / deadlines / tenants,
and the engine runs with chunked prefill — once with the prefix cache
on and once off. Reported: p50/p99 TTFT and ITL, prefix-page hit rate,
preemption rate, the fraction of prefill compute the cache saved, and a
bitwise greedy-output equality flag between the two runs.

``run_bench`` is the facade entry (``repro.run.bench`` / ``python -m
repro bench``); ``benchmarks/serve_bench.py`` is the legacy script shim.
"""
from __future__ import annotations

import time
from typing import Any, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.models import model as M
from repro.models.nn import split_params
from repro.run.config import BenchSpec
from repro.serve import ServeConfig, ServeEngine
from repro.serve.api import _percentile as _pct


def make_trace(n: int, prompt_len: int, gen_short: int, gen_long: int,
               rate: float, seed: int):
    """Poisson arrivals; 1-in-4 requests carries the long generation (the
    heavy-tailed staggering that makes fixed batches burn padding steps).
    Prompts share one length so the fixed baseline's contiguous-cache
    prefill stays well-defined; the engine handles ragged prompts too
    (tests/test_serve.py)."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, size=n))
    reqs = []
    for i in range(n):
        gen = gen_long if i % 4 == 3 else gen_short
        prompt = rng.integers(0, 500, size=prompt_len).tolist()
        reqs.append((float(arrivals[i]), prompt, gen))
    return reqs


def run_fixed(cfg, values, trace, batch: int):
    """Arrival-order fixed batches; each decodes to its longest member."""
    from repro.launch.serve import greedy_decode, make_serve_step

    serve_step, _ = make_serve_step(cfg, None, batch)
    # both executables consume the KV cache and return its successor, so
    # the cache buffer is donated — the contiguous cache is the dominant
    # allocation here and would otherwise be double-buffered every step
    step_jit = jax.jit(serve_step, donate_argnums=(1,))
    decode_jit = jax.jit(
        lambda v, c, f, s, n: greedy_decode(cfg, v, c, f, s, n, serve_step),
        static_argnums=(4,), donate_argnums=(1,))
    # warm the executables (steady-state throughput, both backends)
    P = len(trace[0][1])
    max_g = max(g for _, _, g in trace)
    wcache, _ = split_params(M.init_cache(cfg, batch, P + max_g))
    wtok = jnp.zeros((batch, 1), jnp.int32)
    logits, wcache = step_jit(values, wcache, wtok,
                              jnp.zeros((batch,), jnp.int32))
    jax.block_until_ready(decode_jit(values, wcache, wtok,
                                     jnp.ones((batch,), jnp.int32), max_g))

    t0 = time.perf_counter()
    done_at: List[float] = []
    arrive = [a for a, _, _ in trace]
    useful = 0
    for lo in range(0, len(trace), batch):
        group = trace[lo:lo + batch]
        B = len(group)
        P = len(group[0][1])                 # uniform prompt length
        max_g = max(g for _, _, g in group)  # batch decodes to its longest
        # a fixed batch can only launch once its LAST member has arrived
        # (same arrival clock the continuous engine is gated on)
        wait = max(a for a, _, _ in group) - (time.perf_counter() - t0)
        if wait > 0:
            time.sleep(wait)
        tokens = jnp.asarray(np.stack([p for _, p, _ in group]))
        cache, _ = split_params(M.init_cache(cfg, B, P + max_g))
        logits = None
        for t in range(P):
            logits, cache = step_jit(values, cache, tokens[:, t:t + 1],
                                     jnp.full((B,), t, jnp.int32))
        first = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        toks, _ = decode_jit(values, cache, first,
                             jnp.full((B,), P, jnp.int32), max_g)
        jax.block_until_ready(toks)
        end = time.perf_counter() - t0
        # every member waits for the batch's longest: latency from arrival
        for _, _, g in group:
            useful += g                      # tokens the caller asked for
            done_at.append(end)
    wall = time.perf_counter() - t0
    lats = [d - a for d, a in zip(done_at, arrive)]
    return {"tokens": useful, "wall_s": wall,
            "tokens_per_s": useful / wall,
            "latency_p50_s": _pct(lats, 50), "latency_p99_s": _pct(lats, 99)}


def run_continuous(cfg, params, trace, batch: int, page_size: int,
                   num_pages: int):
    max_tokens = max(len(p) + g for _, p, g in trace)
    engine = ServeEngine(cfg, params, ServeConfig(
        max_batch=batch, page_size=page_size, num_pages=num_pages,
        max_blocks_per_seq=-(-max_tokens // page_size),
        token_budget=4 * max(len(p) for _, p, _ in trace),
        log_every=10 ** 9))
    # warm the prefill bucket + decode quantum executables
    for _, prompt, _ in trace[:batch]:
        engine.submit(prompt, max_new=2 * engine.serve.decode_quantum)
    engine.drain()

    t0 = time.perf_counter()
    pending = list(trace)
    handles = []
    while pending or engine.sched.has_work:
        now = time.perf_counter() - t0
        while pending and pending[0][0] <= now:
            _, prompt, gen = pending.pop(0)
            handles.append(engine.submit(prompt, max_new=gen))
        if engine.sched.has_work:
            engine.step()
        elif pending:
            time.sleep(min(pending[0][0] - now, 0.01))
    wall = time.perf_counter() - t0
    preempts = engine.metrics.preemptions
    engine.close()
    tokens = sum(len(h.tokens) for h in handles)
    lats = [h.latency for h in handles]
    return {"tokens": tokens, "wall_s": wall,
            "tokens_per_s": tokens / wall,
            "latency_p50_s": _pct(lats, 50), "latency_p99_s": _pct(lats, 99),
            "preemptions": preempts}


def make_shared_trace(n: int, shared_len: int, tail_len: int,
                      gen_short: int, gen_long: int, rate: float,
                      seed: int):
    """Poisson arrivals where every prompt = one common ``shared_len``
    system prefix + a unique ``tail_len`` tail, with mixed SLO classes:
    1-in-4 requests is interactive (priority 0, a soft deadline), the
    rest are batch (priority 1); tenants alternate. 1-in-4 carries the
    long generation, as in ``make_trace``."""
    rng = np.random.default_rng(seed)
    # tokens stay below 256 so the warmup in run_shared can use the
    # disjoint 256..511 range and still be in-vocab for reduced configs
    shared = rng.integers(0, 256, size=shared_len).tolist()
    arrivals = np.cumsum(rng.exponential(1.0 / rate, size=n))
    reqs = []
    for i in range(n):
        gen = gen_long if i % 4 == 3 else gen_short
        prompt = shared + rng.integers(0, 256, size=tail_len).tolist()
        priority, deadline = (0, 0.5) if i % 4 == 1 else (1, None)
        reqs.append((float(arrivals[i]), prompt, gen, priority, deadline,
                     f"t{i % 2}"))
    return reqs


def run_shared(cfg, params, trace, batch: int, page_size: int,
               num_pages: int, chunk: int, prefix_cache: bool):
    """Drive the mixed-priority shared-prefix trace through the engine
    with the prefix cache on or off (same arrival gating as
    ``run_continuous``); returns latency/SLO/sharing metrics plus the
    per-request greedy outputs (submission order) for the bitwise
    on-vs-off comparison."""
    max_tokens = max(len(p) + g for _, p, g, *_ in trace)
    engine = ServeEngine(cfg, params, ServeConfig(
        max_batch=batch, page_size=page_size, num_pages=num_pages,
        max_blocks_per_seq=-(-max_tokens // page_size),
        token_budget=4 * max(len(p) for _, p, _, *_ in trace),
        prefill_chunk=chunk, prefix_cache=prefix_cache,
        log_every=10 ** 9))
    # warm the executables on disjoint token ids (256..511: no false
    # prefix hits, still in-vocab — out-of-vocab ids would write NaN KV
    # that poisons later reuses of the pages), then zero the sharing
    # counters the warmup touched
    for _, prompt, _, *_ in trace[:batch]:
        warm = [256 + t % 256 for t in prompt]
        engine.submit(warm, max_new=min(2 * engine.serve.decode_quantum,
                                        engine.kv.max_seq_tokens()
                                        - len(warm)))
    engine.drain()
    pool = engine.kv.allocator
    pool.admit_tokens = pool.hit_tokens = pool.cow_copies = 0

    t0 = time.perf_counter()
    pending = list(trace)
    handles = []
    while pending or engine.sched.has_work:
        now = time.perf_counter() - t0
        while pending and pending[0][0] <= now:
            _, prompt, gen, prio, deadline, tenant = pending.pop(0)
            handles.append(engine.submit(prompt, max_new=gen,
                                         priority=prio,
                                         deadline_s=deadline,
                                         tenant=tenant))
        if engine.sched.has_work:
            engine.step()
        elif pending:
            time.sleep(min(pending[0][0] - now, 0.01))
    wall = time.perf_counter() - t0
    engine.sched.check_invariants()
    summary = engine.summary()
    engine.close()
    tokens = sum(len(h.tokens) for h in handles)
    ttfts = [h.ttft for h in handles if h.ttft is not None]
    itls = [h.itl for h in handles if h.itl is not None]
    return {"tokens": tokens, "wall_s": wall,
            "tokens_per_s": tokens / wall,
            "ttft_p50_s": _pct(ttfts, 50), "ttft_p99_s": _pct(ttfts, 99),
            "itl_p50_s": _pct(itls, 50), "itl_p99_s": _pct(itls, 99),
            "prefilled": summary["tokens_prefilled"],
            "prefix_hit_rate": summary["prefix_hit_rate"],
            "cow_copies": summary["cow_copies"],
            "preemptions": summary["preemptions"],
            "preemption_rate": summary["preemption_rate"],
            "outputs": [list(h.tokens) for h in handles]}


def run_bench(arch: str, spec: BenchSpec,
              verbose: bool = True) -> Dict[str, Any]:
    """Both backends over one trace -> {"fixed", "continuous", "speedup"}."""
    cfg = reduced(get_config(arch))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    values, _ = split_params(params)
    trace = make_trace(spec.requests, spec.prompt_len, spec.gen_short,
                       spec.gen_long, spec.rate, spec.seed)

    fixed = run_fixed(cfg, values, trace, spec.batch)
    cont = run_continuous(cfg, params, trace, spec.batch, spec.page_size,
                          spec.num_pages)
    speedup = cont["tokens_per_s"] / fixed["tokens_per_s"]

    # the redundancy leg: same engine, shared-prefix mixed-priority trace,
    # prefix cache off vs on (shorter long-gen tail to bound runtime)
    strace = make_shared_trace(
        spec.requests, spec.shared_prefix_len, spec.prompt_len,
        spec.gen_short, max(spec.gen_short, spec.gen_long // 2),
        spec.rate, spec.seed)
    off = run_shared(cfg, params, strace, spec.batch, spec.page_size,
                     spec.num_pages, spec.prefill_chunk, prefix_cache=False)
    on = run_shared(cfg, params, strace, spec.batch, spec.page_size,
                    spec.num_pages, spec.prefill_chunk, prefix_cache=True)
    outputs_equal = float(on.pop("outputs") == off.pop("outputs"))
    prefill_saved = 1.0 - on["prefilled"] / max(off["prefilled"], 1)
    shared_speedup = on["tokens_per_s"] / max(off["tokens_per_s"], 1e-9)

    if verbose:
        print(f"arch={cfg.name} requests={spec.requests} "
              f"batch={spec.batch} gen={spec.gen_short}/{spec.gen_long} "
              f"rate={spec.rate}/s")
        for name, r in (("fixed", fixed), ("continuous", cont)):
            print(f"  {name:10s} {r['tokens']:5d} tok  "
                  f"{r['tokens_per_s']:8.1f} tok/s  "
                  f"p50={r['latency_p50_s']:.2f}s "
                  f"p99={r['latency_p99_s']:.2f}s")
        print(f"  continuous/fixed tokens/s: {speedup:.2f}x")
        print(f"  shared-prefix trace (prefix={spec.shared_prefix_len} "
              f"chunk={spec.prefill_chunk}):")
        for name, r in (("cache off", off), ("cache on", on)):
            print(f"  {name:10s} {r['tokens']:5d} tok  "
                  f"{r['tokens_per_s']:8.1f} tok/s  "
                  f"prefilled={r['prefilled']:5d}  "
                  f"ttft p50={r['ttft_p50_s']:.3f}s "
                  f"p99={r['ttft_p99_s']:.3f}s  "
                  f"preempt={r['preemptions']}")
        print(f"  hit_rate={on['prefix_hit_rate']:.3f} "
              f"prefill_saved={100.0 * prefill_saved:.1f}% "
              f"cow={on['cow_copies']} "
              f"outputs_equal={bool(outputs_equal)}")
    return {"fixed": fixed, "continuous": cont, "speedup": speedup,
            "shared_off": off, "shared_on": on,
            "prefix_hit_rate": on["prefix_hit_rate"],
            "prefill_saved": prefill_saved,
            "shared_speedup": shared_speedup,
            "prefix_outputs_equal": outputs_equal}
