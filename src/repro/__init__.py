"""Echo-CGC reproduction grown into a jax/Pallas training+serving stack.

Public entry points:

    repro.run            declarative job API (RunConfig + registries +
                         train/serve/dryrun/bench facades)
    python -m repro      unified CLI over job files (see README.md)

Subsystems (DESIGN.md): ``core`` paper math, ``models`` LM substrate,
``dist`` sharding + collectives, ``kernels`` Pallas, ``launch`` engine +
legacy CLIs, ``serve`` continuous batching, ``checkpoint`` snapshots.
"""

__version__ = "0.1.0"
