"""Command R+ 104B — dense GQA decoder [hf:CohereForAI/c4ai-command-r-v01].

64L, d_model=12288, 96 heads, GQA kv=8, d_ff=33792, vocab 256000,
no biases, tied embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b",
    family="dense",
    source="hf:CohereForAI/c4ai-command-r-v01",
    num_layers=64,
    d_model=12288,
    num_heads=96,
    num_kv_heads=8,
    d_ff=33792,
    vocab_size=256000,
    attn_type="gqa",
    use_bias=False,
    tie_embeddings=True,
    head_dim=128,
    rope_theta=1e4,
)
