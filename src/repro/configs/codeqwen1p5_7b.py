"""CodeQwen1.5-7B — Qwen1.5 architecture [hf:Qwen/CodeQwen1.5-7B].

32L, d_model=4096, 32 heads (MHA, kv=32), d_ff=13440, vocab 92416,
attention QKV bias (Qwen1.5 style).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1.5-7b",
    family="dense",
    source="hf:Qwen/CodeQwen1.5-7B",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    d_ff=13440,
    vocab_size=92416,
    attn_type="gqa",
    use_bias=True,
    head_dim=128,
    rope_theta=1e6,
)
