"""Zamba2-2.7B — Mamba2 backbone + shared attention blocks [arXiv:2411.15242].

54 Mamba2 layers, d_model=2560, ssm_state=64; one *shared* transformer block
(32-head attention + d_ff=10240 MLP, same weights every application) applied
every 6 Mamba layers — the Zamba weight-sharing trick.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    source="arXiv:2411.15242",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    d_ff=10240,                # shared-block MLP hidden
    vocab_size=32000,
    attn_type="gqa",
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=128,
    conv_width=4,
    shared_attn_every=6,
    rope_theta=1e4,
)
