"""Config system: model architecture configs, input shapes, and the registry.

Every assigned architecture lives in ``repro/configs/<id>.py`` exposing
``CONFIG`` (the exact published configuration) built on :class:`ModelConfig`.
``reduced()`` derives the CPU smoke-test variant (<=2 layers, d_model<=512,
<=4 experts) from the same family so smoke tests exercise identical code
paths as the full dry-run configs.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture description. Defaults suit a dense GQA decoder."""

    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    source: str = ""                 # citation (paper / model card)

    # --- attention ---
    attn_type: str = "gqa"           # gqa | mla | none
    head_dim: Optional[int] = None   # default d_model // num_heads
    qk_norm: bool = False
    rope_theta: float = 1e4
    mrope: bool = False              # Qwen2-VL M-RoPE
    mrope_sections: Tuple[int, ...] = (16, 24, 24)
    sliding_window: Optional[int] = None
    use_bias: bool = False
    causal: bool = True

    # --- MLA (deepseek-v2 / minicpm3) ---
    q_lora_rank: int = 0             # 0 = full-rank q projection
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # --- MoE ---
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0                # per-expert hidden size
    first_dense_layers: int = 0      # leading dense layers (deepseek-v2)
    router_aux_coef: float = 0.01    # load-balance loss weight
    capacity_factor: float = 1.25

    # --- SSM (mamba2) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 128
    conv_width: int = 4

    # --- hybrid (zamba2): shared attention block every k mamba layers ---
    shared_attn_every: int = 0

    # --- xLSTM ---
    xlstm_pattern: Tuple[str, ...] = ()   # per-layer 'm' (mLSTM) / 's' (sLSTM)

    # --- encoder-only (hubert) ---
    is_encoder: bool = False

    # --- modality frontend stubs ---
    frontend: Optional[str] = None   # None | "audio" | "vision"
    num_vision_tokens: int = 1024    # VLM: leading positions fed by stub

    # --- misc ---
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"          # compute dtype
    param_dtype: str = "float32"
    vocab_round: int = 256           # pad vocab to a multiple (sharding)
    tie_embeddings: bool = False

    # -----------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        r = self.vocab_round
        return (self.vocab_size + r - 1) // r * r

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_num_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    @property
    def has_decode(self) -> bool:
        """Encoder-only models have no autoregressive decode step."""
        return not self.is_encoder

    def supports_long_context(self) -> bool:
        """Sub-quadratic in sequence length (native or via sliding window)."""
        if self.family in ("ssm", "hybrid"):
            return True
        if self.attn_type == "none":
            return True
        return self.sliding_window is not None

    def with_sliding_window(self, window: int = 8192) -> "ModelConfig":
        """Explicit long-context variant (DESIGN.md §4): windowed attention."""
        return dataclasses.replace(self, sliding_window=window)


def reduced(cfg: ModelConfig, layers: int = 2, d_model: int = 256,
            seq_friendly: bool = True) -> ModelConfig:
    """Smoke-test variant of the same family: tiny but same code paths."""
    heads = max(min(cfg.num_heads, 4), 1)
    kv = max(min(cfg.num_kv_heads, heads), 1)
    hd = max(d_model // heads, 32)
    changes: Dict = dict(
        name=cfg.name + "-smoke",
        num_layers=layers,
        d_model=d_model,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=hd,
        d_ff=min(cfg.d_ff, 4 * d_model) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 512),
        vocab_round=64,
        num_vision_tokens=min(cfg.num_vision_tokens, 8),
        dtype="float32",
    )
    if cfg.num_experts:
        changes.update(
            num_experts=min(cfg.num_experts, 4),
            top_k=min(cfg.top_k, 2),
            moe_d_ff=min(cfg.moe_d_ff, d_model),
            num_shared_experts=min(cfg.num_shared_experts, 1),
            first_dense_layers=min(cfg.first_dense_layers, 1),
            # dropless at smoke scale so decode == forward exactly
            capacity_factor=float(min(cfg.num_experts, 4)),
        )
    if cfg.attn_type == "mla":
        changes.update(
            q_lora_rank=min(cfg.q_lora_rank, 128) if cfg.q_lora_rank else 0,
            kv_lora_rank=min(cfg.kv_lora_rank, 64),
            qk_nope_head_dim=32,
            qk_rope_head_dim=16,
            v_head_dim=hd,
        )
    if cfg.ssm_state:
        changes.update(ssm_state=min(cfg.ssm_state, 16), ssm_head_dim=32,
                       ssm_chunk=32)
    if cfg.shared_attn_every:
        changes.update(shared_attn_every=min(cfg.shared_attn_every, layers))
    if cfg.mrope:
        half = hd // 2
        tot = sum(cfg.mrope_sections)
        secs = [s * half // tot for s in cfg.mrope_sections]
        secs[0] += half - sum(secs)
        changes.update(mrope_sections=tuple(secs))
    if cfg.xlstm_pattern:
        changes.update(xlstm_pattern=cfg.xlstm_pattern[:layers] or
                       tuple("ms"[: layers]))
    if cfg.sliding_window:
        changes.update(sliding_window=min(cfg.sliding_window, 64))
    return dataclasses.replace(cfg, **changes)


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # "train" | "prefill" | "decode"


INPUT_SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Architecture registry
# ---------------------------------------------------------------------------

ARCH_IDS = [
    "deepseek-v2-236b",
    "zamba2-2.7b",
    "minicpm3-4b",
    "codeqwen1.5-7b",
    "hubert-xlarge",
    "command-r-plus-104b",
    "xlstm-125m",
    "qwen2-vl-72b",
    "qwen3-moe-30b-a3b",
    "qwen3-0.6b",
]

_MODULE_FOR = {a: "repro.configs." + a.replace("-", "_").replace(".", "p")
               for a in ARCH_IDS}


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULE_FOR:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULE_FOR)}")
    mod = importlib.import_module(_MODULE_FOR[arch])
    return mod.CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
