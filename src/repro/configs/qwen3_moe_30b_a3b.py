"""Qwen3-30B-A3B — MoE, 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B].

48L, d_model=2048, 32 heads, GQA kv=4, per-expert d_ff=768, vocab 151936,
qk-norm, head_dim=128, no shared experts.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    source="hf:Qwen/Qwen3-30B-A3B",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    d_ff=0,                    # all layers MoE
    vocab_size=151936,
    attn_type="gqa",
    qk_norm=True,
    head_dim=128,
    num_experts=128,
    top_k=8,
    moe_d_ff=768,
    num_shared_experts=0,
    first_dense_layers=0,
    rope_theta=1e6,
)
