from .base import (ARCH_IDS, INPUT_SHAPES, ModelConfig, ShapeConfig,
                   all_configs, get_config, reduced)

__all__ = ["ARCH_IDS", "INPUT_SHAPES", "ModelConfig", "ShapeConfig",
           "all_configs", "get_config", "reduced"]
