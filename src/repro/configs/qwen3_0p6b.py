"""Qwen3-0.6B — dense GQA with qk-norm [hf:Qwen/Qwen3-8B family].

28L, d_model=1024, 16 heads, GQA kv=8, d_ff=3072, vocab 151936, head_dim 128,
qk-norm, tied embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b",
    family="dense",
    source="hf:Qwen/Qwen3-8B (family card)",
    num_layers=28,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    d_ff=3072,
    vocab_size=151936,
    attn_type="gqa",
    qk_norm=True,
    head_dim=128,
    tie_embeddings=True,
    rope_theta=1e6,
)
