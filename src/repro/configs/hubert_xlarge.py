"""HuBERT X-Large — encoder-only audio backbone [arXiv:2106.07447].

48L, d_model=1280, 16 heads, d_ff=5120, 504 k-means target classes.
Encoder-only: bidirectional attention, masked-prediction CE loss, NO decode
step (decode_32k / long_500k skipped — DESIGN.md §4). The conv waveform
feature extractor is the assigned STUB: ``input_specs`` feeds precomputed
frame embeddings of shape (batch, frames, d_model).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    source="arXiv:2106.07447",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    d_ff=5120,
    vocab_size=504,
    attn_type="gqa",
    causal=False,
    is_encoder=True,
    frontend="audio",
    use_bias=True,
    rope_theta=1e4,
)
