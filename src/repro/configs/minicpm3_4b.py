"""MiniCPM3-4B — dense decoder with MLA [hf:openbmb/MiniCPM3-4B].

62L, d_model=2560, 40 heads, MLA (q_lora=768, kv_lora=256, qk_nope=64,
qk_rope=32, v=64), d_ff=6400, vocab 73448 (padded to 73472 for sharding).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    family="dense",
    source="hf:openbmb/MiniCPM3-4B",
    num_layers=62,
    d_model=2560,
    num_heads=40,
    num_kv_heads=40,
    d_ff=6400,
    vocab_size=73448,
    attn_type="mla",
    q_lora_rank=768,
    kv_lora_rank=256,
    qk_nope_head_dim=64,
    qk_rope_head_dim=32,
    v_head_dim=64,
    head_dim=64,
    rope_theta=1e4,
)
