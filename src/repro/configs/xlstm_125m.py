"""xLSTM-125M — sLSTM + mLSTM blocks [arXiv:2405.04517].

12L, d_model=768, 4 heads, vocab 50304, d_ff=0 (xLSTM blocks carry their own
up/down projections: mLSTM pf=2, sLSTM gated pf=4/3). Block pattern 3:1
mLSTM:sLSTM (paper's sparse-sLSTM placements).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    source="arXiv:2405.04517",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    attn_type="none",
    xlstm_pattern=("m", "m", "m", "s") * 3,
)
