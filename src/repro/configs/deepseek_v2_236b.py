"""DeepSeek-V2 236B — MoE with Multi-head Latent Attention [arXiv:2405.04434].

60L, d_model=5120, 128 heads, MLA kv_lora=512 (q_lora=1536, qk_nope=128,
qk_rope=64, v=128), 160 routed experts top-6 + 2 shared, per-expert
d_ff=1536, first layer dense (d_ff=12288), vocab 102400.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    source="arXiv:2405.04434",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,          # MLA: kv heads == heads post up-projection
    d_ff=12288,                # dense (first) layer hidden
    vocab_size=102400,
    attn_type="mla",
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    head_dim=128,
    num_experts=160,
    num_shared_experts=2,
    top_k=6,
    moe_d_ff=1536,
    first_dense_layers=1,
    rope_theta=1e4,
)
