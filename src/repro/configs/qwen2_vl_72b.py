"""Qwen2-VL-72B — VLM decoder with M-RoPE [arXiv:2409.12191].

80L, d_model=8192, 64 heads, GQA kv=8, d_ff=29568, vocab 152064, QKV bias,
M-RoPE sections (16, 24, 24). The ViT vision encoder + projector is the
assigned STUB: ``input_specs`` feeds precomputed patch embeddings for the
leading ``num_vision_tokens`` positions (dynamic resolution abstracted as a
variable vision-token count).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    source="arXiv:2409.12191",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    attn_type="gqa",
    use_bias=True,
    head_dim=128,
    mrope=True,
    mrope_sections=(16, 24, 24),
    frontend="vision",
    num_vision_tokens=1024,
    rope_theta=1e6,
)
