"""The paper's own experimental setting (Sec. 4.3 numerical analysis).

Echo-CGC is model-agnostic — its "architecture" is the protocol
configuration. These are the operating points used in the paper's Figure 1
and headline claims, reused by benchmarks and EXPERIMENTS.md §Repro.
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class PaperSetting:
    n: int = 100           # workers
    x: float = 0.1         # resilience f/n
    sigma: float = 0.1     # relative gradient noise (Assumption 5)
    mu_over_L: float = 1.0 # cost-function conditioning
    d: int = 1000          # feature dimension for simulations (d >> n)

    @property
    def f(self) -> int:
        return int(self.x * self.n)


# Figure-1 sweep grids (one per panel).
FIG1A = dict(sigma=[0.01 * i for i in range(1, 16)], x=0.1, mu_over_L=1.0,
             n=100)
FIG1B = dict(mu_over_L=[0.5 + 0.025 * i for i in range(21)], sigma=0.1,
             x=0.1, n=100)
FIG1C = dict(x=[0.005 * i for i in range(1, 40)], sigma=0.1, mu_over_L=1.0,
             n=100)
FIG1D = dict(n=[20 * i for i in range(1, 26)], sigma=0.1, mu_over_L=1.0,
             x=0.1)

HEADLINE = PaperSetting()   # sigma=0.1, x=0.1, n=100 -> C ~ 0.22 (save >75%)
