from .ckpt import (TRAIN_STATE_FORMAT, AsyncCheckpointWriter, latest_step,
                   restore, restore_train_state, save, save_train_state)

__all__ = ["TRAIN_STATE_FORMAT", "AsyncCheckpointWriter", "latest_step",
           "restore", "restore_train_state", "save", "save_train_state"]
