"""Checkpointing: flat .npz snapshots of arbitrary pytrees.

Sharded arrays are gathered to host before writing (fine at the scales this
container trains; a real multi-host deployment would write per-shard files —
the directory layout already namespaces by step so that extension is local
to this module). Restore reshards via ``jax.device_put`` with the target
sharding tree when one is provided.
"""
from __future__ import annotations

import json
import os
import queue
import re
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = leaf
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save(directory: str, step: int, tree, extra: Optional[Dict] = None
         ) -> str:
    """Write <dir>/step_<N>.npz (+ sidecar json). Returns the path."""
    os.makedirs(directory, exist_ok=True)
    flat = _flatten_with_paths(tree)
    arrays = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    path = os.path.join(directory, f"step_{step:08d}.npz")
    tmp = path + ".tmp"
    with open(tmp, "wb") as fh:
        np.savez(fh, **arrays)
    os.replace(tmp, path)
    meta = {"step": step, "keys": sorted(arrays), **(extra or {})}
    with open(os.path.join(directory, f"step_{step:08d}.json"), "w") as fh:
        json.dump(meta, fh)
    return path


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for f in os.listdir(directory)
             if (m := re.fullmatch(r"step_(\d+)\.npz", f))]
    return max(steps) if steps else None


def restore(directory: str, like, step: Optional[int] = None,
            shardings=None) -> Tuple[Any, int]:
    """Restore into the structure of ``like``; optionally reshard.

    Returns (tree, step). Raises FileNotFoundError if no checkpoint exists.
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:08d}.npz")
    data = np.load(path)
    flat_like = _flatten_with_paths(like)
    missing = set(flat_like) - set(data.files)
    if missing:
        raise KeyError(f"checkpoint {path} missing keys: {sorted(missing)[:5]}"
                       f" (+{max(len(missing) - 5, 0)} more)")
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    keys = ["/".join(_path_str(p) for p in path_)
            for path_, _ in jax.tree_util.tree_flatten_with_path(like)[0]]
    arrays = [data[k] for k in keys]
    tree = jax.tree_util.tree_unflatten(treedef, arrays)
    if shardings is not None:
        tree = jax.tree.map(jax.device_put, tree, shardings)
    else:
        tree = jax.tree.map(jax.numpy.asarray, tree)
    return tree, step


# ---------------------------------------------------------------------------
# Complete training snapshots: (values, opt_state, step, extras)
# ---------------------------------------------------------------------------

TRAIN_STATE_FORMAT = "train-state-v1"


def save_train_state(directory: str, step: int, values, opt_state,
                     extra_state: Optional[Dict] = None,
                     extra: Optional[Dict] = None) -> str:
    """Write a complete training snapshot under one step file.

    ``extra_state`` carries strategy extras (e.g. the echo reference
    basis, ``{"basis": [...]}``); ``extra`` is free-form sidecar-json
    metadata. Use :func:`restore_train_state` to read it back — a resume
    restores optimizer moments and the basis, not just the weights.
    """
    tree = {"values": values, "opt_state": opt_state}
    if extra_state:
        tree["extra"] = extra_state
    meta = {"format": TRAIN_STATE_FORMAT}
    meta.update(extra or {})
    return save(directory, step, tree, extra=meta)


class AsyncCheckpointWriter:
    """Background-thread checkpoint writes (mirrors the metrics sink).

    ``submit`` enqueues one :func:`save_train_state` call and returns the
    target path immediately — jax arrays are immutable, so holding
    references is a consistent snapshot and the ``device_get`` +
    ``np.savez`` cost moves off the caller (the Trainer driver loop).
    Writes land in submission order through one worker thread; the
    atomic ``.tmp`` + ``os.replace`` in :func:`save` means a reader
    never sees a half-written file. ``flush`` blocks until everything
    enqueued so far is on disk; ``close`` flushes, stops the thread and
    re-raises the first write error (as ``flush`` does), so failures
    are never silently dropped.
    """

    def __init__(self) -> None:
        self._q: queue.SimpleQueue = queue.SimpleQueue()
        self._error: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._loop,
                                        name="ckpt-writer", daemon=True)
        self._thread.start()

    def _loop(self) -> None:
        while True:
            item = self._q.get()
            if item is None:                       # close sentinel
                return
            if isinstance(item, threading.Event):  # flush barrier
                item.set()
                continue
            args, kwargs = item
            try:
                # span lands on the writer thread: nesting is per-thread,
                # so it shows up as a root "checkpoint.write" entry in the
                # breakdown rather than under the driver's spans.
                from repro import obs
                with obs.span("checkpoint.write"):
                    save_train_state(*args, **kwargs)
                obs.counter("checkpoint.writes")
            except BaseException as e:             # surfaced on flush/close
                if self._error is None:
                    self._error = e

    def _raise_pending(self) -> None:
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError("async checkpoint write failed") from err

    def submit(self, directory: str, step: int, values, opt_state,
               extra_state: Optional[Dict] = None,
               extra: Optional[Dict] = None) -> str:
        """Enqueue one training snapshot; returns the path it will get."""
        if not self._thread.is_alive():
            raise RuntimeError("AsyncCheckpointWriter is closed")
        self._q.put(((directory, step, values, opt_state),
                     dict(extra_state=extra_state, extra=extra)))
        return os.path.join(directory, f"step_{step:08d}.npz")

    def flush(self, timeout: Optional[float] = None) -> bool:
        """Block until every submitted snapshot is on disk (re-raises the
        first write error). With a timeout, returns False on expiry."""
        if self._thread.is_alive():
            barrier = threading.Event()
            self._q.put(barrier)
            if not barrier.wait(timeout):
                return False
        self._raise_pending()
        return True

    def close(self) -> None:
        if self._thread.is_alive():
            self._q.put(None)
            # the worker drains everything queued before the sentinel,
            # so joining IS the flush.
            self._thread.join()
        self._raise_pending()


def _snapshot_keys(directory: str, step: Optional[int]):
    """(stored flat keys, resolved step) of one checkpoint file."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:08d}.npz")
    with np.load(path) as data:
        return set(data.files), step


def restore_train_state(directory: str, values_like, opt_state_like,
                        extra_like=None, step: Optional[int] = None,
                        shardings=None):
    """Restore a training snapshot -> (values, opt_state, extra_state,
    step, complete).

    ``complete`` reports whether optimizer state was restored. Two
    degradation paths keep resumes working across formats/strategies:

    * a pre-v1 checkpoint (a bare values tree, as the old trainer CLI
      wrote) restores the values only — ``opt_state`` and
      ``extra_state`` come back as the passed templates (fresh state)
      and ``complete`` is False so the caller can reset what it must;
    * a v1 checkpoint whose extras are absent or shaped differently
      from ``extra_like`` (e.g. a replicated snapshot resumed under
      echo_dp, or a changed basis size) restores values + opt_state and
      returns ``extra_like`` untouched.

    ``shardings`` (optional) must match the ``{"values", "opt_state"
    [, "extra"]}`` tree and is applied on the v1 paths.
    """
    stored, step = _snapshot_keys(directory, step)
    if not any(k == "values" or k.startswith("values/") for k in stored):
        # pre-v1: the whole file is the values tree
        values, at = restore(directory, values_like, step=step)
        return values, opt_state_like, extra_like, at, False
    base = {"values": values_like, "opt_state": opt_state_like}
    if extra_like is not None:
        # Extras restore only on an EXACT key-set match — a subset match
        # would silently hand back a stale prefix (e.g. the oldest
        # basis entries after shrinking echo_k).
        expected = set(_flatten_with_paths({"extra": extra_like}))
        stored_extra = {k for k in stored if k.startswith("extra/")}
        if expected == stored_extra:
            tree, at = restore(directory, dict(base, extra=extra_like),
                               step=step, shardings=shardings)
            shapes_ok = all(
                tuple(a.shape) == tuple(getattr(b, "shape", ()))
                for a, b in zip(jax.tree.leaves(tree["extra"]),
                                jax.tree.leaves(extra_like)))
            if shapes_ok:
                return (tree["values"], tree["opt_state"], tree["extra"],
                        at, True)
            return tree["values"], tree["opt_state"], extra_like, at, True
    base_shardings = shardings
    if isinstance(shardings, dict) and "extra" in shardings:
        base_shardings = {k: v for k, v in shardings.items()
                          if k != "extra"}
    tree, at = restore(directory, base, step=step,
                       shardings=base_shardings)
    return tree["values"], tree["opt_state"], extra_like, at, True
