"""Checkpointing: flat .npz snapshots of arbitrary pytrees.

Sharded arrays are gathered to host before writing (fine at the scales this
container trains; a real multi-host deployment would write per-shard files —
the directory layout already namespaces by step so that extension is local
to this module). Restore reshards via ``jax.device_put`` with the target
sharding tree when one is provided.
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = leaf
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save(directory: str, step: int, tree, extra: Optional[Dict] = None
         ) -> str:
    """Write <dir>/step_<N>.npz (+ sidecar json). Returns the path."""
    os.makedirs(directory, exist_ok=True)
    flat = _flatten_with_paths(tree)
    arrays = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    path = os.path.join(directory, f"step_{step:08d}.npz")
    tmp = path + ".tmp"
    with open(tmp, "wb") as fh:
        np.savez(fh, **arrays)
    os.replace(tmp, path)
    meta = {"step": step, "keys": sorted(arrays), **(extra or {})}
    with open(os.path.join(directory, f"step_{step:08d}.json"), "w") as fh:
        json.dump(meta, fh)
    return path


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for f in os.listdir(directory)
             if (m := re.fullmatch(r"step_(\d+)\.npz", f))]
    return max(steps) if steps else None


def restore(directory: str, like, step: Optional[int] = None,
            shardings=None) -> Tuple[Any, int]:
    """Restore into the structure of ``like``; optionally reshard.

    Returns (tree, step). Raises FileNotFoundError if no checkpoint exists.
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:08d}.npz")
    data = np.load(path)
    flat_like = _flatten_with_paths(like)
    missing = set(flat_like) - set(data.files)
    if missing:
        raise KeyError(f"checkpoint {path} missing keys: {sorted(missing)[:5]}"
                       f" (+{max(len(missing) - 5, 0)} more)")
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    keys = ["/".join(_path_str(p) for p in path_)
            for path_, _ in jax.tree_util.tree_flatten_with_path(like)[0]]
    arrays = [data[k] for k in keys]
    tree = jax.tree_util.tree_unflatten(treedef, arrays)
    if shardings is not None:
        tree = jax.tree.map(jax.device_put, tree, shardings)
    else:
        tree = jax.tree.map(jax.numpy.asarray, tree)
    return tree, step
