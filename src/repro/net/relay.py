"""Multi-hop relay routing as a broadcast :class:`~repro.comm.Channel`
(DESIGN.md §15).

When the parameter server is out of radio range, every uplink slot is
forwarded by one of ``relays`` relay nodes (slot i routes through relay
``i % relays``). Relaying is not free — every forwarded copy is priced
into the CommLedger through the channel's ``price`` hook — and it is not
trustworthy: a Byzantine relay can corrupt the payload it forwards.
Three routing disciplines trade bits for fault tolerance:

    direct   one path per message. Cheapest, zero tolerance: any
             Byzantine relay on the route corrupts the delivered value
             (the protocol's ``deliver`` hook flips the sign of the
             reconstructed gradient server-side — the overhearing
             workers, in radio range of each other, still hear the
             uncorrupted broadcast).
    dolev    Dolev-style redundant routing over ``2 b + 1``
             node-disjoint relay paths (b = ``byz_relays``): the
             receiver majority-votes, so delivery is protected whenever
             ``relays >= 2 * byz_relays + 1``.
    bracha   Bracha SEND/ECHO/READY authenticated echo over the relay
             set (``repro.net.bracha``): protected whenever
             ``relays >= 3 * byz_relays + 1`` (quorum intersection),
             at the cost of the ECHO + READY floods.

The channel registers as ``"relay"`` in the CHANNELS registry; jobs
normally reach it through the ``scenario.net.{relays, byz_relays,
broadcast}`` axes (``repro.net.apply_to_comm``), which validate the
combination and swap it in for the ideal channel.
"""
from __future__ import annotations

import dataclasses
from typing import ClassVar

import jax.numpy as jnp

from repro.comm.channel import Channel
from repro.run.registry import CHANNELS

BROADCASTS = ("direct", "dolev", "bracha")


@dataclasses.dataclass(frozen=True)
class RelayChannel(Channel):
    """Routed relay delivery with a configurable routing discipline."""

    name: ClassVar[str] = "relay"
    relays: int = 2
    byz_relays: int = 0
    broadcast: str = "direct"

    def __post_init__(self):
        if self.relays < 1:
            raise ValueError(f"RelayChannel needs relays >= 1, "
                             f"got {self.relays}")
        if not 0 <= self.byz_relays <= self.relays:
            raise ValueError(
                f"byz_relays must be in [0, relays={self.relays}], "
                f"got {self.byz_relays}")
        if self.broadcast not in BROADCASTS:
            raise ValueError(f"unknown relay broadcast "
                             f"{self.broadcast!r}; known: {BROADCASTS}")

    # --- routing analysis --------------------------------------------

    @property
    def protected(self) -> bool:
        """Whether delivery survives ``byz_relays`` Byzantine relays."""
        if self.byz_relays == 0:
            return True
        if self.broadcast == "dolev":
            return self.relays >= 2 * self.byz_relays + 1
        if self.broadcast == "bracha":
            return self.relays >= 3 * self.byz_relays + 1
        return False                     # direct: any bad relay corrupts

    def price_factor(self) -> int:
        """Copies of each message on the air: the source uplink plus the
        relay hops the discipline requires."""
        if self.broadcast == "dolev":
            return 1 + (2 * self.byz_relays + 1)
        if self.broadcast == "bracha":
            return 1 + 2 * self.relays   # SEND relayed + ECHO/READY floods
        return 2                         # direct: uplink + one relay hop

    # --- jittable slot-loop hooks ------------------------------------

    def price(self, bits):
        return bits * jnp.float32(self.price_factor())

    def deliver(self, state, slot, vec):
        """What the server decodes from slot ``slot``.

        An unprotected route through a Byzantine relay (slot mod relays
        picks the route) delivers a sign-flipped payload — the worst
        value-preserving corruption, since it exactly reverses the
        gradient's contribution while keeping its norm under the CGC
        clip threshold. Protected disciplines deliver verbatim.
        """
        if self.protected:
            return vec
        corrupted = (slot % self.relays) < self.byz_relays
        return jnp.where(corrupted, -vec, vec)


@CHANNELS.register("relay")
def _build_relay(spec=None) -> RelayChannel:
    if spec is None:
        return RelayChannel()
    return RelayChannel(
        seed=getattr(spec, "seed", 0),
        relays=int(getattr(spec, "relays", 2)),
        byz_relays=int(getattr(spec, "byz_relays", 0)),
        broadcast=getattr(spec, "broadcast", "direct"))
