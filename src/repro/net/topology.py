"""Per-worker directed *hearing graphs* (DESIGN.md §15).

The paper's single-hop radio assumption is that every worker overhears
every other worker's slot. A :class:`HearingGraph` makes that assumption
a swappable axis: ``adj[j][i]`` says whether worker j's radio hears
worker i's broadcast. The protocol slot loop uses it to keep *per-worker*
reference masks — worker j may only echo against raws it actually
overheard, and the server (which hears every uplink slot regardless)
provably detects echoes referencing gradients outside the sender's
hearing set.

Graphs are frozen, hashable (tuple-of-tuples adjacency) so they ride as
jit static args next to ``ProtocolConfig``; :meth:`HearingGraph.matrix`
materialises the (n, n) bool array at trace time.

``TOPOLOGIES`` is the shared plugin registry (``repro.run.registry``):
a builder takes ``(spec, n)`` where ``spec`` is the job's
``scenario.net`` section (:class:`repro.run.config.NetSpec`) and n the
worker count.

    complete            the paper's all-hear set (the bitwise-identical
                        default — the slot loop keeps its shared-mask
                        fast path)
    ring                workers on a cycle hear ``degree // 2``
                        neighbours on each side
    random_geometric    seeded uniform placement on the unit square;
                        j hears i iff their distance is under the radius
                        that targets an average degree of ``degree``
    explicit            adjacency rows from the spec string, e.g.
                        "011;101;110" (row j, column i, no self-loops)
"""
from __future__ import annotations

import dataclasses
import math
from typing import Tuple

from repro.run.registry import TOPOLOGIES


@dataclasses.dataclass(frozen=True)
class HearingGraph:
    """Directed overhearing relation: ``adj[j][i]`` = j hears i's slot.

    ``strict=True`` forces the protocol onto the per-worker-mask path
    even when the adjacency is complete (tests use it to check the
    masked path against the shared-mask fast path).
    """

    adj: Tuple[Tuple[bool, ...], ...]
    strict: bool = False

    def __post_init__(self):
        n = len(self.adj)
        if any(len(row) != n for row in self.adj):
            raise ValueError(f"hearing graph adjacency must be square, "
                             f"got rows of lengths "
                             f"{[len(r) for r in self.adj]}")
        if any(self.adj[j][j] for j in range(n)):
            raise ValueError("hearing graph must not contain self-loops "
                             "(a worker never re-hears its own slot)")

    @property
    def n(self) -> int:
        return len(self.adj)

    @property
    def is_complete(self) -> bool:
        """All-hear set: every off-diagonal edge present (the paper's
        assumption — the slot loop takes the shared-mask fast path)."""
        if self.strict:
            return False
        n = self.n
        return all(self.adj[j][i] for j in range(n) for i in range(n)
                   if i != j)

    def edge_count(self) -> int:
        return sum(sum(row) for row in self.adj)

    def matrix(self):
        """(n, n) bool jnp array — trace-time materialisation."""
        import jax.numpy as jnp
        return jnp.asarray(self.adj, dtype=bool)


def complete_graph(n: int) -> HearingGraph:
    adj = tuple(tuple(i != j for i in range(n)) for j in range(n))
    return HearingGraph(adj=adj)


def ring_graph(n: int, degree: int = 2) -> HearingGraph:
    """Cycle topology: j hears the ``degree // 2`` nearest workers on
    each side (degree=2 is the classic bidirectional ring)."""
    if degree < 2 or degree % 2:
        raise ValueError(f"ring degree must be a positive even number "
                         f"(neighbours split across both sides), "
                         f"got {degree}")
    half = min(degree // 2, n - 1)

    def hears(j: int, i: int) -> bool:
        if i == j:
            return False
        dist = min((j - i) % n, (i - j) % n)
        return dist <= half

    adj = tuple(tuple(hears(j, i) for i in range(n)) for j in range(n))
    return HearingGraph(adj=adj)


def random_geometric_graph(n: int, degree: int = 2,
                           seed: int = 0) -> HearingGraph:
    """Seeded uniform placement on the unit square; j hears i iff
    ``dist(j, i) <= radius`` with the radius picked so the *expected*
    degree is roughly ``degree`` (area pi r^2 ~ degree / n)."""
    import numpy as np
    if n < 2:
        raise ValueError(f"random_geometric needs n >= 2, got {n}")
    rng = np.random.default_rng(seed)
    pos = rng.uniform(0.0, 1.0, size=(n, 2))
    radius = math.sqrt(max(degree, 1) / (n * math.pi))
    d2 = ((pos[:, None, :] - pos[None, :, :]) ** 2).sum(-1)
    hears = d2 <= radius * radius
    np.fill_diagonal(hears, False)
    adj = tuple(tuple(bool(v) for v in row) for row in hears)
    return HearingGraph(adj=adj)


def explicit_graph(adjacency: str, n: int) -> HearingGraph:
    """Parse "011;101;110"-style rows (row j, column i; '1' = j hears
    i). The matrix must be n x n and self-loop free."""
    rows = [r.strip() for r in adjacency.split(";") if r.strip()]
    if len(rows) != n or any(len(r) != n for r in rows):
        raise ValueError(
            f"scenario.net.adjacency must give {n} rows of {n} binary "
            f"digits separated by ';', got {adjacency!r}")
    if any(c not in "01" for r in rows for c in r):
        raise ValueError(f"scenario.net.adjacency rows must be binary "
                         f"strings, got {adjacency!r}")
    adj = tuple(tuple(c == "1" for c in row) for row in rows)
    return HearingGraph(adj=adj)


@TOPOLOGIES.register("complete")
def _build_complete(spec, n: int) -> HearingGraph:
    return complete_graph(n)


@TOPOLOGIES.register("ring")
def _build_ring(spec, n: int) -> HearingGraph:
    return ring_graph(n, degree=getattr(spec, "degree", 2))


@TOPOLOGIES.register("random_geometric")
def _build_random_geometric(spec, n: int) -> HearingGraph:
    return random_geometric_graph(n, degree=getattr(spec, "degree", 2),
                                  seed=getattr(spec, "seed", 0))


@TOPOLOGIES.register("explicit")
def _build_explicit(spec, n: int) -> HearingGraph:
    adjacency = getattr(spec, "adjacency", "")
    if not adjacency:
        raise ValueError("topology 'explicit' needs scenario.net.adjacency "
                         "(e.g. \"011;101;110\")")
    return explicit_graph(adjacency, n)
