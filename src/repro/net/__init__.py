"""repro.net — network topology, relay routing and reliable broadcast
(DESIGN.md §15).

    topology.py  per-worker directed hearing graphs (complete / ring /
                 random_geometric / explicit) behind the TOPOLOGIES
                 registry; the protocol slot loop consumes them as
                 per-worker reference masks
    relay.py     RelayChannel — multi-hop routed delivery priced into
                 the CommLedger, with direct / Dolev / Bracha routing
                 disciplines and Byzantine-relay corruption semantics
    bracha.py    the SEND/ECHO/READY quorum machinery (host-side
                 simulation + the plain-relay wrong-accept comparator)
    attacks.py   channel-aware adversaries (echo_jam / colluding_fade /
                 little_is_enough) in the shared ATTACKS registry

``resolve_net`` turns a job's ``scenario.net`` section into a
:class:`HearingGraph` for n workers; ``apply_to_comm`` validates the
relay axes against the resolved ``CommConfig`` and swaps the relay
channel in. Both are what ``run.facade.train`` calls.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.run.registry import TOPOLOGIES

from .bracha import (BroadcastOutcome, echo_quorum, ready_quorum,
                     simulate_bracha, simulate_plain_relay)
from .relay import BROADCASTS, RelayChannel
from .topology import (HearingGraph, complete_graph, explicit_graph,
                       random_geometric_graph, ring_graph)
from . import attacks as _attacks               # noqa: F401  (registry)


def resolve_net(spec, n: int) -> HearingGraph:
    """Build the hearing graph a ``scenario.net`` section describes for
    ``n`` workers, via the TOPOLOGIES registry."""
    name = getattr(spec, "topology", "complete") or "complete"
    try:
        builder = TOPOLOGIES[name]
    except KeyError as e:              # did-you-mean text, CLI-friendly
        raise ValueError(e.args[0]) from None
    return builder(spec, n)


def net_active(spec) -> bool:
    """Whether a ``scenario.net`` section asks for anything beyond the
    paper's single-hop complete-graph default."""
    return (getattr(spec, "topology", "complete") != "complete"
            or getattr(spec, "relays", 0) > 0
            or getattr(spec, "byz_relays", 0) > 0
            or getattr(spec, "broadcast", "direct") != "direct")


def apply_to_comm(spec, comm_cfg):
    """Swap the relay channel into a resolved ``CommConfig`` when the
    ``scenario.net`` relay axes ask for one; validate the combination.

    Rejected rather than silently ignored (the ``repro.comm.resolve``
    contract): Byzantine relays or a non-direct broadcast without a
    relay tier, and a relay tier on top of a non-ideal channel (the
    relay fabric replaces the broadcast medium, it does not compose
    with per-slot fading or metering).
    """
    relays = int(getattr(spec, "relays", 0))
    byz_relays = int(getattr(spec, "byz_relays", 0))
    broadcast = getattr(spec, "broadcast", "direct")
    if broadcast not in BROADCASTS:
        raise ValueError(f"scenario.net.broadcast must be one of "
                         f"{BROADCASTS}, got {broadcast!r}")
    if relays == 0:
        if byz_relays:
            raise ValueError(
                f"scenario.net.byz_relays={byz_relays} needs a relay "
                f"tier — set scenario.net.relays > 0")
        if broadcast != "direct":
            raise ValueError(
                f"scenario.net.broadcast={broadcast!r} needs a relay "
                f"tier — set scenario.net.relays > 0")
        return comm_cfg
    if comm_cfg.channel.name != "ideal":
        raise ValueError(
            f"scenario.net.relays={relays} replaces the broadcast "
            f"channel, which is already {comm_cfg.channel.name!r} — "
            f"set scenario.comm.channel=ideal to route through relays")
    channel = RelayChannel(
        seed=getattr(spec, "seed", 0), relays=relays,
        byz_relays=byz_relays, broadcast=broadcast)
    return dataclasses.replace(comm_cfg, channel=channel)


__all__ = [
    "BROADCASTS", "BroadcastOutcome", "HearingGraph", "RelayChannel",
    "apply_to_comm", "complete_graph", "echo_quorum", "explicit_graph",
    "net_active", "random_geometric_graph", "ready_quorum", "resolve_net",
    "ring_graph", "simulate_bracha", "simulate_plain_relay",
]
