"""Bracha-style reliable broadcast over a Byzantine relay set
(DESIGN.md §15).

The classic SEND/ECHO/READY protocol (Bracha 1987), instantiated on the
relay tier of :class:`repro.net.relay.RelayChannel`: the source SENDs
its value to every relay; each correct relay ECHOes the first SEND it
sees; on an ECHO quorum (> (R + b) / 2 of the R relays, b Byzantine) a
relay sends READY; b + 1 READYs *amplify* (a correct relay sends READY
even without the echo quorum — at least one READY came from a correct
relay); 2 b + 1 READYs accept. With R >= 3 b + 1 any two ECHO quorums
intersect in a correct relay, so colluding Byzantine relays can neither
split correct relays between two values nor push a forged value to
acceptance.

``simulate_bracha`` runs the whole exchange deterministically
(host-side, no jax) and returns a :class:`BroadcastOutcome`; it is both
the unit-testable core of the quorum math and what the train facade
emits as the run's ``net.broadcast`` event. ``simulate_plain_relay`` is
the straw-man comparator: a receiver behind a single forwarding relay
accepts whatever its relay forwards — one Byzantine relay is a wrong
accept, the failure mode the Bracha tier exists to close.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional


@dataclasses.dataclass(frozen=True)
class BroadcastOutcome:
    """What the receiver concluded, and what it cost.

    ``accepted`` is the value the receiver delivered (None: no accept);
    ``safe`` says no *wrong* value was delivered — a no-accept is safe
    (below R = 3b + 1 Bracha loses liveness, never safety); ``messages``
    counts every relay-tier message sent (the bit-pricing basis for the
    relay channel's bracha mode).
    """

    accepted: Optional[Any]
    safe: bool
    messages: int
    echoes: Dict[Any, int]
    readies: Dict[Any, int]
    quorum_echo: int
    quorum_ready: int

    def as_event(self) -> Dict[str, Any]:
        """JSON-friendly digest for the ``net.broadcast`` obs event."""
        return {
            "accepted": self.accepted, "safe": self.safe,
            "messages": self.messages,
            "echoes": {str(k): v for k, v in self.echoes.items()},
            "readies": {str(k): v for k, v in self.readies.items()},
            "quorum_echo": self.quorum_echo,
            "quorum_ready": self.quorum_ready,
        }


def echo_quorum(n_relays: int, byz_relays: int) -> int:
    """Smallest ECHO count a relay needs before READY: > (R + b) / 2."""
    return (n_relays + byz_relays) // 2 + 1


def ready_quorum(byz_relays: int) -> int:
    """READY count that accepts: 2 b + 1 (b + 1 amplifies)."""
    return 2 * byz_relays + 1


def simulate_bracha(n_relays: int, byz_relays: int, value: Any = 1,
                    forged: Any = -1) -> BroadcastOutcome:
    """One Bracha broadcast of ``value`` while ``byz_relays`` colluding
    relays push ``forged`` at every step (the strongest equivocation the
    model allows: they ECHO and READY the forged value unconditionally).

    Deterministic and synchronous: correct relays all hear the SEND, so
    the interesting question is purely the quorum arithmetic — does the
    forged value reach acceptance, and does the true one?
    """
    if n_relays < 1:
        raise ValueError(f"need n_relays >= 1, got {n_relays}")
    if not 0 <= byz_relays <= n_relays:
        raise ValueError(f"byz_relays must be in [0, {n_relays}], "
                         f"got {byz_relays}")
    correct = n_relays - byz_relays
    q_echo = echo_quorum(n_relays, byz_relays)
    q_ready = ready_quorum(byz_relays)
    amplify = byz_relays + 1

    messages = n_relays                       # SEND to every relay
    # ECHO round: correct relays echo the SEND value, Byzantine relays
    # echo the forged one.
    echoes = {value: correct, forged: byz_relays} if byz_relays \
        else {value: correct}
    messages += n_relays * n_relays           # each relay echoes to all

    # READY round: a correct relay READYs a value on an echo quorum;
    # amplification then spreads READY through the correct set once any
    # b+1 READYs exist (at least one from a correct relay).
    readies: Dict[Any, int] = {}
    for v, n_echo in echoes.items():
        r = byz_relays if v == forged and byz_relays else 0
        if n_echo >= q_echo:
            r += correct
        elif r >= amplify and v == forged:
            # amplification needs b+1 READYs, but all b forged READYs
            # come from Byzantine relays — never enough on their own
            pass
        readies[v] = r
    messages += n_relays * n_relays           # READY flood

    accepted = None
    for v, n_ready in sorted(readies.items(), key=lambda kv: -kv[1]):
        if n_ready >= q_ready:
            accepted = v
            break
    return BroadcastOutcome(
        accepted=accepted, safe=accepted is None or accepted == value,
        messages=messages, echoes=echoes, readies=readies,
        quorum_echo=q_echo, quorum_ready=q_ready)


def simulate_plain_relay(n_relays: int, byz_relays: int, value: Any = 1,
                         forged: Any = -1) -> BroadcastOutcome:
    """The unprotected baseline: the receiver trusts the single relay
    its route picked (route 0 — Byzantine relays occupy the low routes,
    matching :meth:`repro.net.relay.RelayChannel.deliver`). Any
    ``byz_relays > 0`` is a wrong accept."""
    if n_relays < 1:
        raise ValueError(f"need n_relays >= 1, got {n_relays}")
    if not 0 <= byz_relays <= n_relays:
        raise ValueError(f"byz_relays must be in [0, {n_relays}], "
                         f"got {byz_relays}")
    delivered = forged if byz_relays > 0 else value
    return BroadcastOutcome(
        accepted=delivered, safe=delivered == value,
        messages=2,                            # SEND + one forward
        echoes={}, readies={}, quorum_echo=0, quorum_ready=0)
