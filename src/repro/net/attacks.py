"""Channel-aware Byzantine attacks (DESIGN.md §15).

The attack zoo in ``core.byzantine`` is value-level: the adversary
forges gradients or echo messages. These three exploit the *medium*
instead — ordinary ``ATTACKS`` plugins, so every driver and job file
reaches them through ``scenario.attack``:

    echo_jam          attackers spend their slots jamming: no honest
                      broadcast is overheard or verifiable, so the
                      reference set never forms and every would-be echo
                      pays the O(d) raw fallback — correctness survives
                      (the uplink still reaches the server), the paper's
                      savings do not.
    colluding_fade    colluding attackers replay the lossy channel's
                      seeded fade schedule and swing hard (a deep
                      mean - z*std shift) exactly in fade-heavy rounds,
                      where the thinned reference set and raw
                      retransmissions give the aggregate the least
                      redundancy — staying mild elsewhere to avoid
                      standing out.
    little_is_enough  the Baruch et al. shift, variance-calibrated AND
                      norm-capped to the smallest honest gradient norm,
                      so it provably lands below the CGC clip threshold
                      (with <= f attackers the (n-f)-th smallest norm is
                      at least the smallest honest one) — never clipped,
                      only outvoted.

``colluding_fade`` takes the channel + this round's fading key as extra
keyword arguments; ``core.protocol.run_training`` passes them only to
attacks whose signature asks (signature inspection, so every existing
attack keeps its exact call and trajectory).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.byzantine import AttackPlan, _default_plan
from repro.comm.wire import MSG_SILENT
from repro.run.registry import ATTACKS


def _honest_stats(honest: jax.Array, byz_mask: jax.Array):
    """Mean / std / min-norm over the honest rows only."""
    h = (~byz_mask).astype(honest.dtype)[:, None]
    cnt = jnp.maximum(jnp.sum(h), 1.0)
    mean = jnp.sum(honest * h, 0) / cnt
    var = jnp.sum(((honest - mean) ** 2) * h, 0) / cnt
    norms = jnp.linalg.norm(honest, axis=-1)
    min_norm = jnp.min(jnp.where(byz_mask, jnp.inf, norms))
    return mean, jnp.sqrt(var), min_norm


@ATTACKS.register("echo_jam")
def echo_jam(key, honest, byz_mask, w, true_grad) -> AttackPlan:
    """Attackers jam every honest slot and stay silent themselves.

    Jammed slots behave like faded ones (``core.protocol``): an echo
    cannot be verified so its sender retransmits raw (echo + raw bits on
    the ledger), and a raw is never overheard, so R stays empty and the
    echo mechanism is starved for the whole round. The uplink itself
    still reaches the server — the attack destroys the O(n)-vs-O(d)
    savings, not convergence.
    """
    n, d = honest.shape
    plan = _default_plan(n, d, honest)
    return dataclasses.replace(
        plan, mode=jnp.full((n,), MSG_SILENT, jnp.int32), jam=byz_mask)


@ATTACKS.register("colluding_fade")
def colluding_fade(key, honest, byz_mask, w, true_grad, z: float = 4.0,
                   channel=None, chan_key=None) -> AttackPlan:
    """Coordinated shift timed against the lossy fade schedule.

    The fade draws are a deterministic function of (channel seed, round,
    slot) — public knowledge in the model — so colluders evaluate this
    round's schedule and pick their amplitude: the full ``z``-deep
    mean - z*std shift when at least one slot fades (reference set
    thinned, raws retransmitted), a mild 0.5-std shift otherwise. On a
    non-lossy channel (or a driver that cannot provide ``chan_key``)
    the attack degrades to the mild constant shift.
    """
    n, d = honest.shape
    mean, std, _ = _honest_stats(honest, byz_mask)
    drop = float(getattr(channel, "drop_prob", 0.0)) \
        if channel is not None else 0.0
    if chan_key is not None and drop > 0.0:
        fades = jax.vmap(
            lambda s: jax.random.bernoulli(
                jax.random.fold_in(chan_key, s), drop))(jnp.arange(n))
        zz = jnp.where(jnp.any(fades), z, 0.5)
    else:
        zz = jnp.asarray(0.5)
    bogus = mean - zz * std
    return _default_plan(n, d, jnp.broadcast_to(bogus, (n, d)))


@ATTACKS.register("little_is_enough")
def little_is_enough(key, honest, byz_mask, w, true_grad, z: float = 1.5
                     ) -> AttackPlan:
    """Variance-calibrated shift capped under the CGC clip threshold.

    ``mean - z * std`` (the "A Little Is Enough" direction), rescaled so
    its norm never exceeds the smallest honest gradient norm. The CGC
    threshold is the (n-f)-th smallest received norm; with at most f
    attackers that is >= the smallest honest norm >= this payload's, so
    the attack is provably never clipped — CGC's guarantee here is only
    that the n - f honest gradients outvote it in the sum.
    """
    n, d = honest.shape
    mean, std, min_norm = _honest_stats(honest, byz_mask)
    bogus = mean - z * std
    bnorm = jnp.linalg.norm(bogus)
    cap = jnp.where(jnp.isfinite(min_norm), min_norm, bnorm)
    bogus = bogus * jnp.minimum(1.0, cap / jnp.maximum(bnorm, 1e-30))
    return _default_plan(n, d, jnp.broadcast_to(bogus, (n, d)))
