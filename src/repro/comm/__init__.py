"""repro.comm — typed wire formats + radio channels + the bit ledger
(DESIGN.md §9).

    wire.py     RawGradientMsg / EchoMsg / SilentMsg message types and
                the Codec zoo (fp32 / bf16 / int8 / topk) — each codec
                knows its exact encoded bit size and is THE source of
                truth for communication accounting
    channel.py  the single-hop broadcast models: IdealBroadcast,
                LossyBroadcast (seeded per-slot fading), MeteredBroadcast
                (per-round bit budget) — jittable ChannelState threads
                through the protocol slot loop
    ledger.py   CommLedger: every transmitting layer (Trainer, echo-DP
                rounds, protocol simulation) reports rounds into one
                accounting object
    policy/     the closed-loop control plane: CommPolicy controllers
                (static / adaptive_echo / channel_aware / bandit) that
                turn ledger measurements into per-round (codec, echo_r,
                budget) decisions, plus error-feedback accumulators

``CommConfig`` bundles one channel + one codec as a frozen (hashable,
jit-static) pair; ``resolve`` builds it from a job's
``scenario.comm`` section through the CHANNELS / CODECS registries, so
``--set scenario.comm.codec=int8 --set scenario.comm.drop_prob=0.1``
is all it takes to run a quantized, lossy scenario.
"""
from __future__ import annotations

import dataclasses

from .channel import (IDEAL, Channel, ChannelState, IdealBroadcast,
                      LossyBroadcast, MeteredBroadcast)
from .ledger import CommLedger, echo_round_bits, raw_round_bits
from .policy import (CommDecision, CommPolicy, PolicyContext,
                     RoundObservation, StaticPolicy, ef_compensate, ef_init,
                     resolve_policy)
from .wire import (BITS_PER_FLOAT, FP32, MSG_ECHO, MSG_RAW, MSG_SILENT,
                   Bf16Codec, Codec, EchoMsg, Fp32Codec, Int8Codec, Message,
                   RawGradientMsg, Sign1Codec, SilentMsg, TopKCodec,
                   messages_from_round, payload_bits)


@dataclasses.dataclass(frozen=True)
class CommConfig:
    """One resolved communication setup: how messages are encoded and
    what medium carries them. Frozen + hashable, so it rides along as a
    jit static argument everywhere the protocol does."""

    channel: Channel = IDEAL
    codec: Codec = FP32


DEFAULT_COMM = CommConfig()


def resolve(spec=None) -> CommConfig:
    """Build a :class:`CommConfig` from a ``run.config.CommSpec`` (or
    None for the paper's ideal fp32 default) via the registries.

    Knobs that contradict the selected channel are rejected rather than
    silently ignored — ``drop_prob`` without ``channel=lossy`` (or
    ``budget_bits`` without ``channel=metered``) would otherwise run an
    ideal-channel experiment whose config.json claims losses.
    """
    if spec is None:
        return DEFAULT_COMM
    if spec.drop_prob and spec.channel != "lossy":
        raise ValueError(
            f"scenario.comm.drop_prob={spec.drop_prob} has no effect on "
            f"channel {spec.channel!r} — set scenario.comm.channel=lossy "
            f"(or drop_prob=0)")
    if spec.budget_bits and spec.channel != "metered":
        raise ValueError(
            f"scenario.comm.budget_bits={spec.budget_bits} has no effect "
            f"on channel {spec.channel!r} — set "
            f"scenario.comm.channel=metered (or budget_bits=0)")
    from repro.run.registry import CHANNELS, CODECS
    try:
        channel = CHANNELS[spec.channel](spec)
        codec = CODECS[spec.codec](spec)
    except KeyError as e:              # did-you-mean text, CLI-friendly
        raise ValueError(e.args[0]) from None
    return CommConfig(channel=channel, codec=codec)


__all__ = [
    "BITS_PER_FLOAT", "FP32", "IDEAL", "MSG_ECHO", "MSG_RAW", "MSG_SILENT",
    "Bf16Codec", "Channel", "ChannelState", "Codec", "CommConfig",
    "CommDecision", "CommLedger", "CommPolicy", "DEFAULT_COMM", "EchoMsg",
    "Fp32Codec", "IdealBroadcast", "Int8Codec", "LossyBroadcast", "Message",
    "MeteredBroadcast", "PolicyContext", "RawGradientMsg", "RoundObservation",
    "Sign1Codec", "SilentMsg", "StaticPolicy", "TopKCodec",
    "echo_round_bits",
    "ef_compensate", "ef_init", "messages_from_round", "payload_bits",
    "raw_round_bits", "resolve", "resolve_policy",
]
