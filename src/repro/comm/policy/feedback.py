"""Error-feedback accumulators (DESIGN.md §13).

Lossy codecs (int8, topk) bias every round by whatever the quantizer
threw away; a policy that switches codecs mid-run compounds the bias
unpredictably. The classic fix (error feedback; Jin et al.,
arXiv 1902.10336 use it to make 1-bit stochastic signs convergent) is a
per-worker residual carried across rounds: add it to the vector before
encoding, keep what the wire lost for next time:

    wire   = Q(x + e)
    e_next = (x + e) - wire

The residual is bounded whenever the quantizer is a contraction
(``‖v - Q(v)‖ ≤ (1-δ)·‖v‖`` for some δ > 0): ``‖e_next‖ ≤
(1-δ)·‖x + e‖ ≤ (1-δ)(‖x‖ + ‖e‖)``, a geometric recursion with fixed
point ``‖e‖ ≤ (1-δ)/δ · sup‖x‖``. So the per-round bias stays O(1)
instead of accumulating, and every discarded bit is eventually
transmitted — which is what keeps aggressive quantization convergent.

``ef_compensate`` is the whole mechanism and is jittable; callers
(the echo-DP all-gather in ``dist/echo_dp.py``, the protocol slot loop
in ``core/protocol.py``) own *when* to commit the new residual — only
on rounds whose transmission was actually used, so a discarded
optimistic attempt or a faded slot does not destroy state it never
sent.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp


def ef_init(n: int, dim: int, dtype=jnp.float32) -> jnp.ndarray:
    """Fresh residual state: one zero row per worker, gathered layout
    ``(n, dim)`` — the replicated shape the drivers carry round-over-round."""
    return jnp.zeros((n, dim), dtype=dtype)


def ef_compensate(codec, vec: jnp.ndarray,
                  residual: Optional[jnp.ndarray] = None,
                  ) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
    """One worker's encode step with error feedback.

    Returns ``(wire, new_residual)``: what goes on the air and the
    residual to carry *if* this transmission ends up used. ``codec=None``
    means the value rides uncoded — the wire is exact, the residual
    passes through untouched (no compensation, nothing new lost).
    ``residual=None`` runs plain coding with no feedback.
    """
    if codec is None:
        return vec, residual
    if residual is None:
        return codec.roundtrip(vec), None
    compensated = vec + residual
    wire = codec.roundtrip(compensated)
    return wire, compensated - wire


def ef_norms(residual: jnp.ndarray) -> jnp.ndarray:
    """Per-worker residual norms of a gathered ``(n, dim)`` state —
    the boundedness diagnostic the obs layer records."""
    return jnp.linalg.norm(residual, axis=-1)
