"""The adaptive policy zoo (DESIGN.md §13).

Three controllers over the :class:`~repro.comm.policy.base.CommPolicy`
contract, each closing the loop on a different ledger signal:

- ``adaptive_echo``  — Eq. 7 pass rate -> echo deviation-ratio ``r``
- ``channel_aware``  — measured fade rate -> codec ladder position,
                       with a metered budget as a hard constraint
- ``bandit``         — UCB over codec arms, reward = loss decrease
                       per bit spent

All three are deterministic functions of their observation history (no
RNG), so seeded runs replay decision-for-decision.
"""
from __future__ import annotations

import math
from collections import deque
from typing import Optional

from repro.run.registry import POLICIES

from .base import (CODEC_LADDER, CommDecision, CommPolicy, PolicyContext,
                   RoundObservation)


class AdaptiveEchoPolicy(CommPolicy):
    """Tightens/loosens Eq. 7's deviation ratio from the echo-rate curve.

    The one failure mode a looser ``r`` can fix is a *clean* echo round
    that Eq. 7 rejected (``obs.eq7_failed``) — each one costs a full raw
    fallback, O(d) per worker instead of O(n). The controller watches
    the pass rate over a short window of clean attempts and steps ``r``
    on a hysteresis band:

    - pass rate < ``lo``  -> loosen (``r += step``), buying echo rounds
      with reconstruction slack, up to ``r_max``;
    - pass rate ≥ ``hi`` (everything passing) *and* no Eq. 7 failure for
      ``calm`` rounds -> tighten back toward the configured ``r``, never
      below it.

    The asymmetric ``calm`` guard is the anti-oscillation half of the
    hysteresis: a workload with periodic hard rounds (noise shocks)
    keeps resetting the calm clock, so the controller settles at the
    loosest level those rounds need instead of ping-ponging around it.
    The window is cleared after every change so stale observations made
    under the old threshold cannot trigger a double step.
    """

    name = "adaptive_echo"

    def __init__(self, window: int = 6, min_obs: int = 4, lo: float = 0.75,
                 hi: float = 0.999, step: float = 0.02, r_max: float = 0.98,
                 cooldown: int = 2, calm: int = 18):
        super().__init__()
        self.window, self.min_obs = window, min_obs
        self.lo, self.hi, self.step = lo, hi, step
        self.r_max, self.cooldown, self.calm = r_max, cooldown, calm
        self._passes: deque = deque(maxlen=window)
        self._cool = 0
        self._since_fail = 10 ** 9
        self.echo_r = 0.9

    def setup(self, ctx: PolicyContext) -> None:
        super().setup(ctx)
        self.echo_r = ctx.echo_r

    def observe(self, obs: Optional[RoundObservation]) -> CommDecision:
        if obs is None:
            return CommDecision(echo_r=self.echo_r)
        if obs.attempted and obs.echo_drops == 0 and not obs.refused:
            self._passes.append(obs.echoed)
            self._since_fail = 0 if obs.eq7_failed else self._since_fail + 1
        else:
            # faded / refused rounds say nothing about Eq. 7
            self._since_fail += 1
        self._cool = max(self._cool - 1, 0)
        r = self.echo_r
        if len(self._passes) >= self.min_obs and self._cool == 0:
            rate = sum(self._passes) / len(self._passes)
            floor = self.ctx.echo_r if self.ctx is not None else r
            if rate < self.lo and r < self.r_max:
                r = min(round(r + self.step, 6), self.r_max)
            elif (rate >= self.hi and r > floor
                  and self._since_fail >= self.calm):
                r = max(round(r - self.step, 6), floor)
            if r != self.echo_r:
                self._cool = self.cooldown
                self._passes.clear()
        self.echo_r = r
        return CommDecision(echo_r=r)


class ChannelAwarePolicy(CommPolicy):
    """Steps the codec along fp32↔bf16↔int8↔topk from the measured
    fade rate, with the metered budget as a hard constraint.

    An EWMA of the observed per-round drop fraction estimates the
    channel: above ``hi`` the channel is eating retransmissions, so step
    to a cheaper codec (each lost echo slot forces an O(d) raw round —
    shrink d's coefficient); below ``lo`` for long enough, step back up
    for fidelity. ``cooldown`` rounds must pass between steps so one
    estimate never drives two moves.

    Budget (hard constraint, applied after the ladder move): if the
    channel meters bits, the decided codec's worst-case round — echo
    attempt plus full raw fallback — must fit, else keep stepping
    cheaper until one fits (or the cheapest is reached). A metered
    *refusal* observed on the wire forces the same walk immediately.
    """

    name = "channel_aware"

    def __init__(self, alpha: float = 0.5, hi: float = 0.04,
                 lo: float = 0.005, cooldown: int = 2):
        super().__init__()
        self.alpha, self.hi, self.lo, self.cooldown = alpha, hi, lo, cooldown
        self.drop_est = 0.0
        self._cool = 0
        self._idx = 0

    def setup(self, ctx: PolicyContext) -> None:
        super().setup(ctx)
        self._idx = (CODEC_LADDER.index(ctx.codec)
                     if ctx.codec in CODEC_LADDER else len(CODEC_LADDER) - 1)

    def _fit_budget(self, idx: int) -> int:
        ctx = self.ctx
        if ctx is None or not ctx.budget_bits:
            return idx
        while (idx < len(CODEC_LADDER) - 1
               and ctx.round_cost(CODEC_LADDER[idx]) > ctx.budget_bits):
            idx += 1
        return idx

    def observe(self, obs: Optional[RoundObservation]) -> CommDecision:
        idx = self._fit_budget(self._idx)
        if obs is not None:
            self._cool = max(self._cool - 1, 0)
            if obs.attempted and self.ctx is not None:
                rate = obs.echo_drops / max(self.ctx.n, 1)
                self.drop_est = ((1 - self.alpha) * self.drop_est
                                 + self.alpha * rate)
                if self._cool == 0:
                    if self.drop_est > self.hi and idx < len(CODEC_LADDER) - 1:
                        idx += 1
                        self._cool = self.cooldown
                    elif self.drop_est < self.lo and idx > 0:
                        idx -= 1
                        self._cool = self.cooldown
            elif obs.refused:
                # the meter would not even admit the echo attempt
                idx = min(idx + 1, len(CODEC_LADDER) - 1)
                self._cool = self.cooldown
            idx = self._fit_budget(idx)
        self._idx = idx
        return CommDecision(codec=CODEC_LADDER[idx])


class BanditPolicy(CommPolicy):
    """UCB1 over the codec arms, scored by loss decrease per bit.

    Reward for the round that just finished accrues to the arm it ran
    under: ``max(prev_loss - loss, 0) / bits``, normalized by the
    running maximum so rewards live in [0, 1] as UCB1 assumes. Arms are
    first played once each in ladder order (deterministic), then by
    ``mean + c·sqrt(ln t / pulls)`` with the ladder as tie-break —
    no RNG anywhere, so the pull sequence replays under a fixed seed.
    """

    name = "bandit"

    def __init__(self, c: float = math.sqrt(2.0)):
        super().__init__()
        self.c = c
        self.pulls = {a: 0 for a in CODEC_LADDER}
        self.mean = {a: 0.0 for a in CODEC_LADDER}
        self._scale = 0.0              # running max raw reward
        self._prev_loss: Optional[float] = None

    def _credit(self, obs: RoundObservation) -> None:
        if obs.codec not in self.pulls:
            return
        raw = 0.0
        if self._prev_loss is not None and obs.bits > 0:
            raw = max(self._prev_loss - obs.loss, 0.0) / obs.bits
        self._scale = max(self._scale, raw)
        reward = raw / self._scale if self._scale > 0 else 0.0
        n = self.pulls[obs.codec] = self.pulls[obs.codec] + 1
        self.mean[obs.codec] += (reward - self.mean[obs.codec]) / n
        self._prev_loss = obs.loss

    def observe(self, obs: Optional[RoundObservation]) -> CommDecision:
        if obs is not None:
            self._credit(obs)
        for arm in CODEC_LADDER:       # play every arm once, in order
            if self.pulls[arm] == 0:
                return CommDecision(codec=arm)
        t = sum(self.pulls.values())
        best, best_score = CODEC_LADDER[0], -1.0
        for arm in CODEC_LADDER:
            score = (self.mean[arm]
                     + self.c * math.sqrt(math.log(t) / self.pulls[arm]))
            if score > best_score:
                best, best_score = arm, score
        return CommDecision(codec=best)


@POLICIES.register("adaptive_echo")
def _build_adaptive_echo(spec=None) -> CommPolicy:
    return AdaptiveEchoPolicy()


@POLICIES.register("channel_aware")
def _build_channel_aware(spec=None) -> CommPolicy:
    return ChannelAwarePolicy()


@POLICIES.register("bandit")
def _build_bandit(spec=None) -> CommPolicy:
    return BanditPolicy()
