"""The comm control-plane contract (DESIGN.md §13).

PR 5 built the *measurement* plane — codecs price every message,
channels fade/ration them, the :class:`~repro.comm.CommLedger` records
what each round cost — and PR 8 made it observable. This module is the
*control* plane: a :class:`CommPolicy` closes the loop, turning the
measured per-round statistics into the next round's communication
decision.

The contract is deliberately host-side and tiny:

    policy.setup(PolicyContext)          once, before round 0
    policy.observe(obs) -> CommDecision  once per round; ``obs`` is the
                                         previous round's observation
                                         (None before the first round)

A :class:`CommDecision` names the codec, the echo deviation-ratio
threshold (Eq. 7's ``r``) and the per-round bit budget for the coming
round; ``None`` fields mean "keep the current value". Every policy is a
*deterministic* function of its observation history, so a seeded run's
decision trajectory replays exactly — the same property the channels
already guarantee for fading.

Policies register in ``run.registry.POLICIES`` as builders
``(CommSpec) -> CommPolicy`` and are selected by the
``scenario.comm.policy`` config axis (``resolve_policy``). ``static``
is today's behavior: it re-asserts the configured (codec, echo_r) every
round — drivers treat it as a zero-overhead fast path, so a
``static``+fp32 run stays bitwise identical to a run with no policy at
all, while still emitting its (constant) ``comm.policy.*`` decisions.

This module imports neither jax nor any repro sibling beyond the
registry, so policy resolution stays instant.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.run.registry import POLICIES

# The codec ladder the adaptive policies step along, richest first.
# Order is the control knob: stepping "down" (right) trades gradient
# fidelity for fewer bits on the wire.
CODEC_LADDER = ("fp32", "bf16", "int8", "topk", "sign1")


@dataclasses.dataclass(frozen=True)
class PolicyContext:
    """What a policy knows before the first round: the topology, the
    configured starting point, the channel's standing parameters, and
    the price list (bits for one all-raw / all-echo round per codec on
    the ladder) it trades against."""

    n: int                            # workers
    d: int                            # gradient dimension
    echo_k: int                       # echo-DP reference basis size
    codec: str = "fp32"               # configured starting codec
    echo_r: float = 0.9               # configured Eq. 7 threshold
    channel: str = "ideal"
    drop_prob: float = 0.0            # lossy channel's configured rate
    budget_bits: int = 0              # metered channel's per-round cap
    raw_round_bits: Dict[str, int] = dataclasses.field(default_factory=dict)
    echo_round_bits: Dict[str, int] = dataclasses.field(default_factory=dict)

    def round_cost(self, codec: str) -> int:
        """Worst-case bits of one round under ``codec``: an echo attempt
        plus the full raw fallback (what a metered budget must fit)."""
        return (self.raw_round_bits.get(codec, 0)
                + self.echo_round_bits.get(codec, 0))


@dataclasses.dataclass(frozen=True)
class RoundObservation:
    """One finished round, as the driver saw it (host-side floats)."""

    round: int                        # driver step index
    bits: int                         # bits this round actually cost
    baseline_bits: int                # all-raw round, same codec
    fp32_baseline_bits: int           # all-raw round, fp32 (paper units)
    loss: float
    codec: str                        # codec the round ran under
    echo_r: float                     # Eq. 7 threshold the round used
    attempted: bool = False           # optimistic echo round attempted
    echoed: bool = False              # ... and valid (aggregate used)
    echo_drops: int = 0               # faded echo slots (channel)
    refused: bool = False             # metered channel refused the attempt

    @property
    def eq7_failed(self) -> bool:
        """The echo attempt was clean (no fades) but Eq. 7 rejected it —
        the only failure mode a looser threshold can convert."""
        return self.attempted and self.echo_drops == 0 and not self.echoed


@dataclasses.dataclass(frozen=True)
class CommDecision:
    """The next round's communication setup; None = keep current."""

    codec: Optional[str] = None
    echo_r: Optional[float] = None
    budget_bits: Optional[int] = None


class CommPolicy:
    """Base policy: see the module docstring for the contract."""

    name = "policy"
    # Static policies never change anything: drivers keep the exact
    # pre-policy code path (bitwise trajectories) and only emit events.
    static = False

    def __init__(self) -> None:
        self.ctx: Optional[PolicyContext] = None

    def setup(self, ctx: PolicyContext) -> None:
        self.ctx = ctx

    def observe(self, obs: Optional[RoundObservation]) -> CommDecision:
        raise NotImplementedError


class StaticPolicy(CommPolicy):
    """Today's behavior: the configured (codec, echo_r) every round."""

    name = "static"
    static = True

    def observe(self, obs: Optional[RoundObservation]) -> CommDecision:
        ctx = self.ctx
        if ctx is None:
            return CommDecision()
        return CommDecision(codec=ctx.codec, echo_r=ctx.echo_r)


@POLICIES.register("static")
def _build_static(spec=None) -> CommPolicy:
    return StaticPolicy()


def resolve_policy(spec=None) -> CommPolicy:
    """Build the policy a ``run.config.CommSpec`` names (None / absent
    field -> ``static``) through the POLICIES registry."""
    name = getattr(spec, "policy", "static") if spec is not None \
        else "static"
    try:
        return POLICIES[name](spec)
    except KeyError as e:              # did-you-mean text, CLI-friendly
        raise ValueError(e.args[0]) from None
