"""repro.comm.policy — the closed-loop communication control plane.

See ``base.py`` for the contract, ``adaptive.py`` for the controllers,
``feedback.py`` for the error-feedback accumulators that keep lossy
codec switching convergent. Importing this package populates the
POLICIES registry (it is one of ``run.registry._HOSTS``).
"""
from .adaptive import AdaptiveEchoPolicy, BanditPolicy, ChannelAwarePolicy
from .base import (CODEC_LADDER, CommDecision, CommPolicy, PolicyContext,
                   RoundObservation, StaticPolicy, resolve_policy)
from .feedback import ef_compensate, ef_init, ef_norms

__all__ = [
    "AdaptiveEchoPolicy", "BanditPolicy", "ChannelAwarePolicy",
    "CODEC_LADDER", "CommDecision", "CommPolicy", "PolicyContext",
    "RoundObservation", "StaticPolicy", "resolve_policy",
    "ef_compensate", "ef_init", "ef_norms",
]
