"""Single-hop radio broadcast channels (DESIGN.md §9).

The paper assumes a *reliable* single-hop broadcast: every slot is heard
by the server and overheard by every worker. A :class:`Channel` makes
that assumption explicit and swappable — the protocol slot loop threads
a :class:`ChannelState` carry through ``lax.fori_loop`` instead of an
ad-hoc bits array, so all channels are jittable and hashable (frozen
dataclasses, safe as jit static args):

    IdealBroadcast    today's semantics: nothing fades, nothing is
                      rationed — bit accounting only.
    LossyBroadcast    per-slot fading with a seeded PRNG. A faded slot
                      is not *overheard*: a faded raw broadcast never
                      enters the shared reference set R, and a faded
                      echo forces the sender's raw retransmission (the
                      paper's reliability assumption — the server must
                      get *something*, and an echo whose broadcast faded
                      cannot be re-verified, so the fallback is raw).
    MeteredBroadcast  a per-round bit budget. A transmission that would
                      exceed the remaining budget is not admitted: the
                      worker stays silent and the server times it out.

Two host-side hooks serve the coarse-grained echo-DP driver
(``launch.engine.Trainer``), which models the round as one all-or-
nothing echo attempt rather than n slots: ``round_echo_drops`` draws the
round's faded-echo count from the same seeded PRNG, and ``allows_bits``
gates the optimistic attempt against the metered budget. Driver-level
metering is deliberately softer than the slot loop's: it refuses the
echo *attempt*, but the raw fallback always transmits (and is charged on
the ledger even over budget) — a silenced training round would stall
optimization, whereas the protocol simulation can faithfully time a
worker out for one round.
"""
from __future__ import annotations

import dataclasses
from typing import ClassVar, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.run.registry import CHANNELS


class ChannelState(NamedTuple):
    """Carry threaded through the protocol slot loop."""

    key: jax.Array           # PRNG state for fading draws
    bits_used: jax.Array     # () float32 — bits admitted so far this round


@dataclasses.dataclass(frozen=True)
class Channel:
    """Base channel: reliable, unmetered."""

    name: ClassVar[str] = "channel"
    seed: int = 0

    # --- jittable slot-loop surface ----------------------------------

    def init(self, key: Optional[jax.Array] = None) -> ChannelState:
        """Fresh per-round state; ``key`` seeds the fading PRNG (falls
        back to this channel's configured seed)."""
        if key is None:
            key = jax.random.PRNGKey(self.seed)
        return ChannelState(key=key, bits_used=jnp.zeros((), jnp.float32))

    def fade(self, state: ChannelState, slot) -> Tuple[ChannelState,
                                                       jax.Array]:
        """Did slot ``slot``'s broadcast fade? () bool."""
        return state, jnp.asarray(False)

    def admit(self, state: ChannelState, bits) -> Tuple[ChannelState,
                                                        jax.Array]:
        """Charge ``bits`` against the round; () bool = admitted."""
        return state._replace(bits_used=state.bits_used + bits), \
            jnp.asarray(True)

    def price(self, bits):
        """Bits a transmission of ``bits`` payload bits actually costs
        on this medium (relay channels multiply by the copy count;
        single-hop channels return the payload unchanged)."""
        return bits

    def deliver(self, state: ChannelState, slot, vec: jax.Array
                ) -> jax.Array:
        """What the *server* decodes from slot ``slot``'s reconstructed
        vector — the hook a routed channel uses to model Byzantine-relay
        corruption (``repro.net.relay``). Identity on single-hop
        channels: the server is in radio range."""
        return vec

    # --- host-side hooks for the coarse echo-DP driver ---------------

    def price_factor(self) -> int:
        """Per-message copy multiplier of :meth:`price` (host-side; the
        coarse driver scales its round bits by it)."""
        return 1

    def round_echo_drops(self, round_index: int, n: int) -> int:
        """How many of the round's n echo broadcasts fade (deterministic
        in (seed, round_index) — the trainer's bits trajectory replays)."""
        return 0

    def allows_bits(self, bits: int) -> bool:
        """Whether one round of ``bits`` fits the per-round budget."""
        return True


@dataclasses.dataclass(frozen=True)
class IdealBroadcast(Channel):
    """The paper's reliable broadcast — today's semantics exactly."""

    name: ClassVar[str] = "ideal"


@dataclasses.dataclass(frozen=True)
class LossyBroadcast(Channel):
    """Seeded per-slot fading with probability ``drop_prob``."""

    name: ClassVar[str] = "lossy"
    drop_prob: float = 0.1

    def fade(self, state, slot):
        dropped = jax.random.bernoulli(jax.random.fold_in(state.key, slot),
                                       self.drop_prob)
        return state, dropped

    def round_echo_drops(self, round_index: int, n: int) -> int:
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), round_index)
        return int(jax.random.bernoulli(key, self.drop_prob, (n,)).sum())


@dataclasses.dataclass(frozen=True)
class MeteredBroadcast(Channel):
    """Hard per-round bit budget; over-budget slots go silent."""

    name: ClassVar[str] = "metered"
    budget_bits: int = 0              # 0 = unlimited

    def admit(self, state, bits):
        bits = jnp.asarray(bits, jnp.float32)
        if self.budget_bits <= 0:
            return state._replace(bits_used=state.bits_used + bits), \
                jnp.asarray(True)
        ok = state.bits_used + bits <= float(self.budget_bits)
        used = state.bits_used + jnp.where(ok, bits, 0.0)
        return state._replace(bits_used=used), ok

    def allows_bits(self, bits: int) -> bool:
        return self.budget_bits <= 0 or bits <= self.budget_bits


# Registry entries are builders ``(spec) -> Channel`` reading the knobs
# (drop_prob / seed / budget_bits) off the job's CommSpec.


@CHANNELS.register("ideal")
def _build_ideal(spec=None) -> Channel:
    return IdealBroadcast(seed=getattr(spec, "seed", 0) if spec else 0)


@CHANNELS.register("lossy")
def _build_lossy(spec=None) -> Channel:
    if spec is None:
        return LossyBroadcast()
    drop = float(spec.drop_prob)
    if not 0.0 <= drop < 1.0:
        raise ValueError(f"scenario.comm.drop_prob must be in [0, 1), "
                         f"got {drop}")
    return LossyBroadcast(seed=spec.seed, drop_prob=drop)


@CHANNELS.register("metered")
def _build_metered(spec=None) -> Channel:
    budget = getattr(spec, "budget_bits", 0) if spec else 0
    return MeteredBroadcast(seed=getattr(spec, "seed", 0) if spec else 0,
                            budget_bits=int(budget))


IDEAL = IdealBroadcast()
