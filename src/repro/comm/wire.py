"""Typed wire formats for the radio broadcast (DESIGN.md §9).

What a worker puts on the air is one of three messages:

    RawGradientMsg   the d-dimensional gradient itself
    EchoMsg          (norm ratio, coefficient vector, reference bitmap)
    SilentMsg        nothing (crashed / timed-out worker)

and a :class:`Codec` decides how the float payload of a message is
encoded on the wire — and therefore *exactly how many bits it costs*.
Codecs are the single source of truth for communication accounting:
``core.types.raw_bits``/``echo_bits`` are now thin delegates to the
ideal :class:`Fp32Codec`, and the protocol slot loop, the echo-DP
trainer and the :class:`repro.comm.CommLedger` all price messages
through the selected codec.

Every codec is a frozen (hashable, jit-static) dataclass exposing

    encode(vec)            -> payload (tuple of arrays)
    decode(payload, m)     -> (m,) float32 vector
    roundtrip(vec)         -> decode(encode(vec)) — jittable; what the
                              receivers actually see
    vector_bits(m)         -> exact encoded size of an m-vector (works
                              on python ints AND traced ranks)
    raw_msg_bits(d) / echo_msg_bits(n, rank)

``payload_bits`` counts the real bits of an encoded payload so tests
can assert the advertised ``vector_bits`` is honest. The lossy codecs
(bf16 / int8 / top-k) open the quantized-gradient scenario axis; the
fp32 codec reproduces the paper's closed-form accounting bit for bit.

This module imports only jax at module load — never ``repro.core`` — so
``core.types`` can delegate here without a cycle. The lossy codecs'
pack/unpack math dispatches lazily through ``repro.kernels.ops``
(``REPRO_CODEC_BACKEND``): streaming Pallas kernels
(``kernels/codec_pack.py``) on TPU, the same inline jnp math elsewhere —
payload shapes, dtypes and bit accounting are identical either way.
Codec builders register in ``run.registry.CODECS``; ``resolve`` in
``repro.comm`` turns a ``CommSpec`` into instances.
"""
from __future__ import annotations

import dataclasses
from typing import Any, ClassVar, List, Tuple, Union

import jax
import jax.numpy as jnp

from repro.run.registry import CODECS

# Message kinds broadcast in a TDMA slot (source of truth; core.types
# re-exports these for the protocol buffers).
MSG_RAW = 0        # raw d-dimensional gradient
MSG_ECHO = 1       # echo message (k, x, I)
MSG_SILENT = 2     # crashed / absent worker (server times out -> Byzantine)

# Float width of the paper's bit accounting (floats/doubles per dim).
BITS_PER_FLOAT = 32

Payload = Tuple[jax.Array, ...]
Bits = Union[int, jax.Array]

_DTYPE_BITS = {"float32": 32, "bfloat16": 16, "float16": 16, "int8": 8,
               "int32": 32, "uint8": 8, "bool": 1}


def payload_bits(payload: Payload) -> int:
    """Actual bits of an encoded payload (host-side; tests assert this
    equals the codec's advertised ``vector_bits``)."""
    return sum(int(a.size) * _DTYPE_BITS[str(a.dtype)] for a in payload)


# ---------------------------------------------------------------------------
# Codecs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Codec:
    """Base wire encoding. Subclasses override encode/decode/vector_bits;
    message pricing (`raw_msg_bits`/`echo_msg_bits`) is shared."""

    name: ClassVar[str] = "codec"
    lossless: ClassVar[bool] = False

    def encode(self, vec: jax.Array) -> Payload:
        raise NotImplementedError

    def decode(self, payload: Payload, m: int) -> jax.Array:
        raise NotImplementedError

    def roundtrip(self, vec: jax.Array) -> jax.Array:
        """What the receivers decode; jittable, shape-preserving."""
        return self.decode(self.encode(vec), vec.shape[-1])

    def vector_bits(self, m: Bits) -> Bits:
        raise NotImplementedError

    def raw_msg_bits(self, d: Bits) -> Bits:
        """Bits to broadcast a raw d-dimensional gradient (Sec. 2.1)."""
        return self.vector_bits(d)

    def echo_msg_bits(self, n: Bits, rank: Bits) -> Bits:
        """Bits for an echo message ``(k, x, I)``: the (1 + |R|) floats
        on the wire plus an n-bit membership bitmap for the sorted ID
        list I (an upper bound on any practical encoding; O(n) total as
        in the paper)."""
        return self.vector_bits(1 + rank) + n


@dataclasses.dataclass(frozen=True)
class Fp32Codec(Codec):
    """The paper's ideal encoding: 32-bit floats, lossless. Reproduces
    the closed-form ``raw_bits``/``echo_bits`` bit for bit."""

    name: ClassVar[str] = "fp32"
    lossless: ClassVar[bool] = True

    def encode(self, vec):
        return (vec.astype(jnp.float32),)

    def decode(self, payload, m):
        return payload[0]

    def vector_bits(self, m):
        return BITS_PER_FLOAT * m


@dataclasses.dataclass(frozen=True)
class Bf16Codec(Codec):
    """bfloat16 truncation: half the bits, ~2^-8 relative error."""

    name: ClassVar[str] = "bf16"

    def encode(self, vec):
        return (vec.astype(jnp.bfloat16),)

    def decode(self, payload, m):
        return payload[0].astype(jnp.float32)

    def vector_bits(self, m):
        return 16 * m


@dataclasses.dataclass(frozen=True)
class Int8Codec(Codec):
    """Absmax int8 quantization (SIGNSGD-style compressed gradients):
    one fp32 scale + one signed byte per element."""

    name: ClassVar[str] = "int8"

    def encode(self, vec):
        from repro.kernels import ops
        q, scale = ops.int8_pack(vec)
        return (q, scale)

    def decode(self, payload, m):
        from repro.kernels import ops
        q, scale = payload
        return ops.int8_unpack(q, scale, m)

    def vector_bits(self, m):
        return 8 * m + BITS_PER_FLOAT          # bytes + the shared scale


@dataclasses.dataclass(frozen=True)
class TopKCodec(Codec):
    """Top-k sparsification: the k largest-magnitude entries survive,
    each shipped as (fp32 value, int32 index); the rest decode to 0."""

    name: ClassVar[str] = "topk"
    k: int = 32

    def __post_init__(self):
        # k > d clamps to dense-at-fp32-cost downstream (encode and
        # vector_bits both min() against the vector length), but a
        # non-positive k would only surface as an opaque empty-shape
        # failure deep in the pack kernel — reject it here.
        if not isinstance(self.k, int) or isinstance(self.k, bool) \
                or self.k < 1:
            raise ValueError(
                f"TopKCodec needs a positive integer k (entries kept per "
                f"vector), got {self.k!r} — set scenario.comm.topk >= 1")

    def encode(self, vec):
        from repro.kernels import ops
        return ops.topk_pack(vec, self.k)

    def decode(self, payload, m):
        from repro.kernels import ops
        vals, idx = payload
        return ops.topk_unpack(vals, idx, m)

    def vector_bits(self, m):
        kk = min(self.k, m) if isinstance(m, int) else jnp.minimum(self.k, m)
        return kk * (BITS_PER_FLOAT + 32)      # value + int32 index


@dataclasses.dataclass(frozen=True)
class Sign1Codec(Codec):
    """1-bit sign compression (Jin et al., arXiv:1902.10336): one packed
    sign bit per element plus a single fp32 scale — the mean absolute
    value, the L1-norm-preserving choice of scaled SIGNSGD. The deepest
    rung of the codec ladder: 32x fewer payload bits than fp32, with all
    magnitude information collapsed to one scalar (error feedback is the
    intended companion, exactly as for int8/topk).

    A length-1 vector roundtrips to ``sign * |v|`` — exact up to the
    sign convention — so the protocol's echo norm-ratio scalar survives
    this codec unharmed; the coefficient vector does not, which is the
    point of the scenario axis.
    """

    name: ClassVar[str] = "sign1"

    def encode(self, vec):
        vec = jnp.asarray(vec, jnp.float32)
        bits = jnp.packbits((vec >= 0).astype(jnp.uint8))
        scale = jnp.mean(jnp.abs(vec), keepdims=True)
        return (bits, scale.astype(jnp.float32))

    def decode(self, payload, m):
        bits, scale = payload
        signs = jnp.unpackbits(bits, count=m).astype(jnp.float32)
        return scale * (signs * 2.0 - 1.0)

    def vector_bits(self, m):
        # packed sign bytes + the shared fp32 scale; works on python
        # ints and traced ranks alike (// is floor_divide in both).
        return 8 * ((m + 7) // 8) + BITS_PER_FLOAT


# Registry entries are builders ``(spec) -> Codec``: ``repro.comm.resolve``
# calls CODECS[name](spec) so parametrised codecs read their knobs off the
# job's CommSpec while the plain ones ignore it.


@CODECS.register("fp32")
def _build_fp32(spec=None) -> Codec:
    return Fp32Codec()


@CODECS.register("bf16")
def _build_bf16(spec=None) -> Codec:
    return Bf16Codec()


@CODECS.register("int8")
def _build_int8(spec=None) -> Codec:
    return Int8Codec()


@CODECS.register("topk")
def _build_topk(spec=None) -> Codec:
    return TopKCodec(k=getattr(spec, "topk", 32) if spec is not None else 32)


@CODECS.register("sign1")
def _build_sign1(spec=None) -> Codec:
    return Sign1Codec()


FP32 = Fp32Codec()


# ---------------------------------------------------------------------------
# Typed messages (the host-side view of one broadcast slot)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RawGradientMsg:
    """A raw d-dimensional gradient broadcast."""

    grad: Any                       # (d,) array

    kind: ClassVar[int] = MSG_RAW

    def bits(self, codec: Codec, n: int) -> Bits:
        return codec.raw_msg_bits(self.grad.shape[-1])

    def payload(self, codec: Codec) -> Payload:
        return codec.encode(self.grad)


@dataclasses.dataclass(frozen=True)
class EchoMsg:
    """An echo message ``(k, x, I)``: norm ratio, projection
    coefficients (masked to the reference set) and the reference
    bitmap I."""

    ratio: Any                      # () norm ratio ||g|| / ||Ax||
    coeffs: Any                     # (n,) coefficients, zero outside ref
    ref: Any                        # (n,) bool reference bitmap

    kind: ClassVar[int] = MSG_ECHO

    def bits(self, codec: Codec, n: int) -> Bits:
        rank = int(jnp.sum(self.ref))
        return codec.echo_msg_bits(n, rank)

    def payload(self, codec: Codec) -> Payload:
        dense = jnp.concatenate([jnp.reshape(self.ratio, (1,)),
                                 jnp.asarray(self.coeffs)[
                                     jnp.asarray(self.ref)]])
        return codec.encode(dense)


@dataclasses.dataclass(frozen=True)
class SilentMsg:
    """Nothing on the air: a crashed or over-budget worker."""

    kind: ClassVar[int] = MSG_SILENT

    def bits(self, codec: Codec, n: int) -> int:
        return 0


Message = Union[RawGradientMsg, EchoMsg, SilentMsg]


def messages_from_round(round_msgs) -> List[Message]:
    """Decode a dense ``core.types.RoundMessages`` buffer (anything with
    ``kind``/``raw``/``echo_k``/``echo_x``/``echo_ref`` fields) into the
    typed per-slot messages — the host-side analysis view."""
    import numpy as np

    kinds = np.asarray(round_msgs.kind)
    out: List[Message] = []
    for j, kind in enumerate(kinds):
        if kind == MSG_RAW:
            out.append(RawGradientMsg(grad=round_msgs.raw[j]))
        elif kind == MSG_ECHO:
            out.append(EchoMsg(ratio=round_msgs.echo_k[j],
                               coeffs=round_msgs.echo_x[j],
                               ref=round_msgs.echo_ref[j]))
        else:
            out.append(SilentMsg())
    return out
