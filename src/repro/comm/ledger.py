"""CommLedger — the one place communication bits are accounted.

Before ``repro.comm`` the stack counted bits in three disconnected
places: closed-form constants in ``core/types.py``, a ``bits`` carry
array in the protocol slot loop, and hand-rolled ``bits_sent`` /
``bits_baseline`` counters on the Trainer. The ledger replaces the
hand-rolled side: the Trainer's echo-DP driver, the protocol simulation
(``core.protocol.run_training``) and anything else that transmits
reports rounds into one :class:`CommLedger`, which emits the per-round
record fields the existing metrics contract already carries (``bits``,
``bits_cumulative``, ``bits_baseline_cumulative``) plus the cumulative
summary (``bits_sent`` / ``bits_baseline`` / ``bits_saving``).

The baseline is the all-raw round *under the same codec* — apples to
apples, and identical to the paper's ``n * 32 * d`` for fp32.

The ledger is also an observability source: when a tracker is active
(``repro.obs``), every ``record_round`` emits a ``comm.round`` event
and bumps the ``comm.*`` counters, so the bit trajectory is visible in
``events.jsonl`` without a second accounting path.
"""
from __future__ import annotations

from typing import Any, Dict

from repro import obs

from .wire import Codec


def raw_round_bits(codec: Codec, n: int, d: int) -> int:
    """One all-raw round: every worker broadcasts its gradient."""
    return n * int(codec.raw_msg_bits(d))


def echo_round_bits(codec: Codec, n: int, k: int) -> int:
    """One all-echo round: every worker broadcasts an echo over a
    k-reference basis."""
    return n * int(codec.echo_msg_bits(n, k))


class CommLedger:
    """Cumulative per-run communication accounting."""

    def __init__(self) -> None:
        self.rounds = 0
        self.echo_rounds = 0
        self.bits_sent = 0
        self.bits_baseline = 0

    def record_round(self, bits, baseline, echoed: bool = False
                     ) -> Dict[str, Any]:
        """Report one communication round; returns the metrics-record
        fields for it (the names the Trainer sink always emitted).

        Invariant: a round can never transmit (or be priced against) a
        negative number of bits — a negative report means an accounting
        bug upstream, so it raises instead of corrupting the ledger.
        """
        bits = int(bits)
        baseline = int(baseline)
        if bits < 0 or baseline < 0:
            raise ValueError(
                f"negative round bits (bits={bits}, baseline={baseline})"
                f" — communication accounting must be non-negative")
        self.rounds += 1
        self.echo_rounds += int(bool(echoed))
        self.bits_sent += bits
        self.bits_baseline += baseline
        if obs.tracing():
            obs.counter("comm.rounds")
            if echoed:
                obs.counter("comm.echo_rounds")
            obs.counter("comm.bits_sent", bits)
            obs.counter("comm.bits_baseline", baseline)
            obs.event("comm.round", round=self.rounds - 1, bits=bits,
                      baseline=baseline, echoed=bool(echoed),
                      bits_cumulative=self.bits_sent)
        return {"bits": bits,
                "bits_cumulative": self.bits_sent,
                "bits_baseline_cumulative": self.bits_baseline}

    def record_protocol_trace(self, trace: Dict[str, Any], n: int,
                              d: int, codec: Codec) -> None:
        """Fold a ``core.protocol.run_training`` trace into the ledger:
        one record per simulated round, baseline = all-raw same codec."""
        import numpy as np

        baseline = raw_round_bits(codec, n, d)
        # one bulk device->host transfer per array, not one per round
        bits_t = np.asarray(trace["bits"])
        n_echo = trace.get("n_echo")
        echoed_t = (np.asarray(n_echo) > 0) if n_echo is not None \
            else np.zeros(len(bits_t), bool)
        for bits, echoed in zip(bits_t, echoed_t):
            self.record_round(bits=float(bits), baseline=baseline,
                              echoed=bool(echoed))

    @property
    def bits_saving(self) -> float:
        return 1.0 - self.bits_sent / max(self.bits_baseline, 1)

    @property
    def echo_rate(self) -> float:
        return self.echo_rounds / max(self.rounds, 1)

    def summary(self) -> Dict[str, Any]:
        return {"rounds": self.rounds,
                "echo_rounds": self.echo_rounds,
                "echo_rate": self.echo_rate,
                "bits_sent": self.bits_sent,
                "bits_baseline": self.bits_baseline,
                "bits_saving": self.bits_saving}
