"""Byzantine-robust aggregation baselines.

The paper builds on the CGC filter [11] and cites Krum [4], coordinate-wise
median / trimmed mean [6], and plain averaging as the surrounding landscape.
All of them are implemented here with one signature so the trainer, the
protocol simulator and the benchmarks can swap them freely:

    aggregate(G: (n, d) gradients, f: int) -> (d,) update direction

Conventions: CGC returns the filtered *sum* (paper line 44); the others
return a mean-scale vector. ``repro.dist.collectives.AGG_FNS`` re-derives
the same aggregators (same name, same scale) as shard_map collectives over
the worker axes for the distributed trainer. ``AGGREGATORS`` is the shared
plugin registry (``repro.run.registry``): a new aggregator is one
``@AGGREGATORS.register("name")`` function.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.run.registry import AGGREGATORS

from .cgc import cgc_aggregate, cgc_filter


@AGGREGATORS.register("mean")
def mean(G: jax.Array, f: int = 0) -> jax.Array:
    """Fault-intolerant baseline: plain average (times n to match CGC sum)."""
    return jnp.mean(G, axis=0)


@AGGREGATORS.register("cgc")
def cgc_sum(G: jax.Array, f: int) -> jax.Array:
    """The paper's aggregation: CGC filter then sum (Gupta-Vaidya)."""
    return cgc_aggregate(G, f)


@AGGREGATORS.register("cgc_mean")
def cgc_mean(G: jax.Array, f: int) -> jax.Array:
    """CGC filter then mean — scale-compatible with the other baselines."""
    return cgc_aggregate(G, f) / G.shape[0]


@AGGREGATORS.register("krum")
def krum(G: jax.Array, f: int) -> jax.Array:
    """Krum (Blanchard et al., NeurIPS'17).

    Scores each gradient by the sum of squared distances to its n-f-2
    nearest neighbours; returns the minimiser. Requires n > 2f + 2.
    """
    n = G.shape[0]
    sq = jnp.sum((G[:, None, :] - G[None, :, :]) ** 2, axis=-1)  # (n, n)
    sq = sq + jnp.diag(jnp.full((n,), jnp.inf))
    k = max(n - f - 2, 1)
    nearest = jnp.sort(sq, axis=1)[:, :k]
    scores = jnp.sum(nearest, axis=1)
    return G[jnp.argmin(scores)]


@AGGREGATORS.register("multi_krum")
def multi_krum(G: jax.Array, f: int, m: int | None = None) -> jax.Array:
    """Multi-Krum: average the m best-scored gradients."""
    n = G.shape[0]
    m = m if m is not None else max(n - f, 1)
    sq = jnp.sum((G[:, None, :] - G[None, :, :]) ** 2, axis=-1)
    sq = sq + jnp.diag(jnp.full((n,), jnp.inf))
    k = max(n - f - 2, 1)
    scores = jnp.sum(jnp.sort(sq, axis=1)[:, :k], axis=1)
    best = jnp.argsort(scores)[:m]
    return jnp.mean(G[best], axis=0)


@AGGREGATORS.register("median")
def coordinate_median(G: jax.Array, f: int = 0) -> jax.Array:
    """Coordinate-wise median (Yin et al. / Chen-Su-Xu [6] family)."""
    return jnp.median(G, axis=0)


@AGGREGATORS.register("trimmed_mean")
def trimmed_mean(G: jax.Array, f: int) -> jax.Array:
    """Coordinate-wise f-trimmed mean: drop the f largest and f smallest
    entries per coordinate, average the rest. Requires n > 2f."""
    n = G.shape[0]
    if n <= 2 * f:
        raise ValueError(f"trimmed_mean needs n > 2f (n={n}, f={f})")
    s = jnp.sort(G, axis=0)
    kept = s[f:n - f] if f > 0 else s
    return jnp.mean(kept, axis=0)


@AGGREGATORS.register("geometric_median")
def geometric_median(G: jax.Array, f: int = 0, iters: int = 32,
                     eps: float = 1e-8) -> jax.Array:
    """Weiszfeld iterations for the geometric median (RFA-style)."""
    def step(z, _):
        dist = jnp.maximum(jnp.linalg.norm(G - z, axis=-1), eps)
        wts = 1.0 / dist
        z = (wts @ G) / jnp.sum(wts)
        return z, None

    z0 = jnp.mean(G, axis=0)
    z, _ = jax.lax.scan(step, z0, None, length=iters)
    return z


