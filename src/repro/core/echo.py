"""The echo mechanism — the paper's main novelty (Sec. 3, communication phase).

A worker that overheard raw gradients ``R = {g_{i_1}, ..., g_{i_k}}`` computes
the projection of its local gradient onto span(R):

    A = [g_{i_1} | ... | g_{i_k}]  in R^{d x k}
    x = (A^T A)^{-1} A^T g        (Moore-Penrose least squares)
    echo gradient  g* = A x

and broadcasts the O(n)-bit echo message (||g||/||g*||, x, I) iff

    ||g* - g|| <= r ||g||.                                        (Eq. 7)

We work with a *masked fixed-shape* representation: the reference buffer is
always (n, d) with a boolean ``mask`` marking valid rows, so the whole slot
loop jits. The Gram solve adds a tiny ridge scaled to the Gram diagonal for
numerical stability (exact MP-inverse in exact arithmetic per Appendix D —
columns of A are linearly independent by construction).

The server-side reconstruction is ``g~ = k * A_I x`` (paper line 39), which by
construction satisfies ||g~|| = ||g|| (the norm ratio k restores the original
magnitude while keeping the echo direction).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class EchoDecision(NamedTuple):
    send_echo: jax.Array     # () bool — Eq. 7 holds and span is non-empty
    k: jax.Array             # () norm ratio ||g|| / ||g*||
    x: jax.Array             # (n,) projection coefficients (masked)
    echo: jax.Array          # (d,) the echo gradient A x
    residual: jax.Array      # () ||Ax - g|| (diagnostic)


def masked_gram(R: jax.Array, mask: jax.Array, ridge: float) -> jax.Array:
    """Gram matrix A^T A of the masked reference rows, ridged for stability.

    Masked-out rows contribute identity rows/cols so the solve stays
    well-posed without affecting valid coefficients.
    """
    n = R.shape[0]
    Rm = R * mask[:, None]
    G = Rm @ Rm.T                                    # (n, n)
    diag_scale = jnp.maximum(jnp.max(jnp.abs(jnp.diag(G))), 1.0)
    # Identity on masked-out rows keeps the system invertible there.
    off = (~mask).astype(G.dtype)
    G = G + jnp.diag(off * diag_scale + ridge * diag_scale)
    return G


def project_onto_span(
    R: jax.Array, mask: jax.Array, g: jax.Array, ridge: float = 1e-8
) -> Tuple[jax.Array, jax.Array]:
    """Least-squares coefficients x and projection A x of g onto span(R[mask]).

    Equivalent to the paper's x = (A^T A)^{-1} A^T g with A the masked columns
    (we store gradients as rows, so A = R[mask].T). Returns (x, echo) with
    x zero outside the mask.
    """
    Rm = R * mask[:, None]
    b = Rm @ g                                       # A^T g, (n,)
    G = masked_gram(R, mask, ridge)
    x = jnp.linalg.solve(G, b)
    x = x * mask
    echo = x @ Rm                                    # A x, (d,)
    return x, echo


def echo_decision_from_projection(
    x: jax.Array,
    echo: jax.Array,
    mask: jax.Array,
    g: jax.Array,
    r: float,
) -> EchoDecision:
    """Eq. 7 decision given a precomputed projection (x, echo) of g.

    Factored out so the slot loop can run the Gram solve once and derive
    both this decision and the independence test from it.
    """
    g_norm = jnp.linalg.norm(g)
    echo_norm = jnp.linalg.norm(echo)
    residual = jnp.linalg.norm(echo - g)
    nonempty = jnp.any(mask)
    ok = (residual <= r * g_norm) & nonempty & (echo_norm > 0)
    k = jnp.where(echo_norm > 0, g_norm / jnp.maximum(echo_norm, 1e-30), 0.0)
    return EchoDecision(send_echo=ok, k=k, x=x, echo=echo, residual=residual)


def echo_decision(
    R: jax.Array,
    mask: jax.Array,
    g: jax.Array,
    r: float,
    ridge: float = 1e-8,
) -> EchoDecision:
    """Full slot-time computation of worker j (paper lines 18-24)."""
    x, echo = project_onto_span(R, mask, g, ridge)
    return echo_decision_from_projection(x, echo, mask, g, r)


def independent_from_projection(
    echo: jax.Array,
    mask: jax.Array,
    g: jax.Array,
    tol: float = 1e-6,
) -> jax.Array:
    """Appendix-D test given a precomputed projection of g onto span(R).

    Relative-residual form: independent iff ||A A^+ g - g|| > tol ||g||;
    an empty R always accepts g.
    """
    res = jnp.linalg.norm(echo - g)
    return (res > tol * jnp.linalg.norm(g)) | (~jnp.any(mask))


def is_linearly_independent(
    R: jax.Array,
    mask: jax.Array,
    g: jax.Array,
    tol: float = 1e-6,
    ridge: float = 1e-8,
) -> jax.Array:
    """Appendix-D test (line 29): g independent of R iff A A^+ g != g."""
    _, proj = project_onto_span(R, mask, g, ridge)
    return independent_from_projection(proj, mask, g, tol)


def wire_norm_ratio(
    R: jax.Array,
    mask: jax.Array,
    x: jax.Array,
    g: jax.Array,
) -> jax.Array:
    """Norm ratio ``k = ||g|| / ||A x||`` for the coefficients *as
    transmitted*.

    When a lossy codec quantizes the echo coefficients, the sender must
    compute the ratio against the quantized reconstruction ``A x̂`` (not
    its exact projection) or the server-side ``g~ = k A x̂`` loses the
    paper's ``||g~|| = ||g||`` invariant. With the ideal fp32 codec
    ``x̂ == x`` and this is bit-for-bit the ratio
    :func:`echo_decision_from_projection` computes.
    """
    Rm = R * mask[:, None]
    echo = (x * mask) @ Rm
    g_norm = jnp.linalg.norm(g)
    echo_norm = jnp.linalg.norm(echo)
    return jnp.where(echo_norm > 0,
                     g_norm / jnp.maximum(echo_norm, 1e-30), 0.0)


def reconstruct_echo(
    G_server: jax.Array,
    ref_mask: jax.Array,
    k: jax.Array,
    x: jax.Array,
) -> jax.Array:
    """Server-side g~ = k * A_I x (paper line 39).

    ``G_server`` is the server's (n, d) gradient table; ``ref_mask`` marks I.
    Coefficients outside I are zeroed defensively (a Byzantine echo may ship
    junk there).
    """
    xm = x * ref_mask
    return k * (xm @ (G_server * ref_mask[:, None]))
