"""Echo-CGC core: the paper's contribution as a composable JAX library."""
from . import aggregators, byzantine, cgc, costfns, echo, protocol, theory
from .byzantine import ATTACKS, AttackPlan
from .cgc import cgc_aggregate, cgc_filter, cgc_scales, cgc_threshold
from .echo import echo_decision, project_onto_span, reconstruct_echo
from .protocol import (communication_phase, echo_cgc_round, pointwise_round,
                       run_training)
from .theory import (K_STAR, comm_ratio_C, echo_probability, pick_r_eta,
                     r_max_lemma3, r_max_lemma4, resilience_condition)
from .types import ProtocolConfig, RoundStats, ServerState

__all__ = [
    "ATTACKS", "AttackPlan", "K_STAR", "ProtocolConfig", "RoundStats",
    "ServerState", "aggregators", "byzantine", "cgc", "cgc_aggregate",
    "cgc_filter", "cgc_scales", "cgc_threshold", "comm_ratio_C", "costfns",
    "echo", "echo_cgc_round", "echo_decision", "echo_probability",
    "communication_phase", "pick_r_eta", "pointwise_round",
    "project_onto_span", "protocol", "r_max_lemma3", "r_max_lemma4",
    "reconstruct_echo", "resilience_condition", "run_training", "theory",
]
