"""The full Echo-CGC round: computation, communication and aggregation phases.

This is the *faithful* simulation of the paper's Algorithm 1 on a single-hop
radio network: n TDMA slots in worker-ID order, every broadcast overheard by
everyone, raw gradients entering the (shared, in-order) reference set if
linearly independent, echo messages reconstructed by the server, provable
detection of echoes referencing unheard workers, and CGC-filtered sum update.

Everything is fixed-shape and jittable; the slot loop is a lax.fori_loop.

Communication itself is delegated to ``repro.comm`` (DESIGN.md §9): a
:class:`~repro.comm.CommConfig` picks the wire :class:`~repro.comm.Codec`
(what a broadcast costs in bits, and what quantization the receivers see)
and the :class:`~repro.comm.Channel` (ideal / lossy / metered broadcast).
The slot loop threads the channel's :class:`~repro.comm.ChannelState`
through its carry — fading and budget admission are part of the jitted
round. Under the default ideal-fp32 comm config every value and every bit
count is bit-for-bit the paper's closed-form accounting.

A note on the reference sets R_j: in the paper each worker keeps its own R_j,
but every worker hears the same raw broadcasts in the same slot order and
applies the same deterministic independence test — so R_j is exactly the
shared in-order independent prefix known at slot j. We therefore keep ONE
reference buffer keyed by broadcaster ID and snapshot its mask per slot.
(On a lossy channel a faded raw broadcast is skipped by *every* overhearer,
so the reference set stays shared — it just grows more slowly. The
independence test runs on the sender-side projection; quantization noise is
treated as preserving independence.)

That shared-mask argument holds exactly when the hearing graph is complete.
A partial topology (``repro.net``, DESIGN.md §15) breaks it: worker j only
overhears the raws its radio reaches, so R_j really is per-worker. Passing
``net=`` (a :class:`repro.net.HearingGraph`) switches the slot loop to an
(n, n) per-worker mask table — each sender decides and echoes against its
own mask, each receiver runs its own independence test, and the server
(which hears every uplink slot regardless of worker-to-worker reach)
additionally detects echoes referencing workers outside the sender's
hearing set. ``net=None`` or a complete graph keeps the exact shared-mask
code path, jaxpr and all.
"""
from __future__ import annotations

import inspect
from functools import partial
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import obs
from repro.comm import ChannelState, CommConfig, CommLedger, DEFAULT_COMM

from . import aggregators as agg_lib
from .byzantine import AttackPlan
from .cgc import cgc_aggregate, cgc_aggregate_known_bad
from .echo import (echo_decision_from_projection, independent_from_projection,
                   project_onto_span, reconstruct_echo, wire_norm_ratio)
from .types import (MSG_ECHO, MSG_RAW, MSG_SILENT, ProtocolConfig, RoundStats,
                    ServerState)


class CommState(NamedTuple):
    """Carry of the slot loop."""

    G: jax.Array          # (n, d) server gradient table
    received: jax.Array   # (n,) bool
    detected: jax.Array   # (n,) bool
    R: jax.Array          # (n, d) overheard raw gradients (row = sender ID)
    rmask: jax.Array      # (n,) bool shared reference mask — or (n, n)
                          # per-worker masks (rmask[j] = worker j's view)
                          # when a partial hearing graph is threaded in
    bits: jax.Array       # (n,) float bits transmitted per worker
    echoed: jax.Array     # (n,) bool — worker sent an echo message
    faded: jax.Array      # (n,) bool — the channel faded this worker's slot
    chan: ChannelState    # broadcast-channel carry (fading PRNG + budget)
    ef: jax.Array         # (n, d) error-feedback residuals (zeros when off)


def _slot(i: jax.Array, st: CommState, *, cfg: ProtocolConfig,
          grads: jax.Array, byz_mask: jax.Array, plan: AttackPlan,
          comm: CommConfig, use_ef: bool = False) -> CommState:
    """One TDMA slot: worker i broadcasts; server + all workers process."""
    n, d = grads.shape
    g_i = grads[i]
    is_byz = byz_mask[i]
    codec, channel = comm.codec, comm.channel

    # --- Worker i decides what to broadcast (lines 14-24) ----------------
    # One Gram solve serves both the echo decision (Eq. 7) and the
    # independence test (line 29): project the broadcast vector once.
    # For honest workers raw_msg == g_i, so the decision is the paper's;
    # for Byzantine workers every dec field is overridden by the plan.
    raw_msg = jnp.where(is_byz, plan.raw[i], g_i)
    x_proj, proj = project_onto_span(st.R, st.rmask, raw_msg, cfg.ridge)
    dec = echo_decision_from_projection(x_proj, proj, st.rmask, raw_msg,
                                        cfg.r)
    honest_mode = jnp.where(dec.send_echo, MSG_ECHO, MSG_RAW)
    mode = jnp.where(is_byz, plan.mode[i], honest_mode).astype(jnp.int32)

    # --- Channel: per-slot fading ----------------------------------------
    # A faded echo cannot be verified, so the sender retransmits raw
    # (the paper's reliability assumption); a faded raw still reaches the
    # server but is NOT overheard, shrinking the shared reference set.
    chan, faded = channel.fade(st.chan, i)
    # Jamming (net/attacks.echo_jam): a worker spending its radio on noise
    # blankets every *other* slot — same observable semantics as a fade
    # (echoes unverifiable, raws not overheard); the uplink itself is
    # directional enough to survive, so the server still receives.
    jammed = jnp.any(plan.jam & byz_mask) & ~is_byz
    faded = faded | jammed
    fellback = (mode == MSG_ECHO) & faded
    mode = jnp.where(fellback, MSG_RAW, mode)

    # --- Wire coding ------------------------------------------------------
    # Receivers see the codec's reconstruction of every float payload.
    # ``codec.lossless`` is trace-time static: the fp32 default skips the
    # roundtrips and the ratio recompute entirely, so its jaxpr (and every
    # value in it) is exactly the pre-comm slot loop.
    echo_ref = jnp.where(is_byz, plan.echo_ref[i], st.rmask)
    echo_x = jnp.where(is_byz, plan.echo_x[i], dec.x)
    ef_row = st.ef[i]
    if codec.lossless:
        raw_wire = raw_msg
        echo_k = jnp.where(is_byz, plan.echo_k[i], dec.k)
    else:
        if use_ef:
            # error feedback (comm.policy.feedback): compensate the raw
            # payload with this worker's carried residual; what the codec
            # loses this slot is carried to the next raw transmission.
            compensated = raw_msg + ef_row
            raw_wire = codec.roundtrip(compensated)
            ef_row = compensated - raw_wire
        else:
            raw_wire = codec.roundtrip(raw_msg)
        echo_x = codec.roundtrip(echo_x)
        # Honest senders compute the norm ratio against the coefficients
        # AS TRANSMITTED so ||g~|| == ||g|| survives quantization;
        # Byzantine senders forge theirs freely.
        k_honest = wire_norm_ratio(st.R, st.rmask, echo_x, raw_msg)
        echo_k = codec.roundtrip(
            jnp.where(is_byz, plan.echo_k[i], k_honest)[None])[0]

    is_raw = mode == MSG_RAW
    is_echo = mode == MSG_ECHO

    # --- Bit pricing + budget admission (Sec. 2.1 via the codec) ---------
    rank = jnp.sum(echo_ref & st.received)
    raw_cost = jnp.float32(codec.raw_msg_bits(d))
    echo_cost = jnp.asarray(codec.echo_msg_bits(n, rank)).astype(jnp.float32)
    attempt = jnp.where(
        is_echo, echo_cost,
        jnp.where(is_raw,
                  jnp.where(fellback, echo_cost + raw_cost, raw_cost),
                  0.0))
    attempt = channel.price(attempt)   # relay fabrics multiply the copies
    chan, ok = channel.admit(chan, attempt)
    mode = jnp.where(ok, mode, MSG_SILENT)   # over budget: server times out
    is_raw = is_raw & ok
    is_echo = is_echo & ok
    bits_i = jnp.where(ok, attempt, 0.0)

    # --- Server processes the message (lines 33-41) ----------------------
    # Echo referencing an unheard worker == provable Byzantine (lines 36-37).
    bad_ref = jnp.any(echo_ref & ~st.received)
    detected_i = is_echo & bad_ref
    g_echo = reconstruct_echo(st.G, echo_ref & st.received, echo_k, echo_x)
    g_tilde = jnp.where(is_raw, raw_wire,
                        jnp.where(is_echo & ~bad_ref, g_echo,
                                  jnp.zeros((d,), grads.dtype)))
    g_tilde = channel.deliver(st.chan, i, g_tilde)
    G = st.G.at[i].set(g_tilde)
    received = st.received.at[i].set(mode != MSG_SILENT)
    detected = st.detected.at[i].set(detected_i)

    # --- All later workers overhear raw broadcasts (lines 26-31) ---------
    indep = independent_from_projection(proj, st.rmask, raw_msg,
                                        cfg.indep_tol)
    overheard = ~faded & ok
    add = is_raw & indep & overheard
    R = jnp.where(add, st.R.at[i].set(raw_wire), st.R)
    rmask = st.rmask.at[i].set(add | st.rmask[i])

    bits = st.bits.at[i].set(bits_i)
    echoed = st.echoed.at[i].set(is_echo)
    faded_acc = st.faded.at[i].set(faded)
    # the residual commits only when the raw payload actually went on the
    # air and was admitted — a slot that echoed (or was silenced by the
    # meter) never transmitted it, so the carried state must not change
    ef = jnp.where(use_ef & is_raw, st.ef.at[i].set(ef_row), st.ef)

    return CommState(G, received, detected, R, rmask, bits, echoed,
                     faded_acc, chan, ef)


def _slot_net(i: jax.Array, st: CommState, *, cfg: ProtocolConfig,
              grads: jax.Array, byz_mask: jax.Array, plan: AttackPlan,
              comm: CommConfig, hear: jax.Array,
              use_ef: bool = False) -> CommState:
    """One TDMA slot under a partial hearing graph.

    Same protocol as :func:`_slot` with per-worker reference sets:
    ``st.rmask`` is (n, n) with row j = worker j's view, ``hear[j, i]``
    says worker j's radio reaches worker i. The sender decides and
    echoes against its OWN mask; every receiver runs its own
    independence test on the raws it actually overhears; and the server
    — which knows the topology — additionally flags echoes referencing
    workers outside the sender's hearing set (the paper's lines 36-37
    detection generalized to the graph).
    """
    n, d = grads.shape
    g_i = grads[i]
    is_byz = byz_mask[i]
    codec, channel = comm.codec, comm.channel
    mask_i = st.rmask[i]                    # sender's own reference view

    # --- Worker i decides what to broadcast (lines 14-24) ----------------
    raw_msg = jnp.where(is_byz, plan.raw[i], g_i)
    x_proj, proj = project_onto_span(st.R, mask_i, raw_msg, cfg.ridge)
    dec = echo_decision_from_projection(x_proj, proj, mask_i, raw_msg,
                                        cfg.r)
    honest_mode = jnp.where(dec.send_echo, MSG_ECHO, MSG_RAW)
    mode = jnp.where(is_byz, plan.mode[i], honest_mode).astype(jnp.int32)

    # --- Channel: per-slot fading + jamming -------------------------------
    chan, faded = channel.fade(st.chan, i)
    jammed = jnp.any(plan.jam & byz_mask) & ~is_byz
    faded = faded | jammed
    fellback = (mode == MSG_ECHO) & faded
    mode = jnp.where(fellback, MSG_RAW, mode)

    # --- Wire coding ------------------------------------------------------
    echo_ref = jnp.where(is_byz, plan.echo_ref[i], mask_i)
    echo_x = jnp.where(is_byz, plan.echo_x[i], dec.x)
    ef_row = st.ef[i]
    if codec.lossless:
        raw_wire = raw_msg
        echo_k = jnp.where(is_byz, plan.echo_k[i], dec.k)
    else:
        if use_ef:
            compensated = raw_msg + ef_row
            raw_wire = codec.roundtrip(compensated)
            ef_row = compensated - raw_wire
        else:
            raw_wire = codec.roundtrip(raw_msg)
        echo_x = codec.roundtrip(echo_x)
        k_honest = wire_norm_ratio(st.R, mask_i, echo_x, raw_msg)
        echo_k = codec.roundtrip(
            jnp.where(is_byz, plan.echo_k[i], k_honest)[None])[0]

    is_raw = mode == MSG_RAW
    is_echo = mode == MSG_ECHO

    # --- Bit pricing + budget admission -----------------------------------
    rank = jnp.sum(echo_ref & st.received)
    raw_cost = jnp.float32(codec.raw_msg_bits(d))
    echo_cost = jnp.asarray(codec.echo_msg_bits(n, rank)).astype(jnp.float32)
    attempt = jnp.where(
        is_echo, echo_cost,
        jnp.where(is_raw,
                  jnp.where(fellback, echo_cost + raw_cost, raw_cost),
                  0.0))
    attempt = channel.price(attempt)
    chan, ok = channel.admit(chan, attempt)
    mode = jnp.where(ok, mode, MSG_SILENT)
    is_raw = is_raw & ok
    is_echo = is_echo & ok
    bits_i = jnp.where(ok, attempt, 0.0)

    # --- Server processes the message -------------------------------------
    # Topology-aware detection: an echo referencing a worker the sender
    # could not have heard (graph edge absent OR slot not received) is
    # provably Byzantine. Honest masks are built from overheard slots
    # within hearing range, so they never trip this.
    bad_ref = jnp.any(echo_ref & (~st.received | ~hear[i]))
    detected_i = is_echo & bad_ref
    g_echo = reconstruct_echo(st.G, echo_ref & st.received, echo_k, echo_x)
    g_tilde = jnp.where(is_raw, raw_wire,
                        jnp.where(is_echo & ~bad_ref, g_echo,
                                  jnp.zeros((d,), grads.dtype)))
    g_tilde = channel.deliver(st.chan, i, g_tilde)
    G = st.G.at[i].set(g_tilde)
    received = st.received.at[i].set(mode != MSG_SILENT)
    detected = st.detected.at[i].set(detected_i)

    # --- Overhearing, per receiver (lines 26-31 under the graph) ----------
    # Each worker j that hears i runs ITS OWN independence test against
    # its own mask. The shared R buffer stores the wire payload once
    # (row = sender ID, identical for all receivers); membership is the
    # per-worker business, so it lives entirely in rmask[:, i].
    indep = jax.vmap(
        lambda m: independent_from_projection(
            project_onto_span(st.R, m, raw_msg, cfg.ridge)[1],
            m, raw_msg, cfg.indep_tol))(st.rmask)          # (n,)
    on_air = is_raw & ~faded & ok
    add = on_air & indep & hear[:, i]       # hear[j, i]: j overhears i
    R = jnp.where(on_air, st.R.at[i].set(raw_wire), st.R)
    rmask = st.rmask.at[:, i].set(add | st.rmask[:, i])

    bits = st.bits.at[i].set(bits_i)
    echoed = st.echoed.at[i].set(is_echo)
    faded_acc = st.faded.at[i].set(faded)
    ef = jnp.where(use_ef & is_raw, st.ef.at[i].set(ef_row), st.ef)

    return CommState(G, received, detected, R, rmask, bits, echoed,
                     faded_acc, chan, ef)


def communication_phase(
    cfg: ProtocolConfig,
    grads: jax.Array,
    byz_mask: jax.Array,
    plan: AttackPlan,
    comm: Optional[CommConfig] = None,
    chan_key: Optional[jax.Array] = None,
    ef: Optional[jax.Array] = None,
    net=None,
):
    """Run the n TDMA slots; return the server view and round statistics.

    ``comm`` selects the wire codec + broadcast channel (default: the
    paper's ideal fp32 setup); ``chan_key`` seeds this round's fading
    draws (defaults to the channel's configured seed).

    ``ef`` (an (n, d) residual array) threads error-feedback
    accumulators through the slot loop: each worker's raw payload is
    compensated pre-encode and the codec's loss carried to its next raw
    slot. When given, the return value grows to
    ``(server, stats, ef_next)`` — callers that never pass it keep the
    two-tuple contract (and the exact pre-policy jaxpr).

    ``net`` (a :class:`repro.net.HearingGraph`, trace-time static)
    restricts worker-to-worker overhearing. ``None`` or a complete graph
    keeps the exact shared-reference-mask slot body; anything partial
    switches to the per-worker (n, n) mask variant (:func:`_slot_net`).
    """
    comm = comm if comm is not None else DEFAULT_COMM
    n, d = grads.shape
    shared = net is None or net.is_complete
    if net is not None and net.n != n:
        raise ValueError(f"hearing graph is for n={net.n} workers, "
                         f"round has n={n}")
    st = CommState(
        G=jnp.zeros((n, d), grads.dtype),
        received=jnp.zeros((n,), bool),
        detected=jnp.zeros((n,), bool),
        R=jnp.zeros((n, d), grads.dtype),
        rmask=jnp.zeros((n,) if shared else (n, n), bool),
        bits=jnp.zeros((n,), jnp.float32),
        echoed=jnp.zeros((n,), bool),
        faded=jnp.zeros((n,), bool),
        chan=comm.channel.init(chan_key),
        ef=ef if ef is not None else jnp.zeros((n, d), grads.dtype),
    )
    if shared:
        body = partial(_slot, cfg=cfg, grads=grads, byz_mask=byz_mask,
                       plan=plan, comm=comm, use_ef=ef is not None)
    else:
        body = partial(_slot_net, cfg=cfg, grads=grads, byz_mask=byz_mask,
                       plan=plan, comm=comm, hear=net.matrix(),
                       use_ef=ef is not None)
    st = jax.lax.fori_loop(0, n, body, st)

    server = ServerState(G=st.G, received=st.received, detected=st.detected)
    # rank_R under per-worker masks: rows referenced by at least one view
    # (the shared-path statistic is the same reduction on a 1-D mask).
    rmask_any = st.rmask if shared else jnp.any(st.rmask, axis=0)
    stats = RoundStats(
        bits_sent=st.bits,
        echo_sent=st.echoed,
        n_echo=jnp.sum(st.echoed.astype(jnp.int32)),
        n_detected=jnp.sum(st.detected.astype(jnp.int32)),
        rank_R=jnp.sum(rmask_any.astype(jnp.int32)),
        n_faded=jnp.sum(st.faded.astype(jnp.int32)),
    )
    if ef is not None:
        return server, stats, st.ef
    return server, stats


def aggregate(server: ServerState, f: int, aggregator: str = "cgc"
              ) -> jax.Array:
    """Aggregation phase. ``cgc`` is the paper's (filter + sum, line 42-44);
    the rest are baselines operating on the same reconstructed table.

    Workers the server *knows* are bad — timed out or provably detected
    — are excluded from the CGC order statistic
    (:func:`~repro.core.cgc.cgc_aggregate_known_bad`): their zero rows
    must not drag the clip threshold to 0 at the n = f + 1 crash edge.
    Clean rounds take the untouched fused-kernel branch.
    """
    G = jnp.where(server.received[:, None], server.G, 0.0)
    if aggregator == "cgc":
        bad = ~server.received | server.detected
        return cgc_aggregate_known_bad(G, f, bad)
    return agg_lib.AGGREGATORS[aggregator](G, f)


@partial(jax.jit, static_argnames=("cfg", "aggregator", "comm", "net"))
def echo_cgc_round(
    cfg: ProtocolConfig,
    w: jax.Array,
    grads: jax.Array,
    byz_mask: jax.Array,
    plan: AttackPlan,
    aggregator: str = "cgc",
    comm: Optional[CommConfig] = None,
    chan_key: Optional[jax.Array] = None,
    ef: Optional[jax.Array] = None,
    net=None,
):
    """One full Echo-CGC round given precomputed worker gradients.

    Returns (w_next, server_state, stats). ``grads[j]`` is what an *honest*
    worker j would send; Byzantine rows are overridden by ``plan``.

    With an ``ef`` residual array the slot loop runs error-feedback
    compensation and the return grows to
    ``(w_next, server, stats, ef_next)``.

    ``net`` (static, hashable) is the optional partial hearing graph.
    """
    if ef is not None:
        server, stats, ef_next = communication_phase(
            cfg, grads, byz_mask, plan, comm=comm, chan_key=chan_key, ef=ef,
            net=net)
        g_agg = aggregate(server, cfg.f, aggregator)
        return w - cfg.eta * g_agg, server, stats, ef_next
    server, stats = communication_phase(cfg, grads, byz_mask, plan,
                                        comm=comm, chan_key=chan_key,
                                        net=net)
    g_agg = aggregate(server, cfg.f, aggregator)
    w_next = w - cfg.eta * g_agg
    return w_next, server, stats


@partial(jax.jit, static_argnames=("cfg", "aggregator", "comm"))
def pointwise_round(
    cfg: ProtocolConfig,
    w: jax.Array,
    grads: jax.Array,
    byz_mask: jax.Array,
    plan: AttackPlan,
    aggregator: str = "cgc",
    comm: Optional[CommConfig] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Prior-algorithm baseline round (point-to-point network, no echoes).

    Every worker uploads its raw gradient: bits = n * codec.raw_msg_bits(d)
    (= n * 32 * d for fp32). Used for the communication-complexity
    comparison and for pure-CGC [11] / Krum [4] baselines.
    """
    n, d = grads.shape
    codec = (comm if comm is not None else DEFAULT_COMM).codec
    G = jnp.where(byz_mask[:, None], plan.raw, grads)
    g_agg = (cgc_aggregate(G, cfg.f) if aggregator == "cgc"
             else agg_lib.AGGREGATORS[aggregator](G, cfg.f))
    w_next = w - cfg.eta * g_agg
    bits = jnp.float32(n * codec.raw_msg_bits(d))
    return w_next, bits


def run_training(
    cfg: ProtocolConfig,
    cost,
    attack_fn: Callable[..., AttackPlan],
    byz_mask: jax.Array,
    key: jax.Array,
    w0: jax.Array,
    rounds: int,
    aggregator: str = "cgc",
    use_radio: bool = True,
    comm: Optional[CommConfig] = None,
    ledger: Optional[CommLedger] = None,
    policy=None,
    error_feedback: bool = False,
    net=None,
):
    """Multi-round driver: Echo-CGC (use_radio) or point-to-point baseline.

    Returns a dict of per-round traces: dist2 (||w-w*||^2), value, bits,
    n_echo, n_detected. A :class:`~repro.comm.CommLedger` passed as
    ``ledger`` gets one record per simulated round (the simulation's
    reporting hook into the shared accounting surface).

    ``policy`` (a :class:`~repro.comm.policy.CommPolicy`) closes the
    control loop: a *dynamic* policy moves the driver to a per-round
    host loop where the previous round's statistics pick the next
    round's (codec, r, budget); None and static policies keep the exact
    scanned trajectory. ``error_feedback`` threads per-worker residual
    accumulators through the slot loop (lossy codecs only; a no-op —
    zero residuals — under fp32).

    ``net`` (a :class:`repro.net.HearingGraph`) restricts overhearing to
    the graph; ``None`` keeps the paper's complete single-hop radio.
    Channel-aware attacks that declare ``channel=`` / ``chan_key=``
    keyword parameters receive the round's channel object and fading key
    (signature inspection — attacks without them keep their exact call).
    """
    n = cfg.n
    comm = comm if comm is not None else DEFAULT_COMM
    dynamic = policy is not None and not getattr(policy, "static", False)
    if policy is not None:
        _policy_setup(policy, cfg, comm, n, w0.shape[-1])
    if dynamic and use_radio:
        return _run_training_policy(cfg, cost, attack_fn, byz_mask, key,
                                    w0, rounds, aggregator, comm, ledger,
                                    policy, error_feedback, net)
    if policy is not None:
        # static policy on the scanned path: the decision is constant,
        # so it is emitted once up front and the trajectory is bitwise
        # the no-policy run (the BENCH_comm static_bitwise gate).
        dec = policy.observe(None)
        obs.event("comm.policy.decision", step=0, policy=policy.name,
                  codec=dec.codec or comm.codec.name,
                  echo_r=dec.echo_r if dec.echo_r is not None else cfg.r)
    use_ef = bool(error_feedback) and use_radio
    attack_extra = _attack_kwargs(attack_fn)

    def one_round(carry, key_t):
        w, ef = carry
        keys = jax.random.split(key_t, n + 1)
        grads = jax.vmap(lambda k: cost.stoch_grad(k, w))(keys[:n])
        true_grad = cost.grad(w)
        # fold_in (not a wider split) keeps grads/attack draws
        # bitwise-identical to the pre-channel code path.
        chan_key = jax.random.fold_in(key_t, n + 1)
        extra = {}
        if "channel" in attack_extra:
            extra["channel"] = comm.channel
        if "chan_key" in attack_extra:
            extra["chan_key"] = chan_key
        plan = attack_fn(keys[n], grads, byz_mask, w, true_grad, **extra)
        if use_radio:
            if use_ef:
                w_next, server, stats, ef = echo_cgc_round(
                    cfg, w, grads, byz_mask, plan, aggregator, comm,
                    chan_key, ef, net)
            else:
                w_next, server, stats = echo_cgc_round(
                    cfg, w, grads, byz_mask, plan, aggregator, comm,
                    chan_key, None, net)
            bits = jnp.sum(stats.bits_sent)
            n_echo = stats.n_echo
            n_det = stats.n_detected
        else:
            w_next, bits = pointwise_round(cfg, w, grads, byz_mask, plan,
                                           aggregator, comm)
            n_echo = jnp.int32(0)
            n_det = jnp.int32(0)
        out = dict(
            dist2=jnp.sum((w - cost.w_star) ** 2),
            value=cost.value(w),
            bits=bits,
            n_echo=n_echo,
            n_detected=n_det,
        )
        return (w_next, ef), out

    ef0 = (jnp.zeros((n, w0.shape[-1]), w0.dtype) if use_ef else None)
    keys = jax.random.split(key, rounds)
    # host-side spans only: the per-slot loop is jitted/scanned, so the
    # observable unit is the whole simulated trajectory (trace + block)
    # plus the ledger fold-in; per-round bit events come from the ledger.
    with obs.span("protocol.rounds"):
        (w_final, _), trace = jax.lax.scan(one_round, (w0, ef0), keys)
        jax.block_until_ready(w_final)
    obs.counter("protocol.rounds_simulated", rounds)
    trace["w_final"] = w_final
    if ledger is not None:
        d = w0.shape[-1]
        with obs.span("protocol.ledger"):
            ledger.record_protocol_trace(trace, n, d, comm.codec)
    return trace


def _attack_kwargs(attack_fn) -> frozenset:
    """Which channel-aware keyword parameters an attack declares.

    Host-side signature inspection (``repro.net.attacks`` docstring):
    only attacks that ask for ``channel`` / ``chan_key`` get them, so
    every existing attack keeps its exact call and trajectory.
    """
    try:
        params = inspect.signature(attack_fn).parameters
    except (TypeError, ValueError):
        return frozenset()
    return frozenset(k for k in ("channel", "chan_key") if k in params)


def _ladder_codecs(comm: CommConfig):
    """Codec objects for the policy ladder, reusing the configured
    instance for its own rung (it may carry tuned knobs, e.g. top-k)."""
    from repro.comm.policy import CODEC_LADDER
    from repro.run.registry import CODECS
    out = {}
    for name in CODEC_LADDER:
        out[name] = comm.codec if name == comm.codec.name \
            else CODECS[name](None)
    return out


def _policy_setup(policy, cfg: ProtocolConfig, comm: CommConfig,
                  n: int, d: int) -> None:
    """Hand the policy the topology + the ladder's price list."""
    from repro.comm.ledger import echo_round_bits, raw_round_bits
    from repro.comm.policy import PolicyContext
    codecs = _ladder_codecs(comm)
    channel = comm.channel
    policy.setup(PolicyContext(
        n=n, d=d,
        echo_k=n,   # protocol echoes span the (<= n)-vector reference set
        codec=comm.codec.name,
        echo_r=float(cfg.r),
        channel=channel.name,
        drop_prob=float(getattr(channel, "drop_prob", 0.0)),
        budget_bits=int(getattr(channel, "budget_bits", 0)),
        raw_round_bits={c: raw_round_bits(k, n, d)
                        for c, k in codecs.items()},
        echo_round_bits={c: echo_round_bits(k, n, n)
                         for c, k in codecs.items()},
    ))


def _run_training_policy(cfg, cost, attack_fn, byz_mask, key, w0, rounds,
                         aggregator, comm, ledger, policy, error_feedback,
                         net=None):
    """Dynamic-policy driver: one host-side loop iteration per round.

    The per-round body stays jitted (``echo_cgc_round`` caches one
    executable per (cfg, comm) pair, bounded by the codec ladder times
    the distinct ``r`` values the policy visits); the host loop exists
    so the previous round's measured statistics can pick the next
    round's communication setup. RNG (gradient / attack / fading keys)
    is derived exactly as on the scanned path, so the trajectory of a
    seeded run replays decision-for-decision.
    """
    import dataclasses as _dc

    import numpy as np

    from repro.comm import CommConfig as _CC
    from repro.comm.ledger import raw_round_bits
    from repro.comm.policy import RoundObservation
    from repro.comm.wire import FP32

    n, d = cfg.n, w0.shape[-1]
    codecs = _ladder_codecs(comm)
    fp32_round = raw_round_bits(FP32, n, d)
    cur_codec = comm.codec.name
    cur_r = float(cfg.r)
    channel = comm.channel
    switches = 0
    r_changes = 0
    bits_cum = 0

    attack_extra = _attack_kwargs(attack_fn)

    @jax.jit
    def round_inputs(key_t, w):
        keys = jax.random.split(key_t, n + 1)
        grads = jax.vmap(lambda k: cost.stoch_grad(k, w))(keys[:n])
        chan_key = jax.random.fold_in(key_t, n + 1)
        extra = {}
        if "channel" in attack_extra:
            extra["channel"] = comm.channel
        if "chan_key" in attack_extra:
            extra["chan_key"] = chan_key
        plan = attack_fn(keys[n], grads, byz_mask, w, cost.grad(w), **extra)
        return grads, plan, chan_key

    w = w0
    ef = jnp.zeros((n, d), w0.dtype) if error_feedback else None
    last_obs = None
    trace = {k: [] for k in
             ("dist2", "value", "bits", "n_echo", "n_detected")}
    keys = jax.random.split(key, rounds)
    with obs.span("protocol.rounds"):
        for t in range(rounds):
            dec = policy.observe(last_obs)
            obs.counter("comm.policy.decisions")
            changed = False
            if dec.codec is not None and dec.codec != cur_codec:
                cur_codec, changed = dec.codec, True
                switches += 1
                obs.counter("comm.policy.codec_switches")
            if dec.echo_r is not None and float(dec.echo_r) != cur_r:
                cur_r, changed = float(dec.echo_r), True
                r_changes += 1
                obs.counter("comm.policy.echo_r_changes")
            if dec.budget_bits is not None and \
                    hasattr(channel, "budget_bits") and \
                    int(dec.budget_bits) != int(channel.budget_bits):
                channel, changed = _dc.replace(
                    channel, budget_bits=int(dec.budget_bits)), True
            if changed:
                obs.event("comm.policy.decision", step=t,
                          policy=policy.name, codec=cur_codec,
                          echo_r=cur_r)
            codec = codecs[cur_codec]
            cfg_t = cfg._replace(r=cur_r)
            comm_t = _CC(channel=channel, codec=codec)

            grads, plan, chan_key = round_inputs(keys[t], w)
            value = cost.value(w)
            if ef is not None:
                w_next, _, stats, ef = echo_cgc_round(
                    cfg_t, w, grads, byz_mask, plan, aggregator, comm_t,
                    chan_key, ef, net)
            else:
                w_next, _, stats = echo_cgc_round(
                    cfg_t, w, grads, byz_mask, plan, aggregator, comm_t,
                    chan_key, None, net)
            bits = int(np.asarray(jnp.sum(stats.bits_sent)))
            n_echo = int(np.asarray(stats.n_echo))
            n_faded = int(np.asarray(stats.n_faded))
            loss = float(np.asarray(value))
            baseline = raw_round_bits(codec, n, d)
            bits_cum += bits
            last_obs = RoundObservation(
                round=t, bits=bits, baseline_bits=baseline,
                fp32_baseline_bits=fp32_round, loss=loss,
                codec=cur_codec, echo_r=cur_r, attempted=True,
                echoed=n_echo > 0, echo_drops=n_faded)
            obs.event("comm.policy.round", step=t, policy=policy.name,
                      codec=cur_codec, echo_r=cur_r, bits=bits,
                      echoed=n_echo > 0, attempted=True,
                      echo_drops=n_faded, bits_cumulative=bits_cum,
                      fp32_baseline_cumulative=fp32_round * (t + 1),
                      loss=loss)
            if ledger is not None:
                ledger.record_round(bits=bits, baseline=baseline,
                                    echoed=n_echo > 0)
            trace["dist2"].append(jnp.sum((w - cost.w_star) ** 2))
            trace["value"].append(value)
            trace["bits"].append(jnp.float32(bits))
            trace["n_echo"].append(jnp.int32(n_echo))
            trace["n_detected"].append(stats.n_detected)
            w = w_next
    obs.counter("protocol.rounds_simulated", rounds)
    out = {k: jnp.stack(v) for k, v in trace.items()}
    out["w_final"] = w
    out["codec_switches"] = switches
    out["echo_r_changes"] = r_changes
    return out
