"""The full Echo-CGC round: computation, communication and aggregation phases.

This is the *faithful* simulation of the paper's Algorithm 1 on a single-hop
radio network: n TDMA slots in worker-ID order, every broadcast overheard by
everyone, raw gradients entering the (shared, in-order) reference set if
linearly independent, echo messages reconstructed by the server, provable
detection of echoes referencing unheard workers, and CGC-filtered sum update.

Everything is fixed-shape and jittable; the slot loop is a lax.fori_loop.

Communication itself is delegated to ``repro.comm`` (DESIGN.md §9): a
:class:`~repro.comm.CommConfig` picks the wire :class:`~repro.comm.Codec`
(what a broadcast costs in bits, and what quantization the receivers see)
and the :class:`~repro.comm.Channel` (ideal / lossy / metered broadcast).
The slot loop threads the channel's :class:`~repro.comm.ChannelState`
through its carry — fading and budget admission are part of the jitted
round. Under the default ideal-fp32 comm config every value and every bit
count is bit-for-bit the paper's closed-form accounting.

A note on the reference sets R_j: in the paper each worker keeps its own R_j,
but every worker hears the same raw broadcasts in the same slot order and
applies the same deterministic independence test — so R_j is exactly the
shared in-order independent prefix known at slot j. We therefore keep ONE
reference buffer keyed by broadcaster ID and snapshot its mask per slot.
(On a lossy channel a faded raw broadcast is skipped by *every* overhearer,
so the reference set stays shared — it just grows more slowly. The
independence test runs on the sender-side projection; quantization noise is
treated as preserving independence.)
"""
from __future__ import annotations

from functools import partial
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import obs
from repro.comm import ChannelState, CommConfig, CommLedger, DEFAULT_COMM

from . import aggregators as agg_lib
from .byzantine import AttackPlan
from .cgc import cgc_aggregate
from .echo import (echo_decision_from_projection, independent_from_projection,
                   project_onto_span, reconstruct_echo, wire_norm_ratio)
from .types import (MSG_ECHO, MSG_RAW, MSG_SILENT, ProtocolConfig, RoundStats,
                    ServerState)


class CommState(NamedTuple):
    """Carry of the slot loop."""

    G: jax.Array          # (n, d) server gradient table
    received: jax.Array   # (n,) bool
    detected: jax.Array   # (n,) bool
    R: jax.Array          # (n, d) overheard raw gradients (row = sender ID)
    rmask: jax.Array      # (n,) bool — rows of R that are in the reference set
    bits: jax.Array       # (n,) float bits transmitted per worker
    echoed: jax.Array     # (n,) bool — worker sent an echo message
    chan: ChannelState    # broadcast-channel carry (fading PRNG + budget)


def _slot(i: jax.Array, st: CommState, *, cfg: ProtocolConfig,
          grads: jax.Array, byz_mask: jax.Array, plan: AttackPlan,
          comm: CommConfig) -> CommState:
    """One TDMA slot: worker i broadcasts; server + all workers process."""
    n, d = grads.shape
    g_i = grads[i]
    is_byz = byz_mask[i]
    codec, channel = comm.codec, comm.channel

    # --- Worker i decides what to broadcast (lines 14-24) ----------------
    # One Gram solve serves both the echo decision (Eq. 7) and the
    # independence test (line 29): project the broadcast vector once.
    # For honest workers raw_msg == g_i, so the decision is the paper's;
    # for Byzantine workers every dec field is overridden by the plan.
    raw_msg = jnp.where(is_byz, plan.raw[i], g_i)
    x_proj, proj = project_onto_span(st.R, st.rmask, raw_msg, cfg.ridge)
    dec = echo_decision_from_projection(x_proj, proj, st.rmask, raw_msg,
                                        cfg.r)
    honest_mode = jnp.where(dec.send_echo, MSG_ECHO, MSG_RAW)
    mode = jnp.where(is_byz, plan.mode[i], honest_mode).astype(jnp.int32)

    # --- Channel: per-slot fading ----------------------------------------
    # A faded echo cannot be verified, so the sender retransmits raw
    # (the paper's reliability assumption); a faded raw still reaches the
    # server but is NOT overheard, shrinking the shared reference set.
    chan, faded = channel.fade(st.chan, i)
    fellback = (mode == MSG_ECHO) & faded
    mode = jnp.where(fellback, MSG_RAW, mode)

    # --- Wire coding ------------------------------------------------------
    # Receivers see the codec's reconstruction of every float payload.
    # ``codec.lossless`` is trace-time static: the fp32 default skips the
    # roundtrips and the ratio recompute entirely, so its jaxpr (and every
    # value in it) is exactly the pre-comm slot loop.
    echo_ref = jnp.where(is_byz, plan.echo_ref[i], st.rmask)
    echo_x = jnp.where(is_byz, plan.echo_x[i], dec.x)
    if codec.lossless:
        raw_wire = raw_msg
        echo_k = jnp.where(is_byz, plan.echo_k[i], dec.k)
    else:
        raw_wire = codec.roundtrip(raw_msg)
        echo_x = codec.roundtrip(echo_x)
        # Honest senders compute the norm ratio against the coefficients
        # AS TRANSMITTED so ||g~|| == ||g|| survives quantization;
        # Byzantine senders forge theirs freely.
        k_honest = wire_norm_ratio(st.R, st.rmask, echo_x, raw_msg)
        echo_k = codec.roundtrip(
            jnp.where(is_byz, plan.echo_k[i], k_honest)[None])[0]

    is_raw = mode == MSG_RAW
    is_echo = mode == MSG_ECHO

    # --- Bit pricing + budget admission (Sec. 2.1 via the codec) ---------
    rank = jnp.sum(echo_ref & st.received)
    raw_cost = jnp.float32(codec.raw_msg_bits(d))
    echo_cost = jnp.asarray(codec.echo_msg_bits(n, rank)).astype(jnp.float32)
    attempt = jnp.where(
        is_echo, echo_cost,
        jnp.where(is_raw,
                  jnp.where(fellback, echo_cost + raw_cost, raw_cost),
                  0.0))
    chan, ok = channel.admit(chan, attempt)
    mode = jnp.where(ok, mode, MSG_SILENT)   # over budget: server times out
    is_raw = is_raw & ok
    is_echo = is_echo & ok
    bits_i = jnp.where(ok, attempt, 0.0)

    # --- Server processes the message (lines 33-41) ----------------------
    # Echo referencing an unheard worker == provable Byzantine (lines 36-37).
    bad_ref = jnp.any(echo_ref & ~st.received)
    detected_i = is_echo & bad_ref
    g_echo = reconstruct_echo(st.G, echo_ref & st.received, echo_k, echo_x)
    g_tilde = jnp.where(is_raw, raw_wire,
                        jnp.where(is_echo & ~bad_ref, g_echo,
                                  jnp.zeros((d,), grads.dtype)))
    G = st.G.at[i].set(g_tilde)
    received = st.received.at[i].set(mode != MSG_SILENT)
    detected = st.detected.at[i].set(detected_i)

    # --- All later workers overhear raw broadcasts (lines 26-31) ---------
    indep = independent_from_projection(proj, st.rmask, raw_msg,
                                        cfg.indep_tol)
    overheard = ~faded & ok
    add = is_raw & indep & overheard
    R = jnp.where(add, st.R.at[i].set(raw_wire), st.R)
    rmask = st.rmask.at[i].set(add | st.rmask[i])

    bits = st.bits.at[i].set(bits_i)
    echoed = st.echoed.at[i].set(is_echo)

    return CommState(G, received, detected, R, rmask, bits, echoed, chan)


def communication_phase(
    cfg: ProtocolConfig,
    grads: jax.Array,
    byz_mask: jax.Array,
    plan: AttackPlan,
    comm: Optional[CommConfig] = None,
    chan_key: Optional[jax.Array] = None,
) -> Tuple[ServerState, RoundStats]:
    """Run the n TDMA slots; return the server view and round statistics.

    ``comm`` selects the wire codec + broadcast channel (default: the
    paper's ideal fp32 setup); ``chan_key`` seeds this round's fading
    draws (defaults to the channel's configured seed)."""
    comm = comm if comm is not None else DEFAULT_COMM
    n, d = grads.shape
    st = CommState(
        G=jnp.zeros((n, d), grads.dtype),
        received=jnp.zeros((n,), bool),
        detected=jnp.zeros((n,), bool),
        R=jnp.zeros((n, d), grads.dtype),
        rmask=jnp.zeros((n,), bool),
        bits=jnp.zeros((n,), jnp.float32),
        echoed=jnp.zeros((n,), bool),
        chan=comm.channel.init(chan_key),
    )
    body = partial(_slot, cfg=cfg, grads=grads, byz_mask=byz_mask, plan=plan,
                   comm=comm)
    st = jax.lax.fori_loop(0, n, body, st)

    server = ServerState(G=st.G, received=st.received, detected=st.detected)
    stats = RoundStats(
        bits_sent=st.bits,
        echo_sent=st.echoed,
        n_echo=jnp.sum(st.echoed.astype(jnp.int32)),
        n_detected=jnp.sum(st.detected.astype(jnp.int32)),
        rank_R=jnp.sum(st.rmask.astype(jnp.int32)),
    )
    return server, stats


def aggregate(server: ServerState, f: int, aggregator: str = "cgc"
              ) -> jax.Array:
    """Aggregation phase. ``cgc`` is the paper's (filter + sum, line 42-44);
    the rest are baselines operating on the same reconstructed table."""
    G = jnp.where(server.received[:, None], server.G, 0.0)
    if aggregator == "cgc":
        return cgc_aggregate(G, f)
    return agg_lib.AGGREGATORS[aggregator](G, f)


@partial(jax.jit, static_argnames=("cfg", "aggregator", "comm"))
def echo_cgc_round(
    cfg: ProtocolConfig,
    w: jax.Array,
    grads: jax.Array,
    byz_mask: jax.Array,
    plan: AttackPlan,
    aggregator: str = "cgc",
    comm: Optional[CommConfig] = None,
    chan_key: Optional[jax.Array] = None,
) -> Tuple[jax.Array, ServerState, RoundStats]:
    """One full Echo-CGC round given precomputed worker gradients.

    Returns (w_next, server_state, stats). ``grads[j]`` is what an *honest*
    worker j would send; Byzantine rows are overridden by ``plan``.
    """
    server, stats = communication_phase(cfg, grads, byz_mask, plan,
                                        comm=comm, chan_key=chan_key)
    g_agg = aggregate(server, cfg.f, aggregator)
    w_next = w - cfg.eta * g_agg
    return w_next, server, stats


@partial(jax.jit, static_argnames=("cfg", "aggregator", "comm"))
def pointwise_round(
    cfg: ProtocolConfig,
    w: jax.Array,
    grads: jax.Array,
    byz_mask: jax.Array,
    plan: AttackPlan,
    aggregator: str = "cgc",
    comm: Optional[CommConfig] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Prior-algorithm baseline round (point-to-point network, no echoes).

    Every worker uploads its raw gradient: bits = n * codec.raw_msg_bits(d)
    (= n * 32 * d for fp32). Used for the communication-complexity
    comparison and for pure-CGC [11] / Krum [4] baselines.
    """
    n, d = grads.shape
    codec = (comm if comm is not None else DEFAULT_COMM).codec
    G = jnp.where(byz_mask[:, None], plan.raw, grads)
    g_agg = (cgc_aggregate(G, cfg.f) if aggregator == "cgc"
             else agg_lib.AGGREGATORS[aggregator](G, cfg.f))
    w_next = w - cfg.eta * g_agg
    bits = jnp.float32(n * codec.raw_msg_bits(d))
    return w_next, bits


def run_training(
    cfg: ProtocolConfig,
    cost,
    attack_fn: Callable[..., AttackPlan],
    byz_mask: jax.Array,
    key: jax.Array,
    w0: jax.Array,
    rounds: int,
    aggregator: str = "cgc",
    use_radio: bool = True,
    comm: Optional[CommConfig] = None,
    ledger: Optional[CommLedger] = None,
):
    """Multi-round driver: Echo-CGC (use_radio) or point-to-point baseline.

    Returns a dict of per-round traces: dist2 (||w-w*||^2), value, bits,
    n_echo, n_detected. A :class:`~repro.comm.CommLedger` passed as
    ``ledger`` gets one record per simulated round (the simulation's
    reporting hook into the shared accounting surface).
    """
    n = cfg.n
    comm = comm if comm is not None else DEFAULT_COMM

    def one_round(carry, key_t):
        w = carry
        keys = jax.random.split(key_t, n + 1)
        grads = jax.vmap(lambda k: cost.stoch_grad(k, w))(keys[:n])
        true_grad = cost.grad(w)
        plan = attack_fn(keys[n], grads, byz_mask, w, true_grad)
        if use_radio:
            # fold_in (not a wider split) keeps grads/attack draws
            # bitwise-identical to the pre-channel code path.
            chan_key = jax.random.fold_in(key_t, n + 1)
            w_next, server, stats = echo_cgc_round(
                cfg, w, grads, byz_mask, plan, aggregator, comm, chan_key)
            bits = jnp.sum(stats.bits_sent)
            n_echo = stats.n_echo
            n_det = stats.n_detected
        else:
            w_next, bits = pointwise_round(cfg, w, grads, byz_mask, plan,
                                           aggregator, comm)
            n_echo = jnp.int32(0)
            n_det = jnp.int32(0)
        out = dict(
            dist2=jnp.sum((w - cost.w_star) ** 2),
            value=cost.value(w),
            bits=bits,
            n_echo=n_echo,
            n_detected=n_det,
        )
        return w_next, out

    keys = jax.random.split(key, rounds)
    # host-side spans only: the per-slot loop is jitted/scanned, so the
    # observable unit is the whole simulated trajectory (trace + block)
    # plus the ledger fold-in; per-round bit events come from the ledger.
    with obs.span("protocol.rounds"):
        w_final, trace = jax.lax.scan(one_round, w0, keys)
        jax.block_until_ready(w_final)
    obs.counter("protocol.rounds_simulated", rounds)
    trace["w_final"] = w_final
    if ledger is not None:
        d = w0.shape[-1]
        with obs.span("protocol.ledger"):
            ledger.record_protocol_trace(trace, n, d, comm.codec)
    return trace
