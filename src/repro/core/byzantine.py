"""Byzantine attack zoo.

The adversary is omniscient (paper Sec. 2.1): it sees the parameter w^t and
all honest gradients before choosing the Byzantine messages. Due to the
reliable-local-broadcast property it cannot equivocate (same message reaches
server and all workers) and cannot spoof identities — so an attack is fully
described by *what each Byzantine worker broadcasts in its slot*:

  - a raw (bogus) d-dimensional vector, or
  - an echo message (k, x, I), possibly malformed (I referencing a worker the
    server never heard from -> provable detection, paper line 36-37), or
  - silence (crash; the synchronous server times the worker out).

An ``Attack`` maps (key, honest_grads, byz_mask, w, true_grad) -> per-worker
raw vectors plus optional echo-forging flags, consumed by the protocol.
``ATTACKS`` is the shared plugin registry (``repro.run.registry``): a new
attack is one ``@ATTACKS.register("name")`` function.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.run.registry import ATTACKS

from .types import MSG_ECHO, MSG_RAW, MSG_SILENT


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class AttackPlan:
    """What each Byzantine worker broadcasts.

    raw:        (n, d) vector to send when mode==MSG_RAW (rows for honest
                workers are ignored).
    mode:       (n,) int32 — MSG_RAW / MSG_ECHO / MSG_SILENT per worker
                (honest rows ignored).
    echo_k:     (n,) forged norm ratio when mode==MSG_ECHO.
    echo_x:     (n, n) forged coefficients.
    echo_ref:   (n, n) bool forged reference set I (may point at unheard
                workers -> server detection).
    jam:        (n,) bool — worker spends its radio on jamming instead of
                (or besides) its own slot: every *honest* slot of the
                round is unverifiable/unoverhearable, as if faded
                (``repro.net.attacks.echo_jam``). All-False by default.
    """

    raw: jax.Array
    mode: jax.Array
    echo_k: jax.Array
    echo_x: jax.Array
    echo_ref: jax.Array
    jam: jax.Array


AttackFn = Callable[..., AttackPlan]


def _default_plan(n: int, d: int, raw: jax.Array) -> AttackPlan:
    return AttackPlan(
        raw=raw,
        mode=jnp.full((n,), MSG_RAW, jnp.int32),
        echo_k=jnp.zeros((n,)),
        echo_x=jnp.zeros((n, n)),
        echo_ref=jnp.zeros((n, n), bool),
        jam=jnp.zeros((n,), bool),
    )


@ATTACKS.register("none")
def no_attack(key, honest, byz_mask, w, true_grad) -> AttackPlan:
    """Byzantine workers behave honestly (sanity baseline)."""
    n, d = honest.shape
    return _default_plan(n, d, honest)


@ATTACKS.register("sign_flip")
def sign_flip(key, honest, byz_mask, w, true_grad, scale: float = 1.0
              ) -> AttackPlan:
    """Send -scale * g_j: reverses descent, classic Byzantine SGD attack."""
    n, d = honest.shape
    return _default_plan(n, d, -scale * honest)


@ATTACKS.register("large_norm")
def large_norm(key, honest, byz_mask, w, true_grad, scale: float = 100.0
               ) -> AttackPlan:
    """Blow up the magnitude — what norm-clipping filters (CGC) neutralise."""
    n, d = honest.shape
    return _default_plan(n, d, -scale * honest)


@ATTACKS.register("random_gauss")
def random_gauss(key, honest, byz_mask, w, true_grad, scale: float = 1.0
                 ) -> AttackPlan:
    """Random Gaussian junk scaled to the mean honest norm."""
    n, d = honest.shape
    mean_norm = jnp.mean(jnp.linalg.norm(honest, axis=-1))
    noise = jax.random.normal(key, (n, d)) / jnp.sqrt(d)
    return _default_plan(n, d, scale * mean_norm * noise)


@ATTACKS.register("mean_shift")
def mean_shift(key, honest, byz_mask, w, true_grad, z: float = 1.5
               ) -> AttackPlan:
    """"A Little Is Enough"-style attack (Baruch et al.):

    send mean - z * std of the honest gradients — crafted to stay inside the
    honest spread so norm filters cannot distinguish it, while steadily
    biasing the aggregate.
    """
    n, d = honest.shape
    # Statistics over honest workers only.
    h_mask = (~byz_mask).astype(honest.dtype)[:, None]
    cnt = jnp.maximum(jnp.sum(h_mask), 1.0)
    mean = jnp.sum(honest * h_mask, 0) / cnt
    var = jnp.sum(((honest - mean) ** 2) * h_mask, 0) / cnt
    bogus = mean - z * jnp.sqrt(var)
    return _default_plan(n, d, jnp.broadcast_to(bogus, (n, d)))


@ATTACKS.register("inner_product")
def inner_product(key, honest, byz_mask, w, true_grad, eps: float = 0.1
                  ) -> AttackPlan:
    """Inner-product-manipulation attack (Xie et al.): send -eps * true_grad.

    Small norm (passes CGC untouched) but negative alignment with the
    descent direction.
    """
    n, d = honest.shape
    return _default_plan(n, d, jnp.broadcast_to(-eps * true_grad, (n, d)))


@ATTACKS.register("forged_echo")
def forged_echo(key, honest, byz_mask, w, true_grad, k_scale: float = 50.0
                ) -> AttackPlan:
    """Echo-specific attack: forge (k, x, I).

    Each Byzantine worker emits an echo message whose reference set I points
    at worker 0 plus *itself* — referencing its own (unsent) gradient means
    the server sees G[i] = ⊥ for some i in I and provably detects it
    (paper lines 36-37). Used to exercise the detection path.
    """
    n, d = honest.shape
    plan = _default_plan(n, d, honest)
    mode = jnp.full((n,), MSG_ECHO, jnp.int32)
    ref = jnp.zeros((n, n), bool)
    ref = ref.at[:, 0].set(True)
    # self-reference: row j references column j (never heard in slot order
    # when j echoes instead of sending raw).
    ref = ref | jnp.eye(n, dtype=bool)
    x = jnp.zeros((n, n)).at[:, 0].set(1.0)
    return dataclasses.replace(
        plan, mode=mode, echo_k=jnp.full((n,), k_scale), echo_x=x,
        echo_ref=ref)


@ATTACKS.register("poisoned_echo")
def poisoned_echo(key, honest, byz_mask, w, true_grad, k_scale: float = 25.0
                  ) -> AttackPlan:
    """Echo attack with a *valid* reference set but inflated norm ratio k.

    The reconstruction k * A_I x is well-formed, so the server cannot detect
    it — only the CGC filter's norm clipping bounds its damage. This is the
    attack the paper's Lemma 7/8 analysis has to survive.
    """
    n, d = honest.shape
    plan = _default_plan(n, d, honest)
    mode = jnp.full((n,), MSG_ECHO, jnp.int32)
    ref = jnp.zeros((n, n), bool).at[:, 0].set(True)   # reference slot-0 raw
    x = jnp.zeros((n, n)).at[:, 0].set(-1.0)            # flipped direction
    return dataclasses.replace(
        plan, mode=mode, echo_k=jnp.full((n,), k_scale), echo_x=x,
        echo_ref=ref)


@ATTACKS.register("crash")
def crash(key, honest, byz_mask, w, true_grad) -> AttackPlan:
    """Silent workers — the server times them out (synchronous model)."""
    n, d = honest.shape
    plan = _default_plan(n, d, honest)
    return dataclasses.replace(plan, mode=jnp.full((n,), MSG_SILENT,
                                                   jnp.int32))
