"""The CGC filter of Gupta & Vaidya (PODC 2020), Eq. (8) of the paper.

Sort received gradients by Euclidean norm; the top-f norms are clipped down
to the (n-f)-th smallest norm; directions are preserved. The server then
aggregates by *summing* the filtered gradients (paper Eq. 2 / line 44).

Pure-jnp reference implementation; ``repro.kernels.cgc_clip`` provides the
fused Pallas TPU version with the same contract.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def cgc_threshold(norms: jax.Array, f: int) -> jax.Array:
    """The (n-f)-th smallest norm — the clip level of the CGC filter."""
    n = norms.shape[0]
    if not 0 <= f < n:
        raise ValueError(f"need 0 <= f < n, got f={f}, n={n}")
    sorted_norms = jnp.sort(norms)
    return sorted_norms[n - f - 1]  # (n-f)-th smallest, 0-indexed


def cgc_scales(norms: jax.Array, f: int, eps: float = 1e-12) -> jax.Array:
    """Per-gradient scale factors: min(1, ||g_{(n-f)}|| / ||g_j||).

    Exactly Eq. (8): gradients whose norm ranks above n-f are scaled down to
    the threshold norm; the rest are untouched. Ties are handled naturally —
    a gradient at the threshold gets scale 1.
    """
    thr = cgc_threshold(norms, f)
    return jnp.minimum(1.0, thr / jnp.maximum(norms, eps))


def cgc_filter(G: jax.Array, f: int) -> jax.Array:
    """Apply the CGC filter to an (n, d) stack of gradients -> (n, d).

    The row-scaling pass dispatches through ``kernels.ops.scale_rows``
    (the Pallas ``cgc_clip.scale_rows`` streaming pass on TPU, plain jnp
    elsewhere; ``REPRO_SCALE_BACKEND`` override) — the server-side hot
    path of ``core.protocol.aggregate`` at model scale.
    """
    from repro.kernels import ops
    norms = jnp.linalg.norm(G, axis=-1)
    scales = cgc_scales(norms, f)
    out = ops.scale_rows(G, scales)
    return out.astype(jnp.result_type(G.dtype, scales.dtype))


def cgc_aggregate(G: jax.Array, f: int) -> jax.Array:
    """Filtered *sum* g^t = sum_j CGC(g_j) (paper line 44).

    Dispatches through ``kernels.ops.cgc_fused_aggregate``: on TPU the
    whole round (norms, threshold, clip, reduce) is ONE streaming Pallas
    launch with no host round-trip; elsewhere the jnp backend is bitwise
    ``sum(cgc_filter(G, f))`` (``REPRO_CGC_BACKEND`` override).
    """
    from repro.kernels import ops
    agg, _, _ = ops.cgc_fused_aggregate(G, f)
    return agg


def cgc_aggregate_known_bad(G: jax.Array, f: int,
                            bad: jax.Array) -> jax.Array:
    """CGC aggregate with *known*-Byzantine rows excluded from the clip
    order statistic.

    ``bad`` marks workers the server has already ruled out — timed out
    (never received) or provably detected. Their rows of ``G`` are zero,
    and counting those zero norms in the (n-f)-th-smallest statistic is
    wrong: at the n = f + 1 edge (every Byzantine worker crashed) the
    threshold collapses to 0 and the lone honest gradient is silently
    scaled to nothing — training stalls while every value stays finite.

    The fix maps known-bad norms to +inf before the sort. With k bad
    rows the (n-f)-th smallest of {finite norms} ∪ {inf}^k is exactly
    the (n'-f')-th smallest of the n' = n-k live norms with
    f' = f - k — CGC on the reduced set with the residual fault budget.
    Once k > f the threshold is +inf: no clipping (the filter has no
    guarantee left; degrading to the plain sum of live gradients beats
    zeroing them). With no bad rows a ``lax.cond`` takes the untouched
    :func:`cgc_aggregate` branch, so clean rounds keep the fused-kernel
    path and its exact values.
    """
    from repro.kernels import ops
    n = G.shape[0]
    if not 0 <= f < n:
        raise ValueError(f"need 0 <= f < n, got f={f}, n={n}")

    def masked(G_):
        norms = jnp.linalg.norm(G_, axis=-1)
        thr = jnp.sort(jnp.where(bad, jnp.inf, norms))[n - f - 1]
        # thr = +inf (more bad rows than f) makes every ratio +inf and
        # min(1, .) keeps every scale finite at 1: plain sum, no NaNs.
        scales = jnp.minimum(1.0, thr / jnp.maximum(norms, 1e-12))
        out = ops.scale_rows(G_, scales)
        return jnp.sum(
            out.astype(jnp.result_type(G_.dtype, scales.dtype)), axis=0)

    def clean(G_):
        return cgc_aggregate(G_, f)

    return jax.lax.cond(jnp.any(bad), masked, clean, G)
