"""Strongly-convex cost functions with known (L, mu) for paper validation.

The paper's convergence theory (Sec. 4) is parameterised by the Lipschitz
constant L (Assumption 2), the strong-convexity constant mu (Assumption 3),
and the relative gradient-noise bound sigma (Assumption 5):

    E||g - grad Q(w)||^2 <= sigma^2 ||grad Q(w)||^2.

Each cost here exposes exact (or tightly-bounded) L, mu and a stochastic
gradient oracle whose noise is *relative* so Assumption 5 holds by
construction (quadratic) or is measurable (least-squares / logistic).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CostFn:
    """A strongly-convex objective with a stochastic-gradient oracle.

    Attributes:
      value:       w -> Q(w)
      grad:        w -> exact gradient of Q at w
      stoch_grad:  (key, w) -> one stochastic gradient sample (Assumption 4/5)
      w_star:      argmin Q
      L, mu:       smoothness / strong-convexity constants
      sigma:       relative noise bound of stoch_grad (Assumption 5)
      d:           dimension
    """

    value: Callable[[jax.Array], jax.Array]
    grad: Callable[[jax.Array], jax.Array]
    stoch_grad: Callable[[jax.Array, jax.Array], jax.Array]
    w_star: jax.Array
    L: float
    mu: float
    sigma: float
    d: int


def quadratic(
    key: jax.Array,
    d: int,
    mu: float = 1.0,
    L: float = 1.0,
    sigma: float = 0.1,
) -> CostFn:
    """Q(w) = 1/2 (w - w*)^T H (w - w*) with spec(H) in [mu, L].

    The stochastic oracle returns ``grad * (1 + sigma * u)`` with u a
    unit-variance isotropic perturbation, so Assumption 5 holds with equality
    in expectation: E||g - grad||^2 = sigma^2 ||grad||^2 and E g = grad
    (Assumption 4).
    """
    k_eig, k_rot, k_star = jax.random.split(key, 3)
    # Eigenvalues in [mu, L] with both endpoints hit exactly.
    if d >= 2:
        inner = jax.random.uniform(k_eig, (d - 2,), minval=mu, maxval=L)
        eigs = jnp.concatenate([jnp.array([mu, L]), inner])
    else:
        eigs = jnp.array([L])
    # Random rotation via QR of a Gaussian matrix.
    Qm, _ = jnp.linalg.qr(jax.random.normal(k_rot, (d, d)))
    H = (Qm * eigs) @ Qm.T
    w_star = jax.random.normal(k_star, (d,))

    def value(w):
        dw = w - w_star
        return 0.5 * dw @ H @ dw

    def grad(w):
        return H @ (w - w_star)

    def stoch_grad(key, w):
        g = grad(w)
        # Isotropic relative noise: u = N(0, I)/sqrt(d) has E||u||^2 = 1, so
        # E||sigma*||g||*u||^2 = sigma^2 ||g||^2 — Assumption 5 with equality
        # (and E g_j = grad Q, Assumption 4).
        u = jax.random.normal(key, (d,)) / jnp.sqrt(d)
        return g + sigma * jnp.linalg.norm(g) * u
    return CostFn(value, grad, stoch_grad, w_star, float(L), float(mu),
                  float(sigma), d)


def least_squares(
    key: jax.Array,
    n_data: int,
    d: int,
    batch: int = 8,
    noise: float = 0.0,
    l2: float = 0.0,
) -> CostFn:
    """Q(w) = 1/(2N) ||X w - y||^2 + l2/2 ||w||^2 over a fixed synthetic set.

    The stochastic oracle samples a random mini-batch (the paper's "random
    data batch xi_j^t from the dataset shared by all workers"). sigma is
    estimated empirically at w0 and reported; L = lam_max(X^T X)/N + l2,
    mu = lam_min(X^T X)/N + l2.
    """
    kx, ky, kw = jax.random.split(key, 3)
    X = jax.random.normal(kx, (n_data, d))
    w_true = jax.random.normal(kw, (d,))
    y = X @ w_true + noise * jax.random.normal(ky, (n_data,))

    H = X.T @ X / n_data + l2 * jnp.eye(d)
    eigs = jnp.linalg.eigvalsh(H)
    L = float(eigs[-1])
    mu = float(eigs[0])
    # Closed-form optimum.
    w_star = jnp.linalg.solve(H, X.T @ y / n_data)

    def value(w):
        r = X @ w - y
        return 0.5 * jnp.mean(r ** 2) + 0.5 * l2 * w @ w

    def grad(w):
        return X.T @ (X @ w - y) / n_data + l2 * w

    def stoch_grad(key, w):
        idx = jax.random.randint(key, (batch,), 0, n_data)
        Xb, yb = X[idx], y[idx]
        return Xb.T @ (Xb @ w - yb) / batch + l2 * w

    # Empirical sigma at a reference point (relative noise, Assumption 5).
    k0, keval = jax.random.split(key)
    w0 = jax.random.normal(k0, (d,))
    g0 = grad(w0)
    keys = jax.random.split(keval, 256)
    gs = jax.vmap(lambda k: stoch_grad(k, w0))(keys)
    sigma = float(jnp.sqrt(jnp.mean(jnp.sum((gs - g0) ** 2, -1))
                           / (g0 @ g0)))
    return CostFn(value, grad, stoch_grad, w_star, L, mu, sigma, d)


def logistic_l2(
    key: jax.Array,
    n_data: int,
    d: int,
    batch: int = 16,
    l2: float = 0.1,
    margin: float = 1.0,
) -> CostFn:
    """L2-regularised logistic regression (mu = l2, L = lam_max/4 + l2).

    Strongly convex thanks to the ridge term; w* found by Newton iterations.
    """
    kx, kw = jax.random.split(key)
    X = jax.random.normal(kx, (n_data, d))
    w_true = margin * jax.random.normal(kw, (d,)) / jnp.sqrt(d)
    p = jax.nn.sigmoid(X @ w_true)
    y = (jax.random.uniform(jax.random.fold_in(key, 7), (n_data,)) < p
         ).astype(jnp.float32)

    XtX = X.T @ X / n_data
    L = float(jnp.linalg.eigvalsh(XtX)[-1] / 4.0 + l2)
    mu = float(l2)

    def value(w):
        z = X @ w
        return jnp.mean(jnp.logaddexp(0.0, z) - y * z) + 0.5 * l2 * w @ w

    def grad(w):
        z = X @ w
        return X.T @ (jax.nn.sigmoid(z) - y) / n_data + l2 * w

    def stoch_grad(key, w):
        idx = jax.random.randint(key, (batch,), 0, n_data)
        Xb, yb = X[idx], y[idx]
        z = Xb @ w
        return Xb.T @ (jax.nn.sigmoid(z) - yb) / batch + l2 * w

    # Newton's method for w*.
    def newton_step(w, _):
        z = X @ w
        s = jax.nn.sigmoid(z)
        Hn = (X.T * (s * (1 - s))) @ X / n_data + l2 * jnp.eye(d)
        w = w - jnp.linalg.solve(Hn, grad(w))
        return w, None

    w_star, _ = jax.lax.scan(newton_step, jnp.zeros(d), None, length=50)

    # Empirical sigma at w0 = 0.
    keys = jax.random.split(jax.random.fold_in(key, 11), 256)
    g0 = grad(jnp.zeros(d))
    gs = jax.vmap(lambda k: stoch_grad(k, jnp.zeros(d)))(keys)
    sigma = float(jnp.sqrt(jnp.mean(jnp.sum((gs - g0) ** 2, -1)) / (g0 @ g0)))
    return CostFn(value, grad, stoch_grad, w_star, L, mu, sigma, d)
