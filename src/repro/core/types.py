"""Core datatypes for the Echo-CGC protocol.

Everything is a pytree of fixed-shape jnp arrays so the whole round is
jittable. The radio network is simulated with dense buffers + masks:

- gradients are stored row-major ``(n, d)``;
- the overheard raw-gradient set ``R`` is the same ``(n, d)`` buffer with a
  boolean column mask (a worker's view is a prefix of the slot order);
- messages are tagged unions encoded by ``kind`` flags.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

# Message kinds + the paper's float width now live with the wire formats
# (repro.comm.wire) and are re-exported here for the protocol buffers.
from repro.comm.wire import (BITS_PER_FLOAT, FP32, MSG_ECHO,  # noqa: F401
                             MSG_RAW, MSG_SILENT)


class RoundMessages(NamedTuple):
    """Everything broadcast during one communication phase (n slots)."""

    kind: jax.Array          # (n,) int32 in {MSG_RAW, MSG_ECHO, MSG_SILENT}
    raw: jax.Array           # (n, d) raw gradient per slot (valid iff kind==RAW)
    echo_k: jax.Array        # (n,)   norm ratio ||g||/||Ax||  (valid iff ECHO)
    echo_x: jax.Array        # (n, n) projection coefficients, masked by echo_ref
    echo_ref: jax.Array      # (n, n) bool, echo_ref[j, i] = echo of j references worker i


class ServerState(NamedTuple):
    """Parameter-server view after the communication phase."""

    G: jax.Array             # (n, d) reconstructed gradients (0 for detected Byz)
    received: jax.Array      # (n,) bool, server heard slot j
    detected: jax.Array      # (n,) bool, provably Byzantine (bad echo reference)


class RoundStats(NamedTuple):
    """Per-round accounting used for the paper's communication analysis."""

    bits_sent: jax.Array         # (n,) bits transmitted by each worker
    echo_sent: jax.Array         # (n,) bool, worker echoed instead of raw
    n_echo: jax.Array            # () int32, number of echo messages
    n_detected: jax.Array        # () int32, Byzantine workers caught by server
    rank_R: jax.Array            # () int32, final size of the reference set
    n_faded: Any = None          # () int32, slots the channel faded this round
                                 # (None from pre-channel call sites)


class ProtocolConfig(NamedTuple):
    """Static protocol parameters (hashable; safe as jit static arg)."""

    n: int                   # number of workers
    f: int                   # max tolerable Byzantine workers
    r: float                 # deviation ratio (Eq. 7)
    eta: float               # step size
    indep_tol: float = 1e-6  # relative residual below which a raw gradient is
                             # considered linearly dependent (App. D test)
    ridge: float = 1e-8      # Tikhonov term for the Gram solve (numerical MP-inverse)


def raw_bits(d: int) -> int:
    """Bits to broadcast a raw gradient: d floats (paper Sec. 2.1).

    Delegates to the ideal fp32 codec — ``repro.comm.wire`` owns the
    wire-format bit accounting; this closed form is the fp32 special
    case kept for the paper-facing call sites.
    """
    return FP32.raw_msg_bits(d)


def echo_bits(n: int, rank: jax.Array | int) -> jax.Array | int:
    """Bits for an echo message ``(k, x, I)``.

    One float for the norm ratio, ``|R|`` floats for the coefficients, and an
    n-bit membership bitmap for the sorted ID list ``I`` (an upper bound on
    any practical encoding of I; O(n) total as in the paper). Delegates to
    the ideal fp32 codec in ``repro.comm.wire``.
    """
    return FP32.echo_msg_bits(n, rank)
