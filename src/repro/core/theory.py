"""Closed-form theory of the paper (Sec. 4): convergence and communication.

Implements, as plain functions of the paper's constants:

  - k_x = 1 + (x-1)/sqrt(2x-1)                                   (Eq. 10)
  - k*  = sup_{x>=1} k_x / sqrt(x)  ~= 1.12                      (Lemma 2)
  - beta  (Eq. 9),  alpha_x (Eq. 12),  gamma (Eq. 11)
  - rho(eta) = 1 - 2 beta eta + gamma eta^2                      (Eq. 13)
  - r_max bounds: Lemma 3 (k_n sigma form) and Lemma 4 (k* form)
  - eta* = beta/gamma, valid range eta in (0, 2 beta/gamma)      (Thm 5)
  - p = 1 - (1 + 2/r)^2 sigma^2  (echo-probability lower bound)
  - C(sigma, x, mu/L, n)                                          (Eq. 29)
  - x_max = (mu/L) / (3 + sigma k* sqrt(n))  (max resilience, Sec. 4.3)
  - expected-bits model and ratio vs prior algorithms

These are used (a) to pick valid (r, eta) in the protocol, (b) to reproduce
Figure 1a-d numerically, and (c) as test oracles for measured behaviour.
"""
from __future__ import annotations

import numpy as np

# ---------------------------------------------------------------------------
# Lemma 2: k_x and k*
# ---------------------------------------------------------------------------


def k_x(x: np.ndarray | float) -> np.ndarray | float:
    """Eq. 10 — Gumbel/Hartley-David extreme-order-statistic constant."""
    x = np.asarray(x, dtype=np.float64)
    return 1.0 + (x - 1.0) / np.sqrt(2.0 * x - 1.0)


def k_star(grid: int = 2_000_001, x_hi: float = 50.0) -> float:
    """k* = sup_{x>=1} k_x/sqrt(x) ~= 1.12, attained near x ~= 1.91.

    The ratio -> 1/sqrt(2) as x -> inf and equals 1 at x=1, so a fine grid on
    [1, x_hi] brackets the supremum comfortably.
    """
    xs = np.linspace(1.0, x_hi, grid)
    return float(np.max(k_x(xs) / np.sqrt(xs)))


K_STAR = 1.1157  # cached k_star() (sup at x ~= 1.91); Lemma 2 states ~= 1.12


# ---------------------------------------------------------------------------
# Eqs. 9, 11, 12, 13 — beta, alpha, gamma, rho
# ---------------------------------------------------------------------------


def alpha_x(x: float, sigma: float, h: float) -> float:
    """Eq. 12: alpha_x = x sigma^2 + (1 + k_h sigma)^2."""
    return x * sigma ** 2 + (1.0 + k_x(h) * sigma) ** 2


def beta(n: int, f: int, h: int, b: int, L: float, mu: float, r: float,
         sigma: float) -> float:
    """Eq. 9: beta = (n-2f)(mu - r(1+sigma)L)/(1+r) - b(1 + k_h sigma)L."""
    return ((n - 2 * f) * (mu - r * (1.0 + sigma) * L) / (1.0 + r)
            - b * (1.0 + k_x(h) * sigma) * L)


def gamma(n: int, h: int, b: int, L: float, sigma: float) -> float:
    """Eq. 11: gamma = n L^2 (h (1 + sigma^2) + b alpha_h)."""
    return n * L ** 2 * (h * (1.0 + sigma ** 2) + b * alpha_x(h, sigma, h))


def rho(eta: float, beta_v: float, gamma_v: float) -> float:
    """Eq. 13: rho = 1 - 2 beta eta + gamma eta^2."""
    return 1.0 - 2.0 * beta_v * eta + gamma_v * eta ** 2


def eta_star(beta_v: float, gamma_v: float) -> float:
    """Thm 5: minimiser eta* = beta/gamma; any eta in (0, 2 eta*) gives
    rho in [rho(eta*), 1)."""
    return beta_v / gamma_v


# ---------------------------------------------------------------------------
# Lemmas 3 & 4 — admissible deviation ratio r
# ---------------------------------------------------------------------------


def r_max_lemma3(n: int, f: int, L: float, mu: float, sigma: float) -> float:
    """Eq. 14 (strict upper bound; positive iff n mu - (3 + k_n sigma) f L > 0)."""
    kn = k_x(n)
    num = n * mu - (3.0 + kn * sigma) * f * L
    den = (n - 2 * f) * (1.0 + sigma) * L + (1.0 + kn * sigma) * f * L
    return num / den


def r_max_lemma4(n: int, f: int, L: float, mu: float, sigma: float) -> float:
    """Eq. 15 (uses k* under Assumption 6, sigma < 1/sqrt(n))."""
    num = n * mu - (3.0 + K_STAR) * f * L
    den = (n - 2 * f) * (1.0 + sigma) * L + (1.0 + K_STAR) * f * L
    return num / den


def resilience_condition(n: int, f: int, L: float, mu: float) -> bool:
    """Thm 9 hypothesis: n mu - (3 + k*) f L > 0."""
    return n * mu - (3.0 + K_STAR) * f * L > 0


def pick_r_eta(n: int, f: int, L: float, mu: float, sigma: float,
               r_frac: float = 0.5, eta_frac: float = 1.0
               ) -> tuple[float, float, float, float, float]:
    """Choose admissible (r, eta) per Thm 9 and return (r, eta, beta, gamma, rho).

    r = r_frac * r_max(Lemma 4); eta = eta_frac * eta* (eta* = beta/gamma).
    Raises if the resilience condition fails.
    """
    if not resilience_condition(n, f, L, mu):
        raise ValueError(
            f"resilience violated: n*mu={n * mu:.4g} <= "
            f"(3+k*)*f*L={(3 + K_STAR) * f * L:.4g}")
    r = r_frac * r_max_lemma4(n, f, L, mu, sigma)
    # Worst case h = n - f, b = f (proof uses h >= n-f, b <= f).
    h, b = n - f, f
    b_v = beta(n, f, h, b, L, mu, r, sigma)
    g_v = gamma(n, h, b, L, sigma)
    eta = eta_frac * eta_star(b_v, g_v)
    return r, eta, b_v, g_v, rho(eta, b_v, g_v)


# ---------------------------------------------------------------------------
# Sec. 4.3 — communication complexity
# ---------------------------------------------------------------------------


def echo_probability(r: float, sigma: float) -> float:
    """p = 1 - (1 + 2/r)^2 sigma^2 — lower bound on Pr(g in ball B)."""
    return 1.0 - (1.0 + 2.0 / r) ** 2 * sigma ** 2


def comm_ratio_C(sigma: float, x: float, mu_over_L: float, n: int
                 ) -> float:
    """Eq. 29: upper bound on (Echo-CGC bits) / (prior-algorithm bits).

    Uses the Lemma-3 style bound with k_n sigma ~= sigma k* sqrt(n), exactly
    as plotted in Figure 1. Returns +inf outside the admissible region
    mu/L - (3 + sigma k* sqrt(n)) x > 0.
    """
    s_kn = sigma * K_STAR * np.sqrt(n)
    den = mu_over_L - (3.0 + s_kn) * x
    if np.ndim(den) == 0:
        if den <= 0:
            return float("inf")
    num = (1.0 - 2.0 * x) * (1.0 + sigma) + (1.0 + s_kn) * x
    r = den / num
    return float(sigma ** 2 * (1.0 + 2.0 / r) ** 2)


def x_max(sigma: float, mu_over_L: float, n: int) -> float:
    """Maximum resilience x_max = (mu/L) / (3 + sigma k* sqrt(n)) (Fig. 1c)."""
    return mu_over_L / (3.0 + sigma * K_STAR * np.sqrt(n))


def expected_bits_per_round(n: int, d: int, p: float,
                            bits_per_float: int = 32) -> float:
    """Expected worker->server bits per round under echo probability p.

    E[n*] >= n p - 1 echo senders (Sec. 4.3); echoes cost O(n) bits
    (n+1 floats + n-bit ID bitmap), raws cost d floats.
    """
    n_echo = max(n * p - 1.0, 0.0)
    echo_cost = bits_per_float * (n + 1) + n
    raw_cost = bits_per_float * d
    return n_echo * echo_cost + (n - n_echo) * raw_cost


def prior_bits_per_round(n: int, d: int, bits_per_float: int = 32) -> float:
    """Prior algorithms [4, 11]: n raw gradients per round."""
    return float(n) * bits_per_float * d
