"""repro.dist — sharding rules, mesh context, and robust DP collectives.

The layer between the pure model/aggregation math and the launch stack:

    rules  (sharding.py)    logical axis names -> PartitionSpecs
    ctx    (context.py)     ShardCtx: mesh + worker axes + TP axis
    comms  (collectives.py) CGC/Krum/median as shard_map collectives
    moe    (moe_sharding.py) tensor- and expert-parallel MoE
    fsdp   (fsdp.py)        param sharding + blockwise-CGC reduce-scatter
    echo   (echo_dp.py)     coefficient-space optimistic aggregation
    compat (compat.py)      jax version shims (AbstractMesh, shard_map)

Importing the package installs the jax compat shims (idempotent).
"""
from . import compat as _compat

_compat.install()

from .compat import abstract_mesh, mesh_axis_sizes               # noqa: E402
from .context import ShardCtx, make_shard_ctx                     # noqa: E402
from .sharding import (DEFAULT_RULES, EP_RULES, Rule, spec_for,   # noqa: E402
                       tree_shardings, tree_specs)
from .collectives import (AGG_FNS, aggregate_pytree_cgc,          # noqa: E402
                          aggregate_pytree_cgc_sum,
                          aggregate_pytree_mean, inject_byzantine,
                          worker_index)
from .moe_sharding import moe_sharded                             # noqa: E402
from . import collectives, echo_dp, fsdp                          # noqa: E402

__all__ = [
    "AGG_FNS", "DEFAULT_RULES", "EP_RULES", "Rule", "ShardCtx",
    "abstract_mesh", "aggregate_pytree_cgc", "aggregate_pytree_cgc_sum",
    "aggregate_pytree_mean", "collectives", "echo_dp", "fsdp",
    "inject_byzantine", "make_shard_ctx", "mesh_axis_sizes", "moe_sharded",
    "spec_for", "tree_shardings", "tree_specs", "worker_index",
]
