"""jax version compatibility for the distribution layer.

The repo targets the modern jax surface (``jax.shard_map`` with
``axis_names``/``check_vma``, ``jax.set_mesh``, positional
``AbstractMesh(sizes, names)``). The pinned toolchain ships jax 0.4.37,
where those spell differently:

  * ``shard_map`` lives in ``jax.experimental.shard_map`` and takes
    ``check_rep`` / ``auto`` instead of ``check_vma`` / ``axis_names``;
  * partial-manual mode (``auto``) raises NotImplementedError, so
    ``axis_names`` degrades to a fully-manual shard_map over the whole
    mesh — unnamed axes are simply never referenced by the specs, which
    is equivalent for replicated-over-model programs (the CPU test
    topologies) but forgoes compiler-driven tensor parallelism inside
    the region;
  * ``AbstractMesh`` takes a single ``((name, size), ...)`` tuple;
  * there is no mesh context manager under ``jax.set_mesh``.

``install()`` (called on ``repro.dist`` import) adds the missing modern
names onto the ``jax`` namespace so library code and test snippets can be
written against one API. On a jax that already has them it is a no-op.
"""
from __future__ import annotations

import contextlib
import functools
from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import AbstractMesh


def abstract_mesh(axis_sizes: Tuple[int, ...],
                  axis_names: Tuple[str, ...]) -> AbstractMesh:
    """``AbstractMesh(sizes, names)`` on every supported jax version."""
    try:
        return AbstractMesh(tuple(axis_sizes), tuple(axis_names))
    except TypeError:
        return AbstractMesh(tuple(zip(axis_names, axis_sizes)))


def mesh_axis_sizes(mesh) -> Dict[str, int]:
    """{axis name: size} for Mesh and AbstractMesh alike."""
    shape = getattr(mesh, "shape", None)
    if isinstance(shape, dict):
        return dict(shape)
    return dict(zip(mesh.axis_names, mesh.axis_sizes))


def _compat_shard_map(f=None, *, mesh=None, in_specs=None, out_specs=None,
                      axis_names=None, check_vma=None, check_rep=None,
                      auto=None):
    """``jax.shard_map``-alike on jax 0.4.37 (see module docstring)."""
    from jax.experimental.shard_map import shard_map as _sm

    if f is None:                                    # curried usage
        return functools.partial(
            _compat_shard_map, mesh=mesh, in_specs=in_specs,
            out_specs=out_specs, axis_names=axis_names,
            check_vma=check_vma, check_rep=check_rep, auto=auto)
    check = True
    if check_vma is not None:
        check = check_vma
    if check_rep is not None:
        check = check_rep
    # ``axis_names``/``auto`` request partial-manual mode; 0.4.37's ``auto``
    # is not implemented, so run fully manual: axes outside ``axis_names``
    # are untouched by the specs and stay effectively replicated.
    del axis_names, auto
    return _sm(f, mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check)


@contextlib.contextmanager
def _compat_set_mesh(mesh):
    """``jax.set_mesh``-alike: enter the physical mesh context if possible.

    Every shard_map / NamedSharding in this repo names its mesh explicitly,
    so on old jax the default-mesh context only needs to not interfere.
    """
    if hasattr(mesh, "__enter__"):
        with mesh:
            yield mesh
    else:                                            # AbstractMesh
        yield mesh


def install() -> None:
    """Add modern aliases onto the jax namespace when missing (idempotent)."""
    if not hasattr(jax, "shard_map"):
        jax.shard_map = _compat_shard_map
    if not hasattr(jax, "set_mesh"):
        jax.set_mesh = _compat_set_mesh


def partial_manual_supported() -> bool:
    """True when jax.shard_map honors ``axis_names`` (partial-manual mode).

    The 0.4.37 shim degrades to fully-manual, so shard_maps cannot nest
    — callers that need a nested region (EP inside the worker shard_map)
    should fail fast when this is False.
    """
    return getattr(jax, "shard_map", None) is not _compat_shard_map
