"""Distributed MoE: tensor-parallel and expert-parallel shard_map wrappers.

``moe_sharded(p, cfg, x_flat, ctx)`` is what ``models/moe.moe_forward``
dispatches to when a ShardCtx with a mesh is supplied. Two layouts:

  * ``tp`` — every device holds all experts with a 1/M slice of the
    expert hidden dim (DEFAULT_RULES: "mlp" -> model axis). Routing and
    dispatch run locally on each data shard's tokens (the fp32 router is
    replicated, so all model shards agree); expert matmuls produce
    partial outputs that one psum over the model axis completes. Robust
    default: no divisibility constraint on the expert count.
  * ``ep`` — experts themselves are sharded over the model axis
    (EP_RULES: "expert" -> model, full d_ff per expert). Each shard
    dispatches its local tokens into per-expert capacity buffers, an
    all_to_all ships each buffer to the owning shard, experts run on the
    union of all shards' tokens, and a second all_to_all returns the
    outputs to the tokens' home shards.

Both run as one fully-manual shard_map over the mesh. On jax 0.4.37
(no partial-manual shard_map) this means ``moe_impl="ep"`` cannot be
nested inside the trainer's worker shard_map; the direct (pjit-level)
entry points — serving, prefill, and the dist tests — are unaffected.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import nn
from .compat import install, mesh_axis_sizes
from .context import ShardCtx

install()


def _batch_spec(ctx: ShardCtx):
    if not ctx.batch_axes:
        return None
    return ctx.batch_axes if len(ctx.batch_axes) > 1 else ctx.batch_axes[0]


def _pmean_stats(stats, ctx: ShardCtx):
    if not ctx.batch_axes:
        return stats
    return jax.tree.map(lambda s: jax.lax.pmean(s, ctx.batch_axes), stats)


def _weight_specs(p: Dict[str, Any], expert_entry, mlp_gate_entry,
                  mlp_down_entry) -> Dict[str, Any]:
    """in_specs tree for the MoE param dict (router fp32 stays replicated)."""
    specs = jax.tree.map(lambda _: P(), p)
    specs["w_gate"] = P(expert_entry, None, mlp_gate_entry)
    specs["w_up"] = P(expert_entry, None, mlp_gate_entry)
    specs["w_down"] = P(expert_entry, mlp_down_entry, None)
    return specs


def moe_sharded(p, cfg: ModelConfig, x_flat: jax.Array, ctx: ShardCtx
                ) -> Tuple[jax.Array, Any]:
    """Distributed MoE on flattened tokens (T, D); see module docstring."""
    if ctx.moe_impl == "ep":
        return _moe_ep(p, cfg, x_flat, ctx)
    if ctx.moe_impl == "tp":
        return _moe_tp(p, cfg, x_flat, ctx)
    if ctx.moe_impl == "local":
        from repro.models.moe import moe_local
        return moe_local(p, cfg, x_flat)
    raise ValueError(f"unknown moe_impl {ctx.moe_impl!r}; "
                     f"known: 'tp', 'ep', 'local'")


# ---------------------------------------------------------------------------
# Tensor-parallel experts (d_ff sliced over the model axis)
# ---------------------------------------------------------------------------


def _moe_tp(p, cfg: ModelConfig, x: jax.Array, ctx: ShardCtx):
    from repro.models.moe import moe_local

    m_ax = ctx.model_axis
    sizes = mesh_axis_sizes(ctx.mesh)
    M = sizes.get(m_ax, 1) if m_ax else 1
    if M > 1 and cfg.moe_d_ff % M:
        raise ValueError(f"tp MoE needs moe_d_ff % model axis == 0 "
                         f"(moe_d_ff={cfg.moe_d_ff}, model={M})")
    bspec = _batch_spec(ctx)

    def fn(p_sh, x_loc):
        y, stats = moe_local(p_sh, cfg, x_loc)
        if m_ax and M > 1:
            y = jax.lax.psum(y, m_ax)
        return y, _pmean_stats(stats, ctx)

    in_specs = (_weight_specs(p, None, m_ax if M > 1 else None,
                              m_ax if M > 1 else None),
                P(bspec, None))
    out_specs = (P(bspec, None), jax.tree.map(lambda _: P(), _abs_stats()))
    sm = jax.shard_map(fn, mesh=ctx.mesh, in_specs=in_specs,
                       out_specs=out_specs, check_vma=False)
    return sm(p, x)


# ---------------------------------------------------------------------------
# Expert parallelism (experts sharded, all_to_all token exchange)
# ---------------------------------------------------------------------------


def _moe_ep(p, cfg: ModelConfig, x: jax.Array, ctx: ShardCtx):
    from repro.models.moe import (MoEStats, dispatch_indices,
                                  load_balance_loss, router_topk)

    m_ax = ctx.model_axis
    sizes = mesh_axis_sizes(ctx.mesh)
    M = sizes.get(m_ax, 1) if m_ax else 1
    E, k = cfg.num_experts, cfg.top_k
    if M > 1 and E % M:
        raise ValueError(f"ep MoE needs num_experts % model axis == 0 "
                         f"(experts={E}, model={M})")
    E_loc = E // M
    bspec = _batch_spec(ctx)

    def fn(p_sh, x_loc):
        # p_sh: full router, (E_loc, D, F) expert slabs
        T, D = x_loc.shape
        C = int(max(8, round(T * k / E * cfg.capacity_factor)))

        logits = x_loc.astype(jnp.float32) @ p_sh["router"]
        top_w, top_i, probs = router_topk(logits, k)
        aux = load_balance_loss(probs, top_i, E)

        st, se, pos, keep, order = dispatch_indices(top_i, C, E)
        flat_w = top_w.reshape(-1)[order]
        idx = jnp.where(keep, se * C + pos, E * C)
        buf = jnp.zeros((E * C + 1, D), x_loc.dtype).at[idx].set(x_loc[st])
        buf = buf[:-1].reshape(E, C, D)

        if M > 1:
            # ship each expert's buffer to its owner shard; receive the
            # buffers every shard built for *my* experts.
            recv = jax.lax.all_to_all(buf, m_ax, split_axis=0,
                                      concat_axis=0, tiled=True)
        else:
            recv = buf
        # rows of recv: (source shard, local expert) -> regroup per expert
        xe = recv.reshape(M, E_loc, C, D).transpose(1, 0, 2, 3)
        xe = xe.reshape(E_loc, M * C, D)

        wg = p_sh["w_gate"].astype(x_loc.dtype)
        wu = p_sh["w_up"].astype(x_loc.dtype)
        wd = p_sh["w_down"].astype(x_loc.dtype)
        g = jnp.einsum("ecd,edf->ecf", xe, wg)
        u = jnp.einsum("ecd,edf->ecf", xe, wu)
        h = nn.swiglu(g, u)
        out = jnp.einsum("ecf,efd->ecd", h, wd)           # (E_loc, M*C, D)

        back = out.reshape(E_loc, M, C, D).transpose(1, 0, 2, 3)
        back = back.reshape(E, C, D)
        if M > 1:
            out_buf = jax.lax.all_to_all(back, m_ax, split_axis=0,
                                         concat_axis=0, tiled=True)
        else:
            out_buf = back
        # rows back in global-expert order (owner-major == expert id)
        out_flat = out_buf.reshape(E * C, D)
        y_copies = jnp.where(
            keep[:, None], out_flat[jnp.where(keep, se * C + pos, 0)], 0.0)
        y_copies = y_copies * flat_w[:, None].astype(x_loc.dtype)
        y = jnp.zeros((T, D), x_loc.dtype).at[st].add(y_copies)

        dropped = 1.0 - jnp.sum(keep.astype(jnp.float32)) / (T * k)
        stats = MoEStats(aux_loss=aux, dropped_frac=dropped)
        return y, _pmean_stats(stats, ctx)

    in_specs = (_weight_specs(p, m_ax if M > 1 else None, None, None),
                P(bspec, None))
    out_specs = (P(bspec, None), jax.tree.map(lambda _: P(), _abs_stats()))
    sm = jax.shard_map(fn, mesh=ctx.mesh, in_specs=in_specs,
                       out_specs=out_specs, check_vma=False)
    return sm(p, x)


def _abs_stats():
    from repro.models.moe import MoEStats
    return MoEStats(aux_loss=0, dropped_frac=0)
