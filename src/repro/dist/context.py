"""ShardCtx: the one object the model/launch layers carry around.

It names the mesh, which axes are the data-parallel "worker" axes (each
data shard is one Byzantine-fault-containment unit, paper Sec. 2), which
axis is tensor-parallel, and which MoE implementation to use. It is a
frozen dataclass so call sites can ``dataclasses.replace`` it (the tests
flip ``moe_impl`` that way) and so it hashes as a jit-static closure.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

from .compat import mesh_axis_sizes

DATA_AXES_ORDER = ("pod", "data")    # leading axis is the pod-level DP axis


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    """Mesh context threaded through model forward / train / serve code."""

    mesh: Any = None
    batch_axes: Tuple[str, ...] = ()     # manual worker axes (DP)
    model_axis: Optional[str] = "model"  # TP axis (None: no model axis)
    moe_impl: str = "tp"                 # "tp" | "ep" | "local"
    remat: str = "full"                  # "full" | "save_psum"
    layer_gather: Optional[Callable] = None   # FSDP just-in-time gather
    global_batch: int = 0

    @property
    def num_workers(self) -> int:
        """Product of the data-axis sizes (1 without a mesh)."""
        if self.mesh is None or not self.batch_axes:
            return 1
        sizes = mesh_axis_sizes(self.mesh)
        n = 1
        for ax in self.batch_axes:
            n *= sizes[ax]
        return n


def make_shard_ctx(mesh, global_batch: int, moe_impl: str = "tp"
                   ) -> ShardCtx:
    """Build the ShardCtx for ``mesh``: data axes = pod+data, model = TP."""
    if mesh is None:
        return ShardCtx(mesh=None, batch_axes=(), model_axis=None,
                        moe_impl=moe_impl, global_batch=global_batch)
    sizes = mesh_axis_sizes(mesh)
    batch_axes = tuple(a for a in DATA_AXES_ORDER if a in sizes)
    n_workers = 1
    for a in batch_axes:
        n_workers *= sizes[a]
    if batch_axes and global_batch % n_workers:
        raise ValueError(
            f"global_batch={global_batch} must divide over the "
            f"{n_workers} data-parallel workers of axes {batch_axes}")
    model_axis = "model" if "model" in sizes else None
    return ShardCtx(mesh=mesh, batch_axes=batch_axes, model_axis=model_axis,
                    moe_impl=moe_impl, global_batch=global_batch)
