"""FSDP over the data axes with blockwise-CGC gradient reduction.

Parameters (and, mirrored by the trainer, optimizer state) are sharded
over the data-parallel worker axes along one planned dimension per leaf.
Inside the worker shard_map each leaf is all-gathered just in time for
the forward; the gather's custom VJP is where Byzantine robustness
happens: the full-size cotangent each worker produces for a leaf is its
per-worker *block* gradient, so the VJP

  1. clips blockwise with the CGC filter (an n-scalar norm all-gather +
     ``cgc_scales``, exactly ``core.cgc`` semantics per block),
  2. psums the clipped blocks (the filtered sum, paper line 44), and
  3. slices this worker's shard back out (a reduce-scatter).

Per-worker full gradients therefore never materialise — the memory point
of FSDP survives the robust aggregation. Blockwise clipping is an
approximation of the replicated trainer's whole-gradient clipping; with
honest (outlier-free) workers the two agree to a few 1e-4
(tests/test_dist.py::test_fsdp_matches_replicated_trainer).

Leaves too small to be worth sharding (< ``MIN_FSDP_ELEMS`` elements, a
module global so tests can lower it) stay replicated and are aggregated
exactly by ``aggregate_rest_cgc``.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.cgc import cgc_scales
from repro.models.nn import Param
from .collectives import _gather_scalar, tree_norm, worker_index
from .compat import mesh_axis_sizes

MIN_FSDP_ELEMS = 1 << 16        # below this a leaf stays replicated

# Logical axes the TP layout may claim (DEFAULT_RULES targets the model
# axis for them) — the FSDP plan must not collide with those dims.
_MODEL_LOGICAL = {"mlp", "heads", "kv_heads", "vocab", "expert"}


def _is_param(x) -> bool:
    return isinstance(x, Param)


def _dp_total(mesh, dp_axes: Sequence[str]) -> int:
    sizes = mesh_axis_sizes(mesh)
    n = 1
    for a in dp_axes:
        n *= sizes[a]
    return n


def plan_fsdp(params: Any, mesh, dp_axes: Sequence[str] = ("data",)):
    """Param tree -> matching tree of shard-dimension indices (or None).

    Picks, per leaf, the largest dimension that (a) is not the scanned
    "layers" axis, (b) is not a dim the TP rules map to the model axis,
    and (c) divides by the total data-parallel width. Small leaves
    (< MIN_FSDP_ELEMS) are never planned.
    """
    dp = _dp_total(mesh, dp_axes)

    def choose(p: Param) -> Optional[int]:
        shape = tuple(p.value.shape)
        n_elems = 1
        for s in shape:
            n_elems *= int(s)
        if n_elems < MIN_FSDP_ELEMS:
            return None
        best, best_size = None, 0
        for d, (sz, name) in enumerate(zip(shape, p.axes)):
            if name == "layers" or name in _MODEL_LOGICAL:
                continue
            if sz % dp or sz <= best_size:
                continue
            best, best_size = d, sz
        return best

    return jax.tree.map(choose, params, is_leaf=_is_param)


# ---------------------------------------------------------------------------
# Spec / sharding trees for the planned layout
# ---------------------------------------------------------------------------


def _spec_for_plan(shape_len: int, d: Optional[int],
                   dp_axes: Sequence[str]) -> P:
    if d is None:
        return P()
    entry = dp_axes[0] if len(dp_axes) == 1 else tuple(dp_axes)
    entries = [None] * shape_len
    entries[d] = entry
    return P(*entries)


def _map_with_plan(fn: Callable, params: Any, plan: Any):
    """tree-map ``fn(param, plan_leaf)`` where plan leaves may be None."""
    p_leaves, treedef = jax.tree.flatten(params, is_leaf=_is_param)
    d_leaves = jax.tree.flatten(plan, is_leaf=lambda x: x is None)[0]
    assert len(p_leaves) == len(d_leaves), (len(p_leaves), len(d_leaves))
    return jax.tree.unflatten(treedef,
                              [fn(p, d) for p, d in zip(p_leaves, d_leaves)])


def fsdp_manual_specs(params: Any, plan: Any,
                      dp_axes: Sequence[str]) -> Any:
    """PartitionSpec tree (Param positions -> P) for the worker shard_map."""
    return _map_with_plan(
        lambda p, d: _spec_for_plan(len(p.value.shape), d, dp_axes),
        params, plan)


def fsdp_tree_shardings(params: Any, mesh, plan: Any,
                        dp_axes: Sequence[str] = ("data",)) -> Any:
    """NamedSharding tree for placing params/opt-state in the FSDP layout."""
    return _map_with_plan(
        lambda p, d: NamedSharding(
            mesh, _spec_for_plan(len(p.value.shape), d, dp_axes)),
        params, plan)


# ---------------------------------------------------------------------------
# Just-in-time gather with the blockwise-CGC reduce-scatter VJP
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3, 4))
def _gather_leaves(leaves: Tuple[jax.Array, ...],
                   dims: Tuple[Optional[int], ...], axes: Tuple[str, ...],
                   f: int, use_cgc: bool) -> Tuple[jax.Array, ...]:
    """Gather every planned leaf of one block (unplanned pass through).

    One custom_vjp over the whole block (the top-level params, or one
    layer of the scan) so the backward clips all of the block's leaves
    with a *joint* CGC scale — the per-worker norm is taken over the
    block's concatenated gradient, the closest locally-computable proxy
    for the replicated trainer's whole-gradient norm.
    """
    return tuple(
        v if d is None else jax.lax.all_gather(v, axes, axis=d, tiled=True)
        for v, d in zip(leaves, dims))


def _gather_leaves_fwd(leaves, dims, axes, f, use_cgc):
    return _gather_leaves(leaves, dims, axes, f, use_cgc), None


def _gather_leaves_bwd(dims, axes, f, use_cgc, _res, cts):
    n = int(jax.lax.psum(1, axes))
    wid = worker_index(axes)
    planned = [ct for ct, d in zip(cts, dims) if d is not None]
    if use_cgc and planned:
        # cts are this worker's full-size block gradients: clip blockwise
        # with one joint scale (CGC filter on the block norms).
        norms = _gather_scalar(tree_norm(planned), axes)
        scale = cgc_scales(norms, f)[wid]
    else:
        scale = None
    out = []
    for ct, d in zip(cts, dims):
        if d is None:                   # unplanned: stays a local gradient
            out.append(ct)
            continue
        if use_cgc:
            total = jax.lax.psum(ct * scale.astype(ct.dtype), axes)
        else:
            total = jax.lax.psum(ct, axes) / n
        blk = total.shape[d] // n
        out.append(jax.lax.dynamic_slice_in_dim(total, wid * blk, blk, d))
    return (tuple(out),)


_gather_leaves.defvjp(_gather_leaves_fwd, _gather_leaves_bwd)


def make_gather_fn(plan: Any, dp_axes: Sequence[str], f: int, use_cgc: bool,
                   strip_layer_dim: bool = False) -> Callable:
    """Build gather(values_subtree) for a plan subtree.

    ``strip_layer_dim`` adjusts planned dims for use inside the layer
    scan, where the leading "layers" axis has been peeled off.
    """
    axes = tuple(dp_axes)
    d_leaves = jax.tree.flatten(plan, is_leaf=lambda x: x is None)[0]

    def gather(values):
        v_leaves, treedef = jax.tree.flatten(values)
        assert len(v_leaves) == len(d_leaves), \
            (len(v_leaves), len(d_leaves))
        dims = tuple(None if d is None else d - int(strip_layer_dim)
                     for d in d_leaves)
        out = _gather_leaves(tuple(v_leaves), dims, axes, f, use_cgc)
        return jax.tree.unflatten(treedef, list(out))

    return gather


def aggregate_rest_cgc(grads: Any, plan: Any, dp_axes: Sequence[str],
                       f: int, use_cgc: bool = True) -> Any:
    """Aggregate the replicated (un-planned) remainder leaves exactly.

    Planned leaves pass through untouched — their aggregation already
    happened in the gather VJP's blockwise reduce-scatter. ``use_cgc``
    must match the gather fns so both leaf classes use the same scale
    convention: CGC filtered sum, or the plain mean.
    """
    axes = tuple(dp_axes)
    g_leaves, treedef = jax.tree.flatten(grads)
    d_leaves = jax.tree.flatten(plan, is_leaf=lambda x: x is None)[0]
    assert len(g_leaves) == len(d_leaves), (len(g_leaves), len(d_leaves))
    rest = [g for g, d in zip(g_leaves, d_leaves) if d is None]
    if rest and use_cgc:
        norms = _gather_scalar(tree_norm(rest), axes)
        scale = cgc_scales(norms, f)[worker_index(axes)]
        rest = iter([jax.lax.psum(g * scale.astype(g.dtype), axes)
                     for g in rest])
    elif rest:
        rest = iter([jax.lax.pmean(g, axes) for g in rest])
    else:
        rest = iter(())
    out = [g if d is not None else next(rest)
           for g, d in zip(g_leaves, d_leaves)]
    return jax.tree.unflatten(treedef, out)


def clip_fsdp_global_norm(grads: Any, plan: Any, dp_axes: Sequence[str],
                          max_norm: float) -> Tuple[Any, jax.Array]:
    """Global-norm clip aware of the FSDP layout.

    Planned leaves are disjoint per-worker shards (their squared norms
    psum to the true global contribution); unplanned leaves are
    replicated (counted once). Every worker derives the same scale, so
    replicated state stays in sync.
    """
    axes = tuple(dp_axes)
    g_leaves, _ = jax.tree.flatten(grads)
    d_leaves = jax.tree.flatten(plan, is_leaf=lambda x: x is None)[0]
    assert len(g_leaves) == len(d_leaves), (len(g_leaves), len(d_leaves))
    f32 = jnp.float32
    shard_sq = sum((jnp.sum(jnp.square(g.astype(f32)))
                    for g, d in zip(g_leaves, d_leaves) if d is not None),
                   jnp.zeros((), f32))
    rest_sq = sum((jnp.sum(jnp.square(g.astype(f32)))
                   for g, d in zip(g_leaves, d_leaves) if d is None),
                  jnp.zeros((), f32))
    norm = jnp.sqrt(jax.lax.psum(shard_sq, axes) + rest_sq)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(f32) * scale).astype(g.dtype),
                        grads), norm
