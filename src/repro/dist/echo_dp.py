"""Echo-compressed data-parallel aggregation (the paper's idea at scale).

The radio-network insight of Echo-CGC — a worker whose gradient is close
to the span of previously-heard gradients broadcasts O(n) coefficients
instead of O(d) raw values — maps onto DP training as an *optimistic
fast path*. The trainer keeps ``K`` reference pytrees (the last K round
aggregates, replicated on every worker). Each round every worker:

  1. projects its gradient onto span(basis) using the precomputed K x K
     Gram matrix (one K-vector of tree-dots, one K x K solve — no
     d-sized collective anywhere),
  2. checks the paper's Eq. 7 condition ||g - Bx|| <= r ||g||,
  3. all-gathers only the (K,) coefficient vectors and its gradient norm.

If *all* workers pass the echo test (``all_echo``), CGC runs entirely in
coefficient space: reconstructed gradients are k_j * B x_j with the norm
ratio k_j = ||g_j|| / ||B x_j|| (paper line 39), their norms are the
gathered ||g_j||, and the filtered sum is B @ (sum_j s_j k_j x_j) — each
worker rebuilds it locally from the shared basis. Per-round collective
traffic drops from O(d) to O(n*K + n).

When any worker fails the test the round's metrics flag all_echo=False
and the driver (``repro.launch.engine.Trainer``) re-runs the standard
full-gradient CGC step, then rolls the basis with the returned raw
aggregate (``roll_basis``). Successful echo rounds leave the basis
unchanged by default — the reconstructed aggregate lies in span(basis)
and adds no information, mirroring the paper's reference set R, which
only ever contains overheard RAW gradients (``TrainerConfig.roll_policy``
flips this to roll every round).
"""
from __future__ import annotations

from typing import Any, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.cgc import cgc_scales
from .collectives import _gather_scalar, tree_norm, worker_index

F32 = jnp.float32
_RIDGE = 1e-6


def round_comm_bits(codec, n: int, d: int, k: int, all_echo: bool,
                    attempted: bool = True) -> int:
    """Bits one echo-DP driver round costs under ``codec``.

    An attempted optimistic round has every worker broadcast an echo over
    the k-reference basis (:func:`repro.comm.echo_round_bits`); when the
    round is invalid (or was never attempted — e.g. a metered channel
    refused it) every worker retransmits its raw gradient on top. The
    driver reports this into the shared :class:`repro.comm.CommLedger`.
    """
    from repro.comm import echo_round_bits, raw_round_bits
    bits = echo_round_bits(codec, n, k) if attempted else 0
    if not (attempted and all_echo):
        bits += raw_round_bits(codec, n, d)
    return bits


def init_basis(values: Any, k: int) -> List[Any]:
    """K zero reference pytrees shaped like the gradient (f32)."""
    zero = jax.tree.map(lambda v: jnp.zeros(v.shape, F32), values)
    return [zero for _ in range(k)]


def roll_basis(basis: List[Any], aggregate: Any) -> List[Any]:
    """Drop the oldest reference, append this round's aggregate."""
    newest = jax.tree.map(lambda a: a.astype(F32), aggregate)
    return list(basis[1:]) + [newest]


def tree_vdot(a: Any, b: Any) -> jax.Array:
    """<a, b> over all leaves (fp32)."""
    return sum(jnp.vdot(x.astype(F32), y.astype(F32))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def basis_gram(basis: Sequence[Any]) -> jax.Array:
    """(K, K) Gram matrix of the reference pytrees."""
    k = len(basis)
    rows = []
    for i in range(k):
        rows.append(jnp.stack([tree_vdot(basis[i], basis[j])
                               for j in range(k)]))
    return jnp.stack(rows)


def _ridged(gram: jax.Array) -> jax.Array:
    scale = jnp.maximum(jnp.max(jnp.abs(jnp.diag(gram))), 1.0)
    return gram + _RIDGE * scale * jnp.eye(gram.shape[0], dtype=gram.dtype)


def echo_dp_aggregate(grads: Any, basis: Sequence[Any], gram: jax.Array,
                      axes: Sequence[str], f: int, r: float,
                      codec=None, ef=None
                      ) -> Tuple[Any, jax.Array, Dict[str, jax.Array]]:
    """Coefficient-space CGC over the worker axes.

    Returns (aggregate, all_echo, diags); the aggregate is only valid
    when ``all_echo`` is True (the driver falls back otherwise).

    ``codec`` (a :class:`repro.comm.Codec`, or None for the lossless
    default) is applied to each worker's transmitted coefficient vector:
    the all-gather carries the codec's reconstruction, so a quantized
    wire format degrades the shared aggregate exactly as it would on the
    air. The Eq. 7 test stays sender-local on the exact projection.

    ``ef`` (a replicated ``(n, K)`` array, or None) carries per-worker
    error-feedback residuals (``comm.policy.feedback``): each worker
    adds its row before encoding its coefficients and keeps what the
    codec lost. The updated residuals ride back gathered under
    ``diags["ef_state"]`` — the driver commits them only when this
    round's transmission is actually used (echo valid, no fades), so a
    discarded optimistic attempt never corrupts the carried state.
    """
    axes = tuple(axes)
    K = len(basis)
    # Projection of my gradient onto span(basis): x = (B^T B)^-1 B^T g.
    b = jnp.stack([tree_vdot(basis[i], grads) for i in range(K)])   # (K,)
    x = jnp.linalg.solve(_ridged(gram), b)                          # (K,)
    g_norm = tree_norm(grads)
    proj_sq = x @ gram @ x
    res_sq = jnp.maximum(g_norm ** 2 - 2.0 * (x @ b) + proj_sq, 0.0)
    ok = jnp.sqrt(res_sq) <= r * g_norm                    # Eq. 7

    n_ok = jax.lax.psum(ok.astype(jnp.int32), axes)
    n = int(jax.lax.psum(1, axes))
    all_echo = n_ok == n

    # O(K)-per-worker exchange: coefficients + norms only, wire-coded
    # (with error-feedback compensation when the driver threads it).
    ef_new = None
    if ef is None:
        x_wire = x if codec is None else codec.roundtrip(x)
    else:
        from repro.comm.policy.feedback import ef_compensate
        my_ef = ef[worker_index(axes)]                     # my (K,) row
        x_wire, my_ef_new = ef_compensate(codec, x, my_ef)
        if my_ef_new is None:                              # codec=None
            my_ef_new = my_ef
        ef_new = jax.lax.all_gather(my_ef_new.astype(F32), axes)  # (n, K)
    xs = jax.lax.all_gather(x_wire, axes)                  # (n, K)
    norms = _gather_scalar(g_norm, axes)                   # (n,)
    proj_norms = jnp.sqrt(jnp.maximum(
        jnp.einsum("nk,kl,nl->n", xs, gram, xs), 1e-30))
    k_ratio = jnp.where(proj_norms > 1e-15, norms / proj_norms, 0.0)
    scales = cgc_scales(norms, f)                          # CGC on ||g_j||
    coef = jnp.sum((scales * k_ratio)[:, None] * xs, axis=0)   # (K,)
    agg = jax.tree.map(
        lambda *leaves: sum(c * l.astype(F32)
                            for c, l in zip(coef, leaves)),
        *basis)
    diags = {
        "echo_frac": n_ok.astype(F32) / n,
        "echo_residual_ratio": jax.lax.pmean(
            jnp.sqrt(res_sq) / jnp.maximum(g_norm, 1e-30), axes),
    }
    if ef_new is not None:
        diags["ef_state"] = ef_new
        diags["ef_residual_norm"] = jnp.max(
            jnp.linalg.norm(ef_new, axis=-1))
    return agg, all_echo, diags
