"""Name-based sharding rules: logical axis names -> mesh PartitionSpecs.

Parameters and inputs carry *logical* axis names (``models/nn.py`` Param
trees: "embed", "mlp", "batch", ...). A rule set is an ordered tuple of
``Rule(logical, mesh_axes)`` entries mapping a logical name to candidate
mesh axes; ``spec_for`` resolves one array's names into a PartitionSpec
with three semantics (exercised by ``tests/test_dist.py``):

  * **priority** — rules are applied in order, so e.g. "batch" claims the
    data axes before "kv_seq" can, and "kv_heads" beats "kv_seq" to the
    model axis;
  * **divisibility fallback** — a dimension only takes a mesh axis if its
    size is divisible by the axis (an 8-way KV-head dim on a 16-way model
    axis stays replicated and the axis remains available for later rules);
  * **no axis reuse** — each mesh axis is consumed at most once per array;
    a rule with several candidates takes every still-free, still-dividing
    axis jointly (e.g. "kv_seq" over ("data", "model") when batch=1 frees
    the data axis).

``DEFAULT_RULES`` is the dense/TP layout; ``EP_RULES`` flips MoE expert
weights to expert-parallel (experts sharded over the model axis, full
d_ff per expert).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.nn import Param
from .compat import mesh_axis_sizes


@dataclasses.dataclass(frozen=True)
class Rule:
    """One logical axis -> candidate mesh axes (tried in order)."""

    logical: str
    mesh_axes: Tuple[str, ...]


DEFAULT_RULES: Tuple[Rule, ...] = (
    Rule("batch", ("pod", "data")),
    Rule("heads", ("model",)),
    Rule("kv_heads", ("model",)),
    Rule("vocab", ("model",)),
    Rule("mlp", ("model",)),
    Rule("expert", ()),                 # replicated: TP slices d_ff instead
    Rule("kv_seq", ("data", "model")),
    # "embed", "qkv", "layers", None carry no rule -> replicated.
)

EP_RULES: Tuple[Rule, ...] = (
    Rule("batch", ("pod", "data")),
    Rule("heads", ("model",)),
    Rule("kv_heads", ("model",)),
    Rule("vocab", ("model",)),
    Rule("expert", ("model",)),         # expert-parallel: experts sharded,
    Rule("mlp", ()),                    # full d_ff kept per expert
    Rule("kv_seq", ("data", "model")),
)


def spec_for(shape: Sequence[int], names: Sequence[Optional[str]], mesh,
             rules: Tuple[Rule, ...] = DEFAULT_RULES) -> P:
    """Resolve one array's logical names into a PartitionSpec on ``mesh``."""
    assert len(shape) == len(names), (tuple(shape), tuple(names))
    sizes = mesh_axis_sizes(mesh)
    rule_for = {r.logical: (i, r) for i, r in enumerate(rules)}
    entries: list = [None] * len(shape)
    used: set = set()
    order = sorted((d for d in range(len(shape)) if names[d] in rule_for),
                   key=lambda d: (rule_for[names[d]][0], d))
    for d in order:
        _, rule = rule_for[names[d]]
        chosen = []
        prod = 1
        for ax in rule.mesh_axes:
            if ax not in sizes or ax in used:
                continue
            if shape[d] % (prod * sizes[ax]) == 0:
                chosen.append(ax)
                prod *= sizes[ax]
        if chosen:
            used.update(chosen)
            entries[d] = chosen[0] if len(chosen) == 1 else tuple(chosen)
    return P(*entries)


def _is_param(x) -> bool:
    return isinstance(x, Param)


def tree_specs(tree, mesh, rules: Optional[Tuple[Rule, ...]] = None):
    """Param tree -> matching tree of PartitionSpecs (leaves at Params)."""
    rules = DEFAULT_RULES if rules is None else rules
    return jax.tree.map(
        lambda p: spec_for(p.value.shape, p.axes, mesh, rules), tree,
        is_leaf=_is_param)


def tree_shardings(tree, mesh, rules: Optional[Tuple[Rule, ...]] = None):
    """Param tree -> matching tree of NamedShardings on ``mesh``."""
    rules = DEFAULT_RULES if rules is None else rules
    return jax.tree.map(
        lambda p: NamedSharding(
            mesh, spec_for(p.value.shape, p.axes, mesh, rules)),
        tree, is_leaf=_is_param)
