"""Byzantine-robust aggregation as shard_map collectives.

``core/aggregators.py`` defines every aggregator on an explicit (n, d)
gradient table. Inside a data-parallel shard_map no such table exists —
each worker holds only its own full-size gradient pytree — so this module
re-derives the same math from collectives over the worker axes:

  * **CGC** (Gupta-Vaidya filter, the paper's aggregation) needs only the
    per-worker gradient *norms*: an n-scalar all-gather, a shared
    ``cgc_scales`` computation, and one psum of the locally-scaled
    gradients. The (n, d) table is never materialised — this is the
    communication pattern that scales CGC to real model sizes.
  * **median / trimmed-mean** are coordinate-wise: leaf-by-leaf
    all-gathers (transient n-times-leaf buffers, never the concatenated
    table) followed by the per-coordinate reduction.
  * **Krum** accumulates the pairwise squared-distance matrix leaf by
    leaf, scores like ``core.aggregators.krum``, then psum-selects the
    winner's gradient.

``AGG_FNS[name](grads, axes, f) -> (aggregate, diags)`` follows the
``core.aggregators.AGGREGATORS`` scale conventions exactly: "cgc" is the
filtered *sum* (paper line 44), everything else is mean-scale — the CPU
test asserts ``AGG_FNS["cgc"]`` matches ``core.aggregators.cgc_sum`` on
the gathered table to ~1e-5 (reduction order differs, so not bitwise).

The norm hot path (``tree_norm``, feeding every CGC/echo/FSDP
aggregation here) dispatches through ``kernels.ops.tree_sq_norm`` to the
fused Pallas streaming pass on TPU (DESIGN.md §5).
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.cgc import cgc_scales, cgc_threshold
from repro.run.registry import COLLECTIVE_AGGREGATORS

F32 = jnp.float32


# ---------------------------------------------------------------------------
# Worker identity
# ---------------------------------------------------------------------------


def axis_sizes(axes: Sequence[str]) -> Tuple[int, ...]:
    """Static sizes of manual mesh axes (psum of a literal constant-folds)."""
    return tuple(jax.lax.psum(1, ax) for ax in axes)


def worker_index(axes: Sequence[str]) -> jax.Array:
    """Linear worker id over ``axes`` (row-major, matching all_gather)."""
    sizes = axis_sizes(axes)
    wid = jnp.zeros((), jnp.int32)
    for ax, sz in zip(axes, sizes):
        wid = wid * sz + jax.lax.axis_index(ax)
    return wid


def num_workers(axes: Sequence[str]) -> int:
    return int(jax.lax.psum(1, tuple(axes)))


def _gather_scalar(x: jax.Array, axes: Sequence[str]) -> jax.Array:
    """All workers' values of a scalar -> (n,) in worker-index order."""
    return jax.lax.all_gather(x.astype(F32), tuple(axes))


def _gather_leaf(x: jax.Array, axes: Sequence[str]) -> jax.Array:
    """All workers' values of one leaf -> (n, *leaf shape)."""
    return jax.lax.all_gather(x, tuple(axes))


# ---------------------------------------------------------------------------
# Byzantine injection (testing / resilience experiments)
# ---------------------------------------------------------------------------

_BYZ_SCALE = {"sign_flip": 1.0, "large_norm": 100.0, "zero": 0.0,
              "little_is_enough": 0.33}


def inject_byzantine(grads, wid: jax.Array, n_byz: int, mode: str,
                     scale: float = None):
    """Overwrite the gradients of workers ``wid < n_byz`` with an attack.

    Mirrors ``core.byzantine``: "sign_flip" sends -scale*g (classic
    descent reversal), "large_norm" sends -scale*g with a huge scale
    (what CGC's norm clipping neutralises), "zero" crashes silently,
    "little_is_enough" reverses with a sub-unit scale (Baruch et al.) —
    deliberately small enough that norm clipping never fires on it.
    """
    if mode not in _BYZ_SCALE:
        raise ValueError(f"unknown byzantine mode {mode!r}; "
                         f"known: {sorted(_BYZ_SCALE)}")
    s = _BYZ_SCALE[mode] if scale is None else scale
    is_byz = wid < n_byz
    factor = jnp.where(is_byz, jnp.float32(-s if mode != "zero" else 0.0),
                       1.0)
    return jax.tree.map(lambda g: g * factor.astype(g.dtype), grads)


# ---------------------------------------------------------------------------
# Norm-only CGC (the scalable path)
# ---------------------------------------------------------------------------


def tree_norm(grads) -> jax.Array:
    """Global L2 norm of a gradient pytree (fp32 accumulation).

    The sum of squares dispatches through ``kernels.ops.tree_sq_norm``
    — on TPU that is the fused Pallas streaming pass
    (``cgc_clip.row_sq_norms``) instead of a per-leaf jnp reduction
    chain, so every CGC/echo/FSDP norm in this module rides the kernel
    (backend switch: ``kernels.ops.set_norm_backend`` /
    ``REPRO_NORM_BACKEND``).
    """
    from repro.kernels.ops import tree_sq_norm
    return jnp.sqrt(tree_sq_norm(grads))


@COLLECTIVE_AGGREGATORS.register("cgc")
def aggregate_pytree_cgc_sum(grads, axes: Sequence[str], f: int):
    """CGC filtered *sum* over the worker axes (== cgc_sum on the table).

    One scalar all-gather (the norms) + one psum of the scaled gradients;
    gradients themselves are never gathered.
    """
    axes = tuple(axes)
    norms = _gather_scalar(tree_norm(grads), axes)        # (n,)
    scales = cgc_scales(norms, f)
    mine = scales[worker_index(axes)]
    agg = jax.tree.map(
        lambda g: jax.lax.psum(g * mine.astype(g.dtype), axes), grads)
    diags = {
        "cgc_threshold": cgc_threshold(norms, f),
        "cgc_clipped_frac": jnp.mean((scales < 1.0 - 1e-6).astype(F32)),
        "grad_norm_mean": jnp.mean(norms),
    }
    return agg, diags


@COLLECTIVE_AGGREGATORS.register("cgc_mean")
def aggregate_pytree_cgc(grads, axes: Sequence[str], f: int):
    """CGC filter + *mean* (scale-compatible with the other pytree fns)."""
    axes = tuple(axes)
    n = num_workers(axes)
    agg, diags = aggregate_pytree_cgc_sum(grads, axes, f)
    return jax.tree.map(lambda g: g / n, agg), diags


@COLLECTIVE_AGGREGATORS.register("mean")
def aggregate_pytree_mean(grads, axes: Sequence[str], f: int = 0):
    """Fault-intolerant baseline: plain pmean over the worker axes."""
    axes = tuple(axes)
    return jax.tree.map(lambda g: jax.lax.pmean(g, axes), grads), {}


# ---------------------------------------------------------------------------
# Table-based aggregators (leaf-wise gathers, no concatenated table)
# ---------------------------------------------------------------------------


@COLLECTIVE_AGGREGATORS.register("median")
def aggregate_pytree_median(grads, axes: Sequence[str], f: int = 0):
    """Coordinate-wise median across workers, leaf by leaf."""
    axes = tuple(axes)
    agg = jax.tree.map(
        lambda g: jnp.median(_gather_leaf(g.astype(F32), axes), axis=0
                             ).astype(g.dtype), grads)
    return agg, {}


@COLLECTIVE_AGGREGATORS.register("trimmed_mean")
def aggregate_pytree_trimmed_mean(grads, axes: Sequence[str], f: int):
    """Coordinate-wise f-trimmed mean across workers (needs n > 2f)."""
    axes = tuple(axes)
    n = num_workers(axes)
    if n <= 2 * f:
        raise ValueError(f"trimmed_mean needs n > 2f (n={n}, f={f})")

    def trim(g):
        table = jnp.sort(_gather_leaf(g.astype(F32), axes), axis=0)
        kept = table[f:n - f] if f > 0 else table
        return jnp.mean(kept, axis=0).astype(g.dtype)

    return jax.tree.map(trim, grads), {}


@COLLECTIVE_AGGREGATORS.register("krum")
def aggregate_pytree_krum(grads, axes: Sequence[str], f: int):
    """Krum (Blanchard et al.): leafwise pairwise distances -> winner psum."""
    axes = tuple(axes)
    n = num_workers(axes)
    sq = jnp.zeros((n, n), F32)
    for g in jax.tree.leaves(grads):
        t = _gather_leaf(g.astype(F32), axes).reshape(n, -1)
        # ||ti - tj||^2 via the Gram matrix: no (n, n, d) intermediate.
        gram = t @ t.T
        sn = jnp.diag(gram)
        sq = sq + jnp.maximum(sn[:, None] + sn[None, :] - 2.0 * gram, 0.0)
    sq = sq + jnp.diag(jnp.full((n,), jnp.inf))
    k = max(n - f - 2, 1)
    scores = jnp.sum(jnp.sort(sq, axis=1)[:, :k], axis=1)
    winner = jnp.argmin(scores)
    mine = (worker_index(axes) == winner)
    agg = jax.tree.map(
        lambda g: jax.lax.psum(g * mine.astype(g.dtype), axes), grads)
    return agg, {"krum_score_min": jnp.min(scores)}


# The shared plugin registry (repro.run.registry): a new distributed
# aggregator is one @COLLECTIVE_AGGREGATORS.register("name") function
# with the (grads, axes, f) -> (aggregate, diags) signature above.
AGG_FNS = COLLECTIVE_AGGREGATORS
