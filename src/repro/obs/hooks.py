"""Step/round/request hook registration.

The Trainer and ServeEngine accept a ``hooks`` object and fire it at
the protocol-relevant moments; :class:`TrackerHook` is the stock
implementation that forwards those moments to the active tracker as
events + counters. Everything is a no-op by default so engines can
call hooks unconditionally.
"""
from __future__ import annotations

from typing import Any, Dict, Iterable, Mapping, Optional

from . import context as obs


class Hooks:
    """No-op base. Subclass and override what you care about."""

    # --- training rounds -------------------------------------------------
    def on_round_start(self, step: int) -> None:
        pass

    def on_round_end(self, step: int, record: Mapping[str, Any]) -> None:
        pass

    # --- serve request lifecycle -----------------------------------------
    def on_admit(self, req: Any) -> None:
        pass

    def on_preempt(self, req: Any) -> None:
        pass

    def on_finish(self, req: Any) -> None:
        pass

    # --- generic per-step ------------------------------------------------
    def on_step(self, record: Mapping[str, Any]) -> None:
        pass


class HookList(Hooks):
    """Fans every callback out to a list of hooks, in order."""

    def __init__(self, hooks: Iterable[Hooks]):
        self.hooks = list(hooks)

    def on_round_start(self, step: int) -> None:
        for h in self.hooks:
            h.on_round_start(step)

    def on_round_end(self, step: int, record: Mapping[str, Any]) -> None:
        for h in self.hooks:
            h.on_round_end(step, record)

    def on_admit(self, req: Any) -> None:
        for h in self.hooks:
            h.on_admit(req)

    def on_preempt(self, req: Any) -> None:
        for h in self.hooks:
            h.on_preempt(req)

    def on_finish(self, req: Any) -> None:
        for h in self.hooks:
            h.on_finish(req)

    def on_step(self, record: Mapping[str, Any]) -> None:
        for h in self.hooks:
            h.on_step(record)


# Round-record fields worth echoing into the event stream; the full
# record already lands in metrics.jsonl, so the event stays compact.
_ROUND_FIELDS = ("loss", "all_echo", "echoed", "bits", "bits_cumulative")


class TrackerHook(Hooks):
    """Forwards engine lifecycle moments to the active tracker."""

    def on_round_start(self, step: int) -> None:
        obs.counter("train.rounds")

    def on_round_end(self, step: int, record: Mapping[str, Any]) -> None:
        if not obs.tracing():
            return
        fields: Dict[str, Any] = {"step": step}
        for k in _ROUND_FIELDS:
            if k in record:
                fields[k] = record[k]
        obs.event("train.round", **fields)

    def on_admit(self, req: Any) -> None:
        obs.counter("serve.admitted")
        obs.event("serve.admit", rid=getattr(req, "rid", None))

    def on_preempt(self, req: Any) -> None:
        obs.counter("serve.preempted")
        obs.event("serve.preempt", rid=getattr(req, "rid", None))

    def on_finish(self, req: Any) -> None:
        obs.counter("serve.finished")
        obs.event("serve.finish", rid=getattr(req, "rid", None),
                  generated=len(getattr(req, "generated", ()) or ()))


def as_hooks(hooks: "Hooks | Iterable[Hooks] | None") -> Hooks:
    """Normalise a hooks argument: None → no-op, iterable → HookList."""
    if hooks is None:
        return Hooks()
    if isinstance(hooks, Hooks):
        return hooks
    return HookList(hooks)
