"""Background line writer shared by every jsonl-emitting sink.

``MetricsSink`` (launch/engine.py) and :class:`~repro.obs.tracker.
JsonlTracker` both stream newline-terminated records to disk off the
driver hot loop. This module owns that machinery ONCE, with the same
error contract as ``checkpoint.AsyncCheckpointWriter``:

* ``write`` enqueues and returns immediately; one daemon thread drains
  the queue to the file (flushing whenever it catches up).
* writer-thread exceptions are never swallowed: the first one is stored
  and re-raised (wrapped) by the next ``flush()`` or ``close()`` call —
  the contract the checkpoint writer already had, now shared.
* an atexit hook closes every live writer, so a run that crashes out of
  its driver loop (an exception propagating past the Trainer) still
  lands its tail records before the interpreter kills daemon threads.

Import-light on purpose: stdlib only.
"""
from __future__ import annotations

import atexit
import os
import queue
import threading
import weakref
from typing import Optional

# Every open writer, weakly held: the atexit sweep flushes what is still
# alive at interpreter shutdown without keeping closed writers pinned.
_LIVE: "weakref.WeakSet" = weakref.WeakSet()
_ATEXIT_REGISTERED = False


def _close_live_writers() -> None:
    """atexit: drain every still-open writer, never raising (the run is
    already going down; the tail records matter more than the error)."""
    for w in list(_LIVE):
        try:
            w.close(reraise=False)
        except Exception:
            pass


class AsyncLineWriter:
    """Non-blocking append of text lines to one file.

    ``write(line)`` enqueues (the line must already end in a newline);
    ``flush()`` blocks until everything enqueued so far is on disk and
    re-raises the first background write error; ``close()`` drains,
    joins the thread, closes the file and re-raises likewise. ``close``
    is idempotent.
    """

    def __init__(self, path: str, append: bool = True):
        global _ATEXIT_REGISTERED
        self.path = path
        if os.path.dirname(path):
            os.makedirs(os.path.dirname(path), exist_ok=True)
        self._fh = open(path, "a" if append else "w")
        self._q: queue.SimpleQueue = queue.SimpleQueue()
        self._error: Optional[BaseException] = None
        self._thread: Optional[threading.Thread] = threading.Thread(
            target=self._loop, name="line-writer", daemon=True)
        self._thread.start()
        if not _ATEXIT_REGISTERED:
            atexit.register(_close_live_writers)
            _ATEXIT_REGISTERED = True
        _LIVE.add(self)

    def _note(self, e: BaseException) -> None:
        if self._error is None:
            self._error = e

    def _loop(self) -> None:
        while True:
            item = self._q.get()
            if item is None:                       # close sentinel
                return
            if isinstance(item, threading.Event):  # flush barrier
                try:
                    self._fh.flush()
                except BaseException as e:
                    self._note(e)
                item.set()
                continue
            try:
                self._fh.write(item)
                if self._q.empty():
                    self._fh.flush()
            except BaseException as e:             # surfaced on flush/close
                self._note(e)

    def _raise_pending(self) -> None:
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError(
                f"background write to {self.path} failed") from err

    def write(self, line: str) -> None:
        if self._thread is None:
            raise RuntimeError(f"writer for {self.path} is closed")
        self._q.put(line)

    def flush(self, timeout: Optional[float] = None) -> bool:
        """Barrier: block until every line written so far is on disk.
        Re-raises the first background error; with a ``timeout``,
        returns False on expiry (without consuming a pending error)."""
        if self._thread is not None and self._thread.is_alive():
            barrier = threading.Event()
            self._q.put(barrier)
            if not barrier.wait(timeout):
                return False
        self._raise_pending()
        return True

    def close(self, reraise: bool = True) -> None:
        if self._thread is not None:
            self._q.put(None)
            # the thread drains everything queued before the sentinel,
            # so joining IS the flush; only then is the file closeable.
            self._thread.join()
            self._thread = None
        if self._fh is not None:
            try:
                self._fh.close()
            except BaseException as e:
                self._note(e)
            self._fh = None
        _LIVE.discard(self)
        if reraise:
            self._raise_pending()
