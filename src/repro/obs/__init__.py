"""repro.obs — the unified observability layer (DESIGN.md §12).

One measurement plane for the whole repo: trackers (pluggable sinks in
the ``TRACKERS`` registry) receive structured events, counters, and
nested wall-clock spans from instrumented code, which only ever calls
the free functions here (``span``/``counter``/``event``/``metric``)
against the process-active tracker. Engines fire :class:`Hooks` at
round/request lifecycle moments; ``report`` renders any finished run
dir's ``summary.json``.
"""
from .context import (counter, event, get_tracker, metric, set_tracker,
                      span, tracing, use_tracker)
from .hooks import HookList, Hooks, TrackerHook, as_hooks
from .report import load_run, render, report
from .tracker import (InMemoryTracker, JsonlTracker, RecordingTracker,
                      StdoutTracker, Tracker, make_tracker)
from .writer import AsyncLineWriter

__all__ = [
    "AsyncLineWriter",
    "HookList",
    "Hooks",
    "InMemoryTracker",
    "JsonlTracker",
    "RecordingTracker",
    "StdoutTracker",
    "Tracker",
    "TrackerHook",
    "as_hooks",
    "counter",
    "event",
    "get_tracker",
    "load_run",
    "make_tracker",
    "metric",
    "render",
    "report",
    "set_tracker",
    "span",
    "tracing",
    "use_tracker",
]
