"""Tracker protocol and the pluggable sinks behind it.

A tracker receives three kinds of signals from instrumented code:

* **events** — structured records (``{"kind": name, **fields}``), e.g.
  one ``comm.round`` event per protocol round with its bit accounting;
* **counters** — monotonically increasing named integers, e.g. per
  kernel-backend dispatch counts;
* **spans** — wall-clock timed sections with thread-local nesting;
  nested spans produce slash-joined paths (``serve.step/prefill``), and
  every tracker keeps a per-path ``{count, total_s}`` aggregate that
  becomes the per-subsystem timing breakdown in ``summary.json``.

Sinks live in the ``TRACKERS`` registry: ``noop`` (the default-off
tracker — shared singleton spans, near-zero overhead), ``memory``
(tests), ``jsonl`` (one JSON line per event/span via AsyncLineWriter),
``stdout``. Code under instrumentation never talks to a sink class
directly — it calls the free functions in :mod:`repro.obs.context`,
which dispatch to the active tracker (or to nothing).
"""
from __future__ import annotations

import json
import threading
import time
from typing import Any, Callable, Dict, List, Optional

from ..run.registry import TRACKERS
from .writer import AsyncLineWriter


class _NoopSpan:
    """Shared do-nothing context manager: the disabled-tracker hot path
    allocates nothing per call."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NOOP_SPAN = _NoopSpan()


class Tracker:
    """Base tracker; also the noop sink. ``enabled`` lets callers skip
    building event payloads entirely when nothing is listening."""

    enabled = False

    def event(self, kind: str, **fields: Any) -> None:
        pass

    def counter(self, name: str, n: int = 1) -> None:
        pass

    def metric(self, name: str, value: float) -> None:
        pass

    def span(self, name: str):
        return _NOOP_SPAN

    def snapshot(self) -> Dict[str, Any]:
        return {"counters": {}, "metrics": {}, "spans": {}}

    def flush(self) -> None:
        pass

    def close(self) -> None:
        pass


class _Span:
    """One live timed section; re-entrant per tracker via the
    thread-local span stack (nesting = slash-joined path)."""

    __slots__ = ("tracker", "name", "path", "t0")

    def __init__(self, tracker: "RecordingTracker", name: str):
        self.tracker = tracker
        self.name = name
        self.path = name
        self.t0 = 0.0

    def __enter__(self):
        stack = self.tracker._stack()
        if stack:
            self.path = stack[-1] + "/" + self.name
        stack.append(self.path)
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        dt = time.perf_counter() - self.t0
        stack = self.tracker._stack()
        if stack and stack[-1] == self.path:
            stack.pop()
        self.tracker._record_span(self.path, dt)
        return False


class RecordingTracker(Tracker):
    """Shared aggregation machinery: counter/metric/span bookkeeping is
    identical across sinks; subclasses only decide where each record
    line goes via ``_emit``."""

    enabled = True

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._local = threading.local()
        self.counters: Dict[str, int] = {}
        self.metrics: Dict[str, float] = {}
        # span path -> [count, total seconds]
        self._spans: Dict[str, List[float]] = {}

    def _stack(self) -> List[str]:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        return stack

    def _emit(self, rec: Dict[str, Any]) -> None:
        pass

    def event(self, kind: str, **fields: Any) -> None:
        self._emit({"kind": kind, **fields})

    def counter(self, name: str, n: int = 1) -> None:
        with self._lock:
            self.counters[name] = self.counters.get(name, 0) + n

    def metric(self, name: str, value: float) -> None:
        with self._lock:
            self.metrics[name] = float(value)

    def span(self, name: str):
        return _Span(self, name)

    def _record_span(self, path: str, dt: float) -> None:
        with self._lock:
            cell = self._spans.get(path)
            if cell is None:
                cell = self._spans[path] = [0, 0.0]
            cell[0] += 1
            cell[1] += dt
        self._emit({"kind": "span", "path": path, "dt_s": dt})

    def snapshot(self) -> Dict[str, Any]:
        with self._lock:
            return {
                "counters": dict(self.counters),
                "metrics": dict(self.metrics),
                "spans": {
                    path: {"count": int(c), "total_s": t}
                    for path, (c, t) in sorted(self._spans.items())
                },
            }


class InMemoryTracker(RecordingTracker):
    """Keeps every emitted record in ``self.events`` — the test sink."""

    def __init__(self) -> None:
        super().__init__()
        self.events: List[Dict[str, Any]] = []

    def _emit(self, rec: Dict[str, Any]) -> None:
        with self._lock:
            self.events.append(rec)


class JsonlTracker(RecordingTracker):
    """Streams one JSON line per event/span to ``path`` off-thread."""

    def __init__(self, path: str) -> None:
        super().__init__()
        self.path = path
        self._writer = AsyncLineWriter(path)

    def _emit(self, rec: Dict[str, Any]) -> None:
        self._writer.write(json.dumps(rec) + "\n")

    def flush(self) -> None:
        self._writer.flush()

    def close(self) -> None:
        self._writer.close()


class StdoutTracker(RecordingTracker):
    """Prints each record — debugging sink (``--set obs.tracker=stdout``)."""

    def __init__(self, printer: Optional[Callable[[str], None]] = None) -> None:
        super().__init__()
        self._print = printer if printer is not None else print

    def _emit(self, rec: Dict[str, Any]) -> None:
        self._print("[obs] " + json.dumps(rec))


@TRACKERS.register("noop")
def _noop_tracker(**kw: Any) -> Tracker:
    return Tracker()


@TRACKERS.register("memory")
def _memory_tracker(**kw: Any) -> Tracker:
    return InMemoryTracker()


@TRACKERS.register("jsonl")
def _jsonl_tracker(*, path: Optional[str] = None, **kw: Any) -> Tracker:
    if path is None:
        raise ValueError("jsonl tracker requires a path (obs.events_path)")
    return JsonlTracker(path)


@TRACKERS.register("stdout")
def _stdout_tracker(*, printer: Optional[Callable[[str], None]] = None,
                    **kw: Any) -> Tracker:
    return StdoutTracker(printer)


def make_tracker(name: str, *, path: Optional[str] = None,
                 printer: Optional[Callable[[str], None]] = None) -> Tracker:
    """Build the named sink; unknown names raise the registry's
    did-you-mean KeyError."""
    return TRACKERS[name](path=path, printer=printer)
