"""``python -m repro report <run_dir>`` — render a finished run.

Reads the ``summary.json`` the facades drop at the end of every run
(``{"kind", "summary", "obs": {counters, metrics, spans}}``) plus the
run dir's ``config.json``, and prints a human-readable digest:
throughput, echo rate, bits-vs-baseline, and the per-subsystem span
breakdown. Stdlib-only so reporting never imports jax.
"""
from __future__ import annotations

import json
import os
from typing import Any, Callable, Dict, List, Optional


def load_run(run_dir: str) -> Dict[str, Any]:
    """Load ``summary.json`` (+ ``config.json`` if present); raises a
    FileNotFoundError naming what a finished run should contain."""
    path = os.path.join(run_dir, "summary.json")
    if not os.path.exists(path):
        raise FileNotFoundError(
            f"{path} not found — is {run_dir!r} a finished run dir? "
            f"(runs write summary.json on completion)")
    with open(path) as fh:
        data = json.load(fh)
    cfg_path = os.path.join(run_dir, "config.json")
    if os.path.exists(cfg_path):
        with open(cfg_path) as fh:
            data.setdefault("config", json.load(fh))
    data.setdefault("policy_events", load_policy_events(run_dir))
    data.setdefault("net_events", load_net_events(run_dir))
    return data


def _load_events(run_dir: str, prefix: str) -> List[Dict[str, Any]]:
    """Events under one kind prefix from ``events.jsonl`` (empty when
    the run had no jsonl tracker). Malformed lines — e.g. a run killed
    mid-write — are skipped, not fatal."""
    path = os.path.join(run_dir, "events.jsonl")
    if not os.path.exists(path):
        return []
    out = []
    with open(path) as fh:
        for line in fh:
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except ValueError:
                continue
            if str(rec.get("kind", "")).startswith(prefix):
                out.append(rec)
    return out


def load_policy_events(run_dir: str) -> List[Dict[str, Any]]:
    """The run's ``comm.policy.*`` events."""
    return _load_events(run_dir, "comm.policy.")


def load_net_events(run_dir: str) -> List[Dict[str, Any]]:
    """The run's ``net.*`` events (topology / relay channel / reliable
    broadcast digests from ``repro.net``)."""
    return _load_events(run_dir, "net.")


def _fmt_s(t: float) -> str:
    if t >= 1.0:
        return f"{t:.2f}s"
    if t >= 1e-3:
        return f"{t * 1e3:.2f}ms"
    return f"{t * 1e6:.0f}us"


def _pct(x: float) -> str:
    return f"{100.0 * x:.1f}%"


def _span_lines(spans: Dict[str, Dict[str, Any]]) -> List[str]:
    """The per-subsystem breakdown: every span path, indented by depth,
    with share-of-root-time, total, count and mean."""
    if not spans:
        return ["  (no spans recorded)"]
    root_total = sum(v["total_s"] for p, v in spans.items() if "/" not in p)
    lines = []
    width = max(len(p.rsplit("/", 1)[-1]) + 2 * p.count("/") for p in spans)
    for path in sorted(spans):
        v = spans[path]
        depth = path.count("/")
        name = "  " * depth + path.rsplit("/", 1)[-1]
        share = v["total_s"] / root_total if root_total > 0 else 0.0
        mean = v["total_s"] / v["count"] if v["count"] else 0.0
        lines.append(f"  {name:<{width}}  {_pct(share):>6}  "
                     f"total {_fmt_s(v['total_s']):>9}  "
                     f"n={v['count']:<6} mean {_fmt_s(mean)}")
    return lines


def _train_lines(s: Dict[str, Any]) -> List[str]:
    lines = []
    if "rounds" in s:
        lines.append(f"  rounds        {s['rounds']}"
                     + (f"  (wall {s['wall_s']}s)" if "wall_s" in s else ""))
    if s.get("rounds") and "wall_s" in s and s["wall_s"]:
        lines.append(f"  rounds/s      "
                     f"{s['rounds'] / s['wall_s']:.2f}")
    if "first_loss" in s and "final_loss" in s:
        lines.append(f"  loss          {s['first_loss']:.6g} -> "
                     f"{s['final_loss']:.6g}")
    if "echo_rate" in s:
        lines.append(f"  echo rounds   {s['echo_rounds']}/{s['rounds']} "
                     f"({_pct(s['echo_rate'])})")
    if "bits_sent" in s:
        lines.append(f"  bits sent     {s['bits_sent']:.4g} vs baseline "
                     f"{s['bits_baseline']:.4g} "
                     f"({_pct(s.get('bits_saving', 0.0))} saved)")
    return lines


def _serve_lines(s: Dict[str, Any]) -> List[str]:
    lines = []
    if "tokens_generated" in s:
        lines.append(f"  tokens        {s['tokens_generated']} in "
                     f"{s.get('wall_s', 0.0)}s "
                     f"({s.get('tokens_per_s', 0.0)} tok/s)")
    if "latency_p50_s" in s:
        lines.append(f"  latency       p50={s['latency_p50_s']}s "
                     f"p99={s['latency_p99_s']}s")
    if "ttft_p50_s" in s:
        lines.append(f"  ttft          p50={s['ttft_p50_s']}s "
                     f"p99={s['ttft_p99_s']}s "
                     f"itl p50={s.get('itl_p50_s', 0.0)}s")
    if "preemptions" in s:
        lines.append(f"  preemptions   {s['preemptions']}")
    if s.get("prefix_hit_tokens"):
        lines.append(f"  prefix cache  {_pct(s['prefix_hit_rate'])} hit "
                     f"({s['prefix_hit_tokens']} tokens adopted, "
                     f"{s.get('cow_copies', 0)} CoW copies)")
    return lines


def _comm_lines(s: Dict[str, Any],
                events: List[Dict[str, Any]]) -> List[str]:
    """The adaptive-communication digest: which policy ran, every
    decision it took, the codec mix, and cumulative bits against the
    fp32 all-raw baseline (the paper's cost unit)."""
    rounds = [e for e in events if e.get("kind") == "comm.policy.round"]
    decisions = [e for e in events
                 if e.get("kind") == "comm.policy.decision"]
    if not rounds and not decisions and "policy" not in s:
        return []
    lines = []
    policy = s.get("policy") or next(
        (e["policy"] for e in decisions + rounds if e.get("policy")), "?")
    lines.append(f"  policy        {policy}")
    if "codec_final" in s:
        lines.append(f"  final         codec={s['codec_final']} "
                     f"echo_r={s.get('echo_r_final')}")
    switches = s.get("codec_switches")
    if switches is None and rounds:
        switches = sum(1 for a, b in zip(rounds, rounds[1:])
                       if a.get("codec") != b.get("codec"))
    if switches is not None:
        lines.append(f"  codec switches {switches}")
    for e in decisions:
        lines.append(f"  decision @{e.get('step', '?'):<4} "
                     f"codec={e.get('codec')} r={e.get('echo_r')}")
    if rounds:
        tally: Dict[str, int] = {}
        for e in rounds:
            c = str(e.get("codec"))
            tally[c] = tally.get(c, 0) + 1
        lines.append("  codec rounds  "
                     + ", ".join(f"{c} x{tally[c]}" for c in sorted(tally)))
        last = rounds[-1]
        cum = last.get("bits_cumulative")
        base = last.get("fp32_baseline_cumulative")
        if cum is not None and base:
            lines.append(f"  bits          {float(cum):.4g} vs "
                         f"{float(base):.4g} fp32 all-raw "
                         f"({_pct(1.0 - float(cum) / float(base))} saved)")
    return lines


def _net_lines(events: List[Dict[str, Any]]) -> List[str]:
    """The network digest: hearing graph, relay tier, and the reliable-
    broadcast outcome (``net.*`` events from ``repro.net``)."""
    lines = []
    for e in events:
        kind = e.get("kind")
        if kind == "net.topology":
            lines.append(f"  topology      {e.get('topology')} "
                         f"(n={e.get('n')}, edges={e.get('edges')}"
                         + (", complete" if e.get("complete") else "")
                         + ")")
        elif kind == "net.channel":
            lines.append(f"  relay tier    {e.get('relays')} relays "
                         f"({e.get('byz_relays')} byzantine), "
                         f"broadcast={e.get('broadcast')}, "
                         f"{'protected' if e.get('protected') else 'UNPROTECTED'}, "
                         f"price x{e.get('price_factor')}")
        elif kind == "net.broadcast":
            lines.append(f"  broadcast     {e.get('discipline')}: "
                         f"accepted={e.get('accepted')} "
                         f"safe={e.get('safe')} "
                         f"messages={e.get('messages')}")
    return lines


def render(data: Dict[str, Any], run_dir: str = "") -> str:
    """Render a loaded run (see :func:`load_run`) to the report text."""
    kind = data.get("kind", "run")
    name = (data.get("config") or {}).get("name", "")
    obs = data.get("obs") or {}
    summary = data.get("summary") or {}

    lines = [f"== repro report: {kind}"
             + (f" '{name}'" if name else "")
             + (f" ({run_dir})" if run_dir else "") + " =="]
    body = _train_lines(summary) if kind == "train" \
        else _serve_lines(summary) if kind == "serve" else []
    if not body:   # unknown kind, or a summary with none of the keys
        body = [f"  {k:<13} {v}" for k, v in sorted(summary.items())]
    lines += body

    comm = _comm_lines(summary, data.get("policy_events") or [])
    if comm:
        lines.append("-- comm policy --")
        lines += comm

    net = _net_lines(data.get("net_events") or [])
    if net:
        lines.append("-- network --")
        lines += net

    lines.append("-- span breakdown (share of root spans) --")
    lines += _span_lines(obs.get("spans") or {})

    counters = obs.get("counters") or {}
    if counters:
        lines.append("-- counters --")
        cw = max(len(k) for k in counters)
        lines += [f"  {k:<{cw}}  {counters[k]}" for k in sorted(counters)]
    metrics = obs.get("metrics") or {}
    if metrics:
        lines.append("-- metrics --")
        mw = max(len(k) for k in metrics)
        lines += [f"  {k:<{mw}}  {metrics[k]:.6g}" for k in sorted(metrics)]
    return "\n".join(lines)


def report(run_dir: str,
           printer: Optional[Callable[[str], None]] = None) -> str:
    """Load + render + print one run dir; returns the rendered text."""
    text = render(load_run(run_dir), run_dir=run_dir)
    (printer or print)(text)
    return text
