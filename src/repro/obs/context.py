"""The active tracker and the free functions instrumented code calls.

Instrumentation sites (``Trainer.run_round``, the serve step loop, the
ledger, kernel dispatch, the checkpoint writer thread) never hold a
tracker reference — they call :func:`span` / :func:`counter` /
:func:`event` here, which dispatch to whatever tracker is currently
installed. The default is the shared noop tracker, so un-instrumented
runs (and all pre-existing call sites) pay one attribute check per
call and allocate nothing.

The active tracker is process-global rather than thread-local on
purpose: background threads (checkpoint writer, metrics writer) must
land their spans in the same breakdown as the driver loop. Span
*nesting* stays thread-local inside each tracker, so cross-thread
spans never corrupt each other's paths.
"""
from __future__ import annotations

from contextlib import contextmanager
from typing import Any

from .tracker import _NOOP_SPAN, Tracker

_NOOP = Tracker()
_ACTIVE: Tracker = _NOOP


def get_tracker() -> Tracker:
    return _ACTIVE


def set_tracker(tracker: "Tracker | None") -> Tracker:
    """Install ``tracker`` (None → noop); returns the previous one."""
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = tracker if tracker is not None else _NOOP
    return prev


@contextmanager
def use_tracker(tracker: "Tracker | None"):
    """Scope ``tracker`` as the active sink; restores on exit."""
    prev = set_tracker(tracker)
    try:
        yield tracker
    finally:
        set_tracker(prev)


def span(name: str):
    """Wall-clock timed section under the active tracker. Nesting
    slash-joins the names: ``with span("serve.step"): with
    span("prefill")`` records the path ``serve.step/prefill``."""
    if not _ACTIVE.enabled:
        return _NOOP_SPAN
    return _ACTIVE.span(name)


def counter(name: str, n: int = 1) -> None:
    if _ACTIVE.enabled:
        _ACTIVE.counter(name, n)


def metric(name: str, value: float) -> None:
    if _ACTIVE.enabled:
        _ACTIVE.metric(name, value)


def event(kind: str, **fields: Any) -> None:
    if _ACTIVE.enabled:
        _ACTIVE.event(kind, **fields)


def tracing() -> bool:
    """True when a real (non-noop) tracker is installed — lets call
    sites skip building expensive event payloads."""
    return _ACTIVE.enabled
