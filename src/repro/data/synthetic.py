"""Deterministic synthetic data pipeline.

Two roles:

1. **LM training batches** — a reproducible token stream with a Zipf-like
   marginal and short-range structure (next token correlated with current),
   so cross-entropy actually decreases during the example runs and data is
   cheap to generate on the fly (no disk, offline container).

2. **The paper's shared dataset semantics** — every worker samples an IID
   mini-batch from the SAME dataset (paper Assumption 4/5); the per-worker
   batch RNG is derived from (round, worker-id), so runs are bitwise
   reproducible across aggregator choices.

Modality stubs (DESIGN.md §4): audio frame embeddings and vision patch
embeddings are generated with the right shapes; the conv codec / ViT that
would produce them is out of scope by assignment.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.model import FRONTEND_DIM


@dataclasses.dataclass(frozen=True)
class SyntheticConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2          # marginal skew
    copy_prob: float = 0.3       # P(next == aux of current): learnable signal


def _token_stream(key, cfg: SyntheticConfig, batch: int) -> jax.Array:
    """(batch, seq_len + 1) int32 tokens with learnable structure."""
    V = cfg.vocab_size
    k1, k2, k3 = jax.random.split(key, 3)
    # Zipf-ish marginal via exponential transform of uniforms.
    u = jax.random.uniform(k1, (batch, cfg.seq_len + 1), minval=1e-6)
    base = jnp.clip((u ** (-1.0 / cfg.zipf_a) - 1.0).astype(jnp.int32), 0,
                    V - 1)
    # Deterministic "grammar": tok_{t+1} = (7 * tok_t + 13) % V with prob p —
    # autoregressive so bigram structure is actually learnable.
    coin = jax.random.uniform(k2, (batch, cfg.seq_len)) < cfg.copy_prob

    def step(tok, inp):
        c, b = inp
        nxt = jnp.where(c, (7 * tok + 13) % V, b)
        return nxt, nxt

    _, rest = jax.lax.scan(
        step, base[:, 0],
        (jnp.moveaxis(coin, 1, 0), jnp.moveaxis(base[:, 1:], 1, 0)))
    return jnp.concatenate([base[:, :1], jnp.moveaxis(rest, 0, 1)], axis=1)


def synthetic_batch(key, cfg: SyntheticConfig) -> Dict[str, jax.Array]:
    toks = _token_stream(key, cfg, cfg.global_batch)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def train_inputs(key, mcfg: ModelConfig, batch: int, seq: int
                 ) -> Dict[str, jax.Array]:
    """A full training batch for any architecture/modality."""
    scfg = SyntheticConfig(vocab_size=mcfg.vocab_size, seq_len=seq,
                           global_batch=batch)
    out = synthetic_batch(key, scfg)
    if mcfg.frontend == "audio":
        kf = jax.random.fold_in(key, 1)
        out["features"] = 0.02 * jax.random.normal(
            kf, (batch, seq, FRONTEND_DIM["audio"]), jnp.float32)
        out.pop("tokens")
    elif mcfg.frontend == "vision":
        kv = jax.random.fold_in(key, 2)
        nv = min(mcfg.num_vision_tokens, seq)
        out["vision_embeds"] = 0.02 * jax.random.normal(
            kv, (batch, nv, FRONTEND_DIM["vision"]), jnp.float32)
        pos = jnp.broadcast_to(jnp.arange(seq), (batch, seq))
        out["mrope_positions"] = jnp.broadcast_to(pos, (3, batch, seq))
    return out


def decode_inputs(key, mcfg: ModelConfig, batch: int, pos_value: int
                  ) -> Dict[str, jax.Array]:
    """One decode-step input (token + position)."""
    tok = jax.random.randint(key, (batch, 1), 0, mcfg.vocab_size,
                             jnp.int32)
    pos = jnp.full((batch,), pos_value, jnp.int32)
    return {"token": tok, "pos": pos}


def make_batch_iterator(mcfg: ModelConfig, batch: int, seq: int,
                        seed: int = 0, start: int = 0
                        ) -> Iterator[Dict[str, jax.Array]]:
    """Infinite deterministic batch iterator (host-side jitted generator).

    ``start`` skips the first batches without generating them, so a
    resumed run continues the data stream where the checkpoint left it.
    """
    gen = jax.jit(lambda k: train_inputs(k, mcfg, batch, seq))
    step = start
    while True:
        yield gen(jax.random.fold_in(jax.random.PRNGKey(seed), step))
        step += 1
