from .synthetic import (SyntheticConfig, decode_inputs, make_batch_iterator,
                        synthetic_batch, train_inputs)

__all__ = ["SyntheticConfig", "decode_inputs", "make_batch_iterator",
           "synthetic_batch", "train_inputs"]
