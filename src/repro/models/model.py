"""Model assembly: init / train-loss / prefill / decode for every family.

Layer layout per family (DESIGN.md §2):
  dense | vlm | audio : homogeneous transformer blocks  -> lax.scan stack
  moe                 : [first_dense_layers unrolled dense] + scanned MoE
  hybrid (zamba2)     : groups of `shared_attn_every` scanned Mamba2 layers,
                        each group followed by the ONE shared transformer
                        block (shared weights, per-application KV caches)
  ssm (xlstm)         : unrolled heterogeneous m/s blocks (depth is small)

Memory discipline: layer bodies are wrapped in jax.checkpoint (full remat
per layer); the cross-entropy is sequence-chunked so full-vocab logits are
never materialised for the whole sequence.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import blocks, nn
from repro.models.moe import MoEStats

F32 = jnp.float32

FRONTEND_DIM = {"audio": 512, "vision": 1152}   # conv-codec / ViT stub dims


def compute_dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def param_dtype(cfg: ModelConfig):
    return jnp.float32 if cfg.param_dtype == "float32" else jnp.bfloat16


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _stack_layers(fn, keys):
    """vmap a per-layer param builder over layer keys; prepend 'layers' axis."""
    stacked = jax.vmap(fn)(keys)
    return jax.tree.map(
        lambda p: nn.Param(p.value, ("layers",) + p.axes), stacked,
        is_leaf=lambda x: isinstance(x, nn.Param))


def init_params(cfg: ModelConfig, key: jax.Array):
    """Returns a Param tree (values + logical sharding axes)."""
    kg = nn.KeyGen(key)
    pd = param_dtype(cfg)
    D, Vp = cfg.d_model, cfg.padded_vocab
    p: Dict[str, Any] = {}

    if cfg.frontend:
        p["frontend_proj"] = nn.param(kg(), (FRONTEND_DIM[cfg.frontend], D),
                                      (None, "embed"), pd)
    p["embed"] = nn.param(kg(), (Vp, D), ("vocab", "embed"), pd,
                          stddev=D ** -0.5)

    fam = cfg.family
    if fam in ("dense", "vlm", "audio"):
        keys = jax.random.split(kg(), cfg.num_layers)
        p["layers"] = _stack_layers(
            lambda k: blocks.transformer_block_params(
                cfg, nn.KeyGen(k), pd, moe=False), keys)
    elif fam == "moe":
        nd = cfg.first_dense_layers
        p["head_layers"] = [
            blocks.transformer_block_params(cfg, nn.KeyGen(kg()), pd,
                                            moe=False)
            for _ in range(nd)]
        keys = jax.random.split(kg(), cfg.num_layers - nd)
        p["layers"] = _stack_layers(
            lambda k: blocks.transformer_block_params(
                cfg, nn.KeyGen(k), pd, moe=True), keys)
    elif fam == "hybrid":
        keys = jax.random.split(kg(), cfg.num_layers)
        p["layers"] = _stack_layers(
            lambda k: blocks.mamba_block_params(cfg, nn.KeyGen(k), pd), keys)
        p["shared_attn"] = blocks.transformer_block_params(
            cfg, nn.KeyGen(kg()), pd, moe=False)
    elif fam == "ssm":
        p["head_layers"] = [
            blocks.xlstm_block_params(cfg, nn.KeyGen(kg()), pd, kind)
            for kind in cfg.xlstm_pattern]
    else:
        raise ValueError(f"unknown family {fam!r}")

    p["final_norm"] = nn.param(kg(), (D,), ("embed",), pd, zero=True)
    if not cfg.tie_embeddings and not cfg.is_encoder:
        p["lm_head"] = nn.param(kg(), (D, Vp), ("embed", "vocab"), pd,
                                stddev=D ** -0.5)
    return p


# ---------------------------------------------------------------------------
# Embedding / frontend
# ---------------------------------------------------------------------------


def embed_inputs(v, cfg: ModelConfig, inputs: Dict[str, jax.Array]
                 ) -> jax.Array:
    dt = compute_dtype(cfg)
    if cfg.frontend == "audio":
        x = nn.dense(inputs["features"].astype(dt),
                     v["frontend_proj"].astype(dt))
        return x
    x = nn.embed_lookup(inputs["tokens"], v["embed"]).astype(dt)
    if cfg.frontend == "vision" and "vision_embeds" in inputs:
        ve = nn.dense(inputs["vision_embeds"].astype(dt),
                      v["frontend_proj"].astype(dt))
        nv = ve.shape[1]
        x = jnp.concatenate([ve, x[:, nv:]], axis=1)
    return x


def head_matrix(v, cfg: ModelConfig):
    if cfg.tie_embeddings or "lm_head" not in v:
        return v["embed"].T
    return v["lm_head"]


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------


def forward(v, cfg: ModelConfig, inputs: Dict[str, jax.Array],
            shard_ctx=None, q_chunk: int = 512
            ) -> Tuple[jax.Array, MoEStats]:
    """Full-sequence forward -> (final hidden (B,S,D), accumulated MoE stats).
    """
    x = embed_inputs(v, cfg, inputs)
    B, S, _ = x.shape
    positions = inputs.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    mrope_pos = inputs.get("mrope_positions")
    stats = blocks.ZERO_STATS()
    qc = min(q_chunk, S)
    # FSDP hook: gather a layer's sharded params just-in-time (dist/fsdp.py)
    gf = getattr(shard_ctx, "layer_gather", None) or (lambda lp: lp)
    # remat policy: "full" recomputes everything; "save_psum" keeps the
    # post-all-reduce block outputs so TP collectives run once (§Perf HC2).
    remat = getattr(shard_ctx, "remat", "full") if shard_ctx else "full"
    if remat == "save_psum":
        from jax.ad_checkpoint import checkpoint_policies as _cp
        policy = _cp.save_only_these_names("attn_out", "mlp_out")
    else:
        policy = None

    def ckpt(fn):
        return jax.checkpoint(fn, policy=policy)

    fam = cfg.family
    if fam in ("dense", "vlm", "audio", "moe"):
        for hp in v.get("head_layers", []):
            x, st = blocks.transformer_block(
                hp, cfg, x, positions, moe=False, mrope_pos=mrope_pos,
                shard_ctx=shard_ctx, q_chunk=qc)

        moe = fam == "moe"

        def body(x, lp):
            x, st = blocks.transformer_block(
                gf(lp), cfg, x, positions, moe=moe, mrope_pos=mrope_pos,
                shard_ctx=shard_ctx, q_chunk=qc)
            return x, st

        x, sts = jax.lax.scan(ckpt(body), x, v["layers"])
        stats = MoEStats(stats.aux_loss + jnp.sum(sts.aux_loss),
                         stats.dropped_frac + jnp.mean(sts.dropped_frac))
    elif fam == "hybrid":
        k = cfg.shared_attn_every
        L = cfg.num_layers
        ng = L // k
        grouped = jax.tree.map(
            lambda a: a.reshape((ng, k) + a.shape[1:]), v["layers"])

        def group_body(x, gp):
            def inner(x, lp):
                return blocks.mamba_block(gf(lp), cfg, x), None
            x, _ = jax.lax.scan(inner, x, gp)
            x, _ = blocks.transformer_block(
                v["shared_attn"], cfg, x, positions, moe=False,
                shard_ctx=shard_ctx, q_chunk=qc)
            return x, None

        assert L % k == 0, (L, k)
        x, _ = jax.lax.scan(ckpt(group_body), x, grouped)
    elif fam == "ssm":
        for lp, kind in zip(v["head_layers"], cfg.xlstm_pattern):
            x = ckpt(
                functools.partial(blocks.xlstm_block, cfg=cfg, kind=kind)
            )(lp, x=x)
    else:
        raise ValueError(fam)

    x = nn.rms_norm(x, v["final_norm"], cfg.norm_eps)
    return x, stats


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------


def chunked_ce_loss(x: jax.Array, w_head: jax.Array, labels: jax.Array,
                    chunk: int = 1024) -> jax.Array:
    """Sequence-chunked CE: never materialises (B, S, V) at once."""
    B, S, D = x.shape
    if S <= chunk or S % chunk != 0:
        logits = jnp.einsum("bsd,dv->bsv", x, w_head.astype(x.dtype))
        return nn.softmax_cross_entropy(logits, labels,
                                        (labels >= 0).astype(F32))
    nc = S // chunk
    xs = jnp.moveaxis(x.reshape(B, nc, chunk, D), 1, 0)
    ls = jnp.moveaxis(labels.reshape(B, nc, chunk), 1, 0)

    def body(carry, xs_):
        x_c, l_c = xs_
        logits = jnp.einsum("bsd,dv->bsv", x_c, w_head.astype(x.dtype))
        logits = logits.astype(F32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, jnp.maximum(l_c, 0)[..., None],
                                 axis=-1)[..., 0]
        mask = (l_c >= 0).astype(F32)
        s, cnt = carry
        return (s + jnp.sum((logz - ll) * mask), cnt + jnp.sum(mask)), None

    (total, count), _ = jax.lax.scan(jax.checkpoint(body), (jnp.zeros((), F32),
                                     jnp.zeros((), F32)), (xs, ls))
    return total / jnp.maximum(count, 1.0)


def train_loss(v, cfg: ModelConfig, batch: Dict[str, jax.Array],
               shard_ctx=None) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Next-token (or masked-prediction for encoders) CE + MoE aux."""
    x, stats = forward(v, cfg, batch, shard_ctx)
    loss = chunked_ce_loss(x, head_matrix(v, cfg) if not cfg.is_encoder
                           else v["embed"].T, batch["labels"])
    aux = cfg.router_aux_coef * stats.aux_loss
    metrics = {"ce_loss": loss, "moe_aux": stats.aux_loss,
               "moe_dropped": stats.dropped_frac}
    return loss + aux, metrics


def prefill_logits(v, cfg: ModelConfig, inputs: Dict[str, jax.Array],
                   shard_ctx=None) -> jax.Array:
    """Forward pass returning last-position logits (B, V)."""
    x, _ = forward(v, cfg, inputs, shard_ctx)
    last = x[:, -1, :]
    return (last @ head_matrix(v, cfg).astype(last.dtype)).astype(F32)


# ---------------------------------------------------------------------------
# Decode (serve_step)
# ---------------------------------------------------------------------------

# Logical sharding axes for cache entries, keyed by leaf name.
_CACHE_AXES = {
    "k": ("batch", "kv_seq", "kv_heads", None),
    "v": ("batch", "kv_seq", "kv_heads", None),
    "kp": (None, None, "kv_heads", None),       # paged pool: (P, ps, K, hd)
    "vp": (None, None, "kv_heads", None),
    "slot_pos": ("batch", "kv_seq"),
    "ckv": ("batch", "kv_seq", None),
    "kpe": ("batch", "kv_seq", None),
    "h": ("batch", "heads", None, None),        # ssm state
    "conv": ("batch", None, "mlp"),
    "C": ("batch", None, None, None),           # mlstm matrix memory
    "n": ("batch", None, None),
    "m": ("batch", None),
    "c": ("batch", None),
}


def _cache_axes_for(key_name: str, ndim: int):
    ax = _CACHE_AXES.get(key_name)
    if ax is None or len(ax) != ndim:
        return ("batch",) + (None,) * (ndim - 1)
    return ax


def _wrap_cache(tree, extra_layer_axis: bool):
    """Plain cache tree -> Param tree with logical axes."""
    def visit(d):
        out = {}
        for k_, val in d.items():
            if isinstance(val, dict):
                out[k_] = visit(val)
            else:
                axes = _cache_axes_for(k_, val.ndim - int(extra_layer_axis))
                if extra_layer_axis:
                    axes = ("layers",) + axes
                out[k_] = nn.Param(val, axes)
        return out
    return visit(tree)


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    """Decode cache as a Param tree (values + logical axes)."""
    dt = compute_dtype(cfg)
    fam = cfg.family
    c: Dict[str, Any] = {}
    if fam in ("dense", "vlm", "audio", "moe"):
        if not cfg.has_decode:
            raise ValueError(f"{cfg.name} is encoder-only: no decode step")
        nd = cfg.first_dense_layers if fam == "moe" else 0
        c["head_layers"] = [_wrap_cache(
            blocks.transformer_block_cache(cfg, batch, max_len, dt), False)
            for _ in range(nd)]
        one = blocks.transformer_block_cache(cfg, batch, max_len, dt)
        L = cfg.num_layers - nd
        stacked = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (L,) + a.shape), one)
        c["layers"] = _wrap_cache(stacked, True)
    elif fam == "hybrid":
        k = cfg.shared_attn_every
        ng = cfg.num_layers // k
        ssm_one = blocks.ssm_init_cache(cfg, batch, dt)
        c["layers"] = _wrap_cache(jax.tree.map(
            lambda a: jnp.broadcast_to(a[None, None],
                                       (ng, k) + a.shape), ssm_one), True)
        attn_one = blocks.transformer_block_cache(cfg, batch, max_len, dt)
        c["shared_attn"] = _wrap_cache(jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (ng,) + a.shape), attn_one),
            True)
    elif fam == "ssm":
        c["head_layers"] = [_wrap_cache(
            blocks.xlstm_block_cache(cfg, batch, dt, kind), False)
            for kind in cfg.xlstm_pattern]
    else:
        raise ValueError(fam)
    return c


def init_paged_cache(cfg: ModelConfig, num_pages: int, page_size: int):
    """Paged decode cache (repro.serve): per-layer page pools as a Param
    tree. All layers share one block table / allocator — a sequence's
    logical block maps to the same page index in every layer. Page 0 is
    the reserved scratch page. Only transformer families with GQA
    attention page their KV (hybrid/ssm state is O(1) per sequence)."""
    dt = compute_dtype(cfg)
    fam = cfg.family
    if fam not in ("dense", "vlm", "audio", "moe"):
        raise ValueError(f"paged KV cache supports transformer families "
                         f"only, got family {fam!r}")
    if not cfg.has_decode:
        raise ValueError(f"{cfg.name} is encoder-only: no decode step")
    if num_pages < 2:
        raise ValueError("need num_pages >= 2 (page 0 is scratch)")
    c: Dict[str, Any] = {}
    nd = cfg.first_dense_layers if fam == "moe" else 0
    c["head_layers"] = [_wrap_cache(
        blocks.transformer_block_paged_cache(cfg, num_pages, page_size, dt),
        False) for _ in range(nd)]
    one = blocks.transformer_block_paged_cache(cfg, num_pages, page_size, dt)
    L = cfg.num_layers - nd
    stacked = jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (L,) + a.shape), one)
    c["layers"] = _wrap_cache(stacked, True)
    return c


def decode_step(v, cfg: ModelConfig, cache, token: jax.Array,
                pos: jax.Array, shard_ctx=None, block_tables=None
                ) -> Tuple[jax.Array, Any]:
    """One-token serve step. token (B,1) int32, pos (B,) -> (logits, cache).

    ``cache`` is the plain value tree (axes stripped by the caller). With
    ``block_tables`` ((B, NB) int32 page ids) the cache must be the paged
    form from :func:`init_paged_cache`; pos -1 marks an inactive lane.
    """
    dt = compute_dtype(cfg)
    x = nn.embed_lookup(token, v["embed"]).astype(dt)     # (B,1,D)
    mrope_pos = None
    if cfg.mrope:
        mrope_pos = jnp.broadcast_to(pos[None, :, None], (3,) + token.shape)
    fam = cfg.family
    new_cache: Dict[str, Any] = {}

    if fam in ("dense", "vlm", "audio", "moe"):
        moe = fam == "moe"
        new_cache["head_layers"] = []
        for hp, hc in zip(v.get("head_layers", []),
                          cache.get("head_layers", [])):
            x, nc_ = blocks.transformer_block_decode(
                hp, cfg, x, pos, hc, moe=False, mrope_pos=mrope_pos,
                shard_ctx=shard_ctx, block_table=block_tables)
            new_cache["head_layers"].append(nc_)

        def body(x, xs_):
            lp, lc = xs_
            x, nc_ = blocks.transformer_block_decode(
                lp, cfg, x, pos, lc, moe=moe, mrope_pos=mrope_pos,
                shard_ctx=shard_ctx, block_table=block_tables)
            return x, nc_

        x, new_cache["layers"] = jax.lax.scan(
            body, x, (v["layers"], cache["layers"]))
    elif block_tables is not None:
        raise ValueError(f"paged decode supports transformer families "
                         f"only, got family {fam!r}")
    elif fam == "hybrid":
        k = cfg.shared_attn_every
        ng = cfg.num_layers // k
        grouped = jax.tree.map(
            lambda a: a.reshape((ng, k) + a.shape[1:]), v["layers"])

        def group_body(x, xs_):
            gp, gc, ac = xs_

            def inner(x, xs2):
                lp, lc = xs2
                x, nc_ = blocks.mamba_block_decode(lp, cfg, x, lc)
                return x, nc_

            x, gc_new = jax.lax.scan(inner, x, (gp, gc))
            x, ac_new = blocks.transformer_block_decode(
                v["shared_attn"], cfg, x, pos, ac, moe=False,
                shard_ctx=shard_ctx)
            return x, (gc_new, ac_new)

        x, (gcs, acs) = jax.lax.scan(
            group_body, x, (grouped, cache["layers"], cache["shared_attn"]))
        new_cache["layers"] = gcs
        new_cache["shared_attn"] = acs
    elif fam == "ssm":
        new_cache["head_layers"] = []
        for lp, lc, kind in zip(v["head_layers"], cache["head_layers"],
                                cfg.xlstm_pattern):
            x, nc_ = blocks.xlstm_block_decode(lp, cfg, x, lc, kind)
            new_cache["head_layers"].append(nc_)
    else:
        raise ValueError(fam)

    x = nn.rms_norm(x, v["final_norm"], cfg.norm_eps)
    logits = (x[:, 0] @ head_matrix(v, cfg).astype(x.dtype)).astype(F32)
    return logits, new_cache
