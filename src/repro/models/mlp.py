"""Dense SwiGLU / GELU MLP."""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import nn


def mlp_params(cfg: ModelConfig, kg: nn.KeyGen, pdtype, d_ff: int = 0
               ) -> Dict[str, Any]:
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    return {
        "w_gate": nn.param(kg(), (D, F), ("embed", "mlp"), pdtype),
        "w_up": nn.param(kg(), (D, F), ("embed", "mlp"), pdtype),
        "w_down": nn.param(kg(), (F, D), ("mlp", "embed"), pdtype),
    }


def mlp_forward(p, x: jax.Array) -> jax.Array:
    g = nn.dense(x, p["w_gate"].astype(x.dtype))
    u = nn.dense(x, p["w_up"].astype(x.dtype))
    return nn.dense(nn.swiglu(g, u), p["w_down"].astype(x.dtype))
