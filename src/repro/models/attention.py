"""Attention variants: GQA (+qk-norm, bias, sliding window, M-RoPE) and MLA.

Memory discipline (these run at 32k prefill / 104B-scale in the dry-run):
  * train/prefill attention is **query-chunked**: a lax.scan over query blocks
    so the live score buffer is (B, H, qc, T) instead of (B, H, S, T);
  * decode uses explicit KV caches; MLA decodes in the **absorbed** latent
    form (cache = compressed c_kv + rope key, never materialising per-head
    K/V — the whole point of MLA);
  * sliding-window decode keeps a ring-buffer cache of `window` slots.

All einsums accumulate in fp32 (`preferred_element_type`) and cast back.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import nn
from repro.models.rope import apply_mrope, apply_rope

F32 = jnp.float32
NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Masking helpers
# ---------------------------------------------------------------------------


def _band_mask(q_pos: jax.Array, k_pos: jax.Array, causal: bool,
               window: Optional[int]) -> jax.Array:
    """(..., Sq, Sk) boolean mask: True = attend."""
    diff = q_pos[..., :, None] - k_pos[..., None, :]
    m = jnp.ones(diff.shape, bool)
    if causal:
        m &= diff >= 0
    if window is not None:
        m &= diff < window
    return m


def _softmax_attend(q: jax.Array, k: jax.Array, v: jax.Array,
                    mask: jax.Array, scale: float) -> jax.Array:
    """q (B,qc,K,G,hd), k (B,T,K,hd), v (B,T,K,hd), mask (B?,qc,T)."""
    scores = jnp.einsum("bqkgh,btkh->bkgqt", q, k,
                        preferred_element_type=F32) * scale
    scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqt,btkh->bqkgh", probs, v,
                     preferred_element_type=F32)
    return out.astype(v.dtype)


def chunked_gqa(q: jax.Array, k: jax.Array, v: jax.Array,
                q_pos: jax.Array, k_pos: jax.Array, *, causal: bool,
                window: Optional[int], q_chunk: int = 512) -> jax.Array:
    """Query-chunked GQA core.

    q: (B, S, H, hd); k/v: (B, T, K, hd) with H = K*G; positions (B, S)/(B, T).
    Scans over query chunks so peak score memory is (B, K, G, qc, T).
    """
    B, S, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    vd = v.shape[-1]                    # may differ from hd (MLA)
    scale = hd ** -0.5
    qg = q.reshape(B, S, K, G, hd)

    if S <= q_chunk or S % q_chunk != 0:
        mask = _band_mask(q_pos, k_pos, causal, window)
        out = _softmax_attend(qg, k, v, mask, scale)
        return out.reshape(B, S, H, vd)

    n_chunks = S // q_chunk
    qs = qg.reshape(B, n_chunks, q_chunk, K, G, hd)
    qp = q_pos.reshape(B, n_chunks, q_chunk)

    def body(_, xs):
        qc, qpc = xs                       # (B, qc, K, G, hd), (B, qc)
        mask = _band_mask(qpc, k_pos, causal, window)
        return None, _softmax_attend(qc, k, v, mask, scale)

    _, outs = jax.lax.scan(body, None,
                           (jnp.moveaxis(qs, 1, 0), jnp.moveaxis(qp, 1, 0)))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, S, K, G, vd)
    return out.reshape(B, S, H, vd)


# ---------------------------------------------------------------------------
# GQA layer
# ---------------------------------------------------------------------------


def gqa_params(cfg: ModelConfig, kg: nn.KeyGen, pdtype) -> Dict[str, Any]:
    D, H, K = cfg.d_model, cfg.num_heads, cfg.num_kv_heads
    hd = cfg.resolved_head_dim
    p = {
        "wq": nn.param(kg(), (D, H, hd), ("embed", "heads", None), pdtype),
        "wk": nn.param(kg(), (D, K, hd), ("embed", "kv_heads", None), pdtype),
        "wv": nn.param(kg(), (D, K, hd), ("embed", "kv_heads", None), pdtype),
        "wo": nn.param(kg(), (H, hd, D), ("heads", None, "embed"), pdtype),
    }
    if cfg.use_bias:
        p["bq"] = nn.param(kg(), (H, hd), ("heads", None), pdtype, zero=True)
        p["bk"] = nn.param(kg(), (K, hd), ("kv_heads", None), pdtype,
                           zero=True)
        p["bv"] = nn.param(kg(), (K, hd), ("kv_heads", None), pdtype,
                           zero=True)
    if cfg.qk_norm:
        p["q_norm"] = nn.param(kg(), (hd,), (None,), pdtype, zero=True)
        p["k_norm"] = nn.param(kg(), (hd,), (None,), pdtype, zero=True)
    return p


def _project_qkv(p, cfg: ModelConfig, x: jax.Array):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if cfg.use_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    if cfg.qk_norm:
        q = nn.rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = nn.rms_norm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def gqa_forward(p, cfg: ModelConfig, x: jax.Array, positions: jax.Array,
                mrope_pos: Optional[jax.Array] = None,
                q_chunk: int = 512) -> jax.Array:
    """Full-sequence (train / prefill) GQA attention."""
    q, k, v = _project_qkv(p, cfg, x)
    if cfg.mrope and mrope_pos is not None:
        q = apply_mrope(q, mrope_pos, cfg.mrope_sections, cfg.rope_theta)
        k = apply_mrope(k, mrope_pos, cfg.mrope_sections, cfg.rope_theta)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    out = chunked_gqa(q, k, v, positions, positions, causal=cfg.causal,
                      window=cfg.sliding_window, q_chunk=q_chunk)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))


def gqa_init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype
                   ) -> Dict[str, jax.Array]:
    """KV cache. With a sliding window, the cache is a ring buffer of
    ``window`` slots; otherwise ``max_len`` slots."""
    K, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    T = min(cfg.sliding_window, max_len) if cfg.sliding_window else max_len
    return {
        "k": jnp.zeros((batch, T, K, hd), dtype),
        "v": jnp.zeros((batch, T, K, hd), dtype),
        # absolute position stored per slot; -1 = empty
        "slot_pos": jnp.full((batch, T), -1, jnp.int32),
    }


def gqa_decode(p, cfg: ModelConfig, x: jax.Array, pos: jax.Array,
               cache: Dict[str, jax.Array],
               mrope_pos: Optional[jax.Array] = None
               ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token decode. x: (B, 1, D); pos: (B,) absolute position."""
    B = x.shape[0]
    q, k_new, v_new = _project_qkv(p, cfg, x)
    pos_b1 = pos[:, None]
    if cfg.mrope and mrope_pos is not None:
        q = apply_mrope(q, mrope_pos, cfg.mrope_sections, cfg.rope_theta)
        k_new = apply_mrope(k_new, mrope_pos, cfg.mrope_sections,
                            cfg.rope_theta)
    else:
        q = apply_rope(q, pos_b1, cfg.rope_theta)
        k_new = apply_rope(k_new, pos_b1, cfg.rope_theta)

    T = cache["k"].shape[1]
    slot = jnp.mod(pos, T) if cfg.sliding_window else jnp.minimum(pos, T - 1)
    bidx = jnp.arange(B)
    k = cache["k"].at[bidx, slot].set(k_new[:, 0])
    v = cache["v"].at[bidx, slot].set(v_new[:, 0])
    slot_pos = cache["slot_pos"].at[bidx, slot].set(pos)

    k_pos = slot_pos                       # (B, T); -1 slots masked below
    mask = (k_pos >= 0) & (k_pos <= pos[:, None])
    if cfg.sliding_window:
        mask &= (pos[:, None] - k_pos) < cfg.sliding_window
    K = k.shape[2]
    H = cfg.num_heads
    G = H // K
    hd = cfg.resolved_head_dim
    qg = q.reshape(B, 1, K, G, hd)
    scores = jnp.einsum("bqkgh,btkh->bkgqt", qg, k,
                        preferred_element_type=F32) * hd ** -0.5
    scores = jnp.where(mask[:, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqt,btkh->bqkgh", probs, v,
                     preferred_element_type=F32).astype(x.dtype)
    out = out.reshape(B, 1, H, hd)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return y, {"k": k, "v": v, "slot_pos": slot_pos}


# ---------------------------------------------------------------------------
# Paged GQA decode (repro.serve): block-table-indexed page pool
# ---------------------------------------------------------------------------


def gqa_paged_init_cache(cfg: ModelConfig, num_pages: int, page_size: int,
                         dtype) -> Dict[str, jax.Array]:
    """Paged KV cache: a pool of fixed-size pages shared by all sequences.

    Logical position t of a sequence with block table ``bt`` lives at page
    ``bt[t // page_size]``, slot ``t % page_size``. Page 0 is reserved as
    the scratch page (inactive/padded writes land there, never attended);
    ``repro.serve.kv_cache`` owns the free-list allocation of the rest.
    """
    K, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    return {
        "kp": jnp.zeros((num_pages, page_size, K, hd), dtype),
        "vp": jnp.zeros((num_pages, page_size, K, hd), dtype),
    }


def gqa_paged_decode(p, cfg: ModelConfig, x: jax.Array, pos: jax.Array,
                     cache: Dict[str, jax.Array], block_table: jax.Array,
                     mrope_pos: Optional[jax.Array] = None
                     ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token decode against the paged cache.

    x: (B, 1, D); pos: (B,) absolute position, -1 = inactive lane (its
    write is directed to the scratch page and its output is zero);
    block_table: (B, NB) page ids. Attention runs through
    ``kernels.ops.paged_decode_attention`` (Pallas on TPU, gather-ref
    elsewhere).
    """
    from repro.kernels import ops as _kops
    B = x.shape[0]
    q, k_new, v_new = _project_qkv(p, cfg, x)
    pos_b1 = pos[:, None]
    if cfg.mrope and mrope_pos is not None:
        q = apply_mrope(q, mrope_pos, cfg.mrope_sections, cfg.rope_theta)
        k_new = apply_mrope(k_new, mrope_pos, cfg.mrope_sections,
                            cfg.rope_theta)
    else:
        q = apply_rope(q, pos_b1, cfg.rope_theta)
        k_new = apply_rope(k_new, pos_b1, cfg.rope_theta)

    ps = cache["kp"].shape[1]
    active = pos >= 0
    blk = jnp.where(active, pos, 0) // ps
    page = jnp.take_along_axis(block_table, blk[:, None], axis=1)[:, 0]
    page = jnp.where(active, page, 0)           # scratch page for idle lanes
    slot = jnp.where(active, pos % ps, 0)
    kp = cache["kp"].at[page, slot].set(k_new[:, 0])
    vp = cache["vp"].at[page, slot].set(v_new[:, 0])

    lengths = jnp.where(active, pos + 1, 0)
    out = _kops.paged_decode_attention(q[:, 0], kp, vp, block_table,
                                       lengths)          # (B, H, hd)
    y = jnp.einsum("bshk,hkd->bsd", out[:, None].astype(x.dtype),
                   p["wo"].astype(x.dtype))
    return y, {"kp": kp, "vp": vp}


# ---------------------------------------------------------------------------
# MLA (Multi-head Latent Attention, DeepSeek-V2 / MiniCPM3)
# ---------------------------------------------------------------------------


def mla_params(cfg: ModelConfig, kg: nn.KeyGen, pdtype) -> Dict[str, Any]:
    D, H = cfg.d_model, cfg.num_heads
    nd, rd, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    lora, qlora = cfg.kv_lora_rank, cfg.q_lora_rank
    p: Dict[str, Any] = {}
    if qlora:
        p["wq_a"] = nn.param(kg(), (D, qlora), ("embed", None), pdtype)
        p["q_norm"] = nn.param(kg(), (qlora,), (None,), pdtype, zero=True)
        p["wq_b"] = nn.param(kg(), (qlora, H, nd + rd),
                             (None, "heads", None), pdtype)
    else:
        p["wq"] = nn.param(kg(), (D, H, nd + rd), ("embed", "heads", None),
                           pdtype)
    p["wkv_a"] = nn.param(kg(), (D, lora + rd), ("embed", None), pdtype)
    p["kv_norm"] = nn.param(kg(), (lora,), (None,), pdtype, zero=True)
    p["wk_b"] = nn.param(kg(), (lora, H, nd), (None, "heads", None), pdtype)
    p["wv_b"] = nn.param(kg(), (lora, H, vd), (None, "heads", None), pdtype)
    p["wo"] = nn.param(kg(), (H, vd, D), ("heads", None, "embed"), pdtype)
    return p


def _mla_q(p, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    if cfg.q_lora_rank:
        cq = nn.dense(x, p["wq_a"].astype(x.dtype))
        cq = nn.rms_norm(cq, p["q_norm"], cfg.norm_eps)
        q = jnp.einsum("bsq,qhk->bshk", cq, p["wq_b"].astype(x.dtype))
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    return q


def mla_forward(p, cfg: ModelConfig, x: jax.Array, positions: jax.Array,
                q_chunk: int = 512) -> jax.Array:
    """Train/prefill MLA: materialise per-head K/V from the latent."""
    B, S, D = x.shape
    H = cfg.num_heads
    nd, rd, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    lora = cfg.kv_lora_rank

    q = _mla_q(p, cfg, x)                              # (B,S,H,nd+rd)
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    ckv_full = nn.dense(x, p["wkv_a"].astype(x.dtype))  # (B,S,lora+rd)
    ckv, k_pe = ckv_full[..., :lora], ckv_full[..., lora:]
    ckv = nn.rms_norm(ckv, p["kv_norm"], cfg.norm_eps)
    k_pe = apply_rope(k_pe[:, :, None, :], positions,
                      cfg.rope_theta)                   # (B,S,1,rd)

    k_nope = jnp.einsum("bsl,lhn->bshn", ckv, p["wk_b"].astype(x.dtype))
    v = jnp.einsum("bsl,lhv->bshv", ckv, p["wv_b"].astype(x.dtype))

    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_pe, (B, S, H, rd))], axis=-1)
    out = chunked_gqa(q_full, k_full, v, positions, positions,
                      causal=cfg.causal, window=cfg.sliding_window,
                      q_chunk=q_chunk)
    return jnp.einsum("bshv,hvd->bsd", out, p["wo"].astype(x.dtype))


def mla_init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype
                   ) -> Dict[str, jax.Array]:
    """Latent cache: compressed c_kv + rope key — the MLA memory win."""
    T = min(cfg.sliding_window, max_len) if cfg.sliding_window else max_len
    return {
        "ckv": jnp.zeros((batch, T, cfg.kv_lora_rank), dtype),
        "kpe": jnp.zeros((batch, T, cfg.qk_rope_head_dim), dtype),
        "slot_pos": jnp.full((batch, T), -1, jnp.int32),
    }


def mla_decode(p, cfg: ModelConfig, x: jax.Array, pos: jax.Array,
               cache: Dict[str, jax.Array]
               ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Absorbed-form single-token MLA decode against the latent cache."""
    B = x.shape[0]
    H = cfg.num_heads
    nd, rd, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    lora = cfg.kv_lora_rank
    scale = (nd + rd) ** -0.5

    q = _mla_q(p, cfg, x)                               # (B,1,H,nd+rd)
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    q_rope = apply_rope(q_rope, pos[:, None], cfg.rope_theta)

    ckv_full = nn.dense(x, p["wkv_a"].astype(x.dtype))
    ckv_new = nn.rms_norm(ckv_full[..., :lora], p["kv_norm"], cfg.norm_eps)
    kpe_new = apply_rope(ckv_full[:, :, None, lora:], pos[:, None],
                         cfg.rope_theta)[:, :, 0, :]    # (B,1,rd)

    T = cache["ckv"].shape[1]
    slot = jnp.mod(pos, T) if cfg.sliding_window else jnp.minimum(pos, T - 1)
    bidx = jnp.arange(B)
    ckv = cache["ckv"].at[bidx, slot].set(ckv_new[:, 0])
    kpe = cache["kpe"].at[bidx, slot].set(kpe_new[:, 0])
    slot_pos = cache["slot_pos"].at[bidx, slot].set(pos)

    # Absorb W_uk into the query: q_lat (B,1,H,lora).
    q_lat = jnp.einsum("bshn,lhn->bshl", q_nope, p["wk_b"].astype(x.dtype))
    scores = (jnp.einsum("bshl,btl->bhst", q_lat, ckv,
                         preferred_element_type=F32)
              + jnp.einsum("bshr,btr->bhst", q_rope, kpe,
                           preferred_element_type=F32)) * scale
    mask = (slot_pos >= 0) & (slot_pos <= pos[:, None])
    if cfg.sliding_window:
        mask &= (pos[:, None] - slot_pos) < cfg.sliding_window
    scores = jnp.where(mask[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    o_lat = jnp.einsum("bhst,btl->bshl", probs, ckv,
                       preferred_element_type=F32).astype(x.dtype)
    out = jnp.einsum("bshl,lhv->bshv", o_lat, p["wv_b"].astype(x.dtype))
    y = jnp.einsum("bshv,hvd->bsd", out, p["wo"].astype(x.dtype))
    return y, {"ckv": ckv, "kpe": kpe, "slot_pos": slot_pos}
