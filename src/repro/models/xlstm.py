"""xLSTM blocks (Beck et al., arXiv:2405.04517): mLSTM and sLSTM.

mLSTM — matrix-memory LSTM with exponential gating. Training/prefill uses
the parallel (attention-like) form with a log-space stabiliser; decode uses
the recurrence over (C, n, m) states. Quadratic scores are query-chunked.

sLSTM — scalar-memory LSTM with block-diagonal recurrent weights; it is
inherently sequential, so training scans over time (the paper's cuda kernel
does the same, fused). Decode is the same single-step cell.

Both blocks carry their own projections (config d_ff = 0): mLSTM up-projects
by pf=2 and runs the cell in the inner dim; sLSTM runs the cell at d_model
followed by a pf=4/3 gated FFN, as in the paper.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import nn

F32 = jnp.float32


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def mlstm_params(cfg: ModelConfig, kg: nn.KeyGen, pdtype) -> Dict[str, Any]:
    D = cfg.d_model
    ed = 2 * D                      # pf = 2 up-projection
    H = cfg.num_heads
    dh = ed // H
    return {
        "w_up": nn.param(kg(), (D, 2 * ed), ("embed", "mlp"), pdtype),
        "wq": nn.param(kg(), (ed, H, dh), ("mlp", "heads", None), pdtype),
        "wk": nn.param(kg(), (ed, H, dh), ("mlp", "heads", None), pdtype),
        "wv": nn.param(kg(), (ed, H, dh), ("mlp", "heads", None), pdtype),
        "w_if": nn.param(kg(), (ed, 2 * H), ("mlp", None), jnp.float32,
                         stddev=ed ** -0.5),
        "b_if": nn.param(kg(), (2 * H,), (None,), jnp.float32, zero=True),
        "norm": nn.param(kg(), (ed,), ("mlp",), pdtype, zero=True),
        "w_down": nn.param(kg(), (ed, D), ("mlp", "embed"), pdtype),
    }


def _mlstm_qkvif(p, cfg: ModelConfig, x: jax.Array):
    up = nn.dense(x, p["w_up"].astype(x.dtype))
    ed = up.shape[-1] // 2
    x_in, z = up[..., :ed], up[..., ed:]
    q = jnp.einsum("bsd,dhk->bshk", x_in, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x_in, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x_in, p["wv"].astype(x.dtype))
    gates = x_in.astype(F32) @ p["w_if"] + p["b_if"]       # (B,S,2H)
    H = q.shape[2]
    log_i = gates[..., :H]                                  # pre-act i gate
    log_f = jax.nn.log_sigmoid(gates[..., H:])              # log f in (-inf,0)
    return q, k, v, log_i, log_f, z, x_in


def mlstm_forward(p, cfg: ModelConfig, x: jax.Array, q_chunk: int = 512
                  ) -> jax.Array:
    """Parallel stabilised form. x: (B, S, D)."""
    B, S, D = x.shape
    q, k, v, log_i, log_f, z, _ = _mlstm_qkvif(p, cfg, x)
    H, dh = q.shape[2], q.shape[3]
    scale = dh ** -0.5

    F_cum = jnp.cumsum(log_f, axis=1)                       # (B,S,H)
    # log D[i,j] = F_i - F_j + log i_j  (j <= i); row stabiliser
    # m_i = max_{j<=i} (log i_j - F_j) + F_i  — running max over the prefix.
    gmax = jax.lax.cummax(log_i - F_cum, axis=1)            # (B,S,H)
    m = gmax + F_cum

    def attend(q_c, Fq_c, m_c, sl):
        # q_c: (B,qc,H,dh); scores vs all keys
        logD = (Fq_c[:, :, None, :] - F_cum[:, None, :, :]
                + log_i[:, None, :, :] - m_c[:, :, None, :])  # (B,qc,S,H)
        ii = sl[:, None] >= jnp.arange(S)[None, :]
        logD = jnp.where(ii[None, :, :, None], logD, -jnp.inf)
        Dm = jnp.exp(logD)
        scores = jnp.einsum("bqhk,bshk->bqsh", q_c, k,
                            preferred_element_type=F32) * scale
        Sm = scores * Dm                                     # (B,qc,S,H)
        norm = jnp.maximum(jnp.abs(jnp.sum(Sm, axis=2)),
                           jnp.exp(-m_c))                    # (B,qc,H)
        out = jnp.einsum("bqsh,bshk->bqhk", Sm, v.astype(F32))
        return out / norm[..., None]

    if S <= q_chunk or S % q_chunk != 0:
        out = attend(q, F_cum, m, jnp.arange(S))
    else:
        nc = S // q_chunk
        qs = jnp.moveaxis(q.reshape(B, nc, q_chunk, H, dh), 1, 0)
        Fs = jnp.moveaxis(F_cum.reshape(B, nc, q_chunk, H), 1, 0)
        ms = jnp.moveaxis(m.reshape(B, nc, q_chunk, H), 1, 0)
        sls = jnp.arange(S).reshape(nc, q_chunk)

        def body(_, xs_):
            q_c, F_c, m_c, sl = xs_
            return None, attend(q_c, F_c, m_c, sl)

        _, outs = jax.lax.scan(body, None, (qs, Fs, ms, sls))
        out = jnp.moveaxis(outs, 0, 1).reshape(B, S, H, dh)

    ed = H * dh
    y = out.reshape(B, S, ed).astype(x.dtype)
    y = nn.rms_norm(y, p["norm"], cfg.norm_eps) * jax.nn.silu(z)
    return nn.dense(y, p["w_down"].astype(x.dtype))


def mlstm_init_cache(cfg: ModelConfig, batch: int, dtype) -> Dict[str, Any]:
    D = cfg.d_model
    ed = 2 * D
    H = cfg.num_heads
    dh = ed // H
    return {
        "C": jnp.zeros((batch, H, dh, dh), F32),
        "n": jnp.zeros((batch, H, dh), F32),
        "m": jnp.full((batch, H), -jnp.inf, F32),
    }


def mlstm_decode(p, cfg: ModelConfig, x: jax.Array, cache: Dict[str, Any]
                 ) -> Tuple[jax.Array, Dict[str, Any]]:
    """Single-token recurrence. x: (B, 1, D)."""
    B = x.shape[0]
    q, k, v, log_i, log_f, z, _ = _mlstm_qkvif(p, cfg, x)
    H, dh = q.shape[2], q.shape[3]
    scale = dh ** -0.5
    log_i, log_f = log_i[:, 0], log_f[:, 0]                 # (B,H)

    m_prev = cache["m"]
    m_new = jnp.maximum(log_f + m_prev, log_i)
    f_sc = jnp.exp(log_f + m_prev - m_new)                  # (B,H)
    i_sc = jnp.exp(log_i - m_new)

    kf = k[:, 0].astype(F32)
    vf = v[:, 0].astype(F32)
    C = (cache["C"] * f_sc[..., None, None]
         + i_sc[..., None, None] * jnp.einsum("bhk,bhv->bhkv", kf, vf))
    n = cache["n"] * f_sc[..., None] + i_sc[..., None] * kf

    qf = q[:, 0].astype(F32) * scale
    num = jnp.einsum("bhk,bhkv->bhv", qf, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", qf, n)),
                      jnp.exp(-m_new))
    out = num / den[..., None]                              # (B,H,dh)
    ed = H * dh
    y = out.reshape(B, 1, ed).astype(x.dtype)
    y = nn.rms_norm(y, p["norm"], cfg.norm_eps) * jax.nn.silu(z)
    y = nn.dense(y, p["w_down"].astype(x.dtype))
    return y, {"C": C, "n": n, "m": m_new}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def slstm_params(cfg: ModelConfig, kg: nn.KeyGen, pdtype) -> Dict[str, Any]:
    D = cfg.d_model
    H = cfg.num_heads
    dh = D // H
    Fd = 4 * D // 3 // 2 * 2        # pf = 4/3 gated FFN, even
    return {
        # four gates (z, i, f, o), input weights + block-diag recurrent
        "w_gates": nn.param(kg(), (D, 4, D), ("embed", None, "mlp"), pdtype),
        "r_gates": nn.param(kg(), (4, H, dh, dh), (None, "heads", None, None),
                            pdtype, stddev=dh ** -0.5),
        "b_gates": nn.param(kg(), (4, D), (None, "mlp"), jnp.float32,
                            zero=True),
        "norm": nn.param(kg(), (D,), ("embed",), pdtype, zero=True),
        "ffn_gate": nn.param(kg(), (D, Fd), ("embed", "mlp"), pdtype),
        "ffn_up": nn.param(kg(), (D, Fd), ("embed", "mlp"), pdtype),
        "ffn_down": nn.param(kg(), (Fd, D), ("mlp", "embed"), pdtype),
    }


def slstm_cell(p, cfg: ModelConfig, wx: jax.Array, state
               ) -> Tuple[Dict[str, jax.Array], jax.Array]:
    """One sLSTM step. wx: (B, 4, D) precomputed input contributions."""
    D = cfg.d_model
    H = cfg.num_heads
    dh = D // H
    c, n, m, h = state["c"], state["n"], state["m"], state["h"]

    hh = h.reshape(-1, H, dh)
    rec = jnp.einsum("bhk,ghkl->bghl", hh, p["r_gates"].astype(h.dtype))
    pre = (wx + rec.reshape(-1, 4, D)).astype(F32) + p["b_gates"]
    z_t = jnp.tanh(pre[:, 0])
    log_i = pre[:, 1]
    log_f = jax.nn.log_sigmoid(pre[:, 2])
    o_t = jax.nn.sigmoid(pre[:, 3])

    m_new = jnp.maximum(log_f + m, log_i)
    i_sc = jnp.exp(log_i - m_new)
    f_sc = jnp.exp(log_f + m - m_new)
    c_new = f_sc * c + i_sc * z_t
    n_new = f_sc * n + i_sc
    h_new = o_t * c_new / jnp.maximum(n_new, 1e-6)
    return {"c": c_new, "n": n_new, "m": m_new, "h": h_new}, h_new


def slstm_init_cache(cfg: ModelConfig, batch: int, dtype) -> Dict[str, Any]:
    D = cfg.d_model
    return {
        "c": jnp.zeros((batch, D), F32),
        "n": jnp.zeros((batch, D), F32),
        "m": jnp.full((batch, D), -jnp.inf, F32),
        "h": jnp.zeros((batch, D), F32),
    }


def _slstm_ffn(p, cfg: ModelConfig, y: jax.Array) -> jax.Array:
    g = nn.dense(y, p["ffn_gate"].astype(y.dtype))
    u = nn.dense(y, p["ffn_up"].astype(y.dtype))
    return nn.dense(nn.swiglu(g, u), p["ffn_down"].astype(y.dtype))


def slstm_forward(p, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """Sequential scan over time. x: (B, S, D)."""
    B, S, D = x.shape
    wx = jnp.einsum("bsd,dgk->bsgk", x, p["w_gates"].astype(x.dtype))
    state = slstm_init_cache(cfg, B, x.dtype)

    def step(st, wx_t):
        st, h = slstm_cell(p, cfg, wx_t, st)
        return st, h

    _, hs = jax.lax.scan(step, state, jnp.moveaxis(wx, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).astype(x.dtype)               # (B,S,D)
    y = nn.rms_norm(y, p["norm"], cfg.norm_eps)
    return _slstm_ffn(p, cfg, y)


def slstm_decode(p, cfg: ModelConfig, x: jax.Array, cache
                 ) -> Tuple[jax.Array, Dict[str, Any]]:
    wx = jnp.einsum("bsd,dgk->bsgk", x, p["w_gates"].astype(x.dtype))[:, 0]
    cache, h = slstm_cell(p, cfg, wx, cache)
    y = nn.rms_norm(h[:, None].astype(x.dtype), p["norm"], cfg.norm_eps)
    return _slstm_ffn(p, cfg, y), cache
