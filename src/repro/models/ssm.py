"""Mamba2 block (SSD — state-space duality form), JAX implementation.

Training/prefill uses the chunked SSD algorithm: within-chunk quadratic
attention-like form + cross-chunk recurrence over the (H, P, N) state,
scanned over chunks — O(S/Q * (Q^2 + Q N P)) work, never materialising the
full (S, S) kernel. Decode is the single-step recurrence with a
(B, H, P, N) state and a causal-conv ring cache.

Shapes: d_inner = expand * d_model, H = d_inner / head_dim (P), state N,
single B/C group (ngroups=1, as in Zamba2).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import nn

F32 = jnp.float32


def ssm_params(cfg: ModelConfig, kg: nn.KeyGen, pdtype) -> Dict[str, Any]:
    D = cfg.d_model
    di = cfg.ssm_d_inner
    N = cfg.ssm_state
    H = cfg.ssm_num_heads
    conv_dim = di + 2 * N
    return {
        # in_proj -> [z(di), x(di), B(N), C(N), dt(H)]
        "w_in": nn.param(kg(), (D, 2 * di + 2 * N + H), ("embed", "mlp"),
                         pdtype),
        "conv_w": nn.param(kg(), (cfg.conv_width, conv_dim), (None, "mlp"),
                           pdtype, stddev=cfg.conv_width ** -0.5),
        "conv_b": nn.param(kg(), (conv_dim,), ("mlp",), pdtype, zero=True),
        "A_log": nn.param(kg(), (H,), (None,), jnp.float32, ones=True),
        "dt_bias": nn.param(kg(), (H,), (None,), jnp.float32, zero=True),
        "D_skip": nn.param(kg(), (H,), (None,), jnp.float32, ones=True),
        "norm": nn.param(kg(), (di,), ("mlp",), pdtype, zero=True),
        "w_out": nn.param(kg(), (di, D), ("mlp", "embed"), pdtype),
    }


def _split_in(cfg: ModelConfig, zxbcdt: jax.Array):
    di, N, H = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_num_heads
    z = zxbcdt[..., :di]
    xc = zxbcdt[..., di:2 * di]
    Bc = zxbcdt[..., 2 * di:2 * di + N]
    Cc = zxbcdt[..., 2 * di + N:2 * di + 2 * N]
    dt = zxbcdt[..., 2 * di + 2 * N:]
    return z, xc, Bc, Cc, dt


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over (B, S, C) with width-k filter (k, C)."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * w[i] for i in range(k))
    return jax.nn.silu(out + b)


def _gated_norm(y: jax.Array, z: jax.Array, scale: jax.Array, eps: float
                ) -> jax.Array:
    return nn.rms_norm(y * jax.nn.silu(z), scale, eps)


def ssm_forward(p, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """Train/prefill Mamba2 on (B, S, D) via chunked SSD."""
    Bsz, S, D = x.shape
    di, N, H = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_num_heads
    P = cfg.ssm_head_dim
    Q = min(cfg.ssm_chunk, S)
    assert S % Q == 0, (S, Q)
    nC = S // Q

    zxbcdt = nn.dense(x, p["w_in"].astype(x.dtype))
    z, xc, Bc, Cc, dt = _split_in(cfg, zxbcdt)
    xbc = jnp.concatenate([xc, Bc, Cc], axis=-1)
    xbc = _causal_conv(xbc, p["conv_w"].astype(x.dtype),
                       p["conv_b"].astype(x.dtype))
    xc, Bc, Cc = xbc[..., :di], xbc[..., di:di + N], xbc[..., di + N:]

    dt = jax.nn.softplus(dt.astype(F32) + p["dt_bias"])        # (B,S,H)
    A = -jnp.exp(p["A_log"])                                    # (H,) < 0
    a = dt * A                                                  # log-decay
    xh = xc.reshape(Bsz, S, H, P).astype(F32)
    xdt = xh * dt[..., None]                                    # dt-weighted

    # chunk views
    def chunk(t):
        return t.reshape((Bsz, nC, Q) + t.shape[2:])

    # One scan over chunks carries the (B, H, N, P) state and computes both
    # the intra-chunk quadratic term and the inter-chunk contribution — peak
    # memory is a single chunk's (B, Q, Q, H) kernel, not all nC at once.
    ii = jnp.arange(Q)
    causal = (ii[:, None] >= ii[None, :])[None, :, :, None]     # (1,Q,Q,1)

    def scan_fn(h, inp):
        a_q, x_q, B_q, C_q = inp       # (B,Q,H), (B,Q,H,P), (B,Q,N), (B,Q,N)
        A_cum = jnp.cumsum(a_q, axis=1)                         # (B,Q,H)
        # intra: L[i,j] = exp(A_cum_i - A_cum_j), j <= i. Mask BEFORE exp:
        # masked entries have diff > 0 and would overflow to inf, poisoning
        # the backward pass through the where.
        diff = A_cum[:, :, None, :] - A_cum[:, None, :, :]      # (B,Q,Q,H)
        L = jnp.exp(jnp.where(causal, diff, -jnp.inf))
        CB = jnp.einsum("bin,bjn->bij", C_q, B_q)               # (B,Q,Q)
        Y_intra = jnp.einsum("bijh,bjhp->bihp", CB[..., None] * L, x_q)
        # inter: contribution of the carried state
        inter_decay = jnp.exp(A_cum)                            # (B,Q,H)
        Y_inter = jnp.einsum("bqn,bqh,bhnp->bqhp", C_q, inter_decay, h)
        # state update
        decay_to_end = jnp.exp(A_cum[:, -1:, :] - A_cum)        # (B,Q,H)
        S_chunk = jnp.einsum("bqn,bqh,bqhp->bhnp", B_q, decay_to_end, x_q)
        h_new = h * jnp.exp(A_cum[:, -1, :])[:, :, None, None] + S_chunk
        return h_new, Y_intra + Y_inter

    h0 = jnp.zeros((Bsz, H, N, P), F32)
    xs = (jnp.moveaxis(chunk(a), 1, 0), jnp.moveaxis(chunk(xdt), 1, 0),
          jnp.moveaxis(chunk(Bc.astype(F32)), 1, 0),
          jnp.moveaxis(chunk(Cc.astype(F32)), 1, 0))
    _, Y = jax.lax.scan(scan_fn, h0, xs)                        # (nC,B,Q,H,P)
    Y = jnp.moveaxis(Y, 0, 1).reshape(Bsz, S, H, P)
    Y = Y + p["D_skip"][:, None] * xh
    Y = Y.reshape(Bsz, S, di).astype(x.dtype)
    Y = _gated_norm(Y, z, p["norm"], cfg.norm_eps)
    return nn.dense(Y, p["w_out"].astype(x.dtype))


def ssm_init_cache(cfg: ModelConfig, batch: int, dtype) -> Dict[str, Any]:
    di, N, H, P = (cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_num_heads,
                   cfg.ssm_head_dim)
    conv_dim = di + 2 * N
    return {
        "h": jnp.zeros((batch, H, N, P), F32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, conv_dim), dtype),
    }


def ssm_decode(p, cfg: ModelConfig, x: jax.Array, cache: Dict[str, Any]
               ) -> Tuple[jax.Array, Dict[str, Any]]:
    """Single-token recurrence. x: (B, 1, D)."""
    Bsz = x.shape[0]
    di, N, H, P = (cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_num_heads,
                   cfg.ssm_head_dim)
    zxbcdt = nn.dense(x[:, 0], p["w_in"].astype(x.dtype))       # (B, ...)
    z, xc, Bc, Cc, dt = _split_in(cfg, zxbcdt[:, None])
    xbc_new = jnp.concatenate([xc, Bc, Cc], axis=-1)[:, 0]      # (B, conv)

    # causal-conv ring: window = [cache, new]
    win = jnp.concatenate([cache["conv"], xbc_new[:, None]], axis=1)
    w = p["conv_w"].astype(x.dtype)
    out = jnp.sum(win * w[None], axis=1) + p["conv_b"].astype(x.dtype)
    xbc = jax.nn.silu(out)
    xc1, Bc1, Cc1 = xbc[:, :di], xbc[:, di:di + N], xbc[:, di + N:]
    conv_cache = win[:, 1:]

    dt1 = jax.nn.softplus(dt[:, 0].astype(F32) + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["A_log"])
    dec = jnp.exp(dt1 * A)                                      # (B,H)
    xh = xc1.reshape(Bsz, H, P).astype(F32)
    h = (cache["h"] * dec[..., None, None]
         + jnp.einsum("bn,bh,bhp->bhnp", Bc1.astype(F32), dt1, xh))
    y = jnp.einsum("bn,bhnp->bhp", Cc1.astype(F32), h)
    y = y + p["D_skip"][:, None] * xh
    y = y.reshape(Bsz, di).astype(x.dtype)
    y = _gated_norm(y[:, None], z, p["norm"], cfg.norm_eps)
    out = nn.dense(y, p["w_out"].astype(x.dtype))
    return out, {"h": h, "conv": conv_cache}
