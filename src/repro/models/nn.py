"""Minimal functional NN toolkit: params with logical axes, norms, dense.

Parameters are plain jnp arrays carried in nested dicts. During init each
leaf is a ``Param(value, axes)`` where ``axes`` names the *logical* sharding
axis of every dimension (e.g. ("embed", "mlp"));
``repro.dist.sharding.spec_for`` / ``tree_shardings`` map logical axes ->
mesh PartitionSpecs. ``split_params`` separates the value tree
from the (static) axes tree so compute functions see plain arrays.

``Param`` registers ``axes`` as pytree aux-data, so ``jax.eval_shape`` over an
init function yields the full (shapes + logical axes) tree without
allocating anything — this is what the multi-pod dry-run uses.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

# Logical axis names used across the model zoo.
# "embed"  : d_model           -> usually unsharded (or replicated)
# "mlp"    : ffn hidden        -> model axis
# "heads"  : attention heads   -> model axis
# "kv_heads": kv heads         -> model axis when divisible, else replicated
# "qkv"    : head_dim          -> unsharded
# "vocab"  : vocabulary        -> model axis
# "expert" : MoE experts       -> model axis (expert-parallel) or unsharded
# "layers" : stacked scan axis -> unsharded
# None     : replicated


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Param:
    value: Any
    axes: Tuple[Optional[str], ...]

    def tree_flatten(self):
        return (self.value,), self.axes

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], aux)


def split_params(tree):
    """Param tree -> (value tree, axes tree). Axes tree is pure python."""
    leaves_is_param = lambda x: isinstance(x, Param)
    values = jax.tree.map(lambda p: p.value, tree,
                          is_leaf=leaves_is_param)
    axes = jax.tree.map(lambda p: p.axes, tree, is_leaf=leaves_is_param)
    return values, axes


def merge_params(values, axes):
    return jax.tree.map(lambda v, a: Param(v, a), values, axes,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            isinstance(e, (str, type(None))) for e in x))


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def normal_init(key, shape, dtype, stddev):
    return stddev * jax.random.normal(key, shape, dtype)


def param(key, shape: Sequence[int], axes: Tuple[Optional[str], ...],
          dtype=jnp.float32, stddev: Optional[float] = None,
          zero: bool = False, ones: bool = False) -> Param:
    """Create one parameter. Default init: truncated-normal-ish fan-in."""
    assert len(shape) == len(axes), (shape, axes)
    if zero:
        v = jnp.zeros(shape, dtype)
    elif ones:
        v = jnp.ones(shape, dtype)
    else:
        if stddev is None:
            fan_in = shape[0] if len(shape) >= 2 else max(shape[-1], 1)
            stddev = fan_in ** -0.5
        v = normal_init(key, shape, dtype, stddev)
    return Param(v, tuple(axes))


class KeyGen:
    """Splitting helper: kg = KeyGen(key); k1 = kg(); k2 = kg()."""

    def __init__(self, key):
        self._key = key

    def __call__(self):
        self._key, k = jax.random.split(self._key)
        return k


# ---------------------------------------------------------------------------
# Compute primitives (operate on plain value trees)
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    """RMSNorm in fp32 accumulation, output in x.dtype."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def dense(x: jax.Array, w: jax.Array, bias: Optional[jax.Array] = None
          ) -> jax.Array:
    """x @ w contracting the last dim of x with the first of w."""
    y = jnp.tensordot(x, w, axes=((-1,), (0,)))
    if bias is not None:
        y = y + bias
    return y


def embed_lookup(tokens: jax.Array, table: jax.Array) -> jax.Array:
    """Token embedding lookup (tokens int32 -> (..., d))."""
    return jnp.take(table, tokens, axis=0)


def softmax_cross_entropy(logits: jax.Array, labels: jax.Array,
                          mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean CE over valid positions; logits (..., V), labels (...) int32."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - ll
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def gelu(x):
    return jax.nn.gelu(x, approximate=True)


def swiglu(x_gate: jax.Array, x_up: jax.Array) -> jax.Array:
    return jax.nn.silu(x_gate) * x_up


def count_params(values) -> int:
    return sum(int(v.size) for v in jax.tree.leaves(values))
