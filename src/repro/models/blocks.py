"""Per-layer blocks: transformer (GQA/MLA x MLP/MoE), Mamba2, xLSTM, and the
Zamba2 shared-attention hybrid wiring."""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import nn
from repro.models.attention import (gqa_decode, gqa_forward, gqa_init_cache,
                                    gqa_paged_decode, gqa_paged_init_cache,
                                    gqa_params, mla_decode, mla_forward,
                                    mla_init_cache, mla_params)
from repro.models.mlp import mlp_forward, mlp_params
from repro.models.moe import MoEStats, moe_forward, moe_params
from repro.models.ssm import (ssm_decode, ssm_forward, ssm_init_cache,
                              ssm_params)
from repro.models.xlstm import (mlstm_decode, mlstm_forward,
                                mlstm_init_cache, mlstm_params, slstm_decode,
                                slstm_forward, slstm_init_cache,
                                slstm_params)

ZERO_STATS = lambda: MoEStats(jnp.zeros(()), jnp.zeros(()))


# ---------------------------------------------------------------------------
# Transformer block (attention + MLP/MoE), pre-norm residual
# ---------------------------------------------------------------------------


def transformer_block_params(cfg: ModelConfig, kg: nn.KeyGen, pdtype,
                             moe: bool) -> Dict[str, Any]:
    p: Dict[str, Any] = {
        "ln_attn": nn.param(kg(), (cfg.d_model,), ("embed",), pdtype,
                            zero=True),
        "ln_mlp": nn.param(kg(), (cfg.d_model,), ("embed",), pdtype,
                           zero=True),
    }
    if cfg.attn_type == "mla":
        p["attn"] = mla_params(cfg, kg, pdtype)
    else:
        p["attn"] = gqa_params(cfg, kg, pdtype)
    if moe:
        p["moe"] = moe_params(cfg, kg, pdtype)
    else:
        p["mlp"] = mlp_params(cfg, kg, pdtype)
    return p


def transformer_block(p, cfg: ModelConfig, x: jax.Array,
                      positions: jax.Array, *, moe: bool,
                      mrope_pos: Optional[jax.Array] = None,
                      shard_ctx=None, q_chunk: int = 512
                      ) -> Tuple[jax.Array, MoEStats]:
    from jax.ad_checkpoint import checkpoint_name
    h = nn.rms_norm(x, p["ln_attn"], cfg.norm_eps)
    if cfg.attn_type == "mla":
        a = mla_forward(p["attn"], cfg, h, positions, q_chunk)
    else:
        a = gqa_forward(p["attn"], cfg, h, positions, mrope_pos, q_chunk)
    # names let the save_psum_outputs remat policy keep the post-all-reduce
    # activations so TP collectives are not replayed in the backward pass
    # (EXPERIMENTS.md §Perf HC2).
    x = x + checkpoint_name(a, "attn_out")
    h = nn.rms_norm(x, p["ln_mlp"], cfg.norm_eps)
    if moe:
        y, stats = moe_forward(p["moe"], cfg, h, shard_ctx)
    else:
        y, stats = mlp_forward(p["mlp"], h), ZERO_STATS()
    return x + checkpoint_name(y, "mlp_out"), stats


def transformer_block_decode(p, cfg: ModelConfig, x: jax.Array,
                             pos: jax.Array, cache, *, moe: bool,
                             mrope_pos=None, shard_ctx=None,
                             block_table=None):
    h = nn.rms_norm(x, p["ln_attn"], cfg.norm_eps)
    if block_table is not None:
        if cfg.attn_type != "gqa":
            raise ValueError(f"paged decode supports attn_type 'gqa' only, "
                             f"got {cfg.attn_type!r}")
        a, cache = gqa_paged_decode(p["attn"], cfg, h, pos, cache,
                                    block_table, mrope_pos)
    elif cfg.attn_type == "mla":
        a, cache = mla_decode(p["attn"], cfg, h, pos, cache)
    else:
        a, cache = gqa_decode(p["attn"], cfg, h, pos, cache, mrope_pos)
    x = x + a
    h = nn.rms_norm(x, p["ln_mlp"], cfg.norm_eps)
    if moe:
        y, _ = moe_forward(p["moe"], cfg, h, shard_ctx)
    else:
        y = mlp_forward(p["mlp"], h)
    return x + y, cache


def transformer_block_cache(cfg: ModelConfig, batch: int, max_len: int,
                            dtype):
    if cfg.attn_type == "mla":
        return mla_init_cache(cfg, batch, max_len, dtype)
    return gqa_init_cache(cfg, batch, max_len, dtype)


def transformer_block_paged_cache(cfg: ModelConfig, num_pages: int,
                                  page_size: int, dtype):
    if cfg.attn_type != "gqa":
        raise ValueError(f"paged KV cache supports attn_type 'gqa' only, "
                         f"got {cfg.attn_type!r}")
    return gqa_paged_init_cache(cfg, num_pages, page_size, dtype)


# ---------------------------------------------------------------------------
# Mamba2 block (pre-norm residual)
# ---------------------------------------------------------------------------


def mamba_block_params(cfg: ModelConfig, kg: nn.KeyGen, pdtype):
    return {
        "ln": nn.param(kg(), (cfg.d_model,), ("embed",), pdtype, zero=True),
        "ssm": ssm_params(cfg, kg, pdtype),
    }


def mamba_block(p, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    h = nn.rms_norm(x, p["ln"], cfg.norm_eps)
    return x + ssm_forward(p["ssm"], cfg, h)


def mamba_block_decode(p, cfg: ModelConfig, x: jax.Array, cache):
    h = nn.rms_norm(x, p["ln"], cfg.norm_eps)
    y, cache = ssm_decode(p["ssm"], cfg, h, cache)
    return x + y, cache


# ---------------------------------------------------------------------------
# xLSTM blocks (pre-norm residual)
# ---------------------------------------------------------------------------


def xlstm_block_params(cfg: ModelConfig, kg: nn.KeyGen, pdtype, kind: str):
    inner = mlstm_params(cfg, kg, pdtype) if kind == "m" else slstm_params(
        cfg, kg, pdtype)
    return {
        "ln": nn.param(kg(), (cfg.d_model,), ("embed",), pdtype, zero=True),
        "cell": inner,
    }


def xlstm_block(p, cfg: ModelConfig, x: jax.Array, kind: str) -> jax.Array:
    h = nn.rms_norm(x, p["ln"], cfg.norm_eps)
    y = (mlstm_forward(p["cell"], cfg, h) if kind == "m"
         else slstm_forward(p["cell"], cfg, h))
    return x + y


def xlstm_block_decode(p, cfg: ModelConfig, x: jax.Array, cache, kind: str):
    h = nn.rms_norm(x, p["ln"], cfg.norm_eps)
    if kind == "m":
        y, cache = mlstm_decode(p["cell"], cfg, h, cache)
    else:
        y, cache = slstm_decode(p["cell"], cfg, h, cache)
    return x + y, cache


def xlstm_block_cache(cfg: ModelConfig, batch: int, dtype, kind: str):
    return (mlstm_init_cache(cfg, batch, dtype) if kind == "m"
            else slstm_init_cache(cfg, batch, dtype))
