"""Rotary position embeddings: standard RoPE and Qwen2-VL M-RoPE."""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float = 1e4) -> jax.Array:
    """Inverse frequencies for the rotary half-dim (head_dim must be even)."""
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 1e4
               ) -> jax.Array:
    """Standard RoPE. x: (..., S, H, hd); positions: broadcastable to (..., S).

    Uses the "rotate half" convention: pairs (x[..., :half], x[..., half:]).
    """
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)                       # (half,)
    angles = positions[..., None].astype(jnp.float32) * inv  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]               # (..., S, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


def mrope_angles(positions: jax.Array, head_dim: int,
                 sections: Sequence[int], theta: float = 1e4
                 ) -> Tuple[jax.Array, jax.Array]:
    """M-RoPE (Qwen2-VL): 3-axis positions (t, h, w) -> (cos, sin).

    positions: (3, ..., S). ``sections`` splits the rotary half-dim into
    temporal/height/width bands (sums to head_dim // 2). Text tokens carry
    identical (t, h, w) so M-RoPE degenerates to standard RoPE there.
    """
    half = head_dim // 2
    assert sum(sections) == half, (sections, half)
    inv = rope_freqs(head_dim, theta)                 # (half,)
    # angles per axis: (3, ..., S, half)
    ang = positions[..., None].astype(jnp.float32) * inv
    # select which position axis drives each frequency band
    idx = jnp.repeat(jnp.arange(3), jnp.array(sections),
                     total_repeat_length=half)        # (half,)
    sel = jax.nn.one_hot(idx, 3, dtype=jnp.float32)   # (half, 3)
    ang = jnp.einsum("a...h,ha->...h", ang, sel)      # (..., S, half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_mrope(x: jax.Array, positions: jax.Array,
                sections: Sequence[int], theta: float = 1e4) -> jax.Array:
    """Apply M-RoPE to x: (..., S, H, hd), positions: (3, ..., S)."""
    cos, sin = mrope_angles(positions, x.shape[-1], sections, theta)
    cos = cos[..., None, :]                           # (..., S, 1, half)
    sin = sin[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)
