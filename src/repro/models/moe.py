"""Mixture-of-Experts layer: top-k token-choice routing, capacity dispatch.

Dispatch is **sort-based** (dropless up to the capacity factor): token copies
are ordered by expert id and scattered into an (E, C, D) buffer, so compute
is a clean grouped matmul whose FLOPs are proportional to tokens x top_k —
no (T, E, C) one-hot einsum blow-up (that would dominate cost_analysis and
wreck the roofline's useful-FLOP ratio).

Sharding modes (DESIGN.md §3):
  * ``local``  — single-device; used by smoke tests and inside shard_map.
  * ``tp``     — expert weights tensor-parallel over the model axis (every
    device holds all experts with a 1/M slice of d_ff); dispatch stays local
    to the device's tokens, one psum over 'model' combines. Robust default.
  * ``ep``     — expert-parallel: experts sharded over the model axis,
    token copies exchanged with all_to_all. Implemented in
    ``repro.dist.moe_sharding`` (``moe_sharded`` dispatches on
    ``ShardCtx.moe_impl``) and enabled per-config for the §Perf hillclimb.

The router always runs in fp32.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import nn
from repro.models.mlp import mlp_forward, mlp_params


class MoEStats(NamedTuple):
    aux_loss: jax.Array        # load-balance loss (scalar)
    dropped_frac: jax.Array    # fraction of token-copies over capacity


def moe_params(cfg: ModelConfig, kg: nn.KeyGen, pdtype) -> Dict[str, Any]:
    D, E, F = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    p: Dict[str, Any] = {
        "router": nn.param(kg(), (D, E), ("embed", None), jnp.float32,
                           stddev=D ** -0.5),
        "w_gate": nn.param(kg(), (E, D, F), ("expert", "embed", "mlp"),
                           pdtype),
        "w_up": nn.param(kg(), (E, D, F), ("expert", "embed", "mlp"),
                         pdtype),
        "w_down": nn.param(kg(), (E, F, D), ("expert", "mlp", "embed"),
                           pdtype),
    }
    if cfg.num_shared_experts:
        p["shared"] = mlp_params(
            cfg, kg, pdtype, d_ff=cfg.num_shared_experts * cfg.moe_d_ff)
    return p


def router_topk(logits: jax.Array, top_k: int
                ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """fp32 softmax -> top-k (renormalised). Returns (weights, ids, probs)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_w, top_i = jax.lax.top_k(probs, top_k)
    top_w = top_w / jnp.maximum(jnp.sum(top_w, -1, keepdims=True), 1e-9)
    return top_w, top_i, probs


def load_balance_loss(probs: jax.Array, top_i: jax.Array, E: int
                      ) -> jax.Array:
    """Switch-style aux loss: E * sum_e f_e * P_e."""
    T, k = top_i.shape
    counts = jnp.zeros((E,), jnp.float32).at[top_i.reshape(-1)].add(1.0)
    f = counts / (T * k)
    P = jnp.mean(probs, axis=0)
    return E * jnp.sum(f * P)


def dispatch_indices(top_i: jax.Array, capacity: int, E: int):
    """Sort token copies by expert; compute each copy's slot in its expert.

    Returns (token index, expert id, slot position, keep mask, sort order)
    per sorted copy.
    """
    T, k = top_i.shape
    TK = T * k
    flat_e = top_i.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T), k)
    order = jnp.argsort(flat_e, stable=True)
    se = flat_e[order]
    st = flat_t[order]
    counts = jnp.zeros((E,), jnp.int32).at[se].add(1)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(TK) - starts[se]
    keep = pos < capacity
    return st, se, pos, keep, order


def moe_local(p, cfg: ModelConfig, x: jax.Array,
              f_slice: Optional[Tuple[int, int]] = None
              ) -> Tuple[jax.Array, MoEStats]:
    """Single-device MoE on flattened tokens x: (T, D) -> (T, D).

    ``f_slice=(start, size)`` restricts expert hidden dims to a d_ff slice —
    used by the tensor-parallel wrapper (caller psums the partial output).
    """
    T, D = x.shape
    E, k = cfg.num_experts, cfg.top_k
    C = int(max(8, round(T * k / E * cfg.capacity_factor)))

    logits = x.astype(jnp.float32) @ p["router"]
    top_w, top_i, probs = router_topk(logits, k)
    aux = load_balance_loss(probs, top_i, E)

    st, se, pos, keep, order = dispatch_indices(top_i, C, E)
    flat_w = top_w.reshape(-1)[order]

    # Scatter kept copies into the (E*C, D) buffer (dummy row E*C for drops).
    idx = jnp.where(keep, se * C + pos, E * C)
    buf = jnp.zeros((E * C + 1, D), x.dtype).at[idx].set(x[st])
    buf = buf[:-1].reshape(E, C, D)

    wg, wu, wd = p["w_gate"], p["w_up"], p["w_down"]
    if f_slice is not None:
        s0, sz = f_slice
        wg = jax.lax.dynamic_slice_in_dim(wg, s0, sz, 2)
        wu = jax.lax.dynamic_slice_in_dim(wu, s0, sz, 2)
        wd = jax.lax.dynamic_slice_in_dim(wd, s0, sz, 1)
    g = jnp.einsum("ecd,edf->ecf", buf, wg.astype(x.dtype))
    u = jnp.einsum("ecd,edf->ecf", buf, wu.astype(x.dtype))
    h = nn.swiglu(g, u)
    out_buf = jnp.einsum("ecf,efd->ecd", h, wd.astype(x.dtype))

    # Gather copies back, weight, and combine per token.
    out_flat = out_buf.reshape(E * C, D)
    y_copies = jnp.where(keep[:, None], out_flat[jnp.where(
        keep, se * C + pos, 0)], 0.0)
    y_copies = y_copies * flat_w[:, None].astype(x.dtype)
    y = jnp.zeros((T, D), x.dtype).at[st].add(y_copies)

    dropped = 1.0 - jnp.sum(keep.astype(jnp.float32)) / (T * k)
    return y, MoEStats(aux_loss=aux, dropped_frac=dropped)


def moe_forward(p, cfg: ModelConfig, x: jax.Array, shard_ctx=None
                ) -> Tuple[jax.Array, MoEStats]:
    """MoE layer on (B, S, D). Routed experts + optional shared experts.

    ``shard_ctx`` (repro.dist.ShardCtx) selects the distributed impl; None
    runs the pure-local path (smoke tests / single device).
    """
    B, S, D = x.shape
    x_flat = x.reshape(B * S, D)
    if shard_ctx is None or shard_ctx.mesh is None:
        y_flat, stats = moe_local(p, cfg, x_flat)
    else:
        from repro.dist import moe_sharded  # local import: avoid cycle
        y_flat, stats = moe_sharded(p, cfg, x_flat, shard_ctx)
    y = y_flat.reshape(B, S, D)
    if cfg.num_shared_experts:
        y = y + mlp_forward(p["shared"], x)
    return y, stats
