"""Unified job CLI: ``python -m repro <command> --config job.json``.

    python -m repro train  --config experiments/jobs/paper_echo_cgc.json \
        --set train.steps=3
    python -m repro serve  --config experiments/jobs/serve_smoke.json
    python -m repro dryrun --config job.json --set dryrun.shape=train_4k
    python -m repro bench  --config job.json
    python -m repro report experiments/runs/<run_dir>   # render a run
    python -m repro list                     # registered plugins
    python -m repro show   --config job.json [--set ...]   # resolved JSON

Every command loads one :class:`repro.run.RunConfig`, applies the
dotted-path ``--set key.path=value`` overrides, and calls the matching
``repro.run`` facade. Legacy flag CLIs (``python -m repro.launch.train``
etc.) keep working as deprecation shims over the same facades.

This module must stay import-light until the command is known: dryrun
forces 512 fake host devices at import time, which only works before jax
initialises its backend.
"""
from __future__ import annotations

import argparse
import sys


def _add_job_args(sub: argparse.ArgumentParser) -> None:
    sub.add_argument("--config", required=True,
                     help="path to a RunConfig job JSON")
    sub.add_argument("--set", dest="overrides", action="append",
                     default=[], metavar="KEY.PATH=VALUE",
                     help="dotted-path override, e.g. train.steps=3 "
                          "(repeatable)")


def _load(args) -> "object":
    from repro.run import RunConfig, apply_overrides
    cfg = RunConfig.load(args.config)
    return apply_overrides(cfg, args.overrides)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro",
        description="Declarative job runner over repro.run configs")
    sub = ap.add_subparsers(dest="command", required=True)
    for name, doc in (("train", "run the training workload"),
                      ("serve", "run the serving workload"),
                      ("dryrun", "lower+compile on the production mesh"),
                      ("bench", "serving benchmark (continuous vs fixed)"),
                      ("show", "print the resolved job config JSON")):
        _add_job_args(sub.add_parser(name, help=doc))
    sub.add_parser("list", help="print every registered plugin per kind")
    rep = sub.add_parser("report",
                         help="render a finished run dir's summary "
                              "(throughput, echo rate, bits, spans)")
    rep.add_argument("run_dir", help="a run directory containing "
                                     "summary.json")
    args = ap.parse_args(argv)

    if args.command == "list":
        from repro.run import available
        for kind, names in available().items():
            print(f"{kind}: {', '.join(names)}")
        return 0

    if args.command == "report":
        # stdlib-only path: reporting never initialises jax
        from repro.obs import report as render_report
        try:
            render_report(args.run_dir)
        except (OSError, ValueError, KeyError) as e:
            raise SystemExit(f"error: {e}") from None
        return 0

    if args.command == "dryrun":
        # MUST precede any jax-initialising import: this sets the forced
        # 512-device topology dryrun compiles against.
        import repro.launch.dryrun  # noqa: F401

    try:
        cfg = _load(args)
    except (ValueError, OSError) as e:
        raise SystemExit(f"error: {e}") from None
    if args.command == "show":
        print(cfg.to_json())
        return 0

    from repro.run import facade
    try:
        if args.command == "train":
            facade.print_train_summary(facade.train(cfg))
        elif args.command == "serve":
            facade.print_serve_summary(facade.serve(cfg))
        elif args.command == "dryrun":
            res = facade.dryrun(cfg)
            print(f"[{res.summary.get('status', '?')}] record -> "
                  f"{res.record_path}")
            return 0 if res.summary.get("status") in ("ok", "skipped",
                                                      "lowered") else 1
        elif args.command == "bench":
            res = facade.bench(cfg)
            print(f"continuous/fixed tokens/s: {res.speedup:.2f}x "
                  f"(result -> {res.run_dir}/result.json)")
    except ValueError as e:
        raise SystemExit(f"error: {e}") from None
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
