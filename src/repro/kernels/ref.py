"""Pure-jnp oracles for every Pallas kernel (the correctness contracts).

Each function is the mathematically transparent implementation the kernels
are tested against with ``jnp.allclose`` over shape/dtype sweeps.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

F32 = jnp.float32


def cgc_norms_ref(G: jax.Array) -> jax.Array:
    """Row L2 norms of an (n, d) gradient stack, fp32 accumulation."""
    return jnp.sqrt(jnp.sum(G.astype(F32) ** 2, axis=-1))


def cgc_clip_ref(G: jax.Array, f: int, eps: float = 1e-12) -> jax.Array:
    """The full CGC filter (Eq. 8): clip top-f norms to the (n-f)-th norm."""
    norms = cgc_norms_ref(G)
    n = norms.shape[0]
    thr = jnp.sort(norms)[n - f - 1]
    scale = jnp.minimum(1.0, thr / jnp.maximum(norms, eps))
    return (G.astype(F32) * scale[:, None]).astype(G.dtype)


def cgc_fused_aggregate_ref(G: jax.Array, f: int, eps: float = 1e-12):
    """The fused CGC round's contract: (sum of clipped rows, row norms,
    clip scales) — the transparent chain the one-launch kernel matches."""
    norms = cgc_norms_ref(G)
    n = norms.shape[0]
    thr = jnp.sort(norms)[n - f - 1]
    scale = jnp.minimum(1.0, thr / jnp.maximum(norms, eps))
    agg = jnp.sum(G.astype(F32) * scale[:, None], axis=0)
    return agg, norms, scale


def gram_ref(A: jax.Array, g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Echo projection inputs: (A A^T, A g) for row-stacked gradients.

    A: (n, d) — the overheard reference gradients as rows; g: (d,).
    Returns (G (n, n), b (n,)) in fp32. The worker then solves G x = b
    instead of forming the Moore-Penrose pseudo-inverse explicitly.
    """
    Af = A.astype(F32)
    return Af @ Af.T, Af @ g.astype(F32)


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         mask: jax.Array) -> jax.Array:
    """GQA single-token decode attention.

    q: (B, H, hd); k/v: (B, T, K, hd) with H = K*G; mask: (B, T) bool
    (True = attend). Returns (B, H, hd) in q.dtype, fp32 softmax.
    """
    B, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    qg = q.reshape(B, K, G, hd).astype(F32)
    scores = jnp.einsum("bkgh,btkh->bkgt", qg, k.astype(F32)) * hd ** -0.5
    scores = jnp.where(mask[:, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgt,btkh->bkgh", probs, v.astype(F32))
    return out.reshape(B, H, hd).astype(q.dtype)


def gather_pages(pages: jax.Array, block_table: jax.Array) -> jax.Array:
    """Materialise a paged cache view: (P, ps, K, hd) + (B, NB) page ids
    -> (B, NB*ps, K, hd). Logical position t of sequence b lives at page
    ``block_table[b, t // ps]``, slot ``t % ps``."""
    B, NB = block_table.shape
    _, ps, K, hd = pages.shape
    return pages[block_table].reshape(B, NB * ps, K, hd)


def paged_decode_attention_ref(q: jax.Array, k_pages: jax.Array,
                               v_pages: jax.Array, block_table: jax.Array,
                               lengths: jax.Array) -> jax.Array:
    """Paged GQA decode oracle: gather the block-table view and run the
    contiguous decode attention with mask = (position < length).

    q: (B, H, hd); k_pages/v_pages: (P, ps, K, hd); block_table: (B, NB)
    int32 page ids; lengths: (B,) valid tokens per sequence (0 = fully
    masked, returns zeros). Bitwise-identical to ``decode_attention_ref``
    on the gathered contiguous cache — the consistency contract for the
    Pallas kernel and the serving paged-decode path.
    """
    k = gather_pages(k_pages, block_table)
    v = gather_pages(v_pages, block_table)
    T = k.shape[1]
    mask = jnp.arange(T)[None, :] < lengths[:, None]
    out = decode_attention_ref(q, k, v, mask)
    # a fully-masked row softmaxes uniformly over -1e30 scores; zero it so
    # inactive batch lanes carry no signal.
    return jnp.where((lengths > 0)[:, None, None], out,
                     jnp.zeros_like(out))
