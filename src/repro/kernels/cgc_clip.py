"""Pallas TPU kernels: CGC norm / clip / fused-aggregate over (n, d) stacks.

The server's aggregation phase (paper Eq. 8) streams a matrix whose row
count n is tiny (#workers) but whose row length d is huge (model
dimension) — a textbook memory-bound shape. All kernels tile d through
VMEM in (n, BLOCK_D) tiles:

  ``norms_kernel``  accumulate per-row sum-of-squares in an (n,) fp32
                    VMEM accumulator while streaming the tiles;
  ``scale_kernel``  re-stream the tiles, multiplying each row by a
                    per-row scale;
  ``fused_kernel``  the whole round in ONE pallas_call: a (2, d_blocks)
                    grid streams the table twice without ever leaving
                    the device — phase 0 accumulates sq-norms and, on
                    its last tile, derives the clip threshold (the
                    (f+1)-th largest norm) and per-row scales entirely
                    in-kernel; phase 1 re-streams, scaling rows and
                    reducing them into the (1, d) aggregate. This
                    replaces the norms -> host sort -> scale_rows -> sum
                    chain (three HBM round trips and a device->host
                    sync) with one launch.

d-tiles are MXU/VPU aligned (BLOCK_D multiple of 128); n is padded to 8
(sublane) by the wrapper in ops.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32

DEFAULT_BLOCK_D = 2048


def _norms_kernel(g_ref, out_ref, acc_ref):
    """Grid (d_blocks,). Accumulate row sum-of-squares into acc (n, 1)."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    blk = g_ref[...].astype(F32)                    # (n, BLOCK_D)
    acc_ref[...] += jnp.sum(blk * blk, axis=1, keepdims=True)

    @pl.when(i == pl.num_programs(0) - 1)
    def _done():
        out_ref[...] = acc_ref[...]


def row_sq_norms(G: jax.Array, block_d: int = DEFAULT_BLOCK_D,
                 interpret: bool = False) -> jax.Array:
    """(n, d) -> (n,) fp32 sum of squares per row."""
    n, d = G.shape
    bd = min(block_d, d)
    assert d % bd == 0, (d, bd)
    out = pl.pallas_call(
        _norms_kernel,
        grid=(d // bd,),
        in_specs=[pl.BlockSpec((n, bd), lambda i: (0, i))],
        out_specs=pl.BlockSpec((n, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 1), F32),
        scratch_shapes=[pltpu.VMEM((n, 1), F32)],
        interpret=interpret,
    )(G)
    return out[:, 0]


def _fused_kernel(g_ref, agg_ref, sq_ref, scale_ref, acc_ref, sc_ref, *,
                  f: int, n_valid: int):
    """Grid (2, d_blocks). Phase 0 accumulates row sum-of-squares into
    acc (n_pad, 1) and, at the last d-tile, derives the CGC threshold
    and per-row clip scales in-kernel (f repeated max-extractions over
    n floats — f and n are tiny, so this beats shipping n norms to the
    host for a sort). Phase 1 re-streams the tiles, writing each
    aggregate d-tile as sum_rows(g * scale)."""
    p = pl.program_id(0)
    i = pl.program_id(1)
    n_pad = acc_ref.shape[0]

    @pl.when((p == 0) & (i == 0))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(p == 0)
    def _accumulate():
        blk = g_ref[...].astype(F32)                # (n_pad, BLOCK_D)
        acc_ref[...] += jnp.sum(blk * blk, axis=1, keepdims=True)

    @pl.when((p == 0) & (i == pl.num_programs(1) - 1))
    def _threshold():
        sq = acc_ref[...]                           # (n_pad, 1)
        norms = jnp.sqrt(sq)
        # 1D iota is unsupported on TPU; build row ids as a 2D iota
        row = jax.lax.broadcasted_iota(jnp.int32, (n_pad, 1), 0)
        valid = row < n_valid
        # knock out the f largest norms (ties -> lowest row first, same
        # value the host-side sort would pick); what remains tops out at
        # the (f+1)-th largest = the clip threshold
        work = jnp.where(valid, norms, -jnp.inf)
        for _ in range(f):                          # f is static
            hit = work == jnp.max(work)
            drop = jnp.min(jnp.where(hit, row, n_pad))
            work = jnp.where(row == drop, -jnp.inf, work)
        thr = jnp.max(work)
        scale = jnp.where(
            valid, jnp.minimum(1.0, thr / jnp.maximum(norms, 1e-12)), 0.0)
        sc_ref[...] = scale                         # phase 1 reads this
        sq_ref[...] = sq
        scale_ref[...] = scale

    @pl.when(p == 1)
    def _scale_and_reduce():
        blk = g_ref[...].astype(F32)
        agg_ref[...] = jnp.sum(blk * sc_ref[...], axis=0, keepdims=True)


def cgc_fused_aggregate(G: jax.Array, f: int, n_valid: int,
                        block_d: int = DEFAULT_BLOCK_D,
                        interpret: bool = False):
    """Fused CGC round on an already-padded (n_pad, d_pad) table.

    Returns ``(agg (1, d_pad) f32, sq (n_pad, 1) f32, scale (n_pad, 1)
    f32)``; rows >= ``n_valid`` are padding (scale 0, excluded from the
    threshold). The ops.py wrapper pads/slices and exposes the public
    ``(agg, norms, scales)`` contract.
    """
    n, d = G.shape
    bd = min(block_d, d)
    assert d % bd == 0, (d, bd)
    assert 0 <= f < n_valid <= n, (f, n_valid, n)
    return pl.pallas_call(
        functools.partial(_fused_kernel, f=f, n_valid=n_valid),
        grid=(2, d // bd),
        in_specs=[pl.BlockSpec((n, bd), lambda p, i: (0, i))],
        out_specs=[pl.BlockSpec((1, bd), lambda p, i: (0, i)),
                   pl.BlockSpec((n, 1), lambda p, i: (0, 0)),
                   pl.BlockSpec((n, 1), lambda p, i: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((1, d), F32),
                   jax.ShapeDtypeStruct((n, 1), F32),
                   jax.ShapeDtypeStruct((n, 1), F32)],
        scratch_shapes=[pltpu.VMEM((n, 1), F32), pltpu.VMEM((n, 1), F32)],
        interpret=interpret,
    )(G)


def _scale_kernel(g_ref, scale_ref, out_ref):
    """Grid (d_blocks,). out = g * scale (row-broadcast)."""
    out_ref[...] = (g_ref[...].astype(F32) * scale_ref[...]).astype(
        out_ref.dtype)


def scale_rows(G: jax.Array, scale: jax.Array,
               block_d: int = DEFAULT_BLOCK_D,
               interpret: bool = False) -> jax.Array:
    n, d = G.shape
    bd = min(block_d, d)
    assert d % bd == 0, (d, bd)
    return pl.pallas_call(
        _scale_kernel,
        grid=(d // bd,),
        in_specs=[pl.BlockSpec((n, bd), lambda i: (0, i)),
                  pl.BlockSpec((n, 1), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((n, bd), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((n, d), G.dtype),
        interpret=interpret,
    )(G, scale.reshape(n, 1))
