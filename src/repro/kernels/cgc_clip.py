"""Pallas TPU kernel: fused CGC norm + clip over an (n, d) gradient stack.

The server's aggregation phase (paper Eq. 8) is two streaming passes over a
matrix whose row count n is tiny (#workers) but whose row length d is huge
(model dimension) — a textbook memory-bound shape. The kernel tiles d
through VMEM in (n, BLOCK_D) tiles:

  pass 1 (``norms_kernel``): accumulate per-row sum-of-squares in an (n,)
         fp32 VMEM accumulator while streaming the tiles;
  host:  sort n floats -> threshold = the (n-f)-th smallest norm (O(n log n)
         on n <= a few hundred — never worth a kernel);
  pass 2 (``scale_kernel``): re-stream the tiles, multiplying each row by
         min(1, thr / norm).

d-tiles are MXU/VPU aligned (BLOCK_D multiple of 128); n is padded to 8
(sublane) by the wrapper in ops.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32

DEFAULT_BLOCK_D = 2048


def _norms_kernel(g_ref, out_ref, acc_ref):
    """Grid (d_blocks,). Accumulate row sum-of-squares into acc (n, 1)."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    blk = g_ref[...].astype(F32)                    # (n, BLOCK_D)
    acc_ref[...] += jnp.sum(blk * blk, axis=1, keepdims=True)

    @pl.when(i == pl.num_programs(0) - 1)
    def _done():
        out_ref[...] = acc_ref[...]


def row_sq_norms(G: jax.Array, block_d: int = DEFAULT_BLOCK_D,
                 interpret: bool = False) -> jax.Array:
    """(n, d) -> (n,) fp32 sum of squares per row."""
    n, d = G.shape
    bd = min(block_d, d)
    assert d % bd == 0, (d, bd)
    out = pl.pallas_call(
        _norms_kernel,
        grid=(d // bd,),
        in_specs=[pl.BlockSpec((n, bd), lambda i: (0, i))],
        out_specs=pl.BlockSpec((n, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 1), F32),
        scratch_shapes=[pltpu.VMEM((n, 1), F32)],
        interpret=interpret,
    )(G)
    return out[:, 0]


def _scale_kernel(g_ref, scale_ref, out_ref):
    """Grid (d_blocks,). out = g * scale (row-broadcast)."""
    out_ref[...] = (g_ref[...].astype(F32) * scale_ref[...]).astype(
        out_ref.dtype)


def scale_rows(G: jax.Array, scale: jax.Array,
               block_d: int = DEFAULT_BLOCK_D,
               interpret: bool = False) -> jax.Array:
    n, d = G.shape
    bd = min(block_d, d)
    assert d % bd == 0, (d, bd)
    return pl.pallas_call(
        _scale_kernel,
        grid=(d // bd,),
        in_specs=[pl.BlockSpec((n, bd), lambda i: (0, i)),
                  pl.BlockSpec((n, 1), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((n, bd), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((n, d), G.dtype),
        interpret=interpret,
    )(G, scale.reshape(n, 1))
