"""Pallas TPU kernels for the paper's compute hot-spots (DESIGN.md §5).

  cgc_clip.py         — fused norm+clip over (n, d) gradients (server agg)
                        incl. the single-launch fused CGC round
                        (norms + in-kernel threshold + clip + reduce)
  codec_pack.py       — wire-codec int8 / top-k pack+unpack streaming
                        kernels (comm/wire.py quantized broadcasts)
  echo_project.py     — single-pass Gram reduction for the echo projection
  decode_attention.py — flash-decode GQA over long KV caches, contiguous
                        and paged (scalar-prefetch block-table gather)

``ops`` holds the jitted public wrappers (interpret-mode on CPU); ``ref``
holds the pure-jnp oracles every kernel is tested against.
"""
from . import ops, ref

__all__ = ["ops", "ref"]
