"""Pallas TPU kernels for the paper's compute hot-spots (DESIGN.md §5).

  cgc_clip.py         — fused norm+clip over (n, d) gradients (server agg)
  echo_project.py     — single-pass Gram reduction for the echo projection
  decode_attention.py — flash-decode GQA over long KV caches, contiguous
                        and paged (scalar-prefetch block-table gather)

``ops`` holds the jitted public wrappers (interpret-mode on CPU); ``ref``
holds the pure-jnp oracles every kernel is tested against.
"""
from . import ops, ref

__all__ = ["ops", "ref"]
