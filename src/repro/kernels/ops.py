"""Jitted public wrappers around the Pallas kernels.

On a TPU backend the kernels run compiled; everywhere else (this CPU
container, unit tests) they run in interpret mode against the same
BlockSpecs, keeping the contract identical to the ref.py oracles.

These ops pad shapes to kernel-friendly multiples (n -> multiple of 8
sublanes, d -> multiple of the d-block) and strip the padding afterwards,
so callers can use arbitrary worker counts / dimensions.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import cgc_clip as _cgc
from repro.kernels import decode_attention as _dec
from repro.kernels import echo_project as _gram
from repro.run.registry import (NORM_BACKENDS, PAGED_ATTN_BACKENDS,
                                Registry, SCALE_BACKENDS)

F32 = jnp.float32


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# ---------------------------------------------------------------------------
# Backend switches (DESIGN.md §5): the CGC hot path in
# dist/collectives.py computes gradient-pytree norms through
# ``tree_sq_norm`` below, which dispatches through the NORM_BACKENDS
# registry either to the fused Pallas streaming pass
# (cgc_clip.row_sq_norms — one kernel over the raveled gradient instead
# of a per-leaf reduction chain) or to plain jnp; scale_rows and
# paged_decode_attention dispatch the same way. Registering a new
# implementation (e.g. a cuda kernel) makes it selectable by name with
# no edits here.
# ---------------------------------------------------------------------------


class _BackendSwitch:
    """One named trace-time backend toggle (REPRO_<NAME>_BACKEND env /
    setter) over a backend registry: "auto" resolves to pallas on TPU
    and jnp elsewhere (interpret-mode pallas is correct anywhere but
    only wins on TPU); any other registered name selects that entry.

    The choice is read at TRACE time: set it before the first jit compile
    of the consuming step — already-compiled executables keep the backend
    they were traced with until ``jax.clear_caches()``.
    """

    def __init__(self, env: str, registry: Registry):
        self.env = env
        self.registry = registry
        self.value = os.environ.get(env, "auto")

    def set(self, name: str) -> None:
        if name != "auto" and name not in self.registry:
            raise ValueError(
                f"unknown {self.env} backend {name!r}; known: "
                f"{['auto'] + self.registry.names()}")
        self.value = name

    def resolve(self) -> str:
        if self.value == "auto":
            return "pallas" if _on_tpu() else "jnp"
        return self.value

    def impl(self):
        return self.registry[self.resolve()]


_norm_switch = _BackendSwitch("REPRO_NORM_BACKEND", NORM_BACKENDS)
_scale_switch = _BackendSwitch("REPRO_SCALE_BACKEND", SCALE_BACKENDS)
_paged_attn_switch = _BackendSwitch("REPRO_PAGED_ATTN_BACKEND",
                                    PAGED_ATTN_BACKENDS)


def set_norm_backend(name: str) -> None:
    """Select the sq-norm backend: "auto" | "jnp" | "pallas"."""
    _norm_switch.set(name)


def norm_backend() -> str:
    return _norm_switch.resolve()


def set_scale_backend(name: str) -> None:
    """Select the row-scaling backend (server-side CGC filter pass 2)."""
    _scale_switch.set(name)


def scale_backend() -> str:
    return _scale_switch.resolve()


def set_paged_attn_backend(name: str) -> None:
    """Select the paged decode-attention backend (repro.serve hot path)."""
    _paged_attn_switch.set(name)


def paged_attn_backend() -> str:
    return _paged_attn_switch.resolve()


@NORM_BACKENDS.register("jnp")
def _tree_sq_norm_jnp(leaves, block_d: int) -> jax.Array:
    return sum(jnp.sum(jnp.square(g.astype(F32))) for g in leaves)


@NORM_BACKENDS.register("pallas")
def _tree_sq_norm_pallas(leaves, block_d: int) -> jax.Array:
    flat = [g.astype(F32).reshape(-1) for g in leaves]
    v = jnp.concatenate(flat) if len(flat) > 1 else flat[0]
    d = v.shape[0]
    bd = min(block_d, max(128, d))
    G = _pad_to(_pad_to(v[None, :], 8, 0), bd, 1)
    return _cgc.row_sq_norms(G, bd, not _on_tpu())[0]


def tree_sq_norm(tree, block_d: int = 2048) -> jax.Array:
    """fp32 sum of squares over every leaf of ``tree`` (or leaf list).

    The "pallas" backend concatenates the raveled leaves into one (1, d)
    row and streams it through ``cgc_clip.row_sq_norms`` in
    (8, block_d) VMEM tiles — the fused pass robust aggregation uses at
    model scale. Safe inside shard_map (interpret mode off-TPU).
    """
    leaves = jax.tree.leaves(tree)
    if not leaves:
        return jnp.zeros((), F32)
    return _norm_switch.impl()(leaves, block_d)


def _pad_to(x: jax.Array, mult: int, axis: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("f", "block_d", "interpret"))
def cgc_clip(G: jax.Array, f: int, block_d: int = 2048,
             interpret: bool | None = None) -> jax.Array:
    """Fused CGC filter (Eq. 8) on an (n, d) gradient stack."""
    if interpret is None:
        interpret = not _on_tpu()
    n, d = G.shape
    bd = min(block_d, max(128, 1 << (d - 1).bit_length() if d < block_d
                          else block_d))
    Gp = _pad_to(_pad_to(G, 8, 0), bd, 1)
    sq = _cgc.row_sq_norms(Gp, bd, interpret)[:n]
    norms = jnp.sqrt(sq)
    thr = jnp.sort(norms)[n - f - 1]
    scale = jnp.minimum(1.0, thr / jnp.maximum(norms, 1e-12))
    scale_p = jnp.pad(scale, (0, Gp.shape[0] - n))
    out = _cgc.scale_rows(Gp, scale_p, bd, interpret)
    return out[:n, :d]


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def cgc_norms(G: jax.Array, block_d: int = 2048,
              interpret: bool | None = None) -> jax.Array:
    if interpret is None:
        interpret = not _on_tpu()
    n, d = G.shape
    bd = min(block_d, max(128, d))
    Gp = _pad_to(_pad_to(G, 8, 0), bd, 1)
    return jnp.sqrt(_cgc.row_sq_norms(Gp, bd, interpret)[:n])


@functools.partial(jax.jit,
                   static_argnames=("ridge", "block_d", "interpret"))
def echo_project(A: jax.Array, mask: jax.Array, g: jax.Array,
                 ridge: float = 1e-8, block_d: int = 1024,
                 interpret: bool | None = None):
    """Kernel-accelerated projection of g onto span(A[mask]).

    Same contract as repro.core.echo.project_onto_span: returns (x, echo).
    """
    if interpret is None:
        interpret = not _on_tpu()
    n, d = A.shape
    bd = min(block_d, max(128, d))
    Am = A * mask[:, None]
    Ap = _pad_to(_pad_to(Am, 8, 0), bd, 1)
    gp = _pad_to(g[None], bd, 1)[0]
    gram, b = _gram.gram_and_proj(Ap, gp, bd, interpret)
    gram, b = gram[:n, :n], b[:n]
    diag_scale = jnp.maximum(jnp.max(jnp.abs(jnp.diag(gram))), 1.0)
    off = (~mask).astype(F32)
    gram = gram + jnp.diag(off * diag_scale + ridge * diag_scale)
    x = jnp.linalg.solve(gram, b) * mask
    echo = x @ Am
    return x, echo


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     mask: jax.Array, block_t: int = 512,
                     interpret: bool | None = None) -> jax.Array:
    """Flash-decode GQA (see decode_attention.py); ref.decode_attention_ref
    is the oracle."""
    if interpret is None:
        interpret = not _on_tpu()
    B, T, K, hd = k.shape
    bt = min(block_t, T)
    if T % bt:
        k = _pad_to(k, bt, 1)
        v = _pad_to(v, bt, 1)
        mask = _pad_to(mask, bt, 1)
    return _dec.decode_attention(q, k, v, mask, bt, interpret)


@SCALE_BACKENDS.register("jnp")
def _scale_rows_jnp(G: jax.Array, scale: jax.Array,
                    block_d: int) -> jax.Array:
    return (G.astype(F32) * scale.astype(F32)[:, None]).astype(G.dtype)


@SCALE_BACKENDS.register("pallas")
def _scale_rows_pallas(G: jax.Array, scale: jax.Array,
                       block_d: int) -> jax.Array:
    n, d = G.shape
    bd = min(block_d, max(128, d))
    Gp = _pad_to(_pad_to(G, 8, 0), bd, 1)
    scale_p = jnp.pad(scale.astype(F32), (0, Gp.shape[0] - n))
    return _cgc.scale_rows(Gp, scale_p, bd, not _on_tpu())[:n, :d]


def scale_rows(G: jax.Array, scale: jax.Array,
               block_d: int = 2048) -> jax.Array:
    """Row-broadcast multiply of an (n, d) stack — pass 2 of the CGC
    filter. Dispatches via the scale backend switch: the Pallas
    ``cgc_clip.scale_rows`` streaming pass on TPU, plain jnp elsewhere
    (``REPRO_SCALE_BACKEND`` / ``set_scale_backend`` override).
    """
    return _scale_switch.impl()(G, scale, block_d)


def paged_decode_attention(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, block_table: jax.Array,
                           lengths: jax.Array,
                           interpret: bool | None = None) -> jax.Array:
    """Paged flash-decode GQA over a block-table-indexed page pool.

    q (B,H,hd); k_pages/v_pages (P,ps,K,hd); block_table (B,NB) int32
    page ids; lengths (B,) valid tokens per sequence (0 -> zeros).
    Dispatches via the paged-attn backend switch: the Pallas kernel
    (scalar-prefetch block-table gather, decode_attention.py) on TPU,
    the gather-then-attend oracle ``ref.paged_decode_attention_ref``
    elsewhere (``REPRO_PAGED_ATTN_BACKEND`` / ``set_paged_attn_backend``
    override) — the jnp path is bitwise the contiguous reference on the
    gathered view.
    """
    return _paged_attn_switch.impl()(q, k_pages, v_pages, block_table,
                                     lengths, interpret)


@PAGED_ATTN_BACKENDS.register("jnp")
def _paged_attn_jnp(q, k_pages, v_pages, block_table, lengths,
                    interpret=None):
    from repro.kernels import ref as _ref
    return _ref.paged_decode_attention_ref(q, k_pages, v_pages,
                                           block_table, lengths)


@PAGED_ATTN_BACKENDS.register("pallas")
def _paged_attn_pallas(q, k_pages, v_pages, block_table, lengths,
                       interpret=None):
    if interpret is None:
        interpret = not _on_tpu()
    return _dec.paged_decode_attention(q, k_pages, v_pages, block_table,
                                       lengths, interpret)
