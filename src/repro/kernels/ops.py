"""Jitted public wrappers around the Pallas kernels.

On a TPU backend the kernels run compiled; everywhere else (this CPU
container, unit tests) they run in interpret mode against the same
BlockSpecs, keeping the contract identical to the ref.py oracles.

These ops pad shapes to kernel-friendly multiples (n -> multiple of 8
sublanes, d -> multiple of the d-block) and strip the padding afterwards,
so callers can use arbitrary worker counts / dimensions.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro import obs
from repro.kernels import cgc_clip as _cgc
from repro.kernels import codec_pack as _pack
from repro.kernels import decode_attention as _dec
from repro.kernels import echo_project as _gram
from repro.run.registry import (CGC_BACKENDS, CODEC_PACK_BACKENDS,
                                NORM_BACKENDS, PAGED_ATTN_BACKENDS,
                                Registry, SCALE_BACKENDS)

F32 = jnp.float32


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# ---------------------------------------------------------------------------
# Backend switches (DESIGN.md §5): the CGC hot path in
# dist/collectives.py computes gradient-pytree norms through
# ``tree_sq_norm`` below, which dispatches through the NORM_BACKENDS
# registry either to the fused Pallas streaming pass
# (cgc_clip.row_sq_norms — one kernel over the raveled gradient instead
# of a per-leaf reduction chain) or to plain jnp; scale_rows and
# paged_decode_attention dispatch the same way. Registering a new
# implementation (e.g. a cuda kernel) makes it selectable by name with
# no edits here.
# ---------------------------------------------------------------------------


class _BackendSwitch:
    """One named trace-time backend toggle (REPRO_<NAME>_BACKEND env /
    setter) over a backend registry: "auto" resolves to pallas on TPU
    and jnp elsewhere (interpret-mode pallas is correct anywhere but
    only wins on TPU); any other registered name selects that entry.

    The choice is read at TRACE time: set it before the first jit compile
    of the consuming step — already-compiled executables keep the backend
    they were traced with until ``jax.clear_caches()``.

    Each resolution bumps a ``kernels.<name>.<backend>`` counter on the
    active tracker. Because dispatch happens at trace time, the counters
    measure how often each backend is *traced into* a compilation, not
    per-device-call frequency — exactly the question "which backend did
    my run actually compile?" that the obs layer answers.
    """

    def __init__(self, name: str, env: str, registry: Registry):
        self.name = name
        self.env = env
        self.registry = registry
        self.value = os.environ.get(env, "auto")

    def set(self, name: str) -> None:
        if name != "auto" and name not in self.registry:
            raise ValueError(
                f"unknown {self.env} backend {name!r}; known: "
                f"{['auto'] + self.registry.names()}")
        self.value = name

    def resolve(self) -> str:
        if self.value == "auto":
            return "pallas" if _on_tpu() else "jnp"
        return self.value

    def impl(self):
        resolved = self.resolve()
        obs.counter(f"kernels.{self.name}.{resolved}")
        return self.registry[resolved]


_norm_switch = _BackendSwitch("norm", "REPRO_NORM_BACKEND", NORM_BACKENDS)
_scale_switch = _BackendSwitch("scale", "REPRO_SCALE_BACKEND",
                               SCALE_BACKENDS)
_paged_attn_switch = _BackendSwitch("paged_attn",
                                    "REPRO_PAGED_ATTN_BACKEND",
                                    PAGED_ATTN_BACKENDS)
_cgc_switch = _BackendSwitch("cgc", "REPRO_CGC_BACKEND", CGC_BACKENDS)
_codec_switch = _BackendSwitch("codec_pack", "REPRO_CODEC_BACKEND",
                               CODEC_PACK_BACKENDS)


def set_norm_backend(name: str) -> None:
    """Select the sq-norm backend: "auto" | "jnp" | "pallas"."""
    _norm_switch.set(name)


def norm_backend() -> str:
    return _norm_switch.resolve()


def set_scale_backend(name: str) -> None:
    """Select the row-scaling backend (server-side CGC filter pass 2)."""
    _scale_switch.set(name)


def scale_backend() -> str:
    return _scale_switch.resolve()


def set_paged_attn_backend(name: str) -> None:
    """Select the paged decode-attention backend (repro.serve hot path)."""
    _paged_attn_switch.set(name)


def paged_attn_backend() -> str:
    return _paged_attn_switch.resolve()


def set_cgc_backend(name: str) -> None:
    """Select the fused CGC aggregation backend (server-side round)."""
    _cgc_switch.set(name)


def cgc_backend() -> str:
    return _cgc_switch.resolve()


def set_codec_pack_backend(name: str) -> None:
    """Select the wire-codec pack/unpack backend (comm/wire.py)."""
    _codec_switch.set(name)


def codec_pack_backend() -> str:
    return _codec_switch.resolve()


@NORM_BACKENDS.register("jnp")
def _tree_sq_norm_jnp(leaves, block_d: int) -> jax.Array:
    return sum(jnp.sum(jnp.square(g.astype(F32))) for g in leaves)


@NORM_BACKENDS.register("pallas")
def _tree_sq_norm_pallas(leaves, block_d: int) -> jax.Array:
    flat = [g.astype(F32).reshape(-1) for g in leaves]
    v = jnp.concatenate(flat) if len(flat) > 1 else flat[0]
    bd = _block_for(v.shape[0], block_d)
    G = pad_rows(v[None, :], bd)
    return _cgc.row_sq_norms(G, bd, not _on_tpu())[0]


def tree_sq_norm(tree, block_d: int = 2048) -> jax.Array:
    """fp32 sum of squares over every leaf of ``tree`` (or leaf list).

    The "pallas" backend concatenates the raveled leaves into one (1, d)
    row and streams it through ``cgc_clip.row_sq_norms`` in
    (8, block_d) VMEM tiles — the fused pass robust aggregation uses at
    model scale. Safe inside shard_map (interpret mode off-TPU).
    """
    leaves = jax.tree.leaves(tree)
    if not leaves:
        return jnp.zeros((), F32)
    return _norm_switch.impl()(leaves, block_d)


def _pad_to(x: jax.Array, mult: int, axis: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _block_for(d: int, block_d: int) -> int:
    """The d-tile for a row of length d: ``block_d`` once rows are long
    enough, else the next power of two (>= 128, so tiles stay
    lane-aligned — ``max(128, d)`` would hand pallas an unaligned tile
    for d like 1000)."""
    if d >= block_d:
        return block_d
    return min(block_d, max(128, 1 << (d - 1).bit_length()))


def pad_rows(G: jax.Array, block_d: int) -> jax.Array:
    """Pad an (n, d) stack to kernel shape: n -> multiple of 8 sublanes,
    d -> multiple of ``block_d``. The one padding path every row-stack
    kernel wrapper shares; a no-op (same array, no copy) when the caller
    already holds a padded table."""
    return _pad_to(_pad_to(G, 8, 0), block_d, 1)


@functools.partial(jax.jit, static_argnames=("f", "block_d", "interpret"))
def cgc_clip(G: jax.Array, f: int, block_d: int = 2048,
             interpret: bool | None = None) -> jax.Array:
    """Fused CGC filter (Eq. 8) on an (n, d) gradient stack."""
    if interpret is None:
        interpret = not _on_tpu()
    n, d = G.shape
    bd = _block_for(d, block_d)
    Gp = pad_rows(G, bd)
    sq = _cgc.row_sq_norms(Gp, bd, interpret)[:n]
    norms = jnp.sqrt(sq)
    thr = jnp.sort(norms)[n - f - 1]
    scale = jnp.minimum(1.0, thr / jnp.maximum(norms, 1e-12))
    scale_p = jnp.pad(scale, (0, Gp.shape[0] - n))
    out = _cgc.scale_rows(Gp, scale_p, bd, interpret)
    return out[:n, :d]


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def cgc_norms(G: jax.Array, block_d: int = 2048,
              interpret: bool | None = None) -> jax.Array:
    if interpret is None:
        interpret = not _on_tpu()
    n, d = G.shape
    bd = _block_for(d, block_d)
    Gp = pad_rows(G, bd)
    return jnp.sqrt(_cgc.row_sq_norms(Gp, bd, interpret)[:n])


@functools.partial(jax.jit,
                   static_argnames=("ridge", "block_d", "interpret"))
def echo_project(A: jax.Array, mask: jax.Array, g: jax.Array,
                 ridge: float = 1e-8, block_d: int = 1024,
                 interpret: bool | None = None):
    """Kernel-accelerated projection of g onto span(A[mask]).

    Same contract as repro.core.echo.project_onto_span: returns (x, echo).
    """
    if interpret is None:
        interpret = not _on_tpu()
    n, d = A.shape
    bd = _block_for(d, block_d)
    Am = A * mask[:, None]
    Ap = pad_rows(Am, bd)
    gp = _pad_to(g[None], bd, 1)[0]
    gram, b = _gram.gram_and_proj(Ap, gp, bd, interpret)
    gram, b = gram[:n, :n], b[:n]
    diag_scale = jnp.maximum(jnp.max(jnp.abs(jnp.diag(gram))), 1.0)
    off = (~mask).astype(F32)
    gram = gram + jnp.diag(off * diag_scale + ridge * diag_scale)
    x = jnp.linalg.solve(gram, b) * mask
    echo = x @ Am
    return x, echo


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     mask: jax.Array, block_t: int = 512,
                     interpret: bool | None = None) -> jax.Array:
    """Flash-decode GQA (see decode_attention.py); ref.decode_attention_ref
    is the oracle."""
    if interpret is None:
        interpret = not _on_tpu()
    B, T, K, hd = k.shape
    bt = min(block_t, T)
    if T % bt:
        k = _pad_to(k, bt, 1)
        v = _pad_to(v, bt, 1)
        mask = _pad_to(mask, bt, 1)
    return _dec.decode_attention(q, k, v, mask, bt, interpret)


@SCALE_BACKENDS.register("jnp")
def _scale_rows_jnp(G: jax.Array, scale: jax.Array,
                    block_d: int) -> jax.Array:
    return (G.astype(F32) * scale.astype(F32)[:, None]).astype(G.dtype)


@SCALE_BACKENDS.register("pallas")
def _scale_rows_pallas(G: jax.Array, scale: jax.Array,
                       block_d: int) -> jax.Array:
    n, d = G.shape
    bd = _block_for(d, block_d)
    Gp = pad_rows(G, bd)
    scale_p = jnp.pad(scale.astype(F32), (0, Gp.shape[0] - n))
    return _cgc.scale_rows(Gp, scale_p, bd, not _on_tpu())[:n, :d]


def scale_rows(G: jax.Array, scale: jax.Array,
               block_d: int = 2048) -> jax.Array:
    """Row-broadcast multiply of an (n, d) stack — pass 2 of the CGC
    filter. Dispatches via the scale backend switch: the Pallas
    ``cgc_clip.scale_rows`` streaming pass on TPU, plain jnp elsewhere
    (``REPRO_SCALE_BACKEND`` / ``set_scale_backend`` override).
    """
    return _scale_switch.impl()(G, scale, block_d)


# ---------------------------------------------------------------------------
# Fused CGC aggregation (the whole server-side round in one dispatch)
# ---------------------------------------------------------------------------


@CGC_BACKENDS.register("jnp")
def _cgc_fused_jnp(G: jax.Array, f: int, block_d: int):
    """Reference backend: bitwise-identical to
    ``sum(core.cgc.cgc_filter(G, f))`` under the jnp scale backend (same
    norm, threshold, scale, cast and reduction order)."""
    from repro.core.cgc import cgc_scales
    norms = jnp.linalg.norm(G, axis=-1)
    scales = cgc_scales(norms, f)
    scaled = (G.astype(F32) * scales.astype(F32)[:, None]).astype(G.dtype)
    scaled = scaled.astype(jnp.result_type(G.dtype, scales.dtype))
    return jnp.sum(scaled, axis=0), norms, scales


@CGC_BACKENDS.register("pallas")
def _cgc_fused_pallas(G: jax.Array, f: int, block_d: int):
    n, d = G.shape
    bd = _block_for(d, block_d)
    Gp = pad_rows(G, bd)
    agg, sq, scale = _cgc.cgc_fused_aggregate(Gp, f, n, bd, not _on_tpu())
    out_dtype = jnp.result_type(G.dtype, F32)
    return (agg[0, :d].astype(out_dtype), jnp.sqrt(sq[:n, 0]),
            scale[:n, 0])


def cgc_fused_aggregate(G: jax.Array, f: int, block_d: int = 2048):
    """One-dispatch CGC round on an (n, d) stack: returns
    ``(aggregate (d,), norms (n,), scales (n,))``.

    Replaces the norms -> host-side sort -> ``scale_rows`` -> sum chain
    of ``core.cgc``: the "pallas" backend streams the table through
    ``cgc_clip.cgc_fused_aggregate`` (threshold derived in-kernel, no
    device->host sync, no (n, d) intermediate); the "jnp" backend is the
    bitwise reference chain (``REPRO_CGC_BACKEND`` / ``set_cgc_backend``
    override). ``f`` must be a static python int.
    """
    n = G.shape[0]
    if not 0 <= f < n:
        raise ValueError(f"need 0 <= f < n, got f={f}, n={n}")
    return _cgc_switch.impl()(G, f, block_d)


# ---------------------------------------------------------------------------
# Wire-codec pack/unpack (comm/wire.py quantized broadcasts)
# ---------------------------------------------------------------------------


def _codec_layout(m: int, block_c: int):
    """Tile layout for a length-m vector: columns of the (ROWS, cols)
    reshape plus the lane tile, cols a multiple of the tile."""
    need = -(-m // _pack.ROWS)
    bc = _block_for(need, block_c)
    return -(-need // bc) * bc, bc


def _as_tiles(v: jax.Array, cols: int) -> jax.Array:
    v = v.astype(F32).reshape(-1)
    return jnp.pad(v, (0, _pack.ROWS * cols - v.shape[0])).reshape(
        _pack.ROWS, cols)


class _JnpCodecPack:
    """Bitwise replica of the inline comm/wire.py codec math."""

    @staticmethod
    def int8_pack(v, block_c):
        v = v.astype(F32)
        scale = jnp.maximum(jnp.max(jnp.abs(v)), 1e-30) / 127.0
        q = jnp.clip(jnp.round(v / scale), -127, 127).astype(jnp.int8)
        return q, scale.astype(F32)

    @staticmethod
    def int8_unpack(q, scale, m, block_c):
        return q.astype(F32) * scale

    @staticmethod
    def topk_pack(v, k, block_c):
        v = v.astype(F32)
        kk = min(k, v.shape[-1])
        _, idx = jax.lax.top_k(jnp.abs(v), kk)
        return v[idx], idx.astype(jnp.int32)

    @staticmethod
    def topk_unpack(vals, idx, m, block_c):
        return jnp.zeros((m,), F32).at[idx].set(vals)


class _PallasCodecPack:
    """Streaming codec_pack.py kernels over the (ROWS, cols) tiling."""

    @staticmethod
    def int8_pack(v, block_c):
        m = v.shape[-1]
        cols, bc = _codec_layout(m, block_c)
        q, scale = _pack.int8_pack(_as_tiles(v, cols), bc, not _on_tpu())
        return q.reshape(-1)[:m], scale[0, 0]

    @staticmethod
    def int8_unpack(q, scale, m, block_c):
        cols, bc = _codec_layout(m, block_c)
        qt = jnp.pad(q.reshape(-1), (0, _pack.ROWS * cols - m)).reshape(
            _pack.ROWS, cols)
        return _pack.int8_unpack(qt, scale, bc, not _on_tpu()
                                 ).reshape(-1)[:m]

    @staticmethod
    def topk_pack(v, k, block_c):
        m = v.shape[-1]
        kk = min(k, m)
        cols, bc = _codec_layout(m, block_c)
        while _pack.ROWS * bc < kk:      # every tile must hold >= kk
            bc *= 2
            cols = -(-cols // bc) * bc
        vals_c, idx_c = _pack.topk_pack_candidates(
            _as_tiles(v, cols), kk, bc, not _on_tpu())
        flat_v, flat_i = vals_c.reshape(-1), idx_c.reshape(-1)
        # exact global top-k over the tiny candidate table, with
        # lax.top_k's tie order (descending |v|, then ascending index);
        # tile pad slots (idx -1) and v's zero padding (idx >= m) lose
        valid = (flat_i >= 0) & (flat_i < m)
        key = jnp.where(valid, jnp.abs(flat_v), -1.0)
        rank = jnp.where(valid, flat_i, jnp.iinfo(jnp.int32).max)
        sel = jnp.lexsort((rank, -key))[:kk]
        return flat_v[sel], flat_i[sel].astype(jnp.int32)

    @staticmethod
    def topk_unpack(vals, idx, m, block_c):
        cols, bc = _codec_layout(m, block_c)
        return _pack.topk_unpack(vals, idx, cols, bc, not _on_tpu()
                                 ).reshape(-1)[:m]


CODEC_PACK_BACKENDS.add("jnp", _JnpCodecPack)
CODEC_PACK_BACKENDS.add("pallas", _PallasCodecPack)


def int8_pack(v: jax.Array, block_c: int = _pack.DEFAULT_BLOCK_C):
    """(m,) float -> ((m,) int8, () fp32 absmax scale). The Int8Codec
    encode path; dispatches via ``REPRO_CODEC_BACKEND``."""
    return _codec_switch.impl().int8_pack(v, block_c)


def int8_unpack(q: jax.Array, scale: jax.Array, m: int,
                block_c: int = _pack.DEFAULT_BLOCK_C) -> jax.Array:
    """((m,) int8, scale) -> (m,) fp32 dequantized."""
    return _codec_switch.impl().int8_unpack(q, scale, m, block_c)


def topk_pack(v: jax.Array, k: int,
              block_c: int = _pack.DEFAULT_BLOCK_C):
    """(m,) float -> ((kk,) values, (kk,) int32 indices), kk=min(k, m),
    ordered exactly as ``lax.top_k`` over |v|."""
    return _codec_switch.impl().topk_pack(v, k, block_c)


def topk_unpack(vals: jax.Array, idx: jax.Array, m: int,
                block_c: int = _pack.DEFAULT_BLOCK_C) -> jax.Array:
    """Sparse (values, indices) -> (m,) dense fp32."""
    return _codec_switch.impl().topk_unpack(vals, idx, m, block_c)


def paged_decode_attention(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, block_table: jax.Array,
                           lengths: jax.Array,
                           interpret: bool | None = None) -> jax.Array:
    """Paged flash-decode GQA over a block-table-indexed page pool.

    q (B,H,hd); k_pages/v_pages (P,ps,K,hd); block_table (B,NB) int32
    page ids; lengths (B,) valid tokens per sequence (0 -> zeros).
    Dispatches via the paged-attn backend switch: the Pallas kernel
    (scalar-prefetch block-table gather, decode_attention.py) on TPU,
    the gather-then-attend oracle ``ref.paged_decode_attention_ref``
    elsewhere (``REPRO_PAGED_ATTN_BACKEND`` / ``set_paged_attn_backend``
    override) — the jnp path is bitwise the contiguous reference on the
    gathered view.
    """
    return _paged_attn_switch.impl()(q, k_pages, v_pages, block_table,
                                     lengths, interpret)


@PAGED_ATTN_BACKENDS.register("jnp")
def _paged_attn_jnp(q, k_pages, v_pages, block_table, lengths,
                    interpret=None):
    from repro.kernels import ref as _ref
    return _ref.paged_decode_attention_ref(q, k_pages, v_pages,
                                           block_table, lengths)


@PAGED_ATTN_BACKENDS.register("pallas")
def _paged_attn_pallas(q, k_pages, v_pages, block_table, lengths,
                       interpret=None):
    if interpret is None:
        interpret = not _on_tpu()
    return _dec.paged_decode_attention(q, k_pages, v_pages, block_table,
                                       lengths, interpret)
