"""Pallas TPU kernels: wire-codec pack/unpack (comm/wire.py hot path).

Quantized-broadcast scenarios run every worker's gradient through a
codec roundtrip each round; at model scale that is pure bandwidth work,
so the int8 and top-k codecs get streaming kernels here, dispatched
behind ``kernels.ops`` (``REPRO_CODEC_BACKEND``). Layout: a length-m
vector is zero-padded and reshaped to ``(ROWS, cols)`` so every tile is
a legal TPU block (int8 wants 32 sublanes; fp32 wants 8 — we use 32 for
both so pack in/out tiles agree), and the kernels stream ``(ROWS,
BLOCK_C)`` column tiles through VMEM:

  int8 pack    (2, c_blocks) grid: phase 0 accumulates per-row absmax
               and, on its last tile, folds it to the global fp32 scale
               (absmax/127) in scratch; phase 1 re-streams, emitting
               clip(round(v/scale)) int8 tiles — one launch instead of
               the jnp max -> div -> round -> clip chain re-reading v.
  int8 unpack  one pass: q * scale.
  topk pack    per-tile candidate extraction: each tile yields its k
               largest-|v| entries (ties -> lowest flat index, matching
               ``lax.top_k`` stability) as (value, flat-index) rows; the
               tiny (c_blocks, k) candidate table is reduced to the
               exact global top-k by the ops.py wrapper.
  topk unpack  one pass over the dense output: each tile selects the
               shipped values whose flat index lands in it.

All kernels are bitwise-faithful to the jnp codec math on the same
input (max/round/clip order preserved; top-k tie-breaks replicated), so
``comm.wire`` can swap backends without perturbing bit accounting or
trajectories.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32

ROWS = 32               # sublane count: int8's minimum tile, fine for f32
DEFAULT_BLOCK_C = 512   # lane tile (multiple of 128)


def _flat_index(cols: int, i: int, bc: int, shape):
    """Global flat index of each element of column-tile i under the
    row-major (ROWS, cols) layout: r * cols + i * bc + c."""
    r = jax.lax.broadcasted_iota(jnp.int32, shape, 0)
    c = jax.lax.broadcasted_iota(jnp.int32, shape, 1)
    return r * cols + i * bc + c


# ---------------------------------------------------------------------------
# int8 absmax quantization
# ---------------------------------------------------------------------------


def _int8_pack_kernel(v_ref, q_ref, scale_ref, amax_ref, s_ref):
    """Grid (2, c_blocks): phase 0 absmax reduce, phase 1 quantize."""
    p = pl.program_id(0)
    i = pl.program_id(1)

    @pl.when((p == 0) & (i == 0))
    def _init():
        amax_ref[...] = jnp.zeros_like(amax_ref)

    @pl.when(p == 0)
    def _absmax():
        amax_ref[...] = jnp.maximum(
            amax_ref[...],
            jnp.max(jnp.abs(v_ref[...]), axis=1, keepdims=True))

    @pl.when((p == 0) & (i == pl.num_programs(1) - 1))
    def _scale():
        s = jnp.maximum(jnp.max(amax_ref[...]), 1e-30) / 127.0
        s_ref[0, 0] = s
        scale_ref[0, 0] = s

    @pl.when(p == 1)
    def _quantize():
        q = jnp.round(v_ref[...] / s_ref[0, 0])
        q_ref[...] = jnp.clip(q, -127, 127).astype(jnp.int8)


def int8_pack(V: jax.Array, block_c: int = DEFAULT_BLOCK_C,
              interpret: bool = False):
    """(ROWS, cols) fp32 -> ((ROWS, cols) int8, (1, 1) fp32 scale)."""
    r, cols = V.shape
    assert r == ROWS and cols % block_c == 0, (V.shape, block_c)
    return pl.pallas_call(
        _int8_pack_kernel,
        grid=(2, cols // block_c),
        in_specs=[pl.BlockSpec((ROWS, block_c), lambda p, i: (0, i))],
        out_specs=[pl.BlockSpec((ROWS, block_c), lambda p, i: (0, i)),
                   pl.BlockSpec((1, 1), lambda p, i: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((ROWS, cols), jnp.int8),
                   jax.ShapeDtypeStruct((1, 1), F32)],
        scratch_shapes=[pltpu.VMEM((ROWS, 1), F32),
                        pltpu.VMEM((1, 1), F32)],
        interpret=interpret,
    )(V)


def _int8_unpack_kernel(q_ref, scale_ref, out_ref):
    out_ref[...] = q_ref[...].astype(F32) * scale_ref[0, 0]


def int8_unpack(Q: jax.Array, scale: jax.Array,
                block_c: int = DEFAULT_BLOCK_C,
                interpret: bool = False) -> jax.Array:
    """((ROWS, cols) int8, scale) -> (ROWS, cols) fp32 dequantized."""
    r, cols = Q.shape
    assert r == ROWS and cols % block_c == 0, (Q.shape, block_c)
    return pl.pallas_call(
        _int8_unpack_kernel,
        grid=(cols // block_c,),
        in_specs=[pl.BlockSpec((ROWS, block_c), lambda i: (0, i)),
                  pl.BlockSpec((1, 1), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((ROWS, block_c), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((ROWS, cols), F32),
        interpret=interpret,
    )(Q, scale.reshape(1, 1).astype(F32))


# ---------------------------------------------------------------------------
# top-k sparsification
# ---------------------------------------------------------------------------


def _topk_pack_kernel(v_ref, vals_ref, idx_ref, *, k: int, cols: int,
                      bc: int, kp: int):
    """Grid (c_blocks,). Extract this tile's k largest-|v| candidates
    (ties -> lowest flat index) into padded (1, kp) rows; slots past k
    carry idx -1."""
    i = pl.program_id(0)
    blk = v_ref[...]                                  # (ROWS, bc)
    gidx = _flat_index(cols, i, bc, blk.shape)
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, kp), 1)
    vals_row = jnp.zeros((1, kp), F32)
    idx_row = jnp.full((1, kp), -1, jnp.int32)
    work = jnp.abs(blk)
    big = cols * ROWS
    for j in range(k):                                # k is static
        hit = work == jnp.max(work)
        first = jnp.min(jnp.where(hit, gidx, big))
        val = jnp.sum(jnp.where(gidx == first, blk, 0.0))
        vals_row = jnp.where(lane == j, val, vals_row)
        idx_row = jnp.where(lane == j, first, idx_row)
        work = jnp.where(gidx == first, -1.0, work)   # below any |v|
    vals_ref[...] = vals_row
    idx_ref[...] = idx_row


def topk_pack_candidates(V: jax.Array, k: int,
                         block_c: int = DEFAULT_BLOCK_C,
                         interpret: bool = False):
    """(ROWS, cols) fp32 -> ((c_blocks, kp) values, (c_blocks, kp) int32
    flat indices): per-tile top-k candidates, kp = k padded to a lane
    multiple (pad slots have idx -1). The exact global top-k is a subset
    of these candidates as long as each tile holds >= k elements."""
    r, cols = V.shape
    assert r == ROWS and cols % block_c == 0, (V.shape, block_c)
    assert ROWS * block_c >= k, (block_c, k)
    nblk = cols // block_c
    kp = -(-k // 128) * 128
    return pl.pallas_call(
        functools.partial(_topk_pack_kernel, k=k, cols=cols, bc=block_c,
                          kp=kp),
        grid=(nblk,),
        in_specs=[pl.BlockSpec((ROWS, block_c), lambda i: (0, i))],
        out_specs=[pl.BlockSpec((1, kp), lambda i: (i, 0)),
                   pl.BlockSpec((1, kp), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((nblk, kp), F32),
                   jax.ShapeDtypeStruct((nblk, kp), jnp.int32)],
        interpret=interpret,
    )(V)


def _topk_unpack_kernel(vals_ref, idx_ref, out_ref, *, k: int, cols: int,
                        bc: int):
    """Grid (c_blocks,). Scatter the k shipped (value, flat index) pairs
    into the dense tile they land in (idx -1 never matches)."""
    i = pl.program_id(0)
    gidx = _flat_index(cols, i, bc, (ROWS, bc))
    acc = jnp.zeros((ROWS, bc), F32)
    for j in range(k):                                # k is static
        acc = jnp.where(gidx == idx_ref[0, j], vals_ref[0, j], acc)
    out_ref[...] = acc


def topk_unpack(vals: jax.Array, idx: jax.Array, cols: int,
                block_c: int = DEFAULT_BLOCK_C,
                interpret: bool = False) -> jax.Array:
    """((k,) values, (k,) int32 flat indices) -> (ROWS, cols) dense."""
    k = vals.shape[0]
    assert cols % block_c == 0, (cols, block_c)
    return pl.pallas_call(
        functools.partial(_topk_unpack_kernel, k=k, cols=cols, bc=block_c),
        grid=(cols // block_c,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM),
                  pl.BlockSpec(memory_space=pltpu.SMEM)],
        out_specs=pl.BlockSpec((ROWS, block_c), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((ROWS, cols), F32),
        interpret=interpret,
    )(vals.reshape(1, k).astype(F32), idx.reshape(1, k).astype(jnp.int32))
