"""Pallas TPU kernel: fused Gram reduction for the echo projection.

The paper's worker computes x = (A^T A)^{-1} A^T g with A the d x |R| matrix
of overheard gradients (d up to 10^7, |R| <= n). Forming the Moore-Penrose
inverse explicitly materialises an |R| x d matrix — pointless data movement
on TPU. The TPU-rethink (DESIGN.md §5): stream A (stored row-major, (n, d))
and g through VMEM once, accumulating BOTH

    G = A A^T   (n x n Gram)      and      b = A g   (n,)

in a single pass, then solve the tiny ridge system G x = b on the host side
of the op (jnp.linalg.solve on an (n, n) matrix). One kernel, one read of
the gradients, MXU-shaped (n_pad x BLOCK_D) @ (BLOCK_D x n_pad) per tile.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32

DEFAULT_BLOCK_D = 1024


def _gram_kernel(a_ref, g_ref, gram_ref, b_ref, gram_acc, b_acc):
    """Grid (d_blocks,). gram += A_blk @ A_blk^T; b += A_blk @ g_blk."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        gram_acc[...] = jnp.zeros_like(gram_acc)
        b_acc[...] = jnp.zeros_like(b_acc)

    a = a_ref[...].astype(F32)                       # (n, BLOCK_D)
    g = g_ref[...].astype(F32)                       # (1, BLOCK_D)
    gram_acc[...] += jax.lax.dot_general(
        a, a, (((1,), (1,)), ((), ())),
        preferred_element_type=F32)                  # (n, n)
    b_acc[...] += jnp.sum(a * g, axis=1, keepdims=True)

    @pl.when(i == pl.num_programs(0) - 1)
    def _done():
        gram_ref[...] = gram_acc[...]
        b_ref[...] = b_acc[...]


def gram_and_proj(A: jax.Array, g: jax.Array,
                  block_d: int = DEFAULT_BLOCK_D,
                  interpret: bool = False):
    """(A (n, d), g (d,)) -> (A A^T (n, n), A g (n,)), fp32, one pass."""
    n, d = A.shape
    bd = min(block_d, d)
    assert d % bd == 0, (d, bd)
    gram, b = pl.pallas_call(
        _gram_kernel,
        grid=(d // bd,),
        in_specs=[pl.BlockSpec((n, bd), lambda i: (0, i)),
                  pl.BlockSpec((1, bd), lambda i: (0, i))],
        out_specs=[pl.BlockSpec((n, n), lambda i: (0, 0)),
                   pl.BlockSpec((n, 1), lambda i: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((n, n), F32),
                   jax.ShapeDtypeStruct((n, 1), F32)],
        scratch_shapes=[pltpu.VMEM((n, n), F32), pltpu.VMEM((n, 1), F32)],
        interpret=interpret,
    )(A, g.reshape(1, d))
    return gram, b[:, 0]
