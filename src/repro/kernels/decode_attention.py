"""Pallas TPU kernel: flash-decode GQA — one query token vs a long KV cache.

The serving hot-spot for decode_32k / long_500k: memory-bound streaming of
the (T, K, hd) cache with an online-softmax accumulator. Grid is
(batch, kv_blocks); TPU executes the last grid dimension sequentially per
batch row, so the (H, hd) output accumulator + (H,) running max / sum live
in VMEM scratch across kv blocks and are finalised on the last block.

Masking: the caller passes a (B, T) bool mask (valid cache slots, causal /
sliding-window semantics already applied — same contract as ref.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32
NEG_INF = -1e30

DEFAULT_BLOCK_T = 512


def _decode_kernel(q_ref, k_ref, v_ref, mask_ref, out_ref,
                   acc_ref, m_ref, l_ref, *, n_groups: int):
    """Grid (B, T_blocks). Online softmax over kv blocks."""
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(F32)                         # (H, hd)
    k = k_ref[0].astype(F32)                         # (bt, K, hd)
    v = v_ref[0].astype(F32)                         # (bt, K, hd)
    mask = mask_ref[0]                               # (bt,)
    H, hd = q.shape
    bt, K, _ = k.shape
    G = n_groups

    qg = q.reshape(K, G, hd)
    s = jnp.einsum("kgh,tkh->kgt", qg, k,
                   preferred_element_type=F32) * hd ** -0.5  # (K, G, bt)
    s = jnp.where(mask[None, None, :], s, NEG_INF)

    m_prev = m_ref[...]                              # (K, G)
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[..., None])                # (K, G, bt)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
    pv = jnp.einsum("kgt,tkh->kgh", p, v,
                    preferred_element_type=F32)      # (K, G, hd)
    acc_ref[...] = acc_ref[...] * alpha[..., None] + pv
    m_ref[...] = m_cur

    @pl.when(t == pl.num_programs(1) - 1)
    def _done():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[..., None]
        out_ref[0] = out.reshape(H, hd).astype(out_ref.dtype)


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     mask: jax.Array, block_t: int = DEFAULT_BLOCK_T,
                     interpret: bool = False) -> jax.Array:
    """q (B,H,hd), k/v (B,T,K,hd), mask (B,T) -> (B,H,hd)."""
    B, H, hd = q.shape
    _, T, K, _ = k.shape
    G = H // K
    bt = min(block_t, T)
    assert T % bt == 0, (T, bt)
    kern = functools.partial(_decode_kernel, n_groups=G)
    return pl.pallas_call(
        kern,
        grid=(B, T // bt),
        in_specs=[
            pl.BlockSpec((1, H, hd), lambda b, t: (b, 0, 0)),
            pl.BlockSpec((1, bt, K, hd), lambda b, t: (b, t, 0, 0)),
            pl.BlockSpec((1, bt, K, hd), lambda b, t: (b, t, 0, 0)),
            pl.BlockSpec((1, bt), lambda b, t: (b, t)),
        ],
        out_specs=pl.BlockSpec((1, H, hd), lambda b, t: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, hd), q.dtype),
        scratch_shapes=[pltpu.VMEM((K, G, hd), F32),
                        pltpu.VMEM((K, G), F32),
                        pltpu.VMEM((K, G), F32)],
        interpret=interpret,
    )(q, k, v, mask)
