"""Pallas TPU kernel: flash-decode GQA — one query token vs a long KV cache.

The serving hot-spot for decode_32k / long_500k: memory-bound streaming of
the (T, K, hd) cache with an online-softmax accumulator. Grid is
(batch, kv_blocks); TPU executes the last grid dimension sequentially per
batch row, so the (H, hd) output accumulator + (H,) running max / sum live
in VMEM scratch across kv blocks and are finalised on the last block.

Masking: the caller passes a (B, T) bool mask (valid cache slots, causal /
sliding-window semantics already applied — same contract as ref.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32
NEG_INF = -1e30

DEFAULT_BLOCK_T = 512


def _decode_kernel(q_ref, k_ref, v_ref, mask_ref, out_ref,
                   acc_ref, m_ref, l_ref, *, n_groups: int):
    """Grid (B, T_blocks). Online softmax over kv blocks."""
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(F32)                         # (H, hd)
    k = k_ref[0].astype(F32)                         # (bt, K, hd)
    v = v_ref[0].astype(F32)                         # (bt, K, hd)
    mask = mask_ref[0]                               # (bt,)
    H, hd = q.shape
    bt, K, _ = k.shape
    G = n_groups

    qg = q.reshape(K, G, hd)
    s = jnp.einsum("kgh,tkh->kgt", qg, k,
                   preferred_element_type=F32) * hd ** -0.5  # (K, G, bt)
    s = jnp.where(mask[None, None, :], s, NEG_INF)

    m_prev = m_ref[...]                              # (K, G)
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[..., None])                # (K, G, bt)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
    pv = jnp.einsum("kgt,tkh->kgh", p, v,
                    preferred_element_type=F32)      # (K, G, hd)
    acc_ref[...] = acc_ref[...] * alpha[..., None] + pv
    m_ref[...] = m_cur

    @pl.when(t == pl.num_programs(1) - 1)
    def _done():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[..., None]
        out_ref[0] = out.reshape(H, hd).astype(out_ref.dtype)


def _paged_decode_kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, out_ref,
                         acc_ref, m_ref, l_ref, *, n_groups: int,
                         page_size: int):
    """Grid (B, NB). Online softmax over the pages of one sequence.

    ``bt_ref``/``len_ref`` are scalar-prefetch refs: the block table is
    consumed by the k/v index maps (each grid step DMAs the page
    ``bt[b, i]`` straight from HBM — the (B, NB*ps, K, hd) gather never
    materialises) and the lengths drive the validity mask here.
    """
    b = pl.program_id(0)
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = len_ref[b]

    @pl.when(i * page_size < length)
    def _compute():
        q = q_ref[0].astype(F32)                     # (H, hd)
        k = k_ref[0].astype(F32)                     # (ps, K, hd)
        v = v_ref[0].astype(F32)
        H, hd = q.shape
        ps, K, _ = k.shape
        G = n_groups
        # (1, 1, ps) slot positions — broadcasted_iota, TPU needs >= 2D
        pos = jax.lax.broadcasted_iota(jnp.int32, (1, 1, ps), 2)
        valid = i * page_size + pos < length

        qg = q.reshape(K, G, hd)
        s = jnp.einsum("kgh,tkh->kgt", qg, k,
                       preferred_element_type=F32) * hd ** -0.5
        s = jnp.where(valid, s, NEG_INF)

        m_prev = m_ref[...]                          # (K, G)
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[..., None])            # (K, G, ps)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
        pv = jnp.einsum("kgt,tkh->kgh", p, v,
                        preferred_element_type=F32)
        acc_ref[...] = acc_ref[...] * alpha[..., None] + pv
        m_ref[...] = m_cur

    @pl.when(i == pl.num_programs(1) - 1)
    def _done():
        H, hd = q_ref[0].shape
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[..., None]
        out_ref[0] = out.reshape(H, hd).astype(out_ref.dtype)


def paged_decode_attention(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, block_table: jax.Array,
                           lengths: jax.Array,
                           interpret: bool = False) -> jax.Array:
    """q (B,H,hd), k/v pages (P,ps,K,hd), block_table (B,NB) int32 page
    ids, lengths (B,) -> (B,H,hd). ref.paged_decode_attention_ref is the
    oracle; sequences with length 0 return zeros."""
    B, H, hd = q.shape
    P, ps, K, _ = k_pages.shape
    NB = block_table.shape[1]
    G = H // K
    kern = functools.partial(_paged_decode_kernel, n_groups=G, page_size=ps)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,               # block_table, lengths
        grid=(B, NB),
        in_specs=[
            pl.BlockSpec((1, H, hd), lambda b, i, bt, ln: (b, 0, 0)),
            pl.BlockSpec((1, ps, K, hd),
                         lambda b, i, bt, ln: (bt[b, i], 0, 0, 0)),
            pl.BlockSpec((1, ps, K, hd),
                         lambda b, i, bt, ln: (bt[b, i], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, H, hd), lambda b, i, bt, ln: (b, 0, 0)),
        scratch_shapes=[pltpu.VMEM((K, G, hd), F32),
                        pltpu.VMEM((K, G), F32),
                        pltpu.VMEM((K, G), F32)],
    )
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, hd), q.dtype),
        interpret=interpret,
    )(block_table.astype(jnp.int32), lengths.astype(jnp.int32),
      q, k_pages, v_pages)
    return jnp.where((lengths > 0)[:, None, None], out, jnp.zeros_like(out))


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     mask: jax.Array, block_t: int = DEFAULT_BLOCK_T,
                     interpret: bool = False) -> jax.Array:
    """q (B,H,hd), k/v (B,T,K,hd), mask (B,T) -> (B,H,hd)."""
    B, H, hd = q.shape
    _, T, K, _ = k.shape
    G = H // K
    bt = min(block_t, T)
    assert T % bt == 0, (T, bt)
    kern = functools.partial(_decode_kernel, n_groups=G)
    return pl.pallas_call(
        kern,
        grid=(B, T // bt),
        in_specs=[
            pl.BlockSpec((1, H, hd), lambda b, t: (b, 0, 0)),
            pl.BlockSpec((1, bt, K, hd), lambda b, t: (b, t, 0, 0)),
            pl.BlockSpec((1, bt, K, hd), lambda b, t: (b, t, 0, 0)),
            pl.BlockSpec((1, bt), lambda b, t: (b, t)),
        ],
        out_specs=pl.BlockSpec((1, H, hd), lambda b, t: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, hd), q.dtype),
        scratch_shapes=[pltpu.VMEM((K, G, hd), F32),
                        pltpu.VMEM((K, G), F32),
                        pltpu.VMEM((K, G), F32)],
        interpret=interpret,
    )(q, k, v, mask)
