"""Plugin registries behind the declarative job API (DESIGN.md §8).

A :class:`Registry` is an ordered name -> object mapping with decorated
registration, duplicate-name rejection and did-you-mean KeyErrors. The
shared instances below are the extension points of the stack — a new
aggregator / attack / train strategy / kernel backend is ONE registered
function, not an if-chain edit across three entry points:

    from repro.run.registry import ATTACKS

    @ATTACKS.register("my_attack")
    def my_attack(key, honest, byz_mask, w, true_grad): ...

Registries satisfy the ``Mapping`` protocol, so the legacy dict surfaces
(``core.aggregators.AGGREGATORS``, ``core.byzantine.ATTACKS``,
``launch.engine.STRATEGIES``, ``dist.collectives.AGG_FNS``) stay valid:
they ARE these registries now. ``available()`` imports the hosting
modules and reports every registered name per kind — the discovery
surface ``python -m repro list`` prints.

This module is import-light on purpose (no jax, no repro siblings) so
config parsing and CLI argument handling never pay for kernel imports.
"""
from __future__ import annotations

from collections.abc import Mapping
from typing import Any, Callable, Dict, Iterator, Optional


class DuplicateRegistrationError(ValueError):
    """A name was registered twice in the same registry."""


class Registry(Mapping):
    """Ordered name -> object mapping with decorator registration."""

    def __init__(self, kind: str):
        self.kind = kind
        self._entries: Dict[str, Any] = {}

    # --- registration ------------------------------------------------

    def register(self, name: Optional[str] = None) -> Callable:
        """Decorator: ``@REG.register("name")`` (or bare ``@REG.register()``
        to use ``__name__``). Returns the object unchanged."""
        def deco(obj):
            self.add(name if name is not None else obj.__name__, obj)
            return obj
        return deco

    def add(self, name: str, obj: Any) -> Any:
        if not isinstance(name, str) or not name:
            raise ValueError(f"{self.kind} registry needs a non-empty "
                             f"string name, got {name!r}")
        if name in self._entries:
            raise DuplicateRegistrationError(
                f"{self.kind} {name!r} is already registered "
                f"(to {self._entries[name]!r}); pick a different name or "
                f"remove the existing entry first")
        self._entries[name] = obj
        return obj

    # --- Mapping protocol (keeps the legacy dict call sites working) --

    def __getitem__(self, name: str) -> Any:
        try:
            return self._entries[name]
        except KeyError:
            raise KeyError(
                f"unknown {self.kind} {name!r}; "
                f"known: {sorted(self._entries)}") from None

    def __iter__(self) -> Iterator[str]:
        return iter(self._entries)

    def __len__(self) -> int:
        return len(self._entries)

    def __repr__(self) -> str:
        return f"Registry({self.kind!r}, {sorted(self._entries)})"

    def names(self):
        return sorted(self._entries)


# ---------------------------------------------------------------------------
# The stack's shared registries. Hosting modules populate them at import:
#   AGGREGATORS            core/aggregators.py      (n, d)-table zoo
#   COLLECTIVE_AGGREGATORS dist/collectives.py      shard_map AGG_FNS
#   ATTACKS                core/byzantine.py        protocol attack zoo
#   TRAIN_STRATEGIES       launch/engine.py         TrainStrategy builders
#   NORM_BACKENDS          kernels/ops.py           tree_sq_norm dispatch
#   SCALE_BACKENDS         kernels/ops.py           scale_rows dispatch
#   PAGED_ATTN_BACKENDS    kernels/ops.py           paged decode attention
#   CGC_BACKENDS           kernels/ops.py           fused CGC aggregation
#   CODEC_PACK_BACKENDS    kernels/ops.py           codec pack/unpack kernels
#   CODECS                 comm/wire.py             wire-format builders
#   CHANNELS               comm/channel.py          broadcast channel builders
#   POLICIES               comm/policy/base.py      comm control-plane policies
#   TRACKERS               obs/tracker.py           observability sinks
#   TOPOLOGIES             net/topology.py          hearing-graph builders
# ---------------------------------------------------------------------------

AGGREGATORS = Registry("aggregator")
COLLECTIVE_AGGREGATORS = Registry("collective aggregator")
ATTACKS = Registry("attack")
TRAIN_STRATEGIES = Registry("train strategy")
NORM_BACKENDS = Registry("norm kernel backend")
SCALE_BACKENDS = Registry("scale kernel backend")
PAGED_ATTN_BACKENDS = Registry("paged-attention kernel backend")
CGC_BACKENDS = Registry("fused-CGC kernel backend")
CODEC_PACK_BACKENDS = Registry("codec pack/unpack kernel backend")
CODECS = Registry("wire codec")
CHANNELS = Registry("broadcast channel")
POLICIES = Registry("comm policy")
TRACKERS = Registry("tracker")
TOPOLOGIES = Registry("hearing-graph topology")

_REGISTRIES: Dict[str, Registry] = {
    "aggregators": AGGREGATORS,
    "collective_aggregators": COLLECTIVE_AGGREGATORS,
    "attacks": ATTACKS,
    "train_strategies": TRAIN_STRATEGIES,
    "norm_backends": NORM_BACKENDS,
    "scale_backends": SCALE_BACKENDS,
    "paged_attn_backends": PAGED_ATTN_BACKENDS,
    "cgc_backends": CGC_BACKENDS,
    "codec_pack_backends": CODEC_PACK_BACKENDS,
    "codecs": CODECS,
    "channels": CHANNELS,
    "comm_policies": POLICIES,
    "trackers": TRACKERS,
    "topologies": TOPOLOGIES,
}

# modules whose import populates the registries above
_HOSTS = ("repro.core.aggregators", "repro.core.byzantine",
          "repro.dist.collectives", "repro.launch.engine",
          "repro.kernels.ops", "repro.comm.wire", "repro.comm.channel",
          "repro.comm.policy", "repro.obs.tracker",
          "repro.net.topology", "repro.net.relay", "repro.net.attacks")


def load_plugins() -> None:
    """Import every registry-hosting module (idempotent)."""
    import importlib
    for mod in _HOSTS:
        importlib.import_module(mod)


def available() -> Dict[str, list]:
    """Every registered name, per registry kind — the discovery surface
    new scenarios are written against (``python -m repro list``)."""
    load_plugins()
    return {key: reg.names() for key, reg in _REGISTRIES.items()}
