"""The declarative job config tree (DESIGN.md §8).

One frozen-dataclass tree describes a whole experiment — the paper's
sweep axes (n, f, attack, aggregator, cost function) plus the systems
knobs (strategy, mesh, serving shapes) — and round-trips losslessly
through JSON, so every run can emit its exact configuration next to its
metrics:

    RunConfig
      model     ModelSpec | None   architecture (None: quadratic cost runs)
      mesh      MeshSpec           host-device forcing + MoE impl
      scenario  ScenarioSpec       aggregator / attack / f / echo / data / comm
      train     TrainSpec | None   trainer workload
      serve     ServeSpec | None   serving workload (incl. sampling)
      dryrun    DryrunSpec | None  lower+compile workload
      bench     BenchSpec | None   serve benchmark workload
      obs       ObsSpec            tracker sink + events path

``to_json``/``from_json`` carry a ``schema_version`` field; unknown keys
are rejected with the known alternatives listed. ``apply_overrides``
implements the CLI's dotted-path ``--set train.steps=3`` edits with
field-type coercion. This module imports neither jax nor any repro
sibling, so config parsing stays instant.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import typing
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

SCHEMA_VERSION = 1


# ---------------------------------------------------------------------------
# Leaf specs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SamplingSpec:
    """Token sampling policy for serving.

    ``temperature == 0`` is exact greedy argmax (the default — bitwise
    the pre-sampling engine). ``temperature > 0`` softmax-samples, with
    the distribution truncated to the ``top_k`` largest logits when
    ``top_k > 0`` and/or to the nucleus (smallest set of tokens whose
    cumulative probability reaches ``top_p``) when ``0 < top_p < 1`` —
    both filters compose, top-k first. ``seed`` makes runs reproducible:
    the engine derives one PRNG key per dispatch from it, so the same
    submissions produce the same tokens.
    """

    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 0.0               # 0 (or >= 1) disables nucleus
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class CommSpec:
    """Communication setup: how gradients/echoes are wire-coded and what
    broadcast medium carries them (``repro.comm``, DESIGN.md §9).

    ``codec`` prices (and for lossy codecs, quantizes) every message;
    ``channel`` is the single-hop radio model. ``policy`` selects the
    closed-loop controller that may retune codec / echo_r / budget per
    round from ledger measurements (``repro.comm.policy``, DESIGN.md
    §13); ``ef`` turns on per-worker error-feedback accumulators so
    lossy codecs stay convergent. The defaults are the paper's ideal
    reliable fp32 broadcast with the static policy — bitwise the
    pre-comm stack.
    """

    channel: str = "ideal"           # registry: channels (ideal|lossy|metered)
    codec: str = "fp32"              # registry: codecs (fp32|bf16|int8|topk)
    drop_prob: float = 0.0           # lossy: per-slot fade probability
    seed: int = 0                    # channel PRNG seed
    budget_bits: int = 0             # metered: per-round bit budget (0 = off)
    topk: int = 32                   # topk codec: entries kept per vector
    policy: str = "static"           # registry: comm_policies
    ef: bool = False                 # error-feedback residual accumulators


@dataclasses.dataclass(frozen=True)
class NetSpec:
    """Network topology + relay tier (``repro.net``, DESIGN.md §15).

    ``topology`` picks the hearing graph restricting worker-to-worker
    overhearing (the paper's single-hop radio is ``complete``);
    ``degree`` parametrises ring / random_geometric; ``adjacency`` is
    the explicit graph's row string ("011;101;110"). ``relays`` > 0
    routes every uplink through a relay tier (``byz_relays`` of them
    Byzantine) with the ``broadcast`` discipline: ``direct`` trusts one
    forwarding relay, ``dolev`` sends over 2b+1 disjoint routes,
    ``bracha`` runs SEND/ECHO/READY reliable broadcast (needs
    relays >= 3*byz_relays + 1 to protect). The defaults are the
    paper's setup — no relays, everyone hears everyone.
    """

    topology: str = "complete"       # registry: topologies
    degree: int = 2                  # ring/random_geometric: hearing degree
    adjacency: str = ""              # explicit: "011;101;110" row string
    relays: int = 0                  # relay tier size (0 = single-hop)
    byz_relays: int = 0              # Byzantine relays in the tier
    broadcast: str = "direct"        # direct | dolev | bracha
    seed: int = 0                    # placement / relay PRNG seed


@dataclasses.dataclass(frozen=True)
class DataSpec:
    """What the workers sample gradients of.

    ``source="synthetic_lm"`` is the deterministic token stream
    (`repro.data`); ``source="quadratic"`` is the paper's numerical
    setting — a strongly-convex quadratic cost of dimension ``dim`` with
    conditioning mu/L and per-worker gradient noise ``noise``
    (Assumption 5), trained from ``w0 * ones(dim)``.
    """

    source: str = "synthetic_lm"     # synthetic_lm | quadratic
    seed: int = 0
    dim: int = 1000                  # quadratic: feature dimension
    mu: float = 0.5                  # quadratic: strong convexity
    L: float = 1.0                   # quadratic: smoothness
    noise: float = 1e-4              # quadratic: worker gradient noise
    w0: float = 2.0                  # quadratic: initial iterate scale


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    arch: str = "qwen3-0.6b"
    smoke: bool = False              # reduced() CPU-friendly variant
    param_dtype: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class MeshSpec:
    """Worker topology. ``devices`` forces that many fake host devices
    before jax initialises (the CLI path on CPU); 0 uses the real
    devices."""

    devices: int = 8
    moe_impl: str = "tp"


@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    """The paper's sweep axes: who aggregates, who lies, how hard."""

    aggregator: str = "cgc"          # registry: collective_aggregators
    attack: str = "sign_flip"        # registry: attacks (trainer byz_mode)
    f: int = 0                       # aggregation resilience parameter
    n_byz: int = 0                   # simulated Byzantine workers
    echo_k: int = 4                  # echo-DP reference basis size
    echo_r: float = 0.9              # echo-DP deviation ratio (Eq. 7)
    data: DataSpec = DataSpec()
    comm: CommSpec = CommSpec()      # wire codec + broadcast channel
    net: NetSpec = NetSpec()         # hearing graph + relay tier


@dataclasses.dataclass(frozen=True)
class TrainSpec:
    strategy: str = "replicated"     # registry: train_strategies
    steps: int = 20
    batch: int = 8
    seq: int = 128
    optimizer: str = "adamw"         # adamw | sgd
    lr: float = 3e-4
    microbatches: int = 1
    clip_norm: float = 0.0
    log_every: int = 5
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 0
    resume: bool = False
    metrics_path: Optional[str] = None   # None: <run_dir>/metrics.jsonl
    # jax.profiler trace window: profile the first N fit rounds into
    # <run_dir>/profile (0 = off). Failures to start the profiler are
    # recorded as obs events, never fatal.
    profile_steps: int = 0


@dataclasses.dataclass(frozen=True)
class ServeSpec:
    requests: int = 8
    max_batch: int = 4
    page_size: int = 16
    num_pages: int = 128
    max_blocks_per_seq: int = 8
    prompt_len: int = 32
    gen: int = 32
    token_budget: int = 256
    decode_quantum: int = 8
    # SLO / redundancy knobs (DESIGN.md §11): prefill_chunk caps the
    # prompt tokens one lane prefills per step (0 = whole prompt at
    # once); prefix_cache enables cross-request CoW prefix sharing;
    # shared_prefix_len prepends that many common "system prompt"
    # tokens to every generated request; priority/deadline_s/tenants
    # set the submitted requests' scheduling class (deadline_s 0 =
    # none; tenants > 1 round-robins tenant labels).
    prefill_chunk: int = 0
    prefix_cache: bool = True
    shared_prefix_len: int = 0
    priority: int = 0
    deadline_s: float = 0.0
    tenants: int = 1
    seed: int = 0
    log_every: int = 5
    metrics_path: Optional[str] = None   # None: <run_dir>/metrics.jsonl
    sampling: SamplingSpec = SamplingSpec()


@dataclasses.dataclass(frozen=True)
class DryrunSpec:
    shape: str = "train_4k"
    variant: Optional[str] = None    # None: derived from train.strategy
    multi_pod: bool = False
    compile: bool = True
    out: str = "experiments/dryrun"


@dataclasses.dataclass(frozen=True)
class BenchSpec:
    """serve_bench trace shape: continuous batching vs fixed batches."""

    requests: int = 16
    batch: int = 4
    prompt_len: int = 8
    gen_short: int = 8
    gen_long: int = 128
    rate: float = 100.0              # Poisson arrival rate (req/s)
    page_size: int = 8
    num_pages: int = 64
    # shared-prefix leg: every request = shared_prefix_len common tokens
    # + a prompt_len unique tail, run with prefix sharing on vs off
    # (36 is deliberately NOT page_size-aligned so the divergent-tail
    # copy-on-write path runs in the standing record, not just tests)
    shared_prefix_len: int = 36
    prefill_chunk: int = 16
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class ObsSpec:
    """Observability: which tracker sink the run installs (DESIGN.md
    §12). ``jsonl`` (default) streams events/spans to ``events_path``
    (None: ``<run_dir>/events.jsonl``); ``noop`` disables tracking
    entirely; ``memory``/``stdout`` are for tests and debugging."""

    tracker: str = "jsonl"           # registry: trackers
    events_path: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """The root of the job tree — one serializable experiment."""

    name: str = "run"
    model: Optional[ModelSpec] = ModelSpec()
    mesh: MeshSpec = MeshSpec()
    scenario: ScenarioSpec = ScenarioSpec()
    train: Optional[TrainSpec] = None
    serve: Optional[ServeSpec] = None
    dryrun: Optional[DryrunSpec] = None
    bench: Optional[BenchSpec] = None
    obs: ObsSpec = ObsSpec()
    runs_root: str = "experiments/runs"

    # --- serialization ----------------------------------------------

    def to_json(self, indent: Optional[int] = 2) -> str:
        d: Dict[str, Any] = {"schema_version": SCHEMA_VERSION}
        d.update(dataclasses.asdict(self))
        return json.dumps(d, indent=indent, sort_keys=False)

    @classmethod
    def from_json(cls, text: str) -> "RunConfig":
        data = json.loads(text)
        if not isinstance(data, dict):
            raise ValueError("job config must be a JSON object")
        if "schema_version" not in data:
            raise ValueError(
                f"job config is missing 'schema_version' (current: "
                f"{SCHEMA_VERSION}) — required so future schema bumps "
                f"can't silently reinterpret old files")
        version = data.pop("schema_version")
        if version != SCHEMA_VERSION:
            raise ValueError(
                f"job config schema_version {version} != supported "
                f"{SCHEMA_VERSION}")
        return _from_dict(cls, data, path="")

    @classmethod
    def load(cls, path: str) -> "RunConfig":
        with open(path) as fh:
            return cls.from_json(fh.read())

    def save(self, path: str) -> None:
        with open(path, "w") as fh:
            fh.write(self.to_json() + "\n")


def config_hash(cfg: RunConfig) -> str:
    """Content hash of the canonical JSON form (run-dir naming)."""
    canon = json.dumps(json.loads(cfg.to_json()), sort_keys=True,
                       separators=(",", ":"))
    return hashlib.sha256(canon.encode()).hexdigest()


# ---------------------------------------------------------------------------
# Generic dataclass <-> dict machinery
# ---------------------------------------------------------------------------


def _unwrap_optional(tp) -> Tuple[Any, bool]:
    """Optional[X] -> (X, True); anything else -> (tp, False)."""
    if typing.get_origin(tp) is Union:
        args = [a for a in typing.get_args(tp) if a is not type(None)]
        if len(args) == 1:
            return args[0], True
    return tp, False


def _from_dict(cls, data: Dict[str, Any], path: str):
    if not isinstance(data, dict):
        raise ValueError(f"{path or cls.__name__}: expected an object, "
                         f"got {type(data).__name__}")
    hints = typing.get_type_hints(cls)
    names = {f.name for f in dataclasses.fields(cls)}
    unknown = set(data) - names
    if unknown:
        raise ValueError(
            f"unknown key(s) {sorted(unknown)} in "
            f"{path or 'job config'}; known: {sorted(names)}")
    kwargs = {}
    for f in dataclasses.fields(cls):
        if f.name not in data:
            continue
        sub = f"{path}.{f.name}" if path else f.name
        kwargs[f.name] = _coerce(hints[f.name], data[f.name], sub)
    return cls(**kwargs)


def _coerce(tp, value: Any, path: str):
    inner, optional = _unwrap_optional(tp)
    if value is None:
        if optional:
            return None
        raise ValueError(f"{path}: null is not allowed here")
    if dataclasses.is_dataclass(inner):
        return _from_dict(inner, value, path)
    if inner is float and isinstance(value, int) \
            and not isinstance(value, bool):
        return float(value)            # JSON writes 1.0 back as 1.0; a
                                       # hand-written 1 still means 1.0
    if inner is int and isinstance(value, bool):
        raise ValueError(f"{path}: expected int, got bool")
    if not isinstance(value, inner):
        raise ValueError(f"{path}: expected {inner.__name__}, "
                         f"got {type(value).__name__} ({value!r})")
    return value


# ---------------------------------------------------------------------------
# Dotted-path overrides: the CLI's --set train.steps=3
# ---------------------------------------------------------------------------


def _parse_leaf(tp, text: str, path: str):
    inner, optional = _unwrap_optional(tp)
    if optional and text.lower() in ("none", "null"):
        return None
    if inner is bool:
        if text.lower() in ("1", "true", "yes", "on"):
            return True
        if text.lower() in ("0", "false", "no", "off"):
            return False
        raise ValueError(f"{path}: expected a bool, got {text!r}")
    if inner in (int, float):
        try:
            return inner(text)
        except ValueError:
            raise ValueError(f"{path}: expected {inner.__name__}, "
                             f"got {text!r}") from None
    return text


def apply_overrides(cfg: RunConfig,
                    assignments: Sequence[str]) -> RunConfig:
    """Apply ``key.path=value`` edits to the frozen tree.

    Values coerce to the target field's type (``--set train.steps=3``
    yields an int; ``--set train.ckpt_dir=none`` clears an Optional).
    Setting into an absent Optional section instantiates its defaults
    first, so ``--set serve.max_batch=2`` works on a train-only job.
    """
    for item in assignments:
        if "=" not in item:
            raise ValueError(f"override {item!r} is not key.path=value")
        key, text = item.split("=", 1)
        cfg = _set_path(cfg, key.strip().split("."), text.strip(), key)
    return cfg


def _set_path(node, parts: List[str], text: str, full_key: str):
    if dataclasses.is_dataclass(node) and not isinstance(node, type):
        hints = typing.get_type_hints(type(node))
        names = {f.name for f in dataclasses.fields(type(node))}
        head = parts[0]
        if head not in names:
            raise ValueError(f"--set {full_key}: no field {head!r} on "
                             f"{type(node).__name__}; known: "
                             f"{sorted(names)}")
        tp = hints[head]
        if len(parts) == 1:
            inner, _ = _unwrap_optional(tp)
            if dataclasses.is_dataclass(inner):
                raise ValueError(
                    f"--set {full_key}: {head!r} is a section, not a "
                    f"leaf field — set one of its fields instead")
            value = _parse_leaf(tp, text, full_key)
        else:
            child = getattr(node, head)
            inner, _ = _unwrap_optional(tp)
            if not dataclasses.is_dataclass(inner):
                raise ValueError(f"--set {full_key}: {head!r} is a leaf "
                                 f"field, not a section")
            if child is None:
                child = inner()        # materialise the default section
            value = _set_path(child, parts[1:], text, full_key)
        return dataclasses.replace(node, **{head: value})
    raise ValueError(f"--set {full_key}: cannot descend into "
                     f"{type(node).__name__}")
