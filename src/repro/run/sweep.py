"""Grid expansion over RunConfig dotted paths (DESIGN.md §8).

``sweep(base, {"train.lr": [1e-3, 3e-4], "scenario.f": [0, 1]})``
expands the cartesian product of the grid axes into one fully-resolved
:class:`RunConfig` per point, reusing the CLI's dotted-path override
machinery — so every value coerces exactly as ``--set`` would and an
unknown path fails with the same did-you-mean error before anything
runs. Each point's ``name`` gets a deterministic ``key=value`` suffix,
and ``out_dir`` optionally emits one loadable job file per point:

    from repro import run
    cfgs = run.sweep(base, {"train.lr": [1e-3, 3e-4]},
                     out_dir="experiments/jobs/lr-sweep")
    for cfg in cfgs:
        run.train(cfg)
"""
from __future__ import annotations

import itertools
import os
import re
from typing import Dict, List, Sequence

from .config import RunConfig, apply_overrides

_SAFE = re.compile(r"[^A-Za-z0-9._=+-]+")


def _point_suffix(assignment: Dict[str, object]) -> str:
    parts = [f"{key.rsplit('.', 1)[-1]}={value}"
             for key, value in assignment.items()]
    return _SAFE.sub("-", "-".join(parts))


def sweep(base: RunConfig, grid: Dict[str, Sequence],
          out_dir: str | None = None) -> List[RunConfig]:
    """Expand ``grid`` (dotted path -> candidate values) over ``base``.

    Returns the configs in row-major order of the grid's insertion
    order. With ``out_dir``, writes ``<name>.json`` per point (the file
    set IS the sweep: each job reruns standalone through
    ``python -m repro train --config ...``).
    """
    if not grid:
        raise ValueError("sweep needs at least one grid axis, e.g. "
                         "{'train.lr': [1e-3, 3e-4]}")
    axes = [(key, list(values)) for key, values in grid.items()]
    for key, values in axes:
        if not values:
            raise ValueError(f"sweep axis {key!r} has no values")
    configs: List[RunConfig] = []
    for combo in itertools.product(*(values for _, values in axes)):
        assignment = {key: value
                      for (key, _), value in zip(axes, combo)}
        cfg = apply_overrides(base, [f"{k}={v}"
                                     for k, v in assignment.items()])
        cfg = apply_overrides(
            cfg, [f"name={base.name}-{_point_suffix(assignment)}"])
        configs.append(cfg)
    names = [cfg.name for cfg in configs]
    if len(set(names)) != len(names):
        dupes = sorted({n for n in names if names.count(n) > 1})
        raise ValueError(
            f"sweep points collide on name(s) {dupes} (values sanitize "
            f"to the same suffix) — rename the base config or "
            f"disambiguate the grid values")
    if out_dir is not None:
        os.makedirs(out_dir, exist_ok=True)
        for cfg in configs:
            cfg.save(os.path.join(out_dir, f"{cfg.name}.json"))
    return configs
