"""repro.run — the declarative job API (DESIGN.md §8).

    config.py    typed RunConfig tree + JSON round-trip + --set overrides
    registry.py  plugin registries (aggregators, attacks, strategies,
                 kernel backends) with decorated registration
    facade.py    train(cfg) / serve(cfg) / dryrun(cfg) / bench(cfg)
                 returning typed results
    rundir.py    per-run output directories (config.json + metrics.jsonl)

The unified CLI (``python -m repro {train,serve,dryrun,bench} --config
job.json [--set key.path=value ...]``) is a thin shell over these
facades; the legacy per-entrypoint CLIs adapt their flags into a
RunConfig and call the same functions.
"""
from .config import (SCHEMA_VERSION, BenchSpec, CommSpec, DataSpec,
                     DryrunSpec, MeshSpec, ModelSpec, ObsSpec, RunConfig,
                     SamplingSpec, ScenarioSpec, ServeSpec, TrainSpec,
                     apply_overrides, config_hash)
from .facade import (BenchResult, DryrunResult, RunResult, ServeResult,
                     TrainResult, bench, dryrun, serve, train)
from .registry import (AGGREGATORS, ATTACKS, CHANNELS, CODECS,
                       COLLECTIVE_AGGREGATORS, NORM_BACKENDS,
                       PAGED_ATTN_BACKENDS, SCALE_BACKENDS, TRACKERS,
                       TRAIN_STRATEGIES, DuplicateRegistrationError,
                       Registry, available)
from .rundir import make_run_dir, run_dir_tag
from .sweep import sweep

__all__ = [
    "SCHEMA_VERSION", "BenchSpec", "CommSpec", "DataSpec", "DryrunSpec",
    "MeshSpec", "ModelSpec", "ObsSpec", "RunConfig", "SamplingSpec",
    "ScenarioSpec",
    "ServeSpec", "TrainSpec", "apply_overrides", "config_hash",
    "BenchResult", "DryrunResult", "RunResult", "ServeResult",
    "TrainResult", "bench", "dryrun", "serve", "train",
    "AGGREGATORS", "ATTACKS", "CHANNELS", "CODECS",
    "COLLECTIVE_AGGREGATORS", "NORM_BACKENDS",
    "PAGED_ATTN_BACKENDS", "SCALE_BACKENDS", "TRACKERS",
    "TRAIN_STRATEGIES",
    "DuplicateRegistrationError", "Registry", "available",
    "make_run_dir", "run_dir_tag", "sweep",
]
