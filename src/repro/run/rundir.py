"""Per-run output directories (DESIGN.md §8).

Every facade run gets its own directory under ``cfg.runs_root`` named
``<UTC step time>-<kind>-<name>-<config hash8>`` and writes its exact
``config.json`` there before doing anything else; metrics default to
``<run_dir>/metrics.jsonl``. Two runs can therefore never clobber each
other's metrics the way a shared ``--metrics`` path could — identical
configs launched in the same second still get distinct directories via
the collision suffix.
"""
from __future__ import annotations

import os
import re
import time

from .config import RunConfig, config_hash

_SAFE = re.compile(r"[^A-Za-z0-9._-]+")


def run_dir_tag(cfg: RunConfig, kind: str, when: float) -> str:
    stamp = time.strftime("%Y%m%d-%H%M%S", time.gmtime(when))
    # job names come from user JSON: strip path separators and friends
    # so the tag always stays a single component under runs_root.
    name = _SAFE.sub("-", cfg.name).strip("-.") or "run"
    return f"{stamp}-{kind}-{name}-{config_hash(cfg)[:8]}"


def make_run_dir(cfg: RunConfig, kind: str) -> str:
    """Create the per-run directory and drop ``config.json`` into it."""
    base = os.path.join(cfg.runs_root, run_dir_tag(cfg, kind, time.time()))
    path, n = base, 0
    while True:
        try:
            os.makedirs(path, exist_ok=False)
            break
        except FileExistsError:
            n += 1
            path = f"{base}-{n}"
    cfg.save(os.path.join(path, "config.json"))
    return path
