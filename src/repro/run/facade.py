"""Typed Python facades over the job config tree (DESIGN.md §8).

``train(cfg)`` / ``serve(cfg)`` / ``dryrun(cfg)`` / ``bench(cfg)`` each
take a :class:`RunConfig`, run the corresponding workload through the
existing subsystems (``launch.engine.Trainer``, ``serve.ServeEngine``,
``launch.dryrun``, ``serve.bench``) and return a typed result object.
Every run creates a per-run directory (``rundir.make_run_dir``) holding
its exact ``config.json`` and, by default, its ``metrics.jsonl`` — the
reproducibility contract: the config that ran is always next to the
numbers it produced.

The legacy CLIs (``repro.launch.train`` / ``repro.launch.serve`` /
``benchmarks/serve_bench.py``) are thin flags->RunConfig adapters over
these facades, so the config-driven and flag-driven paths execute the
same jitted step bit for bit.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
import warnings
from typing import Any, Dict, Iterator, List, Optional

from .config import DataSpec, RunConfig
from .rundir import make_run_dir

_DEPRECATION_WARNED: set = set()


def _make_run_tracker(cfg: RunConfig, run_dir: str):
    """The run's tracker per ``cfg.obs``: the jsonl default streams
    events/spans to ``<run_dir>/events.jsonl``."""
    from repro import obs as obs_lib

    path = cfg.obs.events_path or os.path.join(run_dir, "events.jsonl")
    return obs_lib.make_tracker(cfg.obs.tracker, path=path)


def _write_summary(run_dir: str, kind: str, summary: Dict[str, Any],
                   tracker) -> str:
    """Drop ``summary.json`` — the run's machine-readable digest
    (workload summary + the tracker's counter/span snapshot) that
    ``python -m repro report`` renders."""
    import json

    path = os.path.join(run_dir, "summary.json")
    data = {"kind": kind, "summary": summary, "obs": tracker.snapshot()}
    with open(path, "w") as fh:
        json.dump(data, fh, indent=2, default=str)
        fh.write("\n")
    return path


def warn_legacy(entrypoint: str, replacement: str) -> None:
    """One DeprecationWarning per legacy entry point per process."""
    if entrypoint in _DEPRECATION_WARNED:
        return
    _DEPRECATION_WARNED.add(entrypoint)
    warnings.warn(
        f"{entrypoint} flags are deprecated; use `{replacement}` with a "
        f"job file (see experiments/jobs/) — legacy flags keep working "
        f"through this adapter",
        DeprecationWarning, stacklevel=3)


def force_host_devices(n: int) -> None:
    """Force n fake host devices — must run before jax backend init."""
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" in flags:
        return
    os.environ["XLA_FLAGS"] = (
        flags + f" --xla_force_host_platform_device_count={n}").strip()


# ---------------------------------------------------------------------------
# Result objects
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RunResult:
    """Common shape: the config that ran, where it wrote, what it found."""

    config: RunConfig
    run_dir: str
    summary: Dict[str, Any]


@dataclasses.dataclass
class TrainResult(RunResult):
    metrics_path: str = ""
    state: Any = None                # final launch.engine.TrainState

    @property
    def first_loss(self) -> Optional[float]:
        return self.summary.get("first_loss")

    @property
    def final_loss(self) -> Optional[float]:
        return self.summary.get("final_loss")


@dataclasses.dataclass
class ServeResult(RunResult):
    metrics_path: str = ""
    outputs: List[List[int]] = dataclasses.field(default_factory=list)

    @property
    def tokens_per_s(self) -> float:
        return self.summary.get("tokens_per_s", 0.0)


@dataclasses.dataclass
class DryrunResult(RunResult):
    record_path: str = ""


@dataclasses.dataclass
class BenchResult(RunResult):
    @property
    def speedup(self) -> float:
        return self.summary.get("speedup", 0.0)


# ---------------------------------------------------------------------------
# train
# ---------------------------------------------------------------------------


def _quadratic_setup(data: DataSpec, batch: int):
    """The paper's numerical setting as an engine workload: a strongly
    convex quadratic with per-worker gradient noise (Assumption 5)."""
    import jax
    import jax.numpy as jnp

    from repro.core import costfns

    cost = costfns.quadratic(jax.random.PRNGKey(data.seed), d=data.dim,
                             mu=data.mu, L=data.L, sigma=0.0)

    def loss_fn(values, batch_):
        w = values["w"]
        return cost.value(w) + w @ jnp.mean(batch_["eps"], 0), {}

    def batches(start: int = 0) -> Iterator[Dict[str, jax.Array]]:
        step = start
        base = jax.random.PRNGKey(data.seed + 1)
        while True:
            key = jax.random.fold_in(base, step)
            yield {"eps": data.noise
                   * jax.random.normal(key, (batch, data.dim))}
            step += 1

    values = {"w": jnp.ones((data.dim,)) * data.w0}
    return loss_fn, values, batches


def _model_setup(cfg: RunConfig):
    import dataclasses as _dc

    from repro.configs import get_config, reduced

    model_cfg = get_config(cfg.model.arch)
    if cfg.model.smoke:
        model_cfg = reduced(model_cfg)
    if cfg.model.param_dtype:
        model_cfg = _dc.replace(model_cfg, param_dtype=cfg.model.param_dtype)
    return model_cfg


def _check_forced_devices(cfg: RunConfig) -> int:
    """Devices jax actually has; warn when the config asked for a
    different forced count (backend already initialised, or XLA_FLAGS
    pre-set) so the run_dir's config.json can't silently misrepresent
    the worker topology that ran."""
    import jax

    n_dev = len(jax.devices())
    if cfg.mesh.devices and n_dev != cfg.mesh.devices:
        print(f"warning: mesh.devices={cfg.mesh.devices} requested but "
              f"jax has {n_dev} device(s) — the backend was already "
              f"initialised (or XLA_FLAGS pre-set), so this run uses "
              f"{n_dev}; config.json records the request, not the "
              f"actual count")
    return n_dev


def _make_mesh(cfg: RunConfig, batch: int, strategy: str,
               needs_workers: bool):
    """Worker mesh over the (possibly forced) host devices, with the
    legacy CLI's validation messages."""
    from repro.launch.mesh import make_host_mesh

    scen = cfg.scenario
    n_dev = _check_forced_devices(cfg)
    mesh = make_host_mesh() if n_dev > 1 and batch % n_dev == 0 else None
    if mesh is None and needs_workers:
        raise ValueError(
            f"strategy {strategy!r} needs >1 data-parallel workers: set "
            f"mesh.devices=N (and a train.batch divisible by N), or "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=N")
    if scen.n_byz and mesh is None:
        raise ValueError(
            "scenario.n_byz needs >1 data-parallel workers: set "
            "mesh.devices=N and a train.batch divisible by N")
    if mesh is None and (scen.f or scen.aggregator != "mean"):
        print("warning: single worker — no aggregation runs, so "
              "scenario.aggregator/f are inactive (set mesh.devices=N "
              "to exercise them)")
    return mesh


def train(cfg: RunConfig) -> TrainResult:
    """Run the training workload a :class:`RunConfig` describes."""
    if cfg.train is None:
        raise ValueError("job config has no `train` section")
    if cfg.mesh.devices:
        force_host_devices(cfg.mesh.devices)

    import jax

    from repro.comm import resolve as resolve_comm
    from repro.comm.policy import resolve_policy
    from repro.data import make_batch_iterator
    from repro.launch.engine import (Trainer, TrainerConfig, TrainSettings,
                                     TRAIN_STRATEGIES)
    from repro.models import model as M
    from repro.models.nn import split_params
    from repro.optim import adamw, sgd

    from repro import net as net_lib

    tspec, scen = cfg.train, cfg.scenario
    if tspec.strategy not in TRAIN_STRATEGIES:
        raise ValueError(f"unknown train strategy {tspec.strategy!r}; "
                         f"known: {sorted(TRAIN_STRATEGIES)}")
    # scenario.net: validate + swap the relay channel in before the
    # settings freeze (apply_to_comm is a no-op without a relay tier).
    comm_cfg = net_lib.apply_to_comm(scen.net, resolve_comm(scen.comm))
    settings = TrainSettings(
        aggregator=scen.aggregator, f=scen.f, n_byz=scen.n_byz,
        byz_mode=scen.attack, microbatches=tspec.microbatches,
        clip_norm=tspec.clip_norm, echo_k=scen.echo_k, echo_r=scen.echo_r,
        moe_impl=cfg.mesh.moe_impl, fsdp=tspec.strategy == "fsdp",
        comm=comm_cfg,
        policy=resolve_policy(scen.comm), ef=scen.comm.ef)
    optimizers = {"adamw": adamw, "sgd": sgd}
    if tspec.optimizer not in optimizers:
        raise ValueError(f"unknown train.optimizer {tspec.optimizer!r}; "
                         f"known: {sorted(optimizers)}")
    opt = optimizers[tspec.optimizer](tspec.lr)

    quadratic = scen.data.source == "quadratic"
    if not quadratic and cfg.model is None:
        raise ValueError("job config needs a `model` section unless "
                         "scenario.data.source == 'quadratic'")
    if quadratic:
        loss_fn, values, quad_batches = _quadratic_setup(scen.data,
                                                         tspec.batch)
        model_cfg = None
    else:
        loss_fn = None
        model_cfg = _model_setup(cfg)

    mesh = _make_mesh(cfg, tspec.batch, tspec.strategy,
                      needs_workers=tspec.strategy in ("fsdp", "echo_dp"))

    run_dir = make_run_dir(cfg, "train")
    metrics_path = tspec.metrics_path or os.path.join(run_dir,
                                                      "metrics.jsonl")

    from repro import obs as obs_lib

    tracker = _make_run_tracker(cfg, run_dir)
    with obs_lib.use_tracker(tracker):
        trainer = Trainer(tspec.strategy, model_cfg, opt, settings, mesh,
                          tspec.batch,
                          TrainerConfig(log_every=tspec.log_every,
                                        ckpt_dir=tspec.ckpt_dir,
                                        ckpt_every=tspec.ckpt_every,
                                        resume=tspec.resume,
                                        metrics_path=metrics_path,
                                        profile_steps=tspec.profile_steps,
                                        profile_dir=os.path.join(
                                            run_dir, "profile")),
                          loss_fn=loss_fn,
                          hooks=obs_lib.TrackerHook())
        comm_tag = (f" comm={scen.comm.channel}/{scen.comm.codec}"
                    if (scen.comm.channel,
                        scen.comm.codec) != ("ideal", "fp32")
                    else "")
        if scen.comm.policy != "static":
            comm_tag += f" policy={scen.comm.policy}"
        if scen.comm.ef:
            comm_tag += " ef=on"
        if net_lib.net_active(scen.net):
            # resolve the hearing graph against the workers that ran and
            # emit the run's net.* digest next to the comm events. The
            # coarse driver's echo basis is a parameter-server downlink,
            # so the graph is informational here (DESIGN.md §15); the
            # slot-level simulation enforces it per worker.
            graph = net_lib.resolve_net(scen.net, trainer.n_workers)
            obs_lib.event("net.topology", topology=scen.net.topology,
                          n=graph.n, edges=graph.edge_count(),
                          complete=graph.is_complete,
                          degree=scen.net.degree)
            obs_lib.counter("net.hearing_edges", graph.edge_count())
            comm_tag += f" net={scen.net.topology}"
            if scen.net.relays:
                ch = settings.comm.channel
                obs_lib.event("net.channel", relays=scen.net.relays,
                              byz_relays=scen.net.byz_relays,
                              broadcast=scen.net.broadcast,
                              protected=ch.protected,
                              price_factor=ch.price_factor())
                if scen.net.broadcast == "bracha":
                    outcome = net_lib.simulate_bracha(
                        scen.net.relays, scen.net.byz_relays)
                elif scen.net.broadcast == "direct":
                    outcome = net_lib.simulate_plain_relay(
                        scen.net.relays, scen.net.byz_relays)
                else:
                    outcome = None
                if outcome is not None:
                    obs_lib.event("net.broadcast",
                                  discipline=scen.net.broadcast,
                                  **outcome.as_event())
                comm_tag += (f" relays={scen.net.relays}"
                             f"({scen.net.broadcast})")
        print(f"strategy={tspec.strategy} workers={trainer.n_workers} "
              f"aggregator={scen.aggregator} f={scen.f}{comm_tag} "
              f"run_dir={run_dir}")

        if quadratic:
            state = trainer.init_state(values)
            it = quad_batches(start=state.step)
        else:
            params = M.init_params(model_cfg, jax.random.PRNGKey(0))
            values, _ = split_params(params)
            state = trainer.init_state(values)
            # start=state.step: a resumed run continues the data stream
            # instead of re-consuming batches the checkpointed run saw.
            it = make_batch_iterator(model_cfg, tspec.batch, tspec.seq,
                                     seed=scen.data.seed, start=state.step)
        if state.step:
            print(f"resumed from step {state.step}")

        mesh_ctx = jax.set_mesh(mesh) if mesh is not None \
            else contextlib.nullcontext()
        with mesh_ctx:
            state, summary = trainer.fit(state, it, tspec.steps)
        trainer.close()
        _write_summary(run_dir, "train", summary, tracker)
    tracker.close()
    return TrainResult(config=cfg, run_dir=run_dir, summary=summary,
                       metrics_path=metrics_path, state=state)


def print_train_summary(result: TrainResult) -> None:
    """The CLI's closing lines (shared by legacy and config paths)."""
    summary, tspec = result.summary, result.config.train
    if not summary["rounds"]:
        print(f"nothing to do: resumed at or past train.steps="
              f"{tspec.steps}")
        return
    print(f"final loss {summary['final_loss']:.4f} "
          f"(from {summary['first_loss']:.4f}) in {summary['wall_s']}s")
    if "echo_rate" in summary:
        print(f"echo rounds {summary['echo_rounds']}/{summary['rounds']} "
              f"({100.0 * summary['echo_rate']:.1f}%); cumulative bits "
              f"{summary['bits_sent']:.3e} vs all-raw baseline "
              f"{summary['bits_baseline']:.3e} "
              f"({100.0 * summary['bits_saving']:.1f}% saved)")
    if summary.get("codec_final") is not None:
        print(f"policy {summary['policy']}: "
              f"{summary['codec_switches']} codec switches, settled on "
              f"codec={summary['codec_final']} "
              f"echo_r={summary['echo_r_final']:.3f}")
    if tspec.ckpt_dir:
        print("checkpoint saved to", tspec.ckpt_dir)


# ---------------------------------------------------------------------------
# serve
# ---------------------------------------------------------------------------


def serve(cfg: RunConfig) -> ServeResult:
    """Run the serving workload: a seeded synthetic mixed-length request
    trace through :class:`repro.serve.ServeEngine`.

    ``mesh.devices`` forces host devices exactly like the train facade;
    with more than one device the engine runs tensor-parallel over a
    (data=1, model=n) host mesh (params + page pools sharded by the
    logical-axis rules), honouring ``mesh.moe_impl``.
    """
    if cfg.serve is None:
        raise ValueError("job config has no `serve` section")
    if cfg.model is None:
        raise ValueError("serving needs a `model` section")
    if cfg.mesh.devices:
        force_host_devices(cfg.mesh.devices)

    import jax
    import numpy as np

    from repro.launch.mesh import make_host_mesh
    from repro.models import model as M
    from repro.serve import ServeConfig, ServeEngine

    spec = cfg.serve
    model_cfg = _model_setup(cfg)
    if not model_cfg.has_decode:
        raise ValueError(f"{cfg.model.arch} is encoder-only: no decode "
                         f"step")

    n_dev = _check_forced_devices(cfg)
    mesh = make_host_mesh(model=n_dev) if n_dev > 1 else None

    run_dir = make_run_dir(cfg, "serve")
    metrics_path = spec.metrics_path or os.path.join(run_dir,
                                                     "metrics.jsonl")

    from repro import obs as obs_lib

    tracker = _make_run_tracker(cfg, run_dir)
    with obs_lib.use_tracker(tracker):
        params = M.init_params(model_cfg, jax.random.PRNGKey(spec.seed))
        engine = ServeEngine(model_cfg, params, ServeConfig(
            max_batch=spec.max_batch, page_size=spec.page_size,
            num_pages=spec.num_pages,
            max_blocks_per_seq=spec.max_blocks_per_seq,
            token_budget=spec.token_budget,
            decode_quantum=spec.decode_quantum,
            prefill_chunk=spec.prefill_chunk,
            prefix_cache=spec.prefix_cache, metrics_path=metrics_path,
            log_every=spec.log_every, sampling=spec.sampling),
            mesh=mesh, moe_impl=cfg.mesh.moe_impl,
            hooks=obs_lib.TrackerHook())

        rng = np.random.default_rng(spec.seed)
        # a shared "system prompt" every request starts with — the prefix
        # cache turns its prefill into page adoptions after the first
        # request
        shared = rng.integers(0, model_cfg.vocab_size,
                              size=spec.shared_prefix_len).tolist() \
            if spec.shared_prefix_len else []
        handles = []
        for i in range(spec.requests):
            plen = int(rng.integers(2, max(spec.prompt_len, 2) + 1))
            gen = int(rng.integers(1, max(spec.gen, 1) + 1))
            prompt = shared + rng.integers(0, model_cfg.vocab_size,
                                           size=plen).tolist()
            handles.append(engine.submit(
                prompt, max_new=gen, priority=spec.priority,
                deadline_s=spec.deadline_s or None,
                tenant=f"t{i % max(spec.tenants, 1)}"))

        engine.drain(max_steps=100 * spec.requests * (spec.gen + 2))
        engine.sched.check_invariants()
        summary = engine.summary()
        engine.close()
        _write_summary(run_dir, "serve", summary, tracker)
    tracker.close()
    if not all(h.done for h in handles):
        raise RuntimeError("drain left unfinished requests")
    return ServeResult(config=cfg, run_dir=run_dir, summary=summary,
                       metrics_path=metrics_path,
                       outputs=[list(h.tokens) for h in handles])


def print_serve_summary(result: ServeResult) -> None:
    cfg, spec, summary = result.config, result.config.serve, result.summary
    print(f"arch={cfg.model.arch} requests={spec.requests} "
          f"lanes={spec.max_batch} pages={spec.num_pages}"
          f"x{spec.page_size} run_dir={result.run_dir}")
    print(f"generated {summary['tokens_generated']} tokens in "
          f"{summary['wall_s']}s ({summary['tokens_per_s']} tok/s), "
          f"{summary['preemptions']} preemptions")
    print(f"latency p50={summary['latency_p50_s']}s "
          f"p99={summary['latency_p99_s']}s "
          f"ttft p50={summary['ttft_p50_s']}s "
          f"p99={summary['ttft_p99_s']}s "
          f"itl p50={summary['itl_p50_s']}s")
    if summary.get("prefix_hit_tokens"):
        print(f"prefix cache: hit rate "
              f"{100.0 * summary['prefix_hit_rate']:.1f}% "
              f"({summary['prefix_hit_tokens']} tokens adopted, "
              f"{summary['cow_copies']} CoW copies)")


# ---------------------------------------------------------------------------
# dryrun
# ---------------------------------------------------------------------------


def dryrun(cfg: RunConfig) -> DryrunResult:
    """Lower+compile the job's (arch, shape, variant) on the production
    mesh and record the analysis JSON.

    NOTE: ``repro.launch.dryrun`` forces 512 fake host devices at import,
    which must happen before jax initialises — call this facade first
    thing in a fresh process (the ``python -m repro dryrun`` CLI does).
    """
    if cfg.dryrun is None:
        raise ValueError("job config has no `dryrun` section")
    if cfg.model is None:
        raise ValueError("dryrun needs a `model` section")
    import json

    from repro.launch import dryrun as dry

    spec = cfg.dryrun
    variant = spec.variant
    if variant is None:
        strategy = cfg.train.strategy if cfg.train else "replicated"
        variant = {"replicated": "baseline"}.get(strategy, strategy)
    run_dir = make_run_dir(cfg, "dryrun")
    rec = dry.dryrun_pair(cfg.model.arch, spec.shape, spec.multi_pod,
                          moe_impl=cfg.mesh.moe_impl,
                          compile_=spec.compile, variant=variant,
                          param_dtype=cfg.model.param_dtype)
    os.makedirs(spec.out, exist_ok=True)
    tag = (f"{cfg.model.arch}__{spec.shape}__"
           f"{'2x16x16' if spec.multi_pod else '16x16'}")
    if variant != "baseline":
        tag += f"__{variant}"
    record_path = os.path.join(spec.out, tag + ".json")
    with open(record_path, "w") as fh:
        json.dump(rec, fh, indent=2)
    with open(os.path.join(run_dir, "record.json"), "w") as fh:
        json.dump(rec, fh, indent=2)
    return DryrunResult(config=cfg, run_dir=run_dir, summary=rec,
                        record_path=record_path)


# ---------------------------------------------------------------------------
# bench
# ---------------------------------------------------------------------------


def bench(cfg: RunConfig) -> BenchResult:
    """Continuous-batching vs fixed-batch serving benchmark over a
    Poisson trace (``repro.serve.bench``)."""
    if cfg.bench is None:
        raise ValueError("job config has no `bench` section")
    if cfg.model is None:
        raise ValueError("bench needs a `model` section")
    import json

    from repro.serve.bench import run_bench

    run_dir = make_run_dir(cfg, "bench")
    summary = run_bench(cfg.model.arch, cfg.bench)
    with open(os.path.join(run_dir, "result.json"), "w") as fh:
        json.dump(summary, fh, indent=2)
    return BenchResult(config=cfg, run_dir=run_dir, summary=summary)
