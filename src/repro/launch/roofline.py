"""Roofline analysis: three terms per (arch x shape x mesh).

    compute    = FLOPs / (chips x 197e12)          [bf16 peak, v5e]
    memory     = HBM bytes / (chips x 819e9)
    collective = wire bytes / (chips x 50e9)       [per-link ICI]

FLOP/byte sources. ``compiled.cost_analysis()`` on XLA counts while-loop
bodies ONCE — our layer scan, microbatch scan and q-chunk scans make the raw
number a single-iteration cost, so the roofline uses an ANALYTIC model
(formulas below, standard MFU accounting) as the primary source and records
the compiled numbers alongside with their known trip-count caveat
(EXPERIMENTS.md §Roofline documents the cross-check). Collective bytes come
from the compiled HLO inventory (which collectives exist, at what shapes)
with trip counts applied from the known static structure.

All quantities are PER DEVICE per step.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Dict, Optional

import numpy as np

from repro.configs.base import INPUT_SHAPES, ModelConfig, ShapeConfig
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
from repro.launch.specs import long_context_variant

BF16 = 2
F32 = 4


# ---------------------------------------------------------------------------
# Per-layer forward FLOPs per token (matmul-only, 2*m*n*k convention)
# ---------------------------------------------------------------------------


def _attn_flops_per_tok(cfg: ModelConfig, ctx: float) -> float:
    """GQA/MLA projections + score/PV at average context length ``ctx``."""
    d = cfg.d_model
    if cfg.attn_type == "mla":
        nd, rd, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
        H, lora, qlora = cfg.num_heads, cfg.kv_lora_rank, cfg.q_lora_rank
        q = 2 * (d * qlora + qlora * H * (nd + rd)) if qlora else \
            2 * d * H * (nd + rd)
        kv = 2 * (d * (lora + rd) + lora * H * (nd + vd))
        o = 2 * H * vd * d
        sc = 2 * H * (nd + rd) * ctx + 2 * H * vd * ctx
        return q + kv + o + sc
    H, K = cfg.num_heads, cfg.num_kv_heads
    hd = cfg.resolved_head_dim
    proj = 2 * d * hd * (2 * H + 2 * K)
    sc = 2 * H * hd * ctx * 2
    return proj + sc


def _ffn_flops_per_tok(cfg: ModelConfig, d_ff: int) -> float:
    return 2 * 3 * cfg.d_model * d_ff


def _moe_flops_per_tok(cfg: ModelConfig) -> float:
    route = 2 * cfg.d_model * cfg.num_experts
    expert = cfg.top_k * _ffn_flops_per_tok(cfg, cfg.moe_d_ff)
    shared = _ffn_flops_per_tok(cfg, cfg.num_shared_experts * cfg.moe_d_ff) \
        if cfg.num_shared_experts else 0.0
    return route + expert + shared


def _ssm_flops_per_tok(cfg: ModelConfig) -> float:
    d, di, N = cfg.d_model, cfg.ssm_d_inner, cfg.ssm_state
    H = cfg.ssm_num_heads
    Q = cfg.ssm_chunk
    proj = 2 * d * (2 * di + 2 * N + H) + 2 * di * d
    conv = 2 * cfg.conv_width * (di + 2 * N)
    # SSD per token: CB row (Q x N), intra apply (Q x di), inter output
    # (N x di), amortised state update (~3 N di / Q per token)
    ssd = 2 * Q * N + 2 * Q * di + 2 * N * di + 6 * N * di / Q
    return proj + conv + ssd


def _xlstm_flops_per_tok(cfg: ModelConfig, kind: str, ctx: float) -> float:
    d = cfg.d_model
    if kind == "m":
        ed = 2 * d
        # up (d -> 2ed), qkv (3 x ed x ed), gates, down (ed -> d)
        proj = 2 * d * 2 * ed + 3 * 2 * ed * ed + 2 * ed * d
        sc = 2 * ed * ctx * 2              # scores + PV over context
        return proj + sc
    dh = d // cfg.num_heads
    Fd = 4 * d // 3
    # 4 gate input mats (d x d), block-diag recurrent (4 x d x dh), FFN
    return 4 * 2 * d * d + 4 * 2 * d * dh + 2 * 3 * d * Fd


def fwd_flops_per_token(cfg: ModelConfig, ctx: float) -> float:
    """Average forward FLOPs per token across all layers + LM head."""
    L = cfg.num_layers
    total = 0.0
    if cfg.family in ("dense", "vlm", "audio"):
        total = L * (_attn_flops_per_tok(cfg, ctx)
                     + _ffn_flops_per_tok(cfg, cfg.d_ff))
    elif cfg.family == "moe":
        nd = cfg.first_dense_layers
        total = (nd * (_attn_flops_per_tok(cfg, ctx)
                       + _ffn_flops_per_tok(cfg, cfg.d_ff))
                 + (L - nd) * (_attn_flops_per_tok(cfg, ctx)
                               + _moe_flops_per_tok(cfg)))
    elif cfg.family == "hybrid":
        n_attn = L // cfg.shared_attn_every
        total = (L * _ssm_flops_per_tok(cfg)
                 + n_attn * (_attn_flops_per_tok(cfg, ctx)
                             + _ffn_flops_per_tok(cfg, cfg.d_ff)))
    elif cfg.family == "ssm":
        total = sum(_xlstm_flops_per_tok(cfg, k, ctx)
                    for k in cfg.xlstm_pattern)
    if not cfg.is_encoder:
        total += 2 * cfg.d_model * cfg.padded_vocab     # LM head
    return total


def active_params(cfg: ModelConfig) -> float:
    """Parameters touched per token (MoE: top-k + shared only)."""
    d, L = cfg.d_model, cfg.num_layers
    if cfg.attn_type == "mla":
        nd, rd, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
        H, lora, qlora = cfg.num_heads, cfg.kv_lora_rank, cfg.q_lora_rank
        attn = ((d * qlora + qlora * H * (nd + rd)) if qlora
                else d * H * (nd + rd)) + d * (lora + rd) \
            + lora * H * (nd + vd) + H * vd * d
    else:
        hd = cfg.resolved_head_dim
        attn = d * hd * (2 * cfg.num_heads + 2 * cfg.num_kv_heads)
    if cfg.family == "moe":
        ffn = (cfg.top_k + cfg.num_shared_experts) * 3 * d * cfg.moe_d_ff \
            + d * cfg.num_experts
        nd_l = cfg.first_dense_layers
        per_layer = attn + ffn
        total = nd_l * (attn + 3 * d * cfg.d_ff) + (L - nd_l) * per_layer
    elif cfg.family == "hybrid":
        di, N, H = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_num_heads
        mamba = d * (2 * di + 2 * N + H) + di * d
        n_attn = L // cfg.shared_attn_every
        total = L * mamba + n_attn * (attn + 3 * d * cfg.d_ff)
    elif cfg.family == "ssm":
        total = sum((d * 4 * d + 4 * d * 2 + 2 * d * d * 2) if k == "m"
                    else (4 * d * d + 3 * d * (4 * d // 3))
                    for k in cfg.xlstm_pattern)
    else:
        total = L * (attn + 3 * d * cfg.d_ff)
    total += d * cfg.padded_vocab * (1 if cfg.tie_embeddings or
                                     cfg.is_encoder else 2)
    return float(total)


# ---------------------------------------------------------------------------
# Per-device roofline terms
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops_global: float
    hlo_flops_device: Optional[float]
    useful_ratio: Optional[float]
    fit_hbm: Optional[bool]
    note: str = ""

    def as_dict(self):
        return dataclasses.asdict(self)


def analyze(cfg: ModelConfig, shape: ShapeConfig, chips: int, dp: int,
            tp: int, dryrun_rec: Optional[Dict[str, Any]] = None
            ) -> Roofline:
    cfg = long_context_variant(cfg, shape)
    B, S = shape.global_batch, shape.seq_len
    kind = shape.kind

    if kind == "decode":
        tokens_global = B                       # one token per sequence
        ctx = min(S, cfg.sliding_window or S)   # cache length read
    else:
        tokens_global = B * S
        ctx = (min(S, cfg.sliding_window or S) / 2
               if cfg.causal else min(S, cfg.sliding_window or S))

    fwd_tok = fwd_flops_per_token(cfg, ctx)
    mult = 3.0 if kind == "train" else 1.0      # fwd+bwd; remat excluded
    flops_global = fwd_tok * tokens_global * mult
    flops_dev = flops_global / chips
    compute_s = flops_dev / PEAK_FLOPS_BF16

    # ---- memory term: parameter + state + activation traffic ----------
    n_active = active_params(cfg)
    p_total = dryrun_rec.get("param_bytes_global", n_active * F32) \
        if dryrun_rec else n_active * F32
    p_dev = p_total / tp                        # params sharded over model
    tok_dev = tokens_global / (dp if kind != "decode" or B >= dp else 1)
    if kind == "train":
        micro = (dryrun_rec or {}).get("microbatches", 1) or 1
        # params re-read every microbatch fwd+bwd, opt update 3x params,
        # activation traffic ~24 bytes/elem-layer (bf16 in+out, few tensors)
        bytes_dev = (p_dev * (2 * micro + 3)
                     + tok_dev * cfg.d_model * cfg.num_layers * 24 * BF16 / 2)
    elif kind == "prefill":
        bytes_dev = p_dev / 2 + tok_dev * cfg.d_model * cfg.num_layers * 12
    else:
        cache = (dryrun_rec or {}).get("cache_bytes_global", 0) or \
            _cache_bytes(cfg, B, S)
        bytes_dev = p_dev / 2 + cache / chips
    memory_s = bytes_dev / HBM_BW

    # ---- collective term ----------------------------------------------
    coll_dev = _collective_bytes(cfg, shape, dp, tp, kind,
                                 (dryrun_rec or {}).get("microbatches", 1)
                                 or 1, p_total, tokens_global)
    collective_s = coll_dev / ICI_BW

    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)

    hlo = None
    ratio = None
    fit = None
    if dryrun_rec and dryrun_rec.get("status") == "ok":
        hlo = dryrun_rec.get("cost_analysis", {}).get("flops")
        ma = dryrun_rec.get("memory_analysis", {})
        if ma:
            used = (ma.get("argument_size_in_bytes", 0)
                    + ma.get("temp_size_in_bytes", 0))
            fit = used <= 16 * 2 ** 30
    model_flops = 6.0 * n_active * tokens_global if kind == "train" else \
        2.0 * n_active * tokens_global
    if flops_global:
        ratio = model_flops / flops_global
    return Roofline(
        arch=cfg.name, shape=shape.name,
        mesh=f"{dp}x{tp}" if chips == dp * tp else f"{chips}",
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant, model_flops_global=model_flops,
        hlo_flops_device=hlo, useful_ratio=ratio, fit_hbm=fit)


def _cache_bytes(cfg: ModelConfig, B: int, S: int) -> float:
    T = min(S, cfg.sliding_window or S)
    if cfg.family in ("ssm", "hybrid"):
        di, N, H, P = (cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_num_heads,
                       cfg.ssm_head_dim)
        per = B * (H * N * P * F32 + (cfg.conv_width - 1) * (di + 2 * N)
                   * BF16)
        base = cfg.num_layers * per
        if cfg.family == "hybrid":
            n_attn = cfg.num_layers // cfg.shared_attn_every
            base += n_attn * B * T * 2 * cfg.num_kv_heads * \
                cfg.resolved_head_dim * BF16
        return base
    if cfg.attn_type == "mla":
        return cfg.num_layers * B * T * (cfg.kv_lora_rank
                                         + cfg.qk_rope_head_dim) * BF16
    return cfg.num_layers * B * T * 2 * cfg.num_kv_heads * \
        cfg.resolved_head_dim * BF16


def _collective_bytes(cfg: ModelConfig, shape: ShapeConfig, dp: int,
                      tp: int, kind: str, micro: int, p_total: float,
                      tokens_global: float) -> float:
    """Analytic per-device wire bytes per step (ring-algorithm factors:
    all-reduce ~ 2x its buffer; all-gathers of O(n) norms are negligible).

      train : CGC gradient psum over the data axes (2 x local param shard)
              + tensor-parallel activation psums (2/layer fwd, 2/layer bwd)
      prefill/decode : tensor-parallel activation psums (2/layer)
    """
    coll = 0.0
    if kind == "train" and dp > 1:
        coll += 2.0 * (p_total / tp)             # CGC-filtered grad psum
    if tp > 1:
        tokens_dev = tokens_global / max(dp, 1)
        act_dev = tokens_dev * cfg.d_model * BF16
        psums_per_layer = 4 if kind == "train" else 2
        coll += 2.0 * act_dev * psums_per_layer * cfg.num_layers
    return coll
