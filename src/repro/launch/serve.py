"""Serving entry points — a thin shim over ``repro.serve`` (DESIGN.md §7).

The continuous-batching engine (paged KV cache, FCFS scheduler, Pallas
paged-decode kernel) lives in ``repro.serve``; this module keeps the
fixed-batch building blocks (``make_serve_step``/``make_prefill`` for the
dry-run and benchmarks, ``greedy_decode`` as the baseline decode loop)
and the CLI:

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke

runs a synthetic mixed-length request trace through :class:`ServeEngine`
and prints the throughput/latency summary.
"""
from __future__ import annotations

from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist import ShardCtx, make_shard_ctx
from repro.models import model as M
from repro.serve import (RequestHandle, ServeConfig,  # noqa: F401 (shim)
                         ServeEngine)

F32 = jnp.float32


def make_serve_step(cfg: ModelConfig, mesh, global_batch: int,
                    moe_impl: str = "tp") -> Tuple[Callable, ShardCtx]:
    """serve_step(values, cache, token, pos) -> (logits, new_cache)."""
    ctx = make_shard_ctx(mesh, global_batch, moe_impl)

    def serve_step(values, cache, token, pos):
        return M.decode_step(values, cfg, cache, token, pos,
                             shard_ctx=ctx if mesh is not None else None)

    return serve_step, ctx


def make_prefill(cfg: ModelConfig, mesh, global_batch: int,
                 moe_impl: str = "tp") -> Tuple[Callable, ShardCtx]:
    """prefill(values, inputs) -> last-position logits (B, V)."""
    ctx = make_shard_ctx(mesh, global_batch, moe_impl)

    def prefill(values, inputs):
        return M.prefill_logits(values, cfg, inputs,
                                shard_ctx=ctx if mesh is not None else None)

    return prefill, ctx


def greedy_decode(cfg: ModelConfig, values, cache, first_token, start_pos,
                  steps: int, serve_step, eos: Optional[int] = None):
    """Greedy fixed-batch decode loop (example/benchmark baseline).

    Without ``eos`` every sequence scans all ``steps`` positions. With
    ``eos`` each sequence stops at its first EOS — positions after it
    emit ``eos`` (and append EOS KVs, keeping the cache well-defined) —
    and the loop exits as soon as EVERY sequence has finished instead of
    burning ``steps`` iterations regardless.
    """
    B = first_token.shape[0]
    if eos is None:
        def body(carry, _):
            cache, tok, pos = carry
            logits, cache = serve_step(values, cache, tok, pos)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
            return (cache, nxt, pos + 1), nxt[:, 0]

        (cache, _, _), toks = jax.lax.scan(
            body, (cache, first_token, start_pos), None, length=steps)
        return jnp.moveaxis(toks, 0, 1), cache   # (B, steps)

    def cond(st):
        t, _, _, _, done, _ = st
        return (t < steps) & ~jnp.all(done)

    def body(st):
        t, cache, tok, pos, done, out = st
        logits, cache = serve_step(values, cache, tok, pos)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        nxt = jnp.where(done, eos, nxt)
        out = jax.lax.dynamic_update_slice(out, nxt[:, None], (0, t))
        return (t + 1, cache, nxt[:, None], pos + 1,
                done | (nxt == eos), out)

    done0 = first_token[:, 0] == eos
    out0 = jnp.full((B, steps), eos, jnp.int32)
    _, cache, _, _, _, toks = jax.lax.while_loop(
        cond, body, (jnp.zeros((), jnp.int32), cache, first_token,
                     start_pos, done0, out0))
    return toks, cache


# ---------------------------------------------------------------------------
# Script entry: synthetic serve session over the continuous-batching engine
# ---------------------------------------------------------------------------


def main(argv=None):
    import argparse

    import numpy as np

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-friendly)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--num-pages", type=int, default=128)
    ap.add_argument("--max-blocks-per-seq", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32,
                    help="max synthetic prompt length")
    ap.add_argument("--gen", type=int, default=32,
                    help="max tokens generated per request")
    ap.add_argument("--token-budget", type=int, default=256)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--metrics", default=None,
                    help="jsonl metrics sink path")
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args(argv)

    from repro.configs import get_config, reduced

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    if not cfg.has_decode:
        raise SystemExit(f"{args.arch} is encoder-only: no decode step")

    params = M.init_params(cfg, jax.random.PRNGKey(args.seed))
    engine = ServeEngine(cfg, params, ServeConfig(
        max_batch=args.max_batch, page_size=args.page_size,
        num_pages=args.num_pages,
        max_blocks_per_seq=args.max_blocks_per_seq,
        token_budget=args.token_budget, metrics_path=args.metrics,
        log_every=args.log_every))

    rng = np.random.default_rng(args.seed)
    handles = []
    for _ in range(args.requests):
        plen = int(rng.integers(2, max(args.prompt_len, 2) + 1))
        gen = int(rng.integers(1, max(args.gen, 1) + 1))
        prompt = rng.integers(0, cfg.vocab_size, size=plen).tolist()
        handles.append(engine.submit(prompt, max_new=gen))

    engine.drain(max_steps=100 * args.requests * (args.gen + 2))
    engine.sched.check_invariants()
    summary = engine.summary()
    engine.close()

    assert all(h.done for h in handles), "drain left unfinished requests"
    print(f"arch={cfg.name} requests={args.requests} "
          f"lanes={args.max_batch} pages={args.num_pages}"
          f"x{args.page_size}")
    print(f"generated {summary['tokens_generated']} tokens in "
          f"{summary['wall_s']}s ({summary['tokens_per_s']} tok/s), "
          f"{summary['preemptions']} preemptions")
    print(f"latency p50={summary['latency_p50_s']}s "
          f"p99={summary['latency_p99_s']}s "
          f"ttft p50={summary['ttft_p50_s']}s")
    return summary


if __name__ == "__main__":
    main()
