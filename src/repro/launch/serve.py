"""Serving entry points — a thin shim over ``repro.serve`` (DESIGN.md §7).

The continuous-batching engine (paged KV cache, FCFS scheduler, Pallas
paged-decode kernel) lives in ``repro.serve``; this module keeps the
fixed-batch building blocks (``make_serve_step``/``make_prefill`` for the
dry-run and benchmarks, ``greedy_decode`` as the baseline decode loop)
and the CLI:

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke

is a deprecation shim: it emits one DeprecationWarning, adapts the flags
into a :class:`repro.run.RunConfig` and calls ``repro.run.serve`` — the
same facade ``python -m repro serve --config job.json`` runs (synthetic
mixed-length request trace through :class:`ServeEngine`, throughput /
latency summary).
"""
from __future__ import annotations

from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist import ShardCtx, make_shard_ctx
from repro.models import model as M
from repro.serve import (RequestHandle, ServeConfig,  # noqa: F401 (shim)
                         ServeEngine)

F32 = jnp.float32


def make_serve_step(cfg: ModelConfig, mesh, global_batch: int,
                    moe_impl: str = "tp") -> Tuple[Callable, ShardCtx]:
    """serve_step(values, cache, token, pos) -> (logits, new_cache)."""
    ctx = make_shard_ctx(mesh, global_batch, moe_impl)

    def serve_step(values, cache, token, pos):
        return M.decode_step(values, cfg, cache, token, pos,
                             shard_ctx=ctx if mesh is not None else None)

    return serve_step, ctx


def make_prefill(cfg: ModelConfig, mesh, global_batch: int,
                 moe_impl: str = "tp") -> Tuple[Callable, ShardCtx]:
    """prefill(values, inputs) -> last-position logits (B, V)."""
    ctx = make_shard_ctx(mesh, global_batch, moe_impl)

    def prefill(values, inputs):
        return M.prefill_logits(values, cfg, inputs,
                                shard_ctx=ctx if mesh is not None else None)

    return prefill, ctx


def greedy_decode(cfg: ModelConfig, values, cache, first_token, start_pos,
                  steps: int, serve_step, eos: Optional[int] = None):
    """Greedy fixed-batch decode loop (example/benchmark baseline).

    Without ``eos`` every sequence scans all ``steps`` positions. With
    ``eos`` each sequence stops at its first EOS — positions after it
    emit ``eos`` (and append EOS KVs, keeping the cache well-defined) —
    and the loop exits as soon as EVERY sequence has finished instead of
    burning ``steps`` iterations regardless.
    """
    B = first_token.shape[0]
    if eos is None:
        def body(carry, _):
            cache, tok, pos = carry
            logits, cache = serve_step(values, cache, tok, pos)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
            return (cache, nxt, pos + 1), nxt[:, 0]

        (cache, _, _), toks = jax.lax.scan(
            body, (cache, first_token, start_pos), None, length=steps)
        return jnp.moveaxis(toks, 0, 1), cache   # (B, steps)

    def cond(st):
        t, _, _, _, done, _ = st
        return (t < steps) & ~jnp.all(done)

    def body(st):
        t, cache, tok, pos, done, out = st
        logits, cache = serve_step(values, cache, tok, pos)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        nxt = jnp.where(done, eos, nxt)
        out = jax.lax.dynamic_update_slice(out, nxt[:, None], (0, t))
        return (t + 1, cache, nxt[:, None], pos + 1,
                done | (nxt == eos), out)

    done0 = first_token[:, 0] == eos
    out0 = jnp.full((B, steps), eos, jnp.int32)
    _, cache, _, _, _, toks = jax.lax.while_loop(
        cond, body, (jnp.zeros((), jnp.int32), cache, first_token,
                     start_pos, done0, out0))
    return toks, cache


# ---------------------------------------------------------------------------
# Script entry: synthetic serve session over the continuous-batching engine
# ---------------------------------------------------------------------------


def config_from_flags(args) -> "run.RunConfig":
    """Legacy serve flags -> the equivalent RunConfig job tree."""
    from repro import run
    return run.RunConfig(
        name=f"{args.arch}-serve",
        model=run.ModelSpec(arch=args.arch, smoke=args.smoke),
        mesh=run.MeshSpec(devices=0),
        serve=run.ServeSpec(
            requests=args.requests, max_batch=args.max_batch,
            page_size=args.page_size, num_pages=args.num_pages,
            max_blocks_per_seq=args.max_blocks_per_seq,
            prompt_len=args.prompt_len, gen=args.gen,
            token_budget=args.token_budget, seed=args.seed,
            log_every=args.log_every, metrics_path=args.metrics,
            sampling=run.SamplingSpec(temperature=args.temperature,
                                      top_k=args.top_k,
                                      seed=args.sample_seed)))


def main(argv=None):
    import argparse

    from repro.run import facade

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-friendly)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--num-pages", type=int, default=128)
    ap.add_argument("--max-blocks-per-seq", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32,
                    help="max synthetic prompt length")
    ap.add_argument("--gen", type=int, default=32,
                    help="max tokens generated per request")
    ap.add_argument("--token-budget", type=int, default=256)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature (0 = greedy)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="truncate sampling to the k largest logits")
    ap.add_argument("--sample-seed", type=int, default=0)
    ap.add_argument("--metrics", default=None,
                    help="jsonl metrics sink path")
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args(argv)

    facade.warn_legacy("repro.launch.serve", "python -m repro serve")
    try:
        result = facade.serve(config_from_flags(args))
    except ValueError as e:
        raise SystemExit(str(e)) from None
    facade.print_serve_summary(result)
    return result.summary


if __name__ == "__main__":
    main()
