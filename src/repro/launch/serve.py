"""Serving: batched single-token decode (serve_step) and prefill.

``make_serve_step``/``make_prefill`` return jittable functions used by the
dry-run, the decode benchmarks and the serving example.
"""
from __future__ import annotations

from typing import Callable, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist import ShardCtx, make_shard_ctx
from repro.models import model as M

F32 = jnp.float32


def make_serve_step(cfg: ModelConfig, mesh, global_batch: int,
                    moe_impl: str = "tp") -> Tuple[Callable, ShardCtx]:
    """serve_step(values, cache, token, pos) -> (logits, new_cache)."""
    ctx = make_shard_ctx(mesh, global_batch, moe_impl)

    def serve_step(values, cache, token, pos):
        return M.decode_step(values, cfg, cache, token, pos,
                             shard_ctx=ctx if mesh is not None else None)

    return serve_step, ctx


def make_prefill(cfg: ModelConfig, mesh, global_batch: int,
                 moe_impl: str = "tp") -> Tuple[Callable, ShardCtx]:
    """prefill(values, inputs) -> last-position logits (B, V)."""
    ctx = make_shard_ctx(mesh, global_batch, moe_impl)

    def prefill(values, inputs):
        return M.prefill_logits(values, cfg, inputs,
                                shard_ctx=ctx if mesh is not None else None)

    return prefill, ctx


def greedy_decode(cfg: ModelConfig, values, cache, first_token, start_pos,
                  steps: int, serve_step):
    """Greedy multi-token decode loop (example/benchmark helper)."""
    def body(carry, _):
        cache, tok, pos = carry
        logits, cache = serve_step(values, cache, tok, pos)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return (cache, nxt, pos + 1), nxt[:, 0]

    (cache, _, _), toks = jax.lax.scan(
        body, (cache, first_token, start_pos), None, length=steps)
    return jnp.moveaxis(toks, 0, 1), cache   # (B, steps)
