"""Strategy-based training engine + the real driver loop (DESIGN.md §6).

``launch/train.py`` used to carry three ~120-line ``make_*_train_step``
builders whose shard_map plumbing, microbatch accumulation, batch specs
and metrics handling were copy-pasted. This module factors that stack:

    TrainStrategy       protocol: builds specs + the worker_fn body
    ReplicatedStrategy  params replicated over the worker axes, AGG_FNS
                        (CGC / Krum / median / trimmed-mean) aggregation
    FsdpStrategy        params + opt state sharded over the worker axes,
                        blockwise-CGC reduce-scatter in the gather VJP
    EchoDpStrategy      coefficient-space optimistic aggregation (the
                        paper's echo idea as a DP fast path)
    Trainer             the driver: echo-DP optimistic rounds with
                        ``all_echo`` fallback to the exact CGC step,
                        basis bookkeeping, checkpoint/resume of
                        (values, opt_state, step, basis), a pluggable
                        metrics sink (jsonl + stdout), and per-round bit
                        accounting (``core.types.echo_bits``/``raw_bits``)
                        so the paper's communication-savings curve falls
                        out of a training run.

All strategies share ONE shard_map wrapper, ONE microbatch/grad-
accumulation path, ONE batch-spec helper and ONE metrics contract:
``step(values, opt_state, batch, step[, basis]) -> (values, opt_state,
metrics[, aggregate])`` where ``metrics`` always contains ``loss`` plus
per-strategy diagnostics (``all_echo``, ``cgc_threshold``, ...).

The CLI (``python -m repro.launch.train --strategy {replicated,fsdp,
echo_dp}``) is a thin shell over :class:`Trainer`.
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import (Any, Callable, Dict, Iterator, List, Optional, Protocol,
                    Sequence, Tuple)

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import checkpoint as ckpt_lib
from repro import obs
from repro.comm import CommConfig, CommLedger, DEFAULT_COMM, raw_round_bits
from repro.obs.writer import AsyncLineWriter
from repro.run.registry import TRAIN_STRATEGIES
from repro.dist import (AGG_FNS, ShardCtx, inject_byzantine, make_shard_ctx,
                        tree_shardings)
from repro.dist.echo_dp import (basis_gram, echo_dp_aggregate, init_basis,
                                roll_basis, round_comm_bits)
from repro.models import model as M
from repro.optim import Optimizer, clip_by_global_norm

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class TrainSettings:
    aggregator: str = "cgc"        # mean | cgc | trimmed_mean | ...
    f: int = 0                     # CGC clip count (max Byzantine workers)
    n_byz: int = 0                 # simulated Byzantine workers (testing)
    byz_mode: str = "sign_flip"
    microbatches: int = 1
    clip_norm: float = 0.0         # 0 = off
    moe_impl: str = "tp"
    return_aggregate: bool = False  # emit the aggregated grads (echo basis)
    echo_k: int = 4                # echo-DP: reference basis size
    echo_r: float = 0.5            # echo-DP: deviation ratio (Eq. 7)
    fsdp: bool = False             # shard params+opt over the data axes
                                   # (blockwise CGC in the gather VJP)
    remat: str = "full"            # "full" | "save_psum" (§Perf HC2)
    # Communication setup (repro.comm): wire codec + broadcast channel.
    # None = the paper's ideal fp32 comm (bitwise the pre-comm engine).
    comm: Optional[CommConfig] = None
    # Closed-loop control plane (repro.comm.policy, DESIGN.md §13):
    # ``policy`` is a resolved CommPolicy instance (None = no controller,
    # bitwise the pre-policy engine; a static policy only emits events).
    # ``ef`` threads per-worker error-feedback residuals through the
    # echo-DP coefficient all-gather. ``dynamic_r`` is engine-internal:
    # the Trainer sets it on the per-codec step bundles it builds for a
    # dynamic policy, so the step takes Eq. 7's r as a *traced* scalar
    # (policy retunes it per round with zero recompiles).
    policy: Optional[Any] = None
    ef: bool = False
    dynamic_r: bool = False


# ---------------------------------------------------------------------------
# Shared plumbing: microbatching, batch specs, shard_map wrapper
# ---------------------------------------------------------------------------


def _slice_batch(batch: Dict[str, jax.Array], i, n_micro: int):
    """The i-th of n_micro slices (mrope_positions has batch at dim 1)."""
    out = {}
    for k, x in batch.items():
        dim = 1 if k == "mrope_positions" else 0
        mb = x.shape[dim] // n_micro
        out[k] = jax.lax.dynamic_slice_in_dim(x, i * mb, mb, dim)
    return out


def microbatched_grads(loss_fn, values, batch, n_micro: int):
    """Gradient accumulation over n_micro slices of the local batch.

    ``loss_fn(values, batch) -> (loss, metrics)``; the metrics zeros are
    derived with eval_shape, so any metrics pytree works (one contract
    for LM losses and the scalar cost functions used in tests).
    """
    if n_micro <= 1:
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(values, batch)
        return loss, metrics, grads

    def body(carry, i):
        g_acc, l_acc, m_acc = carry
        (loss, metrics), g = jax.value_and_grad(loss_fn, has_aux=True)(
            values, _slice_batch(batch, i, n_micro))
        g_acc = jax.tree.map(jnp.add, g_acc, g)
        m_acc = jax.tree.map(jnp.add, m_acc, metrics)
        return (g_acc, l_acc + loss, m_acc), None

    zeros_g = jax.tree.map(lambda v: jnp.zeros(v.shape, F32), values)
    m_abs = jax.eval_shape(loss_fn, values, _slice_batch(batch, 0, n_micro))
    zero_m = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), m_abs[1])
    (g, loss, metrics), _ = jax.lax.scan(
        body, (zeros_g, jnp.zeros((), F32), zero_m), jnp.arange(n_micro))
    inv = 1.0 / n_micro
    return (loss * inv,
            jax.tree.map(lambda m: m * inv, metrics),
            jax.tree.map(lambda x: x * inv, g))


def batch_partition_spec(name: str, data_axes: Sequence[str]) -> P:
    """Spec of one batch entry: sharded over the worker axes on dim 0
    (dim 1 for mrope_positions)."""
    axes = tuple(data_axes)
    bspec = axes if len(axes) > 1 else axes[0]
    return P(None, bspec) if name == "mrope_positions" else P(bspec)


def batch_specs(batch: Dict[str, Any], data_axes: Sequence[str]
                ) -> Dict[str, P]:
    return {k: batch_partition_spec(k, data_axes) for k in batch}


def replicated_specs(tree) -> Any:
    return jax.tree.map(lambda _: P(), tree)


def mirror_opt_specs(vspecs, opt_state) -> Any:
    """Mirror parameter specs onto mirroring optimizer-state subtrees.

    Optimizer states that stack N param-shaped trees (Adam's mu/nu) get
    the param specs repeated; anything else is replicated.
    """
    leaves, treedef = jax.tree.flatten(opt_state)
    vleaves = jax.tree.leaves(vspecs)
    if vleaves and len(leaves) % len(vleaves) == 0:
        reps = len(leaves) // len(vleaves)
        return jax.tree.unflatten(treedef, vleaves * reps)
    return replicated_specs(opt_state)


# ---------------------------------------------------------------------------
# Strategy protocol + shared build skeleton
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class StepBundle:
    """A built train step and everything the driver needs to run it."""

    name: str
    fn: Callable                    # (values, opt_state, batch, step[, basis])
    ctx: ShardCtx
    settings: TrainSettings
    needs_basis: bool = False       # fn takes a trailing basis list
    returns_aggregate: bool = False  # fn emits the aggregate pytree
    value_shardings: Any = None     # placement shardings (FSDP) or None
    plan: Any = None                # FSDP shard plan or None


class TrainStrategy(Protocol):
    """Builds the per-worker step body + its shard_map specs."""

    name: str
    needs_basis: bool

    def build(self, cfg, opt: Optimizer, settings: TrainSettings, mesh,
              global_batch: int) -> StepBundle: ...


class _StrategyBase:
    """Template build(): one worker body, one spec path, one wrapper.

    Subclasses override the hooks (validate / prepare / make_loss_fn /
    aggregate / clip / value_specs / opt_specs); the shard_map wrapping,
    microbatching, Byzantine injection, loss/metrics pmean, gradient
    clipping and optimizer update live here exactly once.

    ``loss_fn`` (constructor) overrides the LM loss with any
    ``(values, batch) -> (loss, metrics)`` callable — the driver tests
    run the full engine on quadratic costs this way.
    """

    name = "base"
    needs_basis = False

    def __init__(self, loss_fn: Optional[Callable] = None):
        self.loss_override = loss_fn

    # --- hooks -------------------------------------------------------

    def validate(self, settings: TrainSettings, ctx: ShardCtx, mesh):
        pass

    def prepare(self, cfg, opt, settings, mesh, ctx) -> Dict[str, Any]:
        return {}

    def make_loss_fn(self, cfg, settings, mesh, ctx, env) -> Callable:
        raise NotImplementedError

    def aggregate(self, env, grads, settings, data_axes, extra
                  ) -> Tuple[Any, Dict[str, jax.Array]]:
        raise NotImplementedError

    def clip(self, env, grads, settings, data_axes):
        return clip_by_global_norm(grads, settings.clip_norm)

    def value_specs(self, env, values):
        return replicated_specs(values)

    def opt_specs(self, env, opt_state, vspecs):
        return replicated_specs(opt_state)

    # --- template ----------------------------------------------------

    def build(self, cfg, opt: Optimizer, settings: TrainSettings, mesh,
              global_batch: int) -> StepBundle:
        ctx = make_shard_ctx(mesh, global_batch, settings.moe_impl)
        data_axes = ctx.batch_axes
        self.validate(settings, ctx, mesh)
        env = self.prepare(cfg, opt, settings, mesh, ctx)
        loss_fn = self.loss_override or self.make_loss_fn(
            cfg, settings, mesh, ctx, env)
        ret_agg = self.needs_basis or settings.return_aggregate

        def worker_fn(values, opt_state, batch, step, *extra):
            loss, metrics, grads = microbatched_grads(
                loss_fn, values, batch, settings.microbatches)
            if settings.n_byz and data_axes:
                from repro.dist.collectives import worker_index
                grads = inject_byzantine(grads, worker_index(data_axes),
                                         settings.n_byz, settings.byz_mode)
            agg, diags = self.aggregate(env, grads, settings, data_axes,
                                        extra)
            if data_axes:
                loss = jax.lax.pmean(loss, data_axes)
                metrics = jax.tree.map(
                    lambda m: jax.lax.pmean(m, data_axes), metrics)
            if settings.clip_norm:
                agg, gnorm = self.clip(env, agg, settings, data_axes)
                diags = dict(diags, grad_global_norm=gnorm)
            updates, opt_state = opt.update(agg, opt_state, values, step)
            values = jax.tree.map(lambda p, u: p + u.astype(p.dtype),
                                  values, updates)
            metrics = dict(metrics, loss=loss, **diags)
            if ret_agg:
                return values, opt_state, metrics, agg
            return values, opt_state, metrics

        if mesh is None or not data_axes:
            return StepBundle(self.name, worker_fn, ctx, settings,
                              returns_aggregate=ret_agg)

        def stepped(values, opt_state, batch, step, *extra):
            vspecs = self.value_specs(env, values)
            ospecs = self.opt_specs(env, opt_state, vspecs)
            in_specs = (vspecs, ospecs, batch_specs(batch, data_axes), P(),
                        *[replicated_specs(b) for b in extra])
            out_specs = (vspecs, ospecs, P()) + (
                (replicated_specs(values),) if ret_agg else ())
            fn = jax.shard_map(worker_fn, mesh=mesh, in_specs=in_specs,
                               out_specs=out_specs,
                               axis_names=set(data_axes), check_vma=False)
            return fn(values, opt_state, batch, step, *extra)

        if self.needs_basis:
            def step_fn(values, opt_state, batch, step, basis):
                return stepped(values, opt_state, batch, step, *basis)
        else:
            step_fn = stepped

        return StepBundle(self.name, step_fn, ctx, settings,
                          needs_basis=self.needs_basis,
                          returns_aggregate=ret_agg,
                          value_shardings=env.get("value_shardings"),
                          plan=env.get("plan"))


@TRAIN_STRATEGIES.register("replicated")
class ReplicatedStrategy(_StrategyBase):
    """Params replicated over the worker axes; AGG_FNS aggregation.

    Exactly the paper's setup: each data shard is one Byzantine-fault-
    containment unit, aggregation is CGC (or any ``AGG_FNS`` entry) over
    the worker axes, every worker applies the identical update.
    """

    name = "replicated"

    def validate(self, settings, ctx, mesh):
        if settings.aggregator not in AGG_FNS:
            raise ValueError(f"unknown aggregator {settings.aggregator!r}; "
                             f"known: {sorted(AGG_FNS)}")

    def prepare(self, cfg, opt, settings, mesh, ctx):
        data_axes = ctx.batch_axes
        if settings.moe_impl == "ep" and mesh is not None:
            # expert parallelism runs a NESTED shard_map over the model
            # axis (disjoint from the worker's manual data axes): batch
            # is already local, so batch_axes=() inside.
            from repro.dist.compat import partial_manual_supported
            if data_axes and not partial_manual_supported():
                raise ValueError(
                    "moe_impl='ep' inside the worker shard_map needs "
                    "partial-manual shard_map (jax >= 0.6); this jax only "
                    "supports EP at the pjit level (serve/prefill) — use "
                    "moe_impl='tp' for training")
            inner = ShardCtx(mesh=mesh, batch_axes=(), model_axis="model",
                             moe_impl="ep", remat=settings.remat)
        else:
            inner = (ShardCtx(remat=settings.remat)
                     if settings.remat != "full" else None)
        return {"inner_ctx": inner}

    def make_loss_fn(self, cfg, settings, mesh, ctx, env):
        inner = env["inner_ctx"]
        # inside the worker shard_map the batch is already local -> the
        # MoE layer dispatches locally (model axis auto) unless EP.
        return lambda values, batch: M.train_loss(values, cfg, batch,
                                                  shard_ctx=inner)

    def aggregate(self, env, grads, settings, data_axes, extra):
        if not data_axes:
            return grads, {}
        return AGG_FNS[settings.aggregator](grads, data_axes, settings.f)


@TRAIN_STRATEGIES.register("fsdp")
class FsdpStrategy(_StrategyBase):
    """FSDP (§Perf HC1): params + opt state sharded over the data axes,
    per-layer just-in-time gathers, blockwise CGC on the reduce-scatter
    (dist/fsdp.py). ``value_shardings`` on the bundle carries the
    NamedShardings the driver must place operands with (params are
    LOGICALLY global; FSDP is purely a placement + spec concern).
    """

    name = "fsdp"

    def validate(self, settings, ctx, mesh):
        if settings.aggregator not in ("cgc", "mean"):
            raise ValueError(
                f"FSDP trainer supports aggregator 'cgc' or 'mean' (the "
                f"reduction happens inside the gather VJP), got "
                f"{settings.aggregator!r}")
        if not ctx.batch_axes:
            raise ValueError("FSDP needs a data-parallel axis")
        if settings.n_byz:
            raise ValueError("Byzantine injection is incompatible with FSDP "
                             "(per-worker grads never materialise whole); "
                             "use the replicated trainer to exercise attacks")
        if settings.return_aggregate:
            raise ValueError("return_aggregate is incompatible with FSDP: "
                             "planned gradient leaves are shard-local after "
                             "the reduce-scatter, so no replicated aggregate "
                             "pytree exists to emit")

    def prepare(self, cfg, opt, settings, mesh, ctx):
        from repro.dist.fsdp import (fsdp_manual_specs, fsdp_tree_shardings,
                                     make_gather_fn, plan_fsdp)
        from repro.launch.specs import abstract_params

        data_axes = ctx.batch_axes
        params_abs = abstract_params(cfg)
        plan = plan_fsdp(params_abs, mesh, dp_axes=data_axes)
        # layers subtree gathers inside the scan; everything else up-front.
        plan_top = dict(plan)
        layer_plan = plan_top.pop("layers", None)
        top_plan_full = dict(plan_top)
        if layer_plan is not None:
            top_plan_full["layers"] = jax.tree.map(
                lambda _: None, layer_plan, is_leaf=lambda x: x is None)
        use_cgc = settings.aggregator == "cgc"
        gather_top = make_gather_fn(top_plan_full, data_axes, settings.f,
                                    use_cgc)
        layer_gf = (make_gather_fn(layer_plan, data_axes, settings.f,
                                   use_cgc, strip_layer_dim=True)
                    if layer_plan is not None else None)
        inner_ctx = dataclasses.replace(ShardCtx(), layer_gather=layer_gf,
                                        remat=settings.remat)
        return {
            "plan": plan,
            "use_cgc": use_cgc,
            "gather_top": gather_top,
            "inner_ctx": inner_ctx,
            "vspecs": fsdp_manual_specs(params_abs, plan, data_axes),
            "value_shardings": fsdp_tree_shardings(params_abs, mesh, plan,
                                                   dp_axes=data_axes),
        }

    def make_loss_fn(self, cfg, settings, mesh, ctx, env):
        gather_top, inner = env["gather_top"], env["inner_ctx"]
        return lambda values, batch: M.train_loss(gather_top(values), cfg,
                                                  batch, shard_ctx=inner)

    def aggregate(self, env, grads, settings, data_axes, extra):
        # fsdp leaves: already blockwise-clipped + reduce-scattered in the
        # gather VJP; the replicated remainder gets the exact matching psum.
        from repro.dist.fsdp import aggregate_rest_cgc
        return aggregate_rest_cgc(grads, env["plan"], data_axes, settings.f,
                                  use_cgc=env["use_cgc"]), {}

    def clip(self, env, grads, settings, data_axes):
        # layout-aware: planned leaves are shards, rest is replicated
        from repro.dist.fsdp import clip_fsdp_global_norm
        return clip_fsdp_global_norm(grads, env["plan"], data_axes,
                                     settings.clip_norm)

    def value_specs(self, env, values):
        return env["vspecs"]

    def opt_specs(self, env, opt_state, vspecs):
        return mirror_opt_specs(vspecs, opt_state)


@TRAIN_STRATEGIES.register("echo_dp")
class EchoDpStrategy(_StrategyBase):
    """Echo-compressed DP step (dist/echo_dp.py — §Perf HC3).

    ``step(values, opt_state, batch, step, basis) -> (values, opt_state,
    metrics, aggregate)`` where ``basis`` is a list of echo_k reference
    pytrees (recent raw-round aggregates, replicated on every worker).
    ``metrics["all_echo"]`` reports whether the fast path was valid —
    the :class:`Trainer` re-runs the round with the exact CGC step when
    it is not, and rolls ``basis`` with that raw aggregate.

    The trailing ``basis`` list doubles as the control-plane data path:
    after the echo_k reference pytrees, ``settings.dynamic_r`` appends a
    traced Eq. 7 threshold scalar and ``settings.ef`` appends the
    replicated (n, K) error-feedback residual state — both ride the same
    replicated extras plumbing, so a policy retuning r (or the residual
    carrying across rounds) never triggers a recompile.
    """

    name = "echo_dp"
    needs_basis = True

    def validate(self, settings, ctx, mesh):
        if not ctx.batch_axes:
            raise ValueError("echo-DP aggregation needs a data-parallel axis")

    def make_loss_fn(self, cfg, settings, mesh, ctx, env):
        return lambda values, batch: M.train_loss(values, cfg, batch,
                                                  shard_ctx=None)

    def aggregate(self, env, grads, settings, data_axes, extra):
        extra = list(extra)
        basis, rest = extra[:settings.echo_k], extra[settings.echo_k:]
        r = rest.pop(0) if settings.dynamic_r else settings.echo_r
        ef = rest.pop(0) if settings.ef else None
        gram = basis_gram(basis)
        # lossy codecs quantize the transmitted coefficient vectors; the
        # lossless default keeps the jaxpr identical to the pre-comm step.
        codec = settings.comm.codec if settings.comm is not None else None
        if codec is not None and codec.lossless:
            codec = None
        agg, all_echo, diags = echo_dp_aggregate(
            grads, basis, gram, data_axes, settings.f, r,
            codec=codec, ef=ef)
        return agg, dict(diags, all_echo=all_echo)


# The shared plugin registry (repro.run.registry): a new strategy is one
# @TRAIN_STRATEGIES.register("name") class implementing TrainStrategy.
STRATEGIES = TRAIN_STRATEGIES


# ---------------------------------------------------------------------------
# Shardings for the step operands (shared sharding helpers)
# ---------------------------------------------------------------------------


def param_shardings(params_tree, mesh, rules=None):
    return tree_shardings(params_tree, mesh, rules)


def batch_shardings(batch_specs_tree, mesh, rules=None):
    return tree_shardings(batch_specs_tree, mesh, rules)


def opt_state_shardings(opt_state_abs, params_tree, mesh, rules=None,
                        override=None):
    """Mirror parameter shardings onto the optimizer state by path suffix.

    ``override``: a plain sharding tree (e.g. FSDP shardings) to mirror
    instead of the default rule-derived one. The lookup is a dict keyed
    by the param paths, probed with progressively shorter "/"-suffixes
    of each opt-state path — O(depth) per leaf instead of the old
    O(params) scan, and longest-suffix-first instead of insertion order.
    """
    from repro.checkpoint.ckpt import _flatten_with_paths, _path_str
    pshard = override if override is not None else tree_shardings(
        params_tree, mesh, rules)
    by_path = _flatten_with_paths(pshard)
    rep = NamedSharding(mesh, P())

    leaves = []
    for path, _ in jax.tree_util.tree_flatten_with_path(opt_state_abs)[0]:
        parts = [_path_str(p) for p in path]
        sh = rep
        for i in range(len(parts)):
            cand = "/".join(parts[i:])
            if cand in by_path:
                sh = by_path[cand]
                break
        leaves.append(sh)
    treedef = jax.tree_util.tree_structure(opt_state_abs)
    return jax.tree_util.tree_unflatten(treedef, leaves)


# ---------------------------------------------------------------------------
# Metrics sink
# ---------------------------------------------------------------------------


def _train_record_line(record: Dict[str, Any]) -> str:
    parts = [f"step {record.get('step', 0):5d}",
             f"loss={record.get('loss', 0.0):.4f}"]
    if "all_echo" in record:
        parts.append(f"all_echo={record['all_echo']}")
    if "bits_cumulative" in record:
        parts.append(f"bits={record['bits_cumulative']:.3e}")
    return "  ".join(parts)


class MetricsSink:
    """Per-round metrics writer: jsonl file (every round) + stdout
    (every ``log_every`` rounds). ``printer`` is pluggable for tests;
    ``formatter`` maps a record to its stdout line (default: the trainer
    step/loss/bits line — ``repro.serve`` passes its own).

    jsonl writes are non-blocking: ``emit`` enqueues the serialised
    record and returns; the shared :class:`repro.obs.AsyncLineWriter`
    drains the queue to the file so metrics I/O stays off the driver
    hot loop. ``flush`` blocks until everything enqueued so far is on
    disk; ``close`` flushes, stops the thread and closes the file. Both
    re-raise the first background write error (the
    ``AsyncCheckpointWriter`` contract), and the writer's atexit hook
    lands the tail records even when a run crashes past ``close``.
    """

    def __init__(self, path: Optional[str] = None, log_every: int = 5,
                 printer: Optional[Callable[[str], None]] = None,
                 formatter: Optional[Callable[[Dict[str, Any]], str]] = None):
        self.log_every = max(int(log_every), 1)
        self._writer = AsyncLineWriter(path) if path else None
        self._print = (lambda s: print(s, flush=True)) \
            if printer is None else printer
        self._format = formatter or _train_record_line

    def emit(self, record: Dict[str, Any]) -> None:
        if self._writer is not None:
            self._writer.write(json.dumps(record) + "\n")
        step = record.get("step", 0)
        if step % self.log_every == 0 or record.get("final"):
            self._print(self._format(record))

    def flush(self, timeout: Optional[float] = None) -> bool:
        """Block until every record emitted so far is written to disk.
        Default blocks indefinitely (the durability the old synchronous
        sink had); with a timeout, returns False if it expired. Raises
        if the background writer hit an error."""
        if self._writer is None:
            return True
        return self._writer.flush(timeout)

    def close(self) -> None:
        if self._writer is not None:
            writer, self._writer = self._writer, None
            writer.close()


# ---------------------------------------------------------------------------
# Trainer: the driver loop
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TrainerConfig:
    log_every: int = 5
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 0             # 0: checkpoint only at the end of fit()
    resume: bool = False
    metrics_path: Optional[str] = None  # jsonl sink
    # When the echo basis rolls: "raw" (only after raw/fallback rounds —
    # the paper's reference set R holds overheard RAW gradients; echo
    # aggregates lie in span(basis) and add no information) or "always".
    roll_policy: str = "raw"
    # jax.profiler trace window over the first ``profile_steps`` rounds
    # of fit() (0 = off), written to ``profile_dir``. Profiler failures
    # become obs events, never run failures.
    profile_steps: int = 0
    profile_dir: Optional[str] = None


@dataclasses.dataclass
class TrainState:
    """Everything a resume needs: (values, opt_state, step, basis) plus
    the (n, K) error-feedback residuals when ``TrainSettings.ef`` is on."""

    values: Any
    opt_state: Any
    step: int = 0
    basis: Optional[List[Any]] = None
    ef: Optional[jax.Array] = None


class Trainer:
    """Owns the real training loop over a built :class:`StepBundle`.

    For :class:`EchoDpStrategy` each round first runs the optimistic
    coefficient-space step; when any worker fails the echo test (Eq. 7)
    the round is re-run with the exact CGC step (``ReplicatedStrategy``
    with ``return_aggregate=True``) and the basis rolls with the raw
    aggregate.

    Communication accounting flows through ``repro.comm``: the wire
    codec prices every round (an echo attempt costs
    ``n * echo_msg_bits(n, K)``, a raw/fallback round adds
    ``n * raw_msg_bits(d)``, the all-raw baseline is ``n *
    raw_msg_bits(d)`` per round — the paper's closed form under fp32),
    the broadcast channel can fade echo slots (forcing the raw fallback,
    seeded + reproducible) or refuse over-budget attempts, and every
    round reports into one :class:`~repro.comm.CommLedger` whose fields
    feed the metrics sink. Checkpoint writes happen on a background
    thread (``ckpt_lib.AsyncCheckpointWriter``) so the driver loop never
    blocks on .npz serialization; ``restore``/``close`` flush it.
    """

    def __init__(self, strategy, cfg, opt: Optimizer,
                 settings: TrainSettings, mesh, global_batch: int,
                 config: TrainerConfig = TrainerConfig(),
                 loss_fn: Optional[Callable] = None,
                 printer: Optional[Callable[[str], None]] = None,
                 hooks: Optional[obs.Hooks] = None):
        if isinstance(strategy, str):
            strategy = STRATEGIES[strategy](loss_fn=loss_fn)
        self.strategy = strategy
        self.opt = opt
        self.settings = settings
        self.config = config
        self.mesh = mesh
        self._model_cfg = cfg
        self._global_batch = global_batch
        self.comm = settings.comm if settings.comm is not None \
            else DEFAULT_COMM
        self.bundle = strategy.build(cfg, opt, settings, mesh, global_batch)
        # Donation audit (DESIGN.md §10): a plain round consumes
        # (values, opt_state) and returns their successors, so both
        # buffers are donated — XLA updates weights and moments in place
        # instead of holding two copies of the model live. The echo-DP
        # OPTIMISTIC step must NOT donate: when Eq. 7 fails its outputs
        # are discarded and the same inputs re-enter the exact fallback
        # step, so they have to survive the call. The fallback itself is
        # terminal for the round and donates. Batches are never donated
        # (callers may replay them).
        donate = () if self.bundle.needs_basis else (0, 1)
        self.step_fn = jax.jit(self.bundle.fn, donate_argnums=donate)
        self.fallback_fn = None
        if self.bundle.needs_basis:
            fb = ReplicatedStrategy(
                loss_fn=getattr(strategy, "loss_override", None))
            fb_settings = dataclasses.replace(settings,
                                              return_aggregate=True)
            self.fallback_bundle = fb.build(cfg, opt, fb_settings, mesh,
                                            global_batch)
            self.fallback_fn = jax.jit(self.fallback_bundle.fn,
                                       donate_argnums=(0, 1))
        self.sink = MetricsSink(config.metrics_path, config.log_every,
                                printer)
        self.hooks = obs.as_hooks(hooks)
        self.n_workers = self.bundle.ctx.num_workers
        self._d: Optional[int] = None
        self.ledger = CommLedger()
        self._ckpt_writer: Optional[ckpt_lib.AsyncCheckpointWriter] = None
        self._first_loss: Optional[float] = None
        self._last_loss: Optional[float] = None
        # Control plane (repro.comm.policy): a dynamic policy retunes
        # (codec, echo_r, budget) per round from the previous round's
        # observation; a static one only emits its constant decisions.
        self.policy = settings.policy
        self._policy_dynamic = (self.policy is not None
                                and not getattr(self.policy, "static",
                                                False))
        self._policy_ready = False
        self._last_obs = None
        self._cur_codec_name = self.comm.codec.name
        self._cur_r = float(settings.echo_r)
        self._cur_budget: Optional[int] = None
        self.codec_switches = 0
        self._fp32_cum = 0
        self._codec_cache: Dict[str, Any] = {self.comm.codec.name:
                                             self.comm.codec}
        self._opt_steps: Dict[str, Callable] = {}

    # Legacy counter surface — reads delegate to the comm ledger, which
    # is the single accounting authority now.

    @property
    def n_rounds(self) -> int:
        return self.ledger.rounds

    @property
    def n_echo(self) -> int:
        return self.ledger.echo_rounds

    @property
    def bits_sent(self) -> int:
        return self.ledger.bits_sent

    @property
    def bits_baseline(self) -> int:
        return self.ledger.bits_baseline

    # --- state management -------------------------------------------

    def init_state(self, values, opt_state=None) -> TrainState:
        """Fresh state (placed per the strategy's shardings); resumes
        from ``config.ckpt_dir`` when ``config.resume`` is set and a
        checkpoint exists."""
        # the step fns donate their (values, opt_state) arguments, so the
        # state must own its buffers — never alias what the caller holds
        values = jax.tree.map(jnp.copy, values)
        if self.bundle.value_shardings is not None:
            values = jax.device_put(values, self.bundle.value_shardings)
        if opt_state is None:
            opt_state = self.opt.init(values)
        else:
            opt_state = jax.tree.map(jnp.copy, opt_state)
        basis = (init_basis(values, self.settings.echo_k)
                 if self.bundle.needs_basis else None)
        ef = None
        if self.bundle.needs_basis and self.settings.ef:
            from repro.comm.policy import ef_init
            ef = ef_init(self.n_workers, self.settings.echo_k)
        state = TrainState(values, opt_state, 0, basis, ef)
        cfg = self.config
        if cfg.resume and cfg.ckpt_dir \
                and ckpt_lib.latest_step(cfg.ckpt_dir) is not None:
            state = self.restore(state)
        return state

    def restore(self, like: TrainState, step: Optional[int] = None
                ) -> TrainState:
        if self._ckpt_writer is not None:
            self._ckpt_writer.flush()     # pending async saves land first
        extra_like = {"basis": like.basis} if like.basis is not None else None
        if extra_like is not None and like.ef is not None:
            extra_like["ef"] = like.ef
        values, opt_state, extra, at, complete = ckpt_lib.restore_train_state(
            self.config.ckpt_dir, like.values, like.opt_state,
            extra_like=extra_like, step=step)
        if self.bundle.value_shardings is not None:
            values = jax.device_put(values, self.bundle.value_shardings)
            oshard = opt_state_shardings(
                opt_state, None, self.mesh,
                override=self.bundle.value_shardings)
            opt_state = jax.device_put(opt_state, oshard)
        if not complete:
            # pre-v1 checkpoint: values only — keep the fresh opt/basis.
            opt_state = self.opt.init(values)
        basis = (extra or {}).get("basis", like.basis) \
            if extra is not None else like.basis
        ef = (extra or {}).get("ef", like.ef) \
            if extra is not None else like.ef
        return TrainState(values, opt_state, at, basis, ef)

    def save(self, state: TrainState, wait: bool = True) -> Optional[str]:
        """Checkpoint ``state``; returns the target .npz path.

        The write runs on the background checkpoint thread.
        ``wait=True`` (the default for direct calls) blocks until it is
        on disk; the driver loop passes ``wait=False`` so periodic
        checkpoints never stall training. An async save snapshots the
        state to host memory first: the step fns donate their input
        buffers, so by the time the writer thread serializes, the
        device arrays of this round may already have been consumed by
        the next one.
        """
        if not self.config.ckpt_dir:
            return None
        if self._ckpt_writer is None:
            self._ckpt_writer = ckpt_lib.AsyncCheckpointWriter()
        values, opt_state = state.values, state.opt_state
        extra_state = ({"basis": state.basis}
                       if state.basis is not None else None)
        if extra_state is not None and state.ef is not None:
            extra_state["ef"] = state.ef
        if not wait:
            snap = lambda t: jax.tree.map(      # noqa: E731
                lambda x: np.array(x, copy=True), t)
            values, opt_state = snap(values), snap(opt_state)
            extra_state = snap(extra_state)
        path = self._ckpt_writer.submit(
            self.config.ckpt_dir, state.step, values, opt_state,
            extra_state=extra_state,
            extra={"strategy": self.bundle.name})
        if wait:
            self._ckpt_writer.flush()
        return path

    # --- the loop ----------------------------------------------------

    def _grad_dim(self, values) -> int:
        if self._d is None:
            self._d = int(sum(v.size for v in jax.tree.leaves(values)))
        return self._d

    # --- the control plane (repro.comm.policy, DESIGN.md §13) --------

    def _codec_obj(self, name: str):
        """Codec instance for a policy-decided name (the configured
        instance when the name matches — keeping e.g. a custom topk k —
        registry defaults otherwise)."""
        codec = self._codec_cache.get(name)
        if codec is None:
            from repro.run.registry import CODECS
            codec = self._codec_cache[name] = CODECS[name](None)
        return codec

    def _ensure_policy(self, d: int) -> None:
        """One-time policy setup: topology, starting point, price list."""
        if self._policy_ready:
            return
        from repro.comm.policy import CODEC_LADDER, PolicyContext
        n, K = self.n_workers, self.settings.echo_k
        raw = {c: int(raw_round_bits(self._codec_obj(c), n, d))
               for c in CODEC_LADDER}
        echo = {c: n * int(self._codec_obj(c).echo_msg_bits(n, K))
                for c in CODEC_LADDER} if self.bundle.needs_basis \
            else {c: 0 for c in CODEC_LADDER}
        chan = self.comm.channel
        self.policy.setup(PolicyContext(
            n=n, d=d, echo_k=K, codec=self.comm.codec.name,
            echo_r=float(self.settings.echo_r), channel=chan.name,
            drop_prob=float(getattr(chan, "drop_prob", 0.0)),
            budget_bits=int(getattr(chan, "budget_bits", 0)),
            raw_round_bits=raw, echo_round_bits=echo))
        self._policy_ready = True

    def _opt_step_for(self, codec_name: str) -> Callable:
        """The jitted optimistic step for one policy-decided codec.

        Built lazily and cached per codec name (the ladder bounds the
        cache at 4 entries); each bundle carries ``dynamic_r=True`` so
        Eq. 7's r arrives as a traced scalar — the policy can retune it
        every round without a single recompile. Optimistic steps never
        donate (their outputs are discarded on fallback).
        """
        fn = self._opt_steps.get(codec_name)
        if fn is None:
            s = dataclasses.replace(
                self.settings, dynamic_r=True,
                comm=CommConfig(channel=self.comm.channel,
                                codec=self._codec_obj(codec_name)))
            bundle = type(self.strategy)(
                loss_fn=getattr(self.strategy, "loss_override", None)
            ).build(self._model_cfg, self.opt, s, self.mesh,
                    self._global_batch)
            fn = self._opt_steps[codec_name] = jax.jit(bundle.fn,
                                                       donate_argnums=())
        return fn

    def _policy_decide(self, step: int, d: int):
        """Ask the policy for this round's (codec, channel, echo_r).

        Without a policy this is a passthrough of the configured comm.
        With one, the previous round's observation feeds ``observe`` and
        the decision is applied — but only a *dynamic* policy actually
        changes anything; a static policy's constant decision is emitted
        as events/counters and otherwise ignored, keeping the trajectory
        bitwise identical to the no-policy engine.
        """
        codec, channel = self.comm.codec, self.comm.channel
        echo_r = float(self.settings.echo_r)
        if self.policy is None:
            return codec, channel, echo_r
        self._ensure_policy(d)
        decision = self.policy.observe(self._last_obs)
        obs.counter("comm.policy.decisions")
        switched = r_changed = False
        if self._policy_dynamic:
            if decision.codec is not None \
                    and decision.codec != self._cur_codec_name:
                self._cur_codec_name = decision.codec
                self.codec_switches += 1
                switched = True
                obs.counter("comm.policy.codec_switches")
            if decision.echo_r is not None \
                    and float(decision.echo_r) != self._cur_r:
                self._cur_r = float(decision.echo_r)
                r_changed = True
                obs.counter("comm.policy.echo_r_changes")
            if decision.budget_bits is not None:
                self._cur_budget = int(decision.budget_bits)
            codec = self._codec_obj(self._cur_codec_name)
            echo_r = self._cur_r
            if self._cur_budget is not None \
                    and hasattr(channel, "budget_bits"):
                channel = dataclasses.replace(channel,
                                              budget_bits=self._cur_budget)
        if switched or r_changed:
            obs.event("comm.policy.decision", step=step,
                      policy=self.policy.name, codec=codec.name,
                      echo_r=echo_r, codec_switched=switched,
                      echo_r_changed=r_changed)
        return codec, channel, echo_r

    def _step_and_extras(self, state: TrainState, codec, echo_r: float):
        """The optimistic step fn + its trailing extras list: the basis,
        then (dynamic policies) the traced Eq. 7 threshold, then (ef)
        the residual state — matching ``EchoDpStrategy.aggregate``."""
        extras = list(state.basis)
        if self._policy_dynamic:
            fn = self._opt_step_for(codec.name)
            extras.append(jnp.asarray(echo_r, F32))
        else:
            fn = self.step_fn
        if self.settings.ef and state.ef is not None:
            extras.append(state.ef)
        return fn, extras

    def _observe_round(self, state: TrainState, codec, echo_r: float,
                       bits: int, raw_round: int, loss: float,
                       echoed: bool, attempted: bool, drops: int,
                       led: Dict[str, Any]) -> None:
        """Record the finished round for the policy + the obs stream."""
        from repro.comm import FP32
        from repro.comm.policy import RoundObservation
        n, d = self.n_workers, self._d
        fp32_round = raw_round_bits(FP32, n, d)
        self._fp32_cum += fp32_round
        self._last_obs = RoundObservation(
            round=state.step, bits=bits, baseline_bits=raw_round,
            fp32_baseline_bits=fp32_round, loss=loss, codec=codec.name,
            echo_r=echo_r, attempted=attempted, echoed=echoed,
            echo_drops=drops, refused=self.bundle.needs_basis
            and not attempted)
        obs.event("comm.policy.round", step=state.step,
                  policy=self.policy.name, codec=codec.name,
                  echo_r=echo_r, bits=bits, echoed=echoed,
                  attempted=attempted, echo_drops=drops,
                  bits_cumulative=led["bits_cumulative"],
                  fp32_baseline_cumulative=self._fp32_cum, loss=loss)

    def run_round(self, state: TrainState, batch
                  ) -> Tuple[TrainState, Dict[str, Any]]:
        """One driver round; returns (new_state, metrics record).

        The round is a ``train.round`` span with the optimistic /
        fallback / plain step as child spans, and fires
        ``hooks.on_round_start/end`` around it — host-side only, so
        the jitted computation (and the trajectory) is untouched.
        """
        self.hooks.on_round_start(state.step)
        with obs.span("train.round"):
            new_state, record = self._round_body(state, batch)
        self.hooks.on_round_end(record["step"], record)
        return new_state, record

    def _round_body(self, state: TrainState, batch
                    ) -> Tuple[TrainState, Dict[str, Any]]:
        step_arr = jnp.asarray(state.step)
        n = self.n_workers
        d = self._grad_dim(state.values)
        codec, channel, echo_r = self._policy_decide(state.step, d)
        # A routed channel (repro.net.relay) multiplies every message by
        # its copy count; scaling the baseline too keeps the echo-vs-raw
        # saving a property of the protocol, not the medium.
        price = channel.price_factor()
        raw_round = raw_round_bits(codec, n, d) * price
        record: Dict[str, Any] = {"step": state.step,
                                  "strategy": self.bundle.name}
        echoed = False
        attempted, drops = False, 0
        new_ef = state.ef

        if self.bundle.needs_basis:
            K = self.settings.echo_k
            echo_round = n * int(codec.echo_msg_bits(n, K))
            # A metered channel can refuse the optimistic attempt when a
            # whole echo round would blow the per-round budget.
            attempted = channel.allows_bits(echo_round)
            # A faded echo slot cannot be verified: its sender retransmits
            # raw, so the coefficient-space aggregate (which needs every
            # echo delivered) is invalid and the round falls back. The
            # draw depends only on (seed, step, n) — the bits trajectory
            # replays exactly — so it happens BEFORE the optimistic step:
            # a round the channel already doomed skips straight to the
            # fallback instead of paying for two full train steps.
            drops = channel.round_echo_drops(state.step, n) if attempted \
                else 0
            all_echo = False
            if attempted and drops == 0:
                opt_fn, extras = self._step_and_extras(state, codec, echo_r)
                with obs.span("optimistic"):
                    v, o, m, agg = opt_fn(state.values,
                                          state.opt_state,
                                          batch, step_arr,
                                          extras)
                    all_echo = bool(m["all_echo"])
            echoed = attempted and all_echo and drops == 0
            if echoed:
                rolled = self.config.roll_policy == "always"
                basis = roll_basis(state.basis, agg) if rolled \
                    else state.basis
                # error-feedback residuals commit only on rounds whose
                # transmission was used; a discarded attempt keeps state
                if self.settings.ef and "ef_state" in m:
                    new_ef = m["ef_state"]
            else:
                # optimistic round invalid (Eq. 7 failed, echo slots
                # faded, or never attempted): fall back to the exact CGC
                # step and roll the basis with the raw aggregate.
                with obs.span("fallback"):
                    v, o, m, agg = self.fallback_fn(
                        state.values, state.opt_state, batch, step_arr)
                    basis = roll_basis(state.basis, agg)
                rolled = True
            bits = round_comm_bits(codec, n, d, K, all_echo and drops == 0,
                                   attempted) * price
            record.update(all_echo=echoed, basis_rolled=rolled)
            if drops:
                record["echo_drops"] = drops
            if not attempted:
                record["comm_refused"] = True
            new_state = TrainState(v, o, state.step + 1, basis, new_ef)
        else:
            with obs.span("step"):
                out = self.step_fn(state.values, state.opt_state, batch,
                                   step_arr)
                v, o, m = out[0], out[1], out[2]
            bits = raw_round
            new_state = TrainState(v, o, state.step + 1, None)

        loss = float(m["loss"])
        if self._first_loss is None:
            self._first_loss = loss
        self._last_loss = loss
        led = self.ledger.record_round(bits=bits, baseline=raw_round,
                                       echoed=echoed)
        record.update(loss=loss, **led)
        for k in ("echo_frac", "grad_global_norm", "cgc_threshold",
                  "cgc_clipped_frac", "ef_residual_norm"):
            if k in m:
                record[k] = float(m[k])
        if self._policy_dynamic:
            record["codec"] = codec.name
            record["echo_r"] = echo_r
        if self.policy is not None:
            self._observe_round(state, codec, echo_r, bits, raw_round,
                                loss, echoed, attempted, drops, led)
        self.sink.emit(record)
        return new_state, record

    def _profiler_window(self, steps_done: int):
        """Start/stop the jax.profiler trace around the first
        ``profile_steps`` rounds of this fit(). Never fatal: profiler
        problems (already tracing, missing backend support) become obs
        events and the run continues unprofiled."""
        cfg = self.config
        if not cfg.profile_steps or not cfg.profile_dir:
            return None, 0
        try:
            jax.profiler.start_trace(cfg.profile_dir)
            obs.event("train.profile_start", dir=cfg.profile_dir,
                      steps=cfg.profile_steps)
            return True, steps_done + cfg.profile_steps
        except Exception as e:
            obs.event("train.profile_error", error=repr(e))
            return None, 0

    def _profiler_stop(self) -> None:
        try:
            jax.profiler.stop_trace()
            obs.event("train.profile_stop", dir=self.config.profile_dir)
        except Exception as e:
            obs.event("train.profile_error", error=repr(e))

    def fit(self, state: TrainState, batches: Iterator, steps: int
            ) -> Tuple[TrainState, Dict[str, Any]]:
        """Run rounds until ``state.step`` reaches ``steps`` (absolute —
        a resumed state continues from its checkpointed step)."""
        cfg = self.config
        t0 = time.time()
        profiling, profile_until = self._profiler_window(state.step)
        try:
            while state.step < steps:
                with obs.span("train.data"):
                    batch = next(batches)
                state, _ = self.run_round(state, batch)
                if profiling and state.step >= profile_until:
                    self._profiler_stop()
                    profiling = None
                if cfg.ckpt_dir and cfg.ckpt_every \
                        and state.step % cfg.ckpt_every == 0 \
                        and state.step < steps:
                    with obs.span("train.checkpoint"):
                        self.save(state, wait=False)  # off the driver
        finally:
            if profiling:        # steps < profile window (or a crash)
                self._profiler_stop()
        if cfg.ckpt_dir:
            # the final snapshot is synchronous: fit() returning means it
            # is durable even if the caller never close()s (the periodic
            # saves above are the ones that must stay off the hot loop).
            with obs.span("train.checkpoint"):
                self.save(state)
        summary = self.summary()
        summary["wall_s"] = round(time.time() - t0, 2)
        return state, summary

    def close(self) -> None:
        """Release the metrics sink and the background checkpoint writer
        (call when done with the Trainer — fit() can be called again to
        continue, so it never closes)."""
        self.sink.close()
        if self._ckpt_writer is not None:
            self._ckpt_writer.close()
            self._ckpt_writer = None

    def summary(self) -> Dict[str, Any]:
        led = self.ledger.summary()
        s: Dict[str, Any] = {
            "strategy": self.bundle.name,
            "rounds": led["rounds"],
            "workers": self.n_workers,
            "bits_sent": led["bits_sent"],
            "bits_baseline": led["bits_baseline"],
            "first_loss": self._first_loss,
            "final_loss": self._last_loss,
        }
        if self.bundle.needs_basis and led["rounds"]:
            s["echo_rounds"] = led["echo_rounds"]
            s["echo_rate"] = led["echo_rate"]
            s["bits_saving"] = led["bits_saving"]
        if self.policy is not None:
            s["policy"] = self.policy.name
            s["codec_switches"] = self.codec_switches
            if self._policy_dynamic:
                s["codec_final"] = self._cur_codec_name
                s["echo_r_final"] = self._cur_r
        return s
