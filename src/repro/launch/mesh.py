"""Production meshes.

Single pod: 256 chips as (data=16, model=16). Multi-pod: 2 pods = 512 chips
as (pod=2, data=16, model=16) — the leading axis is the pod-level
data-parallel (and Byzantine-worker) axis.

``make_production_mesh`` is a FUNCTION so importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before any jax init;
tests and benches see the single real CPU device).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1) -> jax.sharding.Mesh:
    """Tiny mesh over whatever devices exist (CPU tests)."""
    n = len(jax.devices())
    data = max(n // model, 1)
    return jax.make_mesh((data, model), ("data", "model"))


# TPU v5e hardware constants used by the roofline analysis (per chip).
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_BW = 50e9                   # bytes/s per link
