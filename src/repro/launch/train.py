"""Distributed trainer CLI — a deprecation shim over ``repro.run``.

The step builders that used to live here are strategies in
``launch/engine.py`` (back-compat ``make_*_train_step`` wrappers below);
the script entry point

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --smoke \
        --strategy echo_dp

is now a flags->RunConfig adapter over the declarative job API: it emits
one DeprecationWarning, builds the equivalent :class:`repro.run.
RunConfig` and calls ``repro.run.train`` — the same facade
``python -m repro train --config job.json`` runs, so legacy flag
invocations and config-driven runs execute the same jitted step bit for
bit (DESIGN.md §8). On CPU-only hosts ``--devices`` forces fake host
devices (default 8) before jax initialises, so the worker axes exist;
pass ``--devices 0`` on real accelerators.
"""
from __future__ import annotations

import jax

from repro.launch.engine import (EchoDpStrategy, FsdpStrategy,  # noqa: F401
                                 MetricsSink, ReplicatedStrategy, StepBundle,
                                 STRATEGIES, Trainer, TrainerConfig,
                                 TrainSettings, TrainState, batch_shardings,
                                 opt_state_shardings, param_shardings)


# ---------------------------------------------------------------------------
# Back-compat step builders (thin shims over the engine strategies)
# ---------------------------------------------------------------------------


def make_train_step(cfg, opt, settings: TrainSettings, mesh,
                    global_batch: int):
    """Replicated CGC train step: (step_fn, ctx). See ReplicatedStrategy."""
    b = ReplicatedStrategy().build(cfg, opt, settings, mesh, global_batch)
    if mesh is None or not b.ctx.batch_axes:
        return jax.jit(b.fn), b.ctx
    return b.fn, b.ctx


def make_fsdp_train_step(cfg, opt, settings: TrainSettings, mesh,
                         global_batch: int):
    """FSDP train step: (step_fn, ctx, (value_shardings, plan)).
    See FsdpStrategy."""
    b = FsdpStrategy().build(cfg, opt, settings, mesh, global_batch)
    return b.fn, b.ctx, (b.value_shardings, b.plan)


def make_echo_train_step(cfg, opt, settings: TrainSettings, mesh,
                         global_batch: int):
    """Echo-compressed DP train step: (step_fn, ctx). See EchoDpStrategy."""
    b = EchoDpStrategy().build(cfg, opt, settings, mesh, global_batch)
    return b.fn, b.ctx


# ---------------------------------------------------------------------------
# Script entry: legacy flags -> RunConfig adapter over repro.run.train
# ---------------------------------------------------------------------------


def _force_host_devices(n: int) -> None:
    """Force n fake host devices — must run before jax backend init."""
    from repro.run.facade import force_host_devices
    force_host_devices(n)


def config_from_flags(args) -> "run.RunConfig":
    """The flags->RunConfig adapter: one legacy argparse namespace maps
    to exactly the job tree the unified CLI would load, so both paths
    run the same jitted step bit for bit."""
    from repro import run
    return run.RunConfig(
        name=f"{args.arch}-{args.strategy}",
        model=run.ModelSpec(arch=args.arch, smoke=args.smoke),
        mesh=run.MeshSpec(devices=args.devices),
        scenario=run.ScenarioSpec(
            aggregator=args.aggregator, attack=args.byz_mode, f=args.f,
            n_byz=args.n_byz, echo_k=args.echo_k, echo_r=args.echo_r),
        train=run.TrainSpec(
            strategy=args.strategy, steps=args.steps, batch=args.batch,
            seq=args.seq, lr=args.lr, microbatches=args.microbatches,
            clip_norm=args.clip_norm, log_every=args.log_every,
            ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
            resume=args.resume, metrics_path=args.metrics))


def main(argv=None):
    import argparse

    from repro.run import facade

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-friendly)")
    ap.add_argument("--strategy", default="replicated",
                    choices=sorted(STRATEGIES))
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--aggregator", default="cgc")
    ap.add_argument("--f", type=int, default=0)
    ap.add_argument("--n-byz", type=int, default=0)
    ap.add_argument("--byz-mode", default="sign_flip")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--clip-norm", type=float, default=0.0)
    ap.add_argument("--echo-k", type=int, default=4)
    ap.add_argument("--echo-r", type=float, default=0.9)
    ap.add_argument("--devices", type=int, default=8,
                    help="force this many fake host devices (0: use the "
                         "real devices — pass 0 on accelerators)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--metrics", default=None,
                    help="jsonl metrics sink path")
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args(argv)

    facade.warn_legacy("repro.launch.train", "python -m repro train")
    try:
        result = facade.train(config_from_flags(args))
    except ValueError as e:
        raise SystemExit(str(e)) from None
    facade.print_train_summary(result)
    return result.summary


if __name__ == "__main__":
    main()
