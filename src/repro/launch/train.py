"""Distributed trainer CLI — a thin shell over ``repro.launch.engine``.

The step builders that used to live here (three copies of the same
shard_map/batch-spec/microbatch plumbing) are now strategies in
``launch/engine.py``; this module keeps back-compat ``make_*_train_step``
wrappers and the script entry point:

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --smoke \
        --strategy echo_dp

runs the real driver loop (engine.Trainer): echo-DP optimistic rounds
with ``all_echo`` fallback to the exact CGC step, periodic checkpoints of
(values, opt_state, step, basis) with ``--resume``, a jsonl metrics sink,
and per-round bit accounting against the all-raw baseline. ``--strategy
replicated|fsdp`` run through the same Trainer. On CPU-only hosts the
CLI forces ``--devices`` fake host devices (default 8) before jax
initialises, so the worker axes exist; pass ``--devices 0`` on real
accelerators.
"""
from __future__ import annotations

import os

import jax

from repro.launch.engine import (EchoDpStrategy, FsdpStrategy,  # noqa: F401
                                 MetricsSink, ReplicatedStrategy, StepBundle,
                                 STRATEGIES, Trainer, TrainerConfig,
                                 TrainSettings, TrainState, batch_shardings,
                                 opt_state_shardings, param_shardings)


# ---------------------------------------------------------------------------
# Back-compat step builders (thin shims over the engine strategies)
# ---------------------------------------------------------------------------


def make_train_step(cfg, opt, settings: TrainSettings, mesh,
                    global_batch: int):
    """Replicated CGC train step: (step_fn, ctx). See ReplicatedStrategy."""
    b = ReplicatedStrategy().build(cfg, opt, settings, mesh, global_batch)
    if mesh is None or not b.ctx.batch_axes:
        return jax.jit(b.fn), b.ctx
    return b.fn, b.ctx


def make_fsdp_train_step(cfg, opt, settings: TrainSettings, mesh,
                         global_batch: int):
    """FSDP train step: (step_fn, ctx, (value_shardings, plan)).
    See FsdpStrategy."""
    b = FsdpStrategy().build(cfg, opt, settings, mesh, global_batch)
    return b.fn, b.ctx, (b.value_shardings, b.plan)


def make_echo_train_step(cfg, opt, settings: TrainSettings, mesh,
                         global_batch: int):
    """Echo-compressed DP train step: (step_fn, ctx). See EchoDpStrategy."""
    b = EchoDpStrategy().build(cfg, opt, settings, mesh, global_batch)
    return b.fn, b.ctx


# ---------------------------------------------------------------------------
# Script entry: real driver loop on (possibly forced) host devices
# ---------------------------------------------------------------------------


def _force_host_devices(n: int) -> None:
    """Force n fake host devices — must run before jax backend init."""
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" in flags:
        return
    os.environ["XLA_FLAGS"] = (
        flags + f" --xla_force_host_platform_device_count={n}").strip()


def main(argv=None):
    import argparse
    import contextlib

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-friendly)")
    ap.add_argument("--strategy", default="replicated",
                    choices=sorted(STRATEGIES))
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--aggregator", default="cgc")
    ap.add_argument("--f", type=int, default=0)
    ap.add_argument("--n-byz", type=int, default=0)
    ap.add_argument("--byz-mode", default="sign_flip")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--clip-norm", type=float, default=0.0)
    ap.add_argument("--echo-k", type=int, default=4)
    ap.add_argument("--echo-r", type=float, default=0.9)
    ap.add_argument("--devices", type=int, default=8,
                    help="force this many fake host devices (0: use the "
                         "real devices — pass 0 on accelerators)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--metrics", default=None,
                    help="jsonl metrics sink path")
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args(argv)

    if args.devices:
        _force_host_devices(args.devices)

    from repro.configs import get_config, reduced
    from repro.data import make_batch_iterator
    from repro.launch.mesh import make_host_mesh
    from repro.models import model as M
    from repro.models.nn import split_params
    from repro.optim import adamw

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    settings = TrainSettings(
        aggregator=args.aggregator, f=args.f, n_byz=args.n_byz,
        byz_mode=args.byz_mode, microbatches=args.microbatches,
        clip_norm=args.clip_norm, echo_k=args.echo_k, echo_r=args.echo_r,
        fsdp=args.strategy == "fsdp")
    opt = adamw(args.lr)

    # Every host device is a data-parallel worker when possible; the
    # robust-aggregation flags are no-ops without a worker axis.
    n_dev = len(jax.devices())
    mesh = (make_host_mesh() if n_dev > 1 and args.batch % n_dev == 0
            else None)
    if mesh is None and args.strategy in ("fsdp", "echo_dp"):
        raise SystemExit(
            f"--strategy {args.strategy} needs >1 data-parallel workers: "
            f"use --devices N (and a --batch divisible by N), or "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=N")
    if args.n_byz and mesh is None:
        raise SystemExit(
            "--n-byz needs >1 data-parallel workers: run with --devices N "
            "and a --batch divisible by N")
    if mesh is None and (args.f or args.aggregator != "mean"):
        print("warning: single worker — no aggregation runs, so "
              "--aggregator/--f are inactive (use --devices N to "
              "exercise them)")

    trainer = Trainer(args.strategy, cfg, opt, settings, mesh, args.batch,
                      TrainerConfig(log_every=args.log_every,
                                    ckpt_dir=args.ckpt_dir,
                                    ckpt_every=args.ckpt_every,
                                    resume=args.resume,
                                    metrics_path=args.metrics))
    print(f"strategy={args.strategy} workers={trainer.n_workers} "
          f"aggregator={args.aggregator} f={args.f}")

    params = M.init_params(cfg, jax.random.PRNGKey(0))
    values, _ = split_params(params)
    state = trainer.init_state(values)
    if state.step:
        print(f"resumed from step {state.step}")

    # start=state.step: a resumed run continues the data stream instead
    # of re-consuming the batches the checkpointed run already saw.
    it = make_batch_iterator(cfg, args.batch, args.seq, start=state.step)
    mesh_ctx = jax.set_mesh(mesh) if mesh is not None \
        else contextlib.nullcontext()
    with mesh_ctx:
        state, summary = trainer.fit(state, it, args.steps)
    trainer.close()

    if not summary["rounds"]:
        print(f"nothing to do: resumed at step {state.step} >= "
              f"--steps {args.steps}")
        return summary
    print(f"final loss {summary['final_loss']:.4f} "
          f"(from {summary['first_loss']:.4f}) in {summary['wall_s']}s")
    if "echo_rate" in summary:
        print(f"echo rounds {summary['echo_rounds']}/{summary['rounds']} "
              f"({100.0 * summary['echo_rate']:.1f}%); cumulative bits "
              f"{summary['bits_sent']:.3e} vs all-raw baseline "
              f"{summary['bits_baseline']:.3e} "
              f"({100.0 * summary['bits_saving']:.1f}% saved)")
    if args.ckpt_dir:
        print("checkpoint saved to", args.ckpt_dir)
    return summary


if __name__ == "__main__":
    main()
