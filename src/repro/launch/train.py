"""Distributed trainer: per-worker gradients + Byzantine-tolerant aggregation.

The train step is a shard_map whose MANUAL axes are the data axes (each data
shard = one Echo-CGC "worker") and whose model axis stays AUTOMATIC (tensor
parallelism inside each worker is handled by pjit sharding propagation).
Inside the shard_map each worker:

    local grads (microbatched)  ->  optional Byzantine injection
    -> CGC aggregation (norm all-gather + clipped psum, DESIGN.md §3.2)
    -> identical optimizer update on every worker (params stay replicated
       over the data axes, sharded over model).

Run as a script for a real (CPU-scale) training session:
    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --smoke ...
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.dist import (AGG_FNS, ShardCtx, inject_byzantine, make_shard_ctx,
                        tree_shardings, tree_specs)
from repro.models import model as M
from repro.models.nn import Param, split_params
from repro.optim import Optimizer, adamw, clip_by_global_norm, sgd

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class TrainSettings:
    aggregator: str = "cgc"        # mean | cgc | trimmed_mean
    f: int = 0                     # CGC clip count (max Byzantine workers)
    n_byz: int = 0                 # simulated Byzantine workers (testing)
    byz_mode: str = "sign_flip"
    microbatches: int = 1
    clip_norm: float = 0.0         # 0 = off
    moe_impl: str = "tp"
    return_aggregate: bool = False  # emit the aggregated grads (echo basis)
    echo_k: int = 4                # echo-DP: reference basis size
    echo_r: float = 0.5            # echo-DP: deviation ratio (Eq. 7)
    fsdp: bool = False             # shard params+opt over the data axes
                                   # (blockwise CGC in the gather VJP)
    remat: str = "full"            # "full" | "save_psum" (§Perf HC2)


def _microbatched_grads(loss_fn, values, batch, n_micro: int):
    """Gradient accumulation over n_micro slices of the local batch."""
    if n_micro <= 1:
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(values, batch)
        return loss, metrics, grads

    def slice_batch(b, i):
        def cut(x):
            mb = x.shape[0] // n_micro if x.ndim >= 1 else None
            return jax.lax.dynamic_slice_in_dim(x, i * mb, mb, 0)
        # mrope_positions has batch at dim 1
        out = {}
        for k_, x in b.items():
            if k_ == "mrope_positions":
                mb = x.shape[1] // n_micro
                out[k_] = jax.lax.dynamic_slice_in_dim(x, i * mb, mb, 1)
            else:
                out[k_] = cut(x)
        return out

    def body(carry, i):
        g_acc, l_acc, m_acc = carry
        (loss, metrics), g = jax.value_and_grad(loss_fn, has_aux=True)(
            values, slice_batch(batch, i))
        g_acc = jax.tree.map(jnp.add, g_acc, g)
        m_acc = jax.tree.map(jnp.add, m_acc, metrics)
        return (g_acc, l_acc + loss, m_acc), None

    zeros_g = jax.tree.map(lambda v: jnp.zeros(v.shape, F32), values)
    zero_m = {"ce_loss": jnp.zeros((), F32), "moe_aux": jnp.zeros((), F32),
              "moe_dropped": jnp.zeros((), F32)}
    (g, loss, metrics), _ = jax.lax.scan(
        body, (zeros_g, jnp.zeros((), F32), zero_m),
        jnp.arange(n_micro))
    inv = 1.0 / n_micro
    return (loss * inv,
            jax.tree.map(lambda m: m * inv, metrics),
            jax.tree.map(lambda x: (x * inv), g))


def make_train_step(cfg: ModelConfig, opt: Optimizer,
                    settings: TrainSettings, mesh, global_batch: int
                    ) -> Tuple[Callable, ShardCtx]:
    """Build the jittable (values, opt_state, batch, step) -> ... step."""
    if settings.aggregator not in AGG_FNS:
        raise ValueError(f"unknown aggregator {settings.aggregator!r}; "
                         f"known: {sorted(AGG_FNS)}")
    ctx = make_shard_ctx(mesh, global_batch, settings.moe_impl)
    data_axes = ctx.batch_axes

    if settings.moe_impl == "ep" and mesh is not None:
        # expert parallelism runs a NESTED shard_map over the model axis
        # (disjoint from the worker's manual data axes): batch is already
        # local, so batch_axes=() inside.
        from repro.dist.compat import partial_manual_supported
        if data_axes and not partial_manual_supported():
            raise ValueError(
                "moe_impl='ep' inside the worker shard_map needs "
                "partial-manual shard_map (jax >= 0.6); this jax only "
                "supports EP at the pjit level (serve/prefill) — use "
                "moe_impl='tp' for training")
        inner_ctx = ShardCtx(mesh=mesh, batch_axes=(), model_axis="model",
                             moe_impl="ep", remat=settings.remat)
    else:
        inner_ctx = (ShardCtx(remat=settings.remat)
                     if settings.remat != "full" else None)

    def loss_fn(values, batch):
        # inside the worker shard_map the batch is already local ->
        # the MoE layer dispatches locally (model axis auto) unless EP.
        return M.train_loss(values, cfg, batch, shard_ctx=inner_ctx)

    def worker_fn(values, opt_state, batch, step):
        loss, metrics, grads = _microbatched_grads(
            loss_fn, values, batch, settings.microbatches)
        if settings.n_byz and data_axes:
            from repro.dist.collectives import worker_index
            wid = worker_index(data_axes)
            grads = inject_byzantine(grads, wid, settings.n_byz,
                                     settings.byz_mode)
        if data_axes:
            agg_fn = AGG_FNS[settings.aggregator]
            grads, diags = agg_fn(grads, data_axes, settings.f)
            loss = jax.lax.pmean(loss, data_axes)
            metrics = jax.tree.map(lambda m: jax.lax.pmean(m, data_axes),
                                   metrics)
        else:
            diags = {}
        if settings.clip_norm:
            grads, gnorm = clip_by_global_norm(grads, settings.clip_norm)
            diags = dict(diags, grad_global_norm=gnorm)
        updates, opt_state = opt.update(grads, opt_state, values, step)
        values = jax.tree.map(lambda p, u: p + u.astype(p.dtype), values,
                              updates)
        metrics = dict(metrics, loss=loss, **diags)
        if settings.return_aggregate:
            return values, opt_state, metrics, grads
        return values, opt_state, metrics

    if mesh is None or not data_axes:
        return jax.jit(worker_fn), ctx

    bspec = data_axes if len(data_axes) > 1 else data_axes[0]

    def batch_spec(name: str):
        return P(None, bspec) if name == "mrope_positions" else P(bspec)

    def wrapped(values, opt_state, batch, step):
        in_specs = (
            jax.tree.map(lambda _: P(), values),
            jax.tree.map(lambda _: P(), opt_state),
            {k_: batch_spec(k_) for k_ in batch},
            P(),
        )
        out_specs = (
            jax.tree.map(lambda _: P(), values),
            jax.tree.map(lambda _: P(), opt_state),
            P(),
        ) + ((jax.tree.map(lambda _: P(), values),)
             if settings.return_aggregate else ())
        fn = jax.shard_map(worker_fn, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, axis_names=set(data_axes),
                           check_vma=False)
        return fn(values, opt_state, batch, step)

    return wrapped, ctx


def make_fsdp_train_step(cfg: ModelConfig, opt: Optimizer,
                         settings: TrainSettings, mesh, global_batch: int):
    """FSDP trainer (§Perf HC1): params + optimizer state sharded over the
    data axes, per-layer just-in-time gathers, blockwise CGC on the
    reduce-scatter (dist/fsdp.py).

    Returns (step_fn, ctx, shardings) where ``shardings`` carries the
    NamedShardings for (values, opt_state) — the driver/dry-run must place
    operands with these (params are LOGICALLY global; FSDP is purely a
    placement + shard_map-spec concern).
    """
    import dataclasses as _dc

    from repro.dist.fsdp import (aggregate_rest_cgc, clip_fsdp_global_norm,
                                 fsdp_manual_specs, fsdp_tree_shardings,
                                 make_gather_fn, plan_fsdp)
    from repro.launch.specs import abstract_params

    if settings.aggregator not in ("cgc", "mean"):
        raise ValueError(
            f"FSDP trainer supports aggregator 'cgc' or 'mean' (the "
            f"reduction happens inside the gather VJP), got "
            f"{settings.aggregator!r}")
    ctx = make_shard_ctx(mesh, global_batch, settings.moe_impl)
    data_axes = ctx.batch_axes
    if not data_axes:
        raise ValueError("FSDP needs a data-parallel axis")
    if settings.n_byz:
        raise ValueError("Byzantine injection is incompatible with FSDP "
                         "(per-worker grads never materialise whole); use "
                         "the replicated trainer to exercise attacks")

    params_abs = abstract_params(cfg)
    plan = plan_fsdp(params_abs, mesh, dp_axes=data_axes)
    # layers subtree gathers inside the scan; everything else up-front.
    plan_top = dict(plan)
    layer_plan = plan_top.pop("layers", None)
    top_plan_full = dict(plan_top)
    if layer_plan is not None:
        top_plan_full["layers"] = jax.tree.map(lambda _: None, layer_plan,
                                               is_leaf=lambda x: x is None)

    use_cgc = settings.aggregator == "cgc"
    gather_top = make_gather_fn(top_plan_full, data_axes, settings.f,
                                use_cgc)
    layer_gf = (make_gather_fn(layer_plan, data_axes, settings.f, use_cgc,
                               strip_layer_dim=True)
                if layer_plan is not None else None)
    inner_ctx = _dc.replace(ShardCtx(), layer_gather=layer_gf,
                            remat=settings.remat)

    def loss_fn(values, batch):
        vg = gather_top(values)
        return M.train_loss(vg, cfg, batch, shard_ctx=inner_ctx)

    def worker_fn(values, opt_state, batch, step):
        loss, metrics, grads = _microbatched_grads(
            loss_fn, values, batch, settings.microbatches)
        # fsdp leaves: already blockwise-clipped + reduce-scattered in the
        # gather VJP; the replicated remainder gets the exact matching psum.
        grads = aggregate_rest_cgc(grads, plan, data_axes, settings.f,
                                   use_cgc=use_cgc)
        loss = jax.lax.pmean(loss, data_axes)
        metrics = jax.tree.map(lambda m: jax.lax.pmean(m, data_axes),
                               metrics)
        if settings.clip_norm:
            # layout-aware: planned leaves are shards, rest is replicated
            grads, gnorm = clip_fsdp_global_norm(grads, plan, data_axes,
                                                 settings.clip_norm)
            metrics = dict(metrics, grad_global_norm=gnorm)
        updates, opt_state = opt.update(grads, opt_state, values, step)
        values = jax.tree.map(lambda p, u: p + u.astype(p.dtype), values,
                              updates)
        return values, opt_state, dict(metrics, loss=loss)

    vspecs = fsdp_manual_specs(params_abs, plan, data_axes)
    vspecs_plain, _ = split_params(jax.tree.map(
        lambda p, s: Param(s, p.axes), params_abs, vspecs,
        is_leaf=lambda x: isinstance(x, Param)))
    bspec = data_axes if len(data_axes) > 1 else data_axes[0]

    def batch_spec(name: str):
        return P(None, bspec) if name == "mrope_positions" else P(bspec)

    def ospec_like(opt_state):
        # mirror param specs onto mirroring optimizer-state subtrees
        leaves, treedef = jax.tree.flatten(opt_state)
        vleaves = jax.tree.leaves(vspecs_plain)
        if len(leaves) % max(len(vleaves), 1) == 0 and vleaves:
            reps = len(leaves) // len(vleaves)
            return jax.tree.unflatten(treedef, vleaves * reps)
        return jax.tree.map(lambda _: P(), opt_state)

    def wrapped(values, opt_state, batch, step):
        in_specs = (vspecs_plain, ospec_like(opt_state),
                    {k_: batch_spec(k_) for k_ in batch}, P())
        out_specs = (vspecs_plain, ospec_like(opt_state), P())
        fn = jax.shard_map(worker_fn, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, axis_names=set(data_axes),
                           check_vma=False)
        return fn(values, opt_state, batch, step)

    vshard = fsdp_tree_shardings(params_abs, mesh, plan, dp_axes=data_axes)
    return wrapped, ctx, (vshard, plan)


def make_echo_train_step(cfg: ModelConfig, opt: Optimizer,
                         settings: TrainSettings, mesh, global_batch: int
                         ) -> Tuple[Callable, ShardCtx]:
    """Echo-compressed DP train step (dist/echo_dp.py — §Perf HC3).

    step(values, opt_state, batch, step, basis) ->
        (values, opt_state, metrics, aggregate)
    where ``basis`` is a list of echo_k reference pytrees (the previous
    aggregates, replicated on every worker) and metrics["all_echo"] reports
    whether the fast path was valid — the driver re-runs the round with the
    standard CGC step when it is not, and rolls ``basis`` with the returned
    aggregate (repro.dist.echo_dp.roll_basis).
    """
    from repro.dist.echo_dp import basis_gram, echo_dp_aggregate

    ctx = make_shard_ctx(mesh, global_batch, settings.moe_impl)
    data_axes = ctx.batch_axes
    if not data_axes:
        raise ValueError("echo-DP aggregation needs a data-parallel axis")

    def loss_fn(values, batch):
        return M.train_loss(values, cfg, batch, shard_ctx=None)

    def worker_fn(values, opt_state, batch, step, *basis):
        basis = list(basis)
        loss, metrics, grads = _microbatched_grads(
            loss_fn, values, batch, settings.microbatches)
        if settings.n_byz:
            from repro.dist.collectives import worker_index
            wid = worker_index(data_axes)
            grads = inject_byzantine(grads, wid, settings.n_byz,
                                     settings.byz_mode)
        gram = basis_gram(basis)
        agg, all_echo, diags = echo_dp_aggregate(
            grads, basis, gram, data_axes, settings.f, settings.echo_r)
        loss = jax.lax.pmean(loss, data_axes)
        metrics = jax.tree.map(lambda m: jax.lax.pmean(m, data_axes),
                               metrics)
        if settings.clip_norm:
            agg, gnorm = clip_by_global_norm(agg, settings.clip_norm)
            diags = dict(diags, grad_global_norm=gnorm)
        updates, opt_state = opt.update(agg, opt_state, values, step)
        values = jax.tree.map(lambda p, u: p + u.astype(p.dtype), values,
                              updates)
        metrics = dict(metrics, loss=loss, all_echo=all_echo, **diags)
        return values, opt_state, metrics, agg

    bspec = data_axes if len(data_axes) > 1 else data_axes[0]

    def batch_spec(name: str):
        return P(None, bspec) if name == "mrope_positions" else P(bspec)

    def wrapped(values, opt_state, batch, step, basis):
        rep = lambda t: jax.tree.map(lambda _: P(), t)
        in_specs = (rep(values), rep(opt_state),
                    {k_: batch_spec(k_) for k_ in batch}, P(),
                    *[rep(b) for b in basis])
        out_specs = (rep(values), rep(opt_state), P(), rep(values))
        fn = jax.shard_map(worker_fn, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, axis_names=set(data_axes),
                           check_vma=False)
        return fn(values, opt_state, batch, step, *basis)

    return wrapped, ctx


# ---------------------------------------------------------------------------
# Shardings for the step operands
# ---------------------------------------------------------------------------


def param_shardings(params_tree, mesh, rules=None):
    return tree_shardings(params_tree, mesh, rules)


def opt_state_shardings(opt_state_abs, params_tree, mesh, rules=None,
                        override=None):
    """Mirror parameter shardings onto the optimizer state by path suffix.

    ``override``: a plain sharding tree (e.g. FSDP shardings) to mirror
    instead of the default rule-derived one.
    """
    from repro.checkpoint.ckpt import _flatten_with_paths
    pshard = override if override is not None else tree_shardings(
        params_tree, mesh, rules)
    flat_p = _flatten_with_paths(pshard)

    def lookup(path_key: str, leaf):
        for k_, sh in flat_p.items():
            if path_key.endswith(k_):
                return sh
        return NamedSharding(mesh, P())

    flat_paths = jax.tree_util.tree_flatten_with_path(opt_state_abs)[0]
    leaves = []
    for path, leaf in flat_paths:
        from repro.checkpoint.ckpt import _path_str
        key = "/".join(_path_str(p) for p in path)
        leaves.append(lookup(key, leaf))
    treedef = jax.tree_util.tree_structure(opt_state_abs)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def batch_shardings(batch_specs, mesh, rules=None):
    return tree_shardings(batch_specs, mesh, rules)


# ---------------------------------------------------------------------------
# Script entry: small real training run on host devices
# ---------------------------------------------------------------------------


def main(argv=None):
    import argparse

    from repro.configs import get_config, reduced
    from repro.data import make_batch_iterator
    from repro import checkpoint as ckpt_lib

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--aggregator", default="cgc")
    ap.add_argument("--f", type=int, default=0)
    ap.add_argument("--n-byz", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    settings = TrainSettings(aggregator=args.aggregator, f=args.f,
                             n_byz=args.n_byz)
    opt = adamw(args.lr)

    # Use every host device as a data-parallel worker when possible; the
    # robust-aggregation flags are no-ops without a worker axis.
    from repro.launch.mesh import make_host_mesh
    n_dev = len(jax.devices())
    mesh = (make_host_mesh() if n_dev > 1 and args.batch % n_dev == 0
            else None)
    if args.n_byz and mesh is None:
        raise SystemExit(
            "--n-byz needs >1 data-parallel workers: run with "
            "XLA_FLAGS=--xla_force_host_platform_device_count=N and a "
            "--batch divisible by N")
    if mesh is None and (args.f or args.aggregator != "mean"):
        print("warning: single worker — no aggregation runs, so "
              "--aggregator/--f are inactive (force multiple host devices "
              "via XLA_FLAGS to exercise them)")

    params = M.init_params(cfg, jax.random.PRNGKey(0))
    values, _ = split_params(params)
    opt_state = opt.init(values)
    step_fn, ctx = make_train_step(cfg, opt, settings, mesh=mesh,
                                   global_batch=args.batch)
    if mesh is not None:
        step_fn = jax.jit(step_fn)

    it = make_batch_iterator(cfg, args.batch, args.seq)
    import contextlib
    mesh_ctx = jax.set_mesh(mesh) if mesh is not None \
        else contextlib.nullcontext()
    with mesh_ctx:
        for step in range(args.steps):
            batch = next(it)
            values, opt_state, metrics = step_fn(values, opt_state, batch,
                                                 jnp.asarray(step))
            if step % 5 == 0 or step == args.steps - 1:
                print(f"step {step:4d} loss={float(metrics['loss']):.4f}")
    if args.ckpt_dir:
        ckpt_lib.save(args.ckpt_dir, args.steps, values)
        print("checkpoint saved to", args.ckpt_dir)


if __name__ == "__main__":
    main()
