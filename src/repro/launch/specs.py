"""ShapeDtypeStruct input stand-ins for every (architecture x input shape).

``input_specs`` returns a Param tree (ShapeDtypeStruct values + logical
axes) — shardable, weak-type-correct, zero allocation. The dry-run lowers
against these; the trainer/server build identical trees with real data.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import model as M
from repro.models.nn import Param

I32 = jnp.int32
F32 = jnp.float32


def _sds(shape, dtype, axes) -> Param:
    return Param(jax.ShapeDtypeStruct(tuple(shape), dtype), tuple(axes))


def long_context_variant(cfg: ModelConfig, shape: ShapeConfig
                         ) -> ModelConfig:
    """long_500k on attention archs uses the explicit sliding-window
    variant (DESIGN.md §4); SSM/hybrid run natively."""
    if shape.name == "long_500k" and cfg.attn_type != "none" \
            and cfg.family not in ("ssm",) and cfg.sliding_window is None:
        return cfg.with_sliding_window(8192)
    return cfg


def batch_for(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Param]:
    """Training/prefill batch spec tree."""
    B, S = shape.global_batch, shape.seq_len
    sp: Dict[str, Param] = {}
    if cfg.frontend == "audio":
        sp["features"] = _sds((B, S, M.FRONTEND_DIM["audio"]), F32,
                              ("batch", None, None))
    else:
        sp["tokens"] = _sds((B, S), I32, ("batch", None))
    if cfg.frontend == "vision":
        nv = min(cfg.num_vision_tokens, S)
        sp["vision_embeds"] = _sds((B, nv, M.FRONTEND_DIM["vision"]), F32,
                                   ("batch", None, None))
        sp["mrope_positions"] = _sds((3, B, S), I32, (None, "batch", None))
    if shape.kind == "train":
        sp["labels"] = _sds((B, S), I32, ("batch", None))
    return sp


def decode_specs(cfg: ModelConfig, shape: ShapeConfig
                 ) -> Tuple[Dict[str, Param], Any]:
    """(token/pos specs, cache spec tree) for a serve step."""
    B, S = shape.global_batch, shape.seq_len
    sp = {
        "token": _sds((B, 1), I32, ("batch", None)),
        "pos": _sds((B,), I32, ("batch",)),
    }
    cache = jax.eval_shape(lambda: M.init_cache(cfg, B, S))
    return sp, cache


def abstract_params(cfg: ModelConfig) -> Any:
    """Param tree of ShapeDtypeStructs (no allocation)."""
    return jax.eval_shape(
        lambda k: M.init_params(cfg, k), jax.random.PRNGKey(0))


def check_applicability(cfg: ModelConfig, shape: ShapeConfig
                        ) -> Optional[str]:
    """None if the pair runs; otherwise the documented skip reason."""
    if shape.kind == "decode" and not cfg.has_decode:
        return "encoder-only: no autoregressive decode step (DESIGN.md §4)"
    return None
