import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x input shape x mesh).

The two lines above MUST run before any other import (jax locks the device
count at first init). This module is the ONLY place that forces 512 host
devices — smoke tests and benchmarks see the real single CPU device.

For each pair this:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. builds abstract params / optimizer state / inputs (ShapeDtypeStruct,
     zero allocation),
  3. jits the right step (train_step / prefill / serve_step) with explicit
     in_shardings, .lower()s and .compile()s it,
  4. records memory_analysis(), cost_analysis() and the per-collective byte
     counts parsed from the compiled HLO -> JSON under experiments/dryrun/.

Usage:
  python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k
  python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k \
      --variant echo_dp            # or fsdp / fsdp_savepsum / all
  python -m repro.launch.dryrun --all [--multi-pod] [--out experiments/dryrun]

Train-step variants build through the engine strategies
(repro.launch.engine.STRATEGIES); ``--variant all`` sweeps
baseline+fsdp+echo_dp so the per-variant collective byte counts land
side by side in the records.
"""
import argparse
import json
import re
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config
from repro.dist import tree_shardings
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (abstract_params, batch_for,
                                check_applicability, decode_specs,
                                long_context_variant)
from repro.launch.engine import (STRATEGIES, TrainSettings,
                                 opt_state_shardings)
from repro.launch.serve import make_prefill, make_serve_step
from repro.models.nn import Param, split_params
from repro.optim import adamw

# one HLO op definition per line: "%name = <result shape(s)> <op>(...)"
COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter",
                  "all-to-all", "collective-permute")
_OP_RE = re.compile(
    r"=\s*(?P<lhs>.*?)\s(?P<op>all-reduce|all-gather|reduce-scatter|"
    r"all-to-all|collective-permute)\(")

DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
               "s64": 8, "u64": 8, "s16": 2, "u16": 2, "pred": 1, "s8": 1,
               "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1}


def _shape_bytes(shape_str: str) -> int:
    """'f32[128,1024]{...}' or '(f32[..], f32[..])' (tuple) -> bytes."""
    total = 0
    for m in re.finditer(r"([a-z0-9]+)\[([0-9,]*)\]", shape_str):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES.get(dt, 4)
    return total


def collective_bytes(hlo_text: str) -> Dict[str, Any]:
    """Sum result-shape bytes per collective kind (per-device module).

    Wire-byte estimates use standard ring-algorithm factors: all-reduce
    moves ~2x its buffer, all-gather/reduce-scatter ~1x the large buffer,
    all-to-all / collective-permute ~1x.
    """
    per_kind: Dict[str, float] = {}
    counts: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        if "=" not in line:
            continue
        if not any(op in line for op in COLLECTIVE_OPS):
            continue
        m = _OP_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        b = _shape_bytes(m.group("lhs"))       # sums all dtype[dims] on LHS
        per_kind[op] = per_kind.get(op, 0) + b
        counts[op] = counts.get(op, 0) + 1
    wire = 0.0
    for op, b in per_kind.items():
        wire += 2.0 * b if op == "all-reduce" else float(b)
    return {"result_bytes": per_kind, "counts": counts, "wire_bytes": wire}


def _tree_bytes(tree) -> int:
    return sum(int(np.prod(l.shape)) * l.dtype.itemsize
               for l in jax.tree.leaves(tree))


def _mem_analysis(compiled) -> Dict[str, Any]:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        ma = None
    if ma is None:
        return {}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def _cost_analysis(compiled) -> Dict[str, float]:
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    keep = {}
    for k, v in dict(ca).items():
        if k in ("flops", "bytes accessed", "transcendentals",
                 "optimal_seconds") or k.startswith("bytes accessed"):
            keep[k] = float(v)
    return keep


def dryrun_pair(arch: str, shape_name: str, multi_pod: bool,
                moe_impl: str = "tp", microbatches: Optional[int] = None,
                compile_: bool = True, variant: str = "baseline",
                param_dtype: Optional[str] = None) -> Dict[str, Any]:
    """Lower+compile one (arch, shape, mesh, variant) -> record.

    Variants (§Perf hillclimbs): "baseline"; "fsdp" (params+opt sharded over
    data, blockwise-CGC reduce); "fsdp_savepsum" (fsdp + save_psum remat
    policy); "echo_dp" (echo-compressed aggregation fast path).
    ``param_dtype`` overrides the config's parameter dtype (e.g. bfloat16).
    """
    import dataclasses as _dc
    shape = INPUT_SHAPES[shape_name]
    cfg = get_config(arch)
    if param_dtype:
        cfg = _dc.replace(cfg, param_dtype=param_dtype)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec: Dict[str, Any] = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_name, "kind": shape.kind,
                           "moe_impl": moe_impl, "variant": variant,
                           "param_dtype": cfg.param_dtype}

    skip = check_applicability(cfg, shape)
    if skip:
        rec["status"] = "skipped"
        rec["reason"] = skip
        return rec

    cfg = long_context_variant(cfg, shape)
    rec["sliding_window"] = cfg.sliding_window
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(list(dict(mesh.shape).values())))
    rec["chips"] = n_chips

    params_abs = abstract_params(cfg)
    values_abs, _ = split_params(params_abs)
    pshard = tree_shardings(params_abs, mesh)
    vshard, _ = split_params(
        jax.tree.map(lambda p, s: Param(s, p.axes), params_abs, pshard,
                     is_leaf=lambda x: isinstance(x, Param)))
    rec["param_count"] = int(sum(np.prod(l.shape)
                                 for l in jax.tree.leaves(values_abs)))
    rec["param_bytes_global"] = _tree_bytes(values_abs)

    t0 = time.time()
    if shape.kind == "train":
        sizes_chk = dict(mesh.shape)
        dp_chk = sizes_chk.get("data", 1) * sizes_chk.get("pod", 1)
        per_worker = shape.global_batch // dp_chk
        if microbatches is not None and per_worker % microbatches:
            raise ValueError(
                f"microbatches={microbatches} must divide per-worker batch "
                f"{per_worker} (zero-sized slices otherwise)")
        if microbatches is None:
            # heuristic: bound per-device tokens per microbatch so the
            # remat-saved layer boundaries (L x tok x d_model x 2B) fit HBM.
            sizes = dict(mesh.shape)
            dp = sizes.get("data", 1) * sizes.get("pod", 1)
            tok_per_dev = shape.global_batch * shape.seq_len // dp
            budget = (8192 if cfg.d_model < 4096
                      else 4096 if cfg.d_model < 8192 else 2048)
            microbatches = max(1, tok_per_dev // budget)
            # batch per worker must stay divisible
            while (shape.global_batch // dp) % microbatches:
                microbatches -= 1
        rec["microbatches"] = microbatches
        opt = adamw(1e-4)
        opt_abs = jax.eval_shape(opt.init, values_abs)
        settings = TrainSettings(
            aggregator="cgc", f=1, microbatches=microbatches,
            moe_impl=moe_impl, fsdp=variant.startswith("fsdp"),
            remat="save_psum" if "savepsum" in variant else "full")
        if variant == "echo_dp":
            settings = _dc.replace(settings, echo_k=4, echo_r=0.9)
        batch_abs_p = batch_for(cfg, shape)
        batch_abs, _ = split_params(batch_abs_p)
        bshard, _ = split_params(jax.tree.map(
            lambda p, s: Param(s, p.axes), batch_abs_p,
            tree_shardings(batch_abs_p, mesh),
            is_leaf=lambda x: isinstance(x, Param)))
        sshard = NamedSharding(mesh, P())
        step_abs = jax.ShapeDtypeStruct((), jnp.int32)
        strategy = ("fsdp" if variant.startswith("fsdp")
                    else "echo_dp" if variant == "echo_dp"
                    else "replicated")
        bundle = STRATEGIES[strategy]().build(cfg, opt, settings, mesh,
                                              shape.global_batch)
        vsh = (bundle.value_shardings
               if bundle.value_shardings is not None else vshard)
        oshard = opt_state_shardings(opt_abs, params_abs, mesh,
                                     override=bundle.value_shardings)
        if bundle.needs_basis:
            basis_abs = [jax.tree.map(
                lambda v: jax.ShapeDtypeStruct(v.shape, jnp.float32),
                values_abs) for _ in range(settings.echo_k)]
            bshard_basis = [jax.tree.map(
                lambda _: NamedSharding(mesh, P()), values_abs)
                for _ in range(settings.echo_k)]
            jitted = jax.jit(
                bundle.fn, in_shardings=(vsh, oshard, bshard, sshard,
                                         bshard_basis))
            lowered = jitted.lower(values_abs, opt_abs, batch_abs, step_abs,
                                   basis_abs)
        else:
            jitted = jax.jit(bundle.fn,
                             in_shardings=(vsh, oshard, bshard, sshard))
            lowered = jitted.lower(values_abs, opt_abs, batch_abs, step_abs)
    elif shape.kind == "prefill":
        fn, ctx = make_prefill(cfg, mesh, shape.global_batch)
        batch_abs_p = batch_for(cfg, shape)
        batch_abs, _ = split_params(batch_abs_p)
        bshard, _ = split_params(jax.tree.map(
            lambda p, s: Param(s, p.axes), batch_abs_p,
            tree_shardings(batch_abs_p, mesh),
            is_leaf=lambda x: isinstance(x, Param)))
        jitted = jax.jit(fn, in_shardings=(vshard, bshard))
        lowered = jitted.lower(values_abs, batch_abs)
    else:  # decode
        fn, ctx = make_serve_step(cfg, mesh, shape.global_batch)
        io_specs, cache_abs_p = decode_specs(cfg, shape)
        cache_abs, _ = split_params(cache_abs_p)
        cshard, _ = split_params(jax.tree.map(
            lambda p, s: Param(s, p.axes), cache_abs_p,
            tree_shardings(cache_abs_p, mesh),
            is_leaf=lambda x: isinstance(x, Param)))
        io_abs, _ = split_params(io_specs)
        ioshard, _ = split_params(jax.tree.map(
            lambda p, s: Param(s, p.axes), io_specs,
            tree_shardings(io_specs, mesh),
            is_leaf=lambda x: isinstance(x, Param)))
        rec["cache_bytes_global"] = _tree_bytes(cache_abs)
        jitted = jax.jit(fn, in_shardings=(vshard, cshard,
                                           ioshard["token"], ioshard["pos"]))
        lowered = jitted.lower(values_abs, cache_abs, io_abs["token"],
                               io_abs["pos"])
    rec["lower_s"] = round(time.time() - t0, 2)

    if not compile_:
        rec["status"] = "lowered"
        return rec

    t1 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t1, 2)
    rec["memory_analysis"] = _mem_analysis(compiled)
    rec["cost_analysis"] = _cost_analysis(compiled)
    rec["collectives"] = collective_bytes(compiled.as_text())
    rec["status"] = "ok"
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--moe-impl", default="tp")
    ap.add_argument("--variant", default="baseline",
                    choices=["baseline", "fsdp", "fsdp_savepsum",
                             "echo_dp", "all"],
                    help="'all' sweeps baseline+fsdp+echo_dp on train "
                         "shapes (non-train shapes run baseline only)")
    ap.add_argument("--param-dtype", default=None)
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--no-compile", action="store_true")
    args = ap.parse_args(argv)

    pairs = []
    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) \
        else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    variants = (["baseline", "fsdp", "echo_dp"] if args.variant == "all"
                else [args.variant])
    for a in archs:
        for s in shapes:
            for mp in meshes:
                for v in variants:
                    if v != "baseline" and INPUT_SHAPES[s].kind != "train":
                        continue   # step variants only exist for training
                    pairs.append((a, s, mp, v))

    os.makedirs(args.out, exist_ok=True)
    n_ok = n_skip = n_fail = 0
    for a, s, mp, variant in pairs:
        tag = f"{a}__{s}__{'2x16x16' if mp else '16x16'}"
        if variant != "baseline":
            tag += f"__{variant}"
        if args.moe_impl != "tp":
            tag += f"__{args.moe_impl}"
        if args.param_dtype:
            tag += f"__{args.param_dtype}"
        path = os.path.join(args.out, tag + ".json")
        if os.path.exists(path):
            with open(path) as fh:
                prev = json.load(fh)
            if prev.get("status") in ("ok", "skipped"):
                print(f"[cached]  {tag}: {prev['status']}")
                n_ok += prev["status"] == "ok"
                n_skip += prev["status"] == "skipped"
                continue
        print(f"[dryrun]  {tag} ...", flush=True)
        try:
            rec = dryrun_pair(a, s, mp, moe_impl=args.moe_impl,
                              compile_=not args.no_compile,
                              variant=variant,
                              param_dtype=args.param_dtype,
                              microbatches=args.microbatches)
        except Exception as e:
            rec = {"arch": a, "shape": s,
                   "mesh": "2x16x16" if mp else "16x16",
                   "status": "error", "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-3000:]}
        with open(path, "w") as fh:
            json.dump(rec, fh, indent=2)
        st = rec["status"]
        n_ok += st == "ok"
        n_skip += st == "skipped"
        n_fail += st == "error"
        extra = ""
        if st == "ok":
            ma = rec.get("memory_analysis", {})
            if "temp_size_in_bytes" in ma:
                extra = f" temp={ma['temp_size_in_bytes']/2**30:.2f}GiB"
            extra += (f" lower={rec.get('lower_s')}s"
                      f" compile={rec.get('compile_s')}s")
        if st == "error":
            extra = " " + rec["error"][:160]
        print(f"[{st:7s}] {tag}{extra}", flush=True)
    print(f"done: ok={n_ok} skipped={n_skip} failed={n_fail} "
          f"of {len(pairs)}")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
