"""Measured communication cost of Echo-CGC vs prior algorithms (Sec. 4.3).

Runs the faithful radio-network protocol at the paper's operating points
and compares measured bits / echo fraction against the closed-form bounds
(C, p). One row per (n, sigma, x) cell; also the per-round wall time of the
jitted protocol on this host.
"""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp

from repro.core import byzantine, costfns, theory
from repro.core.protocol import run_training
from repro.core.types import ProtocolConfig, raw_bits


def one_cell(n: int, sigma: float, x: float, d: int = 1000, rounds: int = 10,
             seed: int = 0):
    f = int(n * x)
    key = jax.random.PRNGKey(seed)
    cost = costfns.quadratic(key, d=d, mu=1.0, L=1.0, sigma=sigma)
    r, eta, *_ = theory.pick_r_eta(n, f, 1.0, 1.0, sigma)
    cfg = ProtocolConfig(n=n, f=f, r=r, eta=eta)
    byz = jnp.zeros(n, bool).at[:f].set(True)

    t0 = time.perf_counter()
    tr = run_training(cfg, cost, byzantine.ATTACKS["sign_flip"], byz, key,
                      jnp.ones(d), rounds=rounds)
    jax.block_until_ready(tr["bits"])
    dt_us = (time.perf_counter() - t0) / rounds * 1e6

    bits = float(jnp.mean(jnp.sum(tr["bits"].reshape(rounds, -1)
                                  if tr["bits"].ndim > 1 else
                                  tr["bits"][:, None], axis=-1)))
    bits_p2p = n * raw_bits(d)
    ratio = bits / bits_p2p
    echo_frac = float(jnp.mean(tr["n_echo"])) / (n - 1)
    C = theory.comm_ratio_C(sigma, x, 1.0, n)
    p = theory.echo_probability(r, sigma)
    # The paper's C assumes d >> n (echo bits negligible). At finite d the
    # attainable floor is the echo cost itself — report the d-adjusted
    # bound for an apples-to-apples comparison.
    C_adj = (theory.expected_bits_per_round(n, d, p)
             / theory.prior_bits_per_round(n, d))
    return dict(n=n, sigma=sigma, x=x, r=r, measured_ratio=ratio,
                bound_C=C, bound_C_adj_d=C_adj, echo_frac=echo_frac,
                bound_p=p, us=dt_us)


def run(out_dir: str = "experiments"):
    cells = [
        (20, 0.05, 0.10), (20, 0.10, 0.10),
        (50, 0.05, 0.10), (50, 0.10, 0.06),
        (100, 0.05, 0.10), (100, 0.10, 0.10),
    ]
    os.makedirs(out_dir, exist_ok=True)
    rows = []
    results = []
    for n, s, x in cells:
        c = one_cell(n, s, x)
        rows.append(c)
        results.append((
            f"comm_n{n}_s{s}_x{x}", c["us"],
            f"ratio={c['measured_ratio']:.3f}|C={c['bound_C']:.3f}"
            f"|C_adj={c['bound_C_adj_d']:.3f}"
            f"|echo={c['echo_frac']:.3f}|p={c['bound_p']:.3f}"))
    with open(os.path.join(out_dir, "comm_cost.csv"), "w") as fh:
        fh.write(",".join(rows[0]) + "\n")
        for c in rows:
            fh.write(",".join(f"{v:.6g}" if isinstance(v, float) else str(v)
                              for v in c.values()) + "\n")
    return results
