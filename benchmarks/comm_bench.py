"""Comm-policy bench: the adaptive control plane vs every fixed codec.

Drives the real ``launch.engine.Trainer`` (echo-DP strategy) through a
seeded lossy-channel schedule whose per-round noise is scaled to the
current gradient norm, so every round's echo residual ratio lands just
above the configured Eq. 7 threshold ``r=0.9``: a fixed-codec arm pays
the O(d) raw fallback every round, while the ``adaptive_echo`` policy
loosens ``r`` along its hysteresis band until the rounds convert to
O(n) echo messages — and the projection drops most of the injected
noise on the way, so the adaptive arm wins on *both* axes.

Arms (one process, fresh Trainer each, same seeded schedule):

- ``static`` x {fp32, bf16, int8, topk} — no policy object at all (the
  pre-policy code path);
- ``adaptive`` — ``adaptive_echo`` on the cheapest rung (topk) with
  error-feedback accumulators on.

Gated metrics:

- ``policy_bits_ratio`` (lower) — adaptive total bits / best fixed
  codec's total bits; < 1.0 means the policy beat every fixed arm;
- ``policy_pareto`` (higher) — 1.0 iff the adaptive arm strictly beat
  every fixed codec on bits AND matched its final loss (5% slack);
- ``static_bitwise`` (higher) — 1.0 iff a ``policy=static`` + fp32 run
  produced the exact loss trajectory of a no-policy run (the control
  plane observes, never steers, until a dynamic policy is asked for).

Per-arm bits / final-loss ride along as information. Everything is a
deterministic function of the seeds, so the gate is machine-portable.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

_BODY = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ["JAX_PLATFORMS"] = "cpu"
import json
import jax, jax.numpy as jnp
from repro.comm import resolve
from repro.comm.policy import resolve_policy
from repro.core import costfns
from repro.launch.engine import (EchoDpStrategy, Trainer, TrainerConfig,
                                 TrainSettings)
from repro.optim import sgd
from repro.run.config import CommSpec

n, d, K, rounds = 8, 256, 4, 40
SHOCK = 1.8        # noise norm ~= SHOCK * ||grad||: residual ratio > 0.9
cost = costfns.quadratic(jax.random.PRNGKey(0), d=d, mu=0.5, L=1.0,
                         sigma=0.0)

def loss_fn(values, batch):
    w = values["w"]
    return cost.value(w) + w @ jnp.mean(batch["eps"], 0), {}

mesh = jax.make_mesh((8,), ("data",))

def batch_for(step, w):
    # noise scaled to the *current* gradient so the echo residual ratio
    # sits just above the configured r=0.9 on every round of the decay
    gnorm = float(jnp.linalg.norm(cost.grad(w)))
    sigma = SHOCK * gnorm / (d ** 0.5)
    key = jax.random.fold_in(jax.random.PRNGKey(7), step)
    return {"eps": sigma * jax.random.normal(key, (n, d))}

def drive(codec, policy, ef):
    spec = CommSpec(channel="lossy", codec=codec, drop_prob=0.02, seed=5,
                    policy=policy or "static", ef=ef)
    comm = resolve(spec)
    pol = resolve_policy(spec) if policy else None
    settings = TrainSettings(aggregator="cgc", f=1, echo_k=K, echo_r=0.9,
                             comm=comm, policy=pol, ef=ef)
    tr = Trainer(EchoDpStrategy(loss_fn=loss_fn), None, sgd(0.02),
                 settings, mesh, n, TrainerConfig(log_every=10**9),
                 printer=lambda s: None)
    state = tr.init_state({"w": jnp.ones((d,)) * 2.0})
    losses = []
    with jax.set_mesh(mesh):
        for s in range(rounds):
            batch = batch_for(s, state.values["w"])
            state, rec = tr.run_round(state, batch)
            losses.append(rec["loss"])
    return {"bits": tr.bits_sent, "loss": losses[-1], "losses": losses,
            "echo_rate": tr.n_echo / tr.n_rounds}

fixed = {c: drive(c, None, False)
         for c in ("fp32", "bf16", "int8", "topk")}
adaptive = drive("topk", "adaptive_echo", True)
static_fp32 = drive("fp32", "static", False)

best_fixed = min(a["bits"] for a in fixed.values())
pareto = all(adaptive["bits"] < a["bits"]
             and adaptive["loss"] <= a["loss"] + 0.05 * abs(a["loss"])
             for a in fixed.values())
metrics = {
    "policy_bits_ratio": adaptive["bits"] / best_fixed,
    "policy_pareto": float(pareto),
    "static_bitwise": float(static_fp32["losses"]
                            == fixed["fp32"]["losses"]),
    "adaptive_echo_rate": adaptive["echo_rate"],
    "adaptive_bits": adaptive["bits"],
    "adaptive_final_loss": adaptive["loss"],
}
for c, a in fixed.items():
    metrics[f"bits_{c}"] = a["bits"]
    metrics[f"final_loss_{c}"] = a["loss"]
print(json.dumps(metrics))
"""

# gated keys: seeded decision trajectories, machine-portable; the raw
# per-arm bits/losses ride along as information only
GATE = {
    "policy_bits_ratio": "lower",
    "policy_pareto": "higher",
    "static_bitwise": "higher",
}


def bench():
    """BENCH_comm.json metrics for one run: the fixed-codec arms vs the
    adaptive policy on the seeded lossy schedule (subprocess driver)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(_BODY)],
                       capture_output=True, text=True, env=env, timeout=600)
    if r.returncode != 0:
        raise RuntimeError(f"comm bench failed:\n{r.stdout}\n{r.stderr}")
    return json.loads(r.stdout.strip().splitlines()[-1])


def run(out_dir: str = "experiments"):
    m = bench()
    return [("comm_policy", 0.0,
             f"bits_ratio={m['policy_bits_ratio']:.3f} "
             f"pareto={m['policy_pareto']:.0f}")]
