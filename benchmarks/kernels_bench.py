"""Kernel micro-benchmarks: Pallas (interpret on CPU) vs jnp reference.

On this CPU container the numbers are NOT TPU performance — they validate
the harness and provide the shape sweep used on real hardware (where
interpret=False). us_per_call is the jnp reference path (the production
fallback); derived reports allclose agreement.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cgc import cgc_filter
from repro.kernels import ops, ref


def _time(fn, *args, iters=5):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run(out_dir: str = "experiments"):
    key = jax.random.PRNGKey(0)
    results = []

    for n, d in [(16, 4096), (32, 65536)]:
        G = jax.random.normal(key, (n, d))
        f = n // 4
        us = _time(jax.jit(lambda G: cgc_filter(G, f)), G)
        ok = np.allclose(np.asarray(ops.cgc_clip(G, f)),
                         np.asarray(ref.cgc_clip_ref(G, f)), rtol=1e-4)
        results.append((f"cgc_clip_n{n}_d{d}", us, f"allclose={ok}"))

    for n, d in [(16, 4096), (32, 65536)]:
        A = jax.random.normal(key, (n, d))
        g = jax.random.normal(jax.random.fold_in(key, 1), (d,))
        mask = jnp.ones(n, bool)
        us = _time(jax.jit(ref.gram_ref), A, g)
        x, echo = ops.echo_project(A, mask, g)
        from repro.core.echo import project_onto_span
        x2, echo2 = project_onto_span(A, mask, g)
        ok = np.allclose(np.asarray(echo), np.asarray(echo2), rtol=1e-3,
                         atol=1e-4)
        results.append((f"echo_project_n{n}_d{d}", us, f"allclose={ok}"))

    for B, H, K, T in [(4, 8, 8, 4096), (1, 32, 8, 32768)]:
        hd = 128
        q = jax.random.normal(key, (B, H, hd), jnp.bfloat16)
        k = jax.random.normal(key, (B, T, K, hd), jnp.bfloat16)
        v = jax.random.normal(key, (B, T, K, hd), jnp.bfloat16)
        mask = jnp.ones((B, T), bool)
        us = _time(jax.jit(ref.decode_attention_ref), q, k, v, mask)
        out = ops.decode_attention(q, k, v, mask)
        exp = ref.decode_attention_ref(q, k, v, mask)
        ok = np.allclose(np.asarray(out, np.float32),
                         np.asarray(exp, np.float32), rtol=5e-2, atol=5e-2)
        results.append((f"decode_attn_B{B}_T{T}", us, f"allclose={ok}"))
    return results
