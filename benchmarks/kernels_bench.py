"""Kernel micro-benchmarks: Pallas (interpret on CPU) vs jnp reference.

On this CPU container the numbers are NOT TPU performance — they validate
the harness and provide the shape sweep used on real hardware (where
interpret=False). us_per_call is the jnp reference path (the production
fallback); derived reports allclose agreement.

``bench()`` is the BENCH_kernels.json suite: the gated metrics are
machine-relative ratios (fused-vs-unfused speedup on the same process)
and correctness booleans, never absolute timings.
"""
from __future__ import annotations

import statistics
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cgc import cgc_filter
from repro.kernels import ops, ref


def _time(fn, *args, iters=5):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def _median_time(fn, *args, iters=9):
    jax.block_until_ready(fn(*args))  # compile / warm
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return statistics.median(times) * 1e6


def run(out_dir: str = "experiments"):
    key = jax.random.PRNGKey(0)
    results = []

    for n, d in [(16, 4096), (32, 65536)]:
        G = jax.random.normal(key, (n, d))
        f = n // 4
        us = _time(jax.jit(lambda G: cgc_filter(G, f)), G)
        ok = np.allclose(np.asarray(ops.cgc_clip(G, f)),
                         np.asarray(ref.cgc_clip_ref(G, f)), rtol=1e-4)
        results.append((f"cgc_clip_n{n}_d{d}", us, f"allclose={ok}"))

    for n, d in [(16, 4096), (32, 65536)]:
        A = jax.random.normal(key, (n, d))
        g = jax.random.normal(jax.random.fold_in(key, 1), (d,))
        mask = jnp.ones(n, bool)
        us = _time(jax.jit(ref.gram_ref), A, g)
        x, echo = ops.echo_project(A, mask, g)
        from repro.core.echo import project_onto_span
        x2, echo2 = project_onto_span(A, mask, g)
        ok = np.allclose(np.asarray(echo), np.asarray(echo2), rtol=1e-3,
                         atol=1e-4)
        results.append((f"echo_project_n{n}_d{d}", us, f"allclose={ok}"))

    for B, H, K, T in [(4, 8, 8, 4096), (1, 32, 8, 32768)]:
        hd = 128
        q = jax.random.normal(key, (B, H, hd), jnp.bfloat16)
        k = jax.random.normal(key, (B, T, K, hd), jnp.bfloat16)
        v = jax.random.normal(key, (B, T, K, hd), jnp.bfloat16)
        mask = jnp.ones((B, T), bool)
        us = _time(jax.jit(ref.decode_attention_ref), q, k, v, mask)
        out = ops.decode_attention(q, k, v, mask)
        exp = ref.decode_attention_ref(q, k, v, mask)
        ok = np.allclose(np.asarray(out, np.float32),
                         np.asarray(exp, np.float32), rtol=5e-2, atol=5e-2)
        results.append((f"decode_attn_B{B}_T{T}", us, f"allclose={ok}"))

    m = fused_cgc_metrics()
    results.append(("cgc_fused_n16_d1048576", m["fused_us"],
                    f"speedup={m['fused_speedup']:.2f}x"))
    return results


def fused_cgc_metrics(n: int = 16, d: int = 1 << 20, f: int = 4):
    """Fused-vs-unfused CGC round on one (n, d) table.

    unfused: the pre-fusion driver structure — separate jitted stages
    with the threshold picked on the host between them (norms kernel ->
    device->host sync -> sort -> scale+sum kernel), three passes over
    the table. fused: ``ops.cgc_fused_aggregate``, one dispatch, no
    host round-trip. The ratio is the gated metric; the absolute
    timings are informational only.
    """
    G = jax.random.normal(jax.random.PRNGKey(2), (n, d))

    norms_jit = jax.jit(lambda G: jnp.linalg.norm(G, axis=-1))
    scalesum_jit = jax.jit(
        lambda G, s: jnp.sum(G.astype(jnp.float32) * s[:, None], axis=0))

    def unfused(G):
        norms = np.asarray(norms_jit(G))          # device->host sync
        thr = np.sort(norms)[n - f - 1]           # host-side top-k
        scales = np.minimum(1.0, thr / np.maximum(norms, 1e-12))
        return scalesum_jit(G, jnp.asarray(scales, jnp.float32))

    fused = jax.jit(lambda G: ops.cgc_fused_aggregate(G, f)[0])

    unfused_us = _median_time(unfused, G)
    fused_us = _median_time(fused, G)

    # correctness cross-checks ride along as gated booleans
    Gs = jax.random.normal(jax.random.PRNGKey(3), (13, 1000))
    want, _, _ = ref.cgc_fused_aggregate_ref(Gs, 3)
    ops.set_cgc_backend("jnp")
    agg_jnp, _, _ = ops.cgc_fused_aggregate(Gs, 3)
    ops.set_cgc_backend("pallas")
    agg_pal, _, _ = ops.cgc_fused_aggregate(Gs, 3)
    ops.set_cgc_backend("auto")
    bitwise_jnp = bool(np.array_equal(
        np.asarray(agg_jnp),
        np.asarray(jnp.sum(cgc_filter(Gs, 3), axis=0))))
    allclose_pal = bool(np.allclose(np.asarray(agg_pal), np.asarray(want),
                                    rtol=1e-5, atol=1e-5))

    v = jax.random.normal(jax.random.PRNGKey(4), (5000,))
    ops.set_codec_pack_backend("jnp")
    qj, sj = ops.int8_pack(v)
    vj, ij = ops.topk_pack(v, 64)
    ops.set_codec_pack_backend("pallas")
    qp, sp = ops.int8_pack(v)
    vp, ip = ops.topk_pack(v, 64)
    ops.set_codec_pack_backend("auto")
    int8_bitwise = bool(np.array_equal(np.asarray(qj), np.asarray(qp))
                        and float(sj) == float(sp))
    topk_bitwise = bool(np.array_equal(np.asarray(ij), np.asarray(ip))
                        and np.array_equal(np.asarray(vj), np.asarray(vp)))

    return {
        "fused_speedup": unfused_us / fused_us,
        "fused_us": fused_us,
        "unfused_us": unfused_us,
        "cgc_fused_bitwise_jnp": float(bitwise_jnp),
        "cgc_fused_allclose_pallas": float(allclose_pal),
        "int8_pack_bitwise": float(int8_bitwise),
        "topk_pack_bitwise": float(topk_bitwise),
    }


# gated keys of bench(): ratios + correctness flags, machine-portable
GATE = {
    "fused_speedup": "higher",
    "cgc_fused_bitwise_jnp": "higher",
    "cgc_fused_allclose_pallas": "higher",
    "int8_pack_bitwise": "higher",
    "topk_pack_bitwise": "higher",
}


def bench():
    """BENCH_kernels.json metrics for one run."""
    return fused_cgc_metrics()
