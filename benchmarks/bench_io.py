"""The standing bench trajectory: BENCH_*.json record I/O + the gate.

Each suite (train / kernels / serve) appends one record per run to a
JSON array at the repo root:

    [{"git_sha": "...", "dirty": false, "timestamp": "...",
      "metrics": {...}}, ...]

``git_sha`` is HEAD at emission time, which for the usual
emit-then-commit workflow is the PARENT of the commit that carries the
record — ``dirty`` (uncommitted changes present) flags exactly that
case, and ``--sha`` on ``benchmarks/run.py`` lets a caller stamp the
intended commit explicitly.

and declares a ``GATE`` mapping over the *machine-portable* subset of
its metrics — ratios (fused-vs-unfused speedup, continuous/fixed
speedup, echo rate, bits saving) and correctness booleans, never
absolute wall-clock, so a record emitted on a laptop can gate a CI
runner. ``gate()`` compares a fresh metrics dict against the last
committed record and reports every key that regressed by more than the
threshold (default 20%) in its bad direction.
"""
from __future__ import annotations

import datetime
import json
import os
import subprocess
from typing import Any, Dict, List, Optional

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

BENCH_FILES = {
    "train": "BENCH_train.json",
    "kernels": "BENCH_kernels.json",
    "serve": "BENCH_serve.json",
    "comm": "BENCH_comm.json",
}


def bench_path(suite: str, out_dir: Optional[str] = None) -> str:
    return os.path.join(out_dir or REPO_ROOT, BENCH_FILES[suite])


def git_sha(default: str = "unknown") -> str:
    try:
        out = subprocess.run(["git", "rev-parse", "HEAD"], cwd=REPO_ROOT,
                             capture_output=True, text=True, timeout=10)
        sha = out.stdout.strip()
        return sha if out.returncode == 0 and sha else default
    except OSError:
        return default


def git_dirty() -> bool:
    """True when the tree holds uncommitted changes — the emitted sha
    then names the parent of the commit the record belongs to."""
    try:
        out = subprocess.run(["git", "status", "--porcelain"],
                             cwd=REPO_ROOT, capture_output=True, text=True,
                             timeout=10)
        return out.returncode != 0 or bool(out.stdout.strip())
    except OSError:
        return True


def load_records(path: str) -> List[Dict[str, Any]]:
    """The trajectory at ``path``; [] when absent or empty."""
    if not os.path.exists(path):
        return []
    with open(path) as fh:
        text = fh.read().strip()
    if not text:
        return []
    records = json.loads(text)
    if not isinstance(records, list):
        raise ValueError(f"{path}: expected a JSON array of records")
    return records


def append_record(path: str, metrics: Dict[str, Any],
                  sha: Optional[str] = None,
                  dirty: Optional[bool] = None) -> Dict[str, Any]:
    """Append {git_sha, dirty, timestamp, metrics} to the array at
    ``path``. An explicit ``sha`` overrides the HEAD lookup (and marks
    the record clean unless ``dirty`` says otherwise)."""
    records = load_records(path)
    if dirty is None:
        dirty = False if sha is not None else git_dirty()
    record = {
        "git_sha": sha if sha is not None else git_sha(),
        "dirty": dirty,
        "timestamp": datetime.datetime.now(
            datetime.timezone.utc).isoformat(timespec="seconds"),
        "metrics": metrics,
    }
    records.append(record)
    parent = os.path.dirname(path)
    if parent:
        os.makedirs(parent, exist_ok=True)
    with open(path, "w") as fh:
        json.dump(records, fh, indent=2)
        fh.write("\n")
    return record


def gate(last_metrics: Dict[str, Any], new_metrics: Dict[str, Any],
         directions: Dict[str, str], threshold: float = 0.2
         ) -> List[str]:
    """Regression check: for each gated key, fail when the new value is
    worse than the last recorded one by more than ``threshold``
    (relative). ``directions`` maps key -> "higher" (bigger is better)
    or "lower". Keys absent from either side are skipped (a new metric
    starts gating once it has a baseline record)."""
    failures = []
    for key, direction in directions.items():
        if direction not in ("higher", "lower"):
            raise ValueError(f"gate direction for {key!r} must be "
                             f"'higher' or 'lower', got {direction!r}")
        if key not in last_metrics or key not in new_metrics:
            continue
        last, new = float(last_metrics[key]), float(new_metrics[key])
        if direction == "higher":
            bad = new < last * (1.0 - threshold)
        else:
            bad = new > last * (1.0 + threshold)
        if bad:
            failures.append(
                f"{key}: {new:.4g} vs last {last:.4g} "
                f"(>{threshold:.0%} regression, want {direction})")
    return failures
