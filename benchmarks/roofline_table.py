"""Roofline table: three terms per (arch x shape) from the dry-run records.

Reads experiments/dryrun/*.json (written by repro.launch.dryrun), applies
the analytic FLOP/byte model (repro.launch.roofline — see its docstring for
why the compiled cost_analysis is kept as evidence rather than used raw),
and writes experiments/roofline.csv + a markdown table for EXPERIMENTS.md.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config
from repro.launch.roofline import analyze


def load_recs(dry_dir: str = "experiments/dryrun") -> Dict[str, dict]:
    recs = {}
    if not os.path.isdir(dry_dir):
        return recs
    for f in os.listdir(dry_dir):
        if f.endswith(".json"):
            with open(os.path.join(dry_dir, f)) as fh:
                r = json.load(fh)
            recs[(r.get("arch"), r.get("shape"), r.get("mesh"))] = r
    return recs


def build_table(mesh: str = "16x16", dry_dir: str = "experiments/dryrun"
                ) -> List[dict]:
    recs = load_recs(dry_dir)
    chips = 512 if mesh == "2x16x16" else 256
    dp = 32 if mesh == "2x16x16" else 16
    tp = 16
    rows = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for sname, shape in INPUT_SHAPES.items():
            rec = recs.get((arch, sname, mesh))
            if rec and rec.get("status") == "skipped":
                rows.append(dict(arch=arch, shape=sname, mesh=mesh,
                                 status="skipped",
                                 note=rec.get("reason", "")))
                continue
            rl = analyze(cfg, shape, chips, dp, tp, rec)
            d = rl.as_dict()
            d["status"] = rec.get("status", "no-dryrun") if rec else \
                "no-dryrun"
            d["mesh"] = mesh
            rows.append(d)
    return rows


def write_csv(rows: List[dict], path: str):
    keys = ["arch", "shape", "mesh", "status", "compute_s", "memory_s",
            "collective_s", "dominant", "model_flops_global",
            "useful_ratio", "fit_hbm", "note"]
    with open(path, "w") as fh:
        fh.write(",".join(keys) + "\n")
        for r in rows:
            fh.write(",".join(_fmt(r.get(k)) for k in keys) + "\n")


def _fmt(v):
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v) if v is not None else ""


def write_markdown(rows: List[dict], path: str):
    lines = ["| arch | shape | compute (s) | memory (s) | collective (s) "
             "| dominant | useful ratio | fits HBM |",
             "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.get("status") == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"skipped | — | — |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {_fmt(r['compute_s'])} | "
            f"{_fmt(r['memory_s'])} | {_fmt(r['collective_s'])} | "
            f"{r['dominant']} | {_fmt(r.get('useful_ratio'))} | "
            f"{r.get('fit_hbm')} |")
    with open(path, "w") as fh:
        fh.write("\n".join(lines) + "\n")


def run(out_dir: str = "experiments"):
    results = []
    for mesh in ("16x16", "2x16x16"):
        rows = build_table(mesh)
        write_csv(rows, os.path.join(out_dir, f"roofline_{mesh}.csv"))
        if mesh == "16x16":
            write_markdown(rows,
                           os.path.join(out_dir, "roofline_16x16.md"))
        ok = sum(1 for r in rows if r.get("status") == "ok")
        dom = {}
        for r in rows:
            if "dominant" in r:
                dom[r["dominant"]] = dom.get(r["dominant"], 0) + 1
        results.append((f"roofline_{mesh}", 0.0,
                        f"rows={len(rows)}|ok={ok}|"
                        + "|".join(f"{k}={v}" for k, v in sorted(
                            dom.items()))))
    return results
