"""Convergence benchmark (Thm 9): Echo-CGC vs baselines under attacks.

One row per (attack x aggregator): rounds to reach 1e-6 of the initial
distance, measured per-round contraction vs the proven rho bound.
"""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import byzantine, costfns, theory
from repro.core.protocol import run_training
from repro.core.types import ProtocolConfig

ATTACKS = ["none", "sign_flip", "large_norm", "mean_shift", "poisoned_echo"]
AGGS = ["cgc", "median", "trimmed_mean", "krum", "mean"]


def _run(cost, cfg, byz, attack, agg, key, rounds=80, use_radio=True):
    tr = run_training(cfg, cost, byzantine.ATTACKS[attack], byz, key,
                      jnp.ones(cost.d) * 2.0, rounds=rounds,
                      aggregator=agg, use_radio=use_radio)
    d2 = np.asarray(tr["dist2"], np.float64)
    target = 1e-6 * d2[0]
    hit = np.argmax(d2 <= target) if np.any(d2 <= target) else -1
    rate = (d2[-1] / d2[0]) ** (1.0 / (len(d2) - 1)) if d2[-1] > 0 else 0.0
    return hit, rate, float(d2[-1] / d2[0])


def run(out_dir: str = "experiments"):
    key = jax.random.PRNGKey(0)
    n, f, d, sigma = 16, 2, 30, 0.05
    cost = costfns.quadratic(key, d=d, mu=1.0, L=1.0, sigma=sigma)
    r, eta, b, g, rho = theory.pick_r_eta(n, f, cost.L, cost.mu, sigma)
    cfg = ProtocolConfig(n=n, f=f, r=r, eta=eta)
    byz = jnp.zeros(n, bool).at[:f].set(True)
    os.makedirs(out_dir, exist_ok=True)
    results = []
    lines = ["attack,aggregator,rounds_to_1e6,rate,final_over_init"]
    for attack in ATTACKS:
        for agg in AGGS:
            t0 = time.perf_counter()
            # mean runs point-to-point (the fault-intolerant prior baseline)
            hit, rate, frac = _run(cost, cfg, byz, attack, agg, key,
                                   use_radio=agg != "mean")
            us = (time.perf_counter() - t0) * 1e6 / 80
            lines.append(f"{attack},{agg},{hit},{rate:.4f},{frac:.3g}")
            if agg == "cgc":
                results.append((f"conv_{attack}_cgc", us,
                                f"rate={rate:.4f}|rho_bound={rho:.4f}"))
    with open(os.path.join(out_dir, "convergence.csv"), "w") as fh:
        fh.write("\n".join(lines) + "\n")
    results.append(("conv_rho_bound", 0.0, f"{rho:.4f}"))
    return results
