"""Benchmark harness — one module per paper table/figure.

  fig1           : Figure 1a-d, Eq. 29 curves (the paper's numerical study)
  comm_cost      : measured bits / echo fraction vs the C and p bounds
  convergence    : Thm 9 convergence table (attacks x aggregators)
  kernels_bench  : Pallas kernel shape sweep vs jnp reference
  roofline_table : deliverable (g) — three roofline terms per arch x shape

Prints ``name,us_per_call,derived`` CSV; artifacts land in experiments/.
"""
from __future__ import annotations

import sys


def main() -> None:
    from benchmarks import (comm_cost, convergence, fig1, kernels_bench,
                            roofline_table)
    mods = [("fig1", fig1), ("comm_cost", comm_cost),
            ("convergence", convergence), ("kernels", kernels_bench),
            ("roofline", roofline_table)]
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    for name, mod in mods:
        if only and name != only:
            continue
        try:
            for row in mod.run():
                n, us, derived = row
                print(f"{n},{us:.1f},{derived}", flush=True)
        except Exception as e:  # keep the harness running
            print(f"{name},ERROR,{type(e).__name__}: {e}", flush=True)
            raise


if __name__ == '__main__':
    main()
