"""Benchmark harness — one module per paper table/figure, plus the
standing bench trajectory.

Legacy CSV mode (no flags, optional suite-name filter):

  fig1           : Figure 1a-d, Eq. 29 curves (the paper's numerical study)
  comm_cost      : measured bits / echo fraction vs the C and p bounds
  convergence    : Thm 9 convergence table (attacks x aggregators)
  kernels_bench  : Pallas kernel shape sweep vs jnp reference
  roofline_table : deliverable (g) — three roofline terms per arch x shape

Prints ``name,us_per_call,derived`` CSV; artifacts land in experiments/.

Trajectory mode (``--emit`` / ``--gate``): each suite in ``--suites``
(train / kernels / serve) exposes ``bench() -> metrics`` and a ``GATE``
direction map; ``--gate`` fails (exit 1) when any gated metric regresses
>``--threshold`` vs the LAST record in the suite's BENCH_*.json, and
``--emit`` appends a fresh ``{git_sha, timestamp, metrics}`` record:

    python benchmarks/run.py --emit --gate --suites kernels serve
"""
from __future__ import annotations

import argparse
import json
import os
import sys

# `python benchmarks/run.py` puts benchmarks/ (not the repo root) on
# sys.path; add the root so `from benchmarks import ...` resolves, and
# src/ so `repro` imports even without an editable install.
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (os.path.join(_ROOT, "src"), _ROOT):
    if _p not in sys.path:
        sys.path.insert(0, _p)


def main_csv(only=None) -> None:
    from benchmarks import (comm_cost, convergence, fig1, kernels_bench,
                            roofline_table)
    mods = [("fig1", fig1), ("comm_cost", comm_cost),
            ("convergence", convergence), ("kernels", kernels_bench),
            ("roofline", roofline_table)]
    print("name,us_per_call,derived")
    for name, mod in mods:
        if only and name != only:
            continue
        try:
            for row in mod.run():
                n, us, derived = row
                print(f"{n},{us:.1f},{derived}", flush=True)
        except Exception as e:  # keep the harness running
            print(f"{name},ERROR,{type(e).__name__}: {e}", flush=True)
            raise


def _suite(name):
    if name == "train":
        from benchmarks import train_bench as mod
    elif name == "kernels":
        from benchmarks import kernels_bench as mod
    elif name == "serve":
        from benchmarks import serve_bench as mod
    elif name == "comm":
        from benchmarks import comm_bench as mod
    else:
        raise SystemExit(f"unknown suite {name!r} "
                         f"(known: train kernels serve comm)")
    return mod


def main_trajectory(args) -> int:
    from benchmarks import bench_io

    failed = False
    for name in args.suites:
        mod = _suite(name)
        path = bench_io.bench_path(name, args.out_dir)
        print(f"[{name}] running bench() ...", flush=True)
        metrics = mod.bench()
        print(f"[{name}] {json.dumps(metrics)}", flush=True)
        if args.gate:
            records = bench_io.load_records(path)
            if records:
                failures = bench_io.gate(records[-1]["metrics"], metrics,
                                         mod.GATE, args.threshold)
                for msg in failures:
                    print(f"[{name}] GATE FAIL {msg}", flush=True)
                    failed = True
                if not failures:
                    print(f"[{name}] gate ok vs "
                          f"{records[-1]['git_sha'][:12]}", flush=True)
            else:
                print(f"[{name}] gate skipped: no prior record in "
                      f"{path}", flush=True)
        if args.emit:
            rec = bench_io.append_record(path, metrics, sha=args.sha)
            tag = " (dirty)" if rec.get("dirty") else ""
            print(f"[{name}] emitted record {rec['git_sha'][:12]}{tag} -> "
                  f"{path}", flush=True)
    return 1 if failed else 0


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("only", nargs="?", default=None,
                    help="legacy CSV mode: run just this table")
    ap.add_argument("--emit", action="store_true",
                    help="append a {git_sha, timestamp, metrics} record "
                         "to each suite's BENCH_*.json")
    ap.add_argument("--gate", action="store_true",
                    help="fail on >threshold regression vs the last "
                         "BENCH_*.json record")
    ap.add_argument("--suites", nargs="+",
                    default=["train", "kernels", "serve", "comm"],
                    choices=["train", "kernels", "serve", "comm"],
                    help="trajectory suites to run")
    ap.add_argument("--threshold", type=float, default=0.2,
                    help="relative regression tolerance (default 0.2)")
    ap.add_argument("--sha", default=None,
                    help="stamp emitted records with this sha instead of "
                         "HEAD (provenance for emit-before-commit runs)")
    ap.add_argument("--out-dir", default=None,
                    help="directory for BENCH_*.json (default: repo root)")
    args = ap.parse_args()

    if args.emit or args.gate:
        sys.exit(main_trajectory(args))
    main_csv(args.only)


if __name__ == '__main__':
    main()
