"""Figure 1 (a-d) numerical reproduction: C as a function of sigma, mu/L,
x = f/n, and n (Eq. 29). Writes experiments/fig1.csv."""
from __future__ import annotations

import os
import time

from repro.configs.paper_echo_cgc import FIG1A, FIG1B, FIG1C, FIG1D
from repro.core.theory import comm_ratio_C, x_max


def sweep():
    rows = []
    for s in FIG1A["sigma"]:
        rows.append(("1a_sigma", s, comm_ratio_C(s, FIG1A["x"],
                                                 FIG1A["mu_over_L"],
                                                 FIG1A["n"])))
    for ml in FIG1B["mu_over_L"]:
        rows.append(("1b_mu_over_L", ml, comm_ratio_C(FIG1B["sigma"],
                                                      FIG1B["x"], ml,
                                                      FIG1B["n"])))
    for x in FIG1C["x"]:
        rows.append(("1c_x", x, comm_ratio_C(FIG1C["sigma"], x,
                                             FIG1C["mu_over_L"],
                                             FIG1C["n"])))
    for n in FIG1D["n"]:
        rows.append(("1d_n", n, comm_ratio_C(FIG1D["sigma"], FIG1D["x"],
                                             FIG1D["mu_over_L"], n)))
    return rows


def run(out_dir: str = "experiments"):
    t0 = time.perf_counter()
    rows = sweep()
    dt = (time.perf_counter() - t0) / max(len(rows), 1) * 1e6
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "fig1.csv"), "w") as fh:
        fh.write("panel,value,C\n")
        for p, v, c in rows:
            fh.write(f"{p},{v:.6g},{c:.6g}\n")
    # headline checks (paper Sec. 4.3)
    c_head = comm_ratio_C(0.1, 0.1, 1.0, 100)
    results = [
        ("fig1_sweep", dt, f"points={len(rows)}"),
        ("fig1_headline_C(s=.1,x=.1,n=100)", dt, f"{c_head:.4f}"),
        ("fig1_xmax(s=.1,n=100)", dt, f"{x_max(0.1, 1.0, 100):.4f}"),
    ]
    return results
