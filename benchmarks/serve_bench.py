"""Serving throughput benchmark — a thin shim over ``repro.serve.bench``.

    PYTHONPATH=src python benchmarks/serve_bench.py [--requests 16 ...]

The implementation (Poisson trace, fixed-batch baseline, continuous
engine run, >= 2x acceptance gate) lives in ``repro.serve.bench``; like
the other legacy entry points this script emits one DeprecationWarning
and adapts its flags into a RunConfig for ``repro.run.bench`` — the
facade the unified CLI drives:

    python -m repro bench --config job.json      # needs a `bench` section
"""
from __future__ import annotations

import argparse


def config_from_flags(args) -> "run.RunConfig":
    """Legacy bench flags -> the equivalent RunConfig job tree."""
    from repro import run
    return run.RunConfig(
        name=f"{args.arch}-bench",
        model=run.ModelSpec(arch=args.arch),
        mesh=run.MeshSpec(devices=0),
        bench=run.BenchSpec(
            requests=args.requests, batch=args.batch,
            prompt_len=args.prompt_len, gen_short=args.gen_short,
            gen_long=args.gen_long, rate=args.rate,
            page_size=args.page_size, num_pages=args.num_pages,
            seed=args.seed))


# gated keys of bench(): ratios/flags measured on one machine within one
# process, so they port across hardware — the continuous/fixed speedup,
# the prefix-cache hit rate + prefill-compute saving on the shared-prefix
# trace, and the greedy-output bitwise-equality flag (cache on == off)
GATE = {
    "speedup": "higher",
    "prefix_hit_rate": "higher",
    "prefill_saved": "higher",
    "prefix_outputs_equal": "higher",
}


def bench():
    """BENCH_serve.json metrics for one run: the gated ratios above plus
    absolute tokens/s, latency, p50/p99 TTFT/ITL, and preemption rate
    (informational)."""
    from repro.run.config import BenchSpec
    from repro.serve.bench import run_bench

    res = run_bench("qwen3-0.6b", BenchSpec(), verbose=False)
    on = res["shared_on"]
    return {
        "speedup": res["speedup"],
        "prefix_hit_rate": res["prefix_hit_rate"],
        "prefill_saved": res["prefill_saved"],
        "prefix_outputs_equal": res["prefix_outputs_equal"],
        "shared_speedup": res["shared_speedup"],
        "fixed_tokens_per_s": res["fixed"]["tokens_per_s"],
        "continuous_tokens_per_s": res["continuous"]["tokens_per_s"],
        "continuous_p50_s": res["continuous"]["latency_p50_s"],
        "continuous_p99_s": res["continuous"]["latency_p99_s"],
        "preemptions": res["continuous"].get("preemptions", 0),
        "shared_tokens_per_s": on["tokens_per_s"],
        "ttft_p50_s": on["ttft_p50_s"],
        "ttft_p99_s": on["ttft_p99_s"],
        "itl_p50_s": on["itl_p50_s"],
        "itl_p99_s": on["itl_p99_s"],
        "preemption_rate": on["preemption_rate"],
        "cow_copies": on["cow_copies"],
    }


def main(argv=None):
    from repro.run import facade

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--gen-short", type=int, default=8)
    ap.add_argument("--gen-long", type=int, default=128)
    ap.add_argument("--rate", type=float, default=100.0,
                    help="Poisson arrival rate (req/s)")
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--num-pages", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    facade.warn_legacy("benchmarks/serve_bench.py", "python -m repro bench")
    return facade.bench(config_from_flags(args)).summary


if __name__ == "__main__":
    main()
