"""Train-loop bench: the echo-DP driver on the paper's quadratic cost,
plus the remat-policy sweep for the LM strategies.

Runs the real ``launch.engine.Trainer`` (optimistic echo rounds + exact
CGC fallback) for a fixed seeded schedule and reports the trajectory
metrics the paper is about: the echo success rate and the fraction of
broadcast bits saved vs the all-raw baseline. Both are deterministic
functions of the seeded run (decisions have wide margins), so they gate
cleanly across machines; wall-clock per round rides along as
information only.

The remat sweep (DESIGN.md §16 HC2) runs the reduced LM through the
replicated strategy under both ``TrainSettings.remat`` policies —
``full`` (recompute everything in backward) and ``save_psum`` (keep
cross-worker psum results) — in one process, and reports the loss-match
flag (gated: the policy must stay numerically inert) and the speed
ratio (informational: remat trades compute for memory, so the ratio is
hardware-shaped).

The obs leg (DESIGN.md §12) drives the same seeded echo-DP schedule
twice in one subprocess — tracker disabled vs a jsonl tracker with the
full ``TrackerHook`` — and reports ``obs_bitwise`` (gated: observing a
run must never steer its trajectory) and ``obs_overhead``
(informational: tracker wall-clock cost is machine-shaped).

The drivers need multiple workers, so each run happens in a subprocess
with 8 fake CPU devices (the calling process has already initialised
jax single-device).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

_BODY = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ["JAX_PLATFORMS"] = "cpu"
import json, time
import jax, jax.numpy as jnp
from repro.core import costfns
from repro.launch.engine import (EchoDpStrategy, Trainer, TrainerConfig,
                                 TrainSettings)
from repro.optim import sgd

n, d, K, rounds = 8, 256, 4, 12
shocks = (4, 8)                     # rounds whose noise breaks Eq. 7
cost = costfns.quadratic(jax.random.PRNGKey(0), d=d, mu=0.5, L=1.0,
                         sigma=0.0)

def loss_fn(values, batch):
    w = values["w"]
    return cost.value(w) + w @ jnp.mean(batch["eps"], 0), {}

def batch_for(step):
    scale = 10.0 if step in shocks else 1e-4
    key = jax.random.fold_in(jax.random.PRNGKey(7), step)
    return {"eps": scale * jax.random.normal(key, (n, d))}

mesh = jax.make_mesh((8,), ("data",))
settings = TrainSettings(aggregator="cgc", f=1, echo_k=K, echo_r=0.9)
tr = Trainer(EchoDpStrategy(loss_fn=loss_fn), None, sgd(0.02), settings,
             mesh, n, TrainerConfig(log_every=10**9),
             printer=lambda s: None)
state = tr.init_state({"w": jnp.ones((d,)) * 2.0})

losses = []
with jax.set_mesh(mesh):
    for s in range(rounds):              # warm the executables
        state, rec = tr.run_round(state, batch_for(s))
        losses.append(rec["loss"])
    t0 = time.perf_counter()
    for s in range(rounds, 2 * rounds):  # timed steady-state rounds
        state, rec = tr.run_round(state, batch_for(s))
        losses.append(rec["loss"])
    wall = time.perf_counter() - t0

print(json.dumps({
    "echo_rate": tr.n_echo / tr.n_rounds,
    "bits_saving": 1.0 - tr.bits_sent / tr.bits_baseline,
    "final_loss": losses[-1],
    "loss_decreased": float(min(losses) < losses[0]),
    "us_per_round": wall / rounds * 1e6,
}))
"""

_OBS_BODY = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ["JAX_PLATFORMS"] = "cpu"
import json, tempfile, time
import jax, jax.numpy as jnp
from repro import obs
from repro.core import costfns
from repro.launch.engine import (EchoDpStrategy, Trainer, TrainerConfig,
                                 TrainSettings)
from repro.optim import sgd

n, d, K, rounds = 8, 256, 4, 12
shocks = (4, 8)
cost = costfns.quadratic(jax.random.PRNGKey(0), d=d, mu=0.5, L=1.0,
                         sigma=0.0)

def loss_fn(values, batch):
    w = values["w"]
    return cost.value(w) + w @ jnp.mean(batch["eps"], 0), {}

def batch_for(step):
    scale = 10.0 if step in shocks else 1e-4
    key = jax.random.fold_in(jax.random.PRNGKey(7), step)
    return {"eps": scale * jax.random.normal(key, (n, d))}

mesh = jax.make_mesh((8,), ("data",))
settings = TrainSettings(aggregator="cgc", f=1, echo_k=K, echo_r=0.9)

def drive(hooks=None):
    # fresh Trainer, same seeded schedule: the trajectory must not
    # depend on whether anyone is watching
    tr = Trainer(EchoDpStrategy(loss_fn=loss_fn), None, sgd(0.02),
                 settings, mesh, n, TrainerConfig(log_every=10**9),
                 printer=lambda s: None, hooks=hooks)
    state = tr.init_state({"w": jnp.ones((d,)) * 2.0})
    losses = []
    with jax.set_mesh(mesh):
        for s in range(rounds):              # warm the executables
            state, rec = tr.run_round(state, batch_for(s))
            losses.append(rec["loss"])
        t0 = time.perf_counter()
        for s in range(rounds, 2 * rounds):  # timed steady-state rounds
            state, rec = tr.run_round(state, batch_for(s))
            losses.append(rec["loss"])
    return losses, time.perf_counter() - t0

drive()                                     # compile warm-up run
base_losses, base_wall = drive()            # tracker disabled (noop)
path = os.path.join(tempfile.mkdtemp(), "events.jsonl")
with obs.use_tracker(obs.JsonlTracker(path)):
    obs_losses, obs_wall = drive(hooks=obs.TrackerHook())

print(json.dumps({
    # disabled-tracker runs must be bitwise identical to instrumented
    # ones: the obs layer may observe the trajectory, never steer it
    "obs_bitwise": float(base_losses == obs_losses),
    "obs_overhead": obs_wall / base_wall - 1.0,
}))
"""

_REMAT_BODY = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ["JAX_PLATFORMS"] = "cpu"
import json, time
import jax
import numpy as np
from repro.configs import get_config, reduced
from repro.data import make_batch_iterator
from repro.launch.engine import Trainer, TrainerConfig, TrainSettings
from repro.models import model as M
from repro.models.nn import split_params
from repro.optim import sgd

cfg = reduced(get_config("qwen3-0.6b"))
batch, seq, rounds = 8, 32, 3
mesh = jax.make_mesh((8,), ("data",))
it = make_batch_iterator(cfg, batch, seq, seed=0)
batches = [next(it) for _ in range(2 * rounds)]

walls, losses = {}, {}
for remat in ("full", "save_psum"):
    tr = Trainer("replicated", cfg, sgd(1e-3),
                 TrainSettings(aggregator="mean", remat=remat), mesh,
                 batch, TrainerConfig(log_every=10**9),
                 printer=lambda s: None)
    values, _ = split_params(M.init_params(cfg, jax.random.PRNGKey(0)))
    state = tr.init_state(values)
    ls = []
    with jax.set_mesh(mesh):
        for b in batches[:rounds]:       # warm the executable
            state, rec = tr.run_round(state, b)
            ls.append(rec["loss"])
        t0 = time.perf_counter()
        for b in batches[rounds:]:       # timed steady-state rounds
            state, rec = tr.run_round(state, b)
            ls.append(rec["loss"])
        walls[remat] = time.perf_counter() - t0
    losses[remat] = ls

print(json.dumps({
    "remat_loss_match": float(np.allclose(losses["full"],
                                          losses["save_psum"],
                                          rtol=1e-4, atol=1e-6)),
    "remat_savepsum_speedup": walls["full"] / walls["save_psum"],
    "us_per_round_full": walls["full"] / rounds * 1e6,
    "us_per_round_save_psum": walls["save_psum"] / rounds * 1e6,
}))
"""

# gated keys: deterministic trajectory ratios/flags, machine-portable
# (the remat speed ratio and obs_overhead are informational — remat
# trades compute for memory, and tracker overhead is machine-shaped)
GATE = {
    "echo_rate": "higher",
    "bits_saving": "higher",
    "loss_decreased": "higher",
    "remat_loss_match": "higher",
    "obs_bitwise": "higher",
}


def _run_body(body: str):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                       capture_output=True, text=True, env=env, timeout=600)
    if r.returncode != 0:
        raise RuntimeError(f"train bench failed:\n{r.stdout}\n{r.stderr}")
    return json.loads(r.stdout.strip().splitlines()[-1])


def bench():
    """BENCH_train.json metrics for one run: the echo-DP driver plus the
    LM remat-policy sweep (subprocess drivers)."""
    metrics = _run_body(_BODY)
    metrics.update(_run_body(_OBS_BODY))
    metrics.update(_run_body(_REMAT_BODY))
    return metrics


def run(out_dir: str = "experiments"):
    m = bench()
    return [("train_echo_driver", m["us_per_round"],
             f"echo_rate={m['echo_rate']:.2f}")]
