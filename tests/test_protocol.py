"""End-to-end Echo-CGC protocol behaviour (Algorithm 1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.net  # noqa: F401  (registers the channel-aware attacks)
from repro.comm import CommConfig
from repro.comm.channel import LossyBroadcast
from repro.comm.wire import FP32
from repro.core import byzantine, costfns, theory
from repro.core.protocol import (communication_phase, echo_cgc_round,
                                 pointwise_round, run_training)
from repro.core.types import MSG_RAW, ProtocolConfig, raw_bits


def _cfg(n=12, f=1, r=0.3, eta=0.01):
    return ProtocolConfig(n=n, f=f, r=r, eta=eta)


def _identical_grads(n=12, d=24, seed=0):
    g = jax.random.normal(jax.random.PRNGKey(seed), (d,))
    return jnp.tile(g, (n, 1))


def _no_plan(n, d):
    return byzantine.no_attack(jax.random.PRNGKey(1),
                               jnp.zeros((n, d)), jnp.zeros(n, bool),
                               None, None)


def test_slot0_always_raw_rest_echo_when_identical():
    n, d = 12, 24
    grads = _identical_grads(n, d)
    cfg = _cfg(n=n)
    plan = _no_plan(n, d)
    server, stats = communication_phase(cfg, grads, jnp.zeros(n, bool), plan)
    assert not bool(stats.echo_sent[0])       # empty R -> raw (line 15)
    assert int(stats.n_echo) == n - 1         # everyone else echoes
    assert int(stats.rank_R) == 1             # identical grads: rank 1
    # server reconstruction is exact for every echo
    np.testing.assert_allclose(np.asarray(server.G), np.asarray(grads),
                               rtol=1e-4, atol=1e-5)


def test_bits_accounting():
    n, d = 10, 50
    grads = _identical_grads(n, d)
    cfg = _cfg(n=n)
    server, stats = communication_phase(cfg, grads, jnp.zeros(n, bool),
                                        _no_plan(n, d))
    total = float(jnp.sum(stats.bits_sent))
    p2p = n * raw_bits(d)
    assert total < 0.35 * p2p                 # large saving when echoing
    # raw slot costs exactly 32 d
    assert float(stats.bits_sent[0]) == raw_bits(d)


def test_reconstruction_matches_local_gradient_norm():
    # For every honest echoing worker: ||g~_j|| == ||g_j|| (paper invariant)
    n, d = 10, 30
    key = jax.random.PRNGKey(3)
    base = jax.random.normal(key, (d,))
    grads = base + 0.05 * jax.random.normal(jax.random.fold_in(key, 1),
                                            (n, d))
    cfg = _cfg(n=n, r=0.5)
    server, stats = communication_phase(cfg, grads, jnp.zeros(n, bool),
                                        _no_plan(n, d))
    gn = np.linalg.norm(np.asarray(grads), axis=1)
    rn = np.linalg.norm(np.asarray(server.G), axis=1)
    np.testing.assert_allclose(rn, gn, rtol=1e-4)


def test_forged_echo_detected():
    n, d, f = 10, 16, 3
    grads = _identical_grads(n, d, seed=4)
    byz_mask = jnp.zeros(n, bool).at[jnp.array([4, 7, 9])].set(True)
    plan = byzantine.forged_echo(jax.random.PRNGKey(0), grads, byz_mask,
                                 None, None)
    cfg = _cfg(n=n, f=f)
    server, stats = communication_phase(cfg, grads, byz_mask, plan)
    assert int(stats.n_detected) == 3         # self-reference caught
    # detected workers contribute the zero vector (line 37)
    for j in (4, 7, 9):
        assert float(jnp.linalg.norm(server.G[j])) == 0.0


def test_crash_workers_ignored():
    n, d = 8, 12
    grads = _identical_grads(n, d, seed=5)
    byz_mask = jnp.zeros(n, bool).at[2].set(True)
    plan = byzantine.crash(jax.random.PRNGKey(0), grads, byz_mask, None,
                           None)
    cfg = _cfg(n=n, f=2)
    server, stats = communication_phase(cfg, grads, byz_mask, plan)
    assert not bool(server.received[2])
    assert float(jnp.linalg.norm(server.G[2])) == 0.0


@pytest.mark.parametrize("attack", ["sign_flip", "large_norm", "mean_shift",
                                    "poisoned_echo", "echo_jam",
                                    "little_is_enough", "colluding_fade"])
def test_convergence_under_attack(attack):
    """Theorem 9: Echo-CGC converges despite f Byzantine workers."""
    key = jax.random.PRNGKey(0)
    d, n, f = 24, 16, 2
    cost = costfns.quadratic(key, d=d, mu=1.0, L=1.0, sigma=0.05)
    r, eta, *_ = theory.pick_r_eta(n, f, cost.L, cost.mu, cost.sigma)
    cfg = ProtocolConfig(n=n, f=f, r=r, eta=eta)
    byz_mask = jnp.zeros(n, bool).at[:f].set(True)
    trace = run_training(cfg, cost, byzantine.ATTACKS[attack], byz_mask,
                         key, jnp.zeros(d), rounds=60)
    d0, dT = float(trace["dist2"][0]), float(trace["dist2"][-1])
    assert dT < 1e-2 * d0, (attack, d0, dT)


@pytest.mark.parametrize("channel", [None,
                                     LossyBroadcast(seed=3, drop_prob=0.3)])
def test_n_equals_f_plus_one_crash_degrades_to_raw_only(channel):
    """The n = f+1 edge: every Byzantine worker crashed, one honest
    worker left. The empty crashed slots must not drag the CGC clip
    threshold to zero (the server filters on *known-bad* rows, reduced
    f' = f - crashed), so the lone raw gradient still drives descent —
    with and without a fading channel on top."""
    key = jax.random.PRNGKey(0)
    d, n, f = 12, 2, 1
    cost = costfns.quadratic(key, d=d, mu=1.0, L=1.0, sigma=0.01)
    cfg = ProtocolConfig(n=n, f=f, r=0.3, eta=0.05)
    byz_mask = jnp.zeros(n, bool).at[0].set(True)
    comm = None if channel is None else CommConfig(channel=channel,
                                                   codec=FP32)
    trace = run_training(cfg, cost, byzantine.ATTACKS["crash"], byz_mask,
                         key, jnp.ones(d) * 2.0, rounds=50, comm=comm)
    d2 = np.asarray(trace["dist2"])
    assert np.isfinite(d2).all()
    assert int(np.asarray(trace["n_echo"]).sum()) == 0   # raw-only
    assert d2[-1] < 0.25 * d2[0], (d2[0], d2[-1])


def test_rate_within_proven_bound():
    """Average contraction factor <= rho (the proven worst-case rate)."""
    key = jax.random.PRNGKey(1)
    d, n, f = 16, 16, 2
    cost = costfns.quadratic(key, d=d, mu=1.0, L=1.0, sigma=0.05)
    r, eta, b, g, rho = theory.pick_r_eta(n, f, cost.L, cost.mu, cost.sigma)
    cfg = ProtocolConfig(n=n, f=f, r=r, eta=eta)
    byz_mask = jnp.zeros(n, bool).at[:f].set(True)
    trace = run_training(cfg, cost, byzantine.ATTACKS["sign_flip"],
                         byz_mask, key, jnp.ones(d) * 3.0, rounds=40)
    dist2 = np.asarray(trace["dist2"])
    measured = (dist2[-1] / dist2[0]) ** (1.0 / (len(dist2) - 1))
    assert measured <= rho + 0.02, (measured, rho)


def test_echo_cgc_matches_pointwise_cgc_without_echoes():
    """With r=0 no one echoes: Echo-CGC == plain CGC [11] on raw gradients."""
    key = jax.random.PRNGKey(2)
    d, n, f = 12, 8, 1
    cost = costfns.quadratic(key, d=d, sigma=0.3)
    w = jnp.ones(d)
    keys = jax.random.split(key, n)
    grads = jax.vmap(lambda k: cost.stoch_grad(k, w))(keys)
    byz = jnp.zeros(n, bool)
    plan = _no_plan(n, d)
    cfg0 = ProtocolConfig(n=n, f=f, r=0.0, eta=0.05)
    w1, server, stats = echo_cgc_round(cfg0, w, grads, byz, plan)
    assert int(stats.n_echo) == 0
    w2, _ = pointwise_round(cfg0, w, grads, byz, plan)
    np.testing.assert_allclose(np.asarray(w1), np.asarray(w2), rtol=1e-5)


def test_echo_fraction_meets_theory_bound():
    """Measured echo rate >= p = 1 - (1+2/r)^2 sigma^2 (Sec. 4.3)."""
    key = jax.random.PRNGKey(7)
    d, n = 40, 24
    sigma = 0.05
    cost = costfns.quadratic(key, d=d, sigma=sigma)
    r = 0.5
    cfg = ProtocolConfig(n=n, f=0, r=r, eta=0.01)
    byz = jnp.zeros(n, bool)
    trace = run_training(cfg, cost, byzantine.no_attack, byz, key,
                         jnp.ones(d), rounds=30, aggregator="cgc")
    p = theory.echo_probability(r, sigma)
    echo_frac = float(jnp.mean(trace["n_echo"] / (n - 1)))
    assert echo_frac >= p - 0.1, (echo_frac, p)
