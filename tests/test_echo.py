"""Echo mechanism: projection, decision rule, server reconstruction."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.echo import (echo_decision, is_linearly_independent,
                             project_onto_span, reconstruct_echo)


def _setup(n=6, d=40, k=3, seed=0):
    key = jax.random.PRNGKey(seed)
    R = jax.random.normal(key, (n, d))
    mask = jnp.arange(n) < k
    g = jax.random.normal(jax.random.fold_in(key, 1), (d,))
    return R, mask, g


def test_projection_is_least_squares():
    R, mask, g = _setup()
    x, echo = project_onto_span(R, mask, g)
    # residual orthogonal to the span
    res = g - echo
    for i in range(3):
        assert float(jnp.abs(R[i] @ res)) < 1e-3
    # coefficients vanish outside the mask
    assert np.all(np.asarray(x[3:]) == 0)


def test_projection_exact_for_in_span_vector():
    R, mask, _ = _setup()
    coeffs = jnp.array([0.5, -1.2, 2.0, 0, 0, 0])
    g = coeffs @ (R * mask[:, None])
    x, echo = project_onto_span(R, mask, g)
    np.testing.assert_allclose(np.asarray(echo), np.asarray(g), rtol=1e-4,
                               atol=1e-5)
    dec = echo_decision(R, mask, g, r=1e-3)
    assert bool(dec.send_echo)
    assert float(dec.residual) < 1e-3 * float(jnp.linalg.norm(g))


def test_echo_decision_rejects_orthogonal():
    d = 30
    R = jnp.zeros((4, d)).at[0, 0].set(1.0).at[1, 1].set(1.0)
    mask = jnp.array([True, True, False, False])
    g = jnp.zeros((d,)).at[5].set(1.0)       # orthogonal to span
    dec = echo_decision(R, mask, g, r=0.5)
    assert not bool(dec.send_echo)


def test_empty_reference_never_echoes():
    R, _, g = _setup()
    mask = jnp.zeros(6, bool)
    dec = echo_decision(R, mask, g, r=1e9)
    assert not bool(dec.send_echo)


def test_reconstruction_preserves_norm():
    # server reconstructs g~ = k A x with ||g~|| = ||g|| (paper Sec. 4.2)
    R, mask, g = _setup(seed=2)
    dec = echo_decision(R, mask, g, r=10.0)   # force echo
    assert bool(dec.send_echo)
    g_rec = reconstruct_echo(R, mask, dec.k, dec.x)
    assert float(jnp.linalg.norm(g_rec)) == pytest.approx(
        float(jnp.linalg.norm(g)), rel=1e-4)
    # direction == echo direction
    cos = float((g_rec @ dec.echo) /
                (jnp.linalg.norm(g_rec) * jnp.linalg.norm(dec.echo)))
    assert cos == pytest.approx(1.0, abs=1e-5)


def test_reconstruction_masks_extra_coefficients():
    R, mask, g = _setup(seed=3)
    x = jnp.ones((6,))                        # junk outside mask
    g1 = reconstruct_echo(R, mask, 1.0, x)
    g2 = reconstruct_echo(R, mask, 1.0, x * mask)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-6)


def test_linear_independence_detection():
    R, mask, _ = _setup()
    dep = 2.0 * R[0] - R[1]                   # in span
    assert not bool(is_linearly_independent(R, mask, dep, tol=1e-4))
    key = jax.random.PRNGKey(9)
    indep = jax.random.normal(key, (40,))
    assert bool(is_linearly_independent(R, mask, indep, tol=1e-4))
    # empty reference set accepts anything
    assert bool(is_linearly_independent(R, jnp.zeros(6, bool), dep))
