"""Coverage for launch specs, RoPE/M-RoPE, FSDP planning, roofline model."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config
from repro.dist import abstract_mesh
from repro.launch.roofline import active_params, analyze, fwd_flops_per_token
from repro.launch.specs import (batch_for, check_applicability, decode_specs,
                                long_context_variant)
from repro.models.rope import apply_mrope, apply_rope

MESH = abstract_mesh((16, 16), ("data", "model"))


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------


def test_rope_preserves_norm_and_relativity():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (1, 8, 2, 64))
    pos = jnp.arange(8)[None, :]
    y = apply_rope(x, pos)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(x), axis=-1),
                               np.linalg.norm(np.asarray(y), axis=-1),
                               rtol=1e-5)
    # relative property: <rope(q,m), rope(k,n)> depends only on m-n
    q = jax.random.normal(jax.random.fold_in(key, 1), (1, 1, 1, 64))
    k = jax.random.normal(jax.random.fold_in(key, 2), (1, 1, 1, 64))
    def dot(m, n):
        qm = apply_rope(q, jnp.array([[m]]))
        kn = apply_rope(k, jnp.array([[n]]))
        return float(jnp.sum(qm * kn))
    assert dot(3, 1) == pytest.approx(dot(7, 5), rel=1e-4)
    assert dot(3, 1) != pytest.approx(dot(3, 2), rel=1e-3)


def test_mrope_reduces_to_rope_for_text():
    """Identical (t,h,w) positions == standard RoPE (text tokens)."""
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (2, 6, 4, 64))
    pos = jnp.broadcast_to(jnp.arange(6), (2, 6))
    pos3 = jnp.broadcast_to(pos, (3, 2, 6))
    y1 = apply_rope(x, pos)
    y2 = apply_mrope(x, pos3, (8, 12, 12))
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5,
                               atol=1e-6)


def test_mrope_distinct_axes_differ():
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, (1, 4, 2, 64))
    t = jnp.arange(4)[None]
    same = jnp.stack([t, t, t])[:, 0][:, None, :]
    spatial = jnp.stack([t, t * 2, t * 3])[:, 0][:, None, :]
    y1 = apply_mrope(x, same.reshape(3, 1, 4), (8, 12, 12))
    y2 = apply_mrope(x, spatial.reshape(3, 1, 4), (8, 12, 12))
    assert float(jnp.max(jnp.abs(y1 - y2))) > 1e-3


# ---------------------------------------------------------------------------
# launch.specs
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("shape", list(INPUT_SHAPES))
def test_specs_cover_every_pair(arch, shape):
    cfg = get_config(arch)
    sc = INPUT_SHAPES[shape]
    if check_applicability(cfg, sc):
        assert sc.kind == "decode" and cfg.is_encoder
        return
    cfg = long_context_variant(cfg, sc)
    if sc.kind == "decode":
        io, cache = decode_specs(cfg, sc)
        assert io["token"].value.shape == (sc.global_batch, 1)
        assert len(jax.tree.leaves(cache)) > 0
    else:
        b = batch_for(cfg, sc)
        key = "features" if cfg.frontend == "audio" else "tokens"
        assert b[key].value.shape[0] == sc.global_batch


def test_long_context_variant_windows_dense_only():
    dense = get_config("command-r-plus-104b")
    assert long_context_variant(dense,
                                INPUT_SHAPES["long_500k"]).sliding_window \
        == 8192
    assert long_context_variant(dense,
                                INPUT_SHAPES["decode_32k"]).sliding_window \
        is None
    ssm = get_config("xlstm-125m")
    assert long_context_variant(ssm,
                                INPUT_SHAPES["long_500k"]).sliding_window \
        is None


# ---------------------------------------------------------------------------
# FSDP planning
# ---------------------------------------------------------------------------


def test_fsdp_plan_avoids_model_axis_and_small_leaves():
    from repro.dist.fsdp import plan_fsdp
    from repro.launch.specs import abstract_params
    cfg = get_config("qwen3-0.6b")
    params = abstract_params(cfg)
    plan = plan_fsdp(params, MESH, dp_axes=("data",))
    leaves = jax.tree.leaves(plan, is_leaf=lambda x: x is None)
    planned = [d for d in leaves if d is not None]
    assert planned, "large leaves must be planned"
    # norm scales (tiny) are never planned
    assert plan["final_norm"] is None
    # planned dim must divide by dp=16
    from repro.models.nn import Param
    flat_p = jax.tree.leaves(params,
                             is_leaf=lambda x: isinstance(x, Param))
    flat_d = jax.tree.leaves(plan, is_leaf=lambda x: x is None)
    for p, d in zip(flat_p, flat_d):
        if d is not None:
            assert p.value.shape[d] % 16 == 0


# ---------------------------------------------------------------------------
# roofline analytic model
# ---------------------------------------------------------------------------


def test_active_params_close_to_param_count_dense():
    """For dense archs, active == total params (sanity of the model)."""
    from repro.launch.specs import abstract_params
    from repro.models.nn import Param
    for arch in ["qwen3-0.6b", "codeqwen1.5-7b"]:
        cfg = get_config(arch)
        n_true = sum(int(np.prod(p.value.shape)) for p in jax.tree.leaves(
            abstract_params(cfg), is_leaf=lambda x: isinstance(x, Param)))
        n_model = active_params(cfg)
        assert abs(n_model - n_true) / n_true < 0.02, (arch, n_model, n_true)


def test_moe_active_far_below_total():
    cfg = get_config("deepseek-v2-236b")
    from repro.launch.specs import abstract_params
    from repro.models.nn import Param
    n_true = sum(int(np.prod(p.value.shape)) for p in jax.tree.leaves(
        abstract_params(cfg), is_leaf=lambda x: isinstance(x, Param)))
    n_active = active_params(cfg)
    assert n_active < 0.2 * n_true          # 21B active of 236B


def test_roofline_terms_positive_and_dominant_valid():
    for arch in ["qwen3-0.6b", "zamba2-2.7b", "deepseek-v2-236b"]:
        cfg = get_config(arch)
        for sname, sc in INPUT_SHAPES.items():
            if check_applicability(cfg, sc):
                continue
            rl = analyze(cfg, sc, 256, 16, 16, None)
            assert rl.compute_s > 0 and rl.memory_s > 0
            assert rl.dominant in ("compute", "memory", "collective")
            assert rl.model_flops_global > 0


def test_decode_flops_much_smaller_than_train():
    cfg = get_config("qwen3-0.6b")
    f_train = fwd_flops_per_token(cfg, 2048)
    rl_t = analyze(cfg, INPUT_SHAPES["train_4k"], 256, 16, 16, None)
    rl_d = analyze(cfg, INPUT_SHAPES["decode_32k"], 256, 16, 16, None)
    assert rl_d.compute_s < 1e-2 * rl_t.compute_s
    assert f_train > 2 * active_params(cfg) * 0.5
