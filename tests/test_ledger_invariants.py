"""CommLedger accounting invariants (DESIGN.md §9).

Every transmitting layer reports rounds into one :class:`CommLedger`;
these tests pin the invariants that make that accounting trustworthy:

  * a round can never report negative bits (``record_round`` raises);
  * the per-round total the ledger books equals the sum of the
    per-worker ``bits_sent`` the slot loop priced (raw / echo / silent
    partition: silent pays 0, raw pays exactly the codec's raw cost,
    an echo pays the rank-dependent echo cost, and a faded echo that
    falls back to raw pays echo + raw — never less than raw);
  * retransmissions on a lossy channel never decrease the ledger — the
    cumulative bit count is monotone non-decreasing round over round.

When ``hypothesis`` is installed the channel-parameter sweep runs as a
property test; otherwise those cases fall back to a fixed grid.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import comm
from repro.comm import FP32, CommConfig, CommLedger, LossyBroadcast
from repro.core import byzantine, costfns
from repro.core.protocol import communication_phase, run_training
from repro.core.types import ProtocolConfig, raw_bits
from repro.run.config import CommSpec

try:
    import hypothesis
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                      # pragma: no cover - optional dep
    hypothesis = None


def _cfg(n=12, f=1, r=0.3, eta=0.01):
    return ProtocolConfig(n=n, f=f, r=r, eta=eta)


def _near_identical_grads(n, d, seed=0, jitter=0.02):
    key = jax.random.PRNGKey(seed)
    base = jax.random.normal(key, (d,))
    return base + jitter * jax.random.normal(jax.random.fold_in(key, 1),
                                             (n, d))


def _no_plan(n, d):
    return byzantine.no_attack(jax.random.PRNGKey(1),
                               jnp.zeros((n, d)), jnp.zeros(n, bool),
                               None, None)


def _check_round_partition(stats, n, d):
    """The raw/echo/silent partition of one round's per-worker bits."""
    bits = np.asarray(stats.bits_sent, dtype=np.float64)
    echoed = np.asarray(stats.echo_sent)
    assert (bits >= 0).all(), bits
    raw_cost = float(raw_bits(d))
    min_echo = float(FP32.echo_msg_bits(n, 0))
    for j in range(n):
        if bits[j] == 0:
            assert not echoed[j]          # silent slots transmit nothing
        elif echoed[j]:
            # echo cost is rank-dependent but bounded below by rank 0
            assert bits[j] >= min_echo
            assert bits[j] <= float(FP32.echo_msg_bits(n, n))
        else:
            # raw, or a faded echo retransmitted raw (echo + raw): a
            # retransmission never pays LESS than the plain raw message
            assert bits[j] >= raw_cost


def test_record_round_rejects_negative_bits():
    ledger = CommLedger()
    with pytest.raises(ValueError, match="non-negative"):
        ledger.record_round(bits=-1, baseline=100)
    with pytest.raises(ValueError, match="non-negative"):
        ledger.record_round(bits=100, baseline=-1)
    # the failed reports must not have corrupted the ledger
    assert ledger.rounds == 0
    assert ledger.bits_sent == 0


def test_ideal_round_partition_and_total():
    n, d = 12, 24
    grads = _near_identical_grads(n, d)
    server, stats = communication_phase(_cfg(n=n), grads,
                                        jnp.zeros(n, bool), _no_plan(n, d))
    _check_round_partition(stats, n, d)
    # ideal channel: nobody fades, so every non-echo slot that
    # transmitted pays EXACTLY the raw cost
    bits = np.asarray(stats.bits_sent)
    echoed = np.asarray(stats.echo_sent)
    sent_raw = (bits > 0) & ~echoed
    assert sent_raw.any()
    np.testing.assert_allclose(bits[sent_raw], raw_bits(d))
    # and the round total the ledger would book is the per-worker sum
    ledger = CommLedger()
    rec = ledger.record_round(bits=float(jnp.sum(stats.bits_sent)),
                              baseline=n * raw_bits(d),
                              echoed=int(stats.n_echo) > 0)
    assert rec["bits"] == int(bits.sum())
    assert ledger.bits_sent == int(bits.sum())


def test_ledger_matches_per_round_trace_totals():
    key = jax.random.PRNGKey(0)
    d, n, f = 16, 12, 1
    cost = costfns.quadratic(key, d=d, mu=1.0, L=1.0, sigma=0.05)
    cfg = _cfg(n=n, f=f)
    byz = jnp.zeros(n, bool).at[:f].set(True)
    ledger = CommLedger()
    trace = run_training(cfg, cost, byzantine.ATTACKS["sign_flip"], byz,
                         key, jnp.zeros(d), rounds=8, ledger=ledger)
    per_round = np.asarray(trace["bits"], dtype=np.float64)
    assert (per_round >= 0).all()
    assert ledger.rounds == 8
    assert ledger.bits_sent == int(per_round.sum())
    assert ledger.bits_baseline == 8 * n * raw_bits(d)
    assert ledger.echo_rounds == int((np.asarray(trace["n_echo"]) > 0).sum())


def _lossy_comm(drop_prob, seed=0):
    return comm.resolve(CommSpec(channel="lossy", drop_prob=drop_prob,
                                 seed=seed))


def _assert_lossy_invariants(drop_prob, seed):
    """One lossy run: partition holds per round, ledger is monotone."""
    key = jax.random.PRNGKey(seed)
    d, n = 16, 12
    cost = costfns.quadratic(key, d=d, mu=1.0, L=1.0, sigma=0.05)
    cfg = _cfg(n=n, f=0)
    lossy = _lossy_comm(drop_prob, seed=seed)
    ledger = CommLedger()
    trace = run_training(cfg, cost, byzantine.no_attack,
                         jnp.zeros(n, bool), key, jnp.zeros(d),
                         rounds=6, comm=lossy, ledger=ledger)
    per_round = np.asarray(trace["bits"], dtype=np.float64)
    assert (per_round >= 0).all()
    # retransmissions never decrease the ledger: cumulative bits are
    # monotone non-decreasing however many echoes faded and fell back
    cumulative = np.cumsum(per_round)
    assert (np.diff(cumulative) >= 0).all()
    assert ledger.bits_sent == int(per_round.sum())
    assert ledger.rounds == 6
    # and each individual round's slot pricing respects the partition
    grads = _near_identical_grads(n, d, seed=seed)
    _, stats = communication_phase(cfg, grads, jnp.zeros(n, bool),
                                   _no_plan(n, d), comm=lossy,
                                   chan_key=jax.random.PRNGKey(seed + 1))
    _check_round_partition(stats, n, d)


def test_lossy_channel_never_decreases_ledger():
    _assert_lossy_invariants(drop_prob=0.3, seed=0)


def test_lossy_fallback_pays_at_least_raw():
    """With heavy fading, some echo attempts fade mid-slot and the
    worker retransmits raw — paying echo + raw, never less than raw."""
    n, d = 12, 24
    grads = _near_identical_grads(n, d, seed=2)
    lossy = CommConfig(channel=LossyBroadcast(seed=0, drop_prob=0.6),
                       codec=FP32)
    fellback_seen = False
    for s in range(8):
        _, stats = communication_phase(_cfg(n=n), grads,
                                       jnp.zeros(n, bool), _no_plan(n, d),
                                       comm=lossy,
                                       chan_key=jax.random.PRNGKey(s))
        _check_round_partition(stats, n, d)
        bits = np.asarray(stats.bits_sent)
        echoed = np.asarray(stats.echo_sent)
        # fellback slots are priced echo + raw: strictly above raw
        fellback_seen |= bool(((bits > raw_bits(d)) & ~echoed).any())
    assert fellback_seen, "0.6 fade over 8 rounds produced no fallback"


if hypothesis is not None:
    @settings(max_examples=10, deadline=None)
    @given(drop_prob=st.floats(min_value=0.0, max_value=0.8),
           seed=st.integers(min_value=0, max_value=2**16))
    def test_lossy_invariants_property(drop_prob, seed):
        _assert_lossy_invariants(drop_prob=drop_prob, seed=seed)
else:
    @pytest.mark.parametrize("drop_prob,seed",
                             [(0.0, 1), (0.15, 2), (0.5, 3)])
    def test_lossy_invariants_grid(drop_prob, seed):
        # fixed-grid fallback for containers without hypothesis
        _assert_lossy_invariants(drop_prob=drop_prob, seed=seed)
