"""repro.net: hearing graphs, relay channels, Bracha reliable broadcast
and the channel-aware attacks (DESIGN.md §15)."""
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.net  # registers topologies / relay channel / attacks
from repro.comm import CommConfig
from repro.comm.channel import IdealBroadcast, LossyBroadcast
from repro.comm.wire import FP32
from repro.core import byzantine, costfns, protocol, theory
from repro.core.types import MSG_ECHO, ProtocolConfig
from repro.net import (HearingGraph, RelayChannel, apply_to_comm,
                       complete_graph, echo_quorum, explicit_graph,
                       net_active, random_geometric_graph, ready_quorum,
                       resolve_net, ring_graph, simulate_bracha,
                       simulate_plain_relay)
from repro.run.config import NetSpec, RunConfig
from repro.run.registry import ATTACKS, TOPOLOGIES


def _identical_grads(n, d=24, seed=0):
    g = jax.random.normal(jax.random.PRNGKey(seed), (d,))
    return jnp.tile(g, (n, 1))


def _no_plan(n, d):
    return byzantine.no_attack(jax.random.PRNGKey(1), jnp.zeros((n, d)),
                               jnp.zeros(n, bool), None, None)


# ---------------------------------------------------------------------------
# Topology builders
# ---------------------------------------------------------------------------


def test_topology_builders_and_validation():
    assert sorted(TOPOLOGIES.names()) == ["complete", "explicit",
                                          "random_geometric", "ring"]
    g = complete_graph(6)
    assert g.n == 6 and g.is_complete and g.edge_count() == 30

    ring = ring_graph(8, degree=2)
    assert not ring.is_complete and ring.edge_count() == 16
    assert ring.adj[0][1] and ring.adj[0][7] and not ring.adj[0][4]
    with pytest.raises(ValueError, match="even"):
        ring_graph(8, degree=3)

    geo = random_geometric_graph(10, degree=4, seed=3)
    assert geo.n == 10
    # seeded: the same spec builds the same graph
    assert geo.adj == random_geometric_graph(10, degree=4, seed=3).adj

    ex = explicit_graph("011;101;110", 3)
    assert ex.is_complete
    with pytest.raises(ValueError, match="3 rows"):
        explicit_graph("01;10", 3)
    with pytest.raises(ValueError, match="self-loops"):
        explicit_graph("111;101;110", 3)

    spec = NetSpec(topology="ring", degree=4)
    assert resolve_net(spec, 8).adj == ring_graph(8, 4).adj
    with pytest.raises(ValueError, match="complete"):
        resolve_net(NetSpec(topology="mesh3d"), 8)
    with pytest.raises(ValueError, match="adjacency"):
        resolve_net(NetSpec(topology="explicit"), 3)

    assert not net_active(NetSpec())
    assert net_active(NetSpec(topology="ring"))
    assert net_active(NetSpec(relays=2))


def test_hearing_graph_is_jit_static():
    g = ring_graph(6, 2)
    assert hash(g) == hash(ring_graph(6, 2))
    m = g.matrix()
    assert m.shape == (6, 6) and m.dtype == bool
    assert not bool(m[0, 3]) and bool(m[0, 1])


# ---------------------------------------------------------------------------
# Reference-set math under a partial hearing graph
# ---------------------------------------------------------------------------


def test_complete_graph_is_bitwise_the_shared_path():
    """The tentpole gate: passing an explicit complete graph must leave
    the training trajectory bit-for-bit identical to net=None."""
    n, d, f = 12, 24, 1
    key = jax.random.PRNGKey(0)
    cost = costfns.quadratic(key, d=d, mu=1.0, L=1.0, sigma=0.05)
    cfg = ProtocolConfig(n=n, f=f, r=0.3, eta=0.01)
    byz = jnp.zeros(n, bool).at[0].set(True)

    def run(net):
        return protocol.run_training(cfg, cost, byzantine.ATTACKS["sign_flip"],
                                     byz, jax.random.PRNGKey(1),
                                     jnp.zeros(d), rounds=10, net=net)

    t0, t1 = run(None), run(complete_graph(n))
    for k in ("dist2", "value", "bits", "n_echo", "n_detected", "w_final"):
        np.testing.assert_array_equal(np.asarray(t0[k]), np.asarray(t1[k]),
                                      err_msg=k)


def test_strict_complete_masked_path_matches_shared_path():
    """strict=True forces the per-worker-mask slot body; on a complete
    adjacency every worker's view coincides, so both paths agree."""
    n, d = 10, 16
    grads = jax.vmap(lambda k: jax.random.normal(k, (d,)))(
        jax.random.split(jax.random.PRNGKey(2), n))
    cfg = ProtocolConfig(n=n, f=1, r=0.9, eta=0.01)
    plan = _no_plan(n, d)
    nb = jnp.zeros(n, bool)
    srv_a, st_a = protocol.communication_phase(cfg, grads, nb, plan)
    strict = HearingGraph(adj=complete_graph(n).adj, strict=True)
    assert not strict.is_complete          # forced onto the masked path
    srv_b, st_b = protocol.communication_phase(cfg, grads, nb, plan,
                                              net=strict)
    np.testing.assert_allclose(np.asarray(srv_a.G), np.asarray(srv_b.G),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(srv_a.received),
                                  np.asarray(srv_b.received))
    assert int(st_a.n_echo) == int(st_b.n_echo)
    assert int(st_a.rank_R) == int(st_b.rank_R)


def test_ring_echo_rate_drops_to_neighbours_only():
    """n=8 identical gradients: the complete graph echoes every slot
    after the first, the degree-2 ring only every other slot — a worker
    can only echo a raw one of its two neighbours just broadcast, and an
    echo never enters anyone's reference set."""
    n, d = 8, 24
    grads = _identical_grads(n, d)
    cfg = ProtocolConfig(n=n, f=0, r=0.9, eta=0.01)
    plan = _no_plan(n, d)
    nb = jnp.zeros(n, bool)
    _, full = protocol.communication_phase(cfg, grads, nb, plan)
    _, ring = protocol.communication_phase(cfg, grads, nb, plan,
                                           net=ring_graph(n, 2))
    assert int(full.n_echo) == n - 1
    # raw at slots 0,2,4,6 (nobody heard a usable reference), echo at
    # 1,3,5,7 (each heard its predecessor's raw)
    assert int(ring.n_echo) == n // 2
    np.testing.assert_array_equal(
        np.asarray(ring.echo_sent),
        np.asarray([False, True] * (n // 2)))


def test_server_detects_echo_referencing_unheard_worker():
    """Topology-aware lines 36-37: an echo whose reference set includes
    a worker outside the sender's hearing range is provably Byzantine —
    even though the *server* received that worker's slot."""
    n, d = 8, 24
    grads = _identical_grads(n, d)
    cfg = ProtocolConfig(n=n, f=1, r=0.9, eta=0.01)
    plan = _no_plan(n, d)
    byz = jnp.zeros(n, bool).at[4].set(True)
    # worker 4 forges an echo referencing worker 0's raw (ring distance
    # 4 — far outside its degree-2 hearing set)
    plan = dataclasses.replace(
        plan,
        mode=plan.mode.at[4].set(MSG_ECHO),
        echo_ref=plan.echo_ref.at[4, 0].set(True),
        echo_k=plan.echo_k.at[4].set(1.0))
    srv, stats = protocol.communication_phase(cfg, grads, byz, plan,
                                              net=ring_graph(n, 2))
    assert bool(srv.detected[4])
    assert int(stats.n_detected) == 1
    # the same forged echo on the complete graph is NOT detectable —
    # worker 0's raw really was overheard by everyone
    srv_c, _ = protocol.communication_phase(cfg, grads, byz, plan)
    assert not bool(srv_c.detected[4])


# ---------------------------------------------------------------------------
# Relay channel + Bracha broadcast
# ---------------------------------------------------------------------------


def test_relay_channel_validation_and_protection():
    with pytest.raises(ValueError, match="relays"):
        RelayChannel(relays=0)
    with pytest.raises(ValueError, match="byz_relays"):
        RelayChannel(relays=2, byz_relays=3)
    with pytest.raises(ValueError, match="broadcast"):
        RelayChannel(relays=2, broadcast="gossip")
    assert RelayChannel(relays=1).protected           # byz == 0
    assert not RelayChannel(relays=2, byz_relays=1).protected
    assert RelayChannel(relays=3, byz_relays=1,
                        broadcast="dolev").protected  # 2b+1 routes
    assert not RelayChannel(relays=2, byz_relays=1,
                            broadcast="dolev").protected
    assert RelayChannel(relays=4, byz_relays=1,
                        broadcast="bracha").protected  # 3b+1 relays
    assert not RelayChannel(relays=3, byz_relays=1,
                            broadcast="bracha").protected


def test_relay_pricing_multiplies_round_bits():
    n, d = 8, 24
    grads = _identical_grads(n, d)
    cfg = ProtocolConfig(n=n, f=0, r=0.9, eta=0.01)
    plan = _no_plan(n, d)
    nb = jnp.zeros(n, bool)
    _, ideal = protocol.communication_phase(cfg, grads, nb, plan)
    relay = CommConfig(channel=RelayChannel(relays=2), codec=FP32)
    _, routed = protocol.communication_phase(cfg, grads, nb, plan,
                                             comm=relay)
    assert RelayChannel(relays=2).price_factor() == 2
    np.testing.assert_allclose(np.asarray(routed.bits_sent),
                               2.0 * np.asarray(ideal.bits_sent))


def test_byzantine_relay_direct_fails_where_bracha_converges():
    """The acceptance gate: one Byzantine relay on direct routing wrecks
    the aggregate (corrupted slots flip sign), while the Bracha tier
    with relays >= 3b+1 delivers every slot intact and training
    converges as on the ideal channel."""
    n, d, f = 12, 24, 1
    key = jax.random.PRNGKey(0)
    cost = costfns.quadratic(key, d=d, mu=1.0, L=1.0, sigma=0.05)
    cfg = ProtocolConfig(n=n, f=f, r=0.3, eta=0.01)
    byz = jnp.zeros(n, bool).at[0].set(True)

    def run(channel, rounds=40):
        return protocol.run_training(
            cfg, cost, byzantine.ATTACKS["crash"], byz,
            jax.random.PRNGKey(1), jnp.zeros(d), rounds,
            comm=CommConfig(channel=channel, codec=FP32))

    direct = run(RelayChannel(relays=2, byz_relays=1, broadcast="direct"))
    bracha = run(RelayChannel(relays=4, byz_relays=1, broadcast="bracha"))
    ideal = run(IdealBroadcast())
    d_direct = np.asarray(direct["dist2"])
    d_bracha = np.asarray(bracha["dist2"])
    d_ideal = np.asarray(ideal["dist2"])
    # bracha == ideal values (deliver is the identity when protected)
    np.testing.assert_array_equal(d_bracha, d_ideal)
    assert d_bracha[-1] < 1e-2 * d_bracha[0]
    # the unprotected route provably does not reach the optimum
    assert d_direct[-1] > 100.0 * d_bracha[-1]


def test_bracha_quorum_math():
    assert echo_quorum(4, 1) == 3 and ready_quorum(1) == 3
    ok = simulate_bracha(4, 1)
    assert ok.accepted == 1 and ok.safe
    assert ok.messages == 4 + 16 + 16
    # below 3b+1: liveness is lost, safety never (no wrong accept)
    stuck = simulate_bracha(3, 1)
    assert stuck.accepted is None and stuck.safe
    # no byzantine relays: trivial accept
    clean = simulate_bracha(3, 0)
    assert clean.accepted == 1 and clean.safe
    # the plain relay is the wrong-accept failure mode bracha closes
    wrong = simulate_plain_relay(4, 1)
    assert wrong.accepted == -1 and not wrong.safe
    ev = ok.as_event()
    assert ev["safe"] and json.dumps(ev)   # JSON-serialisable digest


# ---------------------------------------------------------------------------
# scenario.net config plumbing
# ---------------------------------------------------------------------------


def test_netspec_roundtrip_and_apply_to_comm():
    cfg = RunConfig.from_json(json.dumps({
        "schema_version": 1,
        "scenario": {"net": {"topology": "ring", "degree": 4,
                             "relays": 4, "byz_relays": 1,
                             "broadcast": "bracha"}},
    }))
    assert cfg.scenario.net.topology == "ring"
    assert RunConfig.from_json(cfg.to_json()).scenario.net == \
        cfg.scenario.net
    with pytest.raises(ValueError, match="unknown key"):
        RunConfig.from_json(json.dumps({
            "schema_version": 1,
            "scenario": {"net": {"topologee": "ring"}}}))

    base = CommConfig()
    routed = apply_to_comm(cfg.scenario.net, base)
    assert isinstance(routed.channel, RelayChannel)
    assert routed.channel.protected and routed.channel.broadcast == "bracha"
    # no relay tier: untouched config object
    assert apply_to_comm(NetSpec(topology="ring"), base) is base
    with pytest.raises(ValueError, match="relays"):
        apply_to_comm(NetSpec(byz_relays=1), base)
    with pytest.raises(ValueError, match="relays"):
        apply_to_comm(NetSpec(broadcast="bracha"), base)
    lossy = CommConfig(channel=LossyBroadcast(drop_prob=0.1), codec=FP32)
    with pytest.raises(ValueError, match="ideal"):
        apply_to_comm(NetSpec(relays=2), lossy)


# ---------------------------------------------------------------------------
# Channel-aware attacks
# ---------------------------------------------------------------------------


def test_echo_jam_starves_echoes_but_not_convergence():
    n, d, f = 12, 24, 1
    key = jax.random.PRNGKey(0)
    cost = costfns.quadratic(key, d=d, mu=1.0, L=1.0, sigma=0.05)
    cfg = ProtocolConfig(n=n, f=f, r=0.3, eta=0.01)
    byz = jnp.zeros(n, bool).at[0].set(True)
    jammed = protocol.run_training(cfg, cost, ATTACKS["echo_jam"], byz,
                                   jax.random.PRNGKey(1), jnp.zeros(d), 40)
    clean = protocol.run_training(cfg, cost, ATTACKS["none"], byz,
                                  jax.random.PRNGKey(1), jnp.zeros(d), 40)
    # the reference set never forms: zero echoes, every round all-raw
    assert int(np.asarray(jammed["n_echo"]).sum()) == 0
    assert int(np.asarray(clean["n_echo"]).sum()) > 0
    assert float(np.asarray(jammed["bits"]).sum()) > \
        float(np.asarray(clean["bits"]).sum())
    # correctness survives — the uplink still reaches the server
    d2 = np.asarray(jammed["dist2"])
    assert np.isfinite(d2).all() and d2[-1] < 1e-2 * d2[0]


def test_colluding_fade_swings_hard_only_in_fading_rounds():
    n, d = 12, 24
    key = jax.random.PRNGKey(3)
    grads = jax.vmap(lambda k: jax.random.normal(k, (d,)))(
        jax.random.split(key, n))
    byz = jnp.zeros(n, bool).at[0].set(True)
    fn = ATTACKS["colluding_fade"]
    lossy = LossyBroadcast(seed=9, drop_prob=0.9)
    chan_key = jax.random.PRNGKey(9)
    deep = fn(key, grads, byz, None, None, channel=lossy,
              chan_key=chan_key)
    mild = fn(key, grads, byz, None, None)     # no channel: mild shift
    assert float(jnp.linalg.norm(deep.raw[0])) > \
        float(jnp.linalg.norm(mild.raw[0]))
    # degrades gracefully when the channel cannot fade
    ideal = fn(key, grads, byz, None, None, channel=IdealBroadcast(),
               chan_key=chan_key)
    np.testing.assert_array_equal(np.asarray(ideal.raw[0]),
                                  np.asarray(mild.raw[0]))


def test_little_is_enough_stays_under_the_cgc_clip():
    n, d = 12, 24
    key = jax.random.PRNGKey(5)
    grads = jax.vmap(lambda k: jax.random.normal(k, (d,)))(
        jax.random.split(key, n))
    byz = jnp.zeros(n, bool).at[:2].set(True)
    plan = ATTACKS["little_is_enough"](key, grads, byz, None, None)
    bnorm = float(jnp.linalg.norm(plan.raw[0]))
    honest_norms = np.asarray(jnp.linalg.norm(grads, axis=-1))[2:]
    # capped at the smallest honest norm == never above the (n-f)-th
    # smallest received norm with <= f attackers: never clipped
    assert bnorm <= honest_norms.min() + 1e-5


# ---------------------------------------------------------------------------
# Report section
# ---------------------------------------------------------------------------


def test_report_renders_network_section(tmp_path):
    from repro.obs.report import report
    run_dir = str(tmp_path)
    with open(os.path.join(run_dir, "summary.json"), "w") as fh:
        json.dump({"kind": "train", "summary": {"rounds": 3},
                   "obs": {"counters": {"net.hearing_edges": 16},
                           "spans": {}}}, fh)
    events = [
        {"kind": "net.topology", "topology": "ring", "n": 8, "edges": 16,
         "complete": False},
        {"kind": "net.channel", "relays": 4, "byz_relays": 1,
         "broadcast": "bracha", "protected": True, "price_factor": 9},
        {"kind": "net.broadcast", "discipline": "bracha", "accepted": 1,
         "safe": True, "messages": 36},
    ]
    with open(os.path.join(run_dir, "events.jsonl"), "w") as fh:
        for e in events:
            fh.write(json.dumps(e) + "\n")
    out = []
    text = report(run_dir, printer=out.append)
    assert "-- network --" in text
    assert "topology      ring" in text
    assert "4 relays (1 byzantine)" in text
    assert "bracha: accepted=1 safe=True" in text
