"""Buffer-donation audit regression tests (DESIGN.md §10).

The train step and the serve decode/prefill dispatches must donate
their state-carrying arguments (weights+opt moments, KV caches/page
pools) so XLA updates them in place instead of double-buffering the
largest live allocations. These tests pin the audit's findings:
donation is visible both behaviorally (the donated input buffer is
deleted after the call) and in the compiled memory analysis (non-zero
alias bytes, where the backend reports it).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.engine import (ReplicatedStrategy, Trainer, TrainerConfig,
                                 TrainSettings)
from repro.optim import sgd


def _loss(values, batch):
    w = values["w"]
    return 0.5 * jnp.sum((w - 1.0) ** 2) + w @ jnp.mean(batch["eps"], 0), {}


def _trainer(d=16):
    return Trainer(ReplicatedStrategy(loss_fn=_loss), None, sgd(0.1),
                   TrainSettings(aggregator="mean"), None, 4,
                   TrainerConfig(), printer=lambda s: None)


def _alias_bytes(jitted, *args):
    stats = jitted.lower(*args).compile().memory_analysis()
    if stats is None or not hasattr(stats, "alias_size_in_bytes"):
        pytest.skip("backend reports no memory analysis")
    return stats.alias_size_in_bytes


def test_train_step_donates_state():
    """The plain train step donates (values, opt_state): the compiled
    executable aliases them to outputs and the input buffers are dead
    after one round."""
    tr = _trainer()
    state = tr.init_state({"w": jnp.zeros((16,))})
    batch = {"eps": 0.05 * jax.random.normal(jax.random.PRNGKey(0),
                                             (4, 16))}
    assert _alias_bytes(tr.step_fn, state.values, state.opt_state, batch,
                        jnp.asarray(0)) > 0
    pre_w = state.values["w"]
    state, _ = tr.run_round(state, batch)
    assert pre_w.is_deleted()
    assert not state.values["w"].is_deleted()
    # ...and the next round runs fine on the successor buffers
    state, rec = tr.run_round(state, batch)
    assert np.isfinite(rec["loss"])


def test_init_state_copies_caller_buffers():
    """Donation must never consume arrays the CALLER still holds:
    init_state deep-copies, so the same values dict can seed several
    trainers (the checkpoint tests do exactly this)."""
    values = {"w": jnp.zeros((16,))}
    batch = {"eps": 0.05 * jax.random.normal(jax.random.PRNGKey(1),
                                             (4, 16))}
    trA, trB = _trainer(), _trainer()
    sA = trA.init_state(values)
    assert sA.values["w"] is not values["w"]
    trA.run_round(sA, batch)
    assert not values["w"].is_deleted()
    sB = trB.init_state(values)            # still usable
    sB, rec = trB.run_round(sB, batch)
    assert np.isfinite(rec["loss"])


def test_async_save_snapshots_before_donation(tmp_path):
    """fit() checkpoints off-thread while the NEXT round donates the
    state the writer is serializing — save(wait=False) must snapshot to
    host first, so the restored checkpoint matches the step it named."""
    import itertools

    def batches():
        for s in itertools.count():
            key = jax.random.fold_in(jax.random.PRNGKey(3), s)
            yield {"eps": 0.05 * jax.random.normal(key, (4, 16))}

    tr = _trainer()
    tr.config = TrainerConfig(ckpt_dir=str(tmp_path), ckpt_every=2)
    state, _ = tr.fit(tr.init_state({"w": jnp.zeros((16,))}), batches(), 6)
    tr.close()
    tr2 = _trainer()
    tr2.config = TrainerConfig(ckpt_dir=str(tmp_path), resume=True)
    back = tr2.init_state({"w": jnp.zeros((16,))})
    assert back.step == 6
    np.testing.assert_array_equal(np.asarray(back.values["w"]),
                                  np.asarray(state.values["w"]))


def test_serve_bench_step_donates_cache():
    """The fixed-batch serving baseline donates its contiguous KV cache
    to every step — the dominant allocation is single-buffered."""
    from repro.configs import get_config, reduced
    from repro.launch.serve import make_serve_step
    from repro.models import model as M
    from repro.models.nn import split_params

    cfg = reduced(get_config("qwen3-0.6b"))
    values, _ = split_params(M.init_params(cfg, jax.random.PRNGKey(0)))
    serve_step, _ = make_serve_step(cfg, None, 2)
    step_jit = jax.jit(serve_step, donate_argnums=(1,))
    cache, _ = split_params(M.init_cache(cfg, 2, 16))
    tok = jnp.zeros((2, 1), jnp.int32)
    pos = jnp.zeros((2,), jnp.int32)
    assert _alias_bytes(step_jit, values, cache, tok, pos) > 0
    cache_leaf = jax.tree.leaves(cache)[0]
    _, cache = step_jit(values, cache, tok, pos)
    assert cache_leaf.is_deleted()
    # weights are NOT donated — they serve every request
    assert not jax.tree.leaves(values)[0].is_deleted()


def test_echo_optimistic_step_keeps_inputs_alive():
    """The echo-DP optimistic step must NOT donate: when Eq. 7 fails,
    the SAME (values, opt_state) re-enter the exact fallback step, so
    they must survive the optimistic call. The fallback is terminal for
    the round and does donate. (8 fake devices, so a subprocess.)"""
    from test_engine import _run_subprocess

    _run_subprocess("""
        import jax, jax.numpy as jnp
        from repro.launch.engine import (EchoDpStrategy, Trainer,
                                         TrainerConfig, TrainSettings)
        from repro.optim import sgd

        n, d, K = 8, 64, 4

        def loss_fn(values, batch):
            w = values["w"]
            return 0.5 * jnp.sum(w ** 2) + w @ jnp.mean(batch["eps"], 0), {}

        def batch_for(step, scale):
            key = jax.random.fold_in(jax.random.PRNGKey(7), step)
            return {"eps": scale * jax.random.normal(key, (n, d))}

        mesh = jax.make_mesh((8,), ("data",))
        tr = Trainer(EchoDpStrategy(loss_fn=loss_fn), None, sgd(0.02),
                     TrainSettings(aggregator="cgc", f=1, echo_k=K,
                                   echo_r=0.9),
                     mesh, n, TrainerConfig(log_every=100))
        state = tr.init_state({"w": jnp.ones((d,)) * 2.0})
        with jax.set_mesh(mesh):
            # round 0: zero basis -> fallback; its inputs are donated
            pre = state.values["w"]
            state, rec = tr.run_round(state, batch_for(0, 1e-4))
            assert not rec["all_echo"]
            assert pre.is_deleted(), "fallback must donate its inputs"
            # quiet round: optimistic echo step succeeds and must have
            # left its inputs alive (they were NOT donated)
            pre = state.values["w"]
            state, rec = tr.run_round(state, batch_for(1, 1e-4))
            assert rec["all_echo"]
            assert not pre.is_deleted(), \\
                "optimistic echo step must not donate"
            # shock round: optimistic step runs AND fails Eq. 7; the
            # surviving inputs then feed the fallback, which donates them
            pre = state.values["w"]
            state, rec = tr.run_round(state, batch_for(2, 10.0))
            assert not rec["all_echo"]
            assert pre.is_deleted()
        print("OK")
    """)
