"""Plugin registries (repro.run.registry): registration contract,
duplicate rejection, did-you-mean errors, discovery surface."""
import pytest

from repro.run.registry import DuplicateRegistrationError, Registry
from repro.run import available


def test_register_decorator_and_mapping_protocol():
    reg = Registry("widget")

    @reg.register("alpha")
    def alpha():
        return 1

    @reg.register()
    def beta():
        return 2

    assert reg["alpha"] is alpha and reg["beta"] is beta
    assert sorted(reg) == ["alpha", "beta"] == reg.names()
    assert len(reg) == 2 and "alpha" in reg and "gamma" not in reg
    assert dict(reg) == {"alpha": alpha, "beta": beta}


def test_duplicate_name_rejected():
    reg = Registry("widget")
    reg.add("a", 1)
    with pytest.raises(DuplicateRegistrationError, match="already"):
        reg.add("a", 2)
    assert reg["a"] == 1                     # original entry untouched
    with pytest.raises(ValueError):
        reg.add("", 3)
    with pytest.raises(ValueError):
        reg.add(None, 3)


def test_unknown_name_error_lists_alternatives():
    reg = Registry("widget")
    reg.add("alpha", 1)
    reg.add("beta", 2)
    with pytest.raises(KeyError) as e:
        reg["gamma"]
    msg = str(e.value)
    assert "widget" in msg and "gamma" in msg
    assert "alpha" in msg and "beta" in msg


def test_stack_registries_carry_the_zoos():
    """The legacy dict surfaces ARE the registries now — same names,
    same objects, plus the did-you-mean KeyError."""
    from repro.core.aggregators import AGGREGATORS, cgc_sum
    from repro.core.byzantine import ATTACKS, sign_flip
    from repro.dist import AGG_FNS
    from repro.launch.engine import STRATEGIES, EchoDpStrategy

    assert AGGREGATORS["cgc"] is cgc_sum
    assert ATTACKS["sign_flip"] is sign_flip
    assert STRATEGIES["echo_dp"] is EchoDpStrategy
    assert set(STRATEGIES) == {"replicated", "fsdp", "echo_dp"}
    assert {"mean", "cgc", "median", "trimmed_mean", "krum"} <= set(AGG_FNS)
    with pytest.raises(KeyError, match="sign_flip"):
        ATTACKS["sing_flip"]
    with pytest.raises(KeyError, match="replicated"):
        STRATEGIES["replicatd"]


def test_available_reports_every_kind():
    names = available()
    assert {"aggregators", "collective_aggregators", "attacks",
            "train_strategies", "norm_backends", "scale_backends",
            "paged_attn_backends"} <= set(names)
    assert "cgc" in names["aggregators"]
    assert "cgc" in names["collective_aggregators"]
    assert "sign_flip" in names["attacks"]
    assert names["train_strategies"] == ["echo_dp", "fsdp", "replicated"]
    for kind in ("norm_backends", "scale_backends", "paged_attn_backends"):
        assert names[kind] == ["jnp", "pallas"]


def test_backend_switch_validates_against_registry():
    from repro.kernels import ops

    with pytest.raises(ValueError) as e:
        ops.set_norm_backend("cuda")
    assert "jnp" in str(e.value) and "pallas" in str(e.value) \
        and "auto" in str(e.value)
    # a newly registered backend becomes selectable with no ops.py edit
    from repro.run.registry import NORM_BACKENDS
    NORM_BACKENDS.add("test_dummy", lambda leaves, block_d: 0.0)
    try:
        ops.set_norm_backend("test_dummy")
        assert ops.norm_backend() == "test_dummy"
    finally:
        ops.set_norm_backend("auto")
        NORM_BACKENDS._entries.pop("test_dummy")
