"""Decode-vs-forward consistency: the KV-cache / recurrent-state serve path
must reproduce the full-sequence forward logits token by token.

This is the strongest integration test of the cache machinery (GQA ring
buffers, MLA absorbed decode, Mamba2 chunked-vs-step, mLSTM parallel-vs-
recurrent, hybrid grouped caches).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import model as M
from repro.models.nn import split_params

B, S = 2, 32

ARCHS = ["qwen3-0.6b", "minicpm3-4b", "zamba2-2.7b", "xlstm-125m",
         "qwen3-moe-30b-a3b"]


def _full_logits(cfg, values, tokens):
    x, _ = M.forward(values, cfg, {"tokens": tokens})
    w = M.head_matrix(values, cfg)
    return jnp.einsum("bsd,dv->bsv", x, w.astype(x.dtype)).astype(
        jnp.float32)


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    cfg = reduced(get_config(arch))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    values, _ = split_params(params)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size, jnp.int32)
    ref = np.asarray(jax.jit(
        lambda v, t: _full_logits(cfg, v, t))(values, tokens))

    cache, _ = split_params(M.init_cache(cfg, B, S))
    step = jax.jit(lambda v, c, t, p: M.decode_step(v, cfg, c, t, p))
    errs = []
    for t in range(S):
        logits, cache = step(values, cache, tokens[:, t:t + 1],
                             jnp.full((B,), t, jnp.int32))
        got = np.asarray(logits)
        denom = np.maximum(np.abs(ref[:, t]).max(), 1.0)
        errs.append(np.abs(got - ref[:, t]).max() / denom)
    assert max(errs) < 2e-3, (arch, max(errs))


def test_sliding_window_decode_matches_windowed_forward():
    cfg = reduced(get_config("qwen3-0.6b")).with_sliding_window(8)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    values, _ = split_params(params)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size, jnp.int32)
    ref = np.asarray(jax.jit(
        lambda v, t: _full_logits(cfg, v, t))(values, tokens))
    # ring-buffer cache of exactly `window` slots
    cache, _ = split_params(M.init_cache(cfg, B, S))
    assert cache["layers"]["k"].shape[2] == 8     # (L, B, window, K, hd)
    step = jax.jit(lambda v, c, t, p: M.decode_step(v, cfg, c, t, p))
    errs = []
    for t in range(S):
        logits, cache = step(values, cache, tokens[:, t:t + 1],
                             jnp.full((B,), t, jnp.int32))
        denom = np.maximum(np.abs(ref[:, t]).max(), 1.0)
        errs.append(np.abs(np.asarray(logits) - ref[:, t]).max() / denom)
    assert max(errs) < 2e-3, max(errs)
