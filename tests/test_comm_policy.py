"""repro.comm.policy — the adaptive communication control plane.

Host-side policy units (deterministic decision trajectories), the
error-feedback accumulator invariants, the TopKCodec validation
regression, the static-policy bitwise guarantee on the real Trainer,
the seeded adaptive job's replayability, and the report CLI's comm
section. Multi-worker legs run in subprocesses with 8 fake CPU devices
(this process has already initialised jax single-device).
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm.policy import (CODEC_LADDER, AdaptiveEchoPolicy,
                               BanditPolicy, ChannelAwarePolicy,
                               CommDecision, PolicyContext,
                               RoundObservation, StaticPolicy,
                               ef_compensate, ef_init, ef_norms,
                               resolve_policy)
from repro.comm.wire import Bf16Codec, Fp32Codec, Int8Codec, TopKCodec
from repro.run.config import CommSpec


def _ctx(**kw):
    base = dict(n=8, d=256, echo_k=4, codec="int8", echo_r=0.9,
                channel="lossy", drop_prob=0.1,
                raw_round_bits={c: b for c, b in
                                zip(CODEC_LADDER,
                                    (8192, 4096, 2048, 1024, 512))},
                echo_round_bits={c: 64 for c in CODEC_LADDER})
    base.update(kw)
    return PolicyContext(**base)


def _obs(**kw):
    base = dict(round=0, bits=1000, baseline_bits=2048,
                fp32_baseline_bits=8192, loss=1.0, codec="int8",
                echo_r=0.9, attempted=True)
    base.update(kw)
    return RoundObservation(**base)


# ---------------------------------------------------------------------------
# Policy resolution + the static contract
# ---------------------------------------------------------------------------


def test_resolve_policy_registry():
    assert isinstance(resolve_policy(None), StaticPolicy)
    spec = CommSpec(policy="adaptive_echo")
    assert isinstance(resolve_policy(spec), AdaptiveEchoPolicy)
    with pytest.raises(ValueError, match="bandit"):  # did-you-mean text
        resolve_policy(CommSpec(policy="bandid"))


def test_static_policy_reasserts_configured_point():
    pol = StaticPolicy()
    pol.setup(_ctx(codec="bf16", echo_r=0.8))
    assert pol.static
    for obs in (None, _obs(echoed=False), _obs(echoed=True)):
        dec = pol.observe(obs)
        assert dec == CommDecision(codec="bf16", echo_r=0.8)


# ---------------------------------------------------------------------------
# adaptive_echo: hysteresis-banded r tuning
# ---------------------------------------------------------------------------


def test_adaptive_echo_loosens_on_eq7_failures_then_holds():
    pol = AdaptiveEchoPolicy()
    pol.setup(_ctx(echo_r=0.9))
    r_seen = []
    for t in range(12):       # every clean attempt fails Eq. 7
        dec = pol.observe(_obs(round=t, echoed=False, echo_r=pol.echo_r))
        r_seen.append(dec.echo_r)
    assert r_seen[0] == 0.9
    assert max(r_seen) > 0.9           # loosened
    assert max(r_seen) <= pol.r_max
    # monotone while failing: never tightens into a failing workload
    assert r_seen == sorted(r_seen)


def test_adaptive_echo_tightens_only_after_calm():
    pol = AdaptiveEchoPolicy(calm=6)
    pol.setup(_ctx(echo_r=0.9))
    for t in range(8):                 # drive r up
        pol.observe(_obs(round=t, echoed=False))
    loose = pol.echo_r
    assert loose > 0.9
    for t in range(40):                # long all-pass calm stretch
        pol.observe(_obs(round=8 + t, echoed=True))
    assert pol.echo_r == 0.9           # tightened back, never below floor


def test_adaptive_echo_ignores_faded_and_refused_rounds():
    pol = AdaptiveEchoPolicy()
    pol.setup(_ctx(echo_r=0.9))
    for t in range(20):                # failures, but the channel's fault
        pol.observe(_obs(round=t, echoed=False, echo_drops=2))
    for t in range(20):
        pol.observe(_obs(round=20 + t, echoed=False, refused=True))
    assert pol.echo_r == 0.9           # no Eq. 7 signal -> no movement


# ---------------------------------------------------------------------------
# channel_aware: drop-rate ladder stepping + budget as hard constraint
# ---------------------------------------------------------------------------


def test_channel_aware_steps_down_ladder_on_drops():
    pol = ChannelAwarePolicy()
    pol.setup(_ctx(codec="fp32"))
    seen = ["fp32"]
    for t in range(12):                # persistent 25% fade rate
        dec = pol.observe(_obs(round=t, codec=seen[-1], echoed=False,
                               echo_drops=2))
        seen.append(dec.codec)
    # walked the ladder monotonically toward the cheap end
    idxs = [CODEC_LADDER.index(c) for c in seen]
    assert idxs == sorted(idxs)
    assert seen[-1] == CODEC_LADDER[-1]        # cheapest rung (sign1)


def test_channel_aware_recovers_on_clean_channel():
    pol = ChannelAwarePolicy()
    pol.setup(_ctx(codec="fp32"))
    for t in range(12):
        pol.observe(_obs(round=t, echoed=False, echo_drops=2))
    assert CODEC_LADDER[pol._idx] == CODEC_LADDER[-1]
    for t in range(60):                # clean channel: EWMA decays
        dec = pol.observe(_obs(round=12 + t, echoed=True, echo_drops=0))
    assert dec.codec == "fp32"         # stepped all the way back up


def test_channel_aware_budget_is_hard_constraint():
    # budget fits only the two cheapest rungs: the policy must never
    # decide a codec whose worst-case round blows the cap
    pol = ChannelAwarePolicy()
    pol.setup(_ctx(codec="fp32", channel="metered", budget_bits=2200))
    dec = pol.observe(None)
    assert CODEC_LADDER.index(dec.codec) >= CODEC_LADDER.index("int8")
    for t in range(40):                # even on a perfectly clean channel
        dec = pol.observe(_obs(round=t, codec=dec.codec, echoed=True))
        assert _ctx().round_cost(dec.codec) <= 2200


def test_channel_aware_refusal_steps_down_immediately():
    pol = ChannelAwarePolicy()
    pol.setup(_ctx(codec="bf16"))
    dec = pol.observe(_obs(round=0, attempted=False, refused=True))
    assert CODEC_LADDER.index(dec.codec) > CODEC_LADDER.index("bf16")


# ---------------------------------------------------------------------------
# bandit: deterministic UCB over codec arms
# ---------------------------------------------------------------------------


def test_bandit_plays_all_arms_then_replays_deterministically():
    def drive():
        pol = BanditPolicy()
        pol.setup(_ctx())
        pulls, obs = [], None
        for t in range(40):
            dec = pol.observe(obs)
            pulls.append(dec.codec)
            # every arm buys the same loss decrease, so the
            # bits-per-loss-decrease reward favors the cheap end
            obs = _obs(round=t, codec=dec.codec, loss=64.0 - t,
                       bits=_ctx().raw_round_bits[dec.codec])
        return pulls
    a, b = drive(), drive()
    assert a == b                      # no RNG anywhere
    assert set(a[:len(CODEC_LADDER)]) == set(CODEC_LADDER)  # probe all arms
    # after probing, the best bits-per-loss arm gets the most pulls
    tail = a[len(CODEC_LADDER):]
    assert max(set(tail), key=tail.count) == CODEC_LADDER[-1]


# ---------------------------------------------------------------------------
# Error feedback: the residual invariants
# ---------------------------------------------------------------------------


def test_ef_compensate_identity_paths():
    vec = jnp.arange(8.0)
    res = jnp.ones(8)
    wire, new = ef_compensate(None, vec, res)
    np.testing.assert_array_equal(np.asarray(wire), np.asarray(vec))
    assert new is res                  # codec=None: passthrough untouched
    wire, new = ef_compensate(Int8Codec(), vec, None)
    assert new is None                 # no feedback requested
    # fp32 is exact: the compensated wire carries the residual, and the
    # new residual is exactly zero
    wire, new = ef_compensate(Fp32Codec(), vec, res)
    np.testing.assert_allclose(np.asarray(wire), np.asarray(vec + res))
    np.testing.assert_allclose(np.asarray(new), 0.0, atol=0.0)


def test_ef_every_discarded_bit_eventually_ships():
    # sum(wire_t) + e_T == sum(x_t): error feedback conserves mass
    codec = Int8Codec()
    key = jax.random.PRNGKey(0)
    e = jnp.zeros(64)
    total_x = jnp.zeros(64)
    total_wire = jnp.zeros(64)
    for t in range(50):
        x = jax.random.normal(jax.random.fold_in(key, t), (64,))
        wire, e = ef_compensate(codec, x, e)
        total_x += x
        total_wire += wire
    np.testing.assert_allclose(np.asarray(total_wire + e),
                               np.asarray(total_x), rtol=1e-4, atol=1e-4)


def test_ef_residual_norm_bounded_int8():
    # int8 roundtrip is a contraction, so ||e_t|| stays O(sup||x||)
    codec = Int8Codec()
    key = jax.random.PRNGKey(1)
    e = jnp.zeros(128)
    norms = []
    for t in range(200):
        x = jax.random.normal(jax.random.fold_in(key, t), (128,))
        _, e = ef_compensate(codec, x, e)
        norms.append(float(jnp.linalg.norm(e)))
    sup_x = float(jnp.sqrt(128.0)) * 5.0     # generous sup ||x||
    assert max(norms) < sup_x
    # and it does not trend: the last quarter is no worse than the first
    q = len(norms) // 4
    assert max(norms[-q:]) < 2.0 * max(norms[:q]) + 1e-6


def test_ef_init_and_norms_shapes():
    e = ef_init(6, 32)
    assert e.shape == (6, 32) and float(jnp.sum(jnp.abs(e))) == 0.0
    assert ef_norms(e).shape == (6,)


try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 10 ** 6), dim=st.integers(2, 96),
           scale=st.floats(0.1, 100.0), steps=st.integers(5, 40))
    def test_ef_residual_bounded_property(seed, dim, scale, steps):
        """||e|| never exceeds the contraction bound (1-δ)/δ · sup||x||
        for any seeded int8 stream; δ for per-tensor int8 is ~1/127 of
        the max entry, so a very loose multiple of sup||x|| suffices."""
        codec = Int8Codec()
        key = jax.random.PRNGKey(seed)
        e = jnp.zeros(dim)
        sup = 0.0
        for t in range(steps):
            x = scale * jax.random.normal(jax.random.fold_in(key, t),
                                          (dim,))
            sup = max(sup, float(jnp.linalg.norm(x)))
            _, e = ef_compensate(codec, x, e)
            assert float(jnp.linalg.norm(e)) <= 0.5 * sup + 1e-6
except ImportError:                    # hypothesis is a test extra
    pass


# ---------------------------------------------------------------------------
# TopKCodec validation (regression: bad k used to fail deep in pack)
# ---------------------------------------------------------------------------


def test_topk_codec_rejects_bad_k():
    for bad in (0, -3, 1.5, "8", True):
        with pytest.raises(ValueError, match="scenario.comm.topk"):
            TopKCodec(k=bad)


def test_topk_codec_k_above_dim_clamps_end_to_end():
    vec = jnp.arange(1.0, 9.0)                 # d=8
    codec = TopKCodec(k=64)
    out = codec.roundtrip(vec)
    np.testing.assert_allclose(np.asarray(out), np.asarray(vec))
    assert int(codec.vector_bits(8)) == 8 * (32 + 32)   # priced at d, not k


def test_topk_spec_validation_reaches_cli_path():
    from repro.comm import resolve
    with pytest.raises(ValueError, match="scenario.comm.topk"):
        resolve(CommSpec(codec="topk", topk=0))


# ---------------------------------------------------------------------------
# Trainer integration: static is bitwise, adaptive job replays
# ---------------------------------------------------------------------------


def _run_subprocess(body: str):
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        os.environ["JAX_PLATFORMS"] = "cpu"
    """) + textwrap.dedent(body)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=600)
    if r.returncode != 0:
        raise AssertionError(f"subprocess failed:\n{r.stdout}\n{r.stderr}")
    return r.stdout


JOB = os.path.join(os.path.dirname(__file__), "..", "experiments", "jobs",
                   "adaptive_lossy.json")


def test_static_policy_is_bitwise_on_trainer():
    """policy=static emits events but must not steer: the loss/bits
    trajectory is bit-for-bit the no-policy run's, fp32 and int8."""
    out = _run_subprocess("""
        import jax, jax.numpy as jnp
        from repro.comm import resolve
        from repro.comm.policy import resolve_policy
        from repro.core import costfns
        from repro.launch.engine import (EchoDpStrategy, Trainer,
                                         TrainerConfig, TrainSettings)
        from repro.optim import sgd
        from repro.run.config import CommSpec

        n, d, K, rounds = 8, 128, 4, 8
        cost = costfns.quadratic(jax.random.PRNGKey(0), d=d, mu=0.5,
                                 L=1.0, sigma=0.0)

        def loss_fn(values, batch):
            w = values["w"]
            return cost.value(w) + w @ jnp.mean(batch["eps"], 0), {}

        mesh = jax.make_mesh((8,), ("data",))

        def drive(codec, use_policy):
            spec = CommSpec(channel="lossy", codec=codec, drop_prob=0.1,
                            seed=3, policy="static")
            comm = resolve(spec)
            pol = resolve_policy(spec) if use_policy else None
            settings = TrainSettings(aggregator="cgc", f=1, echo_k=K,
                                     echo_r=0.9, comm=comm, policy=pol)
            tr = Trainer(EchoDpStrategy(loss_fn=loss_fn), None, sgd(0.02),
                         settings, mesh, n, TrainerConfig(log_every=10**9),
                         printer=lambda s: None)
            state = tr.init_state({"w": jnp.ones((d,)) * 2.0})
            traj = []
            with jax.set_mesh(mesh):
                for s in range(rounds):
                    key = jax.random.fold_in(jax.random.PRNGKey(7), s)
                    batch = {"eps": (10.0 if s == 4 else 1e-4)
                             * jax.random.normal(key, (n, d))}
                    state, rec = tr.run_round(state, batch)
                    traj.append((rec["loss"], rec["bits"],
                                 rec["all_echo"]))
            return traj

        for codec in ("fp32", "int8"):
            assert drive(codec, False) == drive(codec, True), codec
        print("OK")
    """)
    assert "OK" in out


def test_adaptive_lossy_job_replays_decision_for_decision(tmp_path):
    """The seeded adaptive job run twice produces identical bits,
    codec/echo_r decision and loss trajectories."""
    out = _run_subprocess(f"""
        import json
        from repro import run

        base = run.RunConfig.load({str(JOB)!r})
        base = run.apply_overrides(
            base, ["train.steps=8", "runs_root=" + {str(tmp_path)!r}])

        results = [run.train(base) for _ in range(2)]
        trajs = []
        for res in results:
            recs = [json.loads(l) for l in
                    open(res.metrics_path).read().splitlines()]
            trajs.append([(r["bits"], r["bits_cumulative"], r["loss"],
                           r.get("codec"), r.get("echo_r"),
                           r["all_echo"]) for r in recs])
        assert trajs[0] == trajs[1], trajs     # seeded: replays exactly
        assert len(trajs[0]) == 8
        s = results[0].summary
        assert s["policy"] == "adaptive_echo"
        assert "codec_final" in s and "echo_r_final" in s
        print("OK", s["codec_switches"], s["echo_r_final"])
    """)
    assert "OK" in out


def test_protocol_run_training_policy_and_ef(tmp_path):
    """core.protocol.run_training: static stays bitwise, the dynamic
    path reports decisions, and EF threads the slot loop."""
    import dataclasses

    from repro.comm import CommLedger, resolve
    from repro.core import byzantine, costfns
    from repro.core.protocol import ProtocolConfig, run_training

    key = jax.random.PRNGKey(0)
    d, n, f = 16, 8, 1
    cost = costfns.quadratic(key, d=d, mu=1.0, L=1.0, sigma=0.05)
    cfg = ProtocolConfig(n=n, f=f, r=0.15, eta=0.02)
    byz = jnp.zeros(n, bool).at[:f].set(True)
    spec = CommSpec(channel="lossy", codec="int8", drop_prob=0.2, seed=3)
    comm = resolve(spec)
    args = (cfg, cost, byzantine.no_attack, byz, key, jnp.ones(d) * 2.0)

    base = run_training(*args, rounds=6, comm=comm)
    static = run_training(*args, rounds=6, comm=comm,
                          policy=resolve_policy(spec))
    np.testing.assert_array_equal(np.asarray(base["w_final"]),
                                  np.asarray(static["w_final"]))

    spec_dyn = dataclasses.replace(spec, policy="channel_aware",
                                   drop_prob=0.4, ef=True)
    led = CommLedger()
    dyn = run_training(*args, rounds=10, comm=resolve(spec_dyn),
                       ledger=led, policy=resolve_policy(spec_dyn),
                       error_feedback=True)
    assert led.rounds == 10
    assert dyn["codec_switches"] >= 1          # 40% drops force a step
    assert dyn["bits"].shape == (10,)

    ef_run = run_training(*args, rounds=6, comm=comm, error_feedback=True)
    assert float(ef_run["dist2"][-1]) < float(ef_run["dist2"][0])


# ---------------------------------------------------------------------------
# Report CLI: the comm section
# ---------------------------------------------------------------------------


def test_report_renders_comm_section(tmp_path):
    from repro.obs.report import render

    events = [
        {"kind": "comm.policy.decision", "step": 2, "policy":
         "channel_aware", "codec": "topk", "echo_r": 0.9},
        {"kind": "comm.policy.round", "step": 0, "policy": "channel_aware",
         "codec": "int8", "echo_r": 0.9, "bits": 9000, "echoed": True,
         "attempted": True, "echo_drops": 0, "bits_cumulative": 9000,
         "fp32_baseline_cumulative": 32000, "loss": 5.0},
        {"kind": "comm.policy.round", "step": 1, "policy": "channel_aware",
         "codec": "topk", "echo_r": 0.9, "bits": 4000, "echoed": False,
         "attempted": True, "echo_drops": 2, "bits_cumulative": 13000,
         "fp32_baseline_cumulative": 64000, "loss": 4.0},
    ]
    data = {"kind": "train",
            "summary": {"policy": "channel_aware", "codec_switches": 1,
                        "codec_final": "topk", "echo_r_final": 0.9},
            "obs": {}, "policy_events": events}
    text = render(data)
    assert "-- comm policy --" in text
    assert "channel_aware" in text
    assert "codec switches 1" in text
    assert "decision @2" in text
    assert "int8 x1" in text and "topk x1" in text
    assert "fp32 all-raw" in text and "79.7% saved" in text


def test_report_loads_policy_events_from_run_dir(tmp_path):
    from repro.obs.report import load_run

    run_dir = tmp_path / "run"
    run_dir.mkdir()
    (run_dir / "summary.json").write_text(json.dumps(
        {"kind": "train", "summary": {"policy": "static"}, "obs": {}}))
    lines = [json.dumps({"kind": "comm.policy.decision", "step": 0,
                         "policy": "static", "codec": "fp32",
                         "echo_r": 0.9}),
             json.dumps({"kind": "train.profile_start", "dir": "x"}),
             "{not json"]
    (run_dir / "events.jsonl").write_text("\n".join(lines) + "\n")
    data = load_run(str(run_dir))
    assert len(data["policy_events"]) == 1     # filtered + tolerant
    assert data["policy_events"][0]["codec"] == "fp32"


def test_report_no_comm_section_without_policy():
    from repro.obs.report import render

    text = render({"kind": "train", "summary": {"rounds": 3}, "obs": {}})
    assert "-- comm policy --" not in text
