"""The observability layer (repro.obs, DESIGN.md §12): tracker sinks,
span nesting, the async line writer's error contract, run summaries and
the ``python -m repro report`` CLI.

Single-worker paths run in-process; the echo_dp strategy needs >1
data-parallel workers, so that leg runs in a subprocess with 8 forced
host devices (the test_dist.py pattern).
"""
import json
import os
import subprocess
import sys
import textwrap
import threading

import pytest

from repro import obs
from repro.obs.writer import AsyncLineWriter
from repro.run import ObsSpec, TRACKERS


# ---------------------------------------------------------------------------
# AsyncLineWriter: ordering, error surfacing, atexit flush
# ---------------------------------------------------------------------------


def test_async_line_writer_roundtrip(tmp_path):
    path = tmp_path / "out.jsonl"
    w = AsyncLineWriter(str(path))
    for i in range(100):
        w.write(f"line {i}\n")
    assert w.flush()
    assert path.read_text().splitlines()[0] == "line 0"
    w.close()
    assert path.read_text().splitlines() == [f"line {i}" for i in range(100)]
    w.close()                                  # idempotent


def test_async_line_writer_surfaces_background_error(tmp_path):
    w = AsyncLineWriter(str(tmp_path / "x.jsonl"))
    w._fh.close()                              # sabotage the sink
    w.write("doomed\n")
    with pytest.raises(RuntimeError, match="background write"):
        w.flush()
    w.close(reraise=False)                     # drained error; clean close


def test_async_line_writer_close_reraises(tmp_path):
    w = AsyncLineWriter(str(tmp_path / "x.jsonl"))
    w._fh.close()
    w.write("doomed\n")
    with pytest.raises(RuntimeError, match="background write"):
        w.close()
    w.close()                                  # already closed: no-op


def test_async_line_writer_write_after_close_raises(tmp_path):
    w = AsyncLineWriter(str(tmp_path / "x.jsonl"))
    w.close()
    with pytest.raises(RuntimeError, match="closed"):
        w.write("late\n")


def test_async_line_writer_atexit_flushes_tail(tmp_path):
    """A process that exits without close() still lands its records —
    the atexit sweep drains every live writer."""
    path = tmp_path / "tail.jsonl"
    code = textwrap.dedent(f"""
        from repro.obs.writer import AsyncLineWriter
        w = AsyncLineWriter({str(path)!r})
        for i in range(50):
            w.write(f"rec {{i}}\\n")
        # no close(), no flush(): atexit must land these
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=120)
    assert r.returncode == 0, r.stderr
    assert path.read_text().splitlines() == [f"rec {i}" for i in range(50)]


def test_metrics_sink_surfaces_writer_error(tmp_path):
    """MetricsSink honours the AsyncCheckpointWriter error contract:
    background write failures re-raise on flush()/close()."""
    from repro.launch.engine import MetricsSink

    sink = MetricsSink(str(tmp_path / "metrics.jsonl"), log_every=100,
                       printer=lambda s: None)
    sink.emit({"step": 0, "loss": 1.0})
    sink._writer._fh.close()                   # sabotage
    sink.emit({"step": 1, "loss": 0.5})
    with pytest.raises(RuntimeError, match="background write"):
        sink.flush()
    sink.close()                               # error already consumed


def test_metrics_sink_jsonl_shape(tmp_path):
    from repro.launch.engine import MetricsSink

    path = tmp_path / "metrics.jsonl"
    sink = MetricsSink(str(path), log_every=100, printer=lambda s: None)
    records = [{"step": i, "loss": 1.0 / (i + 1)} for i in range(5)]
    for rec in records:
        sink.emit(rec)
    sink.close()
    assert [json.loads(l) for l in path.read_text().splitlines()] == records


# ---------------------------------------------------------------------------
# Tracker sinks + the context API
# ---------------------------------------------------------------------------


def test_tracker_registry_and_make_tracker(tmp_path, capsys):
    assert {"noop", "memory", "jsonl", "stdout"} <= set(TRACKERS)
    assert obs.make_tracker("noop").enabled is False
    with pytest.raises(KeyError, match="noop"):
        obs.make_tracker("nopo")               # did-you-mean
    with pytest.raises(ValueError, match="path"):
        obs.make_tracker("jsonl")

    printed = []
    t = obs.make_tracker("stdout", printer=printed.append)
    t.event("hello", x=1)
    assert printed == ['[obs] {"kind": "hello", "x": 1}']

    path = tmp_path / "events.jsonl"
    t = obs.make_tracker("jsonl", path=str(path))
    t.event("e", n=2)
    with t.span("work"):
        pass
    t.counter("hits", 3)
    t.close()
    recs = [json.loads(l) for l in path.read_text().splitlines()]
    assert recs[0] == {"kind": "e", "n": 2}
    assert recs[1]["kind"] == "span" and recs[1]["path"] == "work"
    assert t.snapshot()["counters"] == {"hits": 3}


def test_context_noop_until_tracker_set():
    assert not obs.tracing()
    assert obs.span("x") is obs.tracker._NOOP_SPAN
    obs.counter("x")                           # all silently dropped
    obs.event("x", a=1)
    obs.metric("x", 1.0)
    t = obs.InMemoryTracker()
    with obs.use_tracker(t):
        assert obs.tracing()
        obs.counter("x", 2)
    assert not obs.tracing()                   # restored on exit
    assert t.counters == {"x": 2}


def test_span_nesting_builds_slash_paths():
    t = obs.InMemoryTracker()
    with obs.use_tracker(t):
        with obs.span("train.round"):
            with obs.span("optimistic"):
                pass
            with obs.span("fallback"):
                pass
        with obs.span("train.round"):
            with obs.span("optimistic"):
                pass
    spans = t.snapshot()["spans"]
    assert set(spans) == {"train.round", "train.round/optimistic",
                          "train.round/fallback"}
    assert spans["train.round"]["count"] == 2
    assert spans["train.round/optimistic"]["count"] == 2
    assert spans["train.round/fallback"]["count"] == 1
    # exit order: inner spans close (and record) before their parent
    paths = [e["path"] for e in t.events if e["kind"] == "span"]
    assert paths[0] == "train.round/optimistic"
    assert paths.index("train.round/fallback") \
        < paths.index("train.round")


def test_span_nesting_is_thread_local():
    """A span opened on another thread is a root span there — it never
    inherits this thread's open path (the checkpoint-writer case)."""
    t = obs.InMemoryTracker()
    with obs.use_tracker(t):
        with obs.span("main.outer"):
            th = threading.Thread(
                target=lambda: obs.span("worker.write").__enter__()
                .__exit__(None, None, None))
            th.start()
            th.join()
    assert set(t.snapshot()["spans"]) == {"main.outer", "worker.write"}


# ---------------------------------------------------------------------------
# Facade runs: summary.json + span/counter totals
# ---------------------------------------------------------------------------


def _quad_cfg(tmp_path, tracker="memory", steps=3):
    from repro.run import (DataSpec, MeshSpec, RunConfig, ScenarioSpec,
                           TrainSpec)
    return RunConfig(
        name="obs-quad",
        model=None,
        mesh=MeshSpec(devices=0),
        scenario=ScenarioSpec(
            aggregator="mean", f=0,
            data=DataSpec(source="quadratic", dim=16, mu=0.5, L=1.0,
                          noise=1e-3)),
        train=TrainSpec(strategy="replicated", steps=steps, batch=4,
                        optimizer="sgd", lr=0.1, log_every=100),
        obs=ObsSpec(tracker=tracker),
        runs_root=str(tmp_path / "runs"))


def test_train_run_writes_summary_with_span_breakdown(tmp_path):
    from repro.run import facade

    result = facade.train(_quad_cfg(tmp_path, tracker="memory"))
    data = json.load(open(os.path.join(result.run_dir, "summary.json")))
    assert data["kind"] == "train"
    assert data["summary"]["rounds"] == 3
    snap = data["obs"]
    assert snap["counters"]["train.rounds"] == 3
    assert snap["spans"]["train.round"]["count"] == 3
    assert snap["spans"]["train.round/step"]["count"] == 3
    assert snap["spans"]["train.data"]["count"] >= 3


def test_train_run_jsonl_tracker_streams_events(tmp_path):
    from repro.run import facade

    result = facade.train(_quad_cfg(tmp_path, tracker="jsonl"))
    events_path = os.path.join(result.run_dir, "events.jsonl")
    recs = [json.loads(l) for l in open(events_path).read().splitlines()]
    span_paths = {r["path"] for r in recs if r["kind"] == "span"}
    assert "train.round" in span_paths and "train.round/step" in span_paths
    # report renders the finished dir
    text = obs.report(result.run_dir, printer=lambda s: None)
    assert "== repro report: train 'obs-quad'" in text
    assert "span breakdown" in text and "train.round" in text


def test_echo_dp_three_rounds_span_and_counter_totals(tmp_path):
    """The issue's acceptance check: a seeded 3-round echo_dp quadratic
    run records the optimistic/fallback span taxonomy and per-round
    comm counters (in-memory tracker, snapshot via summary.json)."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        os.environ["JAX_PLATFORMS"] = "cpu"
        import json
        from repro.run import (DataSpec, MeshSpec, ObsSpec, RunConfig,
                               ScenarioSpec, TrainSpec, facade)
        from repro.obs import report

        cfg = RunConfig(
            name="obs-echo", model=None, mesh=MeshSpec(devices=8),
            scenario=ScenarioSpec(aggregator="cgc", f=1, echo_k=4,
                                  echo_r=0.9,
                                  data=DataSpec(source="quadratic",
                                                dim=64, noise=1e-3)),
            train=TrainSpec(strategy="echo_dp", steps=3, batch=8,
                            optimizer="sgd", lr=0.02, log_every=100),
            obs=ObsSpec(tracker="memory"),
            runs_root=os.environ["OBS_RUNS_ROOT"])
        result = facade.train(cfg)
        data = json.load(open(os.path.join(result.run_dir,
                                           "summary.json")))
        snap = data["obs"]
        assert snap["counters"]["train.rounds"] == 3
        assert snap["counters"]["comm.rounds"] == 3
        assert snap["counters"]["comm.bits_sent"] \\
            == data["summary"]["bits_sent"]
        spans = snap["spans"]
        assert spans["train.round"]["count"] == 3
        assert "train.round/optimistic" in spans
        assert data["summary"]["echo_rounds"] \\
            == snap["counters"].get("comm.echo_rounds", 0)
        text = report(result.run_dir, printer=lambda s: None)
        assert "echo rounds" in text and "optimistic" in text
        print("OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env["OBS_RUNS_ROOT"] = str(tmp_path / "runs")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=600)
    assert r.returncode == 0, f"{r.stdout}\n{r.stderr}"
    assert "OK" in r.stdout


# ---------------------------------------------------------------------------
# repro report: golden rendering + CLI
# ---------------------------------------------------------------------------

_GOLDEN_SUMMARY = {
    "kind": "train",
    "summary": {"rounds": 2, "wall_s": 4.0, "first_loss": 1.0,
                "final_loss": 0.5, "echo_rounds": 1, "echo_rate": 0.5,
                "bits_sent": 1000.0, "bits_baseline": 4000.0,
                "bits_saving": 0.75},
    "obs": {"counters": {"train.rounds": 2, "comm.rounds": 2},
            "metrics": {"obs_overhead": 0.0125},
            "spans": {"train.round": {"count": 2, "total_s": 3.0},
                      "train.round/step": {"count": 2, "total_s": 2.0},
                      "train.data": {"count": 2, "total_s": 1.0}}},
}

_GOLDEN_TEXT = """\
== repro report: train 'golden' ==
  rounds        2  (wall 4.0s)
  rounds/s      0.50
  loss          1 -> 0.5
  echo rounds   1/2 (50.0%)
  bits sent     1000 vs baseline 4000 (75.0% saved)
-- span breakdown (share of root spans) --
  train.data    25.0%  total     1.00s  n=2      mean 500.00ms
  train.round   75.0%  total     3.00s  n=2      mean 1.50s
    step        50.0%  total     2.00s  n=2      mean 1.00s
-- counters --
  comm.rounds   2
  train.rounds  2
-- metrics --
  obs_overhead  0.0125"""


def _golden_run_dir(tmp_path):
    with open(tmp_path / "summary.json", "w") as fh:
        json.dump(_GOLDEN_SUMMARY, fh)
    with open(tmp_path / "config.json", "w") as fh:
        json.dump({"name": "golden"}, fh)
    return str(tmp_path)


def test_report_golden(tmp_path):
    run_dir = _golden_run_dir(tmp_path)
    assert obs.render(obs.load_run(run_dir)) == _GOLDEN_TEXT


def test_report_cli(tmp_path, capsys):
    from repro.__main__ import main

    run_dir = _golden_run_dir(tmp_path)
    assert main(["report", run_dir]) == 0
    out = capsys.readouterr().out
    assert "== repro report: train 'golden'" in out
    assert "75.0% saved" in out and "span breakdown" in out


def test_report_missing_summary_is_friendly(tmp_path):
    from repro.__main__ import main

    with pytest.raises(FileNotFoundError, match="summary.json"):
        obs.load_run(str(tmp_path))
    with pytest.raises(SystemExit, match="error: "):
        main(["report", str(tmp_path)])
