"""repro.comm: wire codecs, broadcast channels, the bit ledger, and
their integration with the protocol simulation and the echo-DP driver
(DESIGN.md §9)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import comm
from repro.comm import (CommConfig, CommLedger, DEFAULT_COMM, EchoMsg,
                        IdealBroadcast, Int8Codec, LossyBroadcast,
                        MeteredBroadcast, RawGradientMsg, SilentMsg,
                        TopKCodec, payload_bits, raw_round_bits, resolve)
from repro.core import byzantine, costfns, protocol
from repro.core.types import ProtocolConfig, echo_bits, raw_bits

ALL_CODECS = (comm.Fp32Codec(), comm.Bf16Codec(), Int8Codec(),
              TopKCodec(k=8), comm.Sign1Codec())


def _setup(n=12, d=24, seed=0, r=0.3):
    g = jnp.tile(jax.random.normal(jax.random.PRNGKey(seed), (d,)), (n, 1))
    cfg = ProtocolConfig(n=n, f=1, r=r, eta=0.01)
    plan = byzantine.no_attack(jax.random.PRNGKey(1), jnp.zeros((n, d)),
                               jnp.zeros(n, bool), None, None)
    return cfg, g, plan


# ---------------------------------------------------------------------------
# Codecs
# ---------------------------------------------------------------------------


def test_fp32_codec_is_the_closed_form():
    """The ideal codec IS core.types.raw_bits/echo_bits, bit for bit —
    the codecs replaced the closed-form constants as source of truth."""
    c = DEFAULT_COMM.codec
    for d in (1, 50, 1000):
        assert c.raw_msg_bits(d) == raw_bits(d) == 32 * d
    for n in (4, 10, 64):
        for rank in (0, 1, n // 2, n):
            assert c.echo_msg_bits(n, rank) == echo_bits(n, rank) \
                == 32 * (1 + rank) + n
    # the traced-rank path agrees with the python-int path
    got = jax.jit(lambda r: c.echo_msg_bits(10, r))(jnp.int32(3))
    assert int(got) == echo_bits(10, 3)


@pytest.mark.parametrize("codec", ALL_CODECS, ids=lambda c: c.name)
def test_codec_bit_size_is_honest(codec):
    """The advertised vector_bits equals the actual encoded payload."""
    for m in (1, 5, 37, 256):
        v = jax.random.normal(jax.random.PRNGKey(m), (m,))
        assert payload_bits(codec.encode(v)) == int(codec.vector_bits(m))


@pytest.mark.parametrize("codec", ALL_CODECS, ids=lambda c: c.name)
def test_codec_roundtrip_error_bounds(codec):
    v = jax.random.normal(jax.random.PRNGKey(7), (64,))
    rt = codec.roundtrip(v)
    assert rt.shape == v.shape and rt.dtype == jnp.float32
    err = np.abs(np.asarray(rt) - np.asarray(v))
    if codec.lossless:
        assert np.array_equal(np.asarray(rt), np.asarray(v))
    elif codec.name == "bf16":
        assert np.all(err <= np.abs(np.asarray(v)) / 128 + 1e-7)
    elif codec.name == "int8":
        scale = float(np.max(np.abs(np.asarray(v)))) / 127.0
        assert np.all(err <= scale * 0.5 + 1e-7)
    elif codec.name == "topk":
        # kept entries are exact, dropped entries decode to zero
        rt_np, v_np = np.asarray(rt), np.asarray(v)
        kept = rt_np != 0.0
        assert kept.sum() <= codec.k
        np.testing.assert_array_equal(rt_np[kept], v_np[kept])
        # the k largest magnitudes all survived
        order = np.argsort(-np.abs(v_np))[:codec.k]
        assert kept[order].all()
    elif codec.name == "sign1":
        # every sign exact, every magnitude the shared mean-|v| scale
        rt_np, v_np = np.asarray(rt), np.asarray(v)
        assert np.array_equal(np.sign(rt_np), np.where(v_np >= 0, 1.0, -1.0))
        np.testing.assert_allclose(np.abs(rt_np), np.mean(np.abs(v_np)),
                                   rtol=1e-6)


def test_sign1_scalar_is_exact_and_on_the_ladder():
    """A length-1 vector roundtrips exactly (the echo norm-ratio scalar
    survives sign compression) and sign1 is the ladder's deepest rung."""
    from repro.comm.policy import CODEC_LADDER
    codec = comm.Sign1Codec()
    for x in (3.25, -2.5, 0.0):
        assert float(codec.roundtrip(jnp.asarray([x]))[0]) == x
    assert CODEC_LADDER[-1] == "sign1"
    # 32x payload compression for byte-aligned d, plus the fp32 scale
    assert int(codec.vector_bits(256)) == 256 + 32


def test_typed_messages_price_like_the_codec():
    n, d = 10, 40
    c = DEFAULT_COMM.codec
    raw = RawGradientMsg(grad=jnp.ones((d,)))
    assert raw.bits(c, n) == raw_bits(d)
    ref = jnp.zeros((n,), bool).at[jnp.array([0, 3, 4])].set(True)
    echo = EchoMsg(ratio=jnp.float32(1.5),
                   coeffs=jnp.ones((n,)) * ref, ref=ref)
    assert echo.bits(c, n) == echo_bits(n, 3)
    assert SilentMsg().bits(c, n) == 0
    # the dense payload (ratio + referenced coefficients) prices the
    # float part of the message
    assert payload_bits(echo.payload(c)) == 32 * (1 + 3)


def test_messages_from_round_decodes_the_dense_buffers():
    from repro.core.types import MSG_ECHO, MSG_RAW, MSG_SILENT, RoundMessages
    n, d = 4, 6
    rm = RoundMessages(
        kind=jnp.array([MSG_RAW, MSG_ECHO, MSG_SILENT, MSG_RAW]),
        raw=jnp.arange(n * d, dtype=jnp.float32).reshape(n, d),
        echo_k=jnp.ones((n,)),
        echo_x=jnp.zeros((n, n)).at[1, 0].set(2.0),
        echo_ref=jnp.zeros((n, n), bool).at[1, 0].set(True))
    msgs = comm.messages_from_round(rm)
    assert [type(m) for m in msgs] == [RawGradientMsg, EchoMsg, SilentMsg,
                                       RawGradientMsg]
    assert msgs[1].bits(DEFAULT_COMM.codec, n) == echo_bits(n, 1)


# ---------------------------------------------------------------------------
# Channels in the protocol slot loop
# ---------------------------------------------------------------------------


def test_ideal_channel_is_bitwise_todays_protocol():
    """comm=None, comm=DEFAULT_COMM and an explicitly-built ideal/fp32
    config all produce identical results — the redesign is invisible
    until a scenario opts in."""
    cfg, g, plan = _setup()
    byz = jnp.zeros(cfg.n, bool)
    a = protocol.communication_phase(cfg, g, byz, plan)
    b = protocol.communication_phase(cfg, g, byz, plan, comm=DEFAULT_COMM)
    c = protocol.communication_phase(cfg, g, byz, plan,
                                     comm=CommConfig(IdealBroadcast(),
                                                     comm.Fp32Codec()))
    for x, y in ((a, b), (a, c)):
        np.testing.assert_array_equal(np.asarray(x[0].G), np.asarray(y[0].G))
        np.testing.assert_array_equal(np.asarray(x[1].bits_sent),
                                      np.asarray(y[1].bits_sent))


def test_lossy_channel_seeded_and_shrinks_reference_set():
    cfg, g, plan = _setup(n=16)
    byz = jnp.zeros(cfg.n, bool)
    lossy = CommConfig(channel=LossyBroadcast(drop_prob=0.5, seed=3))
    _, s1 = protocol.communication_phase(cfg, g, byz, plan, comm=lossy)
    _, s2 = protocol.communication_phase(cfg, g, byz, plan, comm=lossy)
    # deterministic under the configured seed
    np.testing.assert_array_equal(np.asarray(s1.bits_sent),
                                  np.asarray(s2.bits_sent))
    # a different round key moves the fades
    other = protocol.communication_phase(
        cfg, g, byz, plan, comm=lossy,
        chan_key=jax.random.PRNGKey(99))[1]
    assert not np.array_equal(np.asarray(s1.bits_sent),
                              np.asarray(other.bits_sent))
    # identical gradients: ideally rank_R == 1 with slot 0 raw; heavy
    # fading makes later workers raw-retransmit (echo fallback costs
    # echo + raw bits) and faded raws never enter R
    _, ideal_stats = protocol.communication_phase(cfg, g, byz, plan)
    assert int(s1.n_echo) < int(ideal_stats.n_echo)
    assert float(jnp.sum(s1.bits_sent)) > float(
        jnp.sum(ideal_stats.bits_sent))
    # every slot was still received by the server (reliability assumption)
    assert bool(jnp.all(protocol.communication_phase(
        cfg, g, byz, plan, comm=lossy)[0].received))


def test_metered_channel_budget_is_hard():
    cfg, g, plan = _setup(n=10, d=50)
    byz = jnp.zeros(cfg.n, bool)
    budget = int(1.5 * raw_bits(50))          # fits the slot-0 raw + echoes
    metered = CommConfig(channel=MeteredBroadcast(budget_bits=budget))
    server, stats = protocol.communication_phase(cfg, g, byz, plan,
                                                 comm=metered)
    assert float(jnp.sum(stats.bits_sent)) <= budget
    # an impossible budget silences everyone
    tiny = CommConfig(channel=MeteredBroadcast(budget_bits=8))
    server2, stats2 = protocol.communication_phase(cfg, g, byz, plan,
                                                   comm=tiny)
    assert float(jnp.sum(stats2.bits_sent)) == 0.0
    assert not bool(jnp.any(server2.received))


def test_quantized_echo_keeps_norm_invariant():
    """int8 wire coding: the sender recomputes the norm ratio against
    the coefficients AS TRANSMITTED (echo.wire_norm_ratio), so the
    paper's ||g~|| == ||g|| reconstruction invariant survives
    quantization."""
    n, d = 10, 30
    key = jax.random.PRNGKey(3)
    base = jax.random.normal(key, (d,))
    grads = base + 0.05 * jax.random.normal(jax.random.fold_in(key, 1),
                                            (n, d))
    cfg = ProtocolConfig(n=n, f=1, r=0.5, eta=0.01)
    plan = byzantine.no_attack(key, jnp.zeros((n, d)), jnp.zeros(n, bool),
                               None, None)
    int8 = CommConfig(codec=Int8Codec())
    server, stats = protocol.communication_phase(cfg, grads,
                                                 jnp.zeros(n, bool), plan,
                                                 comm=int8)
    assert int(stats.n_echo) >= n // 2
    gn = np.linalg.norm(np.asarray(grads), axis=1)
    rn = np.linalg.norm(np.asarray(server.G), axis=1)
    np.testing.assert_allclose(rn, gn, rtol=2e-3)
    # and the echo slots got int8 prices, cheaper than fp32 echoes
    echo_slots = np.asarray(stats.echo_sent)
    fp32_cost = np.asarray([echo_bits(n, 1)] * n, dtype=np.float32)
    assert np.all(np.asarray(stats.bits_sent)[echo_slots]
                  < fp32_cost[echo_slots])


# ---------------------------------------------------------------------------
# Ledger
# ---------------------------------------------------------------------------


def test_ledger_matches_closed_form_on_ideal_channel():
    """Protocol simulation reporting: the ledger's cumulative bits are
    exactly the trace's (closed-form fp32) bits, and the baseline is the
    paper's n * 32 * d per round."""
    key = jax.random.PRNGKey(0)
    d, n, rounds = 16, 8, 12
    cost = costfns.quadratic(key, d=d, sigma=0.05)
    cfg = ProtocolConfig(n=n, f=1, r=0.5, eta=0.05)
    ledger = CommLedger()
    trace = protocol.run_training(cfg, cost, byzantine.no_attack,
                                  jnp.zeros(n, bool), key, jnp.ones(d),
                                  rounds=rounds, ledger=ledger)
    assert ledger.rounds == rounds
    assert ledger.bits_sent == int(np.asarray(trace["bits"]).sum())
    assert ledger.bits_baseline == rounds * n * raw_bits(d)
    assert ledger.bits_sent < ledger.bits_baseline
    assert 0.0 < ledger.bits_saving < 1.0
    assert ledger.echo_rounds == int((np.asarray(trace["n_echo"]) > 0).sum())
    s = ledger.summary()
    assert s["bits_sent"] == ledger.bits_sent
    assert s["echo_rate"] == ledger.echo_rounds / rounds


def test_round_cost_helpers():
    from repro.dist.echo_dp import round_comm_bits
    c = DEFAULT_COMM.codec
    n, d, k = 8, 128, 4
    assert raw_round_bits(c, n, d) == n * raw_bits(d)
    assert comm.echo_round_bits(c, n, k) == n * int(echo_bits(n, k))
    assert round_comm_bits(c, n, d, k, all_echo=True) \
        == n * int(echo_bits(n, k))
    assert round_comm_bits(c, n, d, k, all_echo=False) \
        == n * int(echo_bits(n, k)) + n * raw_bits(d)
    assert round_comm_bits(c, n, d, k, all_echo=False, attempted=False) \
        == n * raw_bits(d)


# ---------------------------------------------------------------------------
# Config surface: resolve + registries
# ---------------------------------------------------------------------------


def test_resolve_builds_from_the_registries():
    from repro.run import CommSpec, available

    assert resolve(None) is DEFAULT_COMM
    got = resolve(CommSpec())
    assert got.channel.name == "ideal" and got.codec.name == "fp32"
    got = resolve(CommSpec(channel="lossy", codec="topk", drop_prob=0.25,
                           seed=7, topk=16))
    assert isinstance(got.channel, LossyBroadcast)
    assert got.channel.drop_prob == 0.25 and got.channel.seed == 7
    assert isinstance(got.codec, TopKCodec) and got.codec.k == 16
    got = resolve(CommSpec(channel="metered", budget_bits=1024))
    assert isinstance(got.channel, MeteredBroadcast)
    assert got.channel.budget_bits == 1024
    # unknown names: ValueError with the known alternatives (CLI-friendly)
    with pytest.raises(ValueError, match="fp32"):
        resolve(CommSpec(codec="fp64"))
    with pytest.raises(ValueError, match="lossy"):
        resolve(CommSpec(channel="fading"))
    with pytest.raises(ValueError, match="drop_prob"):
        resolve(CommSpec(channel="lossy", drop_prob=1.5))
    # knobs inconsistent with the selected channel are rejected, not
    # silently ignored (a half-specified lossy scenario would otherwise
    # run ideal while its config.json claims losses)
    with pytest.raises(ValueError, match="channel=lossy"):
        resolve(CommSpec(drop_prob=0.1))
    with pytest.raises(ValueError, match="channel=metered"):
        resolve(CommSpec(channel="lossy", drop_prob=0.1, budget_bits=64))
    names = available()
    assert names["codecs"] == ["bf16", "fp32", "int8", "sign1", "topk"]
    assert names["channels"] == ["ideal", "lossy", "metered", "relay"]


def test_comm_config_is_jit_static():
    cc = CommConfig(channel=LossyBroadcast(drop_prob=0.3, seed=1),
                    codec=Int8Codec())
    assert hash(cc) == hash(CommConfig(LossyBroadcast(drop_prob=0.3, seed=1),
                                       Int8Codec()))
    g = jax.jit(lambda x, comm: comm.codec.roundtrip(x),
                static_argnames=("comm",))
    a = g(jnp.arange(4.0), cc)
    b = g(jnp.arange(4.0), cc)                 # same static key: cache hit
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# End to end: the echo-DP trainer on a lossy, quantized scenario
# ---------------------------------------------------------------------------


def _run_subprocess(body: str):
    """Run a snippet under 8 fake CPU devices; raise on failure."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        os.environ["JAX_PLATFORMS"] = "cpu"
    """) + textwrap.dedent(body)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=600)
    if r.returncode != 0:
        raise AssertionError(f"subprocess failed:\n{r.stdout}\n{r.stderr}")
    return r.stdout


JOB = os.path.join(os.path.dirname(__file__), "..", "experiments", "jobs",
                   "lossy_echo_cgc.json")


def test_lossy_job_end_to_end_reproducible(tmp_path):
    """The acceptance scenario: the lossy/int8 quadratic job runs end to
    end through the train facade with a seeded, replayable bits
    trajectory, and fades force raw fallbacks the ledger prices."""
    out = _run_subprocess(f"""
        import json
        from repro import run

        base = run.RunConfig.load({str(JOB)!r})
        base = run.apply_overrides(
            base, ["train.steps=6", "runs_root=" + {str(tmp_path)!r}])

        results = [run.train(base) for _ in range(2)]
        trajs = []
        for res in results:
            recs = [json.loads(l) for l in
                    open(res.metrics_path).read().splitlines()]
            trajs.append([(r["bits"], r["bits_cumulative"],
                           r["all_echo"], r.get("echo_drops", 0))
                          for r in recs])
        assert trajs[0] == trajs[1], trajs     # seeded: replays exactly
        bits = [t[0] for t in trajs[0]]
        assert len(bits) == 6
        s = results[0].summary
        assert s["bits_sent"] == trajs[0][-1][1]
        # int8 echo rounds are cheaper than the all-raw fp32 baseline
        assert s["bits_sent"] < s["bits_baseline"]
        print("OK", [t[2] for t in trajs[0]], s["bits_saving"])
    """)
    assert out.startswith("OK") or "OK" in out


def test_trainer_metered_channel_skips_unaffordable_echo():
    """A metered channel whose budget can't fit one echo round makes the
    driver skip the optimistic attempt and go straight to raw."""
    _run_subprocess("""
        import jax, jax.numpy as jnp
        from repro.comm import CommConfig, MeteredBroadcast
        from repro.core import costfns
        from repro.launch.engine import (EchoDpStrategy, Trainer,
                                         TrainerConfig, TrainSettings)
        from repro.optim import sgd

        n, d, K = 8, 64, 4
        cost = costfns.quadratic(jax.random.PRNGKey(0), d=d, mu=0.5, L=1.0,
                                 sigma=0.0)

        def loss_fn(values, batch):
            w = values["w"]
            return cost.value(w) + w @ jnp.mean(batch["eps"], 0), {}

        mesh = jax.make_mesh((8,), ("data",))
        comm = CommConfig(channel=MeteredBroadcast(budget_bits=16))
        settings = TrainSettings(aggregator="cgc", f=1, echo_k=K,
                                 echo_r=0.9, comm=comm)
        tr = Trainer(EchoDpStrategy(loss_fn=loss_fn), None, sgd(0.02),
                     settings, mesh, n, TrainerConfig(log_every=100),
                     printer=lambda s: None)
        state = tr.init_state({"w": jnp.ones((d,)) * 2.0})
        with jax.set_mesh(mesh):
            for s in range(3):
                key = jax.random.fold_in(jax.random.PRNGKey(7), s)
                batch = {"eps": 1e-4 * jax.random.normal(key, (n, d))}
                state, rec = tr.run_round(state, batch)
                assert rec["comm_refused"] and not rec["all_echo"]
        from repro.core.types import raw_bits
        assert tr.bits_sent == 3 * n * raw_bits(d)   # raw only, no echoes
        print("OK")
    """)
