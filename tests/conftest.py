import os

# Tests run on the single real CPU device; only launch/dryrun.py forces the
# 512-device placeholder topology (see the system brief).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import pytest


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
