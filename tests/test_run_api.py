"""The declarative job API end to end (repro.run facades + the unified
``python -m repro`` CLI + legacy-shim equivalence). Everything here runs
on the single in-process CPU device (mesh.devices=0)."""
import json
import os

import pytest

from repro.run import (DataSpec, MeshSpec, ModelSpec, RunConfig,
                       ScenarioSpec, TrainSpec, facade)


def _quad_cfg(tmp_path, steps=3, name="quad"):
    return RunConfig(
        name=name,
        model=None,
        mesh=MeshSpec(devices=0),
        scenario=ScenarioSpec(
            aggregator="mean", f=0,
            data=DataSpec(source="quadratic", dim=16, mu=0.5, L=1.0,
                          noise=1e-3)),
        train=TrainSpec(strategy="replicated", steps=steps,
                        batch=4, optimizer="sgd", lr=0.1, log_every=100),
        runs_root=str(tmp_path / "runs"))


def test_train_facade_quadratic_and_run_dir(tmp_path, capsys):
    cfg = _quad_cfg(tmp_path)
    result = facade.train(cfg)
    assert result.config == cfg
    assert result.summary["rounds"] == 3
    assert result.final_loss < result.first_loss   # SGD descends
    # per-run directory: exact config next to the metrics it produced
    assert os.path.dirname(result.metrics_path) == result.run_dir
    saved = RunConfig.load(os.path.join(result.run_dir, "config.json"))
    assert saved == cfg
    records = [json.loads(l) for l in
               open(result.metrics_path).read().splitlines()]
    assert len(records) == 3 and records[0]["step"] == 0
    assert records[0]["loss"] == result.first_loss


def test_run_dirs_never_collide(tmp_path):
    cfg = _quad_cfg(tmp_path, steps=1)
    a = facade.train(cfg)
    b = facade.train(cfg)             # same config, same second is fine
    assert a.run_dir != b.run_dir
    assert os.path.exists(os.path.join(a.run_dir, "metrics.jsonl"))
    assert os.path.exists(os.path.join(b.run_dir, "metrics.jsonl"))


def test_train_facade_validation_errors(tmp_path):
    with pytest.raises(ValueError, match="no `train` section"):
        facade.train(RunConfig(train=None))
    with pytest.raises(ValueError, match="bogus.*known"):
        facade.train(RunConfig(mesh=MeshSpec(devices=0),
                               train=TrainSpec(strategy="bogus"),
                               runs_root=str(tmp_path)))
    with pytest.raises(ValueError, match="optimizer"):
        facade.train(RunConfig(mesh=MeshSpec(devices=0),
                               train=TrainSpec(optimizer="lion"),
                               runs_root=str(tmp_path)))
    bad = _quad_cfg(tmp_path)
    with pytest.raises(ValueError, match="model"):
        facade.train(RunConfig(model=None, mesh=MeshSpec(devices=0),
                               train=TrainSpec(),
                               runs_root=str(tmp_path)))
    assert bad.model is None          # quadratic path needs no model


def test_cli_show_and_list(tmp_path, capsys):
    from repro.__main__ import main

    job = tmp_path / "job.json"
    _quad_cfg(tmp_path).save(str(job))
    assert main(["show", "--config", str(job),
                 "--set", "train.steps=9"]) == 0
    out = capsys.readouterr().out
    shown = RunConfig.from_json(out)
    assert shown.train.steps == 9

    assert main(["list"]) == 0
    out = capsys.readouterr().out
    assert "train_strategies: echo_dp, fsdp, replicated" in out
    assert "attacks:" in out and "sign_flip" in out


def test_cli_friendly_errors(tmp_path):
    """Bad --set paths, bad job files and missing files exit with the
    did-you-mean message, not a traceback."""
    from repro.__main__ import main

    job = tmp_path / "job.json"
    _quad_cfg(tmp_path).save(str(job))
    with pytest.raises(SystemExit, match="no field 'stepz'"):
        main(["train", "--config", str(job), "--set", "train.stepz=3"])
    with pytest.raises(SystemExit, match="error:"):
        main(["show", "--config", str(tmp_path / "nope.json")])
    bad = tmp_path / "bad.json"
    bad.write_text('{"schema_version": 1, "trian": {}}')
    with pytest.raises(SystemExit, match="train"):
        main(["show", "--config", str(bad)])


def test_cli_train_runs_job_file(tmp_path, capsys):
    from repro.__main__ import main

    job = tmp_path / "job.json"
    _quad_cfg(tmp_path).save(str(job))
    assert main(["train", "--config", str(job),
                 "--set", "train.steps=2"]) == 0
    out = capsys.readouterr().out
    assert "final loss" in out
    runs = os.listdir(tmp_path / "runs")
    assert len(runs) == 1
    saved = RunConfig.load(str(tmp_path / "runs" / runs[0] /
                               "config.json"))
    assert saved.train.steps == 2     # the override is what actually ran


# ---------------------------------------------------------------------------
# Legacy shim: single DeprecationWarning + bitwise-identical first step
# ---------------------------------------------------------------------------

_LEGACY_FLAGS = ["--arch", "qwen3-0.6b", "--smoke", "--steps", "1",
                 "--devices", "0", "--batch", "4", "--seq", "32",
                 "--aggregator", "mean"]


def _first_record(path):
    return json.loads(open(path).read().splitlines()[0])


def test_legacy_train_flags_bitwise_equal_config_path(tmp_path,
                                                      monkeypatch):
    """The deprecated flag CLI and the config-driven CLI run the same
    jitted step: first-step metrics are bitwise identical."""
    from repro.__main__ import main as repro_main
    from repro.launch import train as legacy

    monkeypatch.chdir(tmp_path)       # legacy default runs_root is CWD-rel
    legacy_metrics = tmp_path / "legacy.jsonl"
    with pytest.warns(DeprecationWarning):
        legacy.main(_LEGACY_FLAGS + ["--metrics", str(legacy_metrics)])

    cfg_metrics = tmp_path / "config.jsonl"
    job = tmp_path / "job.json"
    cfg = RunConfig(
        name="equivalence",
        model=ModelSpec(arch="qwen3-0.6b", smoke=True),
        mesh=MeshSpec(devices=0),
        scenario=ScenarioSpec(aggregator="mean"),
        train=TrainSpec(strategy="replicated", steps=1, batch=4, seq=32,
                        metrics_path=str(cfg_metrics)),
        runs_root=str(tmp_path / "runs"))
    cfg.save(str(job))
    assert repro_main(["train", "--config", str(job)]) == 0

    a, b = _first_record(legacy_metrics), _first_record(cfg_metrics)
    assert a["loss"] == b["loss"]                  # bitwise (json repr)
    assert a["bits"] == b["bits"] and a["step"] == b["step"]


def test_legacy_adapter_equals_hand_built_config():
    """config_from_flags maps the default flag namespace onto the same
    tree a job file would load (the adapter IS the compatibility
    contract)."""
    import argparse

    from repro.launch.train import config_from_flags

    ns = argparse.Namespace(
        arch="qwen3-0.6b", smoke=True, strategy="echo_dp", steps=4,
        batch=8, seq=64, lr=3e-4, aggregator="cgc", f=1, n_byz=0,
        byz_mode="sign_flip", microbatches=1, clip_norm=0.0, echo_k=4,
        echo_r=0.9, devices=8, ckpt_dir=None, ckpt_every=0, resume=False,
        metrics=None, log_every=5)
    cfg = config_from_flags(ns)
    assert cfg.model == ModelSpec(arch="qwen3-0.6b", smoke=True)
    assert cfg.train.strategy == "echo_dp" and cfg.scenario.f == 1
    assert RunConfig.from_json(cfg.to_json()) == cfg


def test_legacy_warning_fires_exactly_once(monkeypatch):
    import warnings

    monkeypatch.setattr(facade, "_DEPRECATION_WARNED", set())
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        facade.warn_legacy("repro.launch.train", "python -m repro train")
        facade.warn_legacy("repro.launch.train", "python -m repro train")
        facade.warn_legacy("repro.launch.serve", "python -m repro serve")
    deps = [w for w in caught if w.category is DeprecationWarning]
    assert len(deps) == 2             # once per entry point, not per call
    assert "python -m repro train" in str(deps[0].message)
