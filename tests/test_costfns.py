"""Cost-function oracles: constants (L, mu), optimality, Assumptions 4/5."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import costfns


def test_quadratic_constants_and_optimum():
    key = jax.random.PRNGKey(0)
    c = costfns.quadratic(key, d=24, mu=0.5, L=2.0, sigma=0.1)
    assert c.mu == 0.5 and c.L == 2.0
    # grad(w*) = 0 and Q(w*) minimal
    assert float(jnp.linalg.norm(c.grad(c.w_star))) < 1e-4
    w = c.w_star + 0.1
    assert float(c.value(w)) > float(c.value(c.w_star))
    # L-Lipschitz and mu-strong convexity on random pairs (Assumptions 2/3)
    k1, k2 = jax.random.split(key)
    w1 = jax.random.normal(k1, (24,))
    w2 = jax.random.normal(k2, (24,))
    dg = c.grad(w1) - c.grad(w2)
    dw = w1 - w2
    assert float(jnp.linalg.norm(dg)) <= c.L * float(
        jnp.linalg.norm(dw)) * (1 + 1e-5)
    assert float(dg @ dw) >= c.mu * float(dw @ dw) * (1 - 1e-5)


def test_quadratic_stochastic_assumptions():
    key = jax.random.PRNGKey(1)
    sigma = 0.2
    c = costfns.quadratic(key, d=16, sigma=sigma)
    w = jnp.ones(16) * 2.0
    g = c.grad(w)
    keys = jax.random.split(key, 4000)
    gs = jax.vmap(lambda k: c.stoch_grad(k, w))(keys)
    # Assumption 4: unbiased
    bias = jnp.linalg.norm(jnp.mean(gs, 0) - g) / jnp.linalg.norm(g)
    assert float(bias) < 0.02
    # Assumption 5 with equality by construction
    rel = jnp.mean(jnp.sum((gs - g) ** 2, -1)) / jnp.sum(g ** 2)
    assert float(rel) == pytest.approx(sigma ** 2, rel=0.1)


def test_least_squares_optimum_and_sigma():
    key = jax.random.PRNGKey(2)
    c = costfns.least_squares(key, n_data=256, d=10, batch=16)
    assert float(jnp.linalg.norm(c.grad(c.w_star))) < 1e-3
    assert c.L >= c.mu > 0
    assert c.sigma > 0


def test_logistic_newton_optimum():
    key = jax.random.PRNGKey(3)
    c = costfns.logistic_l2(key, n_data=200, d=8, l2=0.1)
    assert float(jnp.linalg.norm(c.grad(c.w_star))) < 1e-4
    assert c.mu == pytest.approx(0.1)
