"""SLO scheduling + prefix sharing, end to end (DESIGN.md §11).

The anchor is the equivalence test: with the default knobs (one tenant,
priority 0, no deadline, chunking off) the SLO scheduler admits in
arrival order and the engine's greedy outputs are bitwise the per-request
contiguous-cache oracle — the new policy machinery is provably inert
until a knob moves. The policy tests then move one knob at a time
(priority, deadline, tenant, pool pressure, chunk, sharing) and check
the ordering or savings it buys, always re-asserting bitwise-equal
outputs: scheduling and sharing decide WHEN tokens compute, never WHAT
they compute.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import model as M
from repro.models.nn import split_params
from repro.serve import ServeConfig, ServeEngine

CFG = reduced(get_config("qwen3-0.6b"))
KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, KEY)


def _ref_greedy(params, prompt, gen):
    """Per-request contiguous-cache greedy decode (the serving oracle)."""
    values = split_params(params)[0]
    cache, _ = split_params(M.init_cache(CFG, 1, len(prompt) + gen))
    step = jax.jit(lambda v, c, t, p: M.decode_step(v, CFG, c, t, p))
    for t, tok in enumerate(prompt):
        logits, cache = step(values, cache,
                             jnp.asarray([[tok]], jnp.int32),
                             jnp.asarray([t], jnp.int32))
    out = [int(jnp.argmax(logits[0]))]
    for i in range(gen - 1):
        logits, cache = step(values, cache,
                             jnp.asarray([[out[-1]]], jnp.int32),
                             jnp.asarray([len(prompt) + i], jnp.int32))
        out.append(int(jnp.argmax(logits[0])))
    return out


def _engine(params, **over):
    kw = dict(max_batch=2, page_size=4, num_pages=64,
              max_blocks_per_seq=8, decode_quantum=2, log_every=10 ** 9)
    kw.update(over)
    return ServeEngine(CFG, params, ServeConfig(**kw))


def _prompt(seed, n):
    rng = np.random.default_rng(seed)
    return rng.integers(0, CFG.vocab_size, size=n).tolist()


# ---------------------------------------------------------------------------
# Equivalence: default knobs == FCFS, outputs == oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("chunk", [0, 64])
def test_default_knobs_are_fcfs_and_match_oracle(params, chunk):
    """One tenant / one class / chunk off-or-huge: admission IS arrival
    order and outputs ARE the per-request oracle, bitwise."""
    prompts = [_prompt(s, n) for s, n in
               zip(range(5), (9, 3, 14, 6, 11))]
    eng = _engine(params, prefill_chunk=chunk)
    reqs = [eng.submit(p, max_new=5) for p in prompts]
    eng.drain(max_steps=300)
    assert eng.sched.admit_order == [r.rid for r in reqs]
    for r, p in zip(reqs, prompts):
        assert r.tokens == _ref_greedy(params, p, 5)
    eng.sched.check_invariants()


# ---------------------------------------------------------------------------
# SLO policy: priority classes, deadlines, tenant fairness
# ---------------------------------------------------------------------------


def test_priority_class_admits_before_arrival_order(params):
    eng = _engine(params, max_batch=1)
    lo = eng.submit(_prompt(0, 6), max_new=3, priority=5)
    hi = eng.submit(_prompt(1, 6), max_new=3, priority=0)
    eng.drain(max_steps=200)
    assert eng.sched.admit_order == [hi.rid, lo.rid]
    assert lo.tokens == _ref_greedy(params, _prompt(0, 6), 3)


def test_earliest_deadline_first_within_class(params):
    eng = _engine(params, max_batch=1)
    lax = eng.submit(_prompt(2, 6), max_new=3, deadline_s=30.0)
    tight = eng.submit(_prompt(3, 6), max_new=3, deadline_s=1e-3)
    none = eng.submit(_prompt(4, 6), max_new=3)   # no deadline: last
    eng.drain(max_steps=200)
    assert eng.sched.admit_order == [tight.rid, lax.rid, none.rid]


def test_tenant_fairness_interleaves_served_tokens(params):
    eng = _engine(params, max_batch=1)
    a0 = eng.submit(_prompt(5, 6), max_new=3, tenant="a")
    a1 = eng.submit(_prompt(6, 6), max_new=3, tenant="a")
    b0 = eng.submit(_prompt(7, 6), max_new=3, tenant="b")
    eng.drain(max_steps=300)
    # once a0's tokens are charged to tenant a, the unserved tenant b
    # jumps the same-class queue ahead of a1
    assert eng.sched.admit_order == [a0.rid, b0.rid, a1.rid]
    assert eng.sched.tenant_served["a"] > 0
    assert b0.tokens == _ref_greedy(params, _prompt(7, 6), 3)


def test_preemption_evicts_lower_class_and_recovers(params):
    """Under pool pressure the priority-5 lane is evicted, the
    priority-0 lane never is, and both still finish with oracle-exact
    outputs (recompute on re-admission)."""
    eng = _engine(params, max_batch=2, page_size=4, num_pages=6,
                  max_blocks_per_seq=4, decode_quantum=1,
                  prefix_cache=False)
    hi = eng.submit(_prompt(8, 8), max_new=8, priority=0)
    lo = eng.submit(_prompt(9, 8), max_new=8, priority=5)
    eng.drain(max_steps=400)
    assert lo.n_preempt >= 1 and hi.n_preempt == 0
    assert hi.tokens == _ref_greedy(params, _prompt(8, 8), 8)
    assert lo.tokens == _ref_greedy(params, _prompt(9, 8), 8)
    pool = eng.kv.allocator
    assert pool.num_free == pool.capacity


# ---------------------------------------------------------------------------
# Chunked prefill + CoW prefix sharing, end to end
# ---------------------------------------------------------------------------


def test_chunked_prefill_spreads_steps_and_matches_oracle(params):
    long, short = _prompt(10, 26), _prompt(11, 5)
    eng = _engine(params, prefill_chunk=5, token_budget=10)
    r_long = eng.submit(long, max_new=4)
    r_short = eng.submit(short, max_new=4)
    eng.drain(max_steps=300)
    assert eng.metrics.prefill_steps > 1        # the chunk actually split
    assert r_long.tokens == _ref_greedy(params, long, 4)
    assert r_short.tokens == _ref_greedy(params, short, 4)


def test_shared_prefix_sharing_is_bitwise_and_saves_prefill(params):
    """Six requests over a common 12-token system prompt, two lanes (so
    later waves admit after earlier prefills registered pages): the
    cache-on engine adopts pages (hit rate > 0), prefills strictly fewer
    tokens, and every output equals the cache-off run AND the oracle."""
    shared = _prompt(12, 12)
    prompts = [shared + _prompt(20 + i, 3 + i) for i in range(6)]

    def run(on):
        eng = _engine(params, max_batch=2, prefix_cache=on)
        reqs = [eng.submit(p, max_new=4) for p in prompts]
        eng.drain(max_steps=500)
        eng.sched.check_invariants()
        return reqs, eng.summary()

    on_reqs, on_sum = run(True)
    off_reqs, off_sum = run(False)
    assert on_sum["prefix_hit_rate"] > 0
    assert on_sum["tokens_prefilled"] < off_sum["tokens_prefilled"]
    assert on_sum["tokens_cached"] == on_sum["prefix_hit_tokens"] > 0
    for on_r, off_r, p in zip(on_reqs, off_reqs, prompts):
        assert on_r.tokens == off_r.tokens == _ref_greedy(params, p, 4)


def test_cow_divergent_tail_copies_then_diverges(params):
    """Prompts sharing a non-block-aligned prefix force the CoW path:
    the divergent tail block is copied, not aliased, so both outputs
    stay oracle-exact."""
    base = _prompt(13, 11)                       # 2 full pages + tail
    a, b = base + _prompt(14, 6), base[:10] + _prompt(15, 7)
    eng = _engine(params, max_batch=1, page_size=4)
    ra = eng.submit(a, max_new=4)
    eng.drain(max_steps=200)                     # a registers its pages
    rb = eng.submit(b, max_new=4)
    eng.drain(max_steps=200)
    assert eng.kv.allocator.cow_copies >= 1
    assert ra.tokens == _ref_greedy(params, a, 4)
    assert rb.tokens == _ref_greedy(params, b, 4)
    eng.sched.check_invariants()


def test_streaming_yields_tokens_incrementally(params):
    prompt = _prompt(16, 7)
    eng = _engine(params)
    other = eng.submit(_prompt(17, 5), max_new=3)
    h = eng.submit(prompt, max_new=5)
    got = list(eng.stream(h, max_steps=200))
    assert got == h.tokens == _ref_greedy(params, prompt, 5)
    assert h.t_first_token is not None and h.ttft >= 0
    eng.drain(max_steps=200)
    assert other.done
