"""End-to-end behaviour of the full system (paper protocol + LM substrate).

The headline reproduction checks live here: Echo-CGC (i) converges under
Byzantine attack where plain averaging fails, (ii) transmits a small
fraction of the baseline bits, (iii) detects forged echoes — all on the
faithful radio-network simulation. The LM-side check trains a small model
end-to-end and requires the loss to drop.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import byzantine, costfns, theory
from repro.core.protocol import run_training
from repro.core.types import ProtocolConfig


@pytest.fixture(scope="module")
def setting():
    key = jax.random.PRNGKey(0)
    d, n, f = 30, 20, 2
    cost = costfns.quadratic(key, d=d, mu=1.0, L=1.0, sigma=0.05)
    r, eta, b, g, rho = theory.pick_r_eta(n, f, cost.L, cost.mu, cost.sigma)
    cfg = ProtocolConfig(n=n, f=f, r=r, eta=eta)
    byz = jnp.zeros(n, bool).at[:f].set(True)
    return key, cost, cfg, byz, rho


def test_echo_cgc_converges_where_mean_fails(setting):
    key, cost, cfg, byz, _ = setting
    w0 = jnp.ones(cost.d) * 2.0
    tr_cgc = run_training(cfg, cost, byzantine.ATTACKS["large_norm"], byz,
                          key, w0, rounds=50, aggregator="cgc")
    tr_mean = run_training(cfg, cost, byzantine.ATTACKS["large_norm"], byz,
                           key, w0, rounds=50, aggregator="mean",
                           use_radio=False)
    assert float(tr_cgc["dist2"][-1]) < 1e-3 * float(tr_cgc["dist2"][0])
    assert float(tr_mean["dist2"][-1]) > float(tr_cgc["dist2"][-1]) * 10


def test_communication_savings_against_p2p(setting):
    """Headline claim: large savings when sigma is small (Sec. 4.3)."""
    key, cost, cfg, byz, _ = setting
    w0 = jnp.ones(cost.d)
    tr = run_training(cfg, cost, byzantine.ATTACKS["sign_flip"], byz, key,
                      w0, rounds=20)
    bits_echo = float(jnp.sum(tr["bits"]))
    bits_p2p = 20 * cfg.n * 32 * cost.d
    saving = 1 - bits_echo / bits_p2p
    assert saving > 0.5, saving


def test_detection_counts(setting):
    key, cost, cfg, byz, _ = setting
    tr = run_training(cfg, cost, byzantine.ATTACKS["forged_echo"], byz, key,
                      jnp.ones(cost.d), rounds=5)
    # every Byzantine forging an invalid echo is provably detected
    assert int(tr["n_detected"][-1]) == int(jnp.sum(byz))


def test_lm_training_loss_drops():
    """examples/train_lm driver logic: tiny LM, loss decreases."""
    from repro.configs import get_config, reduced
    from repro.data import make_batch_iterator
    from repro.launch.train import TrainSettings, make_train_step
    from repro.models import model as M
    from repro.models.nn import split_params
    from repro.optim import adamw

    cfg = reduced(get_config("qwen3-0.6b"), layers=2, d_model=128)
    opt = adamw(1e-3)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    values, _ = split_params(params)
    state = opt.init(values)
    step_fn, _ = make_train_step(cfg, opt, TrainSettings(), None, 8)
    it = make_batch_iterator(cfg, 8, 64, seed=0)
    losses = []
    step_jit = jax.jit(step_fn)
    for s in range(30):
        values, state, metrics = step_jit(values, state, next(it),
                                          jnp.asarray(s))
        losses.append(float(metrics["loss"]))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2, losses[:5]
