"""The fused CGC aggregation op vs the unfused cgc_filter chain.

The contract (ISSUE 6 / DESIGN.md §10): ``ops.cgc_fused_aggregate``
returns (aggregate, norms, scales) matching ``sum(cgc_filter(G, f))``
within fp32 tolerance on the Pallas backend and BITWISE on the jnp
backend, across worker counts, byzantine budgets and dimensions that
are not multiples of the d-block.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cgc import cgc_aggregate, cgc_filter
from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


def _stack(n, d, seed=0):
    G = jax.random.normal(jax.random.fold_in(KEY, seed * 131 + n * d),
                          (n, d))
    return G * jnp.arange(1, n + 1)[:, None]


@pytest.mark.parametrize("n,f,d", [
    (4, 0, 128),        # f=0: threshold is the max norm, nothing clips
    (8, 2, 4096),       # block-aligned d
    (13, 3, 1000),      # d not a multiple of the block, odd n
    (5, 4, 300),        # f = n-1 (max byzantine budget)
    (32, 8, 2048),
    (3, 1, 8192),       # d spanning several 2048-blocks
])
def test_fused_matches_filter_sum(n, f, d):
    G = _stack(n, d)
    want = np.asarray(jnp.sum(cgc_filter(G, f), axis=0))
    want_norms = np.asarray(jnp.linalg.norm(G, axis=-1))
    try:
        ops.set_cgc_backend("jnp")
        agg_j, norms_j, scales_j = ops.cgc_fused_aggregate(G, f)
        ops.set_cgc_backend("pallas")
        agg_p, norms_p, scales_p = ops.cgc_fused_aggregate(G, f)
    finally:
        ops.set_cgc_backend("auto")
    # jnp backend: bitwise the cgc_filter + sum chain
    np.testing.assert_array_equal(np.asarray(agg_j), want)
    # pallas backend: fp32 tolerance (different reduction order)
    np.testing.assert_allclose(np.asarray(agg_p), want, rtol=2e-5,
                               atol=2e-5)
    for norms, scales in ((norms_j, scales_j), (norms_p, scales_p)):
        np.testing.assert_allclose(np.asarray(norms), want_norms,
                                   rtol=1e-5)
        s = np.asarray(scales)
        assert s.shape == (n,) and np.all(s <= 1.0 + 1e-6) \
            and np.all(s > 0)
    # the ref oracle agrees too
    agg_r, norms_r, _ = ref.cgc_fused_aggregate_ref(G, f)
    np.testing.assert_allclose(np.asarray(agg_r), want, rtol=2e-5,
                               atol=2e-5)


def test_fused_threshold_ties_match_sort():
    """Duplicate norms: the in-kernel repeated-max extraction must land
    on the same threshold value as the host-side sort."""
    G = jnp.ones((6, 256)).at[0].mul(3.0).at[1].mul(3.0).at[2].mul(3.0)
    for f in range(6):
        want = np.asarray(jnp.sum(cgc_filter(G, f), axis=0))
        try:
            ops.set_cgc_backend("pallas")
            agg, _, _ = ops.cgc_fused_aggregate(G, f)
        finally:
            ops.set_cgc_backend("auto")
        np.testing.assert_allclose(np.asarray(agg), want, rtol=1e-6,
                                   atol=1e-6)


def test_cgc_aggregate_rides_fused_dispatch():
    """core.cgc.cgc_aggregate now dispatches through the fused op; on
    the default (jnp, this CPU host) backend it is bitwise the old
    sum(cgc_filter) — existing protocol trajectories are unchanged."""
    G = _stack(9, 1000, seed=3)
    assert ops.cgc_backend() in ("jnp", "pallas")
    np.testing.assert_array_equal(
        np.asarray(cgc_aggregate(G, 2)),
        np.asarray(jnp.sum(cgc_filter(G, 2), axis=0)))


def test_fused_backend_switch_validation():
    with pytest.raises(ValueError):
        ops.set_cgc_backend("nope")
    with pytest.raises(ValueError):
        ops.cgc_fused_aggregate(_stack(4, 128), 4)     # f >= n
    with pytest.raises(ValueError):
        ops.cgc_fused_aggregate(_stack(4, 128), -1)


def test_fused_bf16_stack():
    G = _stack(8, 512).astype(jnp.bfloat16)
    want = np.asarray(jnp.sum(cgc_filter(G, 2), axis=0), np.float32)
    try:
        ops.set_cgc_backend("pallas")
        agg, _, _ = ops.cgc_fused_aggregate(G, 2)
    finally:
        ops.set_cgc_backend("auto")
    assert agg.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(agg, np.float32), want,
                               rtol=2e-2, atol=2e-2)


# --- hypothesis property layer (runs under the [test] extra) ----------

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402


@settings(max_examples=25, deadline=None)
@given(n=st.integers(2, 20), d=st.integers(1, 300),
       f_frac=st.floats(0.0, 0.99), seed=st.integers(0, 99))
def test_fused_property_grid(n, d, f_frac, seed):
    """Both backends match sum(cgc_filter) on arbitrary (n, f, d),
    including d far from any block multiple; jnp bitwise."""
    f = min(n - 1, int(f_frac * n))
    G = _stack(n, d, seed)
    want = np.asarray(jnp.sum(cgc_filter(G, f), axis=0))
    try:
        ops.set_cgc_backend("jnp")
        agg_j, _, _ = ops.cgc_fused_aggregate(G, f)
        ops.set_cgc_backend("pallas")
        agg_p, _, _ = ops.cgc_fused_aggregate(G, f)
    finally:
        ops.set_cgc_backend("auto")
    np.testing.assert_array_equal(np.asarray(agg_j), want)
    np.testing.assert_allclose(np.asarray(agg_p), want, rtol=3e-5,
                               atol=3e-5)
