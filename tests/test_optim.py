"""Optimizers and schedules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (adamw, clip_by_global_norm, constant, cosine_decay,
                         linear_warmup_cosine, sgd, sgd_momentum)


def _quad_loss(w):
    return 0.5 * jnp.sum(w ** 2)


@pytest.mark.parametrize("make_opt", [
    lambda: sgd(0.1),
    lambda: sgd_momentum(0.05, 0.9),
    lambda: adamw(0.1),
])
def test_converges_on_quadratic(make_opt):
    opt = make_opt()
    params = {"w": jnp.ones(8) * 5.0}
    state = opt.init(params)
    for step in range(200):
        grads = jax.grad(lambda p: _quad_loss(p["w"]))(params)
        upd, state = opt.update(grads, state, params, jnp.asarray(step))
        params = jax.tree.map(lambda p, u: p + u, params, upd)
    assert float(_quad_loss(params["w"])) < 1e-3


def test_sgd_matches_paper_update():
    # w <- w - eta g (Eq. 2), exactly
    opt = sgd(0.25)
    params = {"w": jnp.array([2.0, -1.0])}
    g = {"w": jnp.array([1.0, 4.0])}
    upd, _ = opt.update(g, opt.init(params), params, jnp.asarray(0))
    np.testing.assert_allclose(np.asarray(upd["w"]), [-0.25, -1.0])


def test_adamw_weight_decay():
    opt = adamw(0.1, weight_decay=0.1)
    params = {"w": jnp.ones(4)}
    zero_g = {"w": jnp.zeros(4)}
    upd, _ = opt.update(zero_g, opt.init(params), params, jnp.asarray(0))
    assert np.all(np.asarray(upd["w"]) < 0)    # decay pulls toward 0


def test_clip_by_global_norm():
    g = {"a": jnp.ones(4) * 3.0, "b": jnp.ones(9) * 4.0}
    clipped, norm = clip_by_global_norm(g, 1.0)
    total = np.sqrt(sum(float(jnp.sum(x ** 2))
                        for x in jax.tree.leaves(clipped)))
    assert total == pytest.approx(1.0, rel=1e-5)
    assert float(norm) == pytest.approx(np.sqrt(9 * 4 + 16 * 9), rel=1e-6)


def test_schedules():
    s = constant(0.5)
    assert float(s(jnp.asarray(100))) == 0.5
    c = cosine_decay(1.0, 100, final_frac=0.1)
    assert float(c(jnp.asarray(0))) == pytest.approx(1.0)
    assert float(c(jnp.asarray(100))) == pytest.approx(0.1, rel=1e-3)
    w = linear_warmup_cosine(1.0, 10, 110)
    assert float(w(jnp.asarray(5))) == pytest.approx(0.5)
    assert float(w(jnp.asarray(10))) == pytest.approx(1.0)
