"""Checkpoint save/restore round-trips."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ck


def _tree(seed=0):
    key = jax.random.PRNGKey(seed)
    return {
        "layers": {"w": jax.random.normal(key, (4, 8)),
                   "b": jnp.zeros(8)},
        "head": [jnp.ones(3), jnp.arange(5, dtype=jnp.int32)],
        "step_scale": jnp.asarray(2.5),
    }


def test_roundtrip(tmp_path):
    t = _tree()
    ck.save(str(tmp_path), 7, t, extra={"note": "unit"})
    restored, step = ck.restore(str(tmp_path), t)
    assert step == 7
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b)), t, restored)


def test_latest_step_and_multiple(tmp_path):
    t = _tree()
    ck.save(str(tmp_path), 1, t)
    ck.save(str(tmp_path), 12, t)
    assert ck.latest_step(str(tmp_path)) == 12
    _, step = ck.restore(str(tmp_path), t)
    assert step == 12
    _, step1 = ck.restore(str(tmp_path), t, step=1)
    assert step1 == 1


def test_missing_key_raises(tmp_path):
    t = _tree()
    ck.save(str(tmp_path), 0, {"only": t["head"]})
    with pytest.raises(KeyError):
        ck.restore(str(tmp_path), t)


def test_missing_dir_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        ck.restore(str(tmp_path / "nope"), _tree())


def test_train_state_roundtrip(tmp_path):
    """save_train_state restores (values, opt_state, extras, step)."""
    from repro.optim import adamw
    opt = adamw(0.1)
    values = _tree(1)
    state = opt.init(values)
    basis = [jax.tree.map(lambda v: jnp.zeros_like(v, jnp.float32), values)
             for _ in range(3)]
    ck.save_train_state(str(tmp_path), 11, values, state,
                        extra_state={"basis": basis},
                        extra={"strategy": "echo_dp"})
    v2, s2, extra, step, complete = ck.restore_train_state(
        str(tmp_path), values, state, extra_like={"basis": basis})
    assert complete and step == 11
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b)), values, v2)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b)), state, s2)
    assert len(extra["basis"]) == 3


def test_train_state_basis_size_change_falls_back(tmp_path):
    """Resuming with a different echo_k must not hand back a stale
    prefix of the stored basis — extras restore only on an exact
    key-set match; otherwise the passed templates come back fresh."""
    from repro.optim import adamw
    opt = adamw(0.1)
    values = _tree(3)
    state = opt.init(values)
    basis4 = [jax.tree.map(lambda v, i=i: jnp.full(v.shape, float(i),
                                                   jnp.float32), values)
              for i in range(4)]
    ck.save_train_state(str(tmp_path), 2, values, state,
                        extra_state={"basis": basis4})
    for k in (3, 6):        # shrink and grow
        like = [jax.tree.map(lambda v: jnp.zeros(v.shape, jnp.float32),
                             values) for _ in range(k)]
        _, _, extra, step, complete = ck.restore_train_state(
            str(tmp_path), values, state, extra_like={"basis": like})
        assert complete and step == 2
        assert len(extra["basis"]) == k
        assert all(float(jnp.sum(jnp.abs(leaf))) == 0.0
                   for leaf in jax.tree.leaves(extra["basis"]))


def test_train_state_legacy_values_only(tmp_path):
    """A pre-v1 checkpoint (bare values tree) restores values only and
    reports complete=False so the caller re-inits optimizer state."""
    from repro.optim import adamw
    opt = adamw(0.1)
    values = _tree(2)
    ck.save(str(tmp_path), 5, values)            # the old CLI format
    fresh = opt.init(values)
    v2, s2, extra, step, complete = ck.restore_train_state(
        str(tmp_path), values, fresh)
    assert not complete and step == 5 and extra is None
    assert s2 is fresh
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b)), values, v2)


def test_async_writer_roundtrip_and_flush(tmp_path):
    """AsyncCheckpointWriter: submit returns the target path immediately,
    flush makes it durable, writes land in submission order, close is
    idempotent and a closed writer refuses new work."""
    from repro.optim import adamw
    opt = adamw(0.1)
    values = _tree(4)
    state = opt.init(values)
    w = ck.AsyncCheckpointWriter()
    paths = [w.submit(str(tmp_path), s, values, state,
                      extra={"strategy": "replicated"}) for s in (3, 9)]
    assert paths[1].endswith("step_00000009.npz")
    assert w.flush()
    assert ck.latest_step(str(tmp_path)) == 9
    v2, s2, _, step, complete = ck.restore_train_state(
        str(tmp_path), values, state)
    assert complete and step == 9
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b)), values, v2)
    w.close()
    w.close()                                  # idempotent
    with pytest.raises(RuntimeError, match="closed"):
        w.submit(str(tmp_path), 10, values, state)


def test_async_writer_surfaces_errors_on_flush(tmp_path):
    """A failed background write must not vanish: flush re-raises it."""
    w = ck.AsyncCheckpointWriter()
    bad = tmp_path / "file"
    bad.write_text("not a directory")
    w.submit(str(bad / "sub"), 0, {"x": jnp.ones(2)}, {"m": jnp.ones(2)})
    with pytest.raises(RuntimeError, match="async checkpoint"):
        w.flush()
    # the writer survives the error and keeps serving
    w.submit(str(tmp_path), 1, {"x": jnp.ones(2)}, {"m": jnp.ones(2)})
    assert w.flush()
    w.close()


def test_training_resume_equivalence(tmp_path):
    """Save at step k, restore, continue — identical to uninterrupted run."""
    from repro.optim import adamw
    opt = adamw(0.05)
    params = {"w": jnp.ones(6) * 3.0}
    state = opt.init(params)

    def run(params, state, start, steps):
        for s in range(start, start + steps):
            g = jax.grad(lambda p: 0.5 * jnp.sum(p["w"] ** 2))(params)
            upd, state = opt.update(g, state, params, jnp.asarray(s))
            params = jax.tree.map(lambda p, u: p + u, params, upd)
        return params, state

    pA, sA = run(params, state, 0, 10)
    pB, sB = run(params, state, 0, 5)
    ck.save(str(tmp_path), 5, {"params": pB, "opt": sB})
    blob, _ = ck.restore(str(tmp_path), {"params": pB, "opt": sB})
    pB2, sB2 = run(blob["params"], blob["opt"], 5, 5)
    np.testing.assert_allclose(np.asarray(pA["w"]), np.asarray(pB2["w"]),
                               rtol=1e-6)
