"""Checkpoint save/restore round-trips."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ck


def _tree(seed=0):
    key = jax.random.PRNGKey(seed)
    return {
        "layers": {"w": jax.random.normal(key, (4, 8)),
                   "b": jnp.zeros(8)},
        "head": [jnp.ones(3), jnp.arange(5, dtype=jnp.int32)],
        "step_scale": jnp.asarray(2.5),
    }


def test_roundtrip(tmp_path):
    t = _tree()
    ck.save(str(tmp_path), 7, t, extra={"note": "unit"})
    restored, step = ck.restore(str(tmp_path), t)
    assert step == 7
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b)), t, restored)


def test_latest_step_and_multiple(tmp_path):
    t = _tree()
    ck.save(str(tmp_path), 1, t)
    ck.save(str(tmp_path), 12, t)
    assert ck.latest_step(str(tmp_path)) == 12
    _, step = ck.restore(str(tmp_path), t)
    assert step == 12
    _, step1 = ck.restore(str(tmp_path), t, step=1)
    assert step1 == 1


def test_missing_key_raises(tmp_path):
    t = _tree()
    ck.save(str(tmp_path), 0, {"only": t["head"]})
    with pytest.raises(KeyError):
        ck.restore(str(tmp_path), t)


def test_missing_dir_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        ck.restore(str(tmp_path / "nope"), _tree())


def test_training_resume_equivalence(tmp_path):
    """Save at step k, restore, continue — identical to uninterrupted run."""
    from repro.optim import adamw
    opt = adamw(0.05)
    params = {"w": jnp.ones(6) * 3.0}
    state = opt.init(params)

    def run(params, state, start, steps):
        for s in range(start, start + steps):
            g = jax.grad(lambda p: 0.5 * jnp.sum(p["w"] ** 2))(params)
            upd, state = opt.update(g, state, params, jnp.asarray(s))
            params = jax.tree.map(lambda p, u: p + u, params, upd)
        return params, state

    pA, sA = run(params, state, 0, 10)
    pB, sB = run(params, state, 0, 5)
    ck.save(str(tmp_path), 5, {"params": pB, "opt": sB})
    blob, _ = ck.restore(str(tmp_path), {"params": pB, "opt": sB})
    pB2, sB2 = run(blob["params"], blob["opt"], 5, 5)
    np.testing.assert_allclose(np.asarray(pA["w"]), np.asarray(pB2["w"]),
                               rtol=1e-6)
