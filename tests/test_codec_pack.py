"""Codec pack/unpack kernels vs the wire-format oracles.

The Pallas backend of ``kernels.ops.int8_pack``/``topk_pack`` must be
BITWISE the jnp codec math (same absmax/round/clip order, same
``lax.top_k`` ordering incl. tie-breaks), so switching backends never
perturbs payloads, bit accounting or training trajectories.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm.wire import Int8Codec, TopKCodec, payload_bits
from repro.kernels import ops

KEY = jax.random.PRNGKey(7)


def _vec(m, seed=0):
    return jax.random.normal(jax.random.fold_in(KEY, m * 31 + seed), (m,))


def _both(fn):
    """Run fn() under the jnp backend then the pallas backend."""
    try:
        ops.set_codec_pack_backend("jnp")
        a = fn()
        ops.set_codec_pack_backend("pallas")
        b = fn()
    finally:
        ops.set_codec_pack_backend("auto")
    return a, b


@pytest.mark.parametrize("m", [1, 7, 128, 1000, 5000, 40000])
def test_int8_pack_backends_bitwise(m):
    v = _vec(m)
    (qj, sj), (qp, sp) = _both(lambda: ops.int8_pack(v))
    assert qp.shape == (m,) and qp.dtype == jnp.int8
    assert sp.shape == () and sp.dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(qj), np.asarray(qp))
    assert float(sj) == float(sp)
    dj, dp = _both(lambda: ops.int8_unpack(qj, sj, m))
    np.testing.assert_array_equal(np.asarray(dj), np.asarray(dp))
    # dequantization error bounded by half a quantization step
    step = float(sj)
    assert np.max(np.abs(np.asarray(dj) - np.asarray(v))) <= step * 0.5001


@pytest.mark.parametrize("m,k", [(1, 1), (10, 32), (128, 32), (1000, 32),
                                 (5000, 200), (40000, 64)])
def test_topk_pack_backends_bitwise(m, k):
    v = _vec(m, seed=1)
    kk = min(k, m)
    (vj, ij), (vp, ip) = _both(lambda: ops.topk_pack(v, k))
    assert vp.shape == (kk,) and ip.shape == (kk,)
    assert ip.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(ij), np.asarray(ip))
    np.testing.assert_array_equal(np.asarray(vj), np.asarray(vp))
    dj, dp = _both(lambda: ops.topk_unpack(vj, ij, m))
    np.testing.assert_array_equal(np.asarray(dj), np.asarray(dp))
    # the oracle: exactly lax.top_k over |v|
    _, idx = jax.lax.top_k(jnp.abs(v), kk)
    np.testing.assert_array_equal(np.asarray(ij), np.asarray(idx))


def test_topk_ties_and_zeros():
    """Crafted ties: many equal magnitudes and zeros — both backends
    must reproduce lax.top_k's stable (lowest-index-first) order, and
    never surface the zero padding the tiled layout adds."""
    v = jnp.zeros((300,)).at[jnp.arange(0, 300, 7)].set(1.0).at[5].set(-1.0)
    for k in [3, 16, 50, 80, 300]:
        (vj, ij), (vp, ip) = _both(lambda: ops.topk_pack(v, k))
        np.testing.assert_array_equal(np.asarray(ij), np.asarray(ip))
        np.testing.assert_array_equal(np.asarray(vj), np.asarray(vp))
        assert np.all(np.asarray(ip) < 300)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_codec_roundtrip_and_bit_honesty(backend):
    """The wire codecs ride the dispatch: payload shapes/dtypes (and so
    ``payload_bits``) are identical on both backends, and roundtrips
    reconstruct within codec error."""
    v = _vec(1000, seed=2)
    try:
        ops.set_codec_pack_backend(backend)
        c8, ctk = Int8Codec(), TopKCodec(k=32)
        p8 = c8.encode(v)
        assert payload_bits(p8) == c8.vector_bits(1000)
        r8 = c8.roundtrip(v)
        ptk = ctk.encode(v)
        assert payload_bits(ptk) == ctk.vector_bits(1000)
        rtk = ctk.roundtrip(v)
    finally:
        ops.set_codec_pack_backend("auto")
    assert r8.shape == (1000,) and rtk.shape == (1000,)
    np.testing.assert_allclose(np.asarray(r8), np.asarray(v), atol=0.05)
    # topk decode: exactly k entries survive, the rest are zero
    nz = np.nonzero(np.asarray(rtk))[0]
    assert len(nz) <= 32
    with pytest.raises(ValueError):
        ops.set_codec_pack_backend("nope")
