"""repro.serve: paged-vs-contiguous consistency, scheduler invariants,
end-to-end continuous-batching smoke (DESIGN.md §7)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.kernels import ops, ref
from repro.models import model as M
from repro.models.nn import split_params
from repro.serve import (BlockAllocator, PagedKVCache, ServeConfig,
                         ServeEngine, contiguous_from_paged,
                         paged_from_contiguous)

CFG = reduced(get_config("qwen3-0.6b"))
KEY = jax.random.PRNGKey(0)


def _params():
    return M.init_params(CFG, KEY)


def _values():
    return split_params(_params())[0]


def _ref_greedy(values, prompt, gen):
    """Per-request contiguous-cache greedy decode (the serving oracle)."""
    cache, _ = split_params(M.init_cache(CFG, 1, len(prompt) + gen))
    step = jax.jit(lambda v, c, t, p: M.decode_step(v, CFG, c, t, p))
    for t, tok in enumerate(prompt):
        logits, cache = step(values, cache,
                             jnp.asarray([[tok]], jnp.int32),
                             jnp.asarray([t], jnp.int32))
    out = [int(jnp.argmax(logits[0]))]
    for i in range(gen - 1):
        logits, cache = step(values, cache,
                             jnp.asarray([[out[-1]]], jnp.int32),
                             jnp.asarray([len(prompt) + i], jnp.int32))
        out.append(int(jnp.argmax(logits[0])))
    return out


# ---------------------------------------------------------------------------
# Paged vs contiguous decode consistency
# ---------------------------------------------------------------------------


def test_paged_attention_ref_bitwise_vs_contiguous():
    """The jnp paged backend IS the contiguous reference on the gathered
    block-table view — bitwise, including fully-masked lanes."""
    B, H, K, hd, P, ps, NB = 3, 8, 4, 32, 16, 8, 5
    q = jax.random.normal(KEY, (B, H, hd))
    kp = jax.random.normal(jax.random.fold_in(KEY, 1), (P, ps, K, hd))
    vp = jax.random.normal(jax.random.fold_in(KEY, 2), (P, ps, K, hd))
    bt = jnp.asarray([[1, 2, 3, 0, 0], [4, 5, 0, 0, 0],
                      [6, 7, 8, 9, 10]], jnp.int32)
    lengths = jnp.asarray([19, 0, 40], jnp.int32)

    k = ref.gather_pages(kp, bt)
    v = ref.gather_pages(vp, bt)
    mask = jnp.arange(NB * ps)[None, :] < lengths[:, None]
    want = np.array(ref.decode_attention_ref(q, k, v, mask))
    want[1] = 0.0                                   # inactive lane zeroed

    ops.set_paged_attn_backend("jnp")
    try:
        got = np.asarray(ops.paged_decode_attention(q, kp, vp, bt, lengths))
    finally:
        ops.set_paged_attn_backend("auto")
    np.testing.assert_array_equal(got, want)


def test_paged_attention_backends_allclose():
    """Pallas (interpret) vs jnp paged backends agree to 1e-5."""
    B, H, K, hd, P, ps = 2, 8, 2, 64, 12, 16
    q = jax.random.normal(KEY, (B, H, hd))
    kp = jax.random.normal(jax.random.fold_in(KEY, 3), (P, ps, K, hd))
    vp = jax.random.normal(jax.random.fold_in(KEY, 4), (P, ps, K, hd))
    bt = jnp.asarray([[3, 1, 7, 0], [2, 5, 9, 11]], jnp.int32)
    lengths = jnp.asarray([50, 17], jnp.int32)
    outs = {}
    try:
        for backend in ("jnp", "pallas"):
            ops.set_paged_attn_backend(backend)
            outs[backend] = np.asarray(
                ops.paged_decode_attention(q, kp, vp, bt, lengths))
    finally:
        ops.set_paged_attn_backend("auto")
    np.testing.assert_allclose(outs["pallas"], outs["jnp"], rtol=1e-5,
                               atol=1e-5)
    with pytest.raises(ValueError):
        ops.set_paged_attn_backend("nope")


def test_paged_decode_matches_contiguous_mixed_lengths():
    """Model-level: paged decode_step tracks the contiguous decode_step
    across a mixed-length batch (one lane goes inactive mid-stream)."""
    B, S, ps, NB = 3, 24, 8, 3
    values = _values()
    tokens = jax.random.randint(jax.random.fold_in(KEY, 9), (B, S), 0,
                                CFG.vocab_size, jnp.int32)
    cache, _ = split_params(M.init_cache(CFG, B, NB * ps))
    pcache, _ = split_params(M.init_paged_cache(CFG, 16, ps))
    bt = jnp.asarray([[1, 2, 3], [4, 5, 6], [7, 8, 9]], jnp.int32)
    step = jax.jit(lambda v, c, t, p: M.decode_step(v, CFG, c, t, p))
    pstep = jax.jit(lambda v, c, t, p, b: M.decode_step(
        v, CFG, c, t, p, block_tables=b))
    for t in range(S):
        pos = jnp.full((B,), t, jnp.int32)
        l1, cache = step(values, cache, tokens[:, t:t + 1], pos)
        ppos = pos.at[1].set(-1) if t >= 10 else pos
        l2, pcache = pstep(values, pcache, tokens[:, t:t + 1], ppos, bt)
        active = np.asarray([0, 2]) if t >= 10 else np.asarray([0, 1, 2])
        np.testing.assert_allclose(np.asarray(l1)[active],
                                   np.asarray(l2)[active],
                                   rtol=1e-5, atol=1e-5)


def test_contiguous_adapters_roundtrip():
    """Pack a warm contiguous cache into pages, decode one more token on
    both paths, and gather the pages back out."""
    B, T, ps = 2, 16, 4
    values = _values()
    lengths = [11, 5]
    tokens = jax.random.randint(jax.random.fold_in(KEY, 5), (B, T), 0,
                                CFG.vocab_size, jnp.int32)
    cache, _ = split_params(M.init_cache(CFG, B, T))
    step = jax.jit(lambda v, c, t, p: M.decode_step(v, CFG, c, t, p))
    for t in range(max(lengths)):
        pos = jnp.asarray([t if t < n else n - 1 for n in lengths],
                          jnp.int32)
        # shorter lane re-writes its last slot; we only compare the
        # longer lane plus the short lane's first `len` slots below
        logits, cache = step(values, cache, tokens[:, t:t + 1], pos)

    kv = PagedKVCache(CFG, num_pages=16, page_size=ps,
                      max_blocks_per_seq=T // ps)
    blocks = paged_from_contiguous(kv, cache, lengths)
    assert len(blocks) == B
    assert kv.allocator.num_free == kv.allocator.capacity \
        - sum(len(b) for b in blocks)

    tables = jnp.asarray(np.stack([kv.table_row(b) for b in blocks]))
    back = contiguous_from_paged(kv, tables, lengths)
    for b, n in enumerate(lengths):
        np.testing.assert_array_equal(
            np.asarray(back["layers"]["k"][:, b, :n]),
            np.asarray(cache["layers"]["k"][:, b, :n]))
        np.testing.assert_array_equal(
            np.asarray(back["layers"]["slot_pos"][:, b, :n]),
            np.asarray(cache["layers"]["slot_pos"][:, b, :n]))

    # the packed pages decode the next token identically
    nxt = jnp.asarray([[3], [7]], jnp.int32)
    l_cont, _ = step(values, cache, nxt, jnp.asarray(lengths, jnp.int32))
    pstep = jax.jit(lambda v, c, t, p, b: M.decode_step(
        v, CFG, c, t, p, block_tables=b))
    l_paged, _ = pstep(values, kv.pages, nxt,
                       jnp.asarray(lengths, jnp.int32), tables)
    np.testing.assert_allclose(np.asarray(l_cont), np.asarray(l_paged),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Allocator / scheduler invariants
# ---------------------------------------------------------------------------


def test_block_allocator_invariants():
    a = BlockAllocator(8)
    assert a.capacity == 7
    assert a.alloc(0) == [] and a.num_free == 7   # no page aliasing
    got = a.alloc(3)
    assert len(got) == 3 and 0 not in got
    assert a.alloc(5) is None and a.num_free == 4   # failed alloc: no change
    a.free(got)
    assert a.num_free == 7
    with pytest.raises(ValueError):
        a.free([got[0]])                            # double free
    with pytest.raises(ValueError):
        a.free([0])                                 # scratch page
    with pytest.raises(ValueError):
        BlockAllocator(1)


def test_scheduler_no_leaks_across_admit_preempt_free():
    """Tiny pool forces preemption; every request drains and every page
    returns to the free list."""
    params = _params()
    engine = ServeEngine(CFG, params, ServeConfig(
        max_batch=2, page_size=4, num_pages=6, max_blocks_per_seq=4,
        token_budget=64, decode_quantum=4, log_every=10 ** 9))
    rng = np.random.default_rng(1)
    handles = [engine.submit(rng.integers(0, CFG.vocab_size, size=8).tolist(),
                             max_new=8) for _ in range(3)]
    while engine.sched.has_work:
        engine.step()
        engine.sched.check_invariants()
    engine.close()
    assert all(h.done for h in handles)
    assert all(len(h.tokens) == 8 for h in handles)
    assert sum(h.n_preempt for h in handles) >= 1
    assert engine.kv.allocator.num_free == engine.kv.allocator.capacity


def test_submit_rejects_oversized_request():
    engine = ServeEngine(CFG, _params(), ServeConfig(
        max_batch=1, page_size=4, num_pages=4, max_blocks_per_seq=2))
    with pytest.raises(ValueError):
        engine.submit(list(range(4)), max_new=8)    # needs 3 pages > 2
    engine.close()


def test_paged_serving_rejects_unsupported_configs():
    with pytest.raises(ValueError):
        ServeEngine(reduced(get_config("zamba2-2.7b")), None, ServeConfig())
    with pytest.raises(ValueError):
        ServeEngine(reduced(get_config("minicpm3-4b")), None, ServeConfig())
    with pytest.raises(ValueError):
        M.init_paged_cache(reduced(get_config("xlstm-125m")), 8, 8)


# ---------------------------------------------------------------------------
# End-to-end: continuous batching == per-request greedy reference
# ---------------------------------------------------------------------------


def test_engine_end_to_end_mixed_prompts_matches_reference():
    values = _values()
    rng = np.random.default_rng(0)
    cases = [(5, 6), (12, 9), (3, 6), (20, 3), (9, 12)]
    prompts = [rng.integers(0, CFG.vocab_size, size=p).tolist()
               for p, _ in cases]
    refs = [_ref_greedy(values, p, g)
            for p, (_, g) in zip(prompts, cases)]

    engine = ServeEngine(CFG, _params(), ServeConfig(
        max_batch=3, page_size=8, num_pages=32, max_blocks_per_seq=6,
        token_budget=64, log_every=10 ** 9))
    handles = [engine.submit(p, max_new=g)
               for p, (_, g) in zip(prompts, cases)]
    done = engine.drain(max_steps=500)
    engine.sched.check_invariants()
    engine.close()
    assert len(done) == len(handles)
    for h, want in zip(handles, refs):
        assert h.done and h.tokens == want, (h.rid, h.tokens, want)


def test_engine_eos_stops_early():
    values = _values()
    prompt = [7, 11, 13, 17, 19]
    full = _ref_greedy(values, prompt, 12)
    eos = full[3]                    # force a stop after 4 tokens
    cut = full.index(eos) + 1
    engine = ServeEngine(CFG, _params(), ServeConfig(
        max_batch=2, page_size=8, num_pages=16, max_blocks_per_seq=4,
        log_every=10 ** 9))
    h = engine.submit(prompt, max_new=12, eos=eos)
    engine.drain(max_steps=100)
    engine.close()
    assert h.done and h.tokens == full[:cut]
    assert engine.kv.allocator.num_free == engine.kv.allocator.capacity


def test_engine_metrics_jsonl(tmp_path):
    path = tmp_path / "serve.jsonl"
    engine = ServeEngine(CFG, _params(), ServeConfig(
        max_batch=2, page_size=8, num_pages=16, max_blocks_per_seq=4,
        metrics_path=str(path), log_every=10 ** 9))
    engine.submit([1, 2, 3], max_new=4)
    engine.drain(max_steps=100)
    summary = engine.summary()
    engine.close()
    import json
    records = [json.loads(line) for line in path.read_text().splitlines()]
    assert len(records) == summary["steps"] and records
    assert {"step", "kind", "generated", "tokens_per_s"} <= set(records[0])
    assert summary["tokens_generated"] == 4
    assert summary["completed"] == 1 and summary["latency_p50_s"] > 0


# ---------------------------------------------------------------------------
# Sampling (ServeSpec.sampling): temperature / top-k, seeded determinism
# ---------------------------------------------------------------------------


def _sampled_run(sampling, submissions):
    from repro.serve import SamplingSpec  # noqa: F401 (re-export check)
    engine = ServeEngine(CFG, _params(), ServeConfig(
        max_batch=2, page_size=8, num_pages=32, max_blocks_per_seq=6,
        token_budget=64, log_every=10 ** 9, sampling=sampling))
    handles = [engine.submit(p, max_new=g) for p, g in submissions]
    engine.drain(max_steps=500)
    engine.sched.check_invariants()
    engine.close()
    assert all(h.done for h in handles)
    return [list(h.tokens) for h in handles]


def test_sampling_seeded_determinism():
    """Same sampling seed -> identical tokens across engines; a different
    seed moves at least one token (temperature spreads the smoke model's
    near-uniform logits wide)."""
    from repro.serve import SamplingSpec

    subs = [([5, 6, 7], 10), ([9, 1, 2, 3], 12)]
    spec = SamplingSpec(temperature=0.8, top_k=16, seed=0)
    a = _sampled_run(spec, subs)
    b = _sampled_run(spec, subs)
    assert a == b
    c = _sampled_run(SamplingSpec(temperature=0.8, top_k=16, seed=1), subs)
    assert a != c
    # every sampled id respects the vocab (top-k masking never leaks -inf)
    assert all(0 <= t < CFG.vocab_size for toks in a + c for t in toks)


def test_sampling_never_emits_vocab_padding_ids():
    """padded_vocab > vocab_size leaves padding columns with arbitrary
    random-init logits; sampling must mask them out."""
    import dataclasses

    from repro.serve import SamplingSpec

    cfg = dataclasses.replace(CFG, vocab_size=500)   # padded_vocab = 512
    assert cfg.padded_vocab > cfg.vocab_size
    params = M.init_params(cfg, KEY)
    engine = ServeEngine(cfg, params, ServeConfig(
        max_batch=2, page_size=8, num_pages=32, max_blocks_per_seq=6,
        token_budget=64, log_every=10 ** 9,
        sampling=SamplingSpec(temperature=5.0, seed=0)))   # near-uniform
    handles = [engine.submit([1, 2, 3], max_new=32),
               engine.submit([4, 5], max_new=32)]
    engine.drain(max_steps=500)
    engine.close()
    toks = [t for h in handles for t in h.tokens]
    assert len(toks) == 64
    assert all(0 <= t < cfg.vocab_size for t in toks), max(toks)


def test_sampling_greedy_default_matches_reference():
    """temperature=0 (the default) is exactly the old greedy engine:
    tokens equal the per-request contiguous-cache argmax reference,
    whatever the sampling seed."""
    from repro.serve import SamplingSpec

    values = _values()
    prompt = [3, 1, 4, 1, 5]
    want = _ref_greedy(values, prompt, 8)
    for seed in (0, 123):
        got = _sampled_run(SamplingSpec(temperature=0.0, seed=seed),
                           [(prompt, 8)])
        assert got == [want]


def test_nucleus_sampling_seeded_determinism():
    """top-p (nucleus) sampling: same seed -> identical tokens across
    engines, different seed moves at least one token, and every id
    respects the vocab (the nucleus mask never leaks -inf or padding)."""
    from repro.serve import SamplingSpec

    subs = [([5, 6, 7], 10), ([9, 1, 2, 3], 12)]
    spec = SamplingSpec(temperature=0.8, top_p=0.9, seed=0)
    a = _sampled_run(spec, subs)
    b = _sampled_run(spec, subs)
    assert a == b
    c = _sampled_run(SamplingSpec(temperature=0.8, top_p=0.9, seed=1), subs)
    assert a != c
    assert all(0 <= t < CFG.vocab_size for toks in a + c for t in toks)
    # top-k composes with top-p (k first, then the nucleus) and stays
    # deterministic under one seed
    both = SamplingSpec(temperature=0.8, top_k=16, top_p=0.9, seed=0)
    assert _sampled_run(both, subs) == _sampled_run(both, subs)


def test_nucleus_tiny_top_p_is_greedy():
    """A nucleus smaller than any single token's probability keeps only
    the argmax: top_p -> 0 degenerates to greedy decoding exactly."""
    from repro.serve import SamplingSpec

    values = _values()
    prompt = [3, 1, 4, 1, 5]
    want = _ref_greedy(values, prompt, 8)
    for seed in (0, 123):
        got = _sampled_run(
            SamplingSpec(temperature=0.7, top_p=1e-6, seed=seed),
            [(prompt, 8)])
        assert got == [want]
