"""PrefixPagePool: refcount / prefix-index invariants (DESIGN.md §11).

Pure host-side tests — no jax arrays. The random-interleaving driver
simulates the scheduler's life cycle (admit with prefix adoption,
decode-time extension + registration, preempt/finish release) and
asserts after every operation that refcounts exactly mirror the live
sequences' page maps, no page is ever double-freed, and a full drain
returns the pool to its capacity. The same driver runs under a seeded
sweep always, and under hypothesis when it is installed (the ``test``
extra).
"""
import dataclasses

import numpy as np
import pytest

from repro.serve import SCRATCH_PAGE, PrefixPagePool


def _pages_of(pool):
    return {"free": len(pool._free), "cached": pool.num_cached,
            "live": pool.num_live}


# ---------------------------------------------------------------------------
# Unit behaviour
# ---------------------------------------------------------------------------


def test_alloc_release_and_lru_eviction():
    pool = PrefixPagePool(num_pages=6, page_size=4)
    assert pool.capacity == 5 and pool.num_free == 5
    a = pool.alloc(2)
    assert len(a) == 2 and SCRATCH_PAGE not in a
    assert pool.alloc(4) is None and pool.num_free == 3  # no change on fail

    # register one page, release both: registered -> cached, other -> free
    key = pool.chain_key(None, (1, 2, 3, 4))
    pool.register(a[0], key)
    pool.release(a)
    assert pool.num_free == 5 and pool.num_cached == 1
    # allocating everything evicts the cached page (LRU) and deindexes it
    b = pool.alloc(5)
    assert b is not None and pool.num_cached == 0
    assert pool._index == {}
    pool.release(b)
    assert pool.num_free == 5


def test_release_errors():
    pool = PrefixPagePool(num_pages=4, page_size=2)
    a = pool.alloc(1)
    pool.release(a)
    with pytest.raises(ValueError):
        pool.release(a)                       # double free
    with pytest.raises(ValueError):
        pool.release([SCRATCH_PAGE])


def test_admit_adopts_full_blocks_and_cow_tail():
    ps = 4
    pool = PrefixPagePool(num_pages=16, page_size=ps)
    toks = list(range(12))                    # 3 full blocks
    first = pool.admit(toks)
    assert first.committed == 0 and len(first.blocks) == 3
    pool.register_progress(first.blocks, first.keys, toks, len(toks))
    assert len(first.keys) == 3               # only FULL blocks index

    # a 10-token prompt sharing toks[:10]: 2 full blocks adopted
    # outright, the partial tail [8] adopted via CoW from block 3
    second = pool.admit(toks[:10])
    assert second.blocks[:2] == first.blocks[:2]
    assert [pool.ref[p] for p in first.blocks[:2]] == [2, 2]
    assert second.cow_src == first.blocks[2] and second.cow_block == 2
    # tail overlap is capped at len-1: the final token must stay
    # computable, so committed = 2*ps + 1 here (overlap over [8])
    assert second.committed == 2 * ps + 1
    assert pool.ref[first.blocks[2]] == 2     # src pinned until the copy
    pool.release([second.cow_src])            # the engine's post-copy drop

    # divergent prompt: adopts the first block only
    div = pool.admit(list(range(4)) + [99] * 6)
    assert div.blocks[0] == first.blocks[0] and div.committed == ps
    assert pool.ref[first.blocks[0]] == 3

    pool.release(first.blocks)
    pool.release(second.blocks)
    pool.release(div.blocks)
    assert pool.num_free == pool.capacity     # registered pages now cached
    assert pool.num_cached > 0
    assert pool.hit_tokens > 0 and pool.admit_tokens == 32


def test_admit_rolls_back_cleanly_on_pool_oom():
    ps = 4
    pool = PrefixPagePool(num_pages=6, page_size=ps)   # 5 usable pages
    toks = list(range(12))
    a = pool.admit(toks)                      # 3 pages
    pool.register_progress(a.blocks, a.keys, toks, len(toks))
    before = _pages_of(pool)
    counters = (pool.admit_tokens, pool.hit_tokens, pool.cow_copies)
    # needs 3 pages, 2 adoptable + cow but only 2 private left... a
    # different 16-token prompt needs 4 private pages -> None, no change
    assert pool.admit([77] * 16) is None
    assert _pages_of(pool) == before
    assert (pool.admit_tokens, pool.hit_tokens,
            pool.cow_copies) == counters
    pool.check()

    # cancel_admit rolls an accepted plan back (budget refusal path)
    plan = pool.admit(toks)
    assert plan is not None and plan.committed > 0
    pool.cancel_admit(plan)
    assert _pages_of(pool) == before
    assert (pool.admit_tokens, pool.hit_tokens,
            pool.cow_copies) == counters
    pool.check()
    pool.release(a.blocks)


def test_register_duplicate_key_keeps_first_page():
    ps = 2
    pool = PrefixPagePool(num_pages=8, page_size=ps)
    a, b = pool.alloc(1), pool.alloc(1)
    key = pool.chain_key(None, (5, 6))
    pool.register(a[0], key)
    pool.register(b[0], key)                  # duplicate: no-op
    assert pool._index[key] == a[0]
    pool.release(b)
    assert pool.num_cached == 0               # b was never indexed -> free
    pool.release(a)
    assert pool.num_cached == 1


def test_prefix_cache_off_never_indexes():
    pool = PrefixPagePool(num_pages=8, page_size=2, prefix_cache=False)
    toks = [1, 2, 3, 4, 5]
    a = pool.admit(toks)
    pool.register_progress(a.blocks, a.keys, toks, len(toks))
    pool.release(a.blocks)
    b = pool.admit(toks)
    assert b.committed == 0 and b.cow_src is None
    assert pool.num_cached == 0 and pool.hit_tokens == 0
    pool.release(b.blocks)


# ---------------------------------------------------------------------------
# Random-interleaving property: admit / extend / preempt / finish
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _Seq:
    blocks: list
    keys: list
    tokens: list


def _drive(num_pages, page_size, seed, ops=120):
    """Random scheduler-shaped interleaving; invariants after every op."""
    pool = PrefixPagePool(num_pages=num_pages, page_size=page_size)
    rng = np.random.default_rng(seed)
    seqs = []
    freed_pages = 0
    for _ in range(ops):
        op = int(rng.integers(0, 4))
        if op == 0:                                    # admit (prefill)
            L = int(rng.integers(1, 4 * page_size + 1))
            toks = rng.integers(0, 5, size=L).tolist()
            plan = pool.admit(toks)
            if plan is not None:
                if plan.cow_src is not None:           # "copy" then drop
                    pool.release([plan.cow_src])
                seq = _Seq(plan.blocks, list(plan.keys), toks)
                pool.register_progress(seq.blocks, seq.keys, seq.tokens, L)
                seqs.append(seq)
        elif op == 1 and seqs:                         # decode growth
            s = seqs[int(rng.integers(len(seqs)))]
            grown = s.tokens + rng.integers(
                0, 5, size=int(rng.integers(1, page_size + 1))).tolist()
            if pool.extend(s.blocks, len(grown)):
                s.tokens = grown
                pool.register_progress(s.blocks, s.keys, s.tokens,
                                       len(s.tokens))
        elif op == 2 and seqs:                         # preempt / finish
            s = seqs.pop(int(rng.integers(len(seqs))))
            pool.release(s.blocks)
            freed_pages += len(s.blocks)
        # a released page must never be releasable twice: refcounts hit
        # zero exactly once, tracked by the exact held == ref match
        from collections import Counter
        held = Counter(p for s in seqs for p in s.blocks)
        assert dict(held) == dict(pool.ref)
        pool.check()
    for s in seqs:                                     # drain
        pool.release(s.blocks)
    pool.check()
    assert pool.ref == {}
    assert pool.num_free == pool.capacity
    return pool


def test_random_interleavings_seeded_sweep():
    for seed in range(12):
        pool = _drive(num_pages=10, page_size=3, seed=seed)
        # sharing actually happened somewhere in the sweep
        if pool.hit_tokens:
            break
    else:
        pytest.fail("no prefix hit across the sweep — trace too weak")


def test_double_release_always_raises_after_drain():
    pool = _drive(num_pages=8, page_size=2, seed=3)
    page = pool.alloc(1)
    pool.release(page)
    with pytest.raises(ValueError):
        pool.release(page)


try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=30, deadline=None)
    @given(num_pages=st.integers(3, 24), page_size=st.integers(1, 6),
           seed=st.integers(0, 10 ** 6))
    def test_random_interleavings_property(num_pages, page_size, seed):
        _drive(num_pages, page_size, seed, ops=60)
except ImportError:                                    # pragma: no cover
    pass                                               # seeded sweep stands in
