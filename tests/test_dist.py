"""Distribution layer tests.

Sharding-rule logic runs on AbstractMesh (no devices needed); collective
behaviour runs in subprocesses with XLA_FLAGS forcing 8 host devices (the
session process already initialised jax with a single CPU device).
"""
import os
import subprocess
import sys
import textwrap

import jax
import pytest
from jax.sharding import PartitionSpec as P

# abstract_mesh: version-compat constructor (current jax rejects the
# positional AbstractMesh((16, 16), ("data", "model")) form).
from repro.dist import DEFAULT_RULES, EP_RULES, abstract_mesh, spec_for

MESH1 = abstract_mesh((16, 16), ("data", "model"))
MESH2 = abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def test_rules_basic():
    # mlp dim -> model axis
    assert spec_for((4096, 13440), ("embed", "mlp"), MESH1) == \
        P(None, "model")
    # batch -> (pod, data) on the multi-pod mesh
    assert spec_for((256, 4096), ("batch", None), MESH2) == \
        P(("pod", "data"), None)
    assert spec_for((256, 4096), ("batch", None), MESH1) == P("data", None)


def test_rules_divisibility_fallback():
    # kv_heads=8 not divisible by model=16 -> replicated, kv_seq takes model
    spec = spec_for((128, 32768, 8, 128),
                    ("batch", "kv_seq", "kv_heads", None), MESH1)
    assert spec == P("data", "model", None, None)
    # kv_heads=32 divisible -> heads win over kv_seq (priority)
    spec = spec_for((128, 32768, 32, 128),
                    ("batch", "kv_seq", "kv_heads", None), MESH1)
    assert spec == P("data", None, "model", None)


def test_rules_no_axis_reuse():
    # batch=1 unshardable; kv_seq may then use (data, model) jointly
    spec = spec_for((1, 524288, 8, 128),
                    ("batch", "kv_seq", "kv_heads", None), MESH1)
    assert spec == P(None, ("data", "model"), None, None)


def test_ep_rules_shard_experts():
    spec = spec_for((160, 5120, 1536), ("expert", "embed", "mlp"), MESH1,
                    EP_RULES)
    assert spec == P("model", None, None)
    spec = spec_for((160, 5120, 1536), ("expert", "embed", "mlp"), MESH1,
                    DEFAULT_RULES)
    assert spec == P(None, None, "model")


def test_vocab_padding_divisible():
    from repro.configs import ARCH_IDS, get_config
    for a in ARCH_IDS:
        cfg = get_config(a)
        assert cfg.padded_vocab % 16 == 0, a
        assert cfg.padded_vocab >= cfg.vocab_size


def _run_subprocess(body: str):
    """Run a snippet under 8 fake CPU devices; raise on failure."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        os.environ["JAX_PLATFORMS"] = "cpu"
    """) + textwrap.dedent(body)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=600)
    if r.returncode != 0:
        raise AssertionError(f"subprocess failed:\n{r.stdout}\n{r.stderr}")
    return r.stdout


def test_cgc_aggregation_collective():
    """CGC over the data axis neutralises a large-norm Byzantine worker."""
    _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.dist.collectives import (aggregate_pytree_cgc,
                                            aggregate_pytree_mean,
                                            inject_byzantine, worker_index)

        mesh = jax.make_mesh((8,), ("data",))

        def step(x):
            wid = worker_index(("data",))
            g = {"w": x * 0 + 1.0}                    # honest grad = ones
            g = inject_byzantine(g, wid, 1, "large_norm", scale=100.0)
            agg, diags = aggregate_pytree_cgc(g, ("data",), f=1)
            agg_mean, _ = aggregate_pytree_mean(g, ("data",))
            return agg["w"], agg_mean["w"]

        sm = jax.shard_map(step, mesh=mesh, in_specs=P("data"),
                           out_specs=(P(), P()), check_vma=False)
        x = jnp.zeros((8,))
        cgc, mean = jax.jit(sm)(x)
        # mean is destroyed by the -100x worker; CGC bounds it near 1
        assert abs(float(mean[0]) - 1.0) > 5.0, float(mean[0])
        err = abs(float(cgc[0]) - 1.0)
        assert err < 0.5, float(cgc[0])
        print("OK")
    """)


def test_agg_fns_cgc_matches_gathered_reference():
    """AGG_FNS["cgc"] inside shard_map == core.aggregators.cgc_sum on the
    gathered (n, d) table (same filtered-sum convention, paper line 44)."""
    _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.core.aggregators import cgc_sum
        from repro.dist import AGG_FNS
        from repro.dist.collectives import worker_index

        n, d, f = 8, 96, 2
        table = jax.random.normal(jax.random.PRNGKey(0), (n, d))
        table = table * (1.0 + 3.0 * jnp.arange(n)[:, None])  # norm spread

        def step(rows):
            g = {"w": rows[0, :64], "b": rows[0, 64:]}   # pytree split of g
            agg, diags = AGG_FNS["cgc"](g, ("data",), f)
            return jnp.concatenate([agg["w"], agg["b"]])

        mesh = jax.make_mesh((n,), ("data",))
        sm = jax.shard_map(step, mesh=mesh, in_specs=P("data", None),
                           out_specs=P(), check_vma=False)
        got = jax.jit(sm)(table)
        want = cgc_sum(table, f)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)
        print("OK")
    """)


def test_sharded_train_step_runs():
    """Full CGC train step on a (4, 2) mesh: loss finite, params move."""
    _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config, reduced
        from repro.data import train_inputs
        from repro.launch.train import TrainSettings, make_train_step
        from repro.models import model as M
        from repro.models.nn import split_params
        from repro.optim import adamw

        cfg = reduced(get_config("qwen3-0.6b"))
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        opt = adamw(1e-3)
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        values, _ = split_params(params)
        state = opt.init(values)
        step_fn, ctx = make_train_step(
            cfg, opt, TrainSettings(aggregator="cgc", f=1, n_byz=1),
            mesh, global_batch=8)
        batch = train_inputs(jax.random.PRNGKey(1), cfg, 8, 32)
        with jax.set_mesh(mesh):
            v2, s2, metrics = jax.jit(step_fn)(values, state, batch,
                                               jnp.asarray(0))
        assert np.isfinite(float(metrics["loss"]))
        moved = sum(float(jnp.sum(jnp.abs(a - b)))
                    for a, b in zip(jax.tree.leaves(values),
                                    jax.tree.leaves(v2)))
        assert moved > 0
        print("OK", float(metrics["loss"]))
    """)


def test_moe_sharded_matches_local():
    """shard_map MoE (tp mode) == single-device moe_local."""
    _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config, reduced
        from repro.dist import make_shard_ctx
        from repro.models import model as M, moe
        from repro.models.nn import split_params

        cfg = reduced(get_config("qwen3-moe-30b-a3b"))
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        values, _ = split_params(params)
        p = jax.tree.map(lambda a: a[0], values["layers"])["moe"]
        x = jax.random.normal(jax.random.PRNGKey(2), (8, 16, cfg.d_model))

        y_local, st_local = moe.moe_forward(p, cfg, x, None)

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        ctx = make_shard_ctx(mesh, 8)
        with jax.set_mesh(mesh):
            y_sh, st_sh = jax.jit(
                lambda p, x: moe.moe_forward(p, cfg, x, ctx))(p, x)
        np.testing.assert_allclose(np.asarray(y_local), np.asarray(y_sh),
                                   rtol=2e-3, atol=2e-3)
        # aux loss: per-shard f_e*P_e averaged != global f_e*P_e exactly
        # (standard DP behaviour) — require agreement to a few percent only.
        np.testing.assert_allclose(float(st_local.aux_loss),
                                   float(st_sh.aux_loss), rtol=5e-2)
        print("OK")
    """)


def test_expert_parallel_matches_local():
    """EP all-to-all dispatch == local MoE oracle (dropless capacity)."""
    _run_subprocess("""
        import dataclasses as dc
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config, reduced
        from repro.dist import make_shard_ctx
        from repro.models import model as M, moe
        from repro.models.nn import split_params

        cfg = reduced(get_config("qwen3-moe-30b-a3b"))
        cfg = dc.replace(cfg, num_experts=4, top_k=2, capacity_factor=8.0)
        values, _ = split_params(M.init_params(cfg, jax.random.PRNGKey(0)))
        p = jax.tree.map(lambda a: a[0], values["layers"])["moe"]
        x = jax.random.normal(jax.random.PRNGKey(2), (8, 16, cfg.d_model))
        y_local, _ = moe.moe_forward(p, cfg, x, None)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        ctx = dc.replace(make_shard_ctx(mesh, 8), moe_impl="ep")
        with jax.set_mesh(mesh):
            y_ep, st = jax.jit(
                lambda p, x: moe.moe_forward(p, cfg, x, ctx))(p, x)
        err = float(jnp.max(jnp.abs(y_local - y_ep)))
        assert err < 2e-3, err
        assert float(st.dropped_frac) == 0.0
        print("OK", err)
    """)


def test_fsdp_matches_replicated_trainer():
    """FSDP + blockwise-CGC step == replicated CGC step (no outliers)."""
    _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        import repro.dist.fsdp as F
        F.MIN_FSDP_ELEMS = 1 << 10
        from repro.configs import get_config, reduced
        from repro.data import make_batch_iterator
        from repro.launch.train import (TrainSettings, make_train_step,
                                        make_fsdp_train_step)
        from repro.models import model as M
        from repro.models.nn import split_params
        from repro.optim import sgd

        cfg = reduced(get_config("qwen3-0.6b"), layers=2, d_model=256)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        opt = sgd(0.05)
        values, _ = split_params(M.init_params(cfg, jax.random.PRNGKey(0)))
        state = opt.init(values)
        st = TrainSettings(aggregator="cgc", f=1, fsdp=True)
        fsdp_step, ctx, (vshard, plan) = make_fsdp_train_step(
            cfg, opt, st, mesh, 8)
        rep_step, _ = make_train_step(
            cfg, opt, TrainSettings(aggregator="cgc", f=1), mesh, 8)
        batch = next(make_batch_iterator(cfg, 8, 32, seed=0))
        with jax.set_mesh(mesh):
            vP = jax.device_put(values, vshard)
            v1, s1, m1 = jax.jit(fsdp_step)(vP, state, batch,
                                            jnp.asarray(0))
            v2, s2, m2 = jax.jit(rep_step)(values, state, batch,
                                           jnp.asarray(0))
        assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-5
        d = max(float(jnp.max(jnp.abs(
            a.astype(jnp.float32) - b.astype(jnp.float32))))
            for a, b in zip(jax.tree.leaves(v1), jax.tree.leaves(v2)))
        assert d < 5e-4, d
        print("OK", d)
    """)


def test_echo_dp_optimistic_training():
    """Echo-compressed DP aggregation: fast path engages, loss converges."""
    _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config, reduced
        from repro.data import make_batch_iterator
        from repro.launch.train import (TrainSettings, make_train_step,
                                        make_echo_train_step)
        from repro.models import model as M
        from repro.models.nn import split_params
        from repro.optim import sgd
        from repro.dist.echo_dp import init_basis, roll_basis

        cfg = reduced(get_config("xlstm-125m"), layers=2, d_model=128)
        mesh = jax.make_mesh((8,), ("data",))
        opt = sgd(0.02)
        values, _ = split_params(M.init_params(cfg, jax.random.PRNGKey(0)))
        state = opt.init(values)
        K = 4
        st = TrainSettings(aggregator="cgc", f=1, echo_k=K, echo_r=0.98,
                           return_aggregate=True)
        echo_step, _ = make_echo_train_step(cfg, opt, st, mesh, 32)
        full_step, _ = make_train_step(cfg, opt, st, mesh, 32)
        ej, fj = jax.jit(echo_step), jax.jit(full_step)
        basis = init_basis(values, K)
        it = make_batch_iterator(cfg, 32, 128, seed=0)
        n_fast, losses = 0, []
        with jax.set_mesh(mesh):
            for s in range(16):
                b = next(it)
                v2, s2, m, agg = ej(values, state, b, jnp.asarray(s), basis)
                if bool(m["all_echo"]):
                    values, state = v2, s2
                    n_fast += 1
                else:
                    values, state, m, agg = fj(values, state, b,
                                               jnp.asarray(s))
                basis = roll_basis(basis, agg)
                losses.append(float(m["loss"]))
        assert np.isfinite(losses).all()
        assert losses[-1] < losses[0]
        assert n_fast >= 4, n_fast      # fast path engages after warm-up
        print("OK fast:", n_fast, "loss:", losses[0], "->", losses[-1])
    """)


def test_byzantine_resistance_end_to_end():
    """CGC training under sign-flip beats mean aggregation (loss-wise)."""
    _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config, reduced
        from repro.data import make_batch_iterator
        from repro.launch.train import TrainSettings, make_train_step
        from repro.models import model as M
        from repro.models.nn import split_params
        from repro.optim import sgd

        cfg = reduced(get_config("xlstm-125m"), layers=2, d_model=128)
        mesh = jax.make_mesh((8,), ("data",))

        def run(aggregator, f):
            opt = sgd(0.05)
            params = M.init_params(cfg, jax.random.PRNGKey(0))
            values, _ = split_params(params)
            state = opt.init(values)
            fn, _ = make_train_step(
                cfg, opt,
                TrainSettings(aggregator=aggregator, f=f, n_byz=2,
                              byz_mode="large_norm"),
                mesh, global_batch=8)
            it = make_batch_iterator(cfg, 8, 32, seed=3)
            with jax.set_mesh(mesh):
                jf = jax.jit(fn)
                for s in range(10):
                    values, state, m = jf(values, state, next(it),
                                          jnp.asarray(s))
            return float(m["loss"])

        loss_cgc = run("cgc", 2)
        loss_mean = run("mean", 0)
        assert np.isfinite(loss_cgc)
        assert loss_cgc < loss_mean or not np.isfinite(loss_mean), (
            loss_cgc, loss_mean)
        print("OK", loss_cgc, loss_mean)
    """)
