"""The bench trajectory plane: BENCH_*.json records + the regression gate.

``benchmarks/bench_io.py`` owns the record schema the CI bench-smoke leg
gates on; these tests pin load/append/gate semantics without running any
actual benchmark.
"""
import json
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks import bench_io  # noqa: E402


def test_append_and_load_records(tmp_path):
    path = str(tmp_path / "sub" / "BENCH_kernels.json")
    assert bench_io.load_records(path) == []
    rec = bench_io.append_record(path, {"fused_speedup": 1.5}, sha="abc123")
    assert rec["git_sha"] == "abc123"
    assert set(rec) == {"git_sha", "dirty", "timestamp", "metrics"}
    assert rec["dirty"] is False            # explicit sha -> clean stamp
    bench_io.append_record(path, {"fused_speedup": 1.6}, sha="def456")
    records = bench_io.load_records(path)
    assert [r["git_sha"] for r in records] == ["abc123", "def456"]
    assert records[-1]["metrics"] == {"fused_speedup": 1.6}
    # the file is plain JSON (an array), readable without bench_io
    assert json.loads((tmp_path / "sub" / "BENCH_kernels.json")
                      .read_text()) == records


def test_append_defaults_to_repo_sha(tmp_path):
    rec = bench_io.append_record(str(tmp_path / "BENCH_train.json"),
                                 {"echo_rate": 0.8})
    assert isinstance(rec["git_sha"], str) and rec["git_sha"]
    assert "T" in rec["timestamp"]          # isoformat
    # no sha override: dirty reflects the actual working tree
    assert rec["dirty"] == bench_io.git_dirty()
    # an explicit dirty flag wins over both defaults
    rec = bench_io.append_record(str(tmp_path / "BENCH_train.json"),
                                 {"echo_rate": 0.8}, sha="abc", dirty=True)
    assert rec["dirty"] is True


def test_bench_path_naming(tmp_path):
    p = bench_io.bench_path("serve", str(tmp_path))
    assert p == str(tmp_path / "BENCH_serve.json")
    # default out_dir is the repo root
    assert bench_io.bench_path("train").endswith(
        os.path.join("repo", "BENCH_train.json")) or \
        bench_io.bench_path("train").startswith(bench_io.REPO_ROOT)
    with pytest.raises(KeyError):
        bench_io.bench_path("nope", str(tmp_path))


def test_gate_directions_and_threshold():
    last = {"fused_speedup": 2.0, "p99_s": 1.0, "extra": 5.0}
    dirs = {"fused_speedup": "higher", "p99_s": "lower"}
    # inside tolerance both ways
    assert bench_io.gate(last, {"fused_speedup": 1.61, "p99_s": 1.19},
                         dirs) == []
    # "higher" metric dropping >20% fails
    fails = bench_io.gate(last, {"fused_speedup": 1.59, "p99_s": 1.0}, dirs)
    assert len(fails) == 1 and "fused_speedup" in fails[0]
    # "lower" metric rising >20% fails
    fails = bench_io.gate(last, {"fused_speedup": 2.0, "p99_s": 1.21}, dirs)
    assert len(fails) == 1 and "p99_s" in fails[0]
    # custom threshold
    assert bench_io.gate(last, {"fused_speedup": 1.1}, dirs,
                         threshold=0.5) == []
    # ungated keys are ignored; gated keys missing from either side skip
    assert bench_io.gate({"extra": 5.0}, {"extra": 1.0}, dirs) == []
    assert bench_io.gate(last, {"p99_s": 0.9}, dirs) == []
    with pytest.raises(ValueError):
        bench_io.gate(last, last, {"fused_speedup": "sideways"})


def test_gate_boolean_flags():
    """Correctness flags ride the gate as 1.0/0.0 'higher' metrics: a
    flag flipping true->false is a >20% drop and fails."""
    dirs = {"cgc_fused_bitwise_jnp": "higher"}
    assert bench_io.gate({"cgc_fused_bitwise_jnp": 1.0},
                         {"cgc_fused_bitwise_jnp": 1.0}, dirs) == []
    fails = bench_io.gate({"cgc_fused_bitwise_jnp": 1.0},
                          {"cgc_fused_bitwise_jnp": 0.0}, dirs)
    assert len(fails) == 1
