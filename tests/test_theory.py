"""Closed-form theory (paper Sec. 4) against its own stated numbers."""
import numpy as np
import pytest

from repro.core import theory


def test_k_star_matches_paper():
    # Lemma 2: k* ~= 1.12, supremum near x ~= 1.91
    ks = theory.k_star(400001, 30.0)
    assert abs(ks - 1.1157) < 2e-3
    assert abs(theory.K_STAR - ks) < 2e-3


def test_k_x_properties():
    xs = np.linspace(1, 100, 500)
    k = theory.k_x(xs)
    assert np.all(np.diff(k) > 0)            # increasing (used in Lemma 3)
    assert k[0] == pytest.approx(1.0)        # k_1 = 1
    assert np.all(k <= theory.K_STAR * np.sqrt(xs) + 1e-9)  # Lemma 2


def test_r_max_positive_iff_resilience():
    n, L, mu, sigma = 50, 1.0, 1.0, 0.1
    f_ok = 5
    assert theory.resilience_condition(n, f_ok, L, mu)
    assert theory.r_max_lemma4(n, f_ok, L, mu, sigma) > 0
    f_bad = int(n * mu / ((3 + theory.K_STAR) * L)) + 1
    assert not theory.resilience_condition(n, f_bad, L, mu)


def test_lemma4_implies_lemma3():
    # r satisfying Eq. 15 must satisfy Eq. 14 under Assumption 6.
    n, f, L, mu = 64, 6, 1.2, 0.9
    sigma = 0.9 / np.sqrt(n)                 # sigma < 1/sqrt(n)
    r4 = theory.r_max_lemma4(n, f, L, mu, sigma)
    r3 = theory.r_max_lemma3(n, f, L, mu, sigma)
    assert 0 < r4 < r3


def test_beta_positive_for_admissible_r():
    n, f, L, mu, sigma = 40, 4, 1.0, 0.8, 0.1
    r = 0.9 * theory.r_max_lemma4(n, f, L, mu, sigma)
    b = theory.beta(n, f, n - f, f, L, mu, r, sigma)
    assert b > 0                             # Lemma 4


def test_rho_in_unit_interval():
    n, f, L, mu, sigma = 30, 3, 1.0, 1.0, 0.1
    r, eta, b, g, rho = theory.pick_r_eta(n, f, L, mu, sigma)
    assert 0 <= rho < 1                      # Theorem 5
    # eta* minimises rho; doubling eta stays < 1 (open interval bound)
    rho2 = theory.rho(1.99 * eta, b, g)
    assert rho2 < 1.0


def test_comm_ratio_headline():
    # Sec 4.3: sigma=0.1, x=0.1, mu/L=1, n=100 -> save > 75%
    C = theory.comm_ratio_C(0.1, 0.1, 1.0, 100)
    assert C < 0.25
    # Fig 1c: x < 0.15 -> C < 0.45 (paper: "as x<0.15, C<0.4")
    assert theory.comm_ratio_C(0.1, 0.14, 1.0, 100) < 0.45
    # blow-up at x_max
    xm = theory.x_max(0.1, 1.0, 100)
    assert theory.comm_ratio_C(0.1, xm + 0.01, 1.0, 100) == float("inf")


def test_comm_ratio_monotonic_in_sigma():
    Cs = [theory.comm_ratio_C(s, 0.1, 1.0, 100)
          for s in (0.02, 0.05, 0.08, 0.1)]
    assert all(a < b for a, b in zip(Cs, Cs[1:]))


def test_echo_probability():
    assert theory.echo_probability(0.5, 0.1) == pytest.approx(0.75)
    assert theory.echo_probability(1e9, 0.0) == pytest.approx(1.0)


def test_expected_bits_reduction():
    n, d = 100, 10 ** 6
    p = 0.9
    ours = theory.expected_bits_per_round(n, d, p)
    prior = theory.prior_bits_per_round(n, d)
    assert ours / prior < (1 - p) + 0.02     # ~ C = 1 - p
