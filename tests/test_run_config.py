"""RunConfig tree: lossless JSON round-trip, dotted-path overrides,
config hashing, schema guards (repro.run, DESIGN.md §8)."""
import dataclasses
import itertools
import json

import pytest

from repro.run import (RunConfig, SCHEMA_VERSION, DataSpec, DryrunSpec,
                       MeshSpec, ModelSpec, SamplingSpec, ScenarioSpec,
                       ServeSpec, TrainSpec, apply_overrides, available,
                       config_hash)


def _roundtrip(cfg: RunConfig) -> RunConfig:
    return RunConfig.from_json(cfg.to_json())


def test_default_roundtrip_and_schema_version():
    cfg = RunConfig()
    data = json.loads(cfg.to_json())
    assert data["schema_version"] == SCHEMA_VERSION
    assert _roundtrip(cfg) == cfg


def test_roundtrip_every_registered_scenario_combination():
    """from_json(to_json(cfg)) == cfg for every registered
    aggregator x attack x strategy (the acceptance-criterion sweep)."""
    names = available()
    combos = list(itertools.product(names["collective_aggregators"],
                                    names["attacks"],
                                    names["train_strategies"]))
    assert len(combos) >= 6 * 9 * 3
    for agg, attack, strategy in combos:
        cfg = RunConfig(
            name=f"{agg}-{attack}-{strategy}",
            model=ModelSpec(arch="qwen3-0.6b", smoke=True),
            scenario=ScenarioSpec(aggregator=agg, attack=attack, f=1,
                                  echo_r=0.75),
            train=TrainSpec(strategy=strategy, steps=3, lr=1e-3),
            serve=ServeSpec(sampling=SamplingSpec(temperature=0.7,
                                                  top_k=5, seed=2)))
        back = _roundtrip(cfg)
        assert back == cfg, (agg, attack, strategy)
        assert config_hash(back) == config_hash(cfg)


def test_roundtrip_none_sections_and_quadratic_data():
    cfg = RunConfig(
        model=None,
        scenario=ScenarioSpec(data=DataSpec(source="quadratic", dim=64,
                                            mu=0.25, L=2.0, noise=1e-3)),
        train=TrainSpec(strategy="echo_dp", optimizer="sgd", lr=0.02),
        serve=None, dryrun=DryrunSpec(variant="fsdp", compile=False))
    back = _roundtrip(cfg)
    assert back == cfg and back.model is None and back.serve is None
    assert back.dryrun.compile is False


def test_from_json_rejects_unknown_keys_listing_alternatives():
    bad = json.dumps({"schema_version": SCHEMA_VERSION, "trian": {}})
    with pytest.raises(ValueError) as e:
        RunConfig.from_json(bad)
    assert "trian" in str(e.value) and "train" in str(e.value)

    nested = json.dumps({"schema_version": SCHEMA_VERSION,
                         "train": {"step": 3}})
    with pytest.raises(ValueError, match="steps"):
        RunConfig.from_json(nested)


def test_from_json_rejects_wrong_schema_version_and_types():
    with pytest.raises(ValueError, match="schema_version"):
        RunConfig.from_json(json.dumps({"schema_version": 999}))
    with pytest.raises(ValueError, match="missing 'schema_version'"):
        RunConfig.from_json(json.dumps({"name": "x"}))
    with pytest.raises(ValueError, match="expected int"):
        RunConfig.from_json(json.dumps(
            {"schema_version": SCHEMA_VERSION,
             "train": {"steps": "three"}}))
    # hand-written integer literals are fine for float fields
    cfg = RunConfig.from_json(json.dumps(
        {"schema_version": SCHEMA_VERSION, "train": {"lr": 1}}))
    assert cfg.train.lr == 1.0 and isinstance(cfg.train.lr, float)


def test_apply_overrides_types_and_sections():
    cfg = RunConfig(train=TrainSpec())
    out = apply_overrides(cfg, ["train.steps=7", "train.lr=0.01",
                                "train.resume=true",
                                "scenario.data.source=quadratic",
                                "model.smoke=true", "name=sweep-3",
                                "train.ckpt_dir=/tmp/x"])
    assert out.train.steps == 7 and out.train.lr == 0.01
    assert out.train.resume is True
    assert out.scenario.data.source == "quadratic"
    assert out.model.smoke is True and out.name == "sweep-3"
    assert out.train.ckpt_dir == "/tmp/x"
    assert out != cfg and _roundtrip(out) == out
    # optional leaf clears back to None
    assert apply_overrides(out,
                           ["train.ckpt_dir=none"]).train.ckpt_dir is None


def test_apply_overrides_materialises_absent_section():
    cfg = RunConfig(serve=None)
    out = apply_overrides(cfg, ["serve.max_batch=2",
                                "serve.sampling.temperature=0.5"])
    assert out.serve.max_batch == 2
    assert out.serve.sampling.temperature == 0.5
    # untouched fields take the section defaults
    assert out.serve.page_size == ServeSpec().page_size


def test_apply_overrides_error_messages():
    cfg = RunConfig(train=TrainSpec())
    with pytest.raises(ValueError, match="no field"):
        apply_overrides(cfg, ["train.stepz=3"])
    with pytest.raises(ValueError, match="section, not a"):
        apply_overrides(cfg, ["train=3"])
    with pytest.raises(ValueError, match="leaf field, not a section"):
        apply_overrides(cfg, ["train.steps.x=3"])
    with pytest.raises(ValueError, match="key.path=value"):
        apply_overrides(cfg, ["train.steps"])
    with pytest.raises(ValueError, match="expected int"):
        apply_overrides(cfg, ["train.steps=many"])
    with pytest.raises(ValueError, match="bool"):
        apply_overrides(cfg, ["train.resume=maybe"])


def test_config_hash_tracks_content():
    a = RunConfig(train=TrainSpec(steps=3))
    b = RunConfig(train=TrainSpec(steps=4))
    assert config_hash(a) != config_hash(b)
    assert config_hash(a) == config_hash(dataclasses.replace(a))
    assert len(config_hash(a)) == 64


def test_frozen_tree():
    cfg = RunConfig()
    with pytest.raises(dataclasses.FrozenInstanceError):
        cfg.name = "x"
    with pytest.raises(dataclasses.FrozenInstanceError):
        cfg.mesh.devices = 3


# ---------------------------------------------------------------------------
# Comm section + sweep grid expansion
# ---------------------------------------------------------------------------


def test_comm_spec_roundtrip_and_overrides():
    """scenario.comm rides the same JSON round-trip + --set machinery as
    every other section, and legacy job files (no comm key) still load
    with the ideal fp32 defaults."""
    from repro.run import CommSpec, ScenarioSpec

    cfg = RunConfig(scenario=ScenarioSpec(
        comm=CommSpec(channel="lossy", codec="int8", drop_prob=0.1,
                      seed=3)))
    assert RunConfig.from_json(cfg.to_json()) == cfg
    out = apply_overrides(RunConfig(), [
        "scenario.comm.codec=int8", "scenario.comm.channel=lossy",
        "scenario.comm.drop_prob=0.25"])
    assert out.scenario.comm.codec == "int8"
    assert out.scenario.comm.drop_prob == 0.25
    legacy = RunConfig.from_json(
        '{"schema_version": 1, "scenario": {"aggregator": "cgc"}}')
    assert legacy.scenario.comm == CommSpec()
    with pytest.raises(ValueError, match="no field"):
        apply_overrides(RunConfig(), ["scenario.comm.drop=0.1"])


def test_sweep_expands_grid_and_emits_job_files(tmp_path):
    from repro.run import sweep

    base = RunConfig(name="base", train=TrainSpec())
    grid = {"train.lr": [1e-3, 3e-4], "scenario.f": [0, 1, 2]}
    cfgs = sweep(base, grid, out_dir=str(tmp_path))
    assert len(cfgs) == 6
    # row-major in grid insertion order; values land typed
    assert [c.train.lr for c in cfgs] == [1e-3] * 3 + [3e-4] * 3
    assert [c.scenario.f for c in cfgs] == [0, 1, 2, 0, 1, 2]
    assert all(isinstance(c.scenario.f, int) for c in cfgs)
    # names are unique and suffixed with the point's assignment
    names = [c.name for c in cfgs]
    assert len(set(names)) == 6 and all(n.startswith("base-") for n in names)
    # one loadable job file per point == the sweep reruns from artifacts
    import os
    files = sorted(os.listdir(tmp_path))
    assert len(files) == 6
    for cfg in cfgs:
        back = RunConfig.load(str(tmp_path / f"{cfg.name}.json"))
        assert back == cfg


def test_sweep_validates_its_grid():
    from repro.run import sweep

    base = RunConfig(train=TrainSpec())
    with pytest.raises(ValueError, match="at least one"):
        sweep(base, {})
    with pytest.raises(ValueError, match="no values"):
        sweep(base, {"train.lr": []})
    with pytest.raises(ValueError, match="no field"):
        sweep(base, {"train.lrz": [1.0]})
    # two values that sanitize to the same name suffix would clobber
    # each other's job file — rejected instead of silently overwriting
    with pytest.raises(ValueError, match="collide"):
        sweep(base, {"scenario.attack": ["sign flip", "sign-flip"]})
