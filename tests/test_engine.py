"""Engine + driver-loop tests (repro.launch.engine).

The multi-round echo-DP driver checks run in a subprocess with 8 fake
CPU devices (the session process already initialised jax with a single
device); the single-device Trainer checks (resume equivalence) run
in-process.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np


def _run_subprocess(body: str):
    """Run a snippet under 8 fake CPU devices; raise on failure."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        os.environ["JAX_PLATFORMS"] = "cpu"
    """) + textwrap.dedent(body)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=600)
    if r.returncode != 0:
        raise AssertionError(f"subprocess failed:\n{r.stdout}\n{r.stderr}")
    return r.stdout


def test_echo_driver_multi_round_quadratic():
    """The real driver loop on a quadratic cost: (a) fallback rounds are
    bit-for-bit the plain CGC step, (b) the basis rolls exactly on raw
    (fallback) rounds — successful echo rounds reuse it unchanged,
    mirroring the paper where only RAW broadcasts enter the reference
    set R — and (c) cumulative bit accounting lands well below the
    all-raw baseline."""
    _run_subprocess("""
        import copy
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import costfns
        from repro.core.types import echo_bits, raw_bits
        from repro.launch.engine import (EchoDpStrategy, ReplicatedStrategy,
                                         Trainer, TrainerConfig,
                                         TrainSettings)
        from repro.optim import sgd

        n, d, K, rounds = 8, 128, 4, 16
        shocks = (5, 9)        # rounds whose worker noise breaks Eq. 7
        cost = costfns.quadratic(jax.random.PRNGKey(0), d=d, mu=0.5, L=1.0,
                                 sigma=0.0)

        def loss_fn(values, batch):
            w = values["w"]
            return cost.value(w) + w @ jnp.mean(batch["eps"], 0), {}

        def batch_for(step):
            scale = 10.0 if step in shocks else 1e-4
            key = jax.random.fold_in(jax.random.PRNGKey(7), step)
            return {"eps": scale * jax.random.normal(key, (n, d))}

        mesh = jax.make_mesh((8,), ("data",))
        opt = sgd(0.02)
        settings = TrainSettings(aggregator="cgc", f=1, echo_k=K,
                                 echo_r=0.9)
        tr = Trainer(EchoDpStrategy(loss_fn=loss_fn), None, opt, settings,
                     mesh, n, TrainerConfig(log_every=100))
        values = {"w": jnp.ones((d,)) * 2.0}
        state = tr.init_state(values)

        # an independently built plain CGC step (what the driver must
        # fall back to, bit for bit)
        plain = jax.jit(ReplicatedStrategy(loss_fn=loss_fn).build(
            None, opt, type(settings)(aggregator="cgc", f=1,
                                      return_aggregate=True),
            mesh, n).fn)

        recs = []
        with jax.set_mesh(mesh):
            for s in range(rounds):
                batch = batch_for(s)
                # the fallback step donates (values, opt_state), so the
                # replay oracle below needs its own copies of the
                # pre-round buffers
                pre = type(state)(jax.tree.map(jnp.copy, state.values),
                                  jax.tree.map(jnp.copy, state.opt_state),
                                  state.step, state.basis)
                state, rec = tr.run_round(state, batch)
                recs.append(rec)
                if not rec["all_echo"]:
                    # (a) bit-for-bit identical to the plain CGC step
                    v2, o2, m2, agg2 = plain(pre.values, pre.opt_state,
                                             batch, jnp.asarray(pre.step))
                    for a, b in zip(jax.tree.leaves(state.values),
                                    jax.tree.leaves(v2)):
                        assert np.array_equal(np.asarray(a), np.asarray(b))
                    # ...and the rolled-in basis entry IS that aggregate
                    for a, b in zip(jax.tree.leaves(state.basis[-1]),
                                    jax.tree.leaves(agg2)):
                        assert np.array_equal(np.asarray(a), np.asarray(b))
                    assert rec["basis_rolled"]
                else:
                    # (b) successful echo rounds leave the basis alone
                    assert state.basis is pre.basis
                    assert not rec["basis_rolled"]

        flags = [r["all_echo"] for r in recs]
        assert not flags[0]                      # zero basis: raw round
        for s in shocks:
            assert not flags[s], flags           # shocks force fallback
        assert sum(flags) >= rounds - 5, flags   # fast path dominates
        # (c) cumulative bits far below the all-raw baseline
        assert tr.bits_baseline == rounds * n * raw_bits(d)
        n_raw = rounds - sum(flags)
        want = rounds * n * int(echo_bits(n, K)) + n_raw * n * raw_bits(d)
        assert tr.bits_sent == want, (tr.bits_sent, want)
        assert tr.bits_sent < 0.5 * tr.bits_baseline
        losses = [r["loss"] for r in recs]
        assert np.isfinite(losses).all()
        assert min(losses) < losses[0]

        # checkpoint round-trips the full echo state (incl. the basis)
        import tempfile
        tmp = tempfile.mkdtemp()
        tr.config = type(tr.config)(ckpt_dir=tmp)
        tr.save(state)
        back = tr.restore(tr.init_state(values))
        assert back.step == state.step
        for a, b in zip(jax.tree.leaves(back.basis),
                        jax.tree.leaves(state.basis)):
            assert np.array_equal(np.asarray(a), np.asarray(b))
        print("OK", flags)
    """)


def test_trainer_resume_equivalence():
    """fit -> checkpoint -> resume == uninterrupted run (values and
    optimizer moments restored, not just weights)."""
    from repro.launch.engine import (ReplicatedStrategy, Trainer,
                                     TrainerConfig, TrainSettings)
    from repro.optim import adamw

    d = 16

    def loss_fn(values, batch):
        w = values["w"]
        return 0.5 * jnp.sum((w - 1.0) ** 2) + w @ jnp.mean(
            batch["eps"], 0), {}

    def batch_for(step):
        key = jax.random.fold_in(jax.random.PRNGKey(3), step)
        return {"eps": 0.05 * jax.random.normal(key, (4, d))}

    values = {"w": jnp.zeros((d,))}
    settings = TrainSettings(aggregator="mean")

    def make(cfg):
        return Trainer(ReplicatedStrategy(loss_fn=loss_fn), None,
                       adamw(0.1), settings, None, 4, cfg,
                       printer=lambda s: None)

    trA = make(TrainerConfig())
    sA = trA.init_state(values)
    for s in range(8):
        sA, _ = trA.run_round(sA, batch_for(s))

    import tempfile
    tmp = tempfile.mkdtemp()
    trB = make(TrainerConfig(ckpt_dir=tmp))
    sB = trB.init_state(values)
    for s in range(4):
        sB, _ = trB.run_round(sB, batch_for(s))
    trB.save(sB)

    trC = make(TrainerConfig(ckpt_dir=tmp, resume=True))
    sC = trC.init_state(values)
    assert sC.step == 4
    for s in range(4, 8):
        sC, _ = trC.run_round(sC, batch_for(s))

    np.testing.assert_allclose(np.asarray(sA.values["w"]),
                               np.asarray(sC.values["w"]), rtol=1e-6)
    for a, c in zip(jax.tree.leaves(sA.opt_state),
                    jax.tree.leaves(sC.opt_state)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c), rtol=1e-6)


def test_trainer_async_checkpoint_resume_equivalence():
    """fit() checkpoints on the background writer (ckpt_every saves never
    block the driver); after close() the snapshot is durable and a
    resumed run continues bit-identically to an uninterrupted one."""
    import itertools
    import tempfile

    from repro.launch.engine import (ReplicatedStrategy, Trainer,
                                     TrainerConfig, TrainSettings)
    from repro.optim import adamw

    d = 16

    def loss_fn(values, batch):
        w = values["w"]
        return 0.5 * jnp.sum((w - 1.0) ** 2) + w @ jnp.mean(
            batch["eps"], 0), {}

    def batches(start=0):
        for s in itertools.count(start):
            key = jax.random.fold_in(jax.random.PRNGKey(3), s)
            yield {"eps": 0.05 * jax.random.normal(key, (4, d))}

    values = {"w": jnp.zeros((d,))}
    settings = TrainSettings(aggregator="mean")

    def make(cfg):
        return Trainer(ReplicatedStrategy(loss_fn=loss_fn), None,
                       adamw(0.1), settings, None, 4, cfg,
                       printer=lambda s: None)

    trA = make(TrainerConfig())
    sA, _ = trA.fit(trA.init_state(values), batches(), 8)

    tmp = tempfile.mkdtemp()
    trB = make(TrainerConfig(ckpt_dir=tmp, ckpt_every=2))
    sB, _ = trB.fit(trB.init_state(values), batches(), 5)
    trB.close()                        # flush-on-close makes saves durable

    trC = make(TrainerConfig(ckpt_dir=tmp, resume=True))
    sC = trC.init_state(values)
    assert sC.step == 5                # resumed from the async final save
    sC, _ = trC.fit(sC, batches(start=5), 8)

    np.testing.assert_array_equal(np.asarray(sA.values["w"]),
                                  np.asarray(sC.values["w"]))
    for a, c in zip(jax.tree.leaves(sA.opt_state),
                    jax.tree.leaves(sC.opt_state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(c))


def test_strategies_registry_and_bundle_contract():
    """All strategies build through the one engine skeleton; the
    replicated no-mesh bundle keeps the (values, opt_state, metrics)
    contract."""
    from repro.configs import get_config, reduced
    from repro.data import train_inputs
    from repro.launch.engine import STRATEGIES, Trainer, TrainerConfig, \
        TrainSettings
    from repro.models import model as M
    from repro.models.nn import split_params
    from repro.optim import sgd

    assert set(STRATEGIES) == {"replicated", "fsdp", "echo_dp"}
    cfg = reduced(get_config("qwen3-0.6b"), layers=2, d_model=128)
    opt = sgd(0.05)

    # FSDP has no replicated aggregate to emit — build must refuse
    import pytest
    from repro.dist import abstract_mesh
    with pytest.raises(ValueError, match="return_aggregate"):
        STRATEGIES["fsdp"]().build(
            cfg, opt, TrainSettings(fsdp=True, return_aggregate=True),
            abstract_mesh((8,), ("data",)), 8)
    b = STRATEGIES["replicated"]().build(cfg, opt, TrainSettings(), None, 4)
    assert not b.needs_basis and b.value_shardings is None
    values, _ = split_params(M.init_params(cfg, jax.random.PRNGKey(0)))
    batch = train_inputs(jax.random.PRNGKey(1), cfg, 4, 16)
    v, o, m = jax.jit(b.fn)(values, opt.init(values), batch,
                            jnp.asarray(0))
    assert np.isfinite(float(m["loss"]))

    tr = Trainer("replicated", cfg, opt, TrainSettings(), None, 4,
                 TrainerConfig(), printer=lambda s: None)
    state = tr.init_state(values)
    state, rec = tr.run_round(state, batch)
    assert state.step == 1 and rec["bits"] == rec["bits_baseline_cumulative"]


def test_metrics_sink_async_flush(tmp_path):
    """The jsonl sink buffers writes on a background thread: emit() never
    blocks on file I/O, flush() is a barrier, close() drains everything."""
    import json

    from repro.launch.engine import MetricsSink

    path = tmp_path / "metrics.jsonl"
    lines_printed = []
    sink = MetricsSink(str(path), log_every=50,
                       printer=lines_printed.append)
    for i in range(200):
        sink.emit({"step": i, "loss": float(i)})
    sink.flush()
    assert len(path.read_text().splitlines()) == 200
    sink.emit({"step": 200, "loss": 0.5})
    sink.close()
    records = [json.loads(l) for l in path.read_text().splitlines()]
    assert len(records) == 201 and records[-1]["step"] == 200
    assert lines_printed and lines_printed[0].startswith("step ")
    sink.close()                       # idempotent
