"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.echo import project_onto_span
from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("n", [4, 13, 32])
@pytest.mark.parametrize("d", [128, 1000, 4096])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_cgc_clip_sweep(n, d, dtype):
    G = (jax.random.normal(KEY, (n, d)) *
         jnp.arange(1, n + 1)[:, None]).astype(dtype)
    f = max(1, n // 4)
    out = ops.cgc_clip(G, f)
    exp = ref.cgc_clip_ref(G, f)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("n,d", [(4, 256), (8, 1000), (16, 2048)])
def test_cgc_norms_sweep(n, d):
    G = jax.random.normal(jax.random.fold_in(KEY, d), (n, d))
    np.testing.assert_allclose(np.asarray(ops.cgc_norms(G)),
                               np.asarray(ref.cgc_norms_ref(G)), rtol=1e-5)


@pytest.mark.parametrize("n,k,d", [(6, 3, 512), (12, 7, 1000), (16, 16, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_echo_project_sweep(n, k, d, dtype):
    key = jax.random.fold_in(KEY, n * d)
    A = jax.random.normal(key, (n, d)).astype(dtype)
    g = jax.random.normal(jax.random.fold_in(key, 1), (d,)).astype(dtype)
    mask = jnp.arange(n) < k
    x, echo = ops.echo_project(A, mask, g)
    x2, echo2 = project_onto_span(A.astype(jnp.float32), mask,
                                  g.astype(jnp.float32))
    tol = 1e-3 if dtype == jnp.float32 else 6e-2
    np.testing.assert_allclose(np.asarray(x), np.asarray(x2), rtol=tol,
                               atol=tol)
    np.testing.assert_allclose(np.asarray(echo), np.asarray(echo2),
                               rtol=tol, atol=tol)


def test_tree_sq_norm_backend_dispatch():
    """The CGC norm path's backend switch: the fused Pallas pass
    (interpret mode here) matches the plain jnp reduction on a
    mixed-shape/dtype gradient pytree."""
    tree = {
        "a": jax.random.normal(KEY, (37, 19)),
        "b": jax.random.normal(jax.random.fold_in(KEY, 1), (301,)),
        "c": jax.random.normal(jax.random.fold_in(KEY, 2), (5,)
                               ).astype(jnp.bfloat16),
        "d": jnp.asarray(2.5),
    }
    assert ops.norm_backend() in ("jnp", "pallas")
    try:
        ops.set_norm_backend("jnp")
        want = float(ops.tree_sq_norm(tree))
        ops.set_norm_backend("pallas")
        got = float(ops.tree_sq_norm(tree))
    finally:
        ops.set_norm_backend("auto")
    np.testing.assert_allclose(got, want, rtol=1e-5)
    assert float(ops.tree_sq_norm({})) == 0.0
    with pytest.raises(ValueError):
        ops.set_norm_backend("nope")
    # dist.collectives.tree_norm rides the same dispatch
    from repro.dist.collectives import tree_norm
    np.testing.assert_allclose(float(tree_norm(tree)), np.sqrt(want),
                               rtol=1e-5)


def test_echo_project_gram_matches_ref():
    A = jax.random.normal(KEY, (8, 1024))
    g = jax.random.normal(jax.random.fold_in(KEY, 1), (1024,))
    from repro.kernels.echo_project import gram_and_proj
    gram, b = gram_and_proj(A, g, 256, interpret=True)
    gram_e, b_e = ref.gram_ref(A, g)
    np.testing.assert_allclose(np.asarray(gram), np.asarray(gram_e),
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(b), np.asarray(b_e), rtol=1e-4)


@pytest.mark.parametrize("B,H,K,T", [(2, 8, 4, 256), (1, 16, 2, 300),
                                     (4, 4, 4, 1024)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(B, H, K, T, dtype):
    hd = 64
    key = jax.random.fold_in(KEY, B * T)
    q = jax.random.normal(key, (B, H, hd)).astype(dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1),
                          (B, T, K, hd)).astype(dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2),
                          (B, T, K, hd)).astype(dtype)
    mask = jax.random.uniform(jax.random.fold_in(key, 3), (B, T)) < 0.8
    out = ops.decode_attention(q, k, v, mask)
    exp = ref.decode_attention_ref(q, k, v, mask)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), rtol=tol,
                               atol=tol)


@pytest.mark.parametrize("B,H,K,P,ps,NB", [(2, 8, 4, 16, 8, 4),
                                           (3, 4, 4, 32, 16, 3),
                                           (1, 16, 2, 8, 8, 7)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_decode_attention_sweep(B, H, K, P, ps, NB, dtype):
    """Pallas paged kernel (scalar-prefetch block-table gather) vs the
    gather-then-attend oracle, mixed lengths incl. an idle lane."""
    hd = 64
    key = jax.random.fold_in(KEY, B * P * ps)
    q = jax.random.normal(key, (B, H, hd)).astype(dtype)
    kp = jax.random.normal(jax.random.fold_in(key, 1),
                           (P, ps, K, hd)).astype(dtype)
    vp = jax.random.normal(jax.random.fold_in(key, 2),
                           (P, ps, K, hd)).astype(dtype)
    perm = jax.random.permutation(jax.random.fold_in(key, 3), P - 1) + 1
    bt = perm[:B * NB].reshape(B, NB).astype(jnp.int32)
    lengths = (jax.random.randint(jax.random.fold_in(key, 4), (B,), 1,
                                  NB * ps + 1)
               .at[0].set(0).astype(jnp.int32))   # lane 0 idle
    from repro.kernels.decode_attention import paged_decode_attention
    out = paged_decode_attention(q, kp, vp, bt, lengths, interpret=True)
    exp = ref.paged_decode_attention_ref(q, kp, vp, bt, lengths)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), rtol=tol,
                               atol=tol)


def test_scale_rows_backend_dispatch():
    """The server-side CGC filter's row-scaling pass: the Pallas
    ``scale_rows`` streaming kernel (interpret mode here) matches plain
    jnp through the ``REPRO_SCALE_BACKEND`` switch, and the protocol's
    ``cgc_filter`` rides the same dispatch."""
    from repro.core.cgc import cgc_filter
    G = jax.random.normal(KEY, (13, 1000)) * \
        jnp.arange(1, 14)[:, None]
    scale = jax.random.uniform(jax.random.fold_in(KEY, 1), (13,))
    assert ops.scale_backend() in ("jnp", "pallas")
    try:
        ops.set_scale_backend("jnp")
        want = np.asarray(ops.scale_rows(G, scale))
        filt_want = np.asarray(cgc_filter(G, 3))
        ops.set_scale_backend("pallas")
        got = np.asarray(ops.scale_rows(G, scale))
        filt_got = np.asarray(cgc_filter(G, 3))
    finally:
        ops.set_scale_backend("auto")
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(filt_got, filt_want, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(filt_want, np.asarray(ref.cgc_clip_ref(G, 3)),
                               rtol=1e-5, atol=1e-5)
    with pytest.raises(ValueError):
        ops.set_scale_backend("nope")


def test_decode_attention_fully_masked_row_safe():
    B, H, K, T, hd = 1, 4, 2, 128, 32
    q = jax.random.normal(KEY, (B, H, hd))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, T, K, hd))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, T, K, hd))
    mask = jnp.zeros((B, T), bool).at[:, 0].set(True)
    out = ops.decode_attention(q, k, v, mask)
    assert np.isfinite(np.asarray(out)).all()
