"""CGC filter (Eq. 8) unit + invariant tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cgc import (cgc_aggregate, cgc_filter, cgc_scales,
                            cgc_threshold)


def _rand(n, d, seed=0, scale_spread=True):
    key = jax.random.PRNGKey(seed)
    G = jax.random.normal(key, (n, d))
    if scale_spread:
        G = G * jnp.arange(1, n + 1)[:, None]
    return G


def test_threshold_is_nf_smallest():
    norms = jnp.array([5.0, 1.0, 3.0, 2.0, 4.0])
    # n=5, f=2 -> (n-f)=3rd smallest = 3.0
    assert float(cgc_threshold(norms, 2)) == 3.0


def test_filter_clips_top_f_only():
    G = _rand(8, 16)
    f = 3
    out = cgc_filter(G, f)
    norms = jnp.linalg.norm(G, axis=1)
    out_norms = jnp.linalg.norm(out, axis=1)
    thr = cgc_threshold(norms, f)
    # every filtered norm <= threshold (+eps)
    assert np.all(np.asarray(out_norms) <= float(thr) * (1 + 1e-5))
    # gradients under the threshold are untouched
    keep = norms <= thr
    np.testing.assert_allclose(np.asarray(out[keep]), np.asarray(G[keep]),
                               rtol=1e-6)


def test_directions_preserved():
    G = _rand(6, 32, seed=1)
    out = cgc_filter(G, 2)
    for i in range(6):
        g, o = np.asarray(G[i]), np.asarray(out[i])
        cos = g @ o / (np.linalg.norm(g) * np.linalg.norm(o))
        assert cos == pytest.approx(1.0, abs=1e-5)


def test_f_zero_is_identity():
    G = _rand(5, 10, seed=2)
    np.testing.assert_allclose(np.asarray(cgc_filter(G, 0)),
                               np.asarray(G), rtol=1e-6)


def test_permutation_equivariance():
    G = _rand(7, 12, seed=3)
    perm = jnp.array([3, 1, 6, 0, 2, 5, 4])
    out1 = cgc_filter(G, 2)[perm]
    out2 = cgc_filter(G[perm], 2)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), rtol=1e-6)


def test_aggregate_bounds_byzantine_influence():
    # A huge Byzantine gradient contributes at most threshold-norm.
    n, d, f = 10, 20, 2
    key = jax.random.PRNGKey(4)
    honest = jax.random.normal(key, (n - 1, d))
    byz = 1e6 * jnp.ones((1, d))
    G = jnp.concatenate([honest, byz])
    agg = cgc_aggregate(G, f)
    norms = jnp.linalg.norm(G, axis=1)
    thr = cgc_threshold(norms, f)
    honest_sum = jnp.sum(cgc_filter(G, f)[:-1], axis=0)
    assert float(jnp.linalg.norm(agg - honest_sum)) <= float(thr) * 1.0001


def test_zero_rows_survive():
    G = jnp.zeros((4, 8)).at[0].set(1.0)
    out = cgc_filter(G, 1)
    assert np.isfinite(np.asarray(out)).all()
