"""Baseline robust aggregators (Krum, medians, trimmed mean)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregators as agg


def _grads_with_outliers(n=10, d=8, n_byz=2, seed=0, spread=0.1):
    key = jax.random.PRNGKey(seed)
    center = jnp.ones((d,))
    honest = center + spread * jax.random.normal(key, (n - n_byz, d))
    byz = -50.0 * jnp.ones((n_byz, d))
    return jnp.concatenate([honest, byz]), center


def test_krum_selects_honest():
    G, center = _grads_with_outliers()
    out = agg.krum(G, f=2)
    assert float(jnp.linalg.norm(out - center)) < 1.0


def test_multi_krum_averages_honest():
    G, center = _grads_with_outliers()
    out = agg.multi_krum(G, f=2)
    assert float(jnp.linalg.norm(out - center)) < 1.0


def test_median_robust():
    G, center = _grads_with_outliers()
    out = agg.coordinate_median(G, 2)
    assert float(jnp.linalg.norm(out - center)) < 1.0


def test_trimmed_mean_robust_and_validates():
    G, center = _grads_with_outliers()
    out = agg.trimmed_mean(G, f=2)
    assert float(jnp.linalg.norm(out - center)) < 1.0
    with pytest.raises(ValueError):
        agg.trimmed_mean(G, f=5)              # n <= 2f


def test_geometric_median_robust():
    G, center = _grads_with_outliers()
    out = agg.geometric_median(G, 2)
    assert float(jnp.linalg.norm(out - center)) < 1.0


def test_mean_not_robust():
    # sanity: the fault-intolerant baseline IS pulled away by the attack
    G, center = _grads_with_outliers()
    out = agg.mean(G, 2)
    assert float(jnp.linalg.norm(out - center)) > 5.0


def test_cgc_sum_scale():
    G, _ = _grads_with_outliers(n_byz=0)
    np.testing.assert_allclose(np.asarray(agg.cgc_mean(G, 0)),
                               np.asarray(agg.mean(G, 0)), rtol=1e-6)
